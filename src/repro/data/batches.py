"""Synthetic batch construction + ShapeDtypeStruct input specs per arch.

`make_batch` materializes data (smoke tests / examples); `input_specs`
returns ShapeDtypeStructs only (dry-run: no allocation).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ModelConfig


def batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    shapes: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.arch_class == "vlm":
        shapes["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    if cfg.arch_class == "encdec":
        shapes["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return shapes


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    out: Dict = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }
    if cfg.arch_class == "vlm":
        out["patch_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.n_image_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.arch_class == "encdec":
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.float32)
    return out


class TokenStream:
    """Deterministic sharded synthetic token pipeline.

    Each data shard draws from a seed derived from (epoch, step, shard), so
    restarts and elastic re-sharding reproduce the same global batch order —
    the property the straggler/fault story relies on (DESIGN.md §6).
    """

    def __init__(self, cfg: ModelConfig, global_batch: int, seq: int,
                 n_shards: int = 1, shard_id: int = 0, seed: int = 1234):
        assert global_batch % n_shards == 0
        self.cfg = cfg
        self.local_batch = global_batch // n_shards
        self.seq = seq
        self.shard_id = shard_id
        self.seed = seed

    def batch_at(self, step: int) -> Dict:
        return make_batch(self.cfg, self.local_batch, self.seq,
                          seed=hash((self.seed, step, self.shard_id)) % (2**31))
