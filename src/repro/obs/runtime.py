"""Runtime range telemetry — per-stage observed min/max, saturation, headroom.

The paper's profile-driven analysis (§IV) bounds each stage's range from a
handful of sample images *before* synthesis; this module closes the loop
from the other side, measuring on the production execution path what the
plan's alpha bits actually cover:

  * **observed range** — finite min/max of the stage's (dequantized f64)
    value array;
  * **observed alpha** — `alpha_for_range` of that observed range, i.e.
    the integral bits this run *needed*;
  * **headroom** — plan alpha minus observed alpha: positive = the static
    plan reserved more bits than this input exercised (a lower bound on
    what a tighter analysis could reclaim), negative would mean runtime
    values escaped the proven range (never, for certified plans);
  * **saturation counts** — pixels sitting exactly on the type's clip
    rails after the snap (`q == int_max`, plus `q == int_min` for signed
    types; an unsigned lower rail of 0 would count every legitimate zero
    pixel).  For stages with a `PhaseSnap`, each sampling-lattice residue
    is counted against its own rails and a per-residue breakdown is
    attached.

Stage arrays are `(y, x)` planes, optionally under any number of leading
batch axes (the batched executors produce `(B, H, W)`): every reduction
runs over *all* leading axes, and the sampling-lattice residue slicing
applies to the trailing two — so batched and per-image-looped telemetry
agree (min/max join, rail counts sum; pinned in tests/test_serving.py).

Everything here is **read-only post-processing** of stage outputs — it
never feeds back into the computation, which is how the tracing-enabled
vs disabled bit-exactness guarantee holds trivially.  It only runs when
the active tracer was created with `runtime_ranges=True` (opt-in: it
materializes and scans every stage array).

Events land in the shared stream as `rt.range` records; see
`repro.obs.report` for the per-stage table and docs/observability.md for
the schema.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from . import tracer as _tracer

__all__ = ["enabled", "record_env", "record_stage"]


def enabled() -> bool:
    """True when an active tracer requested runtime range telemetry."""
    return _tracer.runtime_ranges_enabled()


def _rail_counts(q, t) -> Dict[str, int]:
    """Pixels at the saturation rails of type `t` for qvalues `q`."""
    import numpy as np
    hi = int(np.count_nonzero(q >= t.int_max))
    lo = int(np.count_nonzero(q <= t.int_min)) if t.int_min < 0 else 0
    return {"lo": lo, "hi": hi}


def record_stage(name: str, value, t=None, phase=None,
                 backend: str = "?") -> Optional[dict]:
    """Measure one stage value array and emit an `rt.range` event.

    `t` is the stage's union `FixedPointType` (None = float stage: range
    only, no saturation/headroom).  `phase` is either a
    `lowering.ir.PhaseSnap` or the raw plan form `((My, Mx), {residue:
    type})`; when given, saturation is counted per residue against that
    residue's own rails.  Returns the attr dict (also for tests), or None
    when telemetry is off.
    """
    tr = _tracer.active_tracer()
    if tr is None or not tr.runtime_ranges:
        return None
    import numpy as np

    v = np.asarray(value, dtype=np.float64)
    finite = v[np.isfinite(v)] if not np.all(np.isfinite(v)) else v
    attrs: Dict[str, Any] = {"stage": name, "backend": backend,
                             "n": int(v.size)}
    if finite.size:
        vmin = float(finite.min())
        vmax = float(finite.max())
        attrs["min"] = vmin
        attrs["max"] = vmax
        from repro.core.fixedpoint import alpha_for_range
        attrs["alpha_obs"] = int(alpha_for_range(vmin, vmax))
    if t is not None:
        attrs["type"] = str(t)
        attrs["alpha_plan"] = int(t.alpha)
        if "alpha_obs" in attrs:
            attrs["headroom"] = attrs["alpha_plan"] - attrs["alpha_obs"]
        # saturation: snap back to qvalues (stage arrays are already
        # on-grid, so rint is exact) and count rail hits
        lattice = types = None
        if phase is not None:
            lattice = getattr(phase, "lattice", None)
            types = getattr(phase, "types", None)
            if lattice is None:       # raw plan entry ((My, Mx), {res: t})
                lattice, types = phase
        if lattice is not None and v.ndim >= 2:
            # residues live on the trailing (y, x) axes; leading batch
            # axes pass through the slice so batched rail counts are the
            # sum of the per-image counts
            my, mx = lattice
            sat_lo = sat_hi = 0
            per_res = {}
            for ry in range(my):
                for rx in range(mx):
                    t_res = types.get((ry, rx), t)
                    sub = v[..., ry::my, rx::mx]
                    q = np.rint(sub * (2.0 ** t_res.beta))
                    c = _rail_counts(q, t_res)
                    sat_lo += c["lo"]
                    sat_hi += c["hi"]
                    if c["lo"] or c["hi"]:
                        per_res[f"{ry},{rx}"] = c["lo"] + c["hi"]
            attrs["sat_phases"] = per_res
        else:
            q = np.rint(v * (2.0 ** t.beta))
            c = _rail_counts(q, t)
            sat_lo, sat_hi = c["lo"], c["hi"]
        attrs["sat_lo"] = sat_lo
        attrs["sat_hi"] = sat_hi
        attrs["sat"] = sat_lo + sat_hi
    tr.event("rt.range", **attrs)
    return attrs


def record_env(env: Dict[str, Any], lp, backend: str) -> None:
    """Measure every stage present in `env` against a `LoweredPipeline`'s
    per-stage types (backends call this after execution)."""
    if not enabled():
        return
    for n in lp.order:
        if n in env:
            ls = lp.stages[n]
            record_stage(n, env[n], ls.t, ls.phase, backend=backend)
