"""Per-stage trace summarizer: `python -m repro.obs.report <trace.jsonl>`.

Reads the JSONL stream written by `repro.obs.exporters.write_jsonl` and
prints up to three tables (plain text, or GitHub-flavoured markdown with
`--markdown` — CI appends the latter to the job summary):

  * **analysis passes** — one row per `analysis.pass` span: time and
    memo/disk-cache disposition;
  * **SMT stages** — one row per `smt.stage` span: time, boxes explored,
    boxes/s, budget consumed vs granted, verdict, and a `!budget` marker
    on deadline-exhausted stages;
  * **runtime stages** — execution time per stage (`exec.stage` spans)
    joined with `rt.range` telemetry: observed min/max, saturation
    counts, and alpha headroom (plan bits − observed bits);
  * **pallas islands** — one row per rate island of the fused pallas
    executor (`exec.pallas.island` spans): rate, fused stage count, grid,
    carrier mix, stored-container mix with the boundary-buffer MB it
    materializes and the MB saved vs a uniform int32 baseline, and time
    aggregated over calls;
  * **design search** — per-strategy evaluation rollup (`dse.evaluate`
    spans + cached hits) and the Pareto frontier as accepted during the
    search (`dse.accept` events): psnr / power / area / total bits.

`summarize` / `render` are importable for programmatic use (benchmarks,
examples, tests).
"""
from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List, Optional

__all__ = ["main", "render", "summarize"]


def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or 0 < abs(v) < 0.01:
            return f"{v:.3g}"
        return f"{v:.3f}".rstrip("0").rstrip(".")
    return str(v)


def _table(title: str, cols: List[str], rows: List[Dict[str, Any]],
           markdown: bool) -> str:
    if not rows:
        return ""
    cells = [[_fmt(r.get(c)) for c in cols] for r in rows]
    if markdown:
        lines = [f"#### {title}", "",
                 "| " + " | ".join(cols) + " |",
                 "|" + "|".join("---" for _ in cols) + "|"]
        lines += ["| " + " | ".join(row) + " |" for row in cells]
        return "\n".join(lines) + "\n"
    widths = [max(len(c), *(len(row[i]) for row in cells))
              for i, c in enumerate(cols)]
    sep = "  "
    lines = [f"== {title} ==",
             sep.join(c.ljust(w) for c, w in zip(cols, widths))]
    lines += [sep.join(x.ljust(w) for x, w in zip(row, widths))
              for row in cells]
    return "\n".join(lines) + "\n"


def summarize(records: List[dict]) -> Dict[str, List[Dict[str, Any]]]:
    """Aggregate JSONL records into {passes, smt_stages, runtime} rows."""
    spans = [r for r in records if r.get("kind") == "span"]
    events = [r for r in records if r.get("kind") == "event"]

    passes = []
    for s in spans:
        if s["name"] != "analysis.pass":
            continue
        a = s.get("attrs", {})
        passes.append({
            "pass": a.get("pass", "?"), "column": a.get("column"),
            "ms": s["dur_us"] / 1e3, "memo": a.get("memo"),
        })

    smt_rows = []
    for s in spans:
        if s["name"] != "smt.stage":
            continue
        a = s.get("attrs", {})
        ms = s["dur_us"] / 1e3
        boxes = a.get("boxes")
        row = {
            "stage": a.get("stage", "?"), "ms": ms, "boxes": boxes,
            "boxes/s": (boxes / (ms / 1e3)) if boxes and ms > 0 else None,
            "budget_s": a.get("budget_s"), "consumed_s": a.get("consumed_s"),
            "verdict": a.get("verdict"),
        }
        if a.get("deadline_exhausted"):
            row["verdict"] = f"{row['verdict'] or 'seed'} !budget"
        smt_rows.append(row)

    exec_ms: Dict[str, float] = {}
    for s in spans:
        if s["name"] == "exec.stage":
            st = s.get("attrs", {}).get("stage", "?")
            exec_ms[st] = exec_ms.get(st, 0.0) + s["dur_us"] / 1e3
    runtime = []
    seen = set()
    for e in events:
        if e["name"] != "rt.range":
            continue
        a = e.get("attrs", {})
        st = a.get("stage", "?")
        if st in seen:      # first observation per stage keeps the table small
            continue
        seen.add(st)
        runtime.append({
            "stage": st, "type": a.get("type"),
            "exec_ms": exec_ms.get(st),
            "min": a.get("min"), "max": a.get("max"),
            "sat": a.get("sat"),
            "alpha_plan": a.get("alpha_plan"), "alpha_obs": a.get("alpha_obs"),
            "headroom": a.get("headroom"),
        })
    for st, ms in exec_ms.items():      # spans without telemetry still show
        if st not in seen:
            runtime.append({"stage": st, "exec_ms": ms})

    isl: Dict[tuple, Dict[str, Any]] = {}
    for s in spans:
        if s["name"] != "exec.pallas.island":
            continue
        a = s.get("attrs", {})
        key = (a.get("island"), a.get("rate"), a.get("carriers"),
               a.get("containers"))
        row = isl.setdefault(key, {
            "island": a.get("island"), "rate": a.get("rate"),
            "stages": a.get("stages"), "grid": a.get("grid"),
            "single_tile": a.get("single_tile"),
            "carriers": a.get("carriers"),
            "containers": a.get("containers"),
            "out_mb": a.get("out_mb"), "saved_mb": a.get("saved_mb"),
            "ms": 0.0, "calls": 0,
        })
        row["ms"] += s["dur_us"] / 1e3
        row["calls"] += 1
    islands = sorted(isl.values(), key=lambda r: (r["island"] is None,
                                                  r["island"]))

    # design search: per-strategy evaluation rollup (dse.evaluate spans +
    # cached-hit events) and the frontier as accepted (dse.accept events)
    strat: Dict[tuple, Dict[str, Any]] = {}
    for s in spans:
        if s["name"] != "dse.evaluate":
            continue
        a = s.get("attrs", {})
        key = (a.get("pipeline"), a.get("strategy") or "?")
        row = strat.setdefault(key, {
            "pipeline": key[0], "strategy": key[1],
            "evals": 0, "cached": 0, "ms": 0.0, "best_psnr": None,
        })
        row["evals"] += 1
        row["ms"] += s["dur_us"] / 1e3
        p = a.get("psnr")
        if p is not None and (row["best_psnr"] is None
                              or p > row["best_psnr"]):
            row["best_psnr"] = p
    for e in events:
        if e["name"] != "dse.evaluate":
            continue
        a = e.get("attrs", {})
        key = (a.get("pipeline"), a.get("strategy") or "?")
        if key in strat:
            strat[key]["cached"] += 1
    dse_strategies = sorted(strat.values(),
                            key=lambda r: (str(r["pipeline"]),
                                           -r["evals"], r["strategy"]))

    dse_frontier = []
    for e in events:
        if e["name"] != "dse.accept":
            continue
        a = e.get("attrs", {})
        dse_frontier.append({
            "pipeline": a.get("pipeline"), "strategy": a.get("strategy"),
            "psnr": a.get("psnr"), "power": a.get("power"),
            "area": a.get("area"), "total_bits": a.get("total_bits"),
        })
    dse_frontier.sort(key=lambda r: (str(r["pipeline"]),
                                     r["power"] if r["power"] is not None
                                     else 0.0))

    return {"passes": passes, "smt_stages": smt_rows, "runtime": runtime,
            "islands": islands, "dse_strategies": dse_strategies,
            "dse_frontier": dse_frontier}


def render(summary: Dict[str, List[Dict[str, Any]]],
           markdown: bool = False) -> str:
    parts = [
        _table("analysis passes", ["pass", "column", "ms", "memo"],
               summary["passes"], markdown),
        _table("smt stages",
               ["stage", "ms", "boxes", "boxes/s", "budget_s",
                "consumed_s", "verdict"],
               summary["smt_stages"], markdown),
        _table("runtime stages",
               ["stage", "type", "exec_ms", "min", "max", "sat",
                "alpha_plan", "alpha_obs", "headroom"],
               summary["runtime"], markdown),
        _table("pallas islands",
               ["island", "rate", "stages", "grid", "single_tile",
                "carriers", "containers", "out_mb", "saved_mb",
                "ms", "calls"],
               summary.get("islands", []), markdown),
        _table("design search strategies",
               ["pipeline", "strategy", "evals", "cached", "ms",
                "best_psnr"],
               summary.get("dse_strategies", []), markdown),
        _table("design frontier (accepted points)",
               ["pipeline", "strategy", "psnr", "power", "area",
                "total_bits"],
               summary.get("dse_frontier", []), markdown),
    ]
    out = "\n".join(p for p in parts if p)
    return out if out else "(trace contains no summarizable spans)\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro.obs JSONL trace into per-stage tables.")
    ap.add_argument("trace", help="path to a .jsonl trace file")
    ap.add_argument("--markdown", action="store_true",
                    help="emit GitHub-flavoured markdown tables")
    args = ap.parse_args(argv)
    from .exporters import load_jsonl
    print(render(summarize(load_jsonl(args.trace)), markdown=args.markdown))
    return 0


if __name__ == "__main__":
    sys.exit(main())
