"""`repro.obs` — unified tracing/metrics for analysis, solver, and backends.

Quick start::

    from repro import obs

    with obs.tracing(runtime_ranges=True) as tr:
        plan = run_plan(pipe, ["interval", "smt"])
        run_fixed(pipe, imgs, plan, backend="lowered")
    obs.write_chrome_trace(tr, "trace.json")     # perfetto-loadable
    obs.write_jsonl(tr, "trace.jsonl")           # repro.obs.report input

Submodules: `tracer` (spans/counters core), `exporters` (JSONL + Chrome
trace-event JSON), `runtime` (per-stage range/saturation/headroom
telemetry), `report` (per-stage summary tables, also a CLI:
``python -m repro.obs.report trace.jsonl``).  See docs/observability.md.
"""
from .tracer import (            # noqa: F401
    CounterGroup, Span, Tracer, active_tracer, all_counters, disable,
    enable, event, gauge, is_enabled, runtime_ranges_enabled, span,
    tracing,
)
from .exporters import (         # noqa: F401
    load_jsonl, to_chrome_trace, to_jsonl_records, write_chrome_trace,
    write_jsonl,
)
from .warnonce import reset_warn_once, warn_once   # noqa: F401
from . import runtime            # noqa: F401

__all__ = [
    "CounterGroup", "Span", "Tracer", "active_tracer", "all_counters",
    "disable", "enable", "event", "gauge", "is_enabled", "load_jsonl",
    "reset_warn_once", "runtime", "runtime_ranges_enabled", "span",
    "to_chrome_trace", "to_jsonl_records", "tracing", "warn_once",
    "write_chrome_trace", "write_jsonl",
]
