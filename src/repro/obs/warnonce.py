"""Process-once warning dedupe for capability / fallback notices.

Serving traffic calls the same entry points thousands of times per
second; a capability notice (pallas interpret-mode fallback, plan
disk-cache skip, band-sharding degradation) that fires per call floods
the log and the `warnings` registry.  Every such notice routes through
`warn_once`, which emits each distinct message text exactly once per
process — thread-safe, because the first callers race in from the
serving batcher threads.

Tests that assert on a specific warning clear the registry first
(`reset_warn_once()`, or clear the shared `_WARNED` set directly).
"""
from __future__ import annotations

import threading
import warnings

__all__ = ["warn_once", "reset_warn_once"]

_WARNED: set = set()
_LOCK = threading.Lock()


def warn_once(msg: str, category=RuntimeWarning, stacklevel: int = 3) -> bool:
    """Emit `msg` as a warning the first time it is seen; no-op after.

    Returns True when the warning was actually emitted (first sighting).
    """
    with _LOCK:
        if msg in _WARNED:
            return False
        _WARNED.add(msg)
    warnings.warn(msg, category, stacklevel=stacklevel)
    return True


def reset_warn_once() -> None:
    """Forget every deduped message (test isolation hook)."""
    with _LOCK:
        _WARNED.clear()
