"""Hierarchical span tracer + typed counters — the `repro.obs` core.

Dependency-free (stdlib only) structured instrumentation for the whole
compile path: analysis passes, the SMT tightening loop, and the lowered
execution backends all emit into one event stream so "where did the 30s
stage budget go?" has an answer (docs/observability.md).

Three primitives:

  * **spans** — `with span("smt.stage", stage="det") as sp:` records a
    monotonic `[t0, t1)` interval with nested parent ids (per-thread span
    stacks, so concurrent threads trace independently).  `sp.set(k=v)`
    attaches attributes mid-flight; attributes land in both exporters.
  * **events** — `event("smt.budget_exhausted", stage=...)` is an instant
    marker attached to the current span.
  * **counters / gauges** — `CounterGroup` is a *dict subclass* with a
    lock, `add()` and `reset()`: the three legacy module-global stat dicts
    (`analysis.driver.MEMO_STATS` / `DISK_CACHE_STATS`,
    `smt.solver.STATS`) are byte-compatible shims over it — existing
    `STATS["hits"]`-style reads keep working while mutation is now locked
    and resettable.  `gauge(name, value)` samples a numeric time series.

Tracing is **off by default and free when off**: the module-level `span`
/ `event` / `gauge` helpers check one global and return a shared no-op
object, so the instrumented hot paths cost a pointer compare per call.
Enable with `enable()` / `tracing()`; export with `repro.obs.exporters`
(JSONL + Chrome trace-event JSON, perfetto-loadable).
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "CounterGroup", "Span", "Tracer", "active_tracer", "all_counters",
    "disable", "enable", "event", "gauge", "is_enabled",
    "runtime_ranges_enabled", "span", "tracing",
]


# ---------------------------------------------------------------------------
# typed counters (the legacy-stat-dict mechanism)
# ---------------------------------------------------------------------------

_COUNTER_REGISTRY: Dict[str, "CounterGroup"] = {}
_REGISTRY_LOCK = threading.Lock()


class CounterGroup(dict):
    """A named group of monotonic counters: a locked, resettable dict.

    Subclassing `dict` keeps every legacy consumer byte-compatible
    (`MEMO_STATS["hits"]`, `dict(STATS)`, `.update(...)` all still work)
    while adding what the ad-hoc globals lacked: `add()` mutates under a
    lock (safe for multi-threaded solver use), `reset()` restores the
    declared initial values, and the group registers itself so exporters
    can snapshot every counter in the process (`all_counters()`).

    Values are ints or floats (e.g. `smt.solver.STATS["secs"]`).
    """

    def __init__(self, name: str, **initial):
        super().__init__(**initial)
        self.name = name
        self._initial = dict(initial)
        self._lock = threading.Lock()
        with _REGISTRY_LOCK:
            _COUNTER_REGISTRY[name] = self

    def add(self, key: str, n=1):
        """Locked increment; returns the new value."""
        with self._lock:
            v = self.get(key, 0) + n
            super().__setitem__(key, v)
            return v

    def set(self, key: str, value):
        """Locked gauge-style assignment."""
        with self._lock:
            super().__setitem__(key, value)

    def reset(self) -> None:
        """Restore the declared initial values (drop any extra keys)."""
        with self._lock:
            super().clear()
            super().update(self._initial)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return dict(self)


def all_counters() -> Dict[str, Dict[str, Any]]:
    """{group name: {counter: value}} over every registered group."""
    with _REGISTRY_LOCK:
        groups = list(_COUNTER_REGISTRY.values())
    return {g.name: g.snapshot() for g in groups}


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------

class Span:
    """One finished (or in-flight) span.  Context-manager protocol; use
    through `Tracer.span` / the module-level `span` helper."""

    __slots__ = ("tracer", "name", "attrs", "span_id", "parent_id",
                 "t0", "t1", "thread_id")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = -1
        self.parent_id: Optional[int] = None
        self.t0 = 0.0
        self.t1 = 0.0
        self.thread_id = 0

    def set(self, **attrs) -> "Span":
        """Attach/overwrite attributes (any time before export)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        tr = self.tracer
        self.span_id = next(tr._ids)
        self.thread_id = threading.get_ident()
        stack = tr._stack()
        self.parent_id = stack[-1].span_id if stack else None
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.t1 = time.perf_counter()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:                 # tolerate mis-nested exits
            stack.remove(self)
        self.tracer._record_span(self)
        return False


class _NullSpan:
    """Shared no-op stand-in when tracing is disabled (zero allocation)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False

    def set(self, **attrs):
        return self


_NULL = _NullSpan()


class Tracer:
    """Thread-safe collector of spans, instant events, and gauge samples.

    `runtime_ranges=True` opts the execution backends into per-stage
    observed-range / saturation / alpha-headroom telemetry
    (`repro.obs.runtime`); plain tracing never touches pixel data.
    """

    def __init__(self, runtime_ranges: bool = False):
        self.runtime_ranges = runtime_ranges
        self.t0 = time.perf_counter()
        self.wall_t0 = time.time()
        self._ids = itertools.count(1)     # .__next__ is atomic under the GIL
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._events: List[dict] = []
        self._tls = threading.local()

    # -- collection ---------------------------------------------------------
    def _stack(self) -> List[Span]:
        s = getattr(self._tls, "stack", None)
        if s is None:
            s = self._tls.stack = []
        return s

    def _record_span(self, sp: Span) -> None:
        with self._lock:
            self._spans.append(sp)

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def current_span(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    def event(self, name: str, **attrs) -> None:
        parent = self.current_span()
        rec = {"kind": "event", "name": name,
               "ts": time.perf_counter(),
               "parent": parent.span_id if parent else None,
               "thread": threading.get_ident(), "attrs": attrs}
        with self._lock:
            self._events.append(rec)

    def gauge(self, name: str, value, **attrs) -> None:
        rec = {"kind": "gauge", "name": name,
               "ts": time.perf_counter(), "value": value,
               "thread": threading.get_ident(), "attrs": attrs}
        with self._lock:
            self._events.append(rec)

    # -- queries (exporters + tests) ----------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Span]:
        with self._lock:
            out = list(self._spans)
        if name is not None:
            out = [s for s in out if s.name == name]
        return sorted(out, key=lambda s: (s.t0, s.span_id))

    def events(self, name: Optional[str] = None) -> List[dict]:
        with self._lock:
            out = list(self._events)
        if name is not None:
            out = [e for e in out if e["name"] == name]
        return sorted(out, key=lambda e: e["ts"])

    def us(self, t: float) -> float:
        """Monotonic seconds -> microseconds since this tracer's origin."""
        return (t - self.t0) * 1e6


# ---------------------------------------------------------------------------
# module-level active tracer (the instrumentation surface)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[Tracer] = None


def enable(runtime_ranges: bool = False) -> Tracer:
    """Install (and return) a fresh process-wide tracer."""
    global _ACTIVE
    _ACTIVE = Tracer(runtime_ranges=runtime_ranges)
    return _ACTIVE


def disable() -> Optional[Tracer]:
    """Remove the active tracer; returns it (for export)."""
    global _ACTIVE
    t, _ACTIVE = _ACTIVE, None
    return t


def active_tracer() -> Optional[Tracer]:
    return _ACTIVE


def is_enabled() -> bool:
    return _ACTIVE is not None


def runtime_ranges_enabled() -> bool:
    t = _ACTIVE
    return t is not None and t.runtime_ranges


class tracing:
    """`with tracing() as tr:` — scoped enable/restore (tests, harnesses)."""

    def __init__(self, runtime_ranges: bool = False):
        self.runtime_ranges = runtime_ranges
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = Tracer(runtime_ranges=self.runtime_ranges)
        return _ACTIVE

    def __exit__(self, *a):
        global _ACTIVE
        _ACTIVE = self._prev
        return False


def span(name: str, **attrs):
    """Span on the active tracer, or a shared no-op when tracing is off.

    The disabled path is one global load + `is None` test — cheap enough
    for per-stage instrumentation on production hot loops.
    """
    t = _ACTIVE
    if t is None:
        return _NULL
    return t.span(name, **attrs)


def event(name: str, **attrs) -> None:
    t = _ACTIVE
    if t is not None:
        t.event(name, **attrs)


def gauge(name: str, value, **attrs) -> None:
    t = _ACTIVE
    if t is not None:
        t.gauge(name, value, **attrs)
