"""Trace exporters: JSONL event stream + Chrome trace-event JSON.

Two serializations of the same `Tracer` contents (docs/observability.md):

  * **JSONL** — one JSON object per line, machine-first.  Spans carry
    `{"kind": "span", "name", "id", "parent", "ts_us", "dur_us",
    "thread", "attrs"}`; instant events and gauges keep their `kind`;
    the final line is a `{"kind": "counters"}` snapshot of every
    registered `CounterGroup`.  `repro.obs.report` and the tests consume
    this form via `load_jsonl`.
  * **Chrome trace-event JSON** — `{"traceEvents": [...]}` with `ph:"X"`
    complete events (ts/dur in microseconds), `ph:"i"` instants and
    `ph:"C"` counter samples, loadable in Perfetto (ui.perfetto.dev) or
    `chrome://tracing`.

Attribute values are sanitized with `_jsonable` (numpy scalars → Python
numbers, unknown objects → `repr`), so instrumentation sites may attach
Intervals or numpy results without worrying about serializability.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, List

from .tracer import Tracer, all_counters

__all__ = [
    "load_jsonl", "to_chrome_trace", "to_jsonl_records",
    "write_chrome_trace", "write_jsonl",
]


def _jsonable(v: Any) -> Any:
    """Best-effort conversion to a JSON-serializable value."""
    if v is None or isinstance(v, (bool, str)):
        return v
    if isinstance(v, (int, float)):
        if isinstance(v, float) and not math.isfinite(v):
            return repr(v)          # "inf" / "-inf" / "nan": JSON has none
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_jsonable(x) for x in v]
    # numpy scalars (and anything else quacking like a number)
    try:
        import numpy as np
        if isinstance(v, np.integer):
            return int(v)
        if isinstance(v, np.floating):
            return _jsonable(float(v))
        if isinstance(v, np.ndarray) and v.size <= 16:
            return [_jsonable(x) for x in v.tolist()]
    except Exception:
        pass
    return repr(v)


def _attrs(attrs: Dict[str, Any]) -> Dict[str, Any]:
    return {str(k): _jsonable(v) for k, v in attrs.items()}


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------

def to_jsonl_records(tracer: Tracer) -> List[dict]:
    """The JSONL schema as a list of dicts (ts_us/dur_us relative to the
    tracer origin), ordered by start time; counters snapshot last."""
    recs: List[dict] = [{
        "kind": "meta",
        "wall_t0": tracer.wall_t0,
        "runtime_ranges": tracer.runtime_ranges,
    }]
    rows = [(s.t0, 0, {
        "kind": "span", "name": s.name, "id": s.span_id,
        "parent": s.parent_id, "ts_us": tracer.us(s.t0),
        "dur_us": (s.t1 - s.t0) * 1e6, "thread": s.thread_id,
        "attrs": _attrs(s.attrs),
    }) for s in tracer.spans()]
    for e in tracer.events():
        rec = {"kind": e["kind"], "name": e["name"],
               "ts_us": tracer.us(e["ts"]), "thread": e["thread"],
               "attrs": _attrs(e["attrs"])}
        if e["kind"] == "event":
            rec["parent"] = e["parent"]
        else:
            rec["value"] = _jsonable(e["value"])
        rows.append((e["ts"], 1, rec))
    rows.sort(key=lambda r: (r[0], r[1]))
    recs.extend(r[2] for r in rows)
    recs.append({"kind": "counters", "values": _jsonable(all_counters())})
    return recs


def write_jsonl(tracer: Tracer, path) -> None:
    with open(path, "w") as f:
        for rec in to_jsonl_records(tracer):
            f.write(json.dumps(rec) + "\n")


def load_jsonl(path) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# ---------------------------------------------------------------------------
# Chrome trace-event JSON (perfetto / chrome://tracing)
# ---------------------------------------------------------------------------

def to_chrome_trace(tracer: Tracer, process_name: str = "repro") -> dict:
    ev: List[dict] = [{
        "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }]
    for s in tracer.spans():
        ev.append({
            "ph": "X", "pid": 0, "tid": s.thread_id,
            "name": s.name, "cat": s.name.split(".", 1)[0],
            "ts": tracer.us(s.t0), "dur": (s.t1 - s.t0) * 1e6,
            "args": _attrs(s.attrs),
        })
    for e in tracer.events():
        if e["kind"] == "gauge":
            val = e["value"]
            if not isinstance(val, (int, float)):
                continue            # Chrome counter tracks are numeric-only
            ev.append({
                "ph": "C", "pid": 0, "tid": e["thread"],
                "name": e["name"], "ts": tracer.us(e["ts"]),
                "args": {"value": _jsonable(val)},
            })
        else:
            ev.append({
                "ph": "i", "s": "t", "pid": 0, "tid": e["thread"],
                "name": e["name"], "cat": e["name"].split(".", 1)[0],
                "ts": tracer.us(e["ts"]), "args": _attrs(e["attrs"]),
            })
    return {"traceEvents": ev, "displayTimeUnit": "ms",
            "otherData": {"counters": _jsonable(all_counters())}}


def write_chrome_trace(tracer: Tracer, path, process_name: str = "repro") -> None:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer, process_name), f)
