"""State-space / linear-recurrence token mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both recurrences are implemented twice:
  * `*_scan`    — naive per-token `lax.scan`: the correctness oracle, and the
                  O(1)-state decode path (`long_500k` eligibility).
  * `*_chunked` — chunk-parallel form used for training/prefill: intra-chunk
                  work becomes dense (C x C) matmuls (MXU-friendly), states
                  propagate across chunks with one scan over T/C steps.
                  All per-chunk tensors (decays, scores) are computed INSIDE
                  the chunk-scan body, so peak memory is O(B*H*C^2), not
                  O(B*H*T*C).  This is the TPU analogue of the CUDA chunked
                  kernels the papers ship; decay ratios are computed in log
                  space for stability.

RWKV6 recurrence (per head; K=V=head_dim):
    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T          w_t in (0,1)^K data-dependent

Mamba2 SSD (per head; N=d_state, P=head_dim; scalar decay a_t per head):
    S_t = a_t S_{t-1} + B_t (dt_t x_t)^T
    y_t = C_t . S_t (+ D x_t skip, applied by the caller)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _pick_chunk(T: int, chunk: int) -> int:
    """Largest divisor of T that is <= requested chunk."""
    c = min(chunk, T)
    while T % c != 0:
        c -= 1
    return c


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

def wkv6_scan(r, k, v, w, u, s0=None):
    """Naive oracle / decode path.

    r,k,w: (B, T, H, K); v: (B, T, H, V); u: (H, K); s0: (B, H, K, V).
    Returns (out (B, T, H, V), sT).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                     # (B, H, K/V)
        kv = k_t[..., :, None] * v_t[..., None, :]   # (B, H, K, V)
        out = jnp.einsum("bhk,bhkv->bhv", r_t,
                         s + u[None, :, :, None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, out

    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          w.transpose(1, 0, 2, 3).astype(jnp.float32))
    sT, outs = jax.lax.scan(step, s0, xs)
    return outs.transpose(1, 0, 2, 3), sT


def wkv6_chunked(r, k, v, w, u, s0=None, chunk: int = 64, unroll: int = 1):
    """Chunk-parallel WKV6 (log-space decays). Same signature as wkv6_scan."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    chunk = _pick_chunk(T, chunk)
    C, NC = chunk, T // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, K, V), jnp.float32)

    f32 = jnp.float32
    # (NC, B, H, C, dim) chunked layout — pure reshape/transpose, no compute.
    # r/k/v stage in their input dtype (bf16 in training): the scan's xs are
    # then half the HBM/ICI bytes; the f32 cast happens per chunk in VMEM.
    # w stays f32 — decay precision feeds a log/cumsum chain.
    def to_chunks(x):
        return x.reshape(B, NC, C, H, -1).transpose(1, 0, 3, 2, 4)
    rc, kc, vc = map(to_chunks, (r, k, v))
    wc = to_chunks(w.astype(f32))
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)

    @jax.checkpoint
    def chunk_step(s, inp):
        r_c, k_c, v_c, w_c = inp                       # (B, H, C, K/V)
        r_c = r_c.astype(f32)
        k_c = k_c.astype(f32)
        v_c = v_c.astype(f32)
        logw = jnp.log(jnp.clip(w_c, 1e-12, 1.0))
        logA = jnp.cumsum(logw, axis=-2)               # A_t = prod_{s<=t} w_s
        logA_prev = logA - logw                        # A_{t-1}
        r_dec = r_c * jnp.exp(logA_prev)               # r~_t = r_t A_{t-1}
        k_inc = k_c * jnp.exp(-logA)                   # k~_s = k_s / A_s
        logA_C = logA[..., -1:, :]
        k_end = k_c * jnp.exp(logA_C - logA)           # k^_s = k_s A_C/A_s

        # intra-chunk: strictly-lower-triangular scores + u-weighted diagonal
        scores = jnp.einsum("bhck,bhsk->bhcs", r_dec, k_inc)
        scores = jnp.where(tri[None, None], scores, 0.0)
        diag = jnp.einsum("bhck,bhck->bhc",
                          r_c * u[None, :, None, :], k_c)
        intra = (jnp.einsum("bhcs,bhsv->bhcv", scores, v_c)
                 + diag[..., None] * v_c)

        out = intra + jnp.einsum("bhck,bhkv->bhcv", r_dec, s)
        s_new = (jnp.exp(logA_C[..., 0, :])[..., None] * s
                 + jnp.einsum("bhsk,bhsv->bhkv", k_end, v_c))
        return s_new, out

    sT, outs = jax.lax.scan(chunk_step, s0, (rc, kc, vc, wc), unroll=unroll)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, V)
    return out, sT


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, a, Bm, Cm, s0=None):
    """Naive oracle / decode path.

    x: (B, T, H, P); dt, a: (B, T, H); Bm, Cm: (B, T, N) (ngroups=1, shared
    across heads); s0: (B, H, N, P).  Returns (y (B, T, H, P), sT).
    """
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(s, inp):
        x_t, dt_t, a_t, B_t, C_t = inp
        u = (dt_t[..., None] * x_t)                    # (B, H, P)
        s_new = (a_t[..., None, None] * s
                 + B_t[:, None, :, None] * u[..., None, :])
        y = jnp.einsum("bn,bhnp->bhp", C_t, s_new)
        return s_new, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          a.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    sT, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), sT


def ssd_chunked(x, dt, a, Bm, Cm, s0=None, chunk: int = 64, unroll: int = 1):
    """Chunk-parallel SSD; scalar per-head decays in log space."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    chunk = _pick_chunk(T, chunk)
    C, NC = chunk, T // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, N, P), jnp.float32)
    f32 = jnp.float32

    # u = dt*x staged in the input dtype (bf16 in training); decays f32
    xc = (dt.astype(f32)[..., None] * x.astype(f32)).astype(x.dtype).reshape(
        B, NC, C, H, P).transpose(1, 0, 3, 2, 4)       # (NC,B,H,C,P)
    ac = a.astype(f32).reshape(B, NC, C, H).transpose(1, 0, 3, 2)  # (NC,B,H,C)
    Bc = Bm.reshape(B, NC, C, N).transpose(1, 0, 2, 3)  # (NC,B,C,N)
    Cc = Cm.reshape(B, NC, C, N).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((C, C), bool))

    @jax.checkpoint
    def chunk_step(s, inp):
        x_c, a_c, B_c, C_c = inp
        x_c = x_c.astype(f32)
        B_c = B_c.astype(f32)
        C_c = C_c.astype(f32)
        loga = jnp.log(jnp.clip(a_c, 1e-12, 1.0))
        logA = jnp.cumsum(loga, axis=-1)                # (B, H, C)
        logA_C = logA[..., -1:]

        # M[t,s] = exp(logA_t - logA_s) * (C_t . B_s) for s <= t
        ratio = logA[..., :, None] - logA[..., None, :]
        decay = jnp.where(tri[None, None], jnp.exp(ratio), 0.0)
        cb = jnp.einsum("bcd,bsd->bcs", C_c, B_c)       # (B, C, C)
        M = decay * cb[:, None]                          # (B, H, C, C)
        intra = jnp.einsum("bhcs,bhsp->bhcp", M, x_c)

        C_dec = C_c[:, None, :, :] * jnp.exp(logA)[..., None]   # (B,H,C,N)
        B_end = (B_c[:, None, :, :]
                 * jnp.exp(logA_C[..., None] - logA[..., None]))

        out = intra + jnp.einsum("bhcn,bhnp->bhcp", C_dec, s)
        s_new = (jnp.exp(logA_C[..., 0])[..., None, None] * s
                 + jnp.einsum("bhcn,bhcp->bhnp", B_end, x_c))
        return s_new, out

    sT, outs = jax.lax.scan(chunk_step, s0, (xc, ac, Bc, Cc), unroll=unroll)
    y = outs.transpose(1, 0, 3, 2, 4).reshape(B, T, H, P)
    return y, sT


# ---------------------------------------------------------------------------
# depthwise causal conv (mamba2 frontend)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w, state=None):
    """x: (B, T, D); w: (W, D) depthwise taps. Returns (y, new_state).

    `state` is the last W-1 inputs from the previous segment (B, W-1, D);
    used for chunked prefill and one-token decode.
    """
    B, T, D = x.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, D), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)            # (B, T+W-1, D)
    y = jnp.zeros((B, T, D), jnp.float32)
    for i in range(W):
        y = y + xp[:, i:i + T, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = xp[:, -(W - 1):, :] if W > 1 else jnp.zeros((B, 0, D), x.dtype)
    return y.astype(x.dtype), new_state
