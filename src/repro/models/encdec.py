"""Encoder-decoder LM (whisper-medium backbone).

Per the assignment the modality frontend is a STUB: `input_specs` feeds
precomputed conv-frontend frame embeddings (B, T_enc, D) directly to the
encoder.  The backbone (24L enc + 24L dec, d=1024, 16H, ff=4096) is faithful;
norm/MLP style follows the modern RMSNorm/SwiGLU discipline used across this
framework (recorded as a deviation in DESIGN.md — the assignment pins the
backbone dims, not the 2022 norm flavor).

Encoder: bidirectional self-attention over frames.
Decoder: causal self-attention + cross-attention over encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.attention import (KVCache, attend_decode, attend_train,
                                    attn_param_specs, cross_attend)
from repro.models.common import (ModelConfig, ParamSpec, axes_tree,
                                 constrain_act, dense, init_tree, rms_norm,
                                 shape_tree, swiglu)


def _mlp_specs(cfg: ModelConfig, stacked: int):
    D, F = cfg.d_model, cfg.d_ff
    L, Lx = (stacked,), ("layers",)
    return {
        "w_gate": ParamSpec(L + (D, F), Lx + ("embed", "mlp")),
        "w_up": ParamSpec(L + (D, F), Lx + ("embed", "mlp")),
        "w_down": ParamSpec(L + (F, D), Lx + ("mlp", "embed")),
    }


def param_specs(cfg: ModelConfig) -> Dict:
    D, Vp = cfg.d_model, cfg.vocab_padded
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    return {
        "embed": ParamSpec((Vp, D), ("vocab", "embed")),
        "enc_blocks": {
            "ln_attn": ParamSpec((Le, D), ("layers", "embed"), init="ones"),
            "ln_mlp": ParamSpec((Le, D), ("layers", "embed"), init="ones"),
            "attn": attn_param_specs(cfg, stacked=Le),
            "mlp": _mlp_specs(cfg, Le),
        },
        "enc_norm": ParamSpec((D,), ("embed",), init="ones"),
        "dec_blocks": {
            "ln_attn": ParamSpec((Ld, D), ("layers", "embed"), init="ones"),
            "ln_cross": ParamSpec((Ld, D), ("layers", "embed"), init="ones"),
            "ln_mlp": ParamSpec((Ld, D), ("layers", "embed"), init="ones"),
            "attn": attn_param_specs(cfg, stacked=Ld),
            "cross": attn_param_specs(cfg, stacked=Ld),
            "mlp": _mlp_specs(cfg, Ld),
        },
        "final_norm": ParamSpec((D,), ("embed",), init="ones"),
        "unembed": ParamSpec((D, Vp), ("embed", "vocab")),
    }


def init_params(cfg: ModelConfig, key) -> Dict:
    return init_tree(key, param_specs(cfg))


def param_axes(cfg: ModelConfig) -> Dict:
    return axes_tree(param_specs(cfg))


def encode(params, frames, cfg: ModelConfig) -> jax.Array:
    """frames (B, T_enc, D) [conv-frontend stub output] -> (B, T_enc, D)."""
    x = frames.astype(jnp.bfloat16)
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]

    def body(h, lp):
        a = attend_train(rms_norm(h, lp["ln_attn"], cfg.norm_eps), lp["attn"],
                         cfg, positions=positions, causal=False)
        h = h + a
        m = swiglu(rms_norm(h, lp["ln_mlp"], cfg.norm_eps),
                   lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return constrain_act(h + m, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=cfg.scan_unroll)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def cross_kv(params, enc_out, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Precompute per-decoder-layer cross-attention KV: (L, B, KV, T, hd)."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    Bsz, T, D = enc_out.shape

    def body(_, lp):
        k = dense(enc_out, lp["cross"]["wk"]).reshape(Bsz, T, KV, hd)
        v = dense(enc_out, lp["cross"]["wv"]).reshape(Bsz, T, KV, hd)
        return None, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    _, (ks, vs) = jax.lax.scan(body, None, params["dec_blocks"])
    return ks, vs


def _decode_backbone(params, tokens, enc_out, cfg: ModelConfig) -> jax.Array:
    """Decoder blocks on embedded tokens — everything before the unembed."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    KV, hd = cfg.n_kv_heads, cfg.hd
    Bsz, T, D = enc_out.shape

    def body(h, lp):
        a = attend_train(rms_norm(h, lp["ln_attn"], cfg.norm_eps), lp["attn"],
                         cfg, positions=positions, causal=True)
        h = h + a
        ek = dense(enc_out, lp["cross"]["wk"]).reshape(Bsz, T, KV, hd)
        ev = dense(enc_out, lp["cross"]["wv"]).reshape(Bsz, T, KV, hd)
        c = cross_attend(rms_norm(h, lp["ln_cross"], cfg.norm_eps),
                         lp["cross"], cfg, ek.transpose(0, 2, 1, 3),
                         ev.transpose(0, 2, 1, 3))
        h = h + c
        m = swiglu(rms_norm(h, lp["ln_mlp"], cfg.norm_eps),
                   lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return constrain_act(h + m, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x = constrain_act(x, cfg)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"],
                        unroll=cfg.scan_unroll)
    return x


def decode_train(params, tokens, enc_out, cfg: ModelConfig) -> jax.Array:
    """tokens (B, S), enc_out (B, T, D) -> logits (B, S, Vp)."""
    x = _decode_backbone(params, tokens, enc_out, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return dense(x, params["unembed"]).astype(jnp.float32)


def forward(params, batch: Dict, cfg: ModelConfig) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg)
    return decode_train(params, batch["tokens"], enc_out, cfg)


def loss_fn(params, batch: Dict, cfg: ModelConfig):
    from repro.models.lm import _xent_chunked
    enc_out = encode(params, batch["frames"], cfg)
    x = _decode_backbone(params, batch["tokens"], enc_out, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    Bsz, S, D = x.shape
    nll_sum, z_sum = _xent_chunked(x.reshape(Bsz * S, D), params["unembed"],
                                   batch["labels"].reshape(-1), 1.0)
    denom = jnp.asarray(Bsz * S, jnp.float32)
    loss = nll_sum / denom + 1e-4 * z_sum / denom
    return loss, {"loss": nll_sum / denom, "zloss": 1e-4 * z_sum / denom,
                  "tokens": denom}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      prefill_len: int = 0) -> Dict:
    L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    T = cfg.encoder_seq
    return {
        "k": jnp.zeros((L, batch, KV, max_len, hd), jnp.bfloat16),
        "v": jnp.zeros((L, batch, KV, max_len, hd), jnp.bfloat16),
        "cross_k": jnp.zeros((L, batch, KV, T, hd), jnp.bfloat16),
        "cross_v": jnp.zeros((L, batch, KV, T, hd), jnp.bfloat16),
        "length": jnp.asarray(prefill_len, jnp.int32),
    }


def decode_step(params, token, state: Dict, cfg: ModelConfig):
    """One decoder token against self-KV cache + precomputed cross KV."""
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.bfloat16)
    length = state["length"]

    def body(h, inp):
        lp, k_l, v_l, ck_l, cv_l = inp
        cache = KVCache(k=k_l, v=v_l, length=length)
        a, nc = attend_decode(rms_norm(h, lp["ln_attn"], cfg.norm_eps),
                              lp["attn"], cfg, cache)
        h = h + a
        c = cross_attend(rms_norm(h, lp["ln_cross"], cfg.norm_eps),
                         lp["cross"], cfg, ck_l, cv_l)
        h = h + c
        m = swiglu(rms_norm(h, lp["ln_mlp"], cfg.norm_eps),
                   lp["mlp"]["w_gate"], lp["mlp"]["w_up"], lp["mlp"]["w_down"])
        return h + m, (nc.k, nc.v)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_blocks"], state["k"], state["v"],
                  state["cross_k"], state["cross_v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense(x[:, 0, :], params["unembed"]).astype(jnp.float32)
    new_state = dict(state, k=k_new, v=v_new, length=length + 1)
    return logits, new_state
