"""Mixture-of-Experts FFN — capacity-based top-k with scatter dispatch.

Supports Mixtral (8 routed, top-2) and Qwen2-MoE (60 routed top-4 + shared
experts that see every token).

Dispatch uses scatter-add into per-expert capacity buffers and gather for
the combine, so peak memory is O(T*E) int32 (the position cumsum) plus
O(E*C*D) buffers — NOT the O(T*E*C) one-hot einsum of textbook GShard,
which is quadratic in tokens and unrepresentable at 1M-token batches.
Tokens beyond an expert's capacity are dropped (contribute zero through the
residual), the standard capacity discipline.  Logical axes: "experts" on
the buffer dim (EP-shardable), "expert_ff" for TP within experts.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamSpec, dense


def moe_param_specs(cfg: ModelConfig, stacked: int | None = None) -> Dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    L = (stacked,) if stacked else ()
    Lx = ("layers",) if stacked else ()
    specs = {
        "router": ParamSpec(L + (D, E), Lx + ("embed", "experts")),
        "w_gate": ParamSpec(L + (E, D, F), Lx + ("experts", "embed", "expert_ff")),
        "w_up": ParamSpec(L + (E, D, F), Lx + ("experts", "embed", "expert_ff")),
        "w_down": ParamSpec(L + (E, F, D), Lx + ("experts", "expert_ff", "embed")),
    }
    if cfg.shared_expert_d_ff:
        Fs = cfg.shared_expert_d_ff
        specs.update({
            "shared_gate": ParamSpec(L + (D, Fs), Lx + ("embed", "mlp")),
            "shared_up": ParamSpec(L + (D, Fs), Lx + ("embed", "mlp")),
            "shared_down": ParamSpec(L + (Fs, D), Lx + ("mlp", "embed")),
            # qwen2-moe gates the shared expert per token
            "shared_gate_proj": ParamSpec(L + (D, 1), Lx + ("embed", None)),
        })
    return specs


def _constrain(x, spec_dims, cfg: ModelConfig):
    """Sharding constraint derived from the active act_pspec (if any).

    markers: "tok" = flattened token dim (batch axes + the seq/model axis,
    fully sharded); "cap" = expert capacity dim (batch axes only, so the
    FFN dim can still use the model axis); "tp" = model axis.
    """
    if not cfg.act_pspec:
        return x
    from jax.sharding import PartitionSpec as P
    dp = cfg.act_pspec[0]
    dp_t = dp if isinstance(dp, tuple) else (dp,)
    tok = dp_t + ("model",)
    spec = [tok if d == "tok" else
            (dp if d == "cap" else ("model" if d == "tp" else None))
            for d in spec_dims]
    return jax.lax.with_sharding_constraint(x, P(*spec))


def moe_ffn(x, p, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, F, k = cfg.n_experts, cfg.moe_d_ff, cfg.top_k
    T = B * S
    cap = max(int(cfg.capacity_factor * T * k / E), 8)
    cap = (cap + 255) // 256 * 256 if cap >= 256 else (cap + 7) // 8 * 8

    xt = x.reshape(T, D)
    xt = _constrain(xt, ("cap", None), cfg)
    logits = dense(xt, p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (T, k)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)

    # position of each (token, choice) in its expert's capacity buffer:
    # cumulative count of earlier assignments to the same expert
    assign = jax.nn.one_hot(top_e, E, dtype=jnp.int32)           # (T, k, E)
    assign_flat = assign.reshape(T * k, E)
    pos_flat = jnp.cumsum(assign_flat, axis=0) - assign_flat     # exclusive
    pos = jnp.sum(pos_flat * assign_flat, axis=-1).reshape(T, k) # (T, k)

    # scatter tokens into (E, C, D); overflow (pos >= cap) drops.
    # capacity dim shards over data, FFN dim over model -> per-device expert
    # buffers stay O(E * C/dp * F/tp)
    xe = jnp.zeros((E, cap, D), x.dtype)
    flat_e = top_e.reshape(-1)
    flat_pos = pos.reshape(-1)
    tok_rep = jnp.repeat(xt, k, axis=0)                           # (T*k, D)
    xe = xe.at[flat_e, flat_pos].add(tok_rep, mode="drop")
    xe = _constrain(xe, (None, "cap", None), cfg)

    # nested remat + bf16 cotangents: without this, the outer block-remat's
    # backward holds several f32 (E, C, D) buffers live at once (~2.7GB each
    # at mixtral scale)
    @jax.checkpoint
    def expert_ffn(xe_, wg, wu, wd):
        # bf16 outputs end-to-end: the TPU MXU accumulates in f32 internally
        # anyway, and f32 output intermediates double the buffer budget
        g = jnp.einsum("ecd,edf->ecf", xe_, wg.astype(xe_.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe_, wu.astype(xe_.dtype))
        g = _constrain(g, (None, "cap", "tp"), cfg)
        u = _constrain(u, (None, "cap", "tp"), cfg)
        h = (jax.nn.silu(g.astype(jnp.float32)).astype(xe_.dtype) * u)
        ye_ = jnp.einsum("ecf,efd->ecd", h, wd.astype(xe_.dtype))
        return _constrain(ye_, (None, "cap", None), cfg)

    ye = expert_ffn(xe, p["w_gate"], p["w_up"], p["w_down"])

    # combine: gather each (token, choice)'s output, weight, and sum over k;
    # out-of-capacity choices read as zero ('fill' mode)
    gathered = ye.at[flat_e, flat_pos].get(
        mode="fill", fill_value=0).reshape(T, k, D)
    gathered = _constrain(gathered, ("cap", None, None), cfg)
    out = jnp.sum(gathered * top_p[..., None].astype(x.dtype), axis=1)

    if cfg.shared_expert_d_ff:
        gs = dense(xt, p["shared_gate"])
        us = dense(xt, p["shared_up"])
        hs = (jax.nn.silu(gs.astype(jnp.float32)) * us.astype(jnp.float32)
              ).astype(x.dtype)
        shared = dense(hs, p["shared_down"])
        gate = jax.nn.sigmoid(dense(xt, p["shared_gate_proj"])
                              .astype(jnp.float32)).astype(x.dtype)
        out = out + gate * shared

    return out.reshape(B, S, D)


def aux_load_balance_loss(router_probs, top_e, n_experts: int) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (mean prob x mean dispatch)."""
    mask = jax.nn.one_hot(top_e, n_experts).sum(axis=1)          # (T, E)
    density = jnp.mean(jnp.minimum(mask, 1.0), axis=0)
    prob_mass = jnp.mean(router_probs, axis=0)
    return n_experts * jnp.sum(density * prob_mass)
