"""Decoder LM assembly: embed -> scanned blocks -> norm -> unembed.

One implementation covers dense, MoE, VLM (prefix-LM over patch embeddings),
RWKV6, and the Mamba2+shared-attention hybrid; whisper's encoder-decoder
lives in `repro.models.encdec`.

Layers are stacked and consumed with `jax.lax.scan` so HLO size / compile
time are O(1) in depth.  `cfg.remat` wraps the scan body in jax.checkpoint
(full block rematerialization) for training memory.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.attention import KVCache, attn_param_specs
from repro.models.common import (ModelConfig, ParamSpec, axes_tree,
                                 constrain_act, dense, init_tree, rms_norm,
                                 shape_tree)

# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def param_specs(cfg: ModelConfig) -> Dict:
    D, Vp, L = cfg.d_model, cfg.vocab_padded, cfg.n_layers
    specs: Dict[str, Any] = {
        "embed": ParamSpec((Vp, D), ("vocab", "embed")),
        "final_norm": ParamSpec((D,), ("embed",), init="ones"),
        "unembed": ParamSpec((D, Vp), ("embed", "vocab")),
    }
    if cfg.arch_class in ("dense", "moe", "vlm"):
        specs["blocks"] = B.transformer_specs(cfg, stacked=L)
    elif cfg.arch_class == "rwkv":
        specs["blocks"] = B.rwkv_specs(cfg, stacked=L)
    elif cfg.arch_class == "hybrid":
        specs["blocks"] = B.mamba_specs(cfg, stacked=L)
        # ONE shared transformer block reused every shared_attn_period layers
        specs["shared_attn"] = B.transformer_specs(cfg, stacked=None)
    else:
        raise ValueError(cfg.arch_class)
    return specs


def init_params(cfg: ModelConfig, key) -> Dict:
    return init_tree(key, param_specs(cfg))


def param_axes(cfg: ModelConfig) -> Dict:
    return axes_tree(param_specs(cfg))


def abstract_params(cfg: ModelConfig) -> Dict:
    return shape_tree(param_specs(cfg))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg: ModelConfig, patch_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    x = x * jnp.asarray(cfg.emb_scale, jnp.bfloat16)
    if cfg.arch_class == "vlm" and patch_embeds is not None:
        n = cfg.n_image_tokens
        x = jax.lax.dynamic_update_slice_in_dim(
            x, patch_embeds.astype(x.dtype), 0, axis=1)
    return x


def _run_blocks(params, x, cfg: ModelConfig) -> jax.Array:
    """Scanned layer stack on an embedded stream x (B, S, D)."""
    Bsz, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    prefix = cfg.n_image_tokens if cfg.arch_class == "vlm" else 0

    if cfg.arch_class in ("dense", "moe", "vlm"):
        def body(h, layer_p):
            h = B.transformer_fwd(h, layer_p, cfg, positions=positions,
                                  prefix_len=prefix)
            return constrain_act(h, cfg), None
    elif cfg.arch_class == "rwkv":
        def body(h, layer_p):
            h, _ = B.rwkv_fwd(h, layer_p, cfg, state=None, chunked=True)
            return constrain_act(h, cfg), None
    elif cfg.arch_class == "hybrid":
        # grouped scan: each group = 1 shared-attention block application
        # followed by `period` mamba layers (no lax.cond -> clean cost
        # analysis and exact shared-weight semantics)
        period = cfg.shared_attn_period
        shared = params["shared_attn"]

        def body(h, group_p):
            h = B.transformer_fwd(h, shared, cfg, positions=positions)

            @jax.checkpoint
            def inner(h2, layer_p):
                # nested remat: during the group's backward recompute, only
                # one mamba layer's internals are live at a time
                h2, _ = B.mamba_fwd(h2, layer_p, cfg, state=None,
                                    chunked=True)
                return constrain_act(h2, cfg), None

            h, _ = jax.lax.scan(inner, h, group_p)
            return constrain_act(h, cfg), None
    else:
        raise ValueError(cfg.arch_class)

    if cfg.remat:
        body = jax.checkpoint(body)

    x = constrain_act(x, cfg)
    if cfg.arch_class == "hybrid":
        period = cfg.shared_attn_period
        assert cfg.n_layers % period == 0, (cfg.n_layers, period)
        G = cfg.n_layers // period
        grouped = jax.tree.map(
            lambda a: a.reshape((G, period) + a.shape[1:]), params["blocks"])
        x, _ = jax.lax.scan(body, x, grouped, unroll=cfg.scan_unroll)
    else:
        x, _ = jax.lax.scan(body, x, params["blocks"],
                            unroll=cfg.scan_unroll)
    return x


def forward(params, tokens, cfg: ModelConfig, patch_embeds=None) -> jax.Array:
    """tokens (B, S) -> logits (B, S, vocab_padded)."""
    x = _embed(params, tokens, cfg, patch_embeds)
    x = _run_blocks(params, x, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense(x, params["unembed"]).astype(jnp.float32)
    return logits * cfg.logit_scale


# token-chunked softmax cross entropy: the (T, vocab) logits are never
# materialized at once — the unembed matmul + logsumexp run per chunk under
# jax.checkpoint, so backward recomputes each chunk's logits (the vocab
# analogue of query-chunked attention)
XENT_CHUNKS = 16


def _xent_chunked(x, unembed, targets, logit_scale: float):
    T, D = x.shape
    n = XENT_CHUNKS
    while T % n != 0:
        n //= 2
    xc = x.reshape(n, T // n, D)
    tc = targets.reshape(n, T // n)

    @jax.checkpoint
    def chunk(carry, inp):
        xb, tb = inp
        logits = dense(xb, unembed).astype(jnp.float32) * logit_scale
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tb[:, None], axis=-1)[:, 0]
        nll_sum, z_sum = carry
        return (nll_sum + jnp.sum(lse - picked),
                z_sum + jnp.sum(jnp.square(lse))), None

    (nll_sum, z_sum), _ = jax.lax.scan(
        chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, tc))
    return nll_sum, z_sum


def loss_fn(params, batch: Dict, cfg: ModelConfig) -> Tuple[jax.Array, Dict]:
    """Next-token cross entropy (+ z-loss stabilizer), vocab-chunked."""
    x = _embed(params, batch["tokens"], cfg, batch.get("patch_embeds"))
    x = _run_blocks(params, x, cfg)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    Bsz, S, D = x.shape
    targets = batch["labels"].reshape(-1)
    nll_sum, z_sum = _xent_chunked(x.reshape(Bsz * S, D), params["unembed"],
                                   targets, cfg.logit_scale)
    denom = jnp.asarray(Bsz * S, jnp.float32)
    loss = nll_sum / denom
    zloss = 1e-4 * z_sum / denom
    return loss + zloss, {"loss": loss, "zloss": zloss, "tokens": denom}


# ---------------------------------------------------------------------------
# decode (serve_step): one token against carried state
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      prefill_len: int = 0) -> Dict:
    """State pytree for one-token decode. `prefill_len` marks the cache as
    already holding that many tokens (dry-run decodes against a full cache)."""
    L, D = cfg.n_layers, cfg.d_model
    length = jnp.asarray(prefill_len, jnp.int32)
    if cfg.arch_class in ("dense", "moe", "vlm"):
        KV, hd = cfg.n_kv_heads, cfg.hd
        if cfg.kv_cache_dtype == "int8":
            # paper technique on the decode working set: int8 codes +
            # per-(pos, head) scales => ~2x fewer cache bytes per step
            return {
                "k": jnp.zeros((L, batch, KV, max_len, hd), jnp.int8),
                "v": jnp.zeros((L, batch, KV, max_len, hd), jnp.int8),
                "k_scale": jnp.zeros((L, batch, KV, max_len, 1), jnp.float32),
                "v_scale": jnp.zeros((L, batch, KV, max_len, 1), jnp.float32),
                "length": length,
            }
        return {
            "k": jnp.zeros((L, batch, KV, max_len, hd), jnp.bfloat16),
            "v": jnp.zeros((L, batch, KV, max_len, hd), jnp.bfloat16),
            "length": length,
        }
    if cfg.arch_class == "rwkv":
        K = cfg.rwkv_head_dim
        H = D // K
        return {
            "s": jnp.zeros((L, batch, H, K, K), jnp.float32),
            "x_att": jnp.zeros((L, batch, D), jnp.bfloat16),
            "x_ffn": jnp.zeros((L, batch, D), jnp.bfloat16),
            "length": length,
        }
    if cfg.arch_class == "hybrid":
        d_inner, H, N, conv_dim, _ = B.mamba_dims(cfg)
        P = cfg.ssm_head_dim
        G = (L + cfg.shared_attn_period - 1) // cfg.shared_attn_period
        KV, hd = cfg.n_kv_heads, cfg.hd
        return {
            "s": jnp.zeros((L, batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((L, batch, B.CONV_W - 1, conv_dim), jnp.bfloat16),
            "attn_k": jnp.zeros((G, batch, KV, max_len, hd), jnp.bfloat16),
            "attn_v": jnp.zeros((G, batch, KV, max_len, hd), jnp.bfloat16),
            "length": length,
        }
    raise ValueError(cfg.arch_class)


def decode_step(params, token, state: Dict, cfg: ModelConfig
                ) -> Tuple[jax.Array, Dict]:
    """token (B,) int32 -> (logits (B, vocab_padded), new state)."""
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.bfloat16)
    x = x * jnp.asarray(cfg.emb_scale, jnp.bfloat16)
    length = state["length"]

    if cfg.arch_class in ("dense", "moe", "vlm"):
        quantized = cfg.kv_cache_dtype == "int8"

        if quantized:
            def body(h, inp):
                layer_p, k_l, v_l, ks_l, vs_l = inp
                cache = KVCache(k=k_l, v=v_l, length=length,
                                k_scale=ks_l, v_scale=vs_l)
                h, nc = B.transformer_step(h, layer_p, cfg, cache)
                return h, (nc.k, nc.v, nc.k_scale, nc.v_scale)

            x, (k_new, v_new, ks_new, vs_new) = jax.lax.scan(
                body, x, (params["blocks"], state["k"], state["v"],
                          state["k_scale"], state["v_scale"]))
            new_state = {"k": k_new, "v": v_new, "k_scale": ks_new,
                         "v_scale": vs_new, "length": length + 1}
        else:
            def body(h, inp):
                layer_p, k_l, v_l = inp
                cache = KVCache(k=k_l, v=v_l, length=length)
                h, new_cache = B.transformer_step(h, layer_p, cfg, cache)
                return h, (new_cache.k, new_cache.v)

            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["blocks"], state["k"], state["v"]))
            new_state = {"k": k_new, "v": v_new, "length": length + 1}

    elif cfg.arch_class == "rwkv":
        def body(h, inp):
            layer_p, s_l, xa_l, xf_l = inp
            st = {"s": s_l, "x_att": xa_l, "x_ffn": xf_l}
            h, st = B.rwkv_fwd(h, layer_p, cfg, state=st, chunked=False)
            return h, (st["s"], st["x_att"], st["x_ffn"])

        x, (s_new, xa_new, xf_new) = jax.lax.scan(
            body, x, (params["blocks"], state["s"], state["x_att"],
                      state["x_ffn"]))
        new_state = {"s": s_new, "x_att": xa_new, "x_ffn": xf_new,
                     "length": length + 1}

    elif cfg.arch_class == "hybrid":
        # grouped scan mirroring forward(): shared attn + `period` mamba
        # layers per group; per-group KV caches ride along as scan xs
        period = cfg.shared_attn_period
        shared = params["shared_attn"]
        G = cfg.n_layers // period
        grouped_blocks = jax.tree.map(
            lambda a: a.reshape((G, period) + a.shape[1:]), params["blocks"])
        grouped_s = state["s"].reshape((G, period) + state["s"].shape[1:])
        grouped_conv = state["conv"].reshape(
            (G, period) + state["conv"].shape[1:])

        def body(h, inp):
            group_p, s_g, conv_g, k_g, v_g = inp
            cache = KVCache(k=k_g, v=v_g, length=length)
            h, nc = B.transformer_step(h, shared, cfg, cache)

            def inner(h2, inp2):
                layer_p, s_l, conv_l = inp2
                st = {"s": s_l, "conv": conv_l}
                h2, st = B.mamba_fwd(h2, layer_p, cfg, state=st,
                                     chunked=False)
                return h2, (st["s"], st["conv"])

            h, (s_new_g, conv_new_g) = jax.lax.scan(
                inner, h, (group_p, s_g, conv_g))
            return h, (s_new_g, conv_new_g, nc.k, nc.v)

        x, (s_new, conv_new, ak, av) = jax.lax.scan(
            body, x, (grouped_blocks, grouped_s, grouped_conv,
                      state["attn_k"], state["attn_v"]))
        new_state = {
            "s": s_new.reshape(state["s"].shape),
            "conv": conv_new.reshape(state["conv"].shape),
            "attn_k": ak, "attn_v": av, "length": length + 1,
        }
    else:
        raise ValueError(cfg.arch_class)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense(x[:, 0, :], params["unembed"]).astype(jnp.float32)
    return logits * cfg.logit_scale, new_state
