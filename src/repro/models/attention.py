"""Attention: GQA/MQA with RoPE, optional qk-norm and sliding window.

Two entry points:
  * `attend_train`  — full-sequence causal attention (training / prefill)
  * `attend_decode` — one new token against a KV cache (serve_step)

Layouts: activations (B, S, D); q (B, S, H, hd); kv (B, S, KV, hd);
cache (B, KV, S_max, hd).  Logical axes: H/KV -> "heads"/"kv_heads",
hd -> "head_dim", S -> "seq".
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, ParamSpec, dense, rms_norm

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def attn_param_specs(cfg: ModelConfig, stacked: int | None = None) -> Dict:
    """Projection params for one attention block (optionally layer-stacked)."""
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    L = (stacked,) if stacked else ()
    Lx = ("layers",) if stacked else ()
    specs = {
        "wq": ParamSpec(L + (D, H * hd), Lx + ("embed", "heads_joined")),
        "wk": ParamSpec(L + (D, KV * hd), Lx + ("embed", "kv_joined")),
        "wv": ParamSpec(L + (D, KV * hd), Lx + ("embed", "kv_joined")),
        "wo": ParamSpec(L + (H * hd, D), Lx + ("heads_joined", "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec(L + (hd,), Lx + (None,), init="ones")
        specs["k_norm"] = ParamSpec(L + (hd,), Lx + (None,), init="ones")
    return specs


class KVCache(NamedTuple):
    k: jax.Array        # (B, KV, S_max, hd) — bf16, or int8 codes
    v: jax.Array        # (B, KV, S_max, hd)
    length: jax.Array   # () int32 — tokens already cached
    # int8 cache (paper technique on decode bytes): per-(pos, head) absmax
    # scales; None for the bf16 cache
    k_scale: Optional[jax.Array] = None   # (B, KV, S_max, 1) f32
    v_scale: Optional[jax.Array] = None


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------

def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd)


def _causal_mask(S: int, window: int, prefix: int = 0) -> jax.Array:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = j <= i
    if window > 0:
        mask &= (i - j) < window
    if prefix > 0:
        # prefix-LM (PaliGemma): the image/prompt prefix attends bidirectionally
        mask |= j < prefix
    return mask                                          # (S, S) bool


def _project_qkv(x, p, cfg: ModelConfig, positions):
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _split_heads(dense(x, p["wq"]), H, hd)
    k = _split_heads(dense(x, p["wk"]), KV, hd)
    v = _split_heads(dense(x, p["wv"]), KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# above this many tokens, attention runs query-chunked (memory O(Cq * S)
# per step instead of O(S^2)) — mandatory for the 32k prefill shapes
QUERY_CHUNK = 1024


def _attend_block(q, k, v, q_pos, k_pos, cfg: ModelConfig, causal: bool,
                  prefix_len: int):
    """Attention for one query block against full K/V.

    q: (B, KV, G, Cq, hd); k, v: (B, KV, S, hd); *_pos: absolute positions.
    Exact softmax — each query row sees its whole key range.
    """
    scale = cfg.hd ** -0.5
    logits = jnp.einsum("bkgsh,bkth->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        i = q_pos[:, None]
        j = k_pos[None, :]
        mask = j <= i
        if cfg.sliding_window > 0:
            mask &= (i - j) < cfg.sliding_window
        if prefix_len > 0:
            mask |= j < prefix_len
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v,
                     preferred_element_type=jnp.float32)
    return out


def attend_train(x, p, cfg: ModelConfig, positions=None,
                 causal: bool = True, prefix_len: int = 0) -> jax.Array:
    """Full-sequence attention. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    groups = H // KV
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(x, p, cfg, positions)

    # (B, KV, G, S, hd) grouped query layout
    q = q.reshape(B, S, KV, groups, hd).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)                          # (B, KV, S, hd)
    v = v.transpose(0, 2, 1, 3)
    pos = jnp.arange(S)

    if S <= QUERY_CHUNK or S % QUERY_CHUNK != 0:
        out = _attend_block(q, k, v, pos, pos, cfg, causal, prefix_len)
    else:
        # scan over query chunks: peak live logits are (.., Cq, S), not (S, S)
        n_chunks = S // QUERY_CHUNK
        q_chunks = q.reshape(B, KV, groups, n_chunks, QUERY_CHUNK, hd)
        q_chunks = jnp.moveaxis(q_chunks, 3, 0)          # (NC, B, KV, G, Cq, hd)
        pos_chunks = pos.reshape(n_chunks, QUERY_CHUNK)

        @jax.checkpoint
        def step(_, inp):
            # checkpointed: backward recomputes this chunk's (Cq, S) probs
            # instead of stacking them across all chunks (which would be the
            # full S x S matrix again)
            qc, pc = inp
            return None, _attend_block(qc, k, v, pc, pos, cfg, causal,
                                       prefix_len)

        _, outs = jax.lax.scan(step, None, (q_chunks, pos_chunks))
        out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, groups, S, hd)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * hd).astype(x.dtype)
    return dense(out, p["wo"])


def attend_decode(x, p, cfg: ModelConfig, cache: KVCache
                  ) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, D); returns (out (B, 1, D), new cache)."""
    B, S1, D = x.shape
    assert S1 == 1
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    groups = H // KV
    pos = cache.length                                    # scalar
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(x, p, cfg, positions)

    k_new = k.transpose(0, 2, 1, 3)                       # (B, KV, 1, hd)
    v_new = v.transpose(0, 2, 1, 3)
    quantized = cache.k_scale is not None

    def _qvec(x):
        sc = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
        sc = jnp.where(sc == 0, 1.0, sc).astype(jnp.float32)
        q_ = jnp.clip(jnp.rint(x.astype(jnp.float32) / sc),
                      -128, 127).astype(jnp.int8)
        return q_, sc

    if quantized:
        kq, ks = _qvec(k_new)
        vq, vs = _qvec(v_new)
        k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, kq, pos, axis=2)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, vq, pos, axis=2)
        ks_all = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks, pos,
                                                     axis=2)
        vs_all = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs, pos,
                                                     axis=2)
        # fused dequant on read: int8 codes * f32 scale -> bf16
        k_eff = (k_all.astype(jnp.float32) * ks_all).astype(jnp.bfloat16)
        v_eff = (v_all.astype(jnp.float32) * vs_all).astype(jnp.bfloat16)
    else:
        k_all = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), pos, axis=2)
        v_all = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), pos, axis=2)
        ks_all = vs_all = None
        k_eff, v_eff = k_all, v_all

    q = q.reshape(B, 1, KV, groups, hd).transpose(0, 2, 3, 1, 4)  # (B,KV,G,1,hd)
    scale = hd ** -0.5
    logits = jnp.einsum("bkgsh,bkth->bkgst", q, k_eff.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    S_max = k_all.shape[2]
    idx = jnp.arange(S_max)
    valid = idx <= pos
    if cfg.sliding_window > 0:
        valid &= (pos - idx) < cfg.sliding_window
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_eff.dtype)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v_eff,
                     preferred_element_type=jnp.float32)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H * hd).astype(x.dtype)
    out = dense(out, p["wo"])
    return out, KVCache(k=k_all, v=v_all, length=cache.length + 1,
                        k_scale=ks_all, v_scale=vs_all)


def cross_attend(x, p, cfg: ModelConfig, enc_k, enc_v) -> jax.Array:
    """Decoder cross-attention over precomputed encoder KV (B, KV, T, hd).

    Query-chunked like attend_train: at 32k decoder tokens the full
    (S, T_enc) probability tensor would be hundreds of GB.
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    groups = H // KV
    q = _split_heads(dense(x, p["wq"]), H, hd)
    q = q.reshape(B, S, KV, groups, hd).transpose(0, 2, 3, 1, 4)
    scale = hd ** -0.5
    k = enc_k.astype(q.dtype)

    def block(qc):
        logits = jnp.einsum("bkgsh,bkth->bkgst", qc, k,
                            preferred_element_type=jnp.float32) * scale
        probs = jax.nn.softmax(logits, axis=-1).astype(enc_v.dtype)
        return jnp.einsum("bkgst,bkth->bkgsh", probs, enc_v,
                          preferred_element_type=jnp.float32)

    if S <= QUERY_CHUNK or S % QUERY_CHUNK != 0:
        out = block(q)
    else:
        n_chunks = S // QUERY_CHUNK
        q_chunks = jnp.moveaxis(
            q.reshape(B, KV, groups, n_chunks, QUERY_CHUNK, hd), 3, 0)

        @jax.checkpoint
        def step(_, qc):
            return None, block(qc)

        _, outs = jax.lax.scan(step, None, q_chunks)
        out = jnp.moveaxis(outs, 0, 3).reshape(B, KV, groups, S, hd)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * hd).astype(x.dtype)
    return dense(out, p["wo"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    KV, hd = cfg.n_kv_heads, cfg.hd
    return KVCache(
        k=jnp.zeros((batch, KV, max_len, hd), dtype),
        v=jnp.zeros((batch, KV, max_len, hd), dtype),
        length=jnp.zeros((), jnp.int32),
    )
