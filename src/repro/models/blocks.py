"""Per-layer blocks: dense transformer, MoE, RWKV6, Mamba2 (+ shared attn).

Every block type provides
  * `<kind>_specs(cfg, stacked)` — ParamSpec tree (stacked on the layer axis)
  * `<kind>_fwd(x, p, cfg, ...)` — full-sequence forward (train / prefill)
  * `<kind>_step(x, p, cfg, state)` — one-token decode with carried state
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.attention import (KVCache, attend_decode, attend_train,
                                    attn_param_specs)
from repro.models.common import (ModelConfig, ParamSpec, dense, rms_norm,
                                 swiglu)
from repro.models.moe import moe_ffn, moe_param_specs

# ---------------------------------------------------------------------------
# dense / MoE transformer blocks
# ---------------------------------------------------------------------------


def transformer_specs(cfg: ModelConfig, stacked: int | None) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    L = (stacked,) if stacked else ()
    Lx = ("layers",) if stacked else ()
    specs = {
        "ln_attn": ParamSpec(L + (D,), Lx + ("embed",), init="ones"),
        "ln_mlp": ParamSpec(L + (D,), Lx + ("embed",), init="ones"),
        "attn": attn_param_specs(cfg, stacked),
    }
    if cfg.is_moe:
        specs["moe"] = moe_param_specs(cfg, stacked)
    else:
        specs["mlp"] = {
            "w_gate": ParamSpec(L + (D, F), Lx + ("embed", "mlp")),
            "w_up": ParamSpec(L + (D, F), Lx + ("embed", "mlp")),
            "w_down": ParamSpec(L + (F, D), Lx + ("mlp", "embed")),
        }
    return specs


def transformer_fwd(x, p, cfg: ModelConfig, positions=None,
                    prefix_len: int = 0):
    h = attend_train(rms_norm(x, p["ln_attn"], cfg.norm_eps), p["attn"], cfg,
                     positions=positions, prefix_len=prefix_len)
    x = x + cfg.residual_scale * h
    hin = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.is_moe:
        h = moe_ffn(hin, p["moe"], cfg)
    else:
        h = swiglu(hin, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                   p["mlp"]["w_down"])
    return x + cfg.residual_scale * h


def transformer_step(x, p, cfg: ModelConfig, cache: KVCache
                     ) -> Tuple[jax.Array, KVCache]:
    h, cache = attend_decode(rms_norm(x, p["ln_attn"], cfg.norm_eps),
                             p["attn"], cfg, cache)
    x = x + cfg.residual_scale * h
    hin = rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.is_moe:
        h = moe_ffn(hin, p["moe"], cfg)
    else:
        h = swiglu(hin, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                   p["mlp"]["w_down"])
    return x + cfg.residual_scale * h, cache


# ---------------------------------------------------------------------------
# RWKV6 (Finch) block
# ---------------------------------------------------------------------------

RWKV_LORA = 64


def rwkv_specs(cfg: ModelConfig, stacked: int | None) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    H = D // cfg.rwkv_head_dim
    K = cfg.rwkv_head_dim
    L = (stacked,) if stacked else ()
    Lx = ("layers",) if stacked else ()
    return {
        "ln1": ParamSpec(L + (D,), Lx + ("embed",), init="ones"),
        "ln2": ParamSpec(L + (D,), Lx + ("embed",), init="ones"),
        "tmix": {
            # static token-shift interpolators (data-dependent decay keeps
            # its LoRA below — the Finch signature feature)
            "mu_r": ParamSpec(L + (D,), Lx + ("embed",), init="small"),
            "mu_k": ParamSpec(L + (D,), Lx + ("embed",), init="small"),
            "mu_v": ParamSpec(L + (D,), Lx + ("embed",), init="small"),
            "mu_g": ParamSpec(L + (D,), Lx + ("embed",), init="small"),
            "mu_w": ParamSpec(L + (D,), Lx + ("embed",), init="small"),
            "w_r": ParamSpec(L + (D, D), Lx + ("embed", "heads_joined")),
            "w_k": ParamSpec(L + (D, D), Lx + ("embed", "heads_joined")),
            "w_v": ParamSpec(L + (D, D), Lx + ("embed", "heads_joined")),
            "w_g": ParamSpec(L + (D, D), Lx + ("embed", "heads_joined")),
            "w_o": ParamSpec(L + (D, D), Lx + ("heads_joined", "embed")),
            "w0": ParamSpec(L + (D,), Lx + (None,), init="small"),
            "w_lora_a": ParamSpec(L + (D, RWKV_LORA), Lx + ("embed", None)),
            "w_lora_b": ParamSpec(L + (RWKV_LORA, D), Lx + (None, None)),
            "u": ParamSpec(L + (H, K), Lx + (None, None), init="small"),
            "ln_x": ParamSpec(L + (D,), Lx + ("embed",), init="ones"),
        },
        "cmix": {
            "mu_k": ParamSpec(L + (D,), Lx + ("embed",), init="small"),
            "mu_r": ParamSpec(L + (D,), Lx + ("embed",), init="small"),
            "w_k": ParamSpec(L + (D, F), Lx + ("embed", "mlp")),
            "w_v": ParamSpec(L + (F, D), Lx + ("mlp", "embed")),
            "w_r": ParamSpec(L + (D, D), Lx + ("embed", "heads_joined")),
        },
    }


def _token_shift(x, x_prev_last):
    """x_{t-1} along seq; position 0 takes the carried last token."""
    shifted = jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def _rwkv_decay(xw, p):
    """Data-dependent per-channel decay in (0, 1)."""
    lora = jnp.einsum("bsd,dr->bsr", xw.astype(jnp.float32),
                      p["w_lora_a"].astype(jnp.float32))
    lora = jnp.einsum("bsr,rd->bsd", jnp.tanh(lora),
                      p["w_lora_b"].astype(jnp.float32))
    return jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + lora))


def rwkv_tmix(x, p, cfg: ModelConfig, x_last, s0, chunked: bool):
    B, T, D = x.shape
    K = cfg.rwkv_head_dim
    H = D // K
    xs = _token_shift(x, x_last)
    dx = xs - x

    def mix(mu):
        return x + dx * mu.astype(x.dtype)

    r = dense(mix(p["mu_r"]), p["w_r"]).reshape(B, T, H, K)
    k = dense(mix(p["mu_k"]), p["w_k"]).reshape(B, T, H, K)
    v = dense(mix(p["mu_v"]), p["w_v"]).reshape(B, T, H, K)
    g = dense(mix(p["mu_g"]), p["w_g"])
    w = _rwkv_decay(mix(p["mu_w"]), p).reshape(B, T, H, K)

    fn = ssm.wkv6_chunked if chunked else ssm.wkv6_scan
    out, sT = fn(r, k, v, w, p["u"].astype(jnp.float32), s0)
    out = out.reshape(B, T, D)
    # per-head group norm (ln_x), then gate
    out = out.reshape(B, T, H, K)
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, T, D)
    out = out * p["ln_x"].astype(jnp.float32)
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return dense(out, p["w_o"]), x[:, -1, :], sT


def rwkv_cmix(x, p, x_last):
    xs = _token_shift(x, x_last)
    dx = xs - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    k = dense(xk, p["w_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(dense(xr, p["w_r"]).astype(jnp.float32)).astype(x.dtype)
    return r * dense(k, p["w_v"]), x[:, -1, :]


def rwkv_fwd(x, p, cfg: ModelConfig, state=None, chunked: bool = True):
    """state = dict(s, x_att, x_ffn) or None (zeros). Returns (x, new state)."""
    B, T, D = x.shape
    K = cfg.rwkv_head_dim
    H = D // K
    if state is None:
        state = {
            "s": jnp.zeros((B, H, K, K), jnp.float32),
            "x_att": jnp.zeros((B, D), x.dtype),
            "x_ffn": jnp.zeros((B, D), x.dtype),
        }
    h, x_att, sT = rwkv_tmix(rms_norm(x, p["ln1"], cfg.norm_eps), p["tmix"],
                             cfg, state["x_att"], state["s"], chunked)
    x = x + h
    h, x_ffn = rwkv_cmix(rms_norm(x, p["ln2"], cfg.norm_eps), p["cmix"],
                         state["x_ffn"])
    x = x + h
    return x, {"s": sT, "x_att": x_att, "x_ffn": x_ffn}


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 backbone)
# ---------------------------------------------------------------------------

CONV_W = 4


def mamba_dims(cfg: ModelConfig):
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    H = d_inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N
    d_in_proj = 2 * d_inner + 2 * N + H     # z, xBC, dt
    return d_inner, H, N, conv_dim, d_in_proj


def mamba_specs(cfg: ModelConfig, stacked: int | None) -> Dict:
    D = cfg.d_model
    d_inner, H, N, conv_dim, d_in_proj = mamba_dims(cfg)
    L = (stacked,) if stacked else ()
    Lx = ("layers",) if stacked else ()
    return {
        "ln": ParamSpec(L + (D,), Lx + ("embed",), init="ones"),
        "in_proj": ParamSpec(L + (D, d_in_proj), Lx + ("embed", "heads_joined")),
        "conv_w": ParamSpec(L + (CONV_W, conv_dim), Lx + (None, "heads_joined"),
                            init="small"),
        "conv_b": ParamSpec(L + (conv_dim,), Lx + ("heads_joined",), init="zeros"),
        "a_log": ParamSpec(L + (H,), Lx + (None,), init="small"),
        "d_skip": ParamSpec(L + (H,), Lx + (None,), init="ones"),
        "dt_bias": ParamSpec(L + (H,), Lx + (None,), init="small"),
        "ln_y": ParamSpec(L + (d_inner,), Lx + ("heads_joined",), init="ones"),
        "out_proj": ParamSpec(L + (d_inner, D), Lx + ("heads_joined", "embed")),
    }


def mamba_fwd(x, p, cfg: ModelConfig, state=None, chunked: bool = True):
    """state = dict(s (B,H,N,P), conv (B,CONV_W-1,conv_dim)) or None."""
    B, T, D = x.shape
    d_inner, H, N, conv_dim, _ = mamba_dims(cfg)
    P = cfg.ssm_head_dim
    if state is None:
        state = {
            "s": jnp.zeros((B, H, N, P), jnp.float32),
            "conv": jnp.zeros((B, CONV_W - 1, conv_dim), x.dtype),
        }
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    proj = dense(xin, p["in_proj"])
    z, xBC, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)

    xBC, conv_state = ssm.causal_conv1d(xBC, p["conv_w"], state["conv"])
    xBC = xBC + p["conv_b"].astype(xBC.dtype)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, T, H, P)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,T,H)
    a = jnp.exp(-jnp.exp(p["a_log"].astype(jnp.float32))[None, None] * dt)

    fn = ssm.ssd_chunked if chunked else ssm.ssd_scan
    y, sT = fn(xs, dt, a, Bm, Cm, state["s"])
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(B, T, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2's norm-before-out with z gating)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["ln_y"], cfg.norm_eps)
    return x + dense(y, p["out_proj"]), {"s": sT, "conv": conv_state}
