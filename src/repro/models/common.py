"""Model configuration + shared neural-net primitives.

Conventions
-----------
* Parameters are nested dicts of jnp arrays.  Per-layer parameters are
  STACKED on a leading layer axis and consumed with `jax.lax.scan`, so HLO
  size and compile time are O(1) in depth (mandatory for 40L x 512-device
  dry-runs).
* Every parameter has *logical axes* (a tuple of names parallel to its
  shape).  `repro.launch.sharding` maps logical axes -> mesh axes with a
  divisibility check (non-divisible dims fall back to replication).
* Activations are bf16, parameters f32 (cast to bf16 at use), matmuls
  accumulate f32 — the usual TPU mixed-precision discipline.  The paper's
  technique then *narrows* selected tensors further via repro.quant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------

VOCAB_PAD_MULTIPLE = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_class: str                 # dense | moe | rwkv | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # attention features
    qk_norm: bool = False
    sliding_window: int = 0         # 0 = full causal
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM / RWKV
    ssm_state: int = 0              # mamba2 N
    ssm_head_dim: int = 64          # mamba2 P
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    shared_attn_period: int = 0
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500         # whisper conv-frontend output length
    # vlm (paligemma)
    n_image_tokens: int = 0
    # miniCPM-style mu-parametrization scales
    emb_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    # numerics
    norm_eps: float = 1e-6
    remat: bool = True
    # unroll factor for the layer scan: 1 = while-loop (fast compile);
    # True/n_layers = fully unrolled (exact cost_analysis for roofline)
    scan_unroll: int = 1
    # activation sharding constraint for (batch, seq) dims of the residual
    # stream at layer boundaries, e.g. (("pod","data"), "model") = Megatron
    # sequence parallelism. () = unconstrained (single-host tests).
    act_pspec: tuple = ()
    # cast >=2D params before the forward pass: "bf16" halves the FSDP
    # all-gather bytes, "int8" quarters them vs f32 (paper technique on the
    # collective wire: gather codes+scales, dequantize after — QAT-style
    # straight-through gradients). False/"" = f32 gathers.
    train_cast_bf16: bool = False
    train_weight_cast: str = ""    # "" | "bf16" | "int8"
    # KV cache storage: "bf16" or "int8" (paper technique on decode bytes;
    # per-vector absmax scales, dequant fused into the attention read)
    kv_cache_dtype: str = "bf16"
    # quantization policy hook (repro.quant); None = bf16 everywhere
    quant_recipe: Optional[str] = None

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return ((self.vocab_size + VOCAB_PAD_MULTIPLE - 1)
                // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.arch_class == "rwkv"

    @property
    def sub_quadratic(self) -> bool:
        """Supports O(1)-state decode (long_500k eligibility)."""
        return self.arch_class in ("rwkv", "hybrid")

    def param_count(self) -> int:
        """Approximate dense parameter count (reporting/roofline only)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_padded, self.n_layers
        hd, H, KV = self.hd, self.n_heads, self.n_kv_heads
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.arch_class == "rwkv":
            per_layer = 4 * D * D + 3 * D * self.d_ff // 1  # tmix + cmix approx
        elif self.is_moe:
            ffn = 3 * D * self.moe_d_ff * self.n_experts + D * self.n_experts
            if self.shared_expert_d_ff:
                ffn += 3 * D * self.shared_expert_d_ff
            per_layer = attn + ffn
        else:
            per_layer = attn + 3 * D * F
        total = L * per_layer + 2 * V * D
        if self.n_encoder_layers:
            total += self.n_encoder_layers * (attn + 2 * D * F)
        return total


# ---------------------------------------------------------------------------
# logical-axis bookkeeping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == ndim
    init: str = "normal"              # normal | zeros | ones | small

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_param(key, spec: ParamSpec, dtype=jnp.float32) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    scale = 0.02 if spec.init == "normal" else 0.006
    # fan-in scaled normal
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = min(scale, 1.0 / math.sqrt(max(fan_in, 1)))
    return std * jax.random.normal(key, spec.shape, dtype)


def init_tree(key, specs: PyTree, dtype=jnp.float32) -> PyTree:
    leaves, treedef = jax.tree.flatten(specs,
                                       is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    vals = [init_param(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(specs: PyTree) -> PyTree:
    """The logical-axis tree parallel to the param tree."""
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def shape_tree(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def constrain_act(x, cfg: "ModelConfig"):
    """Sequence-parallel sharding constraint on a (B, S, ...) activation."""
    if not cfg.act_pspec:
        return x
    from jax.sharding import PartitionSpec as P
    spec = list(cfg.act_pspec)[:x.ndim] + [None] * (x.ndim - len(cfg.act_pspec))
    return jax.lax.with_sharding_constraint(x, P(*spec))


def rms_norm(x, weight, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight + bias
    return out.astype(x.dtype)


def dense(x, w, compute_dtype=jnp.bfloat16):
    """x @ w with bf16 compute, f32 accumulation."""
    return jax.lax.dot_general(
        x.astype(compute_dtype), w.astype(compute_dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(compute_dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g.astype(jnp.float32)).astype(u.dtype) * u, w_down)


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = dense(x, w_up) + b_up.astype(jnp.bfloat16)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(jnp.bfloat16)
    return dense(h, w_down) + b_down.astype(jnp.bfloat16)
