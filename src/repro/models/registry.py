"""Uniform model interface over decoder-only and encoder-decoder archs."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    param_specs: Callable[[], Dict]
    init_params: Callable[[Any], Dict]
    param_axes: Callable[[], Dict]
    loss_fn: Callable  # (params, batch) -> (loss, metrics)
    forward: Callable
    init_decode_state: Callable  # (batch, max_len, prefill_len) -> state
    decode_step: Callable  # (params, token, state) -> (logits, state)


def get_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.arch_class == "encdec":
        return ModelBundle(
            cfg=cfg,
            param_specs=lambda: encdec.param_specs(cfg),
            init_params=lambda key: encdec.init_params(cfg, key),
            param_axes=lambda: encdec.param_axes(cfg),
            loss_fn=lambda p, b: encdec.loss_fn(p, b, cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            init_decode_state=lambda bs, ml, pl=0: encdec.init_decode_state(
                cfg, bs, ml, pl),
            decode_step=lambda p, t, s: encdec.decode_step(p, t, s, cfg),
        )
    return ModelBundle(
        cfg=cfg,
        param_specs=lambda: lm.param_specs(cfg),
        init_params=lambda key: lm.init_params(cfg, key),
        param_axes=lambda: lm.param_axes(cfg),
        loss_fn=lambda p, b: lm.loss_fn(p, b, cfg),
        forward=lambda p, b: lm.forward(
            p, b["tokens"], cfg, patch_embeds=b.get("patch_embeds")),
        init_decode_state=lambda bs, ml, pl=0: lm.init_decode_state(
            cfg, bs, ml, pl),
        decode_step=lambda p, t, s: lm.decode_step(p, t, s, cfg),
    )
