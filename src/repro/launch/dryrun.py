import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the 512
placeholder host devices let jax.make_mesh build the production meshes; the
compiled artifact's memory/cost analyses feed EXPERIMENTS.md's roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single   # 16x16 only
Results stream to benchmarks/results/dryrun.json (one record per cell).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.launch.lowering import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, skip_reason

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")


def run_one(arch: str, shape: str, multi_pod: bool, out: list) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    reason = skip_reason(cfg, cell)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if reason:
        rec.update(status="skip", reason=reason)
        out.append(rec)
        print(f"[skip] {arch} x {shape} x {mesh_name}: {reason}", flush=True)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lc = lower_cell(arch, cfg, cell, mesh, mesh_name)
        rec.update(status="ok", compile_s=round(time.time() - t0, 1),
                   **lc.analyses())
        mem = rec["memory"]
        n_dev = 512 if multi_pod else 256
        print(f"[ok]   {arch} x {shape} x {mesh_name}: "
              f"{rec['compile_s']}s compile, "
              f"flops={rec['flops']:.3e}, hbm={rec['hbm_bytes']:.3e}, "
              f"coll={rec['collective_bytes'].get('total', 0):.3e}, "
              f"temp/dev={mem['temp_size']/1e9:.2f}GB "
              f"args/dev={mem['argument_size']/1e9:.2f}GB", flush=True)
    except Exception as e:  # a failure here is a sharding bug
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   compile_s=round(time.time() - t0, 1))
        print(f"[FAIL] {arch} x {shape} x {mesh_name}: {e}", flush=True)
        traceback.print_exc()
    out.append(rec)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        "dry-run needs the 512-device placeholder topology; do not import "
        "jax before this module sets XLA_FLAGS")

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    mesh_names = {"pod2x16x16" if m else "pod16x16" for m in meshes}

    os.makedirs(os.path.abspath(RESULTS), exist_ok=True)
    out_path = args.out or os.path.abspath(
        os.path.join(RESULTS, "dryrun.json"))
    records: list = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            records = [r for r in json.load(f)
                       if not ((args.arch is None or r["arch"] == args.arch)
                               and (args.shape is None
                                    or r["shape"] == args.shape)
                               and r["mesh"] in mesh_names)]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_one(arch, shape, multi, records)
                n_fail += rec["status"] == "fail"
                with open(out_path, "w") as f:
                    json.dump(records, f, indent=1)
    print(f"\nwrote {out_path}; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
