"""Shared lowering logic: build abstract args + shardings and jit-lower one
(arch x shape x mesh) cell.  Used by dryrun.py, benchmarks/roofline.py, and
the perf-iteration scripts — mesh-size agnostic (works on the 1-CPU debug
mesh for tests and the 512-device placeholder topology for the dry-run).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.batches import batch_shapes
from repro.launch import sharding as shd
from repro.launch.shapes import ShapeCell
from repro.models.common import ModelConfig
from repro.models.registry import ModelBundle, get_model
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import TrainState, init_train_state, \
    make_train_step

PyTree = Any


def _abstract(tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _with_shardings(shapes: PyTree, shardings: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def train_state_shapes(bundle: ModelBundle) -> TrainState:
    """Abstract TrainState via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: init_train_state(bundle, k), jax.random.PRNGKey(0))


def train_state_shardings(bundle: ModelBundle, state_shapes: TrainState,
                          mesh: Mesh) -> TrainState:
    axes = bundle.param_axes()
    p_shard = shd.shardings_for_tree(state_shapes.params, axes, mesh,
                                     rules=shd.TRAIN_RULES)
    oaxes = shd.opt_state_axes(axes, state_shapes.params, mesh,
                               rules=shd.TRAIN_RULES)
    m_shard = shd.shardings_for_tree(state_shapes.opt.m, oaxes, mesh,
                                     rules=shd.TRAIN_ZERO1_RULES)
    v_shard = shd.shardings_for_tree(state_shapes.opt.v, oaxes, mesh,
                                     rules=shd.TRAIN_ZERO1_RULES)
    scalar = shd.replicated(mesh)
    opt_shard = state_shapes.opt._replace(step=scalar, m=m_shard, v=v_shard)
    return TrainState(params=p_shard, opt=opt_shard, ef=None)


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh
                ) -> Tuple[PyTree, PyTree]:
    """ShapeDtypeStruct stand-ins + shardings for the cell's model inputs."""
    if cell.kind in ("train", "prefill"):
        shapes = batch_shapes(cfg, cell.global_batch, cell.seq)
        axes = shd.batch_logical_axes(shapes)
        shard = {k: NamedSharding(
            mesh, shd.spec_for(tuple(shapes[k].shape), axes[k], mesh))
            for k in shapes}
        return shapes, shard
    # decode: one token + the decode state
    bundle = get_model(cfg)
    state_shapes = jax.eval_shape(
        lambda: bundle.init_decode_state(cell.global_batch, cell.seq,
                                         cell.seq - 1))
    axes = shd.decode_state_axes(cfg)
    state_shard = jax.tree.map(
        lambda s, ax: NamedSharding(
            mesh, shd.spec_for(tuple(s.shape), ax, mesh)),
        state_shapes, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tok = jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32)
    tok_shard = NamedSharding(
        mesh, shd.spec_for((cell.global_batch,), ("batch",), mesh))
    return {"token": tok, "state": state_shapes}, \
        {"token": tok_shard, "state": state_shard}


def normalize_cost_analysis(raw: Any) -> Dict[str, float]:
    """Flatten `Compiled.cost_analysis()` across JAX versions.

    Older releases return one flat ``{metric: value}`` dict; newer ones
    return a *list* of per-computation dicts (the entry computation first),
    and either may be None/empty for trivial programs.  Every consumer in
    this repo (`LoweredCell.analyses`, and through it dryrun.py,
    benchmarks/roofline.py) wants the entry computation's flat dict, so
    normalize here — one helper, not one patch per call site.
    """
    if raw is None:
        return {}
    if isinstance(raw, dict):
        return raw
    # list of per-computation dicts: the entry computation's totals already
    # include called computations, so merging would double-count — take the
    # first non-empty entry.
    for entry in raw:
        if entry:
            return entry
    return {}


@dataclasses.dataclass
class LoweredCell:
    arch: str
    shape: str
    mesh_name: str
    lowered: Any
    compiled: Any

    def analyses(self) -> Dict:
        cost = normalize_cost_analysis(self.compiled.cost_analysis())
        mem = self.compiled.memory_analysis()
        coll = collective_bytes(self.compiled.as_text())
        out = {
            "flops": float(cost.get("flops", 0.0)),
            "hbm_bytes": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": coll,
            "memory": {
                "argument_size": getattr(mem, "argument_size_in_bytes", 0),
                "output_size": getattr(mem, "output_size_in_bytes", 0),
                "temp_size": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_size": getattr(
                    mem, "generated_code_size_in_bytes", 0),
            },
        }
        return out


_COLL_RE = re.compile(
    r"^\s*(?:\S+\s*=\s*)?((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
                "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
                "pred": 1}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in optimized HLO.

    cost_analysis() does not expose collective traffic, so we parse the
    compiled module: each matched op contributes the byte size of its
    result shape(s) (per participating device).
    """
    totals: Dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shapes_str, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(shapes_str):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0.0) + nbytes
        totals["total"] = totals.get("total", 0.0) + nbytes
    return totals


def lower_cell(arch: str, cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
               mesh_name: str = "", donate: bool = True,
               seq_parallel: bool = True,
               accum_steps=None) -> LoweredCell:
    if seq_parallel and not cfg.act_pspec and cell.kind in ("train",
                                                            "prefill"):
        # Megatron-SP: residual stream sharded (batch -> data, seq -> model)
        # at layer boundaries, so remat-saved activations are 16x smaller
        bax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
        cfg = dataclasses.replace(cfg, act_pspec=(bax, "model"))
    bundle = get_model(cfg)
    with mesh:
        if cell.kind == "train":
            opt_cfg = AdamWConfig(
                schedule="wsd" if "minicpm" in arch else "cosine")
            # MoE cells microbatch 4x: expert capacity buffers dominate
            # activation memory and scale with tokens-in-flight
            accum = accum_steps if accum_steps else (4 if cfg.is_moe else 1)
            step_fn = make_train_step(bundle, opt_cfg, accum_steps=accum)
            state_shapes = train_state_shapes(bundle)
            state_shard = train_state_shardings(bundle, state_shapes, mesh)
            batch_sh, batch_shard = input_specs(cfg, cell, mesh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(state_shard, batch_shard),
                out_shardings=(state_shard, shd.replicated(mesh)),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(_with_shardings(state_shapes, state_shard),
                                   _with_shardings(batch_sh, batch_shard))
        elif cell.kind == "prefill":
            # serving stores bf16 weights (training keeps f32 masters);
            # AutoQuant int8 stores halve this again (see repro.quant)
            params_shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
                jax.eval_shape(bundle.init_params, jax.random.PRNGKey(0)))
            p_shard = shd.shardings_for_tree(params_shapes,
                                             bundle.param_axes(), mesh)
            batch_sh, batch_shard = input_specs(cfg, cell, mesh)
            jitted = jax.jit(bundle.forward,
                             in_shardings=(p_shard, batch_shard))
            lowered = jitted.lower(_with_shardings(params_shapes, p_shard),
                                   _with_shardings(batch_sh, batch_shard))
        else:  # decode
            params_shapes = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
                jax.eval_shape(bundle.init_params, jax.random.PRNGKey(0)))
            p_shard = shd.shardings_for_tree(params_shapes,
                                             bundle.param_axes(), mesh)
            specs, shards = input_specs(cfg, cell, mesh)

            def serve_step(params, token, state):
                return bundle.decode_step(params, token, state)

            jitted = jax.jit(
                serve_step,
                in_shardings=(p_shard, shards["token"], shards["state"]),
                out_shardings=None,
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(
                _with_shardings(params_shapes, p_shard),
                _with_shardings(specs["token"], shards["token"]),
                _with_shardings(specs["state"], shards["state"]))
        compiled = lowered.compile()
    return LoweredCell(arch=arch, shape=cell.name, mesh_name=mesh_name,
                       lowered=lowered, compiled=compiled)
