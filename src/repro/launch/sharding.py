"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter / state tensor carries a tuple of logical axis names
(`repro.models.common.ParamSpec.axes`); the rules below map each name to a
mesh axis.  `spec_for` enforces divisibility: a dim whose size does not
divide the mapped mesh axes is REPLICATED instead (e.g. paligemma's 10
kv-heads never meet the 16-way model axis — its attention shards on the
joined head*dim axes instead, which are always multiples of 16 here).

TP scheme (Megatron): column-parallel up/gate + q/k/v projections
("heads_joined"/"kv_joined"/"mlp" -> model), row-parallel down/out
projections, vocab-parallel embed/unembed.  MoE experts shard on the
intra-expert FFN axis ("expert_ff" -> model).  Decode KV caches shard their
sequence axis over the model axis (context-parallel cache).  DP batch
shards over ("pod", "data").

ZeRO-1: optimizer moments additionally shard the first replicated,
divisible dim over "data" (`zero1_axes`).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# logical axis -> mesh axis (or tuple of mesh axes)
BASE_RULES: Dict[str, Any] = {
    "vocab": "model",
    "mlp": "model",
    "expert_ff": "model",
    "heads_joined": "model",
    "kv_joined": "model",
    "cache_seq": "model",
    "rwkv_k": "model",
    "ssm_state": "model",
    "batch": ("pod", "data"),
    "band_rows": "band",   # pipeline row-band grid (lowering.sharded)
    "embed": None,
    "experts": None,
    "layers": None,
    "heads": "model",
    "kv_heads": "model",   # cache prefers head sharding; falls back to seq
    "head_dim": None,
    "seq": None,
    "groups": None,
    "rwkv_heads": None,
    "rwkv_v": None,
    "ssm_heads": None,
    "ssm_p": None,
}


def _mesh_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([_mesh_size(mesh, a) for a in axis]))
    return mesh.shape[axis] if axis in mesh.axis_names else 1


def _present(mesh: Mesh, axis):
    """Restrict a rule to the axes that exist in this mesh."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.axis_names)
        return kept if kept else None
    return axis if axis in mesh.axis_names else None


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             mesh: Mesh, rules: Optional[Dict] = None) -> P:
    """PartitionSpec for one tensor, with divisibility fallback."""
    rules = rules or BASE_RULES
    assert len(shape) == len(axes), (shape, axes)
    parts = []
    used = set()
    for dim, name in zip(shape, axes):
        mapped = _present(mesh, rules.get(name)) if name else None
        if mapped is None:
            parts.append(None)
            continue
        size = _mesh_size(mesh, mapped)
        flat = mapped if isinstance(mapped, tuple) else (mapped,)
        if dim % size != 0 or any(a in used for a in flat):
            parts.append(None)        # replicate: non-divisible or axis reuse
        else:
            parts.append(mapped)
            used.update(flat)
    return P(*parts)


def shardings_for_tree(shapes: PyTree, axes: PyTree, mesh: Mesh,
                       rules: Optional[Dict] = None) -> PyTree:
    """NamedSharding tree parallel to a ShapeDtypeStruct tree."""

    def one(sds, ax):
        return NamedSharding(mesh, spec_for(tuple(sds.shape), ax, mesh, rules))

    return jax.tree.map(one, shapes, axes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def zero1_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
               mesh: Mesh, rules: Optional[Dict] = None
               ) -> Tuple[Optional[str], ...]:
    """Optimizer-moment axes: param axes + 'data' on one replicated dim."""
    rules = rules or BASE_RULES
    data = _mesh_size(mesh, "data")
    out = list(axes)
    for i, (dim, name) in enumerate(zip(shape, axes)):
        mapped = _present(mesh, rules.get(name)) if name else None
        if mapped is None and dim % data == 0 and dim >= data:
            out[i] = "zero1"
            break
    return tuple(out)


ZERO1_RULES = dict(BASE_RULES, zero1="data")

# Training rules: FSDP-style 2D weight sharding — the "embed" (d_model) dim
# of every weight additionally shards over the data axis, so a 47B Mixtral's
# parameters + moments fit 256 chips (XLA inserts the per-layer all-gather /
# reduce-scatter pair, the standard TP+FSDP hybrid).  Serving keeps weights
# TP-only ("embed" replicated) for latency.
TRAIN_RULES = dict(BASE_RULES, embed=("pod", "data"))
TRAIN_ZERO1_RULES = dict(TRAIN_RULES, zero1="data")


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# batch / decode-state logical axes
# ---------------------------------------------------------------------------

def batch_logical_axes(batch_shapes: Dict) -> Dict:
    out = {}
    for k, sds in batch_shapes.items():
        if k in ("tokens", "labels", "mask"):
            out[k] = ("batch", "seq")
        elif k == "patch_embeds":
            out[k] = ("batch", "seq", "embed")
        elif k == "frames":
            out[k] = ("batch", "seq", "embed")
        else:
            out[k] = tuple([None] * len(sds.shape))
    return out


def decode_state_axes(cfg) -> Dict:
    """Logical axes for repro.models.lm/encdec decode states."""
    if cfg.arch_class in ("dense", "moe", "vlm"):
        kv = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
        if getattr(cfg, "kv_cache_dtype", "bf16") == "int8":
            sc = ("layers", "batch", "kv_heads", "cache_seq", None)
            return {"k": kv, "v": kv, "k_scale": sc, "v_scale": sc,
                    "length": ()}
        return {"k": kv, "v": kv, "length": ()}
    if cfg.arch_class == "rwkv":
        return {
            "s": ("layers", "batch", "rwkv_heads", "rwkv_k", "rwkv_v"),
            "x_att": ("layers", "batch", "embed"),
            "x_ffn": ("layers", "batch", "embed"),
            "length": (),
        }
    if cfg.arch_class == "hybrid":
        return {
            "s": ("layers", "batch", "ssm_heads", "ssm_state", "ssm_p"),
            "conv": ("layers", "batch", None, "heads_joined"),
            "attn_k": ("groups", "batch", "kv_heads", "cache_seq", "head_dim"),
            "attn_v": ("groups", "batch", "kv_heads", "cache_seq", "head_dim"),
            "length": (),
        }
    if cfg.arch_class == "encdec":
        kv = ("layers", "batch", "kv_heads", "cache_seq", "head_dim")
        enc = ("layers", "batch", "kv_heads", None, "head_dim")
        return {"k": kv, "v": kv, "cross_k": enc, "cross_v": enc,
                "length": ()}
    raise ValueError(cfg.arch_class)


def opt_state_axes(param_axes_tree: PyTree, param_shapes: PyTree,
                   mesh: Mesh, rules: Optional[Dict] = None) -> Any:
    """Axes for OptState(m, v) with ZeRO-1 'data' sharding."""
    def one(ax, sds):
        return zero1_axes(ax, tuple(sds.shape), mesh, rules)

    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(one, param_axes_tree, param_shapes, is_leaf=is_axes)
