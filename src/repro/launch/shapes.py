"""The assigned input-shape cells and per-(arch x shape) applicability."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def skip_reason(cfg: ModelConfig, cell: ShapeCell) -> Optional[str]:
    """None = runnable; else the reason this cell is skipped (documented)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention arch: 500k-token decode needs sub-quadratic "
                "state (run for ssm/hybrid only, per task spec)")
    return None


def runnable_cells(cfg: ModelConfig):
    return [c for c in SHAPES.values() if skip_reason(cfg, c) is None]
