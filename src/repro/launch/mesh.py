"""Production mesh definitions.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run process forces a 512-device host platform before any
jax initialization; tests and benches keep the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod's 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices exist (tests on 1 CPU)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_band_mesh(n: int | None = None):
    """1-D mesh whose ``"band"`` axis shards a pipeline's row-band grid.

    The sharded pipeline executor (`repro.lowering.sharded`) splits each
    rate island's lattice-aligned band schedule over this axis.  Defaults
    to every local device (1 on the CPU test host — the sharded program
    still runs through `shard_map`, exercising the full geometry).
    """
    n = len(jax.devices()) if n is None else n
    return jax.make_mesh((n,), ("band",))


def batch_axes(mesh) -> tuple:
    """The mesh axes a global-batch dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)
