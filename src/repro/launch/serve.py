"""Serving launcher: batched autoregressive decode with continuous batching.

A minimal production-shaped server loop: a request queue feeds decode slots;
finished sequences release their slot to the next request (continuous
batching); every slot shares the jitted one-token `decode_step` whose state
layout is the dry-run's serve_step.  Optionally weights are stored int8
(AutoQuant) and dequantized on the fly.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --requests 6 --slots 2 --max-new 16
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.models.registry import get_model


class Request:
    def __init__(self, rid: int, prompt: List[int], max_new: int):
        self.rid = rid
        self.prompt = prompt
        self.max_new = max_new
        self.generated: List[int] = []
        self.done = False


class ContinuousBatcher:
    """Slot-based continuous batching over a shared decode state."""

    def __init__(self, bundle, params, n_slots: int, max_len: int):
        self.bundle = bundle
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.state = bundle.init_decode_state(n_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_remaining = np.zeros(n_slots, dtype=np.int64)
        self.next_tok = np.zeros(n_slots, dtype=np.int32)
        self._step = jax.jit(bundle.decode_step)

    def admit(self, req: Request) -> bool:
        for s in range(self.n_slots):
            if self.slot_req[s] is None:
                self.slot_req[s] = req
                # prefill-by-decode: feed prompt tokens one at a time (the
                # prefill_32k path lowers the fused version; this loop is the
                # slot-local fallback that shares the same state layout)
                self.next_tok[s] = req.prompt[0]
                self.slot_remaining[s] = len(req.prompt) - 1 + req.max_new
                return True
        return False

    def active(self) -> bool:
        return any(r is not None for r in self.slot_req)

    def step(self):
        logits, self.state = self._step(
            self.params, jnp.asarray(self.next_tok), self.state)
        sampled = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            consumed = len(req.prompt) - 1 + req.max_new - self.slot_remaining[s]
            if consumed + 1 < len(req.prompt):
                self.next_tok[s] = req.prompt[consumed + 1]   # still prefilling
            else:
                req.generated.append(int(sampled[s]))
                self.next_tok[s] = sampled[s]
            self.slot_remaining[s] -= 1
            if self.slot_remaining[s] <= 0:
                req.done = True
                self.slot_req[s] = None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--quant-bits", type=int, default=0,
                    help="0 = bf16 weights; 8/4 = AutoQuant fake-quant store")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    bundle = get_model(cfg)
    mesh = make_debug_mesh()
    rng = np.random.default_rng(0)

    with mesh:
        params = bundle.init_params(jax.random.PRNGKey(0))
        if args.quant_bits:
            from repro.quant.autoquant import fake_quant_params
            from repro.quant.calibrate import REVERSE_TOPO_CLASSES
            params = fake_quant_params(
                params, {c: args.quant_bits for c in REVERSE_TOPO_CLASSES})
            print(f"serving with {args.quant_bits}-bit weights")

        batcher = ContinuousBatcher(bundle, params, args.slots, args.max_len)
        requests = [Request(i, list(rng.integers(0, cfg.vocab_size, size=4)),
                            args.max_new) for i in range(args.requests)]
        pending = list(requests)
        t0 = time.time()
        steps = 0
        while pending or batcher.active():
            while pending and batcher.admit(pending[0]):
                pending.pop(0)
            batcher.step()
            steps += 1
        dt = time.time() - t0
        assert all(r.done for r in requests)
        n_toks = sum(len(r.generated) for r in requests)
        print(f"served {args.requests} requests ({n_toks} tokens) in "
              f"{steps} decode steps, {dt:.1f}s ({steps / max(dt, 1e-9):.1f} "
              f"steps/s)")
    return steps


if __name__ == "__main__":
    main()
