"""Training launcher: end-to-end driver with checkpoint/restart.

On the real cluster each host runs this under `jax.distributed.initialize`
with the production mesh; on this container it runs the same code on the
debug mesh (1 device) with reduced configs — the fault-tolerance loop,
checkpointing, and data sharding are identical code paths.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.data.batches import TokenStream
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models.registry import get_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine",
                    choices=["cosine", "wsd", "constant"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 gradient compression (error feedback)")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if "minicpm" in args.arch and args.schedule == "cosine":
        args.schedule = "wsd"       # the arch's signature schedule
    bundle = get_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_debug_mesh())

    opt_cfg = AdamWConfig(lr=args.lr, schedule=args.schedule,
                          warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    step_fn = make_train_step(bundle, opt_cfg,
                              compress_grads=args.compress_grads,
                              accum_steps=args.accum)
    stream = TokenStream(cfg, args.batch, args.seq)

    with mesh:
        state = init_train_state(bundle, jax.random.PRNGKey(0),
                                 compress_grads=args.compress_grads)
        start_step = 0
        saver = None
        if args.ckpt_dir:
            saver = ckpt.AsyncCheckpointer(args.ckpt_dir)
            restored, step0 = ckpt.restore_latest(args.ckpt_dir, state)
            if restored is not None:
                state = restored
                start_step = step0 + 1
                print(f"resumed from step {step0}", flush=True)

        jitted = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = stream.batch_at(step)
            state, metrics = jitted(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t0
                print(f"step {step:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"({dt:.1f}s)", flush=True)
                if not np.isfinite(loss):
                    raise RuntimeError("loss diverged")
            if saver and step > 0 and step % args.ckpt_every == 0:
                saver.save(step, state)
        if saver:
            saver.save(args.steps - 1, state)
            saver.wait()
    print("done")
    return state


if __name__ == "__main__":
    main()
