"""Analytic FLOP/byte model per (arch x shape) — the roofline numerator.

XLA's cost_analysis visits each while-loop (scan) body ONCE regardless of
trip count, so compiled HLO_FLOPs understate scanned models by ~L.  The
roofline pipeline therefore combines:

  * MODEL_FLOPS   — the classic 6*N*D (dense) / 6*N_active*D (MoE) training
                    estimate, decode variants for serve steps
  * ANALYTIC      — a per-op walk of the architecture (matmuls, attention
                    quadratic term, chunked-recurrence work), forward or
                    forward+backward
  * HLO           — compiled cost_analysis, corrected for scan trip counts
                    by the differential method in benchmarks/roofline.py

Bytes: parameter traffic + activation traffic at the layer interfaces
(lower bound; the compiled bytes-accessed figure is the upper line).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.launch.shapes import ShapeCell
from repro.models.common import ModelConfig


@dataclasses.dataclass
class FlopsReport:
    model_flops: float       # 6ND-style headline number (per step, global)
    analytic_flops: float    # op-walk estimate (per step, global)
    param_bytes: float       # one full parameter read (bf16)
    act_bytes: float         # layer-interface activation traffic (bf16)


def _attn_layer_flops(cfg: ModelConfig, T: int, ctx: int) -> float:
    """One attention block, forward: projections + score/value matmuls."""
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    proj = 2 * T * D * (H * hd + 2 * KV * hd) + 2 * T * H * hd * D
    window = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
    quad = 2 * 2 * T * window * H * hd * 0.5   # causal halves the square
    return proj + quad


def _mlp_layer_flops(cfg: ModelConfig, T: int) -> float:
    D = cfg.d_model
    if cfg.is_moe:
        f = 6 * T * cfg.top_k * D * cfg.moe_d_ff
        if cfg.shared_expert_d_ff:
            f += 6 * T * D * cfg.shared_expert_d_ff
        f += 2 * T * D * cfg.n_experts      # router
        return f
    return 6 * T * D * cfg.d_ff


def _rwkv_layer_flops(cfg: ModelConfig, T: int, chunk: int = 64) -> float:
    D = cfg.d_model
    K = cfg.rwkv_head_dim
    H = D // K
    proj = 2 * T * D * D * 5 + 2 * T * D * D         # r,k,v,g,o + w-lora-ish
    # chunked WKV: per chunk 4C^2K + 4CKV + O(CK) per head
    C = chunk
    nc = max(T // C, 1)
    rec = H * nc * (4 * C * C * K + 4 * C * K * K)
    cmix = 2 * T * D * cfg.d_ff * 2 + 2 * T * D * D
    return proj + rec + cmix


def _mamba_layer_flops(cfg: ModelConfig, T: int, chunk: int = 64) -> float:
    D = cfg.d_model
    d_inner = cfg.ssm_expand * D
    N = cfg.ssm_state
    P = cfg.ssm_head_dim
    H = d_inner // P
    d_in_proj = 2 * d_inner + 2 * N + H
    proj = 2 * T * D * d_in_proj + 2 * T * d_inner * D
    conv = 2 * T * (d_inner + 2 * N) * 4
    C = chunk
    nc = max(T // C, 1)
    rec = nc * (2 * C * C * N + H * (C * C + 2 * C * C * P + 4 * C * N * P))
    return proj + conv + rec


def _layer_flops(cfg: ModelConfig, T: int, ctx: int) -> float:
    if cfg.arch_class == "rwkv":
        return _rwkv_layer_flops(cfg, T)
    if cfg.arch_class == "hybrid":
        per_mamba = _mamba_layer_flops(cfg, T)
        shared = _attn_layer_flops(cfg, T, ctx) + _mlp_layer_flops(cfg, T)
        # one shared block per `period` mamba layers
        return per_mamba + shared / cfg.shared_attn_period
    return _attn_layer_flops(cfg, T, ctx) + _mlp_layer_flops(cfg, T)


def analytic_flops(cfg: ModelConfig, cell: ShapeCell) -> FlopsReport:
    B, S = cell.global_batch, cell.seq
    D, Vp = cfg.d_model, cfg.vocab_padded
    N_param = cfg.param_count()

    if cell.kind in ("train", "prefill"):
        T = B * S
        fwd = cfg.n_layers * _layer_flops(cfg, T, S) + 2 * T * D * Vp
        if cfg.arch_class == "encdec":
            Te = B * cfg.encoder_seq
            fwd += cfg.n_encoder_layers * (
                _attn_layer_flops(cfg, Te, cfg.encoder_seq)
                + _mlp_layer_flops(cfg, Te))
            # cross attention per decoder layer
            fwd += cfg.n_layers * 2 * 2 * T * cfg.encoder_seq * cfg.n_heads \
                * cfg.hd
        total = 3 * fwd if cell.kind == "train" else fwd
        if cell.kind == "train" and cfg.remat:
            total += fwd  # full-block remat recomputes the forward once
        model = (6 if cell.kind == "train" else 2)
        n_active = N_param
        if cfg.is_moe:
            E, k = cfg.n_experts, cfg.top_k
            expert_p = 3 * D * cfg.moe_d_ff * cfg.n_layers
            n_active = N_param - (E - k) * expert_p
        model_flops = model * n_active * T
        act = 2 * cfg.n_layers * T * D * 2
    else:  # decode: one token per sequence, context = cell.seq
        T = B
        ctx = S
        fwd = cfg.n_layers * _layer_flops(cfg, T, ctx) + 2 * T * D * Vp
        if cfg.arch_class in ("rwkv", "hybrid"):
            # recurrent decode touches state, not context
            fwd = cfg.n_layers * _layer_flops(cfg, T, 1) + 2 * T * D * Vp
        total = fwd
        n_active = N_param
        if cfg.is_moe:
            E, k = cfg.n_experts, cfg.top_k
            expert_p = 3 * D * cfg.moe_d_ff * cfg.n_layers
            n_active = N_param - (E - k) * expert_p
        model_flops = 2 * n_active * T
        # decode reads the KV cache / state once per step
        if cfg.arch_class in ("dense", "moe", "vlm", "encdec"):
            act = (cfg.n_layers * 2 * B * cfg.n_kv_heads * S * cfg.hd * 2
                   + 2 * B * D * cfg.n_layers * 2)
        else:
            K = cfg.rwkv_head_dim
            act = cfg.n_layers * B * (D // K) * K * K * 4 * 2

    param_bytes = 2.0 * N_param      # one bf16 sweep
    return FlopsReport(model_flops=float(model_flops),
                       analytic_flops=float(total),
                       param_bytes=float(param_bytes),
                       act_bytes=float(act))
