"""Elastic re-scaling: move a training job between device topologies.

Scenario (the 1000+-node reality): a pod loses a rack mid-run, or capacity
grows.  Because checkpoints are host-numpy trees (train/checkpoint.py) and
shardings are *derived* (logical axes x rules x mesh), re-scaling is:

    1. restore_latest(...) with shardings built for the NEW mesh
    2. verify divisibility (spec_for's fallback replicates what no longer
       divides — reported, not fatal)
    3. resume; the deterministic TokenStream re-shards the data pipeline
       (seed depends on (step, shard), so no sample is skipped or repeated)

`plan_rescale` reports exactly which tensors change layout and which fall
back to replication, so an operator can veto a bad target topology.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax

from repro.launch import sharding as shd


@dataclasses.dataclass
class RescalePlan:
    n_from: int
    n_to: int
    resharded: List[str]          # tensors whose PartitionSpec changes
    newly_replicated: List[str]   # tensors that no longer divide -> warn
    bytes_moved: float            # lower-bound resharding traffic


def plan_rescale(shapes_tree, axes_tree, mesh_from, mesh_to,
                 rules=None) -> RescalePlan:
    resharded, newly_repl = [], []
    moved = 0.0
    flat_s = jax.tree_util.tree_flatten_with_path(shapes_tree)[0]
    flat_a = jax.tree.leaves(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    for (path, sds), axes in zip(flat_s, flat_a):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        s_from = shd.spec_for(tuple(sds.shape), axes, mesh_from, rules)
        s_to = shd.spec_for(tuple(sds.shape), axes, mesh_to, rules)
        if s_from != s_to:
            resharded.append(name)
            moved += float(sds.size) * sds.dtype.itemsize
        sharded_from = any(p is not None for p in s_from)
        sharded_to = any(p is not None for p in s_to)
        if sharded_from and not sharded_to:
            newly_repl.append(name)
    return RescalePlan(
        n_from=int(np_prod(mesh_from.shape.values())),
        n_to=int(np_prod(mesh_to.shape.values())),
        resharded=resharded, newly_replicated=newly_repl,
        bytes_moved=moved)


def np_prod(vals):
    out = 1
    for v in vals:
        out *= v
    return out


def rescale_restore(ckpt_dir: str, like_tree, axes_tree, new_mesh,
                    rules=None):
    """Restore the latest checkpoint re-sharded for `new_mesh`."""
    from repro.train import checkpoint as ckpt
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), like_tree)
    shards = shd.shardings_for_tree(shapes, axes_tree, new_mesh, rules)
    return ckpt.restore_latest(ckpt_dir, like_tree, sharding_tree=shards)
