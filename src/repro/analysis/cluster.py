"""Homogeneity clustering (paper §IV) as a real `AnalysisPass`.

The paper observes that image-processing DAGs are full of *homogeneous*
stages — same sampling rate, same signal statistics, same operator shape —
and that synthesizing one shared datapath per homogeneity class costs
almost nothing in precision while collapsing both the generated hardware
and, for us, the `(alpha, beta)` search space (`repro.dse` makes one
decision per cluster instead of one per stage).

`ClusterPass` wraps any sub-pass: stages are grouped by

  * **rate** — the stage's output-grid rate relative to the pipeline root
    (exact `Fraction`s accumulated through stride/upsample, the same
    lattice walk `repro.smt.encoder.sampling_lattice` performs);
  * **signal statistics** — the sub-column's (signed, alpha) of the stage;
  * **datapath shape** — the operator census of the stage expression
    (`core.graph.expr_ops`) plus input arity, i.e. what the stage would
    synthesize to;
  * input-ness (input stages never merge with compute stages).

Each cluster's range is the join of its members' ranges and its alpha the
members' max — members share (signed, alpha) by construction, so the join
keeps the same alpha and every member range nests inside its cluster
range: `plan.check_nesting([sub_column, cluster_column])` holds, which
`tests/test_dse.py` pins as the cluster soundness gate.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, List, Optional, Tuple

from repro.core.graph import Pipeline, expr_ops
from repro.core.interval import Interval
from repro.core.range_analysis import StageRange

from repro.analysis.passes import (PassContext, PassResult, make_pass,
                                   register_pass)

Rate = Tuple[Fraction, Fraction]


def stage_rates(pipeline: Pipeline) -> Dict[str, Rate]:
    """Output-grid rate of every stage relative to the pipeline root.

    rate(input) = 1; rate(stage) = rate(in) * upsample / stride per axis —
    the forward lattice accumulation of the phase-split encoder.
    """
    rates: Dict[str, Rate] = {}
    for name in pipeline.topo_order():
        st = pipeline.stages[name]
        if st.is_input or not st.inputs:
            rates[name] = (Fraction(1), Fraction(1))
            continue
        ry, rx = rates[st.inputs[0]]
        uy, ux = st.upsample
        sy, sx = st.stride
        rates[name] = (ry * uy / sy, rx * ux / sx)
    return rates


def _shape_sig(pipeline: Pipeline, name: str) -> Tuple:
    """Datapath-shape signature: operator census + arity + halo extent."""
    st = pipeline.stages[name]
    if st.is_input or st.expr is None:
        return ("input",)
    return (tuple(sorted(expr_ops(st.expr).items())), len(st.inputs),
            st.halo_yx())


def homogeneity_clusters(pipeline: Pipeline,
                         stage_ranges: Dict[str, StageRange],
                         ) -> List[List[str]]:
    """Partition stages into §IV homogeneity classes (topo-stable order).

    Two stages cluster iff they agree on rate, (signed, alpha) of the
    given range column, and datapath shape.  Singleton clusters are kept —
    every stage belongs to exactly one class.
    """
    rates = stage_rates(pipeline)
    groups: Dict[Tuple, List[str]] = {}
    for name in pipeline.topo_order():
        sr = stage_ranges[name]
        key = (pipeline.stages[name].is_input, rates[name],
               sr.signed, sr.alpha, _shape_sig(pipeline, name))
        groups.setdefault(key, []).append(name)
    # stable order: by first member's topo position
    order = {n: i for i, n in enumerate(pipeline.topo_order())}
    return sorted(groups.values(), key=lambda ms: order[ms[0]])


class ClusterPass:
    """Sub-pass ranges, re-joined per homogeneity cluster (see module doc).

    The emitted column assigns every member its cluster's joined range, so
    a consumer that types from this column automatically shares one
    (alpha, signed) decision per cluster; cluster membership lands in the
    column notes (and thus plan provenance / serialized JSON).
    """

    name = "cluster"

    def __init__(self, sub="smt", column: Optional[str] = None):
        self.sub = make_pass(sub)
        self.column = column or f"cluster({self.sub.column})"

    def key(self) -> str:
        return f"cluster({self.sub.key()})"

    def run(self, ctx: PassContext) -> PassResult:
        res = ctx.run(self.sub)
        srs = res.stage_ranges()
        clusters = homogeneity_clusters(ctx.pipeline, srs)
        ranges: Dict[str, Interval] = {}
        alphas: Dict[str, int] = {}
        for members in clusters:
            joined = srs[members[0]].range
            for m in members[1:]:
                joined = joined.join(srs[m].range)
            alpha = max(srs[m].alpha for m in members)
            for m in members:
                ranges[m] = joined
                alphas[m] = alpha
        n_multi = sum(1 for c in clusters if len(c) > 1)
        notes = [f"{len(clusters)} homogeneity cluster(s) over "
                 f"{len(ranges)} stage(s) ({n_multi} shared): "
                 + "; ".join("{" + ",".join(c) + "}" for c in clusters)]
        return PassResult(ranges=ranges, alphas=alphas,
                          notes=list(res.notes) + notes)


def cluster(sub="smt", column: Optional[str] = None) -> ClusterPass:
    return ClusterPass(sub, column=column)


register_pass("cluster", lambda **kw: ClusterPass(**kw))
