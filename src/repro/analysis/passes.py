"""`AnalysisPass` protocol, registry, and the built-in passes.

A pass is the unit of composition of the analysis architecture (paper §V:
"easily deploying any kind of interval/affine arithmetic based range
analyses in the DSL compiler").  Every pass

  * names itself (`name`) and its output plan column (`column`);
  * exposes a **content key** (`key()`) — a stable string over all of its
    parameters, combined with the pipeline content hash for memoization;
  * `run(ctx)` returns a `PassResult`: per-stage sound `Interval` bounds,
    optional explicit alphas (profile statistics are not range-derived),
    optional per-phase sub-ranges keyed by sampling-lattice residue, and
    free-form notes that land in plan provenance.

Passes compose through `PassContext.run`, which consults the driver's memo
table — a sub-pass shared by two combinators executes once per pipeline.
Built-ins wrap the existing analyses: the per-stage domain walk
(interval / affine / intersect), the whole-DAG SMT tightening (with an
optional per-phase collection mode), and the profile executor.  The
combinators (`meet`, `refine`, `widen_to`) live in
`repro.analysis.combinators`.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

from repro.core.graph import Pipeline
from repro.core.interval import Interval
from repro.core.profile import profile_pipeline
from repro.core.range_analysis import StageRange, analyze_direct

Residue = Tuple[int, int]


@dataclasses.dataclass
class PassResult:
    """What one pass produces for one pipeline (pre-plan form)."""
    ranges: Dict[str, Interval]
    # explicit alpha override (profile's alpha^max is a per-pixel statistic,
    # not `alpha_for_range` of the observed join — see core.profile)
    alphas: Optional[Dict[str, int]] = None
    # per-phase sub-ranges: stage -> (lattice (My, Mx), residue -> Interval)
    phases: Optional[Dict[str, Tuple[Tuple[int, int],
                                     Dict[Residue, Interval]]]] = None
    notes: List[str] = dataclasses.field(default_factory=list)

    def stage_ranges(self) -> Dict[str, StageRange]:
        out = {}
        for n, iv in self.ranges.items():
            sr = StageRange.from_interval(iv)
            if self.alphas is not None and n in self.alphas:
                sr = StageRange(range=iv, alpha=self.alphas[n], signed=sr.signed)
            out[n] = sr
        return out

    def phase_stage_ranges(self) -> Optional[Dict]:
        if not self.phases:
            return None
        return {stage: (lat, {res: StageRange.from_interval(iv)
                              for res, iv in rmap.items()})
                for stage, (lat, rmap) in self.phases.items()}


class PassContext(Protocol):
    """What the driver hands each pass (see `repro.analysis.driver`)."""
    pipeline: Pipeline
    input_ranges: Optional[Dict[str, Interval]]

    def run(self, p: "AnalysisPass") -> PassResult: ...
    def with_input_ranges(self, ir: Dict[str, Interval]) -> "PassContext": ...


class AnalysisPass(Protocol):
    name: str
    column: str

    def key(self) -> str: ...
    def run(self, ctx: PassContext) -> PassResult: ...


# ---------------------------------------------------------------------------
# built-in passes
# ---------------------------------------------------------------------------

class DomainPass:
    """Per-stage abstract walk in a registered domain (Algorithm 1)."""

    def __init__(self, domain: str, column: Optional[str] = None):
        self.name = domain
        self.domain = domain
        self.column = column or domain

    def key(self) -> str:
        return f"domain:{self.domain}"

    def run(self, ctx: PassContext) -> PassResult:
        res = analyze_direct(ctx.pipeline, self.domain,
                             input_ranges=ctx.input_ranges)
        return PassResult(ranges={n: r.range for n, r in res.items()})


class SmtPass:
    """Whole-DAG branch-and-prune tightening (`repro.smt.analyze_smt`).

    `phases=True` additionally collects per-phase certified sub-ranges on
    phase-split stages (one entry per sampling-lattice residue) — the union
    bound is unchanged, the sub-ranges become plan phase columns.
    """

    name = "smt"

    def __init__(self, config=None, phases: bool = False,
                 engine: Optional[str] = None, column: str = "smt"):
        self.config = config
        self.phases = phases
        self.engine = engine
        self.column = column

    def _config(self):
        from repro.smt import SMTConfig
        cfg = self.config if self.config is not None else SMTConfig()
        if self.engine is not None and cfg.engine != self.engine:
            cfg = dataclasses.replace(cfg, engine=self.engine)
        return cfg

    def key(self) -> str:
        return f"smt:phases={self.phases}:{self._config()!r}"

    def run(self, ctx: PassContext) -> PassResult:
        from repro.smt import analyze_smt
        collect: Optional[Dict] = {} if self.phases else None
        diag: Dict = {}
        res = analyze_smt(ctx.pipeline, input_ranges=ctx.input_ranges,
                          config=self._config(), collect_phases=collect,
                          diagnostics=diag)
        phases = None
        if collect:
            phases = {stage: (lat, dict(rmap))
                      for stage, (lat, rmap) in collect.items()}
        notes = []
        starved = diag.get("budget_exhausted") or []
        if starved:
            # lands in plan provenance (and thus serialized plan JSON), so
            # downstream readers — benchmarks/alpha_delta.py — can flag
            # seed-kept alphas instead of treating them as converged
            notes.append("budget-exhausted (seed kept): "
                         + ", ".join(starved))
        return PassResult(ranges={n: r.range for n, r in res.items()},
                          phases=phases, notes=notes)


def _hash_images(images) -> str:
    import numpy as np
    h = hashlib.sha256()
    for img in images:
        arrs = img if isinstance(img, (tuple, list)) else (img,)
        if isinstance(img, dict):
            arrs = [img[k] for k in sorted(img)]
        for a in arrs:
            a = np.ascontiguousarray(a)
            h.update(str(a.shape).encode())
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
    return h.hexdigest()[:16]


class ProfilePass:
    """Empirical lower-bound column: run the float executor over samples.

    The result is *not* a sound worst-case bound — it is the paper's
    profile-driven analysis (§V-A), the floor every sound column must
    enclose.  `runner(image, params) -> {stage: ndarray}` defaults to the
    pipeline-bound float executor (`dsl.exec.make_profile_runner`, imported
    lazily so the analysis layer stays jax-free until needed).
    """

    name = "profile"

    _seq = 0       # per-instance token for custom runners (see key())

    def __init__(self, images, runner: Optional[Callable] = None,
                 params: Optional[Dict[str, float]] = None,
                 column: str = "profile", key_suffix: str = ""):
        self.images = list(images)
        self.runner = runner
        self.params = dict(params or {})
        self.column = column
        self.key_suffix = key_suffix
        if runner is not None and not key_suffix:
            # a custom runner's behavior is not content-hashable: give each
            # instance its own memo identity (same instance still hits the
            # cache; two instances with different runners never collide)
            ProfilePass._seq += 1
            self.key_suffix = f":runner#{ProfilePass._seq}"
        # images are copied and never mutated: hash once, not per key() call
        self._img_hash = _hash_images(self.images)

    def key(self) -> str:
        return (f"profile:n={len(self.images)}:img={self._img_hash}"
                f":params={sorted(self.params.items())!r}{self.key_suffix}")

    def run(self, ctx: PassContext) -> PassResult:
        runner = self.runner
        if runner is None:
            from repro.dsl.exec import make_profile_runner
            runner = make_profile_runner(ctx.pipeline)
        prof = profile_pipeline(ctx.pipeline, self.images, runner, self.params)
        return PassResult(
            ranges=dict(prof.observed_range),
            alphas=dict(prof.alpha_max),
            notes=[f"profiled over {len(self.images)} sample(s); empirical "
                   f"lower bound, not a sound worst-case range"])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_PASS_REGISTRY: Dict[str, Callable[..., Any]] = {}


def register_pass(name: str, factory: Callable[..., Any]) -> None:
    _PASS_REGISTRY[name] = factory


def make_pass(spec, **kw):
    """Resolve a pass spec: an `AnalysisPass` instance passes through, a
    registry name is instantiated (kwargs forwarded to the factory)."""
    if isinstance(spec, str):
        try:
            factory = _PASS_REGISTRY[spec]
        except KeyError:
            raise KeyError(
                f"unknown analysis pass {spec!r}; registered: "
                f"{sorted(_PASS_REGISTRY)}") from None
        return factory(**kw)
    return spec


register_pass("interval", lambda **kw: DomainPass("interval", **kw))
register_pass("affine", lambda **kw: DomainPass("affine", **kw))
register_pass("intersect", lambda **kw: DomainPass("intersect", **kw))
register_pass("smt", lambda **kw: SmtPass(**kw))
register_pass("smt-scalar",
              lambda **kw: SmtPass(engine="scalar", column="smt-scalar", **kw))
register_pass("smt-phase-split",
              lambda **kw: SmtPass(**{"phases": True,
                                      "column": "smt-phase-split", **kw}))
register_pass("profile", lambda **kw: ProfilePass(**kw))
