"""`BitwidthPlan` — the first-class artifact every analysis pass emits into.

The paper's second contribution is a software architecture in which any
interval/affine-style range analysis plugs into the DSL compiler (§V).  Up
to PR 3 each analysis had its own ad-hoc result shape (a `StageRange` dict
here, an `(alphas, signed)` pair there, a `ProfileResult` elsewhere) and
nothing downstream could consume them interchangeably.  A `BitwidthPlan`
unifies them:

  * **columns** — one `StageRange` column per analysis pass (``interval``,
    ``smt``, ``profile``, ``meet(interval,affine)``, ...);
  * **provenance** — which pass produced each column, with its spec string
    (the memoization key) and free-form notes (e.g. alpha-clamp events);
  * **phase columns** — optional per-stage sub-columns keyed by the
    output-phase residue of the sampling lattice (the PR-3 phase-split
    wins, now representable as one datapath per residue);
  * **betas** — fractional-bit assignments from the beta search;
  * stable JSON (de)serialization, so plans are cacheable artifacts,
    diffable in review, and CI-gateable (`benchmarks/alpha_delta.py`).

Consumers (`workflows.types_from_alpha`, `dsl.exec.run_fixed`,
`benchmarks/paper_tables.py`) read the plan instead of re-deriving ranges.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Dict, List, Optional, Tuple

from repro.core.fixedpoint import FixedPointType
from repro.core.interval import Interval
from repro.core.range_analysis import StageRange

Residue = Tuple[int, int]
# per-column phase data: stage -> (lattice (My, Mx), residue -> StageRange)
PhaseColumn = Dict[str, Tuple[Tuple[int, int], Dict[Residue, StageRange]]]


class PlanNestingError(AssertionError):
    """A plan-level soundness-nesting check failed (see `check_nesting`)."""


@dataclasses.dataclass
class Provenance:
    """Where a plan column came from."""
    pass_name: str            # registry name of the producing pass
    spec: str                 # the pass's content key (parameters included)
    notes: List[str] = dataclasses.field(default_factory=list)


def _sr_to_json(sr: StageRange) -> Dict:
    return {"lo": sr.range.lo, "hi": sr.range.hi,
            "alpha": sr.alpha, "signed": sr.signed}


def _sr_from_json(d: Dict) -> StageRange:
    return StageRange(range=Interval(float(d["lo"]), float(d["hi"])),
                      alpha=int(d["alpha"]), signed=bool(d["signed"]))


@dataclasses.dataclass
class BitwidthPlan:
    """Per-pipeline bit-width synthesis artifact (columns + provenance)."""

    pipeline: str
    content_hash: str = ""
    columns: Dict[str, Dict[str, StageRange]] = \
        dataclasses.field(default_factory=dict)
    provenance: Dict[str, Provenance] = dataclasses.field(default_factory=dict)
    phases: Dict[str, PhaseColumn] = dataclasses.field(default_factory=dict)
    betas: Dict[str, int] = dataclasses.field(default_factory=dict)
    default_column: str = ""

    # -- construction -------------------------------------------------------
    def add_column(self, name: str, ranges: Dict[str, StageRange],
                   provenance: Provenance,
                   phases: Optional[PhaseColumn] = None) -> None:
        if name in self.columns:
            raise ValueError(f"duplicate plan column {name!r}")
        self.columns[name] = dict(ranges)
        self.provenance[name] = provenance
        if phases:
            self.phases[name] = phases
        if not self.default_column:
            self.default_column = name

    # -- queries ------------------------------------------------------------
    def _col(self, column: Optional[str]) -> str:
        name = column or self.default_column
        if name not in self.columns:
            raise KeyError(f"plan has no column {name!r}; "
                           f"columns: {sorted(self.columns)}")
        return name

    def stage_ranges(self, column: Optional[str] = None) -> Dict[str, StageRange]:
        return dict(self.columns[self._col(column)])

    def alphas(self, column: Optional[str] = None) -> Dict[str, int]:
        return {n: r.alpha for n, r in self.columns[self._col(column)].items()}

    def signed(self, column: Optional[str] = None) -> Dict[str, bool]:
        return {n: r.signed for n, r in self.columns[self._col(column)].items()}

    def stages(self) -> List[str]:
        return list(self.columns[self._col(None)])

    def record_election(self, column: Optional[str],
                        notes: List[str]) -> None:
        """Append datapath-election provenance (narrow-mode lowering).

        `repro.lowering.lower(..., datapath="narrow")` calls this with
        its per-stage carrier/dtype census plus one justification line
        per retained 64-bit datapath, so the plan JSON documents *why*
        any wide resource survives the int32/f32-only election.
        """
        col = self._col(column)
        for note in notes:
            if note not in self.provenance[col].notes:
                self.provenance[col].notes.append(note)

    # -- consumption --------------------------------------------------------
    def types(self, column: Optional[str] = None,
              betas: Optional[Dict[str, int]] = None,
              ) -> Dict[str, FixedPointType]:
        """Fixed-point type map for one column (the executor's input).

        Zero/negative alphas are clamped to 1 bit (a `FixedPointType` needs
        at least one field bit); every clamp is recorded in the column's
        provenance notes and surfaced as a `RuntimeWarning` so zero-range
        stages stay visible instead of silently widening.
        """
        col = self._col(column)
        bmap = self.betas if betas is None else betas
        out: Dict[str, FixedPointType] = {}
        clamped: List[str] = []
        for n, r in self.columns[col].items():
            if r.alpha < 1:
                clamped.append(n)
            out[n] = FixedPointType(alpha=max(r.alpha, 1),
                                    beta=bmap.get(n, 0), signed=r.signed)
        if clamped:
            note = (f"alpha clamped to 1 on zero-range stage(s): "
                    f"{', '.join(clamped)}")
            if note not in self.provenance[col].notes:
                self.provenance[col].notes.append(note)
            warnings.warn(f"plan column {col!r}: {note}", RuntimeWarning,
                          stacklevel=2)
        return out

    def phase_types(self, column: Optional[str] = None,
                    betas: Optional[Dict[str, int]] = None,
                    ) -> Dict[str, Tuple[Tuple[int, int],
                                         Dict[Residue, FixedPointType]]]:
        """Per-phase type maps: stage -> (lattice, residue -> type).

        Only stages with phase sub-columns appear; the executor applies the
        union-column type everywhere else (`dsl.exec.run_fixed`).
        """
        col = self._col(column)
        bmap = self.betas if betas is None else betas
        out = {}
        clamped: List[str] = []
        for stage, (lat, rmap) in self.phases.get(col, {}).items():
            if any(sr.alpha < 1 for sr in rmap.values()):
                clamped.append(stage)
            out[stage] = (lat, {
                res: FixedPointType(alpha=max(sr.alpha, 1),
                                    beta=bmap.get(stage, 0), signed=sr.signed)
                for res, sr in rmap.items()})
        if clamped:
            note = (f"alpha clamped to 1 on zero-range phase(s) of: "
                    f"{', '.join(clamped)}")
            if note not in self.provenance[col].notes:
                self.provenance[col].notes.append(note)
            warnings.warn(f"plan column {col!r}: {note}", RuntimeWarning,
                          stacklevel=2)
        return out

    # -- plan-level checks ---------------------------------------------------
    def check_nesting(self, columns: List[str], strict_alpha: bool = True,
                      ) -> bool:
        """Soundness-nesting invariant across columns, tightest first.

        ``check_nesting(["profile", "smt", "meet(interval,affine)"])``
        asserts per stage that each column's range is enclosed by the next
        one's (and, with `strict_alpha`, that alphas are non-decreasing) —
        the plan-level form of the paper's profile ⊆ solver ⊆ static
        ordering.  Raises `PlanNestingError` listing every violation.
        """
        bad: List[str] = []
        for tight, loose in zip(columns, columns[1:]):
            a, b = self.columns[self._col(tight)], self.columns[self._col(loose)]
            for n in a:
                if n not in b:
                    continue
                if not b[n].range.encloses(a[n].range):
                    bad.append(f"{n}: {tight} {a[n].range} ⊄ "
                               f"{loose} {b[n].range}")
                elif strict_alpha and a[n].alpha > b[n].alpha:
                    bad.append(f"{n}: alpha({tight})={a[n].alpha} > "
                               f"alpha({loose})={b[n].alpha}")
        if bad:
            raise PlanNestingError(
                f"plan {self.pipeline!r} nesting {' ⊆ '.join(columns)} "
                f"violated:\n  " + "\n  ".join(bad))
        return True

    # -- serialization -------------------------------------------------------
    def to_json_dict(self) -> Dict:
        return {
            "version": 1,
            "pipeline": self.pipeline,
            "content_hash": self.content_hash,
            "default_column": self.default_column,
            "columns": {c: {n: _sr_to_json(r) for n, r in col.items()}
                        for c, col in self.columns.items()},
            "provenance": {c: {"pass": p.pass_name, "spec": p.spec,
                               "notes": list(p.notes)}
                           for c, p in self.provenance.items()},
            "phases": {c: {stage: {
                "lattice": list(lat),
                "ranges": {f"{ry},{rx}": _sr_to_json(sr)
                           for (ry, rx), sr in rmap.items()}}
                for stage, (lat, rmap) in pc.items()}
                for c, pc in self.phases.items()},
            "betas": dict(self.betas),
        }

    def to_json(self) -> str:
        """Stable text form: sorted keys, fixed indent — diffable in CI."""
        return json.dumps(self.to_json_dict(), sort_keys=True, indent=1)

    @classmethod
    def from_json_dict(cls, d: Dict) -> "BitwidthPlan":
        plan = cls(pipeline=d["pipeline"],
                   content_hash=d.get("content_hash", ""),
                   default_column=d.get("default_column", ""))
        for c, col in d.get("columns", {}).items():
            plan.columns[c] = {n: _sr_from_json(v) for n, v in col.items()}
        for c, p in d.get("provenance", {}).items():
            plan.provenance[c] = Provenance(pass_name=p["pass"],
                                            spec=p["spec"],
                                            notes=list(p.get("notes", [])))
        for c, pc in d.get("phases", {}).items():
            plan.phases[c] = {}
            for stage, entry in pc.items():
                lat = tuple(entry["lattice"])
                rmap = {}
                for key, v in entry["ranges"].items():
                    ry, rx = key.split(",")
                    rmap[(int(ry), int(rx))] = _sr_from_json(v)
                plan.phases[c][stage] = (lat, rmap)
        plan.betas = {n: int(b) for n, b in d.get("betas", {}).items()}
        if not plan.default_column and plan.columns:
            plan.default_column = next(iter(plan.columns))
        return plan

    @classmethod
    def from_json(cls, text: str) -> "BitwidthPlan":
        return cls.from_json_dict(json.loads(text))
