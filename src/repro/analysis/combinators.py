"""Pass combinators: `meet`, `refine`, `widen_to`.

Combinators are themselves `AnalysisPass`es, so they nest arbitrarily and
memoize like any other pass; their sub-passes run through `ctx.run`, so a
sub-pass shared between two combinators (or requested standalone in the
same plan) executes exactly once per pipeline.

  * ``meet(a, b, ...)`` — sound ∧ sound composition: the per-stage range
    intersection of sound over-approximations is itself sound and at least
    as tight as every operand (the classic reduced product, generalized
    from `core.intersect` to whole passes).
  * ``refine(static, empirical)`` — profile-clamped re-analysis: re-run the
    static pass with the pipeline's *input* ranges clamped to what the
    empirical pass observed.  Sound w.r.t. the profiled input distribution
    only (recorded in the column's provenance notes).
  * ``widen_to(sub, budget)`` — widen every range outward to its exact
    alpha bit boundary, making plans insensitive to sub-bit range jitter
    (stable diffs, stable memo hits downstream).  Widening never changes
    an alpha; stages whose alpha exceeds `budget` are reported in notes —
    soundness always wins over the budget request.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.intersect import _meet as _meet_iv
from repro.core.interval import Interval
from repro.core.range_analysis import StageRange

from repro.analysis.passes import (AnalysisPass, PassContext, PassResult,
                                   make_pass)


class MeetPass:
    name = "meet"

    def __init__(self, *passes, column: Optional[str] = None):
        self.passes: List[AnalysisPass] = [make_pass(p) for p in passes]
        if len(self.passes) < 2:
            raise ValueError("meet() needs at least two passes")
        self.column = column or \
            f"meet({','.join(p.column for p in self.passes)})"

    def key(self) -> str:
        return "meet(" + ";".join(p.key() for p in self.passes) + ")"

    def run(self, ctx: PassContext) -> PassResult:
        results = [ctx.run(p) for p in self.passes]
        ranges: Dict[str, Interval] = dict(results[0].ranges)
        for r in results[1:]:
            for n, iv in r.ranges.items():
                ranges[n] = _meet_iv(ranges[n], iv) if n in ranges else iv
        # phase sub-columns survive the meet: first operand carrying them
        # wins per stage, each phase range met with the stage's met union
        # bound (both sound for that phase, so the meet is too)
        phases = {}
        for r in results:
            for stage, (lat, rmap) in (r.phases or {}).items():
                if stage in phases:
                    continue
                phases[stage] = (lat, {res: _meet_iv(iv, ranges[stage])
                                       for res, iv in rmap.items()})
        return PassResult(ranges=ranges, phases=phases or None)


class RefinePass:
    name = "refine"

    def __init__(self, static, empirical, column: Optional[str] = None):
        self.static = make_pass(static)
        self.empirical = make_pass(empirical)
        self.column = column or \
            f"refine({self.static.column},{self.empirical.column})"

    def key(self) -> str:
        return f"refine({self.static.key()};{self.empirical.key()})"

    def run(self, ctx: PassContext) -> PassResult:
        emp = ctx.run(self.empirical)
        clamped: Dict[str, Interval] = dict(ctx.input_ranges or {})
        for n in ctx.pipeline.input_stages():
            if n not in emp.ranges:
                continue
            declared = clamped.get(n, ctx.pipeline.stages[n].input_range)
            obs = emp.ranges[n]
            clamped[n] = _meet_iv(declared, obs) if declared is not None else obs
        res = ctx.with_input_ranges(clamped).run(self.static)
        return PassResult(
            ranges=dict(res.ranges), alphas=res.alphas, phases=res.phases,
            notes=list(res.notes) + [
                "input ranges clamped to profiled observations; sound only "
                "w.r.t. the profiled input distribution"])


def _bit_boundary(sr: StageRange) -> Interval:
    """Widest range with the same (alpha, signed) at integer granularity."""
    a = sr.alpha
    if a >= 64:                 # analysis blow-up sentinel: leave untouched
        return sr.range
    if sr.signed:
        return Interval(-(2.0 ** (a - 1)), 2.0 ** (a - 1) - 1.0)
    return Interval(0.0, 2.0 ** a - 1.0)


class WidenPass:
    name = "widen_to"

    def __init__(self, sub, budget: int, column: Optional[str] = None):
        self.sub = make_pass(sub)
        self.budget = int(budget)
        self.column = column or f"widen({self.sub.column},{self.budget})"

    def key(self) -> str:
        return f"widen({self.sub.key()};budget={self.budget})"

    def run(self, ctx: PassContext) -> PassResult:
        res = ctx.run(self.sub)
        srs = res.stage_ranges()
        over = [n for n, sr in srs.items() if sr.alpha > self.budget]
        notes = list(res.notes)
        if over:
            notes.append(f"alpha budget {self.budget} exceeded on: "
                         f"{', '.join(over)} (bounds kept sound)")
        widened = {n: _bit_boundary(sr).join(sr.range)
                   for n, sr in srs.items()}

        def widen_iv(iv: Interval) -> Interval:
            return _bit_boundary(StageRange.from_interval(iv)).join(iv)

        phases = None
        if res.phases:                 # phase sub-columns widen alongside
            phases = {stage: (lat, {r: widen_iv(iv)
                                    for r, iv in rmap.items()})
                      for stage, (lat, rmap) in res.phases.items()}
        return PassResult(ranges=widened, alphas=res.alphas, phases=phases,
                          notes=notes)


def meet(*passes, column: Optional[str] = None) -> MeetPass:
    return MeetPass(*passes, column=column)


def refine(static, empirical, column: Optional[str] = None) -> RefinePass:
    return RefinePass(static, empirical, column=column)


def widen_to(sub, budget: int, column: Optional[str] = None) -> WidenPass:
    return WidenPass(sub, budget, column=column)
