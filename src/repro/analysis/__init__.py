"""`repro.analysis` — the composable analysis-pass architecture (paper §V).

One pass pipeline, one artifact: analyses (interval / affine / intersect /
smt / smt-phase-split / profile) are `AnalysisPass`es composed with
`meet` / `refine` / `widen_to` combinators; `run_plan` executes the
declared pass DAG once per pipeline with content-hash memoization and
emits a single `BitwidthPlan` — per-stage range columns with provenance,
optional per-phase sub-columns (one datapath per sampling-lattice
residue), beta assignments, and stable JSON serialization.

    from repro.analysis import run_plan, meet
    plan = run_plan(pipe, ["interval", "affine", meet("interval", "affine"),
                           "smt"])
    plan.check_nesting(["smt", "meet(interval,affine)"])
    types = plan.types("smt")                  # -> dsl.exec.run_fixed

Legacy entry points (`core.range_analysis.analyze`,
`workflows.static_alphas` / `smt_alphas` / `alpha_columns`) are thin shims
over one-pass plans — see docs/analysis_api.md for the migration table.
"""
from repro.analysis.cluster import (ClusterPass, cluster,
                                    homogeneity_clusters, stage_rates)
from repro.analysis.combinators import (MeetPass, RefinePass, WidenPass,
                                        meet, refine, widen_to)
from repro.analysis.driver import (DISK_CACHE_STATS, MEMO_STATS, clear_memo,
                                   one_pass_ranges, pipeline_content_hash,
                                   run_plan)
from repro.analysis.passes import (AnalysisPass, DomainPass, PassResult,
                                   ProfilePass, SmtPass, make_pass,
                                   register_pass)
from repro.analysis.plan import (BitwidthPlan, PlanNestingError, Provenance)

__all__ = [
    "AnalysisPass", "BitwidthPlan", "ClusterPass", "DISK_CACHE_STATS",
    "DomainPass", "MeetPass", "MEMO_STATS",
    "PassResult", "PlanNestingError", "ProfilePass", "Provenance",
    "RefinePass", "SmtPass", "WidenPass", "clear_memo", "cluster",
    "homogeneity_clusters", "make_pass", "meet",
    "one_pass_ranges", "pipeline_content_hash", "refine", "register_pass",
    "run_plan", "stage_rates", "widen_to",
]
