"""`run_plan` — execute a declared pass DAG once per pipeline.

The driver turns a list of passes (names or instances, combinators
included) into one `BitwidthPlan`.  Every pass execution is memoized on

    (pipeline content hash, input-range key, pass content key)

so re-running a plan, sharing a sub-pass between combinators, or the SMT
pass re-seeding itself through `analyze(pipe, "interval")` all hit the
cache instead of re-analyzing.  The memo is process-global (plans are also
serializable for cross-process caching — see `BitwidthPlan.to_json`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Dict, List, Optional, Sequence

from repro import obs
from repro.core.graph import Pipeline
from repro.core.interval import Interval

from repro.analysis.plan import BitwidthPlan, Provenance
from repro.analysis.passes import AnalysisPass, PassResult, make_pass

_MEMO: Dict[tuple, PassResult] = {}
# Registered obs counter groups (locked `.add()`, explicit `.reset()`) that
# remain byte-compatible dicts for every legacy reader.
MEMO_STATS = obs.CounterGroup("analysis.memo", hits=0, misses=0)
# disk-backed plan cache (`run_plan(cache_dir=...)`)
DISK_CACHE_STATS = obs.CounterGroup("analysis.disk_cache",
                                    hits=0, misses=0, writes=0, skips=0)


def clear_memo() -> None:
    _MEMO.clear()
    MEMO_STATS.reset()
    DISK_CACHE_STATS.reset()


def pipeline_content_hash(pipeline: Pipeline) -> str:
    """Stable content hash over stages, params, and outputs.

    Expression trees are frozen dataclasses with deterministic reprs, so
    the hash changes iff the pipeline's analyzed content changes (a mutated
    `Pipeline` object re-hashes — the memo never serves stale results).
    """
    h = hashlib.sha256()
    for name in sorted(pipeline.stages):
        st = pipeline.stages[name]
        h.update(repr((st.name, st.inputs, st.stride, st.upsample,
                       st.is_input, st.input_range, st.expr)).encode())
    h.update(repr(sorted(pipeline.params.items(),
                         key=lambda kv: kv[0])).encode())
    h.update(repr(list(pipeline.outputs)).encode())
    return h.hexdigest()[:16]


def _input_ranges_key(input_ranges: Optional[Dict[str, Interval]]) -> str:
    if not input_ranges:
        return ""
    return ";".join(f"{n}:[{iv.lo!r},{iv.hi!r}]"
                    for n, iv in sorted(input_ranges.items()))


@dataclasses.dataclass
class _Context:
    pipeline: Pipeline
    input_ranges: Optional[Dict[str, Interval]]
    pipe_hash: str

    def run(self, p: AnalysisPass) -> PassResult:
        key = (self.pipe_hash, _input_ranges_key(self.input_ranges), p.key())
        with obs.span("analysis.pass", **{"pass": p.name},
                      column=p.column, key=p.key()) as sp:
            hit = _MEMO.get(key)
            if hit is not None:
                MEMO_STATS.add("hits")
                sp.set(memo="hit")
                return hit
            MEMO_STATS.add("misses")
            sp.set(memo="miss")
            res = p.run(self)
            sp.set(notes=len(res.notes))
            _MEMO[key] = res
            return res

    def with_input_ranges(self, ir: Dict[str, Interval]) -> "_Context":
        return dataclasses.replace(self, input_ranges=ir)


def _disk_cache_key(pipe_hash: str, resolved: Sequence[AnalysisPass],
                    input_ranges, betas, default_column) -> Optional[str]:
    """Stable cross-process cache key, or None when a pass key is only
    process-local (custom profile runners get a per-process `runner#N`
    identity — caching those on disk would collide across processes)."""
    keys = [p.key() for p in resolved]
    if any(":runner#" in k for k in keys):
        return None
    h = hashlib.sha256()
    h.update(pipe_hash.encode())
    h.update(_input_ranges_key(input_ranges).encode())
    for k in keys:
        h.update(b"|")
        h.update(k.encode())
    h.update(repr(sorted((betas or {}).items())).encode())
    h.update((default_column or "").encode())
    return h.hexdigest()[:20]


def run_plan(pipeline: Pipeline, passes: Sequence,
             input_ranges: Optional[Dict[str, Interval]] = None,
             betas: Optional[Dict[str, int]] = None,
             default_column: Optional[str] = None,
             cache_dir: Optional[str] = None) -> BitwidthPlan:
    """Execute the declared pass DAG and collect columns into one plan.

    `passes` entries are registry names (``"interval"``, ``"smt"``, ...) or
    `AnalysisPass` instances (combinators included).  Columns land in the
    plan under each pass's `column` name, with provenance carrying the
    pass's memoization key and notes.

    `cache_dir` opts into the disk-backed plan cache: plans serialize
    stably (`BitwidthPlan.to_json`), so CI and benchmark runs reuse
    cross-run analysis results keyed on `pipeline_content_hash` + every
    pass's content key (+ input ranges, betas, default column).  Passes
    with process-local identities (custom profile runners) skip the disk
    cache with a `RuntimeWarning`; the in-process memo still applies.
    """
    resolved: List[AnalysisPass] = [make_pass(p) for p in passes]
    pipe_hash = pipeline_content_hash(pipeline)
    with obs.span("analysis.run_plan", pipeline=pipeline.name,
                  hash=pipe_hash, n_passes=len(resolved)) as sp:
        cache_path = None
        if cache_dir is not None:
            key = _disk_cache_key(pipe_hash, resolved, input_ranges, betas,
                                  default_column)
            if key is None:
                DISK_CACHE_STATS.add("skips")
                sp.set(disk_cache="skip")
                obs.warn_once(
                    "plan disk cache skipped: a pass key is process-local "
                    "(custom profile runner); pass key_suffix= for a stable "
                    "identity")
            else:
                cache_path = os.path.join(
                    cache_dir, f"{pipeline.name}-{pipe_hash}-{key}.plan.json")
                if os.path.exists(cache_path):
                    try:
                        with open(cache_path) as f:
                            plan = BitwidthPlan.from_json(f.read())
                        if plan.content_hash == pipe_hash:
                            DISK_CACHE_STATS.add("hits")
                            sp.set(disk_cache="hit")
                            return plan
                    except (OSError, ValueError, KeyError):
                        pass      # corrupt entry: fall through and rewrite
                DISK_CACHE_STATS.add("misses")
                sp.set(disk_cache="miss")
        ctx = _Context(pipeline=pipeline, input_ranges=input_ranges,
                       pipe_hash=pipe_hash)
        plan = BitwidthPlan(pipeline=pipeline.name,
                            content_hash=ctx.pipe_hash,
                            betas=dict(betas or {}))
        for p in resolved:
            res = ctx.run(p)
            plan.add_column(p.column, res.stage_ranges(),
                            Provenance(pass_name=p.name, spec=p.key(),
                                       notes=list(res.notes)),
                            phases=res.phase_stage_ranges())
        if default_column:
            plan.default_column = default_column
        if cache_path is not None:
            os.makedirs(cache_dir, exist_ok=True)
            tmp = cache_path + ".tmp"
            with open(tmp, "w") as f:
                f.write(plan.to_json())
            os.replace(tmp, cache_path)
            DISK_CACHE_STATS.add("writes")
        return plan


def one_pass_ranges(pipeline: Pipeline, domain, input_ranges=None):
    """Shim backend for `core.range_analysis.analyze`: a one-pass plan.

    String domains map onto registry passes (so results are memoized and
    plan-consistent); `Domain` instances fall through to the direct walk —
    they have no stable content key to memoize on.
    """
    from repro.core.range_analysis import analyze_direct
    if not isinstance(domain, str):
        return analyze_direct(pipeline, domain, input_ranges=input_ranges)
    try:
        p = make_pass(domain)
    except KeyError:
        # unknown to the pass registry: let the domain registry resolve it
        # (custom user domains registered via absval.register_domain)
        return analyze_direct(pipeline, domain, input_ranges=input_ranges)
    plan = run_plan(pipeline, [p], input_ranges=input_ranges)
    return plan.stage_ranges(p.column)
