"""Pluggable abstract-value framework — paper §IV-C.

The paper's generated HLS C++ is polymorphic in a single type parameter
`typ`; switching it between `float`, `ap_fixed`, an interval type, or
YalAA's affine type re-purposes the same program as a simulator or an
analyzer.  Here the same role is played by a *domain adapter*: the
expression evaluator is written once against this protocol, and any
analysis (interval, affine, or future domains) plugs in via the registry.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Protocol

from repro.core.affine import AffineForm
from repro.core.interval import Interval


class Domain(Protocol):
    """What an abstract domain must provide to the shared evaluator."""

    name: str

    def const(self, v: float) -> Any: ...
    def fresh_signal(self, rng: Interval) -> Any:
        """Abstract value for one homogeneous signal with known range.

        Called once per Ref *occurrence* during combined per-stage analysis:
        interval returns the range itself; affine mints a fresh noise symbol
        (stencil taps read distinct pixels, hence independent signals).
        """
        ...
    def to_interval(self, v: Any) -> Interval: ...


class IntervalDomain:
    name = "interval"

    def const(self, v: float) -> Interval:
        return Interval.point(v)

    def fresh_signal(self, rng: Interval) -> Interval:
        return rng

    def to_interval(self, v: Interval) -> Interval:
        return v


class AffineDomain:
    name = "affine"

    def const(self, v: float) -> AffineForm:
        return AffineForm.point(v)

    def fresh_signal(self, rng: Interval) -> AffineForm:
        return AffineForm.from_interval(rng.lo, rng.hi)

    def to_interval(self, v: AffineForm) -> Interval:
        return v.to_interval()


_REGISTRY: Dict[str, Callable[[], Domain]] = {
    "interval": IntervalDomain,
    "affine": AffineDomain,
}

# Domains living in modules that register themselves on import; resolved on
# first use so core stays import-light and cycle-free (repro.smt imports
# range_analysis, which imports this module).
_LAZY_MODULES: Dict[str, str] = {
    "intersect": "repro.core.intersect",
    "smt": "repro.smt",
    "smt-scalar": "repro.smt",       # reference-oracle solver engine
}


def register_domain(name: str, factory: Callable[[], Domain]) -> None:
    _REGISTRY[name] = factory


def get_domain(name: str) -> Domain:
    if name not in _REGISTRY and name in _LAZY_MODULES:
        import importlib
        importlib.import_module(_LAZY_MODULES[name])
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown analysis domain {name!r}; registered: "
            f"{sorted(set(_REGISTRY) | set(_LAZY_MODULES))}") from None
