"""Stage-DAG IR — the PolyMage analogue (paper §III-A).

An image-processing pipeline is a DAG of *stages*; each stage computes one
output pixel at (i, j) from pixels of its input stages via an expression
tree.  The expression tree is exactly what Algorithm 1 walks (`e->left`,
`e->right`, `e->operator`), and what the executors evaluate on arrays.

Stencils are represented *expanded* into expression form (paper §IV-B: "The
stencil operation here can be expanded in the form of an expression"), with
`Ref` leaves carrying the (dy, dx) tap offset.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.interval import Interval


# ---------------------------------------------------------------------------
# Expression IR
# ---------------------------------------------------------------------------

class Expr:
    """Base expression node. Operator overloads build trees."""

    def _wrap(self, other) -> "Expr":
        if isinstance(other, Expr):
            return other
        return Const(float(other))

    def __add__(self, o): return BinOp("+", self, self._wrap(o))
    def __radd__(self, o): return BinOp("+", self._wrap(o), self)
    def __sub__(self, o): return BinOp("-", self, self._wrap(o))
    def __rsub__(self, o): return BinOp("-", self._wrap(o), self)
    def __mul__(self, o): return BinOp("*", self, self._wrap(o))
    def __rmul__(self, o): return BinOp("*", self._wrap(o), self)
    def __truediv__(self, o): return BinOp("/", self, self._wrap(o))
    def __rtruediv__(self, o): return BinOp("/", self._wrap(o), self)
    def __pow__(self, n: int): return Pow(self, int(n))
    def __neg__(self): return BinOp("*", Const(-1.0), self)

    # comparisons build Cmp nodes (for Select conditions)
    def __lt__(self, o): return Cmp("<", self, self._wrap(o))
    def __le__(self, o): return Cmp("<=", self, self._wrap(o))
    def __gt__(self, o): return Cmp(">", self, self._wrap(o))
    def __ge__(self, o): return Cmp(">=", self, self._wrap(o))


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    value: float


@dataclasses.dataclass(frozen=True)
class Ref(Expr):
    """Pixel (i+dy, j+dx) of input stage `stage`."""
    stage: str
    dy: int = 0
    dx: int = 0


@dataclasses.dataclass(frozen=True)
class ParamRef(Expr):
    """Runtime scalar parameter with a declared range (e.g. USM `weight`)."""
    name: str


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str  # + - * /
    left: Expr
    right: Expr


@dataclasses.dataclass(frozen=True)
class Pow(Expr):
    base: Expr
    n: int


@dataclasses.dataclass(frozen=True)
class Call(Expr):
    fn: str  # abs | sqrt | min | max
    args: Tuple[Expr, ...]


@dataclasses.dataclass(frozen=True)
class Cmp(Expr):
    op: str  # < <= > >=
    left: Expr
    right: Expr


@dataclasses.dataclass(frozen=True)
class Select(Expr):
    cond: Cmp
    then: Expr
    other: Expr


def expr_refs(e: Expr) -> List[Ref]:
    """All Ref leaves of an expression tree, in traversal order."""
    out: List[Ref] = []

    def go(n: Expr):
        if isinstance(n, Ref):
            out.append(n)
        elif isinstance(n, BinOp):
            go(n.left); go(n.right)
        elif isinstance(n, Pow):
            go(n.base)
        elif isinstance(n, Call):
            for a in n.args:
                go(a)
        elif isinstance(n, Cmp):
            go(n.left); go(n.right)
        elif isinstance(n, Select):
            go(n.cond); go(n.then); go(n.other)

    go(e)
    return out


def expr_ops(e: Expr) -> Dict[str, int]:
    """Operation census of an expression tree (for the cost model)."""
    counts: Dict[str, int] = {}

    def bump(k: str):
        counts[k] = counts.get(k, 0) + 1

    def go(n: Expr):
        if isinstance(n, BinOp):
            # constant-folded multiplies by +-1 are wires, not ops
            if not (n.op == "*" and isinstance(n.left, Const) and abs(n.left.value) == 1.0):
                bump(n.op)
            go(n.left); go(n.right)
        elif isinstance(n, Pow):
            bump("*")  # squaring ~ one multiplier; higher powers log-many
            go(n.base)
        elif isinstance(n, Call):
            bump(n.fn)
            for a in n.args:
                go(a)
        elif isinstance(n, Cmp):
            bump("cmp")
            go(n.left); go(n.right)
        elif isinstance(n, Select):
            bump("sel")
            go(n.cond); go(n.then); go(n.other)

    go(e)
    return counts


# ---------------------------------------------------------------------------
# Stages and pipelines
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Stage:
    name: str
    expr: Optional[Expr]                      # None for input stages
    inputs: Tuple[str, ...] = ()
    # sampling: output(i,j) = expr evaluated on input grid at (i*sy, j*sx)
    stride: Tuple[int, int] = (1, 1)          # >1 = downsample
    upsample: Tuple[int, int] = (1, 1)        # >1 = nearest-expand before expr
    is_input: bool = False
    input_range: Optional[Interval] = None    # for input stages (e.g. [0,255])

    def refs(self) -> List[Ref]:
        return expr_refs(self.expr) if self.expr is not None else []

    def halo_yx(self) -> Tuple[int, int]:
        """Per-axis stencil halo (hy, hx) this stage reads.

        A 1-D separable stencil has a zero halo on its orthogonal axis: a
        horizontal 5-tap blur needs no line buffer at all (hy = 0) and a
        vertical one pads no columns (hx = 0).  Executors and the cost
        model must use the per-axis values — the old isotropic
        ``max(|dy|, |dx|)`` over-padded (and over-priced line buffers on)
        every separable stage.
        """
        rs = self.refs()
        if not rs:
            return (0, 0)
        return (max(abs(r.dy) for r in rs), max(abs(r.dx) for r in rs))

    def halo(self) -> int:
        """Isotropic halo — max over both axes of `halo_yx` (legacy)."""
        hy, hx = self.halo_yx()
        return max(hy, hx)


class Pipeline:
    """A DAG of stages with named scalar parameters."""

    def __init__(self, name: str):
        self.name = name
        self.stages: Dict[str, Stage] = {}
        self.params: Dict[str, Interval] = {}   # declared parameter ranges
        self.outputs: List[str] = []

    # -- construction -----------------------------------------------------
    def add_stage(self, stage: Stage) -> Stage:
        if stage.name in self.stages:
            raise ValueError(f"duplicate stage {stage.name!r}")
        for inp in stage.inputs:
            if inp not in self.stages:
                raise ValueError(f"stage {stage.name!r} reads undefined {inp!r}")
        self.stages[stage.name] = stage
        return stage

    def add_param(self, name: str, lo: float, hi: float):
        self.params[name] = Interval(float(lo), float(hi))

    def mark_output(self, name: str):
        if name not in self.stages:
            raise ValueError(name)
        if name not in self.outputs:
            self.outputs.append(name)

    # -- queries ------------------------------------------------------------
    def topo_order(self) -> List[str]:
        order: List[str] = []
        seen: Dict[str, int] = {}  # 0=visiting, 1=done

        def visit(n: str):
            st = seen.get(n)
            if st == 1:
                return
            if st == 0:
                raise ValueError(f"cycle through {n!r}")
            seen[n] = 0
            for inp in self.stages[n].inputs:
                visit(inp)
            seen[n] = 1
            order.append(n)

        for n in self.stages:
            visit(n)
        return order

    def input_stages(self) -> List[str]:
        return [n for n, s in self.stages.items() if s.is_input]

    def consumers(self, name: str) -> List[str]:
        return [n for n, s in self.stages.items() if name in s.inputs]

    def __repr__(self) -> str:
        return f"Pipeline({self.name!r}, {len(self.stages)} stages)"


def stencil_expr(input_name: str, weights: Sequence[Sequence[float]],
                 scale: float = 1.0, center: Optional[Tuple[int, int]] = None) -> Expr:
    """Expand a 2-D stencil into expression form (paper §IV-B).

    `weights[r][c]` taps pixel (i + r - cy, j + c - cx).  Zero taps are
    skipped.  The whole sum is multiplied by `scale` (e.g. 1/16 for the
    binomial blur in Listing 1).
    """
    rows = len(weights)
    cols = max(len(r) for r in weights)
    if center is None:
        center = (rows // 2, cols // 2)
    cy, cx = center
    acc: Optional[Expr] = None
    for r, row in enumerate(weights):
        for c, w in enumerate(row):
            if w == 0:
                continue
            tap: Expr = Ref(input_name, dy=r - cy, dx=c - cx)
            if w != 1:
                tap = BinOp("*", Const(float(w)), tap)
            acc = tap if acc is None else BinOp("+", acc, tap)
    if acc is None:
        acc = Const(0.0)
    if scale != 1.0:
        acc = BinOp("*", Const(float(scale)), acc)
    return acc
