"""FPGA power/area cost model + TPU byte model.

We cannot run Vivado P&R here (the paper's Tables III/VI/VII/X are post-P&R
measurements on a Zynq XC7Z020), so power/area are *modeled* from the same
quantity the paper's analysis controls: per-stage operator bit-widths.  The
model is deliberately simple and is used only for *relative* comparisons
(fixed vs float), which is how the paper reports its wins (3.8x power,
6.2x area on HCD).

Proxies (per output pixel):
  ripple add / sub / cmp / select of width w  ->  w     bit-ops,  w   LUT-bits
  multiplier  wa x wb                         ->  wa*wb/8 bit-ops, wa*wb/8 DSP-bits
  divider / sqrt of width w                   ->  w*w/4 bit-ops (iterative array)
  line buffer of a stage with halo h          ->  2h rows x W pixels x width bits (BRAM)

Float32 op costs use the classic FPGA soft-float factors: a float adder
(align + add + normalize) ~ 4x a 32-bit int adder; float multiply ~ a 24x24
mantissa multiplier (+ exponent adder).  These land the model's float/fixed
ratios in the same regime the paper measures; we report model numbers as
modeled, never as measured watts.

TPU side: bytes/pixel/stage after container legalization (`core.policy`),
the quantity that actually drives HBM energy on the real target.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.fixedpoint import FixedPointType
from repro.core.graph import (BinOp, Call, Cmp, Const, Expr, ParamRef,
                              Pipeline, Pow, Ref, Select)

FLOAT_ADD_FACTOR = 4.0          # soft-float adder vs int adder of same width
FLOAT_MANTISSA = 24             # f32 mantissa incl. hidden bit
F64_MANTISSA = 53               # f64 mantissa incl. hidden bit
CARRIER_BITS = {"int32": 32, "int32pair": 32, "int64": 64}


@dataclasses.dataclass
class StageCost:
    bit_ops: float          # dynamic-power proxy (switched bits per output pixel)
    lut_bits: float         # area proxy: adder/logic bits
    dsp_bits: float         # area proxy: multiplier array bits
    bram_bits: float        # line-buffer storage bits
    storage_bits: int       # stage output element width


def _w(t: Optional[FixedPointType]) -> int:
    return 32 if t is None else t.width


def _expr_cost(e: Expr, w_in: Dict[str, int], w_out: int, is_float: bool,
               params_width: int = 32,
               mantissa: int = FLOAT_MANTISSA) -> Tuple[float, float, float]:
    """(bit_ops, lut_bits, dsp_bits) for one evaluation of `e`.

    Width discipline: each op computes at the max of its operand widths
    (the HLS datapath the paper's generated code produces); the final result
    is stored at `w_out`.  `mantissa` sets the float significand width when
    `is_float` (24 for f32, 53 for an f64 lowered-expr datapath).
    Returns cost and implicitly the width via closure recursion.
    """
    bit_ops = lut = dsp = 0.0
    FLOAT_MANTISSA = mantissa       # shadows the module default below

    def go(n: Expr) -> int:           # returns value width of subtree
        nonlocal bit_ops, lut, dsp
        if isinstance(n, Const):
            return FLOAT_MANTISSA if is_float else max(int(abs(n.value)).bit_length(), 8)
        if isinstance(n, Ref):
            return w_in[n.stage]
        if isinstance(n, ParamRef):
            return params_width if is_float else 16
        if isinstance(n, BinOp):
            wl, wr = go(n.left), go(n.right)
            if n.op in "+-":
                w = max(wl, wr) + 1
                c = w * (FLOAT_ADD_FACTOR if is_float else 1.0)
                bit_ops += c; lut += c
                return min(w, 64)
            if n.op == "*":
                # constant multiplies fold to shift-adds: charge an adder
                if isinstance(n.left, Const) and abs(n.left.value) in (0.0, 1.0):
                    return wr
                wa, wb = (FLOAT_MANTISSA, FLOAT_MANTISSA) if is_float else (wl, wr)
                c = wa * wb / 8.0
                bit_ops += c; dsp += c
                return min(wl + wr, 64) if not is_float else 32
            if n.op == "/":
                w = max(wl, wr) if not is_float else FLOAT_MANTISSA
                c = w * w / 4.0
                bit_ops += c; lut += c
                return w
        if isinstance(n, Pow):
            wb = go(n.base)
            wa = FLOAT_MANTISSA if is_float else wb
            c = wa * wa / 8.0 * max(n.n - 1, 1)
            bit_ops += c; dsp += c
            return min(wb * n.n, 64) if not is_float else 32
        if isinstance(n, Call):
            ws = [go(a) for a in n.args]
            w = max(ws)
            if n.fn == "sqrt":
                c = w * w / 4.0
            else:  # abs/min/max ~ one compare-select
                c = w * (FLOAT_ADD_FACTOR if is_float else 1.0)
            bit_ops += c; lut += c
            return w
        if isinstance(n, Cmp):
            wl, wr = go(n.left), go(n.right)
            w = max(wl, wr)
            c = w * (FLOAT_ADD_FACTOR if is_float else 1.0)
            bit_ops += c; lut += c
            return 1
        if isinstance(n, Select):
            go(n.cond)
            wt, wo = go(n.then), go(n.other)
            w = max(wt, wo)
            bit_ops += w; lut += w
            return w
        raise TypeError(type(n))

    go(e)
    return bit_ops, lut, dsp


def phase_mean_width(phase_entry, union_width: float) -> float:
    """Duty-cycle-weighted datapath width of a phase-split stage.

    `phase_entry` is one `BitwidthPlan.phase_types` value —
    ``((My, Mx), residue -> FixedPointType)``.  A phase-split streaming
    design synthesizes one datapath per sampling-lattice residue (the
    paper §IV homogeneity clusters in silicon); each handles exactly
    1/(My*Mx) of the pixels, so both the switched bits (power) and the
    polyphase-folded structure (area) track the residue *mean* width, with
    residues missing from the map falling back to the union width.
    """
    (my, mx), tmap = phase_entry
    n_res = max(my * mx, 1)
    total = sum(_w(t) for t in tmap.values())
    total += union_width * (n_res - len(tmap))
    return total / n_res


def _intlinear_cost(dp: Dict, w_in_max: float, w_out: int,
                    ) -> Tuple[float, float, float]:
    """(bit_ops, lut_bits, dsp_bits) of a lowered integer MAC datapath.

    Priced from the election's structure instead of the HLS max-width
    walk: constant-weight multiplies are shift-add arrays (weight bits x
    operand bits), the accumulate chain runs at the *carrier* register
    width — 32 for int32 and each half of an int32pair, 64 for int64 —
    and an int32pair pays one widening 64-bit combine adder.  The finish
    is a round+shift at carrier width when dyadic, else one f64 multiply.
    """
    A = CARRIER_BITS[dp["carrier"]]
    dsp = dp.get("wbits", 8 * dp["taps"]) * w_in_max / 8.0
    adders = dp["taps"] * A
    if dp["carrier"] == "int32pair":
        adders += 64                           # the widening combine
    if dp.get("dyadic", True):
        finish_ops, finish_dsp = float(A), 0.0  # round add + shift
    else:
        finish_ops, finish_dsp = 0.0, F64_MANTISSA * F64_MANTISSA / 8.0
    # (the output register + saturate clamp are charged by stage_cost's
    # common tail, like every other datapath)
    bit_ops = dsp + adders + finish_ops + finish_dsp
    return bit_ops, adders + finish_ops, dsp + finish_dsp


def stage_cost(pipeline: Pipeline, name: str,
               types: Dict[str, Optional[FixedPointType]],
               image_width: int = 1920,
               eff_widths: Optional[Dict[str, float]] = None,
               datapath: Optional[Dict] = None) -> StageCost:
    """Cost of one stage's datapath.

    `eff_widths` (optional) overrides the *operand* width of named
    producer stages — the hook `design_cost` uses to price per-phase
    datapaths: a phase-split producer feeds this stage's operators (and
    its line buffers) at the residue-mean width instead of the union
    width (`phase_mean_width`).

    `datapath` (optional) is one `lowered_datapaths` entry: the stage's
    operators are then priced from the lowering's actual election — the
    integer MAC at its carrier width (`_intlinear_cost`), or the expr
    tree as float at the elected mantissa (24 for f32, 53 for f64) —
    instead of the HLS max-width model.  Storage and line buffers still
    follow `types` (the stored representation is unchanged by election).
    """
    st = pipeline.stages[name]
    w_out = _w(types.get(name))
    if st.is_input or st.expr is None:
        return StageCost(0.0, 0.0, 0.0, 0.0, w_out)
    is_float = types.get(name) is None
    eff = eff_widths or {}
    w_in = {i: eff.get(i, _w(types.get(i))) for i in st.inputs}
    if datapath is not None and datapath.get("kind") == "intlinear":
        bit_ops, lut, dsp = _intlinear_cost(
            datapath, max(w_in.values(), default=8.0), w_out)
    elif datapath is not None and datapath.get("kind") == "expr":
        mant = FLOAT_MANTISSA if datapath.get("dtype") == "f32" \
            else F64_MANTISSA
        bit_ops, lut, dsp = _expr_cost(st.expr, w_in, w_out, True,
                                       mantissa=mant)
    else:
        bit_ops, lut, dsp = _expr_cost(st.expr, w_in, w_out, is_float)
    # output stage: every stream stage ends in a register (switches w_out
    # bits per pixel) and, in fixed point, a quantize/saturate clamp
    # (compare-select of width w_out).  Priced at the residue-mean width
    # for phase-split stages — this is where one-datapath-per-residue
    # narrows the silicon even on pipeline outputs.
    w_store = eff.get(name, w_out)
    bit_ops += w_store
    if not is_float:
        lut += w_store
    hy, _hx = st.halo_yx()
    # line buffers: 2*hy full image rows per input, at the input's width —
    # per-axis: a horizontal-only stencil (hy = 0) streams with no BRAM
    bram = sum(2 * hy * image_width * w_in[i] for i in st.inputs) if hy else 0.0
    return StageCost(bit_ops=bit_ops, lut_bits=lut, dsp_bits=dsp,
                     bram_bits=float(bram), storage_bits=w_out)


@dataclasses.dataclass
class DesignCost:
    power_proxy: float       # sum of per-pixel switched bit-ops (dynamic power ~)
    lut_bits: float
    dsp_bits: float
    bram_bits: float
    bytes_per_pixel_tpu: float   # after container legalization

    def ratios_vs(self, other: "DesignCost") -> Dict[str, float]:
        def r(a, b):
            return b / a if a > 0 else float("inf")
        return {
            "power": r(self.power_proxy, other.power_proxy),
            "area_lut": r(self.lut_bits, other.lut_bits),
            "area_dsp": r(self.dsp_bits, other.dsp_bits),
            "bram": r(self.bram_bits, other.bram_bits),
            "tpu_bytes": r(self.bytes_per_pixel_tpu, other.bytes_per_pixel_tpu),
        }


def lowered_datapaths(lp) -> Dict[str, Dict]:
    """Datapath descriptors for `design_cost(..., datapaths=...)`.

    `lp` is a `repro.lowering.LoweredPipeline`; each non-input stage maps
    to the structure its election actually synthesizes — the quantity the
    narrow re-election (`lower(..., datapath="narrow")`) changes and the
    type-map-only model cannot see:

      intlinear: {"kind", "carrier", "taps", "wbits", "dyadic"}
      expr:      {"kind", "dtype"}           # "f64" | "f32"
    """
    out: Dict[str, Dict] = {}
    for n, ls in lp.stages.items():
        if ls.stage.is_input:
            continue
        if ls.kind == "intlinear":
            out[n] = {"kind": "intlinear", "carrier": ls.carrier,
                      "taps": len(ls.int_taps),
                      "wbits": sum(max(abs(tp.W).bit_length(), 1)
                                   for tp in ls.int_taps),
                      "dyadic": ls.dyadic}
        elif ls.kind == "expr":
            out[n] = {"kind": "expr", "dtype": ls.expr_dtype}
    return out


def design_cost(pipeline: Pipeline,
                types: Dict[str, Optional[FixedPointType]],
                image_width: int = 1920,
                phase_types: Optional[Dict] = None,
                datapaths: Optional[Dict[str, Dict]] = None) -> DesignCost:
    """Whole-design cost.  `phase_types` (the `BitwidthPlan.phase_types`
    shape, ``stage -> ((My, Mx), residue -> type)``) prices per-phase
    datapaths: a phase-split stage feeds its consumers (operators and line
    buffers) at the residue-mean width, and its storage traffic is the
    residue mean of the per-residue container bytes — the quantity the
    union-width model erases (closing the ROADMAP per-phase cost item).

    `datapaths` (a `lowered_datapaths` map) prices each stage's operators
    from the lowering's carrier/dtype election instead of the HLS
    max-width walk, so exact vs narrow lowerings of the same type map get
    different costs.  Omitted -> byte-identical to the historical model.
    """
    from repro.core.policy import container_bytes
    phase_types = phase_types or {}
    datapaths = datapaths or {}
    eff: Dict[str, float] = {
        n: phase_mean_width(entry, _w(types.get(n)))
        for n, entry in phase_types.items() if types.get(n) is not None}
    power = lut = dsp = bram = tbytes = 0.0
    for name in pipeline.topo_order():
        c = stage_cost(pipeline, name, types, image_width, eff_widths=eff,
                       datapath=datapaths.get(name))
        power += c.bit_ops
        lut += c.lut_bits
        dsp += c.dsp_bits
        bram += c.bram_bits
        entry = phase_types.get(name)
        if entry is not None and types.get(name) is not None:
            (my, mx), tmap = entry
            n_res = max(my * mx, 1)
            b = sum(container_bytes(t) for t in tmap.values())
            b += container_bytes(types.get(name)) * (n_res - len(tmap))
            tbytes += b / n_res
        else:
            tbytes += container_bytes(types.get(name))
    return DesignCost(power_proxy=power, lut_bits=lut, dsp_bits=dsp,
                      bram_bits=bram, bytes_per_pixel_tpu=tbytes)


def float_design(pipeline: Pipeline) -> Dict[str, Optional[FixedPointType]]:
    """The float32 reference design: every stage typed None."""
    return {n: None for n in pipeline.stages}
