"""Affine-arithmetic abstract domain — paper §III-C (Stolfi & Figueiredo style).

A signal is represented as  x = x0 + sum_i xi * eps_i,  eps_i in [-1, 1].
Correlations between signals are captured by *shared* noise symbols, so
x - x == 0 exactly (where interval arithmetic over-approximates to [-w, w]).

Non-affine ops (mul, div, powers) introduce one fresh noise symbol carrying
the linearization error, per the standard Chebyshev/trivial-range
approximations.  This is the drop-in second domain for the paper's pluggable
framework (§IV-C, the YalAA `typ` switch) — see `repro.core.absval`.
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, Union

from repro.core.interval import Interval

Number = Union[int, float]


def _is_ndarray(x) -> bool:
    return type(x).__module__ == "numpy" and type(x).__name__ == "ndarray"


_fresh_counter = itertools.count()


def _fresh() -> int:
    return next(_fresh_counter)


class AffineForm:
    """x0 + sum_i xi*eps_i with eps_i in [-1,1]."""

    __slots__ = ("x0", "terms")

    def __init__(self, x0: float, terms: Dict[int, float] | None = None):
        self.x0 = float(x0)
        self.terms = dict(terms or {})

    # -- constructors ---------------------------------------------------------
    @staticmethod
    def from_interval(lo: float, hi: float) -> "AffineForm":
        if math.isinf(lo) or math.isinf(hi):
            # top element: unbounded radius around 0
            return AffineForm(0.0, {_fresh(): math.inf})
        mid = 0.5 * (lo + hi)
        rad = 0.5 * (hi - lo)
        if rad == 0.0:
            return AffineForm(mid)
        return AffineForm(mid, {_fresh(): rad})

    @staticmethod
    def point(v: Number) -> "AffineForm":
        return AffineForm(float(v))

    @staticmethod
    def of(v) -> "AffineForm":
        if isinstance(v, AffineForm):
            return v
        return AffineForm.point(v)

    # -- range extraction -------------------------------------------------------
    @property
    def radius(self) -> float:
        return sum(abs(c) for c in self.terms.values())

    def to_interval(self) -> Interval:
        r = self.radius
        return Interval(self.x0 - r, self.x0 + r)

    # -- affine ops (exact) -------------------------------------------------------
    # ndarray operands -> NotImplemented so numpy object arrays dispatch
    # elementwise (per-pixel §IV-C executor).
    def __add__(self, other) -> "AffineForm":
        if _is_ndarray(other):
            return NotImplemented
        o = AffineForm.of(other)
        terms = dict(self.terms)
        for k, v in o.terms.items():
            terms[k] = terms.get(k, 0.0) + v
        return AffineForm(self.x0 + o.x0, {k: v for k, v in terms.items() if v != 0.0})

    __radd__ = __add__

    def __neg__(self) -> "AffineForm":
        return AffineForm(-self.x0, {k: -v for k, v in self.terms.items()})

    def __sub__(self, other) -> "AffineForm":
        if _is_ndarray(other):
            return NotImplemented
        return self + (-AffineForm.of(other))

    def __rsub__(self, other) -> "AffineForm":
        if _is_ndarray(other):
            return NotImplemented
        return AffineForm.of(other) + (-self)

    def scale(self, c: float) -> "AffineForm":
        return AffineForm(self.x0 * c, {k: v * c for k, v in self.terms.items()})

    # -- non-affine ops (fresh noise symbol for the approximation error) ---------
    def __mul__(self, other) -> "AffineForm":
        if _is_ndarray(other):
            return NotImplemented
        o = AffineForm.of(other)
        if not o.terms:       # scalar
            return self.scale(o.x0)
        if not self.terms:
            return o.scale(self.x0)
        # (x0 + X)(y0 + Y) = x0*y0 + x0*Y + y0*X + X*Y ;  |X*Y| <= rad(X)*rad(Y)
        out = AffineForm(self.x0 * o.x0)
        out = out + o.scale(self.x0) + AffineForm(-self.x0 * o.x0)  # x0*y0 + x0*Y
        tmp = self.scale(o.x0)
        out = out + AffineForm(tmp.x0 - self.x0 * o.x0, tmp.terms)  # + y0*X
        err = self.radius * o.radius
        if err > 0.0:
            out.terms[_fresh()] = err
        return out

    __rmul__ = __mul__

    def __truediv__(self, other) -> "AffineForm":
        if _is_ndarray(other):
            return NotImplemented
        o = AffineForm.of(other)
        iv = o.to_interval()
        if iv.lo <= 0.0 <= iv.hi:
            return AffineForm.from_interval(-math.inf, math.inf)
        return self * o.reciprocal()

    def __rtruediv__(self, other) -> "AffineForm":
        if _is_ndarray(other):
            return NotImplemented
        return AffineForm.of(other) / self

    def reciprocal(self) -> "AffineForm":
        """1/x via min-range linear approximation on [lo, hi] (x bounded away from 0)."""
        iv = self.to_interval()
        a, b = iv.lo, iv.hi
        if a <= 0.0 <= b:
            return AffineForm.from_interval(-math.inf, math.inf)
        if not self.terms:
            return AffineForm(1.0 / self.x0)
        # min-range approx: slope p = -1/b^2 (for a>0), intercepts at endpoints
        if a > 0:
            p = -1.0 / (b * b)
            ya = 1.0 / a - p * a
            yb = 1.0 / b - p * b
        else:  # b < 0
            p = -1.0 / (a * a)
            ya = 1.0 / a - p * a
            yb = 1.0 / b - p * b
        q = 0.5 * (ya + yb)
        delta = 0.5 * abs(ya - yb)
        out = self.scale(p)
        out.x0 += q
        out.terms[_fresh()] = delta
        return out

    def __pow__(self, n: int) -> "AffineForm":
        if not isinstance(n, int) or n < 0:
            raise ValueError("affine power requires non-negative int exponent")
        if n == 0:
            return AffineForm(1.0)
        if n == 1:
            return AffineForm(self.x0, dict(self.terms))
        if n == 2:
            return self._square()
        return self._square() ** (n // 2) * (self if n % 2 else AffineForm(1.0))

    def _square(self) -> "AffineForm":
        """x^2 with the tight parabola bound: keeps result non-negative-aware."""
        if not self.terms:
            return AffineForm(self.x0 * self.x0)
        r = self.radius
        x0 = self.x0
        # x^2 = x0^2 + 2*x0*X + X^2 ;  X^2 in [0, r^2] -> center r^2/2, rad r^2/2
        out = self.scale(2.0 * x0)
        out.x0 = x0 * x0 + 0.5 * r * r
        out.terms[_fresh()] = 0.5 * r * r
        return out

    # -- domain transfer functions mirroring Interval ------------------------------
    def abs(self) -> "AffineForm":
        iv = self.to_interval()
        if iv.lo >= 0:
            return self
        if iv.hi <= 0:
            return -self
        a = iv.abs()
        return AffineForm.from_interval(a.lo, a.hi)

    def min_(self, other) -> "AffineForm":
        o = AffineForm.of(other)
        iv = self.to_interval().min_(o.to_interval())
        return AffineForm.from_interval(iv.lo, iv.hi)

    def max_(self, other) -> "AffineForm":
        o = AffineForm.of(other)
        iv = self.to_interval().max_(o.to_interval())
        return AffineForm.from_interval(iv.lo, iv.hi)

    def sqrt(self) -> "AffineForm":
        iv = self.to_interval().sqrt()
        return AffineForm.from_interval(iv.lo, iv.hi)

    def join(self, other: "AffineForm") -> "AffineForm":
        """Lattice join (interval hull) — correlations across an undecided
        Select branch pair are not representable, so noise symbols reset."""
        iv = self.to_interval().join(AffineForm.of(other).to_interval())
        return AffineForm.from_interval(iv.lo, iv.hi)

    def select(self, then_v: "AffineForm", else_v: "AffineForm") -> "AffineForm":
        return then_v.join(else_v)

    def __repr__(self) -> str:
        return f"AA({self.x0:g} ± {self.radius:g}, {len(self.terms)} syms)"
