"""Legalization of (alpha, beta) fixed-point types onto TPU containers.

FPGAs synthesize a 13-bit datapath for a 13-bit type; TPUs do not.  The
analysis results are *legalized* onto the smallest hardware container that
holds alpha+beta bits.  This is where the paper's savings materialize on the
real target: container width drives HBM bytes (the dominant energy term) and
selects the int8 MXU path (2x bf16 throughput on v5e).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.fixedpoint import FixedPointType

# container name -> (bits, jnp storage dtype)
CONTAINERS = {
    "int8": (8, jnp.int8),
    "uint8": (8, jnp.uint8),
    "int16": (16, jnp.int16),
    "uint16": (16, jnp.uint16),
    "int32": (32, jnp.int32),
    "uint32": (32, jnp.uint32),
    "float32": (32, jnp.float32),
}


@dataclasses.dataclass(frozen=True)
class LegalizedType:
    fp: Optional[FixedPointType]     # None = float reference
    container: str                   # key into CONTAINERS
    shift: int                       # binary point position = fp.beta

    @property
    def bits(self) -> int:
        return CONTAINERS[self.container][0]

    @property
    def dtype(self):
        return CONTAINERS[self.container][1]

    @property
    def bytes(self) -> float:
        return self.bits / 8.0


def legalize(t: Optional[FixedPointType]) -> LegalizedType:
    if t is None:
        return LegalizedType(fp=None, container="float32", shift=0)
    w = t.width
    prefix = "" if t.signed else "u"
    if w <= 8:
        c = f"{prefix}int8"
    elif w <= 16:
        c = f"{prefix}int16"
    elif w <= 32:
        c = f"{prefix}int32"
    else:
        # analysis blew past 32 integer bits (e.g. unbounded division):
        # fall back to float32, as the paper falls back to wider types
        return LegalizedType(fp=None, container="float32", shift=0)
    return LegalizedType(fp=t, container=c, shift=t.beta)


def container_bytes(t: Optional[FixedPointType]) -> float:
    return legalize(t).bytes


def legalize_design(types: Dict[str, Optional[FixedPointType]]
                    ) -> Dict[str, LegalizedType]:
    return {k: legalize(v) for k, v in types.items()}


def design_bytes(types: Dict[str, Optional[FixedPointType]]) -> float:
    """Bytes per pixel across all stage buffers (TPU HBM-traffic proxy)."""
    return sum(container_bytes(v) for v in types.values())
