"""Range (alpha) analysis — paper §IV-B, Algorithm 1.

Walks the stage DAG in topologically sorted order; at each stage the
expression tree is evaluated over the chosen abstract domain, exploiting the
homogeneity of pixel signals within a stage: every `Ref` leaf materializes
the *stage-level* combined range of its producer (fresh signal per tap
occurrence — taps read distinct pixels and are treated as independent).

Returns per-stage `(range, alpha)` exactly as Algorithm 1's
COMPUTEBITWIDTH 3-tuples.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.core.absval import Domain, get_domain
from repro.core.fixedpoint import alpha_for_range
from repro.core.graph import (BinOp, Call, Cmp, Const, Expr, ParamRef,
                              Pipeline, Pow, Ref, Select)
from repro.core.interval import Interval


@dataclasses.dataclass
class StageRange:
    """Algorithm 1's (z_lo, z_hi, alpha) bit-width 3-tuple for one stage."""
    range: Interval
    alpha: int
    signed: bool

    @staticmethod
    def from_interval(iv: Interval) -> "StageRange":
        return StageRange(range=iv, alpha=alpha_for_range(iv.lo, iv.hi),
                          signed=iv.lo < 0)


def static_cmp(op: str, l: Interval, r: Interval) -> Optional[bool]:
    """Decide a comparison statically when the operand ranges separate.

    Returns True when `l op r` holds for *every* pair of values, False when
    it holds for none, None when both outcomes are possible (the caller
    must join both Select branches).
    """
    if op == "<":
        if l.hi < r.lo:
            return True
        if l.lo >= r.hi:
            return False
    elif op == "<=":
        if l.hi <= r.lo:
            return True
        if l.lo > r.hi:
            return False
    elif op == ">":
        if l.lo > r.hi:
            return True
        if l.hi <= r.lo:
            return False
    elif op == ">=":
        if l.lo >= r.hi:
            return True
        if l.hi < r.lo:
            return False
    return None


def eval_expr_abstract(e: Expr, domain: Domain,
                       stage_ranges: Dict[str, Interval],
                       params: Dict[str, Interval],
                       param_cache: Optional[Dict[str, Any]] = None) -> Any:
    """Recursive abstract evaluation — the body of COMPUTEBITWIDTH.

    `param_cache` shares one abstract signal across all occurrences of the
    same scalar parameter (a parameter is a single correlated signal; the
    affine domain exploits this for cancellation, e.g. USM's `weight`).
    """
    if param_cache is None:
        param_cache = {}

    def rec(n: Expr) -> Any:
        return eval_expr_abstract(n, domain, stage_ranges, params, param_cache)

    if isinstance(e, Const):
        return domain.const(e.value)
    if isinstance(e, Ref):
        return domain.fresh_signal(stage_ranges[e.stage])
    if isinstance(e, ParamRef):
        if e.name not in param_cache:
            param_cache[e.name] = domain.fresh_signal(params[e.name])
        return param_cache[e.name]
    if isinstance(e, BinOp):
        l = rec(e.left)
        r = rec(e.right)
        if e.op == "+":
            return l + r
        if e.op == "-":
            return l - r
        if e.op == "*":
            return l * r
        if e.op == "/":
            return l / r
        raise ValueError(f"unknown binop {e.op}")
    if isinstance(e, Pow):
        # the compiler maps x*x -> x**2 for tighter even-power ranges (§IV-B)
        return rec(e.base) ** e.n
    if isinstance(e, Call):
        args = [rec(a) for a in e.args]
        if e.fn == "abs":
            return args[0].abs()
        if e.fn == "sqrt":
            return args[0].sqrt()
        if e.fn == "min":
            return args[0].min_(args[1])
        if e.fn == "max":
            return args[0].max_(args[1])
        raise ValueError(f"unknown call {e.fn}")
    if isinstance(e, Select):
        # evaluate the Cmp guard: when the operand ranges separate, only the
        # taken branch can execute; otherwise the value range is the join of
        # both branches.  (Pre-PR-4 this called `t.select(t, o)`, passing the
        # then-value as its own condition — harmless only because every
        # domain's `select` ignored its receiver.)
        if isinstance(e.cond, Cmp):
            taken = static_cmp(e.cond.op,
                               domain.to_interval(rec(e.cond.left)),
                               domain.to_interval(rec(e.cond.right)))
            if taken is True:
                return rec(e.then)
            if taken is False:
                return rec(e.other)
        t, o = rec(e.then), rec(e.other)
        # legacy third-party domains may implement select() but not join()
        return t.join(o) if hasattr(t, "join") else t.select(t, o)
    if isinstance(e, Cmp):
        raise ValueError("bare comparison outside Select")
    raise TypeError(f"unknown expr node {type(e)}")


def analyze_direct(pipeline: Pipeline, domain: str | Domain = "interval",
                   input_ranges: Optional[Dict[str, Interval]] = None,
                   ) -> Dict[str, StageRange]:
    """alpha-analysis over the whole DAG (topological order) — direct walk.

    `input_ranges` overrides the declared ranges of input stages (used by the
    profile-refined re-analysis).

    Domains flagged `whole_dag` (e.g. "smt", see `repro.smt`) cannot run as
    a per-stage expression walk — the whole pipeline is analyzed at once via
    the domain's `analyze_pipeline` hook, which returns the same per-stage
    `StageRange` mapping.

    This is the unmemoized backend the `repro.analysis` pass architecture
    wraps; application code should call `analyze` (the one-pass-plan shim)
    or build a `BitwidthPlan` via `repro.analysis.run_plan`.
    """
    dom = get_domain(domain) if isinstance(domain, str) else domain
    if getattr(dom, "whole_dag", False):
        return dom.analyze_pipeline(pipeline, input_ranges=input_ranges)
    ranges: Dict[str, Interval] = {}
    out: Dict[str, StageRange] = {}
    param_cache: Dict[str, Any] = {}   # shared across stages: one signal/param

    for name in pipeline.topo_order():
        st = pipeline.stages[name]
        if st.is_input:
            iv = (input_ranges or {}).get(name, st.input_range)
            if iv is None:
                raise ValueError(f"input stage {name!r} has no declared range")
        else:
            v = eval_expr_abstract(st.expr, dom, ranges, pipeline.params,
                                   param_cache)
            iv = dom.to_interval(v)
        ranges[name] = iv
        out[name] = StageRange.from_interval(iv)
    return out


def analyze(pipeline: Pipeline, domain: str | Domain = "interval",
            input_ranges: Optional[Dict[str, Interval]] = None,
            ) -> Dict[str, StageRange]:
    """alpha-analysis entry point — a shim over a one-pass `BitwidthPlan`.

    Kept for compatibility: new code should declare a pass pipeline with
    `repro.analysis.run_plan` and consume the resulting plan (see
    docs/analysis_api.md).  This shim routes string domains through the
    pass driver (results are content-hash memoized and byte-identical to
    the direct walk) and returns the legacy per-stage `StageRange` dict.
    """
    from repro.analysis import one_pass_ranges
    return one_pass_ranges(pipeline, domain, input_ranges=input_ranges)


def alpha_table(pipeline: Pipeline, **kw) -> Dict[str, int]:
    """Convenience: stage -> alpha (the paper's Table II right column)."""
    return {k: v.alpha for k, v in analyze(pipeline, **kw).items()}
