"""Profile-driven analysis — paper §V-A.

Runs the pipeline (float executor) over a sample image set and extracts, per
stage i and sample s, the max integral bits alpha_i^s needed by any pixel;
then

    alpha_i^max = max_s alpha_i^s        (worst case over the training set)
    alpha_i^avg = round(mean_s alpha_i^s)

plus the per-pixel bit-width CDF data behind the paper's Figure 5.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.graph import Pipeline
from repro.core.interval import Interval


def np_alpha_bits(x: np.ndarray) -> np.ndarray:
    """Per-pixel integral bits (paper's alpha formula, vectorized).

    For v >= 0: ceil(log2(floor(v)+1));  for v < 0 the sign bit is added and
    magnitude uses ceil(log2(ceil(|v|))).  Matches `fixedpoint.alpha_for_range`
    applied to the degenerate range [v, v].
    """
    x = np.asarray(x, dtype=np.float64)
    pos = np.maximum(x, 0.0)
    bits_pos = np.ceil(np.log2(np.floor(pos) + 1.0))
    neg = np.ceil(np.abs(np.minimum(x, 0.0)))
    with np.errstate(divide="ignore"):
        bits_neg = np.where(neg > 1.0, np.ceil(np.log2(neg)), 0.0)
    bits = np.where(x < 0.0, np.maximum(bits_neg, bits_pos) + 1.0,
                    np.maximum(bits_pos, 1.0))
    return bits.astype(np.int32)


@dataclasses.dataclass
class ProfileResult:
    """Per-stage profile statistics over a sample set."""
    alpha_max: Dict[str, int]
    alpha_avg: Dict[str, int]
    observed_range: Dict[str, Interval]          # join over all samples
    # Fig-5 data: stage -> (bit values, cumulative % of pixels <= bits)
    cdf: Dict[str, Tuple[np.ndarray, np.ndarray]]


def profile_pipeline(pipeline: Pipeline, images: Sequence[np.ndarray],
                     run_float, param_values: Dict[str, float] | None = None,
                     ) -> ProfileResult:
    """`run_float(image, params) -> Dict[stage, np.ndarray]` is the executor
    (injected to avoid a core->dsl dependency; see repro.dsl.exec.run_float).
    """
    names = pipeline.topo_order()
    per_sample_alpha: Dict[str, List[int]] = {n: [] for n in names}
    lo: Dict[str, float] = {n: math.inf for n in names}
    hi: Dict[str, float] = {n: -math.inf for n in names}
    hist: Dict[str, np.ndarray] = {n: np.zeros(65, dtype=np.int64) for n in names}

    for img in images:
        outs = run_float(img, param_values or {})
        for n in names:
            arr = np.asarray(outs[n])
            bits = np_alpha_bits(arr)
            per_sample_alpha[n].append(int(bits.max()))
            lo[n] = min(lo[n], float(arr.min()))
            hi[n] = max(hi[n], float(arr.max()))
            h = np.bincount(bits.ravel(), minlength=65)
            hist[n] += h[:65]

    alpha_max = {n: max(v) for n, v in per_sample_alpha.items()}
    alpha_avg = {n: int(round(float(np.mean(v)))) for n, v in per_sample_alpha.items()}
    cdf = {}
    for n in names:
        total = hist[n].sum()
        cum = 100.0 * np.cumsum(hist[n]) / max(total, 1)
        upper = max(int(np.nonzero(hist[n])[0].max(initial=0)) + 1, 1)
        cdf[n] = (np.arange(upper), cum[:upper])
    return ProfileResult(
        alpha_max=alpha_max,
        alpha_avg=alpha_avg,
        observed_range={n: Interval(lo[n], hi[n]) for n in names},
        cdf=cdf,
    )
