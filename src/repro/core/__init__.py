"""Core: the paper's contribution — bit-width analysis for stage DAGs.

- `fixedpoint`: (alpha, beta) fixed-point types + bit-accurate JAX ops
- `interval`, `affine`: abstract domains (paper §III-C)
- `absval`: the pluggable-domain framework (paper §IV-C)
- `graph`: stage-DAG IR with expanded expression trees (PolyMage analogue)
- `range_analysis`: alpha-analysis, Algorithm 1 (paper §IV-B)
- `profile`: profile-driven alpha^max / alpha^avg (paper §V-A)
- `beta_search`: uniform + reverse-topo beta heuristic (paper §V-B)
- `cost_model`: FPGA power/area proxies; `policy`: TPU container legalization
"""
from repro.core.fixedpoint import FixedPointType, alpha_for_range
from repro.core.interval import Interval
from repro.core.affine import AffineForm
from repro.core.graph import Pipeline, Stage, stencil_expr
from repro.core.range_analysis import analyze, alpha_table, StageRange

__all__ = [
    "FixedPointType", "alpha_for_range", "Interval", "AffineForm",
    "Pipeline", "Stage", "stencil_expr", "analyze", "alpha_table", "StageRange",
]
