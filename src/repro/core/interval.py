"""Interval-arithmetic abstract domain — paper §III-C and Algorithm 1.

An `Interval` [lo, hi] over-approximates the set of values a (homogeneous)
pixel signal can take at a pipeline stage.  Transfer functions follow
Algorithm 1 exactly, including the dedicated `power` rule the compiler uses
when it recognizes x*x as x**2 (paper §IV-B: x in [-2,2] ⇒ x*x = [-4,4] but
x**2 = [0,4]).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence, Union

Number = Union[int, float]


def _is_ndarray(x) -> bool:
    # late import keeps core.interval dependency-free of numpy at import time
    return type(x).__module__ == "numpy" and type(x).__name__ == "ndarray"


@dataclasses.dataclass(frozen=True)
class Interval:
    lo: float
    hi: float

    def __post_init__(self):
        if not (self.lo <= self.hi or (math.isnan(self.lo) or math.isnan(self.hi))):
            raise ValueError(f"malformed interval [{self.lo}, {self.hi}]")

    # -- constructors --------------------------------------------------------
    @staticmethod
    def point(v: Number) -> "Interval":
        return Interval(float(v), float(v))

    @staticmethod
    def top() -> "Interval":
        return Interval(-math.inf, math.inf)

    @staticmethod
    def of(v) -> "Interval":
        if isinstance(v, Interval):
            return v
        return Interval.point(v)

    # -- lattice ---------------------------------------------------------------
    def join(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def contains(self, v: Number) -> bool:
        return self.lo - 1e-12 <= v <= self.hi + 1e-12

    def encloses(self, other: "Interval") -> bool:
        return self.lo - 1e-12 <= other.lo and other.hi <= self.hi + 1e-12

    @property
    def width(self) -> float:
        return self.hi - self.lo

    # -- arithmetic (Algorithm 1 switch) ---------------------------------------
    # NB: ndarray operands return NotImplemented so numpy object arrays
    # dispatch elementwise (the §IV-C per-pixel executor relies on this).
    def __add__(self, other) -> "Interval":
        if _is_ndarray(other):
            return NotImplemented
        o = Interval.of(other)
        return Interval(self.lo + o.lo, self.hi + o.hi)

    __radd__ = __add__

    def __neg__(self) -> "Interval":
        return Interval(-self.hi, -self.lo)

    def __sub__(self, other) -> "Interval":
        if _is_ndarray(other):
            return NotImplemented
        o = Interval.of(other)
        return Interval(self.lo - o.hi, self.hi - o.lo)

    def __rsub__(self, other) -> "Interval":
        if _is_ndarray(other):
            return NotImplemented
        return Interval.of(other) - self

    def __mul__(self, other) -> "Interval":
        if _is_ndarray(other):
            return NotImplemented
        o = Interval.of(other)

        def m(a: float, b: float) -> float:
            # standard interval convention: 0 * inf = 0 (avoids NaN bounds)
            if a == 0.0 or b == 0.0:
                return 0.0
            return a * b

        cands = (m(self.lo, o.lo), m(self.lo, o.hi),
                 m(self.hi, o.lo), m(self.hi, o.hi))
        return Interval(min(cands), max(cands))

    __rmul__ = __mul__

    def __truediv__(self, other) -> "Interval":
        if _is_ndarray(other):
            return NotImplemented
        o = Interval.of(other)
        if o.lo <= 0.0 <= o.hi:
            # divisor interval contains zero -> [-inf, +inf]   (Algorithm 1, case /)
            return Interval.top()
        return self * Interval(1.0 / o.hi, 1.0 / o.lo)

    def __rtruediv__(self, other) -> "Interval":
        if _is_ndarray(other):
            return NotImplemented
        return Interval.of(other) / self

    def __pow__(self, n: int) -> "Interval":
        """Exponentiation rule from paper §IV-B (n a compile-time constant)."""
        if not isinstance(n, int) or n < 0:
            raise ValueError("interval power requires a non-negative int exponent")
        if n == 0:
            return Interval.point(1.0)
        if n % 2 == 1:
            return Interval(self.lo ** n, self.hi ** n)
        # even power
        if self.lo >= 0:
            return Interval(self.lo ** n, self.hi ** n)
        if self.hi < 0:
            return Interval(self.hi ** n, self.lo ** n)
        return Interval(0.0, max(self.lo ** n, self.hi ** n))

    # -- domain-specific transfer functions -------------------------------------
    def abs(self) -> "Interval":
        if self.lo >= 0:
            return self
        if self.hi <= 0:
            return -self
        return Interval(0.0, max(-self.lo, self.hi))

    def min_(self, other) -> "Interval":
        o = Interval.of(other)
        return Interval(min(self.lo, o.lo), min(self.hi, o.hi))

    def max_(self, other) -> "Interval":
        o = Interval.of(other)
        return Interval(max(self.lo, o.lo), max(self.hi, o.hi))

    def sqrt(self) -> "Interval":
        lo = max(self.lo, 0.0)
        return Interval(math.sqrt(lo), math.sqrt(max(self.hi, 0.0)))

    def select(self, then_v: "Interval", else_v: "Interval") -> "Interval":
        """Select(cond, a, b): result may be either branch — join."""
        return then_v.join(else_v)

    def __repr__(self) -> str:
        return f"[{self.lo:g}, {self.hi:g}]"


def stencil_range(inp: Interval, weights: Sequence[Sequence[float]],
                  scale: float = 1.0) -> Interval:
    """Combined range of `scale * sum_k w_k * x_k` with all x_k in `inp`.

    This is the paper's homogeneity trick (§IV-B): every tap of the stencil
    reads a pixel of the *same* stage, hence the same interval; the stencil
    expands into the expression  scale * (w_0*x_0 + w_1*x_1 + ...) and interval
    arithmetic treats the taps as independent (no cancellation), exactly as
    the paper's Table II numbers do (e.g. Sobel on [0,255] -> [-85, 85] after
    the 1/12 scale).
    """
    acc = Interval.point(0.0)
    for row in weights:
        for w in row:
            acc = acc + inp * float(w)
    return acc * scale


def dot_range(inps: Iterable[Interval], weights: Iterable[float]) -> Interval:
    acc = Interval.point(0.0)
    for iv, w in zip(inps, weights):
        acc = acc + iv * float(w)
    return acc
