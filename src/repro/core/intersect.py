"""Intersection (reduced-product) domain: interval ∩ affine.

The paper observes (§VI) that affine arithmetic gave no better ranges than
interval arithmetic on its benchmarks.  The reason is visible in the USM
analysis: affine's multiplication introduces a rad*rad linearization term
that can *widen* results past interval arithmetic, even while its
cancellation handling is tighter on linear subexpressions.

Both domains are sound, so their **intersection** is sound and at least as
tight as either — the classic reduced product.  This domain runs both in
lockstep and intersects ranges at every step, giving the best static bound
the framework can produce without profiling.  Registered as "intersect" in
the pluggable-domain registry (paper §IV-C: adding a domain = one class).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

from repro.core.absval import register_domain
from repro.core.affine import AffineForm
from repro.core.interval import Interval


def _meet(a: Interval, b: Interval) -> Interval:
    """Sound intersection (both are over-approximations of the truth)."""
    lo = max(a.lo, b.lo)
    hi = min(a.hi, b.hi)
    if lo > hi:        # numerical round-off between the two domains
        return a if a.width <= b.width else b
    return Interval(lo, hi)


class IAValue:
    """Paired (interval, affine) value evaluated in lockstep."""

    __slots__ = ("iv", "af")

    def __init__(self, iv: Interval, af: AffineForm):
        self.iv = iv
        self.af = af

    @staticmethod
    def of(v) -> "IAValue":
        if isinstance(v, IAValue):
            return v
        return IAValue(Interval.point(float(v)), AffineForm.point(float(v)))

    def range(self) -> Interval:
        return _meet(self.iv, self.af.to_interval())

    def _wrap(self, iv: Interval, af: AffineForm) -> "IAValue":
        # reduce: clamp the interval component by the affine hull and keep
        # the affine form intact (its correlations are its value)
        return IAValue(_meet(iv, af.to_interval()), af)

    # -- arithmetic -----------------------------------------------------------
    def __add__(self, o):
        o = IAValue.of(o)
        return self._wrap(self.iv + o.iv, self.af + o.af)

    __radd__ = __add__

    def __sub__(self, o):
        o = IAValue.of(o)
        return self._wrap(self.iv - o.iv, self.af - o.af)

    def __rsub__(self, o):
        return IAValue.of(o) - self

    def __mul__(self, o):
        o = IAValue.of(o)
        return self._wrap(self.iv * o.iv, self.af * o.af)

    __rmul__ = __mul__

    def __truediv__(self, o):
        o = IAValue.of(o)
        return self._wrap(self.iv / o.iv, self.af / o.af)

    def __rtruediv__(self, o):
        return IAValue.of(o) / self

    def __pow__(self, n: int):
        return self._wrap(self.iv ** n, self.af ** n)

    def __neg__(self):
        return self._wrap(-self.iv, -self.af)

    # -- transfer functions -------------------------------------------------------
    def abs(self):
        return self._wrap(self.iv.abs(), self.af.abs())

    def sqrt(self):
        return self._wrap(self.iv.sqrt(), self.af.sqrt())

    def min_(self, o):
        o = IAValue.of(o)
        return self._wrap(self.iv.min_(o.iv), self.af.min_(o.af))

    def max_(self, o):
        o = IAValue.of(o)
        return self._wrap(self.iv.max_(o.iv), self.af.max_(o.af))

    def join(self, other) -> "IAValue":
        o = IAValue.of(other)
        return self._wrap(self.iv.join(o.iv), self.af.join(o.af))

    def select(self, t, e):
        return IAValue.of(t).join(e)

    def __repr__(self):
        return f"IA({self.range()!r})"


class IntersectDomain:
    name = "intersect"

    def const(self, v: float) -> IAValue:
        return IAValue.of(v)

    def fresh_signal(self, rng: Interval) -> IAValue:
        return IAValue(rng, AffineForm.from_interval(rng.lo, rng.hi))

    def to_interval(self, v: IAValue) -> Interval:
        return v.range()


register_domain("intersect", IntersectDomain)
