"""Fractional-bit (beta) analysis — paper §V-B.

The paper's two-phase heuristic, verbatim:

  1. **Uniform search** — fix integral bits (from static or profile
     analysis), sweep one global beta applied to every stage, and binary
     search for the smallest beta meeting the application quality target.
  2. **Reverse-topological refinement** — one pass over the stages in
     reverse topologically sorted order; at each stage, binary search the
     per-stage beta downward from the uniform estimate while the quality
     target still holds.

Both phases are generic in a `quality_fn(beta_map) -> float` callback
(higher is better), so the same machinery drives HCD corner accuracy, USM
classification error, DUS PSNR, OF angular error, and the LM token-agreement
metric.  The number of profile passes is tracked — the paper's selling point
is that this needs *very few* passes versus simulated annealing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence

from repro.core.graph import Pipeline

QualityFn = Callable[[Dict[str, int]], float]


@dataclasses.dataclass
class BetaSearchResult:
    betas: Dict[str, int]
    uniform_beta: int
    quality: float
    profile_passes: int


def uniform_beta_search(stage_names: Sequence[str], quality_fn: QualityFn,
                        target: float, beta_hi: int = 16) -> tuple[int, int]:
    """Smallest uniform beta in [0, beta_hi] with quality >= target.

    Returns (beta, passes).  Assumes quality is monotone non-decreasing in
    beta (more precision never hurts) — the same assumption the paper's
    binary search makes.  If even beta_hi misses the target, beta_hi is
    returned (caller sees the achieved quality in the full search).
    """
    passes = 0

    def q(b: int) -> float:
        nonlocal passes
        passes += 1
        return quality_fn({n: b for n in stage_names})

    if q(0) >= target:
        return 0, passes
    lo, hi = 0, beta_hi          # invariant: q(lo) < target
    if q(hi) < target:
        return hi, passes
    while hi - lo > 1:
        mid = (lo + hi) // 2
        if q(mid) >= target:
            hi = mid
        else:
            lo = mid
    return hi, passes


def refine_sequence(order: Sequence[str], betas: Dict[str, int],
                    quality_fn: QualityFn, target: float,
                    beta_lo: int = 0) -> tuple[Dict[str, int], int]:
    """Per-name downward binary searches in the given order (§V-B core).

    For each name in `order`, finds the minimal beta in `[beta_lo, cur]`
    still meeting the target while every other assignment is held fixed.
    This is the refinement kernel shared by the pipeline-stage search
    (`reverse_topo_refine`, beta_lo=0) and the LM weight-class search
    (`quant.autoquant`, beta_lo=MIN_BITS).  Returns (refined, passes).
    """
    betas = dict(betas)
    passes = 0

    for name in order:
        cur = betas[name]
        if cur <= beta_lo:
            continue
        lo, hi = beta_lo, cur     # find min b in [beta_lo, cur] meeting target

        def q(b: int) -> float:
            nonlocal passes
            passes += 1
            trial = dict(betas)
            trial[name] = b
            return quality_fn(trial)

        if q(beta_lo) >= target:
            betas[name] = beta_lo
            continue
        # invariant: q(lo) < target <= q(hi)  (hi=cur met target on entry)
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if q(mid) >= target:
                hi = mid
            else:
                lo = mid
        betas[name] = hi
    return betas, passes


def reverse_topo_refine(pipeline: Pipeline, betas: Dict[str, int],
                        quality_fn: QualityFn, target: float,
                        frozen: Sequence[str] = ()) -> tuple[Dict[str, int], int]:
    """One reverse-topological pass of per-stage binary searches (§V-B).

    `frozen` stages (e.g. 8-bit inputs) are not touched.  Returns the
    refined beta map and the number of profile passes consumed.
    """
    order = [n for n in reversed(pipeline.topo_order()) if n not in frozen]
    return refine_sequence(order, betas, quality_fn, target)


def search(pipeline: Pipeline, quality_fn: QualityFn, target: float,
           beta_hi: int = 16, frozen: Sequence[str] = (),
           fixed_betas: Dict[str, int] | None = None) -> BetaSearchResult:
    """Full beta-analysis: uniform binary search + reverse-topo refinement."""
    names = [n for n in pipeline.topo_order() if n not in frozen]
    fixed = dict(fixed_betas or {})

    def qf(m: Dict[str, int]) -> float:
        return quality_fn({**m, **fixed})

    uni, p1 = uniform_beta_search(names, qf, target, beta_hi)
    start = {n: uni for n in names}
    refined, p2 = reverse_topo_refine(pipeline, start, qf, target, frozen=frozen)
    final_quality = quality_fn({**refined, **fixed})
    return BetaSearchResult(betas={**refined, **fixed}, uniform_beta=uni,
                            quality=final_quality, profile_passes=p1 + p2 + 1)
