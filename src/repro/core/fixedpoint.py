"""Variable-width fixed-point data types — paper §III-A.

A fixed-point type is a tuple (alpha, beta): `alpha` integral bits, `beta`
fractional bits (total width alpha+beta).  Signed types use two's complement,
so the representable ranges are

    unsigned: [0, 2^alpha - 2^-beta]
    signed:   [-2^(alpha-1), 2^(alpha-1) - 2^-beta]

On the FPGA the paper synthesizes an (alpha+beta)-bit datapath directly.  On
TPU we *emulate bit-accurately* by storing the scaled integer value
``round(x * 2^beta)`` in the smallest containing hardware container
(int8/int16/int32 — see `repro.core.policy`), with **saturation-mode**
arithmetic as the paper prescribes (§III-A: saturation instead of wrap-around).

Everything here is pure JAX and jit-safe.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FixedPointType:
    """(alpha, beta) fixed-point format — paper's `typ` parameter."""

    alpha: int            # integral bits (includes sign bit when signed)
    beta: int             # fractional bits
    signed: bool = True

    def __post_init__(self):
        if self.alpha < 0 or self.beta < 0:
            raise ValueError(f"negative field width: {self}")
        if self.alpha + self.beta == 0:
            raise ValueError("zero-width fixed-point type")

    # -- derived quantities ------------------------------------------------
    @property
    def width(self) -> int:
        return self.alpha + self.beta

    @property
    def resolution(self) -> float:
        """Smallest representable increment, 2^-beta."""
        return 2.0 ** (-self.beta)

    @property
    def min_value(self) -> float:
        return -(2.0 ** (self.alpha - 1)) if self.signed else 0.0

    @property
    def max_value(self) -> float:
        if self.signed:
            return 2.0 ** (self.alpha - 1) - self.resolution
        return 2.0 ** self.alpha - self.resolution

    # scaled-integer bounds (value * 2^beta)
    @property
    def int_min(self) -> int:
        return -(1 << (self.width - 1)) if self.signed else 0

    @property
    def int_max(self) -> int:
        return (1 << (self.width - 1)) - 1 if self.signed else (1 << self.width) - 1

    def __str__(self) -> str:  # e.g. s13.4 / u8.0
        return f"{'s' if self.signed else 'u'}{self.alpha}.{self.beta}"

    # -- classmethods -------------------------------------------------------
    @staticmethod
    def for_range(lo: float, hi: float, beta: int = 0) -> "FixedPointType":
        """Smallest type whose range covers [lo, hi] — paper's alpha formula."""
        alpha = alpha_for_range(lo, hi)
        return FixedPointType(alpha=alpha, beta=beta, signed=lo < 0)


def alpha_for_range(lo: float, hi: float) -> int:
    """Number of integral bits for range [lo, hi] — paper §IV-B, eq. for alpha.

        alpha = max(ceil(log2(ceil|lo|)), ceil(log2(floor|hi| + 1))) + 1   if lo < 0
        alpha = ceil(log2(floor(hi) + 1))                                  otherwise
    """
    if math.isinf(lo) or math.isinf(hi):
        return 64  # sentinel: analysis blew up (division by interval containing 0)
    if lo > hi:
        raise ValueError(f"empty range [{lo}, {hi}]")

    def _clog2(v: float) -> int:
        if v <= 1:
            return 0
        return int(math.ceil(math.log2(v)))

    if lo < 0:
        a_neg = _clog2(math.ceil(abs(lo)))
        a_pos = _clog2(math.floor(abs(hi)) + 1) if hi > 0 else 0
        return max(a_neg, a_pos) + 1
    return max(_clog2(math.floor(hi) + 1), 1)


# ---------------------------------------------------------------------------
# Bit-accurate fixed-point emulation ops (jit-safe).
#
# Representation: "qvalue" = the scaled integer round(x * 2^beta), carried in
# an int32 (or int64 for wide intermediates).  All ops saturate.
# ---------------------------------------------------------------------------

def _container_dtype(width: int):
    # emulation container — wide enough for exact arithmetic
    if width <= 15:
        return jnp.int32  # products of two 15-bit values fit int32? use 64 for safety
    return jnp.int64


def quantize(x: jax.Array, t: FixedPointType) -> jax.Array:
    """float -> scaled-int qvalue with round-to-nearest-even + saturation."""
    scaled = x * (2.0 ** t.beta)
    # rint = round-half-to-even, matching typical HLS ap_fixed AP_RND_CONV
    q = jnp.rint(scaled)
    q = jnp.clip(q, t.int_min, t.int_max)
    return q.astype(jnp.int64)


def dequantize(q: jax.Array, t: FixedPointType) -> jax.Array:
    return q.astype(jnp.float64 if q.dtype == jnp.int64 else jnp.float32) * (2.0 ** -t.beta)


def fix_round(x: jax.Array, t: FixedPointType) -> jax.Array:
    """Round a float array onto the (alpha,beta) grid with saturation.

    This is the float-in/float-out view used by the profiling executor: it is
    numerically identical to quantize->dequantize but keeps float dtype.
    """
    step = 2.0 ** t.beta
    q = jnp.rint(x * step)
    q = jnp.clip(q, float(t.int_min), float(t.int_max))
    return q / step


def saturating_add(qa, qb, t: FixedPointType):
    s = qa + qb
    return jnp.clip(s, t.int_min, t.int_max)


def saturating_sub(qa, qb, t: FixedPointType):
    s = qa - qb
    return jnp.clip(s, t.int_min, t.int_max)


def saturating_mul(qa, qb, ta: FixedPointType, tb: FixedPointType,
                   tout: FixedPointType):
    """(a * 2^ba) * (b * 2^bb) = ab * 2^(ba+bb); rescale to tout.beta."""
    prod = qa * qb                       # exact in int64
    shift = ta.beta + tb.beta - tout.beta
    if shift > 0:
        # round-half-up on the dropped bits (cheap FPGA rounding)
        prod = (prod + (1 << (shift - 1))) >> shift
    elif shift < 0:
        prod = prod << (-shift)
    return jnp.clip(prod, tout.int_min, tout.int_max)


# ---------------------------------------------------------------------------
# Float-view helpers used by executors: op in f64, then snap to grid.
# The paper's HLS simulation does exactly this via ap_fixed C++ overloads.
# ---------------------------------------------------------------------------

def apply_fixed(x: jax.Array, t: Optional[FixedPointType]) -> jax.Array:
    """Snap to type grid; None = keep float (the float reference design)."""
    if t is None:
        return x
    return fix_round(x, t)


def quant_error_bound(t: FixedPointType) -> float:
    """Max rounding error introduced by one snap: half a resolution step."""
    return 0.5 * t.resolution


def storage_bits(t: Optional[FixedPointType]) -> int:
    """Bits per stored element (float reference = 32)."""
    return 32 if t is None else t.width


def np_quantize(x: np.ndarray, t: FixedPointType) -> np.ndarray:
    """NumPy twin of `quantize` for oracles in tests."""
    q = np.rint(x * (2.0 ** t.beta))
    return np.clip(q, t.int_min, t.int_max).astype(np.int64)
