"""Zamba2-2.7B — Mamba2 backbone + shared attention block [arXiv:2411.15242].

54 Mamba2 layers, d_model=2560, ssm_state=64 (d_inner=5120, 80 ssm-heads of
dim 64); ONE shared transformer block (32H, d_ff=10240) applied every 6
layers with shared weights.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", arch_class="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, d_ff=10240, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, shared_attn_period=6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", arch_class="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, shared_attn_period=2,
        remat=False,
    )
