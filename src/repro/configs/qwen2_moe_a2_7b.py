"""Qwen1.5/2-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048, 16H (kv=16), expert d_ff=1408, shared-expert ff=5632
(= 4 x 1408), vocab=151936.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", arch_class="moe", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408,
        vocab_size=151936, n_experts=60, top_k=4, moe_d_ff=1408,
        shared_expert_d_ff=5632,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2moe-smoke", arch_class="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=512,
        n_experts=6, top_k=2, moe_d_ff=64, shared_expert_d_ff=128,
        remat=False,
    )
