"""Mixtral 8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].

32L d_model=4096, 32H (kv=8), expert d_ff=14336, vocab=32000, SWA 4096.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", arch_class="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=32000,
        n_experts=8, top_k=2, moe_d_ff=14336, sliding_window=4096,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", arch_class="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
        n_experts=4, top_k=2, moe_d_ff=128, sliding_window=16, remat=False,
    )
