"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 (heads = 2560/64 = 40), d_ff=8960 (3.5x), vocab=65536.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", arch_class="rwkv", n_layers=32, d_model=2560,
        n_heads=40, n_kv_heads=40, d_ff=8960, vocab_size=65536,
        rwkv_head_dim=64,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", arch_class="rwkv", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, d_ff=224, vocab_size=512, rwkv_head_dim=32,
        remat=False,
    )
