"""MiniCPM-2B — llama-like with mu-parametrization scales + WSD schedule
[arXiv:2404.06395].

40L d_model=2304, 36H (kv=36), d_ff=5760, vocab=122753.
Scales: emb x12, residual x1.4/sqrt(L), logits x(256/d_model).
The WSD (warmup-stable-decay) schedule lives in repro.train.optimizer.
"""
import math

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    L = 40
    return ModelConfig(
        name="minicpm-2b", arch_class="dense", n_layers=L, d_model=2304,
        n_heads=36, n_kv_heads=36, d_ff=5760, vocab_size=122753,
        emb_scale=12.0, residual_scale=1.4 / math.sqrt(L),
        logit_scale=256.0 / 2304.0,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-smoke", arch_class="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=160, vocab_size=513,
        emb_scale=12.0, residual_scale=1.4 / math.sqrt(2),
        logit_scale=0.25, remat=False,
    )
