"""Phi-3-medium 14B — RoPE + SwiGLU + GQA [arXiv:2404.14219].

40L d_model=5120, 40H (kv=10), d_ff=17920, vocab=100352.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", arch_class="dense", n_layers=40, d_model=5120,
        n_heads=40, n_kv_heads=10, d_ff=17920, vocab_size=100352,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke", arch_class="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=224, vocab_size=512, remat=False,
    )
