"""DeepSeek-LLM 7B — llama-architecture dense [arXiv:2401.02954].

30L d_model=4096, 32H (kv=32, i.e. MHA), d_ff=11008, vocab=102400.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", arch_class="dense", n_layers=30, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=11008, vocab_size=102400,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke", arch_class="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=192, vocab_size=512, remat=False,
    )
