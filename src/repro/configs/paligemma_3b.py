"""PaliGemma-3B — SigLIP vision frontend (STUB) + Gemma decoder
[arXiv:2407.07726].

18L d_model=2048, 8H (kv=1, MQA), head_dim=256, d_ff=16384, vocab=257216.
The image prefix (256 patch embeddings, precomputed by the stubbed SigLIP)
attends bidirectionally (prefix-LM mask).
"""
import math

from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", arch_class="vlm", n_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384,
        vocab_size=257216, n_image_tokens=256,
        emb_scale=math.sqrt(2048.0),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke", arch_class="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=256, vocab_size=512,
        n_image_tokens=8, emb_scale=8.0, remat=False,
    )
