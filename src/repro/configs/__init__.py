"""Architecture registry: --arch <id> -> ModelConfig (+ reduced smoke twin).

Ten assigned architectures plus the paper's own image-pipeline "configs"
(which live in repro.pipelines; see `pipelines_fpga`).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.common import ModelConfig

ARCH_IDS = [
    "rwkv6-3b",
    "qwen3-4b",
    "deepseek-7b",
    "phi3-medium-14b",
    "minicpm-2b",
    "mixtral-8x7b",
    "qwen2-moe-a2.7b",
    "paligemma-3b",
    "whisper-medium",
    "zamba2-2.7b",
]

_MODULES = {
    "rwkv6-3b": "rwkv6_3b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-7b": "deepseek_7b",
    "phi3-medium-14b": "phi3_medium_14b",
    "minicpm-2b": "minicpm_2b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "paligemma-3b": "paligemma_3b",
    "whisper-medium": "whisper_medium",
    "zamba2-2.7b": "zamba2_2_7b",
}


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).config()


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
