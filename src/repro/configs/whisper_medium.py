"""Whisper-medium — encoder-decoder, conv frontend STUB [arXiv:2212.04356].

24L encoder + 24L decoder, d_model=1024, 16H (kv=16), d_ff=4096,
vocab=51865; encoder input = precomputed frame embeddings (B, 1500, 1024).
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", arch_class="encdec", n_layers=24,
        n_encoder_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51865, encoder_seq=1500,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", arch_class="encdec", n_layers=2,
        n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=512, encoder_seq=16, remat=False,
    )
