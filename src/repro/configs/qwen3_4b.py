"""Qwen3-4B — dense GQA with qk_norm [hf:Qwen/Qwen3-8B family].

36L d_model=2560, 32 q-heads / 8 kv-heads, head_dim=128, d_ff=9728,
vocab=151936.
"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b", arch_class="dense", n_layers=36, d_model=2560,
        n_heads=32, n_kv_heads=8, head_dim=128, d_ff=9728,
        vocab_size=151936, qk_norm=True, rope_theta=1e6,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", arch_class="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256, vocab_size=512,
        qk_norm=True, remat=False,
    )
