"""The train step: loss -> grad -> (optional grad compression) -> AdamW.

Composable pieces so the launcher/dry-run can jit the whole thing under a
mesh with explicit in/out shardings.  Gradient compression reuses the
paper's quantizer (int8 block codes) on the DP all-reduce — applied as
quantize -> dequantize *before* the pjit-inserted all-reduce so the wire
format is 8-bit with error feedback accumulated locally.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import ModelBundle
from repro.train.optimizer import AdamWConfig, OptState, adamw_update, \
    init_opt_state

PyTree = Any


class TrainState(NamedTuple):
    params: PyTree
    opt: OptState
    # error feedback residual for compressed gradients (zeros if unused)
    ef: Optional[PyTree]


def init_train_state(bundle: ModelBundle, key,
                     compress_grads: bool = False) -> TrainState:
    params = bundle.init_params(key)
    ef = jax.tree.map(jnp.zeros_like, params) if compress_grads else None
    return TrainState(params=params, opt=init_opt_state(params), ef=ef)


def _compress_tree(grads: PyTree, ef: PyTree) -> Tuple[PyTree, PyTree]:
    """int8 block fake-quant with error feedback (1-bit-Adam style, 8-bit)."""
    from repro.quant.qtypes import quantize_symmetric, dequantize_symmetric

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_symmetric(gf.reshape(-1), bits=8)
        deq = dequantize_symmetric(q, s).reshape(g.shape)
        return deq.astype(g.dtype), (gf - deq).astype(e.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


def make_train_step(bundle: ModelBundle, opt_cfg: AdamWConfig,
                    compress_grads: bool = False, accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    `accum_steps` > 1 splits the global batch into microbatches and
    accumulates gradients with a scan — the activation-memory lever for the
    largest (MoE) training cells.
    """

    cast = getattr(bundle.cfg, "train_weight_cast", "") or \
        ("bf16" if getattr(bundle.cfg, "train_cast_bf16", False) else "")

    def loss_with_cast(params, batch):
        if cast == "bf16":
            params = jax.tree.map(
                lambda p: p.astype(jnp.bfloat16) if p.ndim >= 2 else p,
                params)
        elif cast == "int8":
            from repro.quant.qtypes import fake_quant_ste
            params = jax.tree.map(
                lambda p: fake_quant_ste(p, bits=8, axis=-1).astype(
                    jnp.bfloat16) if p.ndim >= 2 else p,
                params)
        return bundle.loss_fn(params, batch)

    grad_fn = jax.value_and_grad(loss_with_cast, has_aux=True)

    def accumulate(params, batch: Dict):
        if accum_steps == 1:
            return grad_fn(params, batch)
        micro = jax.tree.map(
            lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                + x.shape[1:]), batch)

        def step(carry, mb):
            (loss_sum, aux_sum, g_sum) = carry
            (loss, aux), g = grad_fn(params, mb)
            return (loss_sum + loss,
                    jax.tree.map(lambda a, b: a + b, aux_sum, aux),
                    jax.tree.map(lambda a, b: a + b, g_sum, g)), None

        zeros_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zero_aux = {"loss": jnp.zeros(()), "zloss": jnp.zeros(()),
                    "tokens": jnp.zeros(())}
        (loss_sum, aux_sum, g_sum), _ = jax.lax.scan(
            step, (jnp.zeros(()), zero_aux, zeros_g), micro)
        inv = 1.0 / accum_steps
        return (loss_sum * inv,
                jax.tree.map(lambda a: a * inv, aux_sum)), \
            jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(state: TrainState, batch: Dict):
        (loss, aux), grads = accumulate(state.params, batch)
        ef = state.ef
        if compress_grads and ef is not None:
            grads, ef = _compress_tree(grads, ef)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, **aux, **opt_metrics}
        return TrainState(params=new_params, opt=new_opt, ef=ef), metrics

    return train_step


def make_eval_step(bundle: ModelBundle):
    def eval_step(params, batch):
        loss, aux = bundle.loss_fn(params, batch)
        return {"loss": loss, **aux}
    return eval_step
