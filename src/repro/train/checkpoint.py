"""Checkpointing + fault tolerance.

Design (DESIGN.md §6):
  * step-numbered directories, each written to a temp name and atomically
    renamed — a crash mid-write never corrupts the latest checkpoint
  * a `manifest.json` records the tree structure; arrays go in one .npz
  * `AsyncCheckpointer` runs saves on a writer thread so the train loop
    does not stall (device->host copy happens synchronously, the disk write
    asynchronously) — the standard TPU checkpointing overlap
  * `restore_latest` scans for the newest complete checkpoint (incomplete
    temp dirs are ignored and garbage-collected) -> crash/preemption restart
  * elastic re-scaling: checkpoints are host numpy, so a restore may target
    a *different* mesh — pass `sharding_tree` and arrays are placed per the
    new topology (`jax.device_put`).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

PyTree = Any

_SEP = "/"


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: PyTree,
         extra: Optional[Dict] = None) -> str:
    """Synchronous atomic save. Returns the final directory path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "time": time.time(),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):      # re-save of the same step: replace
        shutil.rmtree(final)
    os.rename(tmp, final)          # atomic publish
    return final


def _complete_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        full = os.path.join(ckpt_dir, name)
        if name.endswith(".tmp"):
            shutil.rmtree(full, ignore_errors=True)   # gc partial writes
            continue
        if name.startswith("step_") and \
                os.path.exists(os.path.join(full, "manifest.json")):
            steps.append(int(name[5:]))
    return sorted(steps)


def restore(path: str, like: PyTree,
            sharding_tree: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of `like`; optionally re-shard elastically."""
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shards = (jax.tree.leaves(sharding_tree)
              if sharding_tree is not None else [None] * len(flat_like))
    leaves = []
    for (p, leaf), sh in zip(flat_like, shards):
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


def restore_latest(ckpt_dir: str, like: PyTree,
                   sharding_tree: Optional[PyTree] = None):
    """-> (tree, step) of the newest complete checkpoint, or (None, -1)."""
    steps = _complete_steps(ckpt_dir)
    if not steps:
        return None, -1
    step = steps[-1]
    return restore(os.path.join(ckpt_dir, f"step_{step:08d}"), like,
                   sharding_tree), step


def prune(ckpt_dir: str, keep: int = 3):
    steps = _complete_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Overlap disk writes with training; at most one save in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved_step = -1

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None):
        self.wait()                                   # one in flight max
        host_tree = jax.tree.map(np.asarray, tree)    # sync device->host

        def _write():
            save(self.ckpt_dir, step, host_tree, extra)
            prune(self.ckpt_dir, self.keep)
            self.last_saved_step = step

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
