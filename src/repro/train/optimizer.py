"""AdamW + learning-rate schedules (cosine and MiniCPM's WSD), pure JAX.

Optimizer state is a pytree parallel to params (m, v); ZeRO-1 sharding of
(m, v) over the data axis is applied by `repro.launch.sharding`
(`zero1_axes`), not here — the math is sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"        # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1         # WSD: fraction of steps in final decay


class OptState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        sched = 1.0
    elif cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        sched = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        # MiniCPM warmup-stable-decay: flat until the last decay_frac of
        # training, then an exponential-ish linear drop
        decay_start = cfg.total_steps * (1.0 - cfg.decay_frac)
        t = jnp.clip((step - decay_start)
                     / max(cfg.total_steps - decay_start, 1), 0.0, 1.0)
        sched = 1.0 - 0.9 * t
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * sched


def init_opt_state(params: PyTree) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros,
                    v=jax.tree.map(jnp.zeros_like, params))


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 state: OptState) -> Tuple[PyTree, OptState, Dict]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v), \
        {"lr": lr, "grad_norm": gnorm}
