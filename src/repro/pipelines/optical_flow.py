"""Horn–Schunck Optical Flow — paper §VI-D, 30 stages.

10 pre-processing stages + 4 repetitions of a 5-stage set, exactly the
paper's structure (Table IX):

  pre:  It = Img2 - Img1
        Ix, Iy = 1/12-Sobel derivatives of Img1
        Ixx = Ix^2 ; Iyy = Iy^2
        Denom = alpha^2 + Ixx + Iyy
        commonX = Ix / Denom ; commonY = Iy / Denom
        Vx0 = -commonX * It  ; Vy0 = -commonY * It       (k=0 update, u_bar=0)
  iter k=1..4 (5 stages each):
        Avgx_k, Avgy_k = HS 3x3 average of Vx_{k-1}, Vy_{k-1}
        Common_k = (Ix*Avgx_k + Iy*Avgy_k + It) / Denom   (shared numerator/denominator)
        Vx_k = Avgx_k - Ix * Common_k
        Vy_k = Avgy_k - Iy * Common_k

The paper does not state its regularization constant; we use the standard
Horn–Schunck alpha^2 = 100 (alpha = 10) and record it.  The qualitative
claims of Table IX reproduce: static alpha estimates for Common/Vx/Vy grow
by several bits per iteration (interval blow-up through the recurrence),
while profile estimates stay flat — the deep-pipeline gap that motivates
profile-driven refinement.
"""
from __future__ import annotations

from repro.core.graph import Pipeline, Pow
from repro.dsl.builder import PipelineBuilder
from repro.pipelines.hcd import SOBEL_X, SOBEL_Y

ALPHA2 = 100.0
HS_AVG = [[1, 2, 1], [2, 0, 2], [1, 2, 1]]   # classic HS neighborhood average
N_ITERS = 4


def build(n_iters: int = N_ITERS) -> Pipeline:
    p = PipelineBuilder("optical_flow")
    img1 = p.image("img1", 0, 255)
    img2 = p.image("img2", 0, 255)

    It = p.define("It", img2 - img1)
    Ix = p.stencil("Ix", img1, SOBEL_X, scale=1.0 / 12)
    Iy = p.stencil("Iy", img1, SOBEL_Y, scale=1.0 / 12)
    Ixx = p.define("Ixx", Pow(Ix, 2))
    Iyy = p.define("Iyy", Pow(Iy, 2))
    denom = p.define("Denom", ALPHA2 + Ixx + Iyy)
    commonX = p.define("commonX", Ix / denom)
    commonY = p.define("commonY", Iy / denom)
    vx = p.define("Vx0", (0 - commonX) * It)
    vy = p.define("Vy0", (0 - commonY) * It)

    for k in range(1, n_iters + 1):
        avgx = p.stencil(f"Avgx{k}", vx, HS_AVG, scale=1.0 / 12)
        avgy = p.stencil(f"Avgy{k}", vy, HS_AVG, scale=1.0 / 12)
        common = p.define(f"Common{k}", (Ix * avgx + Iy * avgy + It) / denom)
        vx = p.define(f"Vx{k}", avgx - Ix * common)
        vy = p.define(f"Vy{k}", avgy - Iy * common)

    p.output(vx)
    p.output(vy)
    return p.build()


def build_pyramid(n_iters: int = 1) -> Pipeline:
    """Coarse-to-fine (2-level) Horn–Schunck pyramid.

    The classic pyramid scheme the flat `build()` skips: both frames are
    binomial-blurred and decimated by (2, 2), one HS update runs at the
    coarse level, the coarse flow is nearest-expanded back to full rate
    (smoothed, x2 magnitude — one coarse pixel spans two fine pixels), and
    `n_iters` fine-level HS iterations refine it.

    For range analysis this is the sampled deep pipeline the phase-split
    encoder exists for: the fine update's `Avg`/`Common`/`V` stages read
    the upsampled coarse flow, which an alignment-blind encoding must cut
    (independent +-|UVx| taps), while the phase-split expansion shares the
    coarse-level pixels with the fine-level derivative stencils.
    """
    p = PipelineBuilder("of_pyramid")
    img1 = p.image("img1", 0, 255)
    img2 = p.image("img2", 0, 255)
    bin2d = [[r * c for c in (1, 2, 1)] for r in (1, 2, 1)]

    # -- coarse level: blur+decimate, one HS update from zero flow ---------
    c1 = p.downsample("cImg1", img1, bin2d, scale=1.0 / 16, stride=(2, 2))
    c2 = p.downsample("cImg2", img2, bin2d, scale=1.0 / 16, stride=(2, 2))
    cIt = p.define("cIt", c2 - c1)
    cIx = p.stencil("cIx", c1, SOBEL_X, scale=1.0 / 12)
    cIy = p.stencil("cIy", c1, SOBEL_Y, scale=1.0 / 12)
    cDenom = p.define("cDenom", ALPHA2 + Pow(cIx, 2) + Pow(cIy, 2))
    cVx = p.define("cVx0", (0 - cIx / cDenom) * cIt)
    cVy = p.define("cVy0", (0 - cIy / cDenom) * cIt)

    # -- expand flow to full rate (x2: coarse displacement in fine pixels) -
    vx = p.upsample("UVx", cVx, bin2d, scale=2.0 / 16, factor=(2, 2))
    vy = p.upsample("UVy", cVy, bin2d, scale=2.0 / 16, factor=(2, 2))

    # -- fine level: HS refinement seeded by the upsampled coarse flow -----
    It = p.define("It", img2 - img1)
    Ix = p.stencil("Ix", img1, SOBEL_X, scale=1.0 / 12)
    Iy = p.stencil("Iy", img1, SOBEL_Y, scale=1.0 / 12)
    denom = p.define("Denom", ALPHA2 + Pow(Ix, 2) + Pow(Iy, 2))
    for k in range(1, n_iters + 1):
        avgx = p.stencil(f"Avgx{k}", vx, HS_AVG, scale=1.0 / 12)
        avgy = p.stencil(f"Avgy{k}", vy, HS_AVG, scale=1.0 / 12)
        common = p.define(f"Common{k}", (Ix * avgx + Iy * avgy + It) / denom)
        vx = p.define(f"Vx{k}", avgx - Ix * common)
        vy = p.define(f"Vy{k}", avgy - Iy * common)
    p.output(vx)
    p.output(vy)
    return p.build()


def stage_families(n_iters: int = N_ITERS):
    """Grouping used by the benchmark table (paper groups by family)."""
    fams = {
        "Img1,Img2": ["img1", "img2"], "It": ["It"], "Ix,Iy": ["Ix", "Iy"],
        "Ixx,Iyy": ["Ixx", "Iyy"], "Denom": ["Denom"],
        "commonX,commonY": ["commonX", "commonY"], "Vx0,Vy0": ["Vx0", "Vy0"],
    }
    for k in range(1, n_iters + 1):
        fams[f"Avg(iter{k})"] = [f"Avgx{k}", f"Avgy{k}"]
        fams[f"Common(iter{k})"] = [f"Common{k}"]
        fams[f"V(iter{k})"] = [f"Vx{k}", f"Vy{k}"]
    return fams
