"""Application-specific quality metrics — paper §VI.

  HCD : % of pixels whose corner classification matches the wide-type
        reference (paper: "percentage of mis-classified corners")
  USM : (a) fraction of pixels mis-classified at the `masked` Select,
        (b) RMS error of correctly-classified pixels vs float
  DUS : PSNR against the wide-type reference
  OF  : Average Angular Error (AAE, degrees) of the flow field
        [Fleet & Jepson '90 / Otte & Nagel '94 formulation]

All metrics compare a candidate design against a reference produced with
"sufficiently long" types (we use the f64 float executor), matching the
paper's methodology.
"""
from __future__ import annotations

import numpy as np


def hcd_accuracy(ref_harris, test_harris, threshold: float | None = None) -> float:
    """% pixels with identical corner classification (higher is better)."""
    ref = np.asarray(ref_harris, dtype=np.float64)
    test = np.asarray(test_harris, dtype=np.float64)
    if threshold is None:
        threshold = 0.01 * float(ref.max())
    agree = (ref > threshold) == (test > threshold)
    return 100.0 * float(np.mean(agree))


def usm_classification_error(ref_mask_branch, test_mask_branch) -> float:
    """% pixels whose Select branch flipped under fixed point (lower=better)."""
    return 100.0 * float(np.mean(np.asarray(ref_mask_branch) != np.asarray(test_mask_branch)))


def usm_branch(env, params) -> np.ndarray:
    """The masked stage's Select predicate: |img - blury| < thresh."""
    return np.abs(np.asarray(env["img"], dtype=np.float64)
                  - np.asarray(env["blury"], dtype=np.float64)) < params["thresh"]


def rms_correct(ref, test, ref_branch, test_branch) -> float:
    """RMS over pixels classified the same way in both designs."""
    ok = np.asarray(ref_branch) == np.asarray(test_branch)
    if not ok.any():
        return float("inf")
    d = (np.asarray(ref, dtype=np.float64) - np.asarray(test, dtype=np.float64))[ok]
    return float(np.sqrt(np.mean(d * d)))


def psnr(ref, test, peak: float = 255.0) -> float:
    ref = np.asarray(ref, dtype=np.float64)
    test = np.asarray(test, dtype=np.float64)
    mse = float(np.mean((ref - test) ** 2))
    if mse == 0.0:
        return float("inf")
    return 10.0 * np.log10(peak * peak / mse)


def aae_degrees(u_ref, v_ref, u_test, v_test) -> float:
    """Average Angular Error between flow fields, in degrees."""
    u_ref, v_ref = np.asarray(u_ref, np.float64), np.asarray(v_ref, np.float64)
    u_test, v_test = np.asarray(u_test, np.float64), np.asarray(v_test, np.float64)
    num = u_ref * u_test + v_ref * v_test + 1.0
    den = np.sqrt((u_ref ** 2 + v_ref ** 2 + 1.0)
                  * (u_test ** 2 + v_test ** 2 + 1.0))
    cosang = np.clip(num / den, -1.0, 1.0)
    return float(np.degrees(np.mean(np.arccos(cosang))))
