"""Unsharp Mask — paper Listing 1 / Figure 1.

    blurx   : vertical 5-tap binomial /16
    blury   : horizontal 5-tap binomial /16
    sharpen : img*(1+weight) + blury*(-weight)
    masked  : Select(|img - blury| < thresh, img, sharpen), clamped at 0
              (output pixels are non-negative -> unsigned 9-bit, Table V)

`weight` is declared over [0, 1] and `thresh` over [0, 255]; with these the
static analysis reproduces Table V's alpha column (8/8/8/10/9).
"""
from __future__ import annotations

from repro.core.graph import Const, Pipeline
from repro.dsl.builder import PipelineBuilder, absv, ite, maxv

BINOMIAL5 = [1, 4, 6, 4, 1]

DEFAULT_PARAMS = {"weight": 1.0, "thresh": 0.01 * 255}


def build() -> Pipeline:
    p = PipelineBuilder("usm")
    img = p.image("img", 0, 255)
    weight = p.param("weight", 0.0, 1.0)
    thresh = p.param("thresh", 0.0, 255.0)
    blurx = p.stencil("blurx", img, [[w] for w in BINOMIAL5], scale=1.0 / 16)
    blury = p.stencil("blury", blurx, [BINOMIAL5], scale=1.0 / 16)
    sharpen = p.define("sharpen", img * (1 + weight) + blury * (-weight))
    masked = p.define(
        "masked", maxv(ite(absv(img - blury) < thresh, img, sharpen), Const(0.0)))
    p.output(masked)
    return p.build()
