"""The paper's four evaluation benchmarks as stage-DAG pipelines."""
from repro.pipelines import data, dus, hcd, metrics, optical_flow, usm

ALL = {
    "hcd": hcd.build,
    "usm": usm.build,
    "dus": dus.build,
    "optical_flow": optical_flow.build,
}

__all__ = ["ALL", "data", "dus", "hcd", "metrics", "optical_flow", "usm"]
