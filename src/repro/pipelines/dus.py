"""Down-and-Up Sampling — paper §VI-C / Figure 7.

Linear DAG: Dx (decimate x) -> Dy (decimate y) -> Ux (expand x) -> Uy
(expand y).  All four stages are convex binomial stencils, so every range
stays [0, 255] and static analysis gives alpha = 8 everywhere (Table VIII).
"""
from __future__ import annotations

from repro.core.graph import Pipeline
from repro.dsl.builder import PipelineBuilder

BIN3 = [1, 2, 1]


def build() -> Pipeline:
    p = PipelineBuilder("dus")
    img = p.image("img", 0, 255)
    Dx = p.downsample("Dx", img, [BIN3], scale=1.0 / 4, stride=(1, 2))
    Dy = p.downsample("Dy", Dx, [[w] for w in BIN3], scale=1.0 / 4, stride=(2, 1))
    Ux = p.upsample("Ux", Dy, [BIN3], scale=1.0 / 4, factor=(1, 2))
    Uy = p.upsample("Uy", Ux, [[w] for w in BIN3], scale=1.0 / 4, factor=(2, 1))
    p.output(Uy)
    return p.build()
