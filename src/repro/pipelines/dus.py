"""Down-and-Up Sampling — paper §VI-C / Figure 7.

Linear DAG: Dx (decimate x) -> Dy (decimate y) -> Ux (expand x) -> Uy
(expand y).  All four stages are convex binomial stencils, so every range
stays [0, 255] and static analysis gives alpha = 8 everywhere (Table VIII).
Note the flip side: because the kernels are convex (weights sum to 1 and
are non-negative), [0, 255] is also the *true* range of every stage — no
sound analysis, phase-split or not, can tighten the paper's DUS chain.

`build_extended` adds the stages a real down-up pyramid is built *for* —
a difference-of-Gaussians band on the decimated grid and the full-rate
reconstruction residual — where cross-boundary correlation is the whole
signal and alignment-blind analyses collapse to +-255.
"""
from __future__ import annotations

from repro.core.graph import Pipeline
from repro.dsl.builder import PipelineBuilder

BIN3 = [1, 2, 1]
BIN5 = [1, 4, 6, 4, 1]
SHARP3 = [1, 6, 1]     # center-heavy tent: >1/2 of the mass on the sample


def build() -> Pipeline:
    p = PipelineBuilder("dus")
    img = p.image("img", 0, 255)
    Dx = p.downsample("Dx", img, [BIN3], scale=1.0 / 4, stride=(1, 2))
    Dy = p.downsample("Dy", Dx, [[w] for w in BIN3], scale=1.0 / 4, stride=(2, 1))
    Ux = p.upsample("Ux", Dy, [BIN3], scale=1.0 / 4, factor=(1, 2))
    Uy = p.upsample("Uy", Ux, [[w] for w in BIN3], scale=1.0 / 4, factor=(2, 1))
    p.output(Uy)
    return p.build()


def build_extended() -> Pipeline:
    """DUS plus the pyramid's detail channels (scale-space extension).

    Two stages ride on the paper's chain:

      * ``D5``/``band`` — a second, wider decimated blur and the
        difference-of-Gaussians band ``Dy - D5`` on the coarse grid (the
        SIFT-style octave band).  The true band range is the +-255-scaled
        positive/negative mass of the 3x3-minus-5x5 binomial difference
        kernel, +-59.77 — but the two operands live behind stride-2
        producers, so an alignment-blind whole-DAG encoding cuts both and
        reports +-255.  Phase-split encoding recovers the exact aligned
        expansion (2 alpha bits).
      * ``res`` — the reconstruction residual ``img - Uy`` at full rate
        (Laplacian detail).  Every output phase correlates with the center
        tap of the down-up chain, tightening +-255 to +-239.06 (exact
        union over the four phases).
      * ``DyS``/``UyS``/``resS`` — a y-only down-up channel with the
        center-heavy ``SHARP3`` kernel and its residual.  Its two output
        phases *differ by an alpha bit*: the aligned phase keeps more than
        half the center pixel's mass (exact +-87.7, 8 bits) while the
        off-grid phase interpolates (+-223.1, 9 bits).  The union bound
        erases that split — this is the stage the per-phase alpha columns
        of `repro.analysis` exist for (one datapath per lattice residue).
    """
    p = PipelineBuilder("dus_ext")
    img = p.image("img", 0, 255)
    Dx = p.downsample("Dx", img, [BIN3], scale=1.0 / 4, stride=(1, 2))
    Dy = p.downsample("Dy", Dx, [[w] for w in BIN3], scale=1.0 / 4, stride=(2, 1))
    Ux = p.upsample("Ux", Dy, [BIN3], scale=1.0 / 4, factor=(1, 2))
    Uy = p.upsample("Uy", Ux, [[w] for w in BIN3], scale=1.0 / 4, factor=(2, 1))
    D5 = p.downsample("D5", img, [[r * c for c in BIN5] for r in BIN5],
                      scale=1.0 / 256, stride=(2, 2))
    band = p.define("band", Dy - D5)
    res = p.define("res", img - Uy)
    DyS = p.downsample("DyS", img, [[w] for w in SHARP3], scale=1.0 / 8,
                       stride=(2, 1))
    UyS = p.upsample("UyS", DyS, [[w] for w in SHARP3], scale=1.0 / 8,
                     factor=(2, 1))
    resS = p.define("resS", img - UyS)
    p.output(band)
    p.output(res)
    p.output(resS)
    return p.build()
