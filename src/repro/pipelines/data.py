"""Synthetic natural-image generator (profiling / training sets).

The Oxford Buildings set used by the paper is not available offline; per
Torralba & Oliva (paper ref [26]) natural images share a ~1/f amplitude
spectrum, so we synthesize seeded 1/f-spectrum textures overlaid with
geometric structure (edges and corners matter for HCD/OF).  Deterministic
given the seed.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def natural_image(shape: Tuple[int, int] = (64, 64), seed: int = 0,
                  spectral_slope: float = 1.0) -> np.ndarray:
    """One synthetic 8-bit grayscale image in [0, 255]."""
    rng = np.random.default_rng(np.random.PCG64(seed))
    H, W = shape
    # 1/f^slope spectrum noise
    fy = np.fft.fftfreq(H)[:, None]
    fx = np.fft.fftfreq(W)[None, :]
    f = np.sqrt(fy * fy + fx * fx)
    f[0, 0] = 1.0
    amp = 1.0 / (f ** spectral_slope)
    phase = rng.uniform(0, 2 * np.pi, size=(H, W))
    spec = amp * np.exp(1j * phase)
    tex = np.real(np.fft.ifft2(spec))
    tex = (tex - tex.min()) / (tex.max() - tex.min() + 1e-12)

    img = 0.6 * tex
    # geometric structure: rectangles and diagonal edges (corners for HCD)
    for _ in range(rng.integers(3, 8)):
        y0, x0 = rng.integers(0, H - 4), rng.integers(0, W - 4)
        h = int(rng.integers(3, max(H // 3, 4)))
        w = int(rng.integers(3, max(W // 3, 4)))
        val = rng.uniform(0.0, 1.0)
        img[y0:min(y0 + h, H), x0:min(x0 + w, W)] = (
            0.5 * img[y0:min(y0 + h, H), x0:min(x0 + w, W)] + 0.5 * val)
    # global illumination gradient
    gy = np.linspace(0, rng.uniform(-0.3, 0.3), H)[:, None]
    img = np.clip(img + gy, 0, 1)
    return np.round(img * 255.0).astype(np.float64)


def image_set(n: int, shape: Tuple[int, int] = (64, 64), seed: int = 0
              ) -> List[np.ndarray]:
    return [natural_image(shape, seed=seed * 10007 + i) for i in range(n)]


def shifted_pair(shape: Tuple[int, int] = (64, 64), seed: int = 0,
                 shift: Tuple[int, int] = (1, 1)) -> Tuple[np.ndarray, np.ndarray]:
    """An image and its translate — ground-truth-flow pair for OF."""
    base = natural_image((shape[0] + 8, shape[1] + 8), seed=seed)
    dy, dx = shift
    a = base[4:4 + shape[0], 4:4 + shape[1]]
    b = base[4 + dy:4 + dy + shape[0], 4 + dx:4 + dx + shape[1]]
    return a, b


def train_test_split(n_total: int = 20, shape=(64, 64), seed: int = 7):
    """Paper §V-A: a sample set split into equal train/test halves."""
    imgs = image_set(n_total, shape, seed)
    half = n_total // 2
    return imgs[:half], imgs[half:]
