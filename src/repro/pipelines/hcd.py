"""Harris Corner Detection — paper Figure 3 / Table I.

Stage structure and stencils exactly as Table I:

    Ix, Iy : 1/12-scaled Sobel derivatives of the 8-bit input
    Ixx=Ix^2, Ixy=Ix*Iy, Iyy=Iy^2 (the compiler maps x*x -> x**2, §IV-B)
    Sxx/Sxy/Syy : 3x3 box sums
    det = Sxx*Syy - Sxy^2 ; trace = Sxx + Syy ; harris = det - 0.04*trace^2

Static interval analysis over this DAG must reproduce paper Table II
([0,255] -> [-85,85] -> ... -> alpha 34 at `harris`), asserted in tests.
"""
from __future__ import annotations

from repro.core.graph import Pipeline, Pow, Ref
from repro.dsl.builder import PipelineBuilder

SOBEL_X = [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]]
SOBEL_Y = [[-1, -2, -1], [0, 0, 0], [1, 2, 1]]
BOX3 = [[1, 1, 1], [1, 1, 1], [1, 1, 1]]

HARRIS_K = 0.04


def build() -> Pipeline:
    p = PipelineBuilder("hcd")
    img = p.image("img", 0, 255)
    Ix = p.stencil("Ix", img, SOBEL_X, scale=1.0 / 12)
    Iy = p.stencil("Iy", img, SOBEL_Y, scale=1.0 / 12)
    Ixx = p.define("Ixx", Pow(Ix, 2))
    Ixy = p.define("Ixy", Ix * Iy)
    Iyy = p.define("Iyy", Pow(Iy, 2))
    Sxx = p.stencil("Sxx", Ixx, BOX3)
    Sxy = p.stencil("Sxy", Ixy, BOX3)
    Syy = p.stencil("Syy", Iyy, BOX3)
    det = p.define("det", Sxx * Syy - Pow(Sxy, 2))
    trace = p.define("trace", Sxx + Syy)
    harris = p.define("harris", det - HARRIS_K * Pow(trace, 2))
    p.output(harris)
    return p.build()


def corner_threshold(ref_harris) -> float:
    """Classification threshold: a fixed fraction of the max response."""
    import numpy as np
    return 0.01 * float(np.max(np.asarray(ref_harris)))
