"""End-to-end bit-width synthesis workflows — Figure 4 of the paper.

For each benchmark this wires together:  static alpha-analysis -> profile
alpha refinement -> beta search against the application quality metric ->
fixed-point design + cost comparison vs the float reference.

Analyses run through the `repro.analysis` pass architecture: a
`BenchmarkSetup.plan()` is the standard interval/smt/profile
`BitwidthPlan` (with per-phase sub-columns on phase-split stages), and the
historical entry points (`static_alphas`, `smt_alphas`, `alpha_columns`)
are thin shims over one-pass plans — byte-identical alphas, now memoized.

Used by tests, benchmarks/, and examples/ so the methodology lives in one
place.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.analysis import BitwidthPlan, ProfilePass, SmtPass, run_plan
from repro.core import beta_search, cost_model, policy
from repro.core.fixedpoint import FixedPointType
from repro.core.graph import Pipeline
from repro.core.profile import ProfileResult, profile_pipeline
from repro.dsl.exec import run_fixed, run_float
from repro.pipelines import data as pdata
from repro.pipelines import dus, hcd, metrics, optical_flow, usm

TypeMap = Dict[str, Optional[FixedPointType]]


def types_from_alpha(pipeline: Pipeline, alphas: Dict[str, int],
                     signed: Dict[str, bool], betas: Dict[str, int]) -> TypeMap:
    """Per-stage fixed-point types from (alpha, signed, beta) columns.

    Alphas below 1 are clamped (a `FixedPointType` needs at least one field
    bit); the clamp is surfaced as a `RuntimeWarning` so zero-range stages
    stay visible instead of silently widening.  Plan-based flows record the
    same event in provenance — see `BitwidthPlan.types`.
    """
    clamped = sorted(n for n in pipeline.stages if alphas[n] < 1)
    if clamped:
        warnings.warn(
            f"alpha clamped to 1 on zero-range stage(s): "
            f"{', '.join(clamped)}", RuntimeWarning, stacklevel=2)
    return {
        n: FixedPointType(alpha=max(alphas[n], 1), beta=betas.get(n, 0),
                          signed=signed[n])
        for n in pipeline.stages
    }


def static_alphas(pipeline: Pipeline, domain: str = "interval"):
    """Per-stage (alpha, signed) columns of the synthesis flow.

    Deprecation shim: a one-pass `BitwidthPlan` column (`repro.analysis`).
    `domain` selects the static analysis: "interval" (Algorithm 1),
    "affine", "intersect", or "smt" (whole-DAG solver-style analysis,
    `repro.smt`)."""
    plan = run_plan(pipeline, [domain])
    return plan.alphas(), plan.signed()


def smt_alphas(pipeline: Pipeline, config=None):
    """SMT-column twin of `static_alphas` with explicit budget control.

    Deprecation shim over a one-pass plan (`SmtPass(config)`)."""
    plan = run_plan(pipeline, [SmtPass(config=config)])
    return plan.alphas("smt"), plan.signed("smt")


def alpha_columns(setup: "BenchmarkSetup", smt_config=None,
                  profile: Optional[ProfileResult] = None) -> Dict[str, Dict]:
    """interval vs smt vs profile alpha columns for one benchmark.

    This is the paper's §VI comparison axis: static interval bounds,
    solver-tightened static bounds, and profile-driven lower bounds —
    sound analyses must nest as profile ⊆ smt ⊆ interval per stage.

    Deprecation shim: the columns are one three-pass `BitwidthPlan`
    (see `BenchmarkSetup.plan` for the plan itself)."""
    passes = ["interval", SmtPass(config=smt_config)]
    if profile is None:
        passes.append(setup.profile_pass())
    plan = run_plan(setup.pipeline, passes)
    ia = plan.columns["interval"]
    sm = plan.columns["smt"]
    if profile is None:
        pr = plan.columns["profile"]
        prof_alpha = {n: r.alpha for n, r in pr.items()}
        prof_range = {n: r.range for n, r in pr.items()}
    else:
        prof_alpha = profile.alpha_max
        prof_range = profile.observed_range
    return {
        n: {
            "interval": ia[n].alpha,
            "smt": sm[n].alpha,
            "profile_max": prof_alpha[n],
            "interval_range": ia[n].range,
            "smt_range": sm[n].range,
            "profile_range": prof_range[n],
        }
        for n in setup.pipeline.topo_order()
    }


@dataclasses.dataclass
class BenchmarkSetup:
    """One paper benchmark bound to data, params, and its quality metric."""
    name: str
    pipeline: Pipeline
    params: Dict[str, float]
    train_images: List
    test_images: List
    # quality_fn(ref_env, fixed_env, params) -> float, higher = better
    quality_of: Callable
    quality_target: float
    two_input: bool = False

    def ref_envs(self, images=None):
        imgs = self.test_images if images is None else images
        return [run_float(self.pipeline, im, self.params) for im in imgs]

    def fixed_envs(self, types: TypeMap, images=None, backend: str = "numpy"):
        imgs = self.test_images if images is None else images
        return [run_fixed(self.pipeline, im, types, self.params,
                          backend=backend) for im in imgs]

    def executor(self, types, backend: str = "jnp", outputs=None,
                 column: Optional[str] = None):
        """Compiled fixed-point executor over the plan-driven lowering
        (`repro.lowering`): reusable across images, one fused program."""
        from repro.lowering import compile_pipeline
        return compile_pipeline(self.pipeline, types, params=self.params,
                                backend=backend, outputs=outputs,
                                column=column)

    def mean_quality(self, types: TypeMap, images=None, refs=None) -> float:
        imgs = self.test_images if images is None else images
        refs = self.ref_envs(imgs) if refs is None else refs
        qs = [self.quality_of(r, f, self.params)
              for r, f in zip(refs, self.fixed_envs(types, imgs))]
        return float(np.mean(qs))

    def profile(self) -> ProfileResult:
        def runner(image, params):
            return run_float(self.pipeline, image, params)
        return profile_pipeline(self.pipeline, self.train_images, runner,
                                self.params)

    def profile_pass(self) -> ProfilePass:
        """The profile analysis as a memoizable plan pass (same executor
        and sample set as `profile`, keyed on the image content hash)."""
        return ProfilePass(self.train_images, params=self.params)

    def plan(self, smt_config=None, phases: bool = True,
             include_profile: bool = True,
             betas: Optional[Dict[str, int]] = None,
             cache_dir: Optional[str] = None) -> BitwidthPlan:
        """The benchmark's standard `BitwidthPlan`: interval + smt (with
        per-phase sub-columns on phase-split stages) + profile columns,
        default column "smt" — the artifact `run_fixed`, `design_report`,
        and `benchmarks/paper_tables.py` consume.  `cache_dir` enables the
        disk-backed plan cache (`repro.analysis.run_plan`)."""
        passes = ["interval", SmtPass(config=smt_config, phases=phases)]
        if include_profile:
            passes.append(self.profile_pass())
        return run_plan(self.pipeline, passes, betas=betas,
                        default_column="smt", cache_dir=cache_dir)

    def beta_quality_fn(self, alphas, signed, images=None, refs=None):
        imgs = self.train_images if images is None else images
        refs = self.ref_envs(imgs) if refs is None else refs

        def qf(beta_map: Dict[str, int]) -> float:
            types = types_from_alpha(self.pipeline, alphas, signed, beta_map)
            return self.mean_quality(types, imgs, refs)

        return qf

    def run_beta_search(self, alphas, signed, beta_hi: int = 12):
        """Deprecated raw-dict beta search — shim over `repro.dse`.

        `repro.dse.search_betas` is the plan-aware entry point (same
        uniform + reverse-topo machinery, measured quality callback);
        this shim forwards the benchmark's quality metric and training
        images and is numerically identical to the historical path
        (pinned by the shim-equivalence test in tests/test_dse.py).
        """
        warnings.warn(
            "BenchmarkSetup.run_beta_search is deprecated; use "
            "repro.dse.search_betas(pipeline, plan, images=..., "
            "target=...) instead", DeprecationWarning, stacklevel=2)
        from repro.dse import search_betas
        return search_betas(
            self.pipeline, alphas, signed=signed, column=None,
            images=self.train_images, target=self.quality_target,
            params=self.params,
            metric=lambda r, f, p: self.quality_of(r, f, p),
            backend="numpy", beta_hi=beta_hi)


# ---------------------------------------------------------------------------
# benchmark constructors (paper §VI) — image sizes kept small for CPU speed;
# sizes only affect profiling statistics, not the static analysis.
# ---------------------------------------------------------------------------

def make_hcd(n_train: int = 6, n_test: int = 6, shape=(48, 48)) -> BenchmarkSetup:
    train, test = pdata.train_test_split(n_train + n_test, shape, seed=11)

    def quality(ref_env, fix_env, params):
        thr = hcd.corner_threshold(ref_env["harris"])
        return metrics.hcd_accuracy(ref_env["harris"], fix_env["harris"], thr)

    return BenchmarkSetup("hcd", hcd.build(), {}, train[:n_train],
                          test[:n_test], quality, quality_target=99.0)


def make_usm(n_train: int = 6, n_test: int = 6, shape=(48, 48)) -> BenchmarkSetup:
    train, test = pdata.train_test_split(n_train + n_test, shape, seed=23)
    params = dict(usm.DEFAULT_PARAMS)

    def quality(ref_env, fix_env, params_):
        rb = metrics.usm_branch(ref_env, params_)
        fb = metrics.usm_branch(fix_env, params_)
        err = metrics.usm_classification_error(rb, fb)
        return 100.0 - err     # % correctly classified

    return BenchmarkSetup("usm", usm.build(), params, train[:n_train],
                          test[:n_test], quality, quality_target=99.5)


def make_dus(n_train: int = 6, n_test: int = 6, shape=(48, 48)) -> BenchmarkSetup:
    train, test = pdata.train_test_split(n_train + n_test, shape, seed=37)

    def quality(ref_env, fix_env, params_):
        out = "Uy"
        return metrics.psnr(ref_env[out], fix_env[out])

    # paper sets required PSNR to infinity; numerically we use a high bar
    return BenchmarkSetup("dus", dus.build(), {}, train[:n_train],
                          test[:n_test], quality, quality_target=50.0)


def make_of(n_pairs: int = 4, shape=(40, 40)) -> BenchmarkSetup:
    pairs = [pdata.shifted_pair(shape, seed=100 + i, shift=(1, 1))
             for i in range(2 * n_pairs)]
    train = pairs[:n_pairs]
    test = pairs[n_pairs:]

    def quality(ref_env, fix_env, params_):
        k = optical_flow.N_ITERS
        aae = metrics.aae_degrees(ref_env[f"Vx{k}"], ref_env[f"Vy{k}"],
                                  fix_env[f"Vx{k}"], fix_env[f"Vy{k}"])
        return -aae            # higher is better

    return BenchmarkSetup("optical_flow", optical_flow.build(), {}, train,
                          test, quality, quality_target=-2.0, two_input=True)


def make_dus_ext(n_train: int = 6, n_test: int = 6,
                 shape=(48, 48)) -> BenchmarkSetup:
    """Extended DUS: the paper chain plus DoG band + reconstruction
    residual — the sampled detail stages the phase-split encoder tightens."""
    train, test = pdata.train_test_split(n_train + n_test, shape, seed=37)

    def quality(ref_env, fix_env, params_):
        return metrics.psnr(ref_env["res"], fix_env["res"])

    return BenchmarkSetup("dus_ext", dus.build_extended(), {},
                          train[:n_train], test[:n_test], quality,
                          quality_target=50.0)


def make_of_pyramid(n_pairs: int = 4, shape=(40, 40)) -> BenchmarkSetup:
    """Coarse-to-fine Horn–Schunck (2 levels, 1 fine iteration) — the
    sampled deep pipeline for phase-split range analysis."""
    pairs = [pdata.shifted_pair(shape, seed=300 + i, shift=(1, 1))
             for i in range(2 * n_pairs)]
    train = pairs[:n_pairs]
    test = pairs[n_pairs:]

    def quality(ref_env, fix_env, params_):
        aae = metrics.aae_degrees(ref_env["Vx1"], ref_env["Vy1"],
                                  fix_env["Vx1"], fix_env["Vy1"])
        return -aae            # higher is better

    return BenchmarkSetup("of_pyramid", optical_flow.build_pyramid(1), {},
                          train, test, quality, quality_target=-2.0,
                          two_input=True)


ALL_BENCHMARKS = {"hcd": make_hcd, "usm": make_usm, "dus": make_dus,
                  "optical_flow": make_of, "dus_ext": make_dus_ext,
                  "of_pyramid": make_of_pyramid}


# ---------------------------------------------------------------------------
# cost comparison — the paper's Tables III/VI/VII/X axis
# ---------------------------------------------------------------------------

def design_report(pipeline: Pipeline, types,
                  image_width: int = 1920, column: Optional[str] = None) -> Dict:
    """Fixed-vs-float cost report; `types` is a TypeMap or a `BitwidthPlan`
    (whose `column` — default column when None — supplies the types).

    A plan with per-phase sub-columns additionally yields the phase-split
    design costs (`fixed_phase` / `phase_improvement`): one datapath per
    sampling-lattice residue, priced at the residue-mean width
    (`cost_model.design_cost(phase_types=...)`) — the area/power the union
    column over-reports on stages like `dus_ext.resS`.
    """
    phase_types = None
    if isinstance(types, BitwidthPlan):
        plan = types
        phase_types = plan.phase_types(column) or None
        types = plan.types(column)
    fixed = cost_model.design_cost(pipeline, types, image_width)
    flt = cost_model.design_cost(pipeline, cost_model.float_design(pipeline),
                                 image_width)
    legal = policy.legalize_design(types)
    report = {
        "fixed": fixed,
        "float": flt,
        "improvement": fixed.ratios_vs(flt),
        "containers": {k: v.container for k, v in legal.items()},
        "total_bits": sum(t.width if t else 32 for t in types.values()),
    }
    if phase_types:
        fixed_ph = cost_model.design_cost(pipeline, types, image_width,
                                          phase_types=phase_types)
        report["fixed_phase"] = fixed_ph
        # >1 where the per-residue datapaths beat the union-width design
        report["phase_improvement"] = fixed_ph.ratios_vs(fixed)
    return report
