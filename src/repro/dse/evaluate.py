"""Measured candidate evaluation — every `DesignPoint` earns its numbers.

A candidate `(alpha, beta)` assignment is *specialized* into a concrete
fixed-point program (`dsl.exec.run_fixed` over the plan-driven lowering)
and run on the calibration images; quality is PSNR / max-abs-err of the
pipeline outputs against the f64 float oracle, and area/power come from
`cost_model.design_cost` on the same type map.  There is deliberately **no
analytical quality model** anywhere in this module — the paper's search
trusts only executed designs, and so does this one (AnyHLS-style: each
candidate is a fully specialized program, which the bit-exact lowered
backends make cheap).

Two memo layers keep the closed loop fast:

  * the evaluator's own result memo, keyed on the candidate's
    (alphas, betas) content — a re-proposed duplicate config returns its
    `DesignPoint` without touching an executor at all (`DSE_STATS.cached`);
  * the process-wide locked-LRU executor cache in `dsl.exec`
    (`EXEC_CACHE_STATS`), keyed on the type-map content hash — distinct
    configs that *lower identically* (or one config across many images)
    compile exactly once.

`verify(point)` re-scores a point through the **lowered** backend and
asserts bit-identity with the recorded score, then cross-checks the
numpy oracle (exact up to rint rounding ties under XLA FP contraction —
see `Evaluator.verify`) — the "every returned point was scored via
bit-exact lowered execution" gate the `design_search` benchmark enforces
on its whole frontier.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import cost_model
from repro.core.fixedpoint import FixedPointType
from repro.core.graph import Pipeline
from repro.dsl.exec import run_fixed, run_float
from repro.dse.frontier import PSNR_CAP, DesignPoint, ErrorBudget

# closed-loop search telemetry: how many candidates were actually executed,
# how many short-circuited on the result memo, how many the frontier threw
# away (budget violation / dominated), how many it kept
DSE_STATS = obs.CounterGroup("dse", evaluated=0, cached=0, rejected=0,
                             accepted=0)

# Oracle cross-check tolerance for rint rounding-tie flips (see
# Evaluator.verify): one flipped LSB at one pixel moves PSNR by far less
# than this, while any real lowering bug drifts by whole decibels.
ORACLE_TIE_TOL_DB = 1e-3


def output_stages(pipeline: Pipeline) -> List[str]:
    """The pipeline's terminal stages — the signals quality is scored on."""
    outs = [n for n in pipeline.topo_order() if not pipeline.consumers(n)]
    return outs or list(pipeline.topo_order())[-1:]


def psnr_of(ref: np.ndarray, test: np.ndarray, peak: float) -> float:
    """PSNR against an explicit peak (the reference signal's own scale)."""
    mse = float(np.mean((np.asarray(ref, dtype=np.float64)
                         - np.asarray(test, dtype=np.float64)) ** 2))
    if mse == 0.0:
        return PSNR_CAP
    if peak <= 0.0:
        return PSNR_CAP if mse == 0.0 else 0.0
    return min(10.0 * math.log10(peak * peak / mse), PSNR_CAP)


class Evaluator:
    """Scores candidate configs by executing them on calibration images.

    `backend` is the `run_fixed` backend the search loop scores with —
    ``"lowered"`` (default: the fused jit program, flowing through the
    locked-LRU executor cache) or ``"numpy"`` (the per-stage oracle; no
    compile, bit-identical by construction).  `verify` always uses the
    lowered path regardless, so frontier points are lowered-scored either
    way.
    """

    def __init__(self, pipeline: Pipeline, signed: Dict[str, bool],
                 images: Sequence, budget: ErrorBudget,
                 params: Optional[Dict[str, float]] = None,
                 image_width: int = 1920, backend: str = "lowered",
                 plan_hash: str = "", plan_column: str = "",
                 sink: Optional[Callable[[DesignPoint], None]] = None):
        self.pipeline = pipeline
        self.signed = dict(signed)
        self.images = list(images)
        self.budget = budget
        self.params = dict(params or {})
        self.image_width = image_width
        self.backend = backend
        self.plan_hash = plan_hash
        self.plan_column = plan_column
        self.sink = sink
        self._memo: Dict[Tuple, DesignPoint] = {}
        self.outputs = output_stages(pipeline)
        # f64 float oracle envs, computed once; per-output peak = the
        # reference's own max magnitude (so deep-integer outputs like
        # HCD's `harris` are scored on their real scale, not [0, 255])
        self.refs = [run_float(pipeline, im, self.params, backend="numpy")
                     for im in self.images]
        self.peaks = {o: max(float(np.max(np.abs(r[o]))) for r in self.refs)
                      for o in self.outputs}

    # -- candidate -> concrete design ---------------------------------------
    def types_of(self, alphas: Dict[str, int],
                 betas: Dict[str, int]) -> Dict[str, FixedPointType]:
        """Type map of one candidate (alpha floor of 1, plan discipline)."""
        return {n: FixedPointType(alpha=max(int(alphas[n]), 1),
                                  beta=int(betas.get(n, 0)),
                                  signed=self.signed[n])
                for n in self.pipeline.stages}

    def _score(self, types: Dict[str, FixedPointType],
               backend: str) -> Tuple[float, float]:
        """(psnr, max_abs_err) of executed outputs vs the f64 oracle.

        psnr is the worst output's PSNR (mse averaged over images);
        max_abs_err is the global worst-case across outputs and images.
        """
        errs = {o: [] for o in self.outputs}
        abs_err = 0.0
        for im, ref in zip(self.images, self.refs):
            env = run_fixed(self.pipeline, im, types, self.params,
                            backend=backend)
            for o in self.outputs:
                r = np.asarray(ref[o], dtype=np.float64)
                f = np.asarray(env[o], dtype=np.float64)
                errs[o].append(float(np.mean((r - f) ** 2)))
                abs_err = max(abs_err, float(np.max(np.abs(r - f))))
        psnr = PSNR_CAP
        for o in self.outputs:
            mse = float(np.mean(errs[o]))
            peak = self.peaks[o]
            if mse == 0.0:
                continue
            p = 0.0 if peak <= 0.0 else min(
                10.0 * math.log10(peak * peak / mse), PSNR_CAP)
            psnr = min(psnr, p)
        return psnr, abs_err

    # -- the one evaluation entry point -------------------------------------
    def evaluate(self, alphas: Dict[str, int], betas: Dict[str, int],
                 strategy: str = "") -> DesignPoint:
        key = (tuple(sorted((n, max(int(a), 1)) for n, a in alphas.items())),
               tuple(sorted((n, int(b)) for n, b in betas.items())))
        hit = self._memo.get(key)
        if hit is not None:
            DSE_STATS.add("cached")
            obs.event("dse.evaluate", result="cached", strategy=strategy,
                      pipeline=self.pipeline.name)
            return hit
        with obs.span("dse.evaluate", pipeline=self.pipeline.name,
                      strategy=strategy, backend=self.backend) as sp:
            types = self.types_of(alphas, betas)
            psnr, abs_err = self._score(types, self.backend)
            cost = cost_model.design_cost(self.pipeline, types,
                                          self.image_width)
            point = DesignPoint(
                alphas={n: t.alpha for n, t in types.items()},
                betas={n: t.beta for n, t in types.items()},
                signed=dict(self.signed),
                psnr=psnr, max_abs_err=abs_err,
                power=cost.power_proxy, lut_bits=cost.lut_bits,
                dsp_bits=cost.dsp_bits, bram_bits=cost.bram_bits,
                total_bits=sum(t.width for t in types.values()),
                meets_budget=self.budget.met_by(psnr, abs_err),
                strategy=strategy, pipeline=self.pipeline.name,
                plan_hash=self.plan_hash, plan_column=self.plan_column,
                verified=False)   # only verify() asserts, never assumes
            sp.set(psnr=round(psnr, 3), max_abs_err=abs_err,
                   power=cost.power_proxy,
                   area=cost.lut_bits + cost.dsp_bits,
                   total_bits=point.total_bits,
                   meets_budget=point.meets_budget)
        DSE_STATS.add("evaluated")
        self._memo[key] = point
        if self.sink is not None:
            self.sink(point)
        return point

    def verify(self, point: DesignPoint) -> DesignPoint:
        """Assert the point's score came from bit-exact lowered execution.

        Two checks, with different strictness on purpose:

        * the fused lowered backend must reproduce the recorded score
          **bit-exactly** — the score is a deterministic measurement of
          the real lowered program, never a guess;
        * the numpy per-stage oracle must agree exactly too, *except* on
          rint rounding ties in the expr f64 fallback, where XLA's FP
          contraction (FMA / excess precision) can land 1 ulp off a
          representable tie point and flip a single output LSB.  That
          envelope is bounded — at most one resolution step per output
          pixel — so oracle drift beyond one LSB (or beyond
          `ORACLE_TIE_TOL_DB` of PSNR) still raises.  Such points are
          kept but flagged `oracle_exact=False`.
        """
        types = self.types_of(point.alphas, point.betas)
        low = self._score(types, "lowered")
        if low != (point.psnr, point.max_abs_err):
            raise AssertionError(
                f"lowered re-score drifted on {self.pipeline.name}: "
                f"lowered={low} point=({point.psnr}, {point.max_abs_err})")
        if self.backend in ("lowered", "pallas", "sharded"):
            ora = self._score(types, "numpy")
        else:
            ora = low   # scored on numpy already; lowered equality proven
        point.oracle_exact = ora == low
        if not point.oracle_exact:
            lsb = max(2.0 ** -types[o].beta for o in self.outputs)
            if (abs(ora[0] - low[0]) > ORACLE_TIE_TOL_DB
                    or abs(ora[1] - low[1]) > lsb):
                raise AssertionError(
                    f"lowered/oracle divergence beyond the rounding-tie "
                    f"envelope on {self.pipeline.name}: lowered={low} "
                    f"oracle={ora} (tol {ORACLE_TIE_TOL_DB} dB / {lsb})")
            obs.event("dse.verify", pipeline=self.pipeline.name,
                      result="tie-flip", strategy=point.strategy,
                      psnr_delta=abs(ora[0] - low[0]),
                      abs_err_delta=abs(ora[1] - low[1]))
        point.verified = True
        return point

    def quality_fn(self, alphas: Dict[str, int],
                   strategy: str = "beta-search") -> Callable:
        """`core.beta_search`-shaped callback over this evaluator.

        quality(beta_map) = measured worst-output PSNR; every probe the
        beta search makes lands in the evaluator memo (and the sink, i.e.
        the frontier) as a first-class candidate — the un-orphaning of
        `core/beta_search.py`: its binary searches now *are* DSE moves.
        """

        def qf(beta_map: Dict[str, int]) -> float:
            return self.evaluate(alphas, beta_map, strategy=strategy).psnr

        return qf
