"""`repro.dse` — closed-loop bitwidth design-space exploration.

The layer ROADMAP item 3 asked for on top of analysis → plan → compile →
execute: search per-stage `(alpha, beta)` assignments against
`cost_model.design_cost` under a measured output-error budget and return
a Pareto frontier of error vs area/power.

    from repro.dse import ErrorBudget, run_design_search
    plan = setup.plan()
    res = plan and run_design_search(setup.pipeline, plan,
                                     setup.train_images,
                                     ErrorBudget(min_psnr=50.0))
    res.chosen            # cheapest feasible DesignPoint
    res.frontier.to_json()

Pieces: `frontier` (DesignPoint / Frontier model + serde), `evaluate`
(measured scoring through `run_fixed`, executor-cache memoized),
`betas` (plan-aware §V-B beta search — `core.beta_search` un-orphaned),
`strategies` (beta sweep / cluster alpha descent / annealing controller),
`driver` (`run_design_search`).  Homogeneity clustering itself is an
`AnalysisPass` — `repro.analysis.ClusterPass`.  See docs/design_search.md.
"""
from repro.dse.betas import (min_output_psnr, quality_fn_from_plan,
                             search_betas)
from repro.dse.driver import DSEResult, run_design_search, seed_alphas
from repro.dse.evaluate import DSE_STATS, Evaluator, output_stages, psnr_of
from repro.dse.frontier import (PSNR_CAP, DesignPoint, ErrorBudget,
                                Frontier)
from repro.dse.strategies import (anneal, cluster_alpha_descent,
                                  seeded_beta_sweep)

__all__ = [
    "DSE_STATS", "DSEResult", "DesignPoint", "ErrorBudget", "Evaluator",
    "Frontier", "PSNR_CAP", "anneal", "cluster_alpha_descent",
    "min_output_psnr", "output_stages", "psnr_of", "quality_fn_from_plan",
    "run_design_search", "search_betas", "seed_alphas",
    "seeded_beta_sweep",
]
