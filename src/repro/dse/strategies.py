"""Search strategies, layered cheap → smart (ROADMAP item 3).

Every strategy speaks the same currency: it proposes candidate
`(alphas, betas)` assignments to an `Evaluator` (emitting a
`dse.propose` span per proposal batch) and lets the measured
`DesignPoint`s flow into the frontier through the evaluator sink.
Three layers:

  1. `seeded_beta_sweep` — the paper's §V-B heuristic as a DSE strategy:
     plan-seeded uniform beta binary search + reverse-topological
     refinement (`core.beta_search`, un-orphaned), with quality = the
     evaluator's measured worst-output PSNR.  Every probe of the binary
     search is recorded as a first-class candidate.
  2. `cluster_alpha_descent` — greedy alpha-narrowing moves at cluster
     granularity: walk the §IV homogeneity clusters in reverse topo
     order and shave shared integer bits below the profile seed while
     the error budget still holds.  Bounded by [1, sound alpha] from the
     plan — a widening move never exceeds what the sound column proved.
  3. `anneal` — the NAS-style controller loop: propose a random
     cluster-level ±1 (alpha|beta) mutation, evaluate it for real,
     accept on improvement or with Boltzmann probability under a
     geometric temperature schedule.  Seeded `random.Random` end to end,
     so the whole search replays bit-identically.
"""
from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.core import beta_search
from repro.core.beta_search import BetaSearchResult
from repro.core.graph import Pipeline
from repro.dse.evaluate import Evaluator
from repro.dse.frontier import DesignPoint

Assignment = Tuple[Dict[str, int], Dict[str, int]]   # (alphas, betas)


def seeded_beta_sweep(evaluator: Evaluator, pipeline: Pipeline,
                      alphas: Dict[str, int], target_psnr: float,
                      beta_hi: int = 12, frozen: Sequence[str] = (),
                      ) -> Tuple[Dict[str, int], BetaSearchResult]:
    """Strategy 1: uniform beta sweep + reverse-topo refine (§V-B)."""
    with obs.span("dse.propose", strategy="beta-sweep",
                  pipeline=pipeline.name, beta_hi=beta_hi) as sp:
        qf = evaluator.quality_fn(alphas, strategy="beta-sweep")
        res = beta_search.search(pipeline, qf, target_psnr,
                                 beta_hi=beta_hi, frozen=frozen)
        sp.set(uniform_beta=res.uniform_beta, passes=res.profile_passes,
               quality=round(res.quality, 3))
    return dict(res.betas), res


def cluster_alpha_descent(evaluator: Evaluator, pipeline: Pipeline,
                          clusters: List[List[str]],
                          alphas: Dict[str, int], betas: Dict[str, int],
                          sound_alphas: Dict[str, int],
                          ) -> Dict[str, int]:
    """Strategy 2: greedy shared-alpha narrowing per homogeneity cluster.

    Inputs (is_input stages) keep their alphas — their representation is
    fixed by the source data, not the design.  Returns the refined alphas.
    """
    alphas = dict(alphas)
    order = list(reversed(clusters))
    for members in order:
        if any(pipeline.stages[m].is_input for m in members):
            continue
        while min(alphas[m] for m in members) > 1:
            with obs.span("dse.propose", strategy="alpha-descent",
                          pipeline=pipeline.name,
                          cluster=",".join(members)) as sp:
                trial = dict(alphas)
                for m in members:
                    trial[m] = max(alphas[m] - 1, 1)
                point = evaluator.evaluate(trial, betas,
                                           strategy="alpha-descent")
                sp.set(meets_budget=point.meets_budget,
                       psnr=round(point.psnr, 3), power=point.power)
            if not point.meets_budget:
                break
            alphas = trial
    return alphas


def _energy(point: DesignPoint, power_ref: float, area_ref: float,
            min_psnr: float) -> float:
    """Scalarized annealing objective (lower = better).

    Feasible designs score their float-normalized power + area; budget
    violations pay a constant wall plus their PSNR shortfall, so the
    walk can brush the boundary but never settles outside it.
    """
    e = point.power / power_ref + point.area / area_ref
    if not point.meets_budget:
        e += 4.0 + max(min_psnr - point.psnr, 0.0) / 10.0
    return e


def anneal(evaluator: Evaluator, pipeline: Pipeline,
           clusters: List[List[str]], alphas: Dict[str, int],
           betas: Dict[str, int], sound_alphas: Dict[str, int],
           power_ref: float, area_ref: float, *,
           seed: int = 0, iters: int = 40, beta_hi: int = 12,
           t0: float = 0.25, decay: float = 0.92) -> Assignment:
    """Strategy 3: the NAS-style propose → evaluate → accept/refine loop.

    Mutations are cluster-level ±1 steps on alpha (clamped to
    [1, cluster max sound alpha] — never wider than the plan proved
    sound) or beta (clamped to [0, beta_hi]).  Acceptance is simulated
    annealing on the measured, float-normalized power+area energy with a
    geometric temperature schedule; the frontier independently keeps
    every feasible non-dominated probe, so a rejected move is not lost.
    """
    rng = random.Random(seed)
    movable = [c for c in clusters
               if not any(pipeline.stages[m].is_input for m in c)]
    if not movable or iters <= 0:
        return dict(alphas), dict(betas)
    cur_a, cur_b = dict(alphas), dict(betas)
    cur = evaluator.evaluate(cur_a, cur_b, strategy="anneal")
    cur_e = _energy(cur, power_ref, area_ref, evaluator.budget.min_psnr)
    best_a, best_b, best_e = dict(cur_a), dict(cur_b), cur_e
    temp = t0
    for i in range(iters):
        members = movable[rng.randrange(len(movable))]
        knob = rng.choice(("alpha", "beta"))
        delta = rng.choice((-1, 1))
        trial_a, trial_b = dict(cur_a), dict(cur_b)
        if knob == "alpha":
            cap = max(sound_alphas[m] for m in members)
            for m in members:
                trial_a[m] = min(max(trial_a[m] + delta, 1), cap)
        else:
            for m in members:
                trial_b[m] = min(max(trial_b[m] + delta, 0), beta_hi)
        if (trial_a, trial_b) == (cur_a, cur_b):   # clamped into a no-op
            temp *= decay
            continue
        with obs.span("dse.propose", strategy="anneal",
                      pipeline=pipeline.name, step=i, knob=knob,
                      delta=delta, cluster=",".join(members),
                      temp=round(temp, 4)) as sp:
            point = evaluator.evaluate(trial_a, trial_b, strategy="anneal")
            e = _energy(point, power_ref, area_ref,
                        evaluator.budget.min_psnr)
            accept = e < cur_e or rng.random() < math.exp(
                min((cur_e - e) / max(temp, 1e-9), 0.0))
            sp.set(energy=round(e, 4), accepted=accept,
                   meets_budget=point.meets_budget)
        if accept:
            cur_a, cur_b, cur_e = trial_a, trial_b, e
            if point.meets_budget and e < best_e:
                best_a, best_b, best_e = dict(trial_a), dict(trial_b), e
        temp *= decay
    return best_a, best_b
