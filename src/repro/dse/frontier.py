"""`DesignPoint` / `Frontier` — the artifacts of the bitwidth design search.

The paper's end product is not a bit-range table but an area/power-optimal
fixed-point *design*: one (alpha, beta) assignment per stage whose measured
output error stays inside the application budget.  A design search produces
many candidates; the useful summary is the **Pareto frontier** over

    error   (PSNR vs the f64 oracle, higher is better)
    power   (`cost_model.DesignCost.power_proxy`, lower is better)
    area    (LUT + DSP bits, lower is better)

A point enters the frontier only if it *meets the error budget* and no kept
point dominates it; dominated incumbents are evicted on insert, so the two
invariants `tests/test_dse.py` pins — mutual non-domination and
budget-compliance of every returned point — hold by construction.

Every point carries provenance back to the `BitwidthPlan` that seeded the
search (pipeline content hash, plan column, proposing strategy) plus a
`verified` flag set only after the candidate's score came from bit-exact
lowered execution checked against the numpy oracle (`evaluate.Evaluator`).
Serialization is stable sorted JSON, same discipline as the plan itself.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

# PSNR is capped here so exact designs (mse == 0) serialize as a finite,
# stable number instead of Infinity (which is not strict JSON)
PSNR_CAP = 999.0


@dataclasses.dataclass
class ErrorBudget:
    """Output-quality floor every returned design must respect.

    `min_psnr` is measured against the f64 float reference on the
    pipeline's output stages (peak = the reference's own signal peak, so
    deep-integer outputs like HCD's `harris` are scored on their real
    scale).  `max_abs_err`, when set, additionally caps the worst-case
    absolute output error.
    """
    min_psnr: float
    max_abs_err: Optional[float] = None

    def met_by(self, psnr: float, abs_err: float) -> bool:
        if psnr < self.min_psnr:
            return False
        if self.max_abs_err is not None and abs_err > self.max_abs_err:
            return False
        return True

    def to_json_dict(self) -> Dict:
        return {"min_psnr": self.min_psnr, "max_abs_err": self.max_abs_err}

    @classmethod
    def from_json_dict(cls, d: Dict) -> "ErrorBudget":
        return cls(min_psnr=float(d["min_psnr"]),
                   max_abs_err=(None if d.get("max_abs_err") is None
                                else float(d["max_abs_err"])))


@dataclasses.dataclass
class DesignPoint:
    """One evaluated (alpha, beta) assignment with its measured objectives."""

    alphas: Dict[str, int]
    betas: Dict[str, int]
    signed: Dict[str, bool]
    # measured objectives (never analytical guesses — see evaluate.Evaluator)
    psnr: float
    max_abs_err: float
    power: float                 # DesignCost.power_proxy
    lut_bits: float
    dsp_bits: float
    bram_bits: float
    total_bits: int
    meets_budget: bool
    # provenance: which strategy proposed it, which plan seeded the search
    strategy: str = ""
    pipeline: str = ""
    plan_hash: str = ""          # BitwidthPlan.content_hash
    plan_column: str = ""        # plan column the alphas were seeded from
    verified: bool = False       # scored via bit-exact lowered execution
    # the numpy per-stage oracle reproduced the lowered score exactly;
    # False marks a design whose fused f64 expr fallback landed on an
    # rint rounding tie that XLA's FP contraction resolves the other way
    # (a 1-ulp excess-precision artifact, bounded by one output LSB)
    oracle_exact: bool = True

    @property
    def area(self) -> float:
        """Scalar area objective: logic + multiplier array bits."""
        return self.lut_bits + self.dsp_bits

    def dominates(self, other: "DesignPoint") -> bool:
        """Pareto dominance over (error, power, area): no worse on all
        three objectives and strictly better on at least one."""
        ge = (self.psnr >= other.psnr and self.power <= other.power
              and self.area <= other.area)
        gt = (self.psnr > other.psnr or self.power < other.power
              or self.area < other.area)
        return ge and gt

    def key(self) -> Tuple:
        """Content identity of the candidate configuration itself."""
        return (tuple(sorted(self.alphas.items())),
                tuple(sorted(self.betas.items())))

    def to_json_dict(self) -> Dict:
        return {
            "alphas": dict(sorted(self.alphas.items())),
            "betas": dict(sorted(self.betas.items())),
            "signed": dict(sorted(self.signed.items())),
            # numeric fields coerced so serialization is type-stable no
            # matter how the point was constructed (int vs float costs)
            "psnr": float(self.psnr),
            "max_abs_err": float(self.max_abs_err),
            "power": float(self.power), "lut_bits": float(self.lut_bits),
            "dsp_bits": float(self.dsp_bits),
            "bram_bits": float(self.bram_bits),
            "total_bits": int(self.total_bits),
            "meets_budget": self.meets_budget,
            "strategy": self.strategy, "pipeline": self.pipeline,
            "plan_hash": self.plan_hash, "plan_column": self.plan_column,
            "verified": self.verified, "oracle_exact": self.oracle_exact,
        }

    @classmethod
    def from_json_dict(cls, d: Dict) -> "DesignPoint":
        return cls(
            alphas={k: int(v) for k, v in d["alphas"].items()},
            betas={k: int(v) for k, v in d["betas"].items()},
            signed={k: bool(v) for k, v in d["signed"].items()},
            psnr=float(d["psnr"]), max_abs_err=float(d["max_abs_err"]),
            power=float(d["power"]), lut_bits=float(d["lut_bits"]),
            dsp_bits=float(d["dsp_bits"]), bram_bits=float(d["bram_bits"]),
            total_bits=int(d["total_bits"]),
            meets_budget=bool(d["meets_budget"]),
            strategy=d.get("strategy", ""), pipeline=d.get("pipeline", ""),
            plan_hash=d.get("plan_hash", ""),
            plan_column=d.get("plan_column", ""),
            verified=bool(d.get("verified", False)),
            oracle_exact=bool(d.get("oracle_exact", True)))


class Frontier:
    """Budget-gated Pareto frontier over (error, power, area).

    `add` returns the disposition: ``"accepted"`` (kept, dominated
    incumbents evicted), ``"dominated"`` (an incumbent dominates it), or
    ``"budget"`` (error budget violated — never kept).  Duplicate
    configurations resolve to ``"dominated"`` (a point never strictly
    dominates its own copy, and the copy adds nothing).
    """

    def __init__(self, budget: ErrorBudget):
        self.budget = budget
        self._points: List[DesignPoint] = []

    def __len__(self) -> int:
        return len(self._points)

    def add(self, p: DesignPoint) -> str:
        if not p.meets_budget:
            return "budget"
        if any(q.key() == p.key() for q in self._points):
            return "dominated"
        if any(q.dominates(p) for q in self._points):
            return "dominated"
        self._points = [q for q in self._points if not p.dominates(q)]
        self._points.append(p)
        return "accepted"

    def points(self) -> List[DesignPoint]:
        """Frontier points in a stable order: power ascending, then error
        descending — the natural left-to-right Pareto walk."""
        return sorted(self._points,
                      key=lambda p: (p.power, -p.psnr, p.area,
                                     p.total_bits, p.key()))

    def best(self, objective: str = "power") -> Optional[DesignPoint]:
        """Cheapest frontier point by one scalar objective (the "chosen"
        design of the benchmark report); ties break toward better error."""
        pts = self.points()
        if not pts:
            return None
        keyf = {"power": lambda p: (p.power, p.area, -p.psnr),
                "area": lambda p: (p.area, p.power, -p.psnr),
                "psnr": lambda p: (-p.psnr, p.power, p.area)}[objective]
        return min(pts, key=keyf)

    def check_invariants(self) -> bool:
        """The two frontier guarantees, re-checked explicitly (tests)."""
        pts = self._points
        for i, a in enumerate(pts):
            if not a.meets_budget:
                raise AssertionError(f"frontier point violates budget: {a}")
            for j, b in enumerate(pts):
                if i != j and a.dominates(b):
                    raise AssertionError(
                        f"frontier point {i} dominates point {j}")
        return True

    # -- serialization -------------------------------------------------------
    def to_json_dict(self) -> Dict:
        return {
            "version": 1,
            "budget": self.budget.to_json_dict(),
            "points": [p.to_json_dict() for p in self.points()],
        }

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), sort_keys=True, indent=1)

    @classmethod
    def from_json_dict(cls, d: Dict) -> "Frontier":
        fr = cls(ErrorBudget.from_json_dict(d["budget"]))
        fr._points = [DesignPoint.from_json_dict(p) for p in d["points"]]
        return fr

    @classmethod
    def from_json(cls, text: str) -> "Frontier":
        return cls.from_json_dict(json.loads(text))
