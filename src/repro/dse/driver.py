"""`run_design_search` — the closed loop: plan → propose → execute → keep.

One call takes a `BitwidthPlan` and calibration images and returns a
`DSEResult`: the Pareto frontier of measured error vs modeled area/power,
the chosen (cheapest feasible) design, the homogeneity clusters the
search moved over, and the §V-B beta-search result that seeded it.

Layering (each stage feeds the next, every probe lands in the frontier):

  1. seed alphas from the plan — profile column when present (the paper's
     empirical floor), capped by the sound column's proved alphas;
  2. `seeded_beta_sweep` finds per-stage betas meeting the PSNR budget;
  3. `cluster_alpha_descent` shaves shared integer bits per §IV cluster;
  4. `anneal` runs the NAS-style controller over cluster-level ±1 moves.

Determinism: same plan, images, budget, and seed ⇒ the identical frontier
JSON (seeded rng, ordered dicts, measured — not timed — objectives).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro import obs
from repro.analysis.cluster import homogeneity_clusters
from repro.analysis.plan import BitwidthPlan
from repro.core import cost_model
from repro.core.beta_search import BetaSearchResult
from repro.core.graph import Pipeline
from repro.dse.evaluate import DSE_STATS, Evaluator
from repro.dse.frontier import DesignPoint, ErrorBudget, Frontier
from repro.dse.strategies import (anneal, cluster_alpha_descent,
                                  seeded_beta_sweep)


@dataclasses.dataclass
class DSEResult:
    frontier: Frontier
    chosen: Optional[DesignPoint]     # cheapest-power feasible design
    clusters: List[List[str]]
    beta_result: BetaSearchResult
    evaluations: int                  # distinct candidates executed
    plan_column: str

    def to_json_dict(self) -> Dict:
        return {
            "frontier": self.frontier.to_json_dict(),
            "chosen": self.chosen.to_json_dict() if self.chosen else None,
            "clusters": [list(c) for c in self.clusters],
            "uniform_beta": self.beta_result.uniform_beta,
            "beta_search_passes": self.beta_result.profile_passes,
            "evaluations": self.evaluations,
            "plan_column": self.plan_column,
        }


def seed_alphas(plan: BitwidthPlan, column: Optional[str] = None,
                ) -> Dict[str, int]:
    """Starting alphas: the profile column's empirical floor where the
    plan carries one, capped by the sound column's proved alphas."""
    sound = plan.alphas(column)
    if "profile" in plan.columns:
        prof = plan.alphas("profile")
        return {n: min(prof.get(n, a), a) for n, a in sound.items()}
    return dict(sound)


def run_design_search(pipeline: Pipeline, plan: BitwidthPlan,
                      images: Sequence, budget: ErrorBudget, *,
                      params: Optional[Dict[str, float]] = None,
                      column: Optional[str] = None, seed: int = 0,
                      beta_hi: int = 12, anneal_iters: int = 40,
                      ladder: int = 3, image_width: int = 1920,
                      backend: str = "lowered",
                      verify: bool = False) -> DSEResult:
    """Search per-stage (alpha, beta) assignments under an error budget.

    `column` names the plan's sound column (default column when None) —
    it bounds every alpha move; the profile column, when present, seeds
    the starting point.  `backend` is the scoring executor (see
    `Evaluator`).  `verify=True` re-scores every frontier point through
    the lowered backend against the numpy oracle and asserts bit-equality
    (`DesignPoint.verified`).
    """
    col = plan._col(column)
    sound_alphas = plan.alphas(col)
    signed = plan.signed(col)
    frontier = Frontier(budget)

    def sink(point: DesignPoint) -> None:
        disp = frontier.add(point)
        if disp == "accepted":
            DSE_STATS.add("accepted")
            obs.event("dse.accept", pipeline=pipeline.name,
                      strategy=point.strategy, psnr=round(point.psnr, 3),
                      power=point.power, area=point.area,
                      total_bits=point.total_bits)
        else:
            DSE_STATS.add("rejected")
            obs.event("dse.reject", pipeline=pipeline.name,
                      strategy=point.strategy, reason=disp)

    evaluator = Evaluator(pipeline, signed, images, budget,
                          params=params, image_width=image_width,
                          backend=backend, plan_hash=plan.content_hash,
                          plan_column=col, sink=sink)
    with obs.span("dse.search", pipeline=pipeline.name, column=col,
                  seed=seed, backend=backend) as sp:
        start = seed_alphas(plan, column)
        clusters = homogeneity_clusters(pipeline, plan.stage_ranges(col))

        # 1+2: plan-seeded §V-B beta sweep at the seed alphas
        betas, beta_res = seeded_beta_sweep(
            evaluator, pipeline, start, budget.min_psnr, beta_hi=beta_hi)

        # 3: greedy shared-alpha narrowing over the homogeneity clusters
        alphas = cluster_alpha_descent(evaluator, pipeline, clusters,
                                       start, betas, sound_alphas)

        # 4: NAS-style annealing controller around the greedy design
        flt = cost_model.design_cost(
            pipeline, cost_model.float_design(pipeline), image_width)
        best_a, best_b = anneal(
            evaluator, pipeline, clusters, alphas, betas, sound_alphas,
            power_ref=flt.power_proxy,
            area_ref=flt.lut_bits + flt.dsp_bits,
            seed=seed, iters=anneal_iters, beta_hi=beta_hi)

        # 5: quality ladders — the frontier is a trade-off curve, not one
        # winner: step the best design's betas upward (what each extra
        # fractional bit buys in PSNR) and its alphas toward the sound
        # column (what lifting saturation buys), so the caller sees the
        # whole error axis, not just the cheapest feasible corner
        for k in range(1, ladder + 1):
            up_a = {n: min(a + k, sound_alphas[n])
                    for n, a in best_a.items()}
            up_b = {n: min(b + k, beta_hi) for n, b in best_b.items()}
            evaluator.evaluate(best_a, up_b, strategy="beta-ladder")
            evaluator.evaluate(up_a, best_b, strategy="alpha-ladder")
            # saturation and rounding error cap each other: stepping both
            # knobs is what actually climbs the quality axis
            evaluator.evaluate(up_a, up_b, strategy="joint-ladder")

        if verify:
            for p in frontier.points():
                evaluator.verify(p)
        frontier.check_invariants()
        chosen = frontier.best("power")
        sp.set(evaluations=len(evaluator._memo),
               frontier=len(frontier),
               chosen_psnr=(round(chosen.psnr, 3) if chosen else None),
               chosen_power=(chosen.power if chosen else None))
    return DSEResult(frontier=frontier, chosen=chosen, clusters=clusters,
                     beta_result=beta_res,
                     evaluations=len(evaluator._memo), plan_column=col)
