"""Plan-aware beta search — `core.beta_search` un-orphaned from plans.

`core/beta_search.py` is the paper's §V-B two-phase heuristic over an
opaque `quality_fn(beta_map)`; historically every caller hand-built that
callback from raw `(alphas, signed)` dicts.  `search_betas` is the one
modern entry point: hand it a `BitwidthPlan` (or raw columns) plus
calibration images and it constructs the measured quality callback —
fixed-point execution on a named `run_fixed` backend against the f64
float oracle — and runs uniform search + reverse-topo refinement.

`pipelines.workflows.BenchmarkSetup.run_beta_search` is now a deprecated
shim over this function (numerically identical on the same inputs — the
shim-equivalence test in `tests/test_dse.py` pins it on USM).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core import beta_search
from repro.core.beta_search import BetaSearchResult
from repro.core.fixedpoint import FixedPointType
from repro.core.graph import Pipeline


def plan_columns(plan_or_alphas, signed=None, column: Optional[str] = None):
    """(alphas, signed, column_name) from a plan or raw dict columns."""
    if hasattr(plan_or_alphas, "alphas") and hasattr(plan_or_alphas, "_col"):
        plan = plan_or_alphas
        return (plan.alphas(column), plan.signed(column),
                plan._col(column))
    if signed is None:
        raise TypeError("raw alphas need an explicit signed map "
                        "(or pass a BitwidthPlan)")
    return dict(plan_or_alphas), dict(signed), column or ""


def min_output_psnr(pipeline: Pipeline):
    """Default quality metric: worst-output PSNR vs the reference env."""
    from repro.dse.evaluate import output_stages, psnr_of

    outs = output_stages(pipeline)

    def metric(ref_env, fix_env, params) -> float:
        vals = []
        for o in outs:
            r = np.asarray(ref_env[o], dtype=np.float64)
            peak = float(np.max(np.abs(r)))
            vals.append(psnr_of(r, np.asarray(fix_env[o]), peak))
        return min(vals)

    return metric


def quality_fn_from_plan(pipeline: Pipeline, plan_or_alphas, *,
                         images: Sequence, signed=None,
                         column: Optional[str] = None,
                         params: Optional[Dict[str, float]] = None,
                         metric: Optional[Callable] = None,
                         backend: str = "numpy",
                         refs=None) -> Callable[[Dict[str, int]], float]:
    """Measured `quality_fn(beta_map)` for `core.beta_search`.

    `metric(ref_env, fixed_env, params) -> float` (higher = better)
    defaults to worst-output PSNR; quality is the mean over `images`.
    Alphas below 1 take the standard clamp-to-1 (plan discipline).
    """
    from repro.dsl.exec import run_fixed, run_float

    alphas, signed, _col = plan_columns(plan_or_alphas, signed, column)
    params = dict(params or {})
    metric = metric or min_output_psnr(pipeline)
    if refs is None:
        refs = [run_float(pipeline, im, params) for im in images]

    def qf(beta_map: Dict[str, int]) -> float:
        types = {n: FixedPointType(alpha=max(alphas[n], 1),
                                   beta=beta_map.get(n, 0),
                                   signed=signed[n])
                 for n in pipeline.stages}
        qs = [metric(r, run_fixed(pipeline, im, types, params,
                                  backend=backend), params)
              for im, r in zip(images, refs)]
        return float(np.mean(qs))

    return qf


def search_betas(pipeline: Pipeline, plan_or_alphas, *, images: Sequence,
                 target: float, signed=None, column: Optional[str] = None,
                 params: Optional[Dict[str, float]] = None,
                 metric: Optional[Callable] = None, backend: str = "numpy",
                 refs=None, beta_hi: int = 12, frozen: Sequence[str] = (),
                 fixed_betas: Optional[Dict[str, int]] = None,
                 ) -> BetaSearchResult:
    """Uniform sweep + reverse-topo refine against a measured quality.

    The plan-aware face of `core.beta_search.search`: alphas/signed come
    from the plan's `column` (default column when None), quality from
    executing each trial design on `images` via `backend`.
    """
    qf = quality_fn_from_plan(pipeline, plan_or_alphas, images=images,
                              signed=signed, column=column, params=params,
                              metric=metric, backend=backend, refs=refs)
    return beta_search.search(pipeline, qf, target, beta_hi=beta_hi,
                              frozen=frozen, fixed_betas=fixed_betas)
