"""Whole-DAG constraint encoding — the front half of `repro.smt`.

The per-stage interval walk (`core.range_analysis`) deliberately discards
cross-stage correlations: every `Ref` leaf materializes the producer's
*combined* range as a fresh signal.  The paper's SMT analysis (§V-B) instead
encodes the whole stage DAG as one constraint system over shared input-pixel
and parameter variables, so `img - blur(img)` knows both operands read the
same pixels.

`encode_stage` flattens the transitive expression DAG feeding one stage into
a flat CSP:

  * each distinct input pixel ``(stage, dy, dx)`` is ONE variable — taps at
    the same offset share it (correlation recovered), taps at different
    offsets stay independent (the §IV-B homogeneity model);
  * each scalar parameter is one shared variable;
  * every operator application becomes an auxiliary variable with a
    defining constraint ``v = op(args)``;
  * flattening is *budgeted*: past ``max_vars``, and across re/up-sampling
    stages (where tap alignment is data-layout dependent and sharing would
    be unsound), a producer instance becomes a free "cut" variable bounded
    by the best already-known sound range for that stage.  Cuts are what
    make the analysis compositional on deep pipelines: `analyze_smt`
    tightens stages in topological order, so cut bounds inherit earlier
    SMT results rather than raw interval ones.

**Phase-split encoding** (`encode_stage_phases`) removes the sampling cuts:
across stride/upsample stages the §IV homogeneity classes are exactly the
output-phase residues mod the pipeline's sampling lattice, so fixing the
root's output coordinate to one residue makes every tap→source coordinate
map a concrete integer (floor) map — the expansion through sampled
producers becomes exactly aligned and sharing is sound again.  One CSP per
phase; the stage range is the union over phases (`optimize` solves them as
one multi-phase query, `solver.decide_multi`).

Everything downstream (HC4 contraction, branch-and-prune, dichotomic
tightening) operates on this CSP; see `repro.smt.solver` / `.optimize`.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import (BinOp, Call, Cmp, Const, Expr, ParamRef,
                              Pipeline, Pow, Ref, Select)
from repro.core.interval import Interval

# operand encoding: ("v", var_id) or ("c", float)
Operand = Tuple[str, float]

VAR, CONST = "v", "c"


def var(i: int) -> Operand:
    return (VAR, i)


def const(x: float) -> Operand:
    return (CONST, float(x))


@dataclasses.dataclass(frozen=True)
class Def:
    """Defining constraint of one auxiliary variable: ``v = op(args)``.

    ops: ``+ - * / pow abs sqrt min max select``.  For ``pow`` the exponent
    is in `n`; for ``select`` args are ``(cond_l, cond_r, then, other)`` and
    `cmp` holds the comparison operator of the condition.
    """
    op: str
    args: Tuple[Operand, ...]
    n: int = 0
    cmp: str = ""


class CSP:
    """Flat constraint system over interval-boxed real variables."""

    def __init__(self):
        self.names: List[str] = []
        self.kinds: List[str] = []          # input | param | cut | aux
        self.init: List[Interval] = []      # initial box
        self.defs: List[Optional[Def]] = [] # aux vars only; operands < var id

    # -- construction -------------------------------------------------------
    def new_var(self, name: str, iv: Interval, kind: str,
                d: Optional[Def] = None) -> int:
        self.names.append(name)
        self.kinds.append(kind)
        self.init.append(iv)
        self.defs.append(d)
        return len(self.names) - 1

    # -- queries ------------------------------------------------------------
    @property
    def nvars(self) -> int:
        return len(self.names)

    def base_vars(self) -> List[int]:
        """Free variables of the system (everything without a definition)."""
        return [i for i, d in enumerate(self.defs) if d is None]

    def is_linear(self) -> bool:
        """True when every def is affine in the base vars (then one affine
        sweep computes the exact range hull — no search needed)."""
        for d in self.defs:
            if d is None:
                continue
            if d.op in ("+", "-"):
                continue
            if d.op == "*" and (d.args[0][0] == CONST or d.args[1][0] == CONST):
                continue
            if d.op == "/" and d.args[1][0] == CONST:
                continue
            return False
        return True

    def cond_dependent_vars(self) -> set:
        """Base vars some Select condition depends on (transitively).

        The objective has jump discontinuities in these, so monotonicity
        fixing must exclude them (see solver._monotone_fix).
        """
        # deps[v] = set of base vars feeding v
        deps: List[set] = [set() for _ in range(self.nvars)]
        for i, d in enumerate(self.defs):
            if d is None:
                deps[i].add(i)
            else:
                for (tag, val) in d.args:
                    if tag == VAR:
                        deps[i] |= deps[int(val)]
        out: set = set()
        for d in self.defs:
            if d is not None and d.op == "select":
                for (tag, val) in d.args[:2]:
                    if tag == VAR:
                        out |= deps[int(val)]
        return out


_CMP_OPS = {"<", "<=", ">", ">="}


def _is_sampled(pipeline: Pipeline, name: str) -> bool:
    st = pipeline.stages[name]
    return st.stride != (1, 1) or st.upsample != (1, 1)


def closure_is_sampled(pipeline: Pipeline, stage: str) -> bool:
    """True when `stage` or any transitive producer is strided/upsampled —
    i.e. when the alignment-blind encoder would cut (and phase-split can
    recover sharing)."""
    seen = set()
    stack = [stage]
    while stack:
        n = stack.pop()
        if n in seen:
            continue
        seen.add(n)
        if _is_sampled(pipeline, n):
            return True
        st = pipeline.stages[n]
        if st.expr is not None:
            stack.extend(r.stage for r in st.refs())
    return False


def sampling_lattice(pipeline: Pipeline, stage: str
                     ) -> Optional[Tuple[int, int]]:
    """Per-axis phase modulus (My, Mx) of the DAG feeding `stage`.

    Walking from the root, a producer read through a stage with stride `s`
    and upsample `u` advances `s/u` source pixels per root pixel; the
    accumulated per-stage rates are exact rationals.  Choosing the modulus
    as the lcm of all rate denominators makes every per-stage coordinate
    step (`M * rate`) an integer, which is precisely the condition for the
    floor tap→source maps to be translation-invariant within one output
    residue class — each phase CSP then models *every* pixel of its class.

    Returns None when two paths reach the same producer at different rates
    (no uniform lattice exists; callers fall back to the blind encoding).
    """
    rates: Dict[str, Tuple[Fraction, Fraction]] = {
        stage: (Fraction(1), Fraction(1))}
    stack = [stage]
    while stack:
        name = stack.pop()
        st = pipeline.stages[name]
        if st.is_input or st.expr is None:
            continue
        ry, rx = rates[name]
        child_rate = (ry * st.stride[0] / st.upsample[0],
                      rx * st.stride[1] / st.upsample[1])
        for child in dict.fromkeys(r.stage for r in st.refs()):
            if child in rates:
                if rates[child] != child_rate:
                    return None
            else:
                rates[child] = child_rate
                stack.append(child)
    my = mx = 1
    for ry, rx in rates.values():
        my = my * ry.denominator // math.gcd(my, ry.denominator)
        mx = mx * rx.denominator // math.gcd(mx, rx.denominator)
    return my, mx


def _flatten(pipeline: Pipeline, stage: str,
             stage_bounds: Dict[str, Interval],
             input_ranges: Optional[Dict[str, Interval]],
             max_vars: int,
             origin: Optional[Tuple[int, int]]) -> Tuple[CSP, int]:
    """Shared flattening core.

    `origin=None` is the alignment-blind mode (classic `encode_stage`):
    tap offsets accumulate additively, sampled producers are cut, and an
    upsampled root stage cuts each tap individually.  `origin=(ry, rx)`
    is phase-split mode: coordinates are absolute on each stage's own
    output grid, the root sits at its phase residue, and every Ref maps
    through the exact `(y*stride + dy) // upsample` source coordinate —
    sampled producers expand and share like any other stage.
    """
    phase_mode = origin is not None
    csp = CSP()
    inst: Dict[Tuple[str, int, int], Operand] = {}
    params: Dict[str, int] = {}

    def cut(name: str, dy: int, dx: int, tag: str = "") -> Operand:
        return var(csp.new_var(f"{name}[{dy},{dx}]{tag}", stage_bounds[name],
                               "cut"))

    def instantiate(name: str, dy: int, dx: int) -> Operand:
        key = (name, dy, dx)
        if key in inst:
            return inst[key]
        st = pipeline.stages[name]
        if st.is_input:
            iv = (input_ranges or {}).get(name, st.input_range)
            if iv is None:
                raise ValueError(f"input stage {name!r} has no declared range")
            op = var(csp.new_var(f"{name}[{dy},{dx}]", iv, "input"))
        elif (not phase_mode and name != stage
              and _is_sampled(pipeline, name)):
            # blind mode, sampled producer: tap alignment is not uniform
            # across output pixels, so sharing its expansion would be
            # unsound — cut.
            op = cut(name, dy, dx)
        elif csp.nvars >= max_vars:
            op = cut(name, dy, dx)
        else:
            # blind mode only: nearest-expand upsampling makes the *reading*
            # stage's tap->source mapping alignment-dependent — cut each tap
            # individually.  Phase mode resolves the mapping exactly instead.
            cut_taps = not phase_mode and st.upsample != (1, 1)
            op = encode_expr(st.expr, st, dy, dx, cut_taps)
            # the expansion defines the value, but the producer's best known
            # sound range is extra information the flattened expression may
            # not imply (it can come from earlier SMT tightening): meet it
            # into the instance's initial box.  Applied uniformly: constant-
            # folded expansions are wrapped in an aux var first, so they
            # benefit from earlier tightening exactly like VAR roots.
            b = stage_bounds.get(name)
            if b is not None:
                if op[0] == CONST:
                    op = var(csp.new_var(
                        f"{name}[{dy},{dx}]", Interval.point(op[1]), "aux",
                        Def("+", (const(op[1]), const(0.0)))))
                i = int(op[1])
                lo = max(csp.init[i].lo, b.lo)
                hi = min(csp.init[i].hi, b.hi)
                if lo <= hi:
                    csp.init[i] = Interval(lo, hi)
        inst[key] = op
        return op

    def aux(name: str, d: Def) -> Operand:
        return var(csp.new_var(name, Interval.top(), "aux", d))

    def encode_expr(e: Expr, st, Y: int, X: int,
                    cut_taps: bool = False) -> Operand:
        if isinstance(e, Const):
            return const(e.value)
        if isinstance(e, Ref):
            if phase_mode:
                # exact tap->source map: output (Y, X) of `st` reads its
                # producer at the decimated-then-expanded source coordinate
                cy = (Y * st.stride[0] + e.dy) // st.upsample[0]
                cx = (X * st.stride[1] + e.dx) // st.upsample[1]
                return instantiate(e.stage, cy, cx)
            if cut_taps:
                key = (e.stage, Y + e.dy, X + e.dx)
                if key not in inst:
                    inst[key] = cut(e.stage, Y + e.dy, X + e.dx, "~up")
                return inst[key]
            return instantiate(e.stage, Y + e.dy, X + e.dx)
        if isinstance(e, ParamRef):
            if e.name not in params:
                params[e.name] = csp.new_var(
                    e.name, pipeline.params[e.name], "param")
            return var(params[e.name])
        if isinstance(e, BinOp):
            l = encode_expr(e.left, st, Y, X, cut_taps)
            r = encode_expr(e.right, st, Y, X, cut_taps)
            if l[0] == CONST and r[0] == CONST:
                return const(_fold(e.op, l[1], r[1]))
            return aux(e.op, Def(e.op, (l, r)))
        if isinstance(e, Pow):
            b = encode_expr(e.base, st, Y, X, cut_taps)
            if b[0] == CONST:
                return const(b[1] ** e.n)
            return aux(f"pow{e.n}", Def("pow", (b,), n=e.n))
        if isinstance(e, Call):
            args = tuple(encode_expr(a, st, Y, X, cut_taps) for a in e.args)
            return aux(e.fn, Def(e.fn, args))
        if isinstance(e, Select):
            c = e.cond
            if not isinstance(c, Cmp) or c.op not in _CMP_OPS:
                raise ValueError(f"unsupported select condition {c!r}")
            cl = encode_expr(c.left, st, Y, X, cut_taps)
            cr = encode_expr(c.right, st, Y, X, cut_taps)
            t = encode_expr(e.then, st, Y, X, cut_taps)
            o = encode_expr(e.other, st, Y, X, cut_taps)
            return aux("select", Def("select", (cl, cr, t, o), cmp=c.op))
        raise TypeError(f"unknown expr node {type(e)}")

    oy, ox = origin if phase_mode else (0, 0)
    root = instantiate(stage, oy, ox)
    if root[0] == CONST:
        root = var(csp.new_var("root", Interval.point(root[1]), "aux",
                               Def("+", (const(root[1]), const(0.0)))))
    return csp, int(root[1])


def encode_stage(pipeline: Pipeline, stage: str,
                 stage_bounds: Dict[str, Interval],
                 input_ranges: Optional[Dict[str, Interval]] = None,
                 max_vars: int = 400) -> Tuple[CSP, int]:
    """Flatten the DAG feeding `stage` into a CSP; returns (csp, root_var).

    `stage_bounds` must hold a *sound* range for every stage (interval seed,
    progressively replaced by SMT-tightened ones) — used to bound cut vars.
    This is the alignment-blind encoding (sampled producers are cut); see
    `encode_stage_phases` for the phase-split alternative.
    """
    return _flatten(pipeline, stage, stage_bounds, input_ranges, max_vars,
                    origin=None)


def encode_stage_phase(pipeline: Pipeline, stage: str,
                       origin: Tuple[int, int],
                       stage_bounds: Dict[str, Interval],
                       input_ranges: Optional[Dict[str, Interval]] = None,
                       max_vars: int = 400) -> Tuple[CSP, int]:
    """Exactly-aligned CSP for the output pixels `origin (mod lattice)`."""
    return _flatten(pipeline, stage, stage_bounds, input_ranges, max_vars,
                    origin=origin)


def encode_stage_phases(pipeline: Pipeline, stage: str,
                        stage_bounds: Dict[str, Interval],
                        input_ranges: Optional[Dict[str, Interval]] = None,
                        max_vars: int = 400,
                        max_phases: int = 16
                        ) -> Optional[List[Tuple[CSP, int]]]:
    """Phase-split encoding: one exactly-aligned CSP per output-phase
    residue `(ry, rx)` mod the sampling lattice; the stage range is the
    union over phases.

    Returns None (callers fall back to the alignment-blind `encode_stage`)
    when no uniform lattice exists or the phase count exceeds `max_phases`
    — the budget guard for pathologically deep sampling chains.
    """
    lat = sampling_lattice(pipeline, stage)
    if lat is None:
        return None
    my, mx = lat
    if my * mx > max_phases:
        return None
    return [encode_stage_phase(pipeline, stage, (ry, rx), stage_bounds,
                               input_ranges, max_vars)
            for ry in range(my) for rx in range(mx)]


# ---------------------------------------------------------------------------
# program compilation — the batched-box evaluator's input format
# ---------------------------------------------------------------------------
#
# The scalar solver walks `csp.defs` box-by-box through Python dicts/lists.
# The batched evaluator (solver.hc4_batch & friends) instead runs a whole
# (N, nvars) frontier of lo/hi arrays through one flat numpy op table; this
# section compiles a CSP into that table exactly once (cached on the CSP).

OP_ADD, OP_SUB, OP_MUL, OP_DIV, OP_POW = 0, 1, 2, 3, 4
OP_ABS, OP_SQRT, OP_MIN, OP_MAX, OP_SELECT = 5, 6, 7, 8, 9

OPCODES = {"+": OP_ADD, "-": OP_SUB, "*": OP_MUL, "/": OP_DIV,
           "pow": OP_POW, "abs": OP_ABS, "sqrt": OP_SQRT,
           "min": OP_MIN, "max": OP_MAX, "select": OP_SELECT}

CMP_CODES = {"<": 0, "<=": 1, ">": 2, ">=": 3}


@dataclasses.dataclass
class Program:
    """One CSP compiled to a flat, topo-ordered numpy op table.

    Row ``k`` defines variable ``def_var[k]`` as ``opcode[k]`` applied to up
    to four operands; operand slot ``j`` is variable ``argv[k, j]`` when
    ``argv[k, j] >= 0``, else the constant ``argc[k, j]``.  Rows are in
    increasing ``def_var`` order, so a single forward pass is an evaluation
    of the whole DAG (operand ids are always < the defined id).
    """
    nvars: int
    def_var: np.ndarray        # (nd,)  int32 — id of the defined variable
    opcode: np.ndarray         # (nd,)  int8
    argv: np.ndarray           # (nd,4) int32 operand var id; -1 = constant
    argc: np.ndarray           # (nd,4) float64 constant (0 where var)
    nargs: np.ndarray          # (nd,)  int8 number of live operand slots
    pow_n: np.ndarray          # (nd,)  int16 exponent (pow rows)
    cmp: np.ndarray            # (nd,)  int8 comparison code (select rows)
    init_lo: np.ndarray        # (nvars,) initial box
    init_hi: np.ndarray        # (nvars,)
    base: np.ndarray           # (nbase,) int32 base (free) variable ids
    frozen: np.ndarray         # (nvars,) bool — cond-dependent base vars
    # static split-candidate table, scalar `_split_candidates` order: sign
    # splits of zero-straddling mul/div/even-pow operands and select
    # thresholds, nearest the root first.  Columns: (var, split_at,
    # is_select_threshold); sign splits have split_sel=False and split at 0.
    split_var: np.ndarray      # (ns,) int32
    split_at: np.ndarray       # (ns,) float64 (select threshold, else 0.0)
    split_sel: np.ndarray      # (ns,) bool

    @property
    def ndefs(self) -> int:
        return len(self.def_var)


_N_SLOTS = 4


def compile_csp(csp: CSP) -> Program:
    """Compile (and cache) the flat numpy program for `csp`."""
    prog = getattr(csp, "_program", None)
    if prog is not None:
        return prog
    rows = [(i, d) for i, d in enumerate(csp.defs) if d is not None]
    nd = len(rows)
    def_var = np.empty(nd, np.int32)
    opcode = np.empty(nd, np.int8)
    argv = np.full((nd, _N_SLOTS), -1, np.int32)
    argc = np.zeros((nd, _N_SLOTS), np.float64)
    nargs = np.zeros(nd, np.int8)
    pow_n = np.zeros(nd, np.int16)
    cmp = np.zeros(nd, np.int8)
    for k, (i, d) in enumerate(rows):
        def_var[k] = i
        opcode[k] = OPCODES[d.op]
        nargs[k] = len(d.args)
        pow_n[k] = d.n
        if d.op == "select":
            cmp[k] = CMP_CODES[d.cmp]
        for j, (tag, val) in enumerate(d.args):
            if tag == VAR:
                argv[k, j] = int(val)
            else:
                argc[k, j] = float(val)
    init_lo = np.array([iv.lo for iv in csp.init], np.float64)
    init_hi = np.array([iv.hi for iv in csp.init], np.float64)
    base = np.array(csp.base_vars(), np.int32)
    frozen = np.zeros(csp.nvars, bool)
    for i in csp.cond_dependent_vars():
        frozen[i] = True

    # static split candidates, mirroring solver._split_candidates' priority
    # order (reverse def order; within a def: mul/div slots, even-pow
    # operand, select-vs-constant thresholds).  Deduplication is per-box at
    # runtime (only the first qualifying row fires), so repeats are fine.
    s_var: List[int] = []
    s_at: List[float] = []
    s_sel: List[bool] = []
    for i in range(csp.nvars - 1, -1, -1):
        d = csp.defs[i]
        if d is None:
            continue
        if d.op in ("*", "/"):
            cand = [d.args[0], d.args[1]]
        elif d.op == "pow" and d.n % 2 == 0:
            cand = [d.args[0]]
        elif d.op == "select":
            for a, b in ((d.args[0], d.args[1]), (d.args[1], d.args[0])):
                if a[0] == VAR and b[0] == CONST:
                    s_var.append(int(a[1]))
                    s_at.append(float(b[1]))
                    s_sel.append(True)
            continue
        else:
            continue
        for o in cand:
            if o[0] == VAR:
                s_var.append(int(o[1]))
                s_at.append(0.0)
                s_sel.append(False)
    prog = Program(
        nvars=csp.nvars, def_var=def_var, opcode=opcode, argv=argv,
        argc=argc, nargs=nargs, pow_n=pow_n, cmp=cmp,
        init_lo=init_lo, init_hi=init_hi, base=base, frozen=frozen,
        split_var=np.array(s_var, np.int32),
        split_at=np.array(s_at, np.float64),
        split_sel=np.array(s_sel, bool))
    csp._program = prog
    return prog


def _fold(op: str, a: float, b: float) -> float:
    if op == "+":
        return a + b
    if op == "-":
        return a - b
    if op == "*":
        return a * b
    if op == "/":
        return a / b if b != 0 else float("inf")
    raise ValueError(op)
