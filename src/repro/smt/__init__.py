"""`repro.smt` — SMT-style whole-DAG range analysis (paper §V-B).

Emulates the paper's SMT-solver-based alpha analysis without external
dependencies: the stage DAG is flattened into one constraint system over
shared input-pixel/parameter variables (`encoder`), satisfiability queries
"can stage s exceed T?" are answered by HC4 interval contraction + affine
relaxation + branch-and-prune (`solver`, optionally delegated to z3 via
`z3backend`), and per-stage bounds are tightened by the paper's dichotomic
threshold search (`optimize`).

Queries run on the *batched-box* engine by default: the CSP is compiled
once into a flat numpy op table (`encoder.compile_csp`) and the whole
branch-and-prune frontier is contracted/split as (N, nvars) lo/hi arrays —
`SMTConfig(engine="scalar")` (or `analyze(pipe, domain="smt-scalar")`)
selects the original box-at-a-time reference oracle.

Stages read through stride/upsample boundaries use *phase-split* encoding
(`SMTConfig(phase_split=True)`, the default): one exactly-aligned CSP per
output-phase residue of the sampling lattice, solved as a single
OR-composed multi-phase query (`solver.decide_multi`) whose union bound
replaces the alignment-blind sampling cuts.  See docs/range_analysis.md.

Importing this package registers the `"smt"` analysis domain, so

    from repro.core.range_analysis import analyze
    analyze(pipeline, domain="smt")          # whole-DAG dispatch

is the complete integration surface (§IV-C).  The registry lazy-loads this
package on first use of the name, so the import is rarely explicit.
"""
from repro.smt import domain as _domain   # registers "smt" + "smt-scalar"
from repro.smt.optimize import (BudgetExhaustedWarning, SMTConfig,
                                alpha_table_smt, analyze_smt)

__all__ = ["BudgetExhaustedWarning", "SMTConfig", "analyze_smt",
           "alpha_table_smt"]
