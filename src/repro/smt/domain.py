"""Registry glue: the "smt" entry in the pluggable-domain registry.

The paper's §IV-C framework treats an analysis as a type parameter swap;
`analyze(pipe, domain="smt")` should therefore be the whole integration
effort.  Unlike interval/affine, the SMT analysis is *whole-DAG* — it
cannot run as a per-stage expression walk — so the domain carries a
`whole_dag` marker plus an `analyze_pipeline` hook that
`core.range_analysis.analyze` dispatches to.

The per-expression protocol methods still behave like the interval domain,
so code that feeds this domain to `eval_expr_abstract` directly (e.g. the
per-pixel abstract executor) degrades gracefully to interval semantics.

Two registry entries share this adapter: `"smt"` answers queries with the
batched-box engine (vectorized numpy frontier, the default), and
`"smt-scalar"` pins the original box-at-a-time reference oracle — useful
for differential testing and for debugging solver regressions through the
same `analyze(pipe, domain=...)` surface.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.absval import register_domain
from repro.core.interval import Interval

from repro.smt.optimize import SMTConfig, analyze_smt


class SMTDomain:
    name = "smt"
    whole_dag = True     # range_analysis.analyze dispatches to analyze_pipeline
    engine = "batched"

    def __init__(self, config: Optional[SMTConfig] = None):
        if config is None:
            config = SMTConfig(engine=self.engine)
        elif config.engine != self.engine:
            config = dataclasses.replace(config, engine=self.engine)
        self.config = config

    # -- whole-DAG entry point ----------------------------------------------
    def analyze_pipeline(self, pipeline,
                         input_ranges: Optional[Dict[str, Interval]] = None):
        return analyze_smt(pipeline, input_ranges=input_ranges,
                           config=self.config)

    # -- per-expression protocol (interval fallback) ------------------------
    def const(self, v: float) -> Interval:
        return Interval.point(v)

    def fresh_signal(self, rng: Interval) -> Interval:
        return rng

    def to_interval(self, v: Interval) -> Interval:
        return v


class SMTScalarDomain(SMTDomain):
    """Reference-oracle twin: same analysis, scalar branch-and-prune."""
    name = "smt-scalar"
    engine = "scalar"


register_domain("smt", SMTDomain)
register_domain("smt-scalar", SMTScalarDomain)
