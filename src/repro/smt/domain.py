"""Registry glue: the "smt" entry in the pluggable-domain registry.

The paper's §IV-C framework treats an analysis as a type parameter swap;
`analyze(pipe, domain="smt")` should therefore be the whole integration
effort.  Unlike interval/affine, the SMT analysis is *whole-DAG* — it
cannot run as a per-stage expression walk — so the domain carries a
`whole_dag` marker plus an `analyze_pipeline` hook that
`core.range_analysis.analyze` dispatches to.

The per-expression protocol methods still behave like the interval domain,
so code that feeds this domain to `eval_expr_abstract` directly (e.g. the
per-pixel abstract executor) degrades gracefully to interval semantics.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.absval import register_domain
from repro.core.interval import Interval

from repro.smt.optimize import SMTConfig, analyze_smt


class SMTDomain:
    name = "smt"
    whole_dag = True     # range_analysis.analyze dispatches to analyze_pipeline

    def __init__(self, config: Optional[SMTConfig] = None):
        self.config = config

    # -- whole-DAG entry point ----------------------------------------------
    def analyze_pipeline(self, pipeline,
                         input_ranges: Optional[Dict[str, Interval]] = None):
        return analyze_smt(pipeline, input_ranges=input_ranges,
                           config=self.config)

    # -- per-expression protocol (interval fallback) ------------------------
    def const(self, v: float) -> Interval:
        return Interval.point(v)

    def fresh_signal(self, rng: Interval) -> Interval:
        return rng

    def to_interval(self, v: Interval) -> Interval:
        return v


register_domain("smt", SMTDomain)
