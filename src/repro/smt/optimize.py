"""Dichotomic bound tightening — paper §V-B's search loop.

For every stage the paper binary-searches a threshold T, asking the SMT
solver "can this stage's value exceed T?"; UNSAT answers ratchet the bound
down.  `dichotomic_tighten` reproduces that loop on top of
`repro.smt.solver.decide` (or z3 when available):

  1. a certified initial pass (HC4 + affine relaxation on the full box)
     already beats the per-stage interval walk wherever correlations are
     linear — and is *exact* for linear DAGs, no queries needed;
  2. a dichotomic pass over power-of-two thresholds — exactly the
     bit-boundary precision alpha cares about;
  3. a few real-valued bisection steps inside the final bit for reporting
     tight ranges.

Only UNSAT verdicts tighten, so every bound stays a sound over-
approximation regardless of budget; SAT verdicts carry concrete witnesses
that floor the search.  `analyze_smt` runs this per stage in topological
order, feeding tightened ranges back in as cut-variable bounds for deeper
stages (compositional whole-DAG analysis).
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from typing import Dict, Optional

from repro import obs
from repro.core.graph import Pipeline
from repro.core.interval import Interval
from repro.core.range_analysis import StageRange, analyze

from repro.smt import solver as S
from repro.smt.encoder import (CSP, closure_is_sampled, encode_stage,
                               encode_stage_phases, sampling_lattice)

_INF = math.inf


class BudgetExhaustedWarning(RuntimeWarning):
    """A stage kept its interval seed because `time_budget_s` ran out.

    The stage's alpha is still *sound* (the seed is a valid over-
    approximation) but should not be read as the converged SMT answer —
    `benchmarks/alpha_delta.py` annotates such stages, and the
    `smt.budget_exhausted` obs event carries the same information in
    traces."""


@dataclasses.dataclass
class SMTConfig:
    """Budgets for the branch-and-prune emulation of the paper's solver.

    Two engines answer queries: ``"batched"`` (default) runs the whole
    branch-and-prune frontier as vectorized numpy rows — a node costs ~100x
    less than a scalar dict walk, which is why its default budgets are ~64x
    the scalar ones — and ``"scalar"`` is the original box-at-a-time
    reference oracle (kept for differential tests and debugging; it uses
    the pre-batching `scalar_*` budgets so equal-engine comparisons stay
    affordable).

    ``phase_split`` (default on) encodes stages whose producer closure
    crosses stride/upsample boundaries as one exactly-aligned CSP per
    output-phase residue (`encoder.encode_stage_phases`) and solves all
    phases as a single OR-composed query; the alignment-blind cut encoding
    remains the fallback when no uniform sampling lattice exists or the
    phase count exceeds ``max_phases``.
    """
    max_vars: int = 400         # flattening budget per stage CSP (then cuts)
    engine: str = "batched"     # "batched" | "scalar" (reference oracle)
    max_nodes: int = 4096       # batched: branch-and-prune boxes per query
    work_budget: int = 262144   # batched: ~boxes*vars per query — scales
                                # nodes down on large CSPs
    scalar_max_nodes: int = 64  # reference-oracle (pre-batching) budgets
    scalar_work_budget: int = 4096
    batch: int = 512            # boxes popped per batched solver iteration
    hc4_rounds: int = 6
    real_queries: int = 5       # real-valued bisection steps per side
    unknown_budget: int = 3     # UNKNOWN verdicts tolerated per side before
                                # the search settles for the current bound
    time_budget_s: float = 30.0  # per pipeline; overflow stages keep the seed
    use_z3: str = "auto"        # "auto" | "never" — optional z3 delegation
    phase_split: bool = True    # polyphase encoding across sampled stages
    max_phases: int = 16        # fall back to the blind encoding above this

    def decide_fn(self):
        return (S.decide_multi if self.engine == "batched"
                else S.decide_scalar_multi)

    def _nodes_for(self, nvars: int, scalar_scale: bool) -> int:
        mn, wb = ((self.scalar_max_nodes, self.scalar_work_budget)
                  if scalar_scale else (self.max_nodes, self.work_budget))
        return max(8, min(mn, wb // max(nvars, 1)))

    def quick_nodes(self, nvars: int) -> int:
        """Pre-batching (scalar-era) node budget — what one PR-1 query got;
        the batched engine's iterative-deepening quick pass uses this."""
        return self._nodes_for(nvars, scalar_scale=True)

    def bp_budget(self, nvars: int, deadline: float = _INF) -> S.BPBudget:
        nodes = self._nodes_for(nvars, scalar_scale=self.engine != "batched")
        return S.BPBudget(nodes, self.hc4_rounds, self.batch, deadline)


def _z3_decide_multi(entries, sense: str, t: float,
                     deadline: float = _INF) -> S.Verdict:
    """OR-compose z3 verdicts over the phase systems: any SAT is SAT, all
    UNSAT is UNSAT, anything else stays UNKNOWN (branch-and-prune decides).

    Each phase is a separate z3 call, so the anytime deadline is checked
    between phases — a slow multi-phase query degrades to UNKNOWN instead
    of multiplying z3's per-call timeout by the phase count."""
    from repro.smt import z3backend
    best = None
    n_unsat = 0
    for csp, root in entries:
        if time.monotonic() >= deadline:
            return S.Verdict(S.UNKNOWN, best)
        v = z3backend.decide(csp, root, sense, t)
        if v.status == S.SAT:
            return v
        if v.status == S.UNSAT:
            n_unsat += 1
        if v.witness is not None:
            best = (v.witness if best is None else
                    (max(best, v.witness) if sense == "ge"
                     else min(best, v.witness)))
    if n_unsat == len(entries):
        return S.Verdict(S.UNSAT, best)
    return S.Verdict(S.UNKNOWN, best)


def _decide(entries, sense: str, t: float,
            cfg: SMTConfig, deadline: float = _INF,
            escalate: bool = True) -> S.Verdict:
    if cfg.use_z3 != "never":
        from repro.smt import z3backend
        if z3backend.HAVE_Z3:
            v = _z3_decide_multi(entries, sense, t, deadline)
            if v.status != S.UNKNOWN:
                return v
    fn = cfg.decide_fn()
    nvars = max(csp.nvars for csp, _ in entries)
    full = cfg.bp_budget(nvars, deadline)
    if cfg.engine == "batched":
        # iterative deepening: most dichotomic queries resolve within the
        # pre-batching node budget (contraction alone certifies them), so
        # answer those at scalar-era cost and spend the 64x batched budget
        # only where the quick pass is UNKNOWN.  This keeps the *number*
        # of queries a stage completes per second no worse than the scalar
        # engine's while the hard boundary queries get the deep frontier.
        quick_nodes = cfg.quick_nodes(nvars)
        if full.max_nodes > quick_nodes:
            v = fn(entries, sense, t,
                   S.BPBudget(quick_nodes, cfg.hc4_rounds, cfg.batch,
                              deadline))
            now = time.monotonic()
            if v.status != S.UNKNOWN or not escalate or now >= deadline:
                return v
            # time-box the deep run: a failed escalation must not eat the
            # whole remaining slice (it returns a sound UNKNOWN at the cut)
            esc_deadline = (now + max(1.0, 0.25 * (deadline - now))
                            if math.isfinite(deadline) else deadline)
            deep = fn(entries, sense, t,
                      dataclasses.replace(full,
                                          deadline=min(deadline,
                                                       esc_deadline)))
            if deep.status != S.UNKNOWN:
                return deep
            if v.witness is not None and (
                    deep.witness is None or
                    (sense == "ge" and v.witness > deep.witness) or
                    (sense == "le" and v.witness < deep.witness)):
                return v
            return deep
    return fn(entries, sense, t, full)


def _pow2_thresholds(lo: float, hi: float) -> list:
    """Powers of two strictly inside (lo, hi) — the alpha bit boundaries."""
    out = []
    for k in range(-4, 64):
        for sgn in (1.0, -1.0):
            b = sgn * (2.0 ** k)
            if lo < b < hi:
                out.append(b)
    return sorted(set(out))


def _tighten_side(entries, iv: Interval, side: str,
                  cfg: SMTConfig, deadline: float,
                  escalate: bool = True) -> float:
    """Sound new bound for one side of `iv` (hi for "hi", lo for "lo").

    `entries` is the phase list `[(csp, root), ...]` (a single pair for the
    classic alignment-blind encoding); the bound covers the union of phases.
    """
    maximize = side == "hi"
    sense = "ge" if maximize else "le"
    bound = iv.hi if maximize else iv.lo
    if math.isinf(bound):
        return bound
    # floor of the search: best concrete value seen (always achievable)
    floor = iv.lo if maximize else iv.hi
    v0 = cfg.decide_fn()(entries, sense, bound,
                         S.BPBudget(max_nodes=len(entries),
                                    hc4_rounds=cfg.hc4_rounds))
    if v0.status == S.SAT:
        return bound            # the seed bound itself is attained
    if v0.witness is not None:
        floor = v0.witness

    unknowns = 0
    deep_strikes = 0

    def q(t: float) -> S.Verdict:
        # pass the deadline down so one over-budget query cannot overshoot
        # the stage's time slice (the batched engine is "anytime": it
        # returns a sound UNKNOWN at the cutoff).  Deep escalations that
        # come back UNKNOWN twice stop paying for themselves on this side:
        # fall back to quick-only queries (PR-1-era behavior) after that.
        nonlocal deep_strikes
        allow = escalate and deep_strikes < 2
        v = _decide(entries, sense, t, cfg, deadline, escalate=allow)
        if allow and v.status == S.UNKNOWN:
            deep_strikes += 1
        return v

    # -- dichotomic pass over bit boundaries --------------------------------
    bs = _pow2_thresholds(floor, bound) if maximize else \
        sorted(-b for b in _pow2_thresholds(-floor, -bound))
    lo_i, hi_i = 0, len(bs)      # candidate boundary window (unresolved)
    while lo_i < hi_i and time.monotonic() < deadline:
        mid = (lo_i + hi_i) // 2
        t = bs[mid] if maximize else bs[len(bs) - 1 - mid]
        r = q(t)
        if r.status == S.UNSAT:
            bound = math.nextafter(t, -_INF if maximize else _INF)
            hi_i = mid
        else:
            if r.status == S.SAT and r.witness is not None:
                floor = (max(floor, r.witness) if maximize
                         else min(floor, r.witness))
            elif r.status == S.UNKNOWN:
                unknowns += 1
                if unknowns >= cfg.unknown_budget:
                    return bound   # search is stalling; keep the sound bound
            lo_i = mid + 1
    # -- real-valued refinement inside the final bit ------------------------
    for _ in range(cfg.real_queries):
        if time.monotonic() >= deadline:
            break
        span = bound - floor if maximize else floor - bound
        if not math.isfinite(span) or span <= 1e-6 * max(1.0, abs(bound)):
            break
        t = 0.5 * (floor + bound)
        r = q(t)
        if r.status == S.UNSAT:
            bound = math.nextafter(t, -_INF if maximize else _INF)
        elif r.status == S.SAT and r.witness is not None:
            floor = (max(floor, r.witness, t) if maximize
                     else min(floor, r.witness, t))
        else:
            unknowns += 1
            if unknowns >= cfg.unknown_budget:
                break           # UNKNOWN: cannot resolve further, stay sound
            floor = t           # skip the unresolvable region, search higher
    return bound


def tighten_stage(csp: CSP, root: int, seed: Interval, cfg: SMTConfig,
                  deadline: float) -> Interval:
    """Tightened sound range for `root`, always a subset of `seed`."""
    return tighten_stage_phases([(csp, root)], seed, cfg, deadline)


def tighten_stage_phases(entries, seed: Interval, cfg: SMTConfig,
                         deadline: float) -> Interval:
    """Tightened sound union-of-phases range, always a subset of `seed`.

    `entries` holds one `(csp, root)` per output phase (a single entry is
    the classic whole-stage CSP).  Every phase's certified initial pass
    (HC4 + affine relaxation) runs first; when all phases are linear the
    union of their exact affine hulls is returned without any search,
    otherwise the dichotomic searches below query all phases as one
    OR-composed `decide_multi` problem under the shared budget/deadline.
    """
    # certified initial pass per phase: HC4 + affine relaxation on full box
    iv: Optional[Interval] = None
    all_linear = True
    for pi, (csp, root) in enumerate(entries):
        with obs.span("smt.phase", phase=pi, nvars=csp.nvars) as psp:
            box = list(csp.init)
            m = S._meet(box[root], seed)
            if m is None:
                psp.set(pruned=True)
                continue        # seed excludes this phase's root box entirely
            box[root] = m
            if not (S.hc4(csp, box, cfg.hc4_rounds)
                    and S.affine_sweep(csp, box) and S.hc4(csp, box, 2)):
                return seed     # should not happen (seed is sound); bail out
            iv = box[root] if iv is None else iv.join(box[root])
            all_linear &= csp.is_linear()
            psp.set(linear=csp.is_linear(),
                    hull=[box[root].lo, box[root].hi])
    if iv is None:
        return seed
    if all_linear:
        return iv               # affine hulls are exact: no search needed
    if cfg.engine != "batched":
        # scalar reference oracle: exact PR-1 semantics — each side may use
        # the full remaining deadline
        hi = _tighten_side(entries, iv, "hi", cfg, deadline)
        lo = _tighten_side(entries, iv, "lo", cfg, deadline)
        if lo > hi:             # numerical corner: fall back to the pass-1 hull
            return iv
        return Interval(lo, hi)
    # Phase 1 — quick-only dichotomic search (PR-1 semantics: every query
    # runs at the pre-batching node budget, so this phase costs what the
    # scalar engine cost and its bounds are never looser than PR-1's given
    # the same time).  The hi search runs first; split the time between
    # the sides so it cannot starve the lo search.
    now = time.monotonic()
    span = max(deadline - now, 0.0)
    hi = _tighten_side(entries, iv, "hi", cfg,
                       min(deadline, now + 0.35 * span), escalate=False)
    lo = _tighten_side(entries, iv, "lo", cfg,
                       min(deadline, now + 0.7 * span), escalate=False)
    if lo > hi:                 # numerical corner: fall back to the pass-1 hull
        return iv
    # Phase 2 — spend whatever time is left re-searching the (much smaller)
    # residual window with deep batched escalations; UNSAT-only updates, so
    # this can only tighten the phase-1 result.
    if time.monotonic() < deadline:
        iv2 = Interval(lo, hi)
        now = time.monotonic()
        hi = _tighten_side(entries, iv2, "hi", cfg,
                           min(deadline, now + 0.5 * (deadline - now)))
        lo = _tighten_side(entries, Interval(lo, hi), "lo", cfg, deadline)
        if lo > hi:
            return iv2
    return Interval(lo, hi)


def _certified_phase_hull(csp: CSP, root: int, bound: Interval,
                          cfg: SMTConfig) -> Interval:
    """Certified (search-free) sound hull of one phase's root variable.

    The phase's true range is contained in both its own contracted hull
    (HC4 + affine relaxation on the full box) and the stage's final union
    bound, so their meet is sound per phase.  All-linear cut-free phase
    CSPs make the hull exact (affine sweep = exact range hull).
    """
    box = list(csp.init)
    m = S._meet(box[root], bound)
    if m is None:
        return bound
    box[root] = m
    if not (S.hc4(csp, box, cfg.hc4_rounds) and S.affine_sweep(csp, box)
            and S.hc4(csp, box, 2)):
        return bound            # contraction emptied: keep the union bound
    m = S._meet(box[root], bound)
    return m if m is not None else bound


def analyze_smt(pipeline: Pipeline,
                input_ranges: Optional[Dict[str, Interval]] = None,
                config: Optional[SMTConfig] = None,
                collect_phases: Optional[Dict] = None,
                diagnostics: Optional[Dict] = None,
                ) -> Dict[str, StageRange]:
    """Whole-DAG range analysis — drop-in for `range_analysis.analyze` with
    `domain="smt"`, returning the same per-stage 3-tuples.

    Stages are tightened in topological order; each stage's CSP flattens its
    transitive producers into shared input-pixel/parameter variables, with
    already-tightened SMT ranges bounding budget/sampling cut points.  Every
    result is the meet of the tightening with the interval seed, so
    `smt ⊆ interval` holds per stage by construction.

    `collect_phases`, when a dict, is filled with per-phase certified
    sub-ranges for every phase-split stage:  ``{stage: ((My, Mx),
    {(ry, rx): Interval})}``.  Collection is read-only — the union bounds
    this function returns are identical with or without it; the sub-ranges
    feed `BitwidthPlan` phase columns (one datapath per lattice residue).

    `diagnostics`, when a dict, receives ``{"budget_exhausted": [stage,
    ...]}`` — the stages that kept their interval seed because
    `time_budget_s` ran out.  Each such stage also raises a
    `BudgetExhaustedWarning` and emits an `smt.budget_exhausted` obs
    event, so budget-starved alphas are never silently mistaken for
    converged ones.  When tracing is enabled every worked stage gets an
    `smt.stage` span (boxes explored, budget granted vs consumed,
    verdict, deadline-exhaustion flag) with `smt.phase` child spans.
    """
    cfg = config or SMTConfig()
    seed = analyze(pipeline, "interval", input_ranges=input_ranges)
    bounds: Dict[str, Interval] = {n: r.range for n, r in seed.items()}
    deadline = time.monotonic() + cfg.time_budget_s
    topo = pipeline.topo_order()
    work = {n for n in topo
            if not pipeline.stages[n].is_input and bounds[n].width > 0}
    n_left = len(work)
    out: Dict[str, StageRange] = {}
    exhausted = []
    asp = obs.span("smt.analyze", pipeline=pipeline.name, engine=cfg.engine,
                   time_budget_s=cfg.time_budget_s, stages=len(work))
    with asp:
        for name in topo:
            iv = bounds[name]
            seed_iv = iv
            phase_entries = None
            now = time.monotonic()
            stage_exhausted = False
            if name in work and now < deadline:
                # fair-share time slicing: with the batched engine's large
                # per-query budgets a single greedy stage could otherwise eat
                # the whole pipeline budget and leave deep stages (where the
                # whole-DAG analysis wins most) with their interval seeds.
                # Each stage may use up to 2x its equal share of the remaining
                # time; unused time rolls over to later stages.
                slice_s = 2.0 * (deadline - now) / max(n_left, 1)
                stage_deadline = min(deadline, now + max(slice_s, 0.5))
                ssp = obs.span("smt.stage", stage=name,
                               budget_s=stage_deadline - now)
                with ssp:
                    t_stage = time.perf_counter()
                    boxes0 = S.STATS["boxes"]
                    entries = None
                    if cfg.phase_split and closure_is_sampled(pipeline, name):
                        # phase-split: exactly-aligned expansion per
                        # output-phase residue; None = no uniform lattice /
                        # too many phases — fall back to the alignment-blind
                        # cut encoding below
                        entries = encode_stage_phases(
                            pipeline, name, bounds,
                            input_ranges=input_ranges,
                            max_vars=cfg.max_vars, max_phases=cfg.max_phases)
                    if entries is None:
                        entries = [encode_stage(pipeline, name, bounds,
                                                input_ranges=input_ranges,
                                                max_vars=cfg.max_vars)]
                    elif not all(c.is_linear() and "cut" not in c.kinds
                                 for c, _ in entries):
                        # nonlinear (or budget-cut) phases need search, and
                        # the exact expansions are much larger CSPs than the
                        # blind cut encoding — a fixed slice can leave them
                        # UNKNOWN where the small blind system converges.
                        # Run the blind search on half the slice first and
                        # seed the phase pass with its result: the
                        # phase-split bound is then never looser than the
                        # alignment-blind one by construction.  (All-linear
                        # cut-free phases skip this: their union hull is
                        # exact.)
                        b_csp, b_root = encode_stage(
                            pipeline, name, bounds,
                            input_ranges=input_ranges, max_vars=cfg.max_vars)
                        now = time.monotonic()
                        b_deadline = min(stage_deadline,
                                         now + 0.5 * (stage_deadline - now))
                        biv = tighten_stage_phases([(b_csp, b_root)], iv,
                                                   cfg, b_deadline)
                        m = S._meet(iv, biv)
                        iv = m if m is not None else iv
                    tiv = tighten_stage_phases(entries, iv, cfg,
                                               stage_deadline)
                    m = S._meet(iv, tiv)
                    iv = m if m is not None else iv
                    if len(entries) > 1:
                        phase_entries = entries
                    unchanged = (iv.lo == seed_iv.lo and iv.hi == seed_iv.hi)
                    stage_exhausted = (unchanged and
                                       time.monotonic() >= stage_deadline)
                    ssp.set(nvars=max(c.nvars for c, _ in entries),
                            phases=len(entries),
                            boxes=S.STATS["boxes"] - boxes0,
                            consumed_s=time.perf_counter() - t_stage,
                            verdict="seed" if unchanged else "tightened",
                            range=[iv.lo, iv.hi],
                            deadline_exhausted=stage_exhausted)
            elif name in work:
                # the pipeline budget ran out before this stage even started
                stage_exhausted = True
            if stage_exhausted:
                exhausted.append(name)
                obs.event("smt.budget_exhausted", stage=name,
                          time_budget_s=cfg.time_budget_s)
                warnings.warn(
                    f"SMT stage {name!r} kept its interval seed: "
                    f"time_budget_s={cfg.time_budget_s:g} exhausted",
                    BudgetExhaustedWarning, stacklevel=2)
            if name in work:
                n_left -= 1
            bounds[name] = iv
            out[name] = StageRange.from_interval(iv)
            if collect_phases is not None and phase_entries is not None:
                lat = sampling_lattice(pipeline, name)
                if lat is not None:
                    my, mx = lat
                    residues = [(ry, rx)
                                for ry in range(my) for rx in range(mx)]
                    collect_phases[name] = (lat, {
                        res: _certified_phase_hull(csp, root, iv, cfg)
                        for res, (csp, root) in zip(residues, phase_entries)})
        asp.set(budget_exhausted=list(exhausted))
    if diagnostics is not None:
        diagnostics["budget_exhausted"] = list(exhausted)
    return out


def alpha_table_smt(pipeline: Pipeline, **kw) -> Dict[str, int]:
    """Stage -> alpha under the SMT analysis (Table II right-column twin)."""
    return {k: v.alpha for k, v in analyze_smt(pipeline, **kw).items()}
