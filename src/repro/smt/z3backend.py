"""Optional z3 delegation for `repro.smt` satisfiability queries.

The subsystem is dependency-free by design: the branch-and-prune core in
`solver.py` answers every query on its own.  When the `z3-solver` extra is
importable (see requirements-dev.txt), `decide` here encodes the CSP into
nonlinear real arithmetic and lets z3 answer first — exactly the paper's
setup (§V-B) — with the branch-and-prune core as fallback on UNKNOWN /
timeout.  Nothing in this module may be imported unconditionally elsewhere;
gate on `HAVE_Z3`.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.smt.encoder import CONST, CSP, VAR
from repro.smt.solver import SAT, UNKNOWN, UNSAT, Verdict

try:
    import z3  # type: ignore

    HAVE_Z3 = True
except ImportError:           # pragma: no cover - exercised when extra present
    z3 = None
    HAVE_Z3 = False

_CMP = {
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
}


def _encode(csp: CSP, slv) -> list:      # pragma: no cover - needs z3
    xs = [z3.Real(f"v{i}") for i in range(csp.nvars)]

    def term(o):
        return xs[int(o[1])] if o[0] == VAR else z3.RealVal(o[1])

    for i, iv in enumerate(csp.init):
        if not math.isinf(iv.lo):
            slv.add(xs[i] >= iv.lo)
        if not math.isinf(iv.hi):
            slv.add(xs[i] <= iv.hi)
    for i, d in enumerate(csp.defs):
        if d is None:
            continue
        a = term(d.args[0])
        if d.op == "pow":
            e = a
            for _ in range(d.n - 1):
                e = e * a
            slv.add(xs[i] == (z3.RealVal(1) if d.n == 0 else e))
        elif d.op == "abs":
            slv.add(xs[i] == z3.If(a >= 0, a, -a))
        elif d.op == "sqrt":
            slv.add(xs[i] >= 0, xs[i] * xs[i] == z3.If(a >= 0, a, 0))
        else:
            b = term(d.args[1])
            if d.op == "+":
                slv.add(xs[i] == a + b)
            elif d.op == "-":
                slv.add(xs[i] == a - b)
            elif d.op == "*":
                slv.add(xs[i] == a * b)
            elif d.op == "/":
                # guarded: when the divisor box straddles zero the interval
                # seed is [-inf, inf] anyway; only the bound constraints apply
                slv.add(z3.Implies(b != 0, xs[i] * b == a))
            elif d.op == "min":
                slv.add(xs[i] == z3.If(a <= b, a, b))
            elif d.op == "max":
                slv.add(xs[i] == z3.If(a >= b, a, b))
            elif d.op == "select":
                t, o = term(d.args[2]), term(d.args[3])
                slv.add(xs[i] == z3.If(_CMP[d.cmp](a, b), t, o))
    return xs


def decide(csp: CSP, root: int, sense: str, threshold: float,
           timeout_ms: int = 2000) -> Verdict:
    """z3 verdict for `root >= T` ("ge") / `root <= T` ("le"), UNKNOWN when
    z3 is unavailable or times out (callers then fall back to B&P)."""
    if not HAVE_Z3:
        return Verdict(UNKNOWN)
    slv = z3.Solver()                        # pragma: no cover - needs z3
    slv.set("timeout", timeout_ms)
    xs = _encode(csp, slv)
    q = (xs[root] >= threshold) if sense == "ge" else (xs[root] <= threshold)
    slv.add(q)
    res = slv.check()
    if res == z3.unsat:
        return Verdict(UNSAT)
    if res == z3.sat:
        w: Optional[float] = None
        try:
            mv = slv.model()[xs[root]]
            w = float(mv.as_fraction()) if mv is not None else None
        except Exception:
            w = None
        return Verdict(SAT, w)
    return Verdict(UNKNOWN)
