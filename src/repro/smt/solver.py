"""Branch-and-prune satisfiability core — the back half of `repro.smt`.

Answers the paper's §V-B queries — "can stage `s` exceed threshold T?" —
over the CSP produced by `repro.smt.encoder`, without any external solver:

  * **HC4 contraction**: forward interval evaluation of every defining
    constraint, then backward projection (inverse transfer functions) from
    the queried bound onto the free variables, iterated to a fixpoint;
  * **affine relaxation**: one affine-arithmetic sweep with a noise symbol
    per free variable, so linear cancellation (``img - blur(img)``) is
    exact; products of *colinear* deviations keep the signed quadratic
    term, which is what certifies e.g. HCD's ``Ix*Iy <= (3*255/12)^2``;
  * **monotonicity fixing**: interval-gradient (reverse-mode AD over the
    DAG) pins free variables whose derivative sign is constant to the
    bound that maximizes the query — equi-satisfiable, collapses most
    dimensions;
  * **branch-and-prune**: when contraction stalls, split a variable
    (sign-splits of zero-straddling multiplication operands first, then
    largest smear) and recurse under a node budget.

Verdicts are three-valued: UNSAT is a *certificate* (every box refuted),
SAT carries a concrete witness value, UNKNOWN means budget exhausted —
`optimize.dichotomic_tighten` only tightens bounds on UNSAT, so the
analysis stays sound whatever the budget.

When `z3-solver` is importable (optional extra, see requirements-dev.txt)
queries can be delegated to it first — `repro.smt.z3backend`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.affine import AffineForm
from repro.core.interval import Interval

from repro.smt.encoder import CONST, CSP, Def, VAR

UNSAT, SAT, UNKNOWN = "unsat", "sat", "unknown"

_INF = math.inf
_WIDTH_EPS = 1e-7      # below this a variable is no longer split
_MEET_SLACK = 1e-9     # relative slack absorbing float round-off in meets

Box = List[Interval]


@dataclasses.dataclass
class Verdict:
    status: str                      # UNSAT | SAT | UNKNOWN
    witness: Optional[float] = None  # concrete objective value (SAT / best)


# ---------------------------------------------------------------------------
# interval plumbing
# ---------------------------------------------------------------------------

def _meet(a: Interval, b: Interval) -> Optional[Interval]:
    """Intersection; None = empty.  Near-misses within float slack collapse
    to the touching point instead of reporting empty."""
    lo = max(a.lo, b.lo)
    hi = min(a.hi, b.hi)
    if lo > hi:
        if lo - hi <= _MEET_SLACK * max(1.0, abs(lo), abs(hi)):
            mid = 0.5 * (lo + hi)
            return Interval(mid, mid)
        return None
    return Interval(lo, hi)


def _val(box: Box, o) -> Interval:
    return box[int(o[1])] if o[0] == VAR else Interval.point(o[1])


def _cmp_decide(op: str, l: Interval, r: Interval) -> Optional[bool]:
    """Decide `l op r` under the box, or None when undetermined."""
    if op == "<":
        if l.hi < r.lo:
            return True
        if l.lo >= r.hi:
            return False
    elif op == "<=":
        if l.hi <= r.lo:
            return True
        if l.lo > r.hi:
            return False
    elif op == ">":
        if l.lo > r.hi:
            return True
        if l.hi <= r.lo:
            return False
    elif op == ">=":
        if l.lo >= r.hi:
            return True
        if l.hi < r.lo:
            return False
    return None


def _forward_op(d: Def, box: Box) -> Interval:
    a = _val(box, d.args[0])
    if d.op == "pow":
        return a ** d.n
    if d.op == "abs":
        return a.abs()
    if d.op == "sqrt":
        return a.sqrt()
    b = _val(box, d.args[1])
    if d.op == "+":
        return a + b
    if d.op == "-":
        return a - b
    if d.op == "*":
        return a * b
    if d.op == "/":
        return a / b
    if d.op == "min":
        return a.min_(b)
    if d.op == "max":
        return a.max_(b)
    if d.op == "select":
        t = _val(box, d.args[2])
        o = _val(box, d.args[3])
        dec = _cmp_decide(d.cmp, a, b)
        if dec is True:
            return t
        if dec is False:
            return o
        return t.join(o)
    raise ValueError(f"unknown op {d.op}")


def _ext_div(v: Interval, b: Interval) -> Interval:
    """Hull of the Kahan extended division v / b (for backward mul)."""
    if b.lo > 0 or b.hi < 0:
        return v / b
    if b.lo == 0.0 and b.hi > 0:
        if v.lo > 0:
            return Interval(v.lo / b.hi, _INF)
        if v.hi < 0:
            return Interval(-_INF, v.hi / b.hi)
    elif b.hi == 0.0 and b.lo < 0:
        if v.lo > 0:
            return Interval(-_INF, v.lo / b.lo)
        if v.hi < 0:
            return Interval(v.hi / b.lo, _INF)
    return Interval.top()


def _root_n(x: float, n: int) -> float:
    if x <= 0:
        return 0.0
    return x ** (1.0 / n)


_INFEASIBLE = object()   # backward projection proved the box empty


def _backward_op(d: Def, v: Interval, box: Box) -> List:
    """Inverse projections: contracted intervals for each *var* operand
    (None = no contraction, _INFEASIBLE = box refuted).  Caller meets
    Interval results into the box."""
    out: List = [None] * len(d.args)
    a = _val(box, d.args[0])
    if d.op == "pow":
        n = d.n
        if n % 2 == 1:
            lo = math.copysign(_root_n(abs(v.lo), n), v.lo)
            hi = math.copysign(_root_n(abs(v.hi), n), v.hi)
            out[0] = Interval(min(lo, hi), max(lo, hi))
        elif n > 0:
            r = _root_n(max(v.hi, 0.0), n)
            if a.lo >= 0:
                out[0] = Interval(_root_n(max(v.lo, 0.0), n), r)
            elif a.hi <= 0:
                out[0] = Interval(-r, -_root_n(max(v.lo, 0.0), n))
            else:
                out[0] = Interval(-r, r)
        return out
    if d.op == "abs":
        if a.lo >= 0:
            out[0] = Interval(max(v.lo, 0.0), v.hi)
        elif a.hi <= 0:
            out[0] = Interval(-v.hi, -max(v.lo, 0.0))
        else:
            out[0] = Interval(-v.hi, v.hi)
        return out
    if d.op == "sqrt":
        # v = sqrt(max(a, 0)): a <= v.hi^2 always; a >= v.lo^2 only if v.lo>0
        hi2 = v.hi * v.hi
        lo2 = v.lo * v.lo if v.lo > 0 else -_INF
        out[0] = Interval(lo2, hi2)
        return out
    b = _val(box, d.args[1])
    if d.op == "+":
        out[0] = v - b
        out[1] = v - a
    elif d.op == "-":
        out[0] = v + b
        out[1] = a - v
    elif d.op == "*":
        out[0] = _ext_div(v, b)
        out[1] = _ext_div(v, a)
    elif d.op == "/":
        out[0] = v * b
        out[1] = _ext_div(a, v)
    elif d.op == "min":
        # both operands >= v.lo; an operand must also be <= v.hi when the
        # other provably cannot supply the minimum
        for slot, (x, y) in enumerate(((a, b), (b, a))):
            lo = v.lo
            hi = x.hi if y.lo <= v.hi else min(x.hi, v.hi)
            out[slot] = _INFEASIBLE if lo > hi else Interval(lo, hi)
    elif d.op == "max":
        for slot, (x, y) in enumerate(((a, b), (b, a))):
            hi = v.hi
            lo = x.lo if y.hi >= v.lo else max(x.lo, v.lo)
            out[slot] = _INFEASIBLE if lo > hi else Interval(lo, hi)
    elif d.op == "select":
        dec = _cmp_decide(d.cmp, a, b)
        if dec is True:
            out[2] = v
        elif dec is False:
            out[3] = v
    return out


def hc4(csp: CSP, box: Box, rounds: int = 6) -> bool:
    """Forward/backward contraction to (approximate) fixpoint.

    Returns False when the box is proven empty (constraint refuted)."""
    n = csp.nvars
    for _ in range(rounds):
        changed = False
        for i in range(n):           # forward (operand ids < def id)
            d = csp.defs[i]
            if d is None:
                continue
            m = _meet(box[i], _forward_op(d, box))
            if m is None:
                return False
            if m is not box[i] and (m.lo != box[i].lo or m.hi != box[i].hi):
                box[i] = m
                changed = True
        for i in range(n - 1, -1, -1):  # backward
            d = csp.defs[i]
            if d is None:
                continue
            for slot, niv in enumerate(_backward_op(d, box[i], box)):
                if niv is None:
                    continue
                if niv is _INFEASIBLE:
                    return False     # holds even when the slot is a const
                tag, val = d.args[slot]
                if tag != VAR:
                    continue
                j = int(val)
                m = _meet(box[j], niv)
                if m is None:
                    return False
                if m.lo != box[j].lo or m.hi != box[j].hi:
                    box[j] = m
                    changed = True
        if not changed:
            break
    return True


# ---------------------------------------------------------------------------
# affine relaxation sweep
# ---------------------------------------------------------------------------

def _colinear_ratio(a: Dict[int, float], b: Dict[int, float]) -> Optional[float]:
    """r with b == r*a (same symbol support), else None."""
    if not a or len(a) != len(b):
        return None
    r = None
    for k, av in a.items():
        bv = b.get(k)
        if bv is None or av == 0.0:
            return None
        rk = bv / av
        if r is None:
            r = rk
        elif not math.isclose(rk, r, rel_tol=1e-12, abs_tol=1e-300):
            return None
    return r


def _aff_mul(x: AffineForm, y: AffineForm) -> AffineForm:
    """Affine product keeping the signed quadratic term when the deviation
    vectors are colinear: dev_y = r*dev_x  =>  dev_x*dev_y = r*dev_x^2 in
    r*[0, rad_x^2] — exact, instead of the symmetric ±rad_x*rad_y blob.

    This single refinement is what proves Cauchy–Schwarz-flavored facts like
    HCD's `Ix*Iy` bound, where interval and plain affine both give ±85²."""
    r = _colinear_ratio(x.terms, y.terms)
    if r is None or not x.terms:
        return x * y
    rad2 = x.radius ** 2
    qlo, qhi = (r * 0.0, r * rad2) if r >= 0 else (r * rad2, 0.0)
    # x*y = x0*y0 + x0*dev_y + y0*dev_x + r*dev_x^2
    out = AffineForm(x.x0 * y.x0 + 0.5 * (qlo + qhi))
    terms: Dict[int, float] = {}
    for k, c in x.terms.items():
        terms[k] = y.x0 * c + x.x0 * y.terms[k]
    out.terms.update({k: c for k, c in terms.items() if c != 0.0})
    err = 0.5 * (qhi - qlo)
    if err > 0.0:
        from repro.core.affine import _fresh
        out.terms[_fresh()] = err
    return out


def affine_sweep(csp: CSP, box: Box) -> bool:
    """One affine evaluation of the DAG, meeting each var's affine hull into
    the box.  Returns False on empty.

    Base var `i` gets noise symbol `-(i+1)`: negative ids cannot collide
    with the non-negative ids AffineForm's `_fresh()` mints for
    linearization-error terms (aliasing them would fabricate correlations)."""
    forms: List[Optional[AffineForm]] = [None] * csp.nvars

    def form_of(o) -> AffineForm:
        if o[0] == CONST:
            return AffineForm.point(o[1])
        return forms[int(o[1])]

    for i in range(csp.nvars):
        d = csp.defs[i]
        if d is None:
            iv = box[i]
            if math.isinf(iv.lo) or math.isinf(iv.hi):
                forms[i] = AffineForm.from_interval(iv.lo, iv.hi)
            else:
                mid, rad = 0.5 * (iv.lo + iv.hi), 0.5 * (iv.hi - iv.lo)
                forms[i] = AffineForm(mid, {-(i + 1): rad} if rad else {})
            continue
        a = form_of(d.args[0])
        if d.op == "pow":
            f = a ** d.n
        elif d.op == "abs":
            f = a.abs()
        elif d.op == "sqrt":
            f = a.sqrt()
        else:
            b = form_of(d.args[1])
            if d.op == "+":
                f = a + b
            elif d.op == "-":
                f = a - b
            elif d.op == "*":
                f = _aff_mul(a, b)
            elif d.op == "/":
                f = a / b
            elif d.op == "min":
                f = a.min_(b)
            elif d.op == "max":
                f = a.max_(b)
            elif d.op == "select":
                dec = _cmp_decide(d.cmp, a.to_interval(), b.to_interval())
                t, o = form_of(d.args[2]), form_of(d.args[3])
                if dec is True:
                    f = t
                elif dec is False:
                    f = o
                else:
                    iv = t.to_interval().join(o.to_interval())
                    f = AffineForm.from_interval(iv.lo, iv.hi)
            else:
                raise ValueError(d.op)
        # meet the hull into the box, but keep the *form* intact: its
        # correlations are its value (rebuilding from the clamped box would
        # destroy exactly the colinearity the refined product exploits)
        m = _meet(box[i], f.to_interval())
        if m is None:
            return False
        box[i] = m
        forms[i] = f
    return True


# ---------------------------------------------------------------------------
# interval gradients (reverse mode) + monotonicity fixing
# ---------------------------------------------------------------------------

_ZERO = Interval.point(0.0)
_UNIT = Interval(0.0, 1.0)


def gradients(csp: CSP, box: Box, root: int) -> List[Interval]:
    """adjoint[i] ⊇ d(root)/d(var i) over the box (reverse-mode interval AD).

    Select conditions contribute TOP to their operands (jump discontinuity);
    callers must not monotonicity-fix variables feeding a condition."""
    adj: List[Interval] = [_ZERO] * csp.nvars
    adj[root] = Interval.point(1.0)
    for i in range(csp.nvars - 1, -1, -1):
        d = csp.defs[i]
        g = adj[i]
        if d is None or (g.lo == 0.0 and g.hi == 0.0):
            continue
        a = _val(box, d.args[0])
        if d.op == "pow":
            if d.n == 0:
                parts = [_ZERO]      # d(x^0)/dx = 0 (x**-1 would raise)
            else:
                parts = [Interval.point(float(d.n)) * a ** (d.n - 1)]
        elif d.op == "abs":
            if a.lo >= 0:
                parts = [Interval.point(1.0)]
            elif a.hi <= 0:
                parts = [Interval.point(-1.0)]
            else:
                parts = [Interval(-1.0, 1.0)]
        elif d.op == "sqrt":
            if a.lo > 0:
                parts = [Interval(0.5 / math.sqrt(a.hi), 0.5 / math.sqrt(a.lo))]
            else:
                parts = [Interval(0.0, _INF)]
        else:
            b = _val(box, d.args[1])
            if d.op == "+":
                parts = [Interval.point(1.0), Interval.point(1.0)]
            elif d.op == "-":
                parts = [Interval.point(1.0), Interval.point(-1.0)]
            elif d.op == "*":
                parts = [b, a]
            elif d.op == "/":
                if b.lo > 0 or b.hi < 0:
                    inv = Interval(1.0, 1.0) / b
                    parts = [inv, -a * (inv ** 2)]
                else:
                    parts = [Interval.top(), Interval.top()]
            elif d.op in ("min", "max"):
                parts = [_UNIT, _UNIT]
            elif d.op == "select":
                dec = _cmp_decide(d.cmp, a, b)
                if dec is True:
                    parts = [_ZERO, _ZERO, Interval.point(1.0), _ZERO]
                elif dec is False:
                    parts = [_ZERO, _ZERO, _ZERO, Interval.point(1.0)]
                else:
                    parts = [Interval.top(), Interval.top(), _UNIT, _UNIT]
            else:
                raise ValueError(d.op)
        for slot, p in enumerate(parts):
            tag, val = d.args[slot]
            if tag == VAR:
                j = int(val)
                adj[j] = adj[j] + g * p
    return adj


def _monotone_fix(csp: CSP, box: Box, root: int, maximize: bool,
                  frozen: set) -> bool:
    """Pin base vars with constant derivative sign to the objective-optimal
    bound.  Equi-satisfiable for a `root >= T` (maximize) / `root <= T`
    (minimize) query, since the only non-box constraint is on the root.
    Returns True when anything was fixed."""
    adj = gradients(csp, box, root)
    fixed = False
    for i in csp.base_vars():
        if i in frozen or box[i].width <= 0:
            continue
        g = adj[i]
        if g.lo >= 0:
            v = box[i].hi if maximize else box[i].lo
        elif g.hi <= 0:
            v = box[i].lo if maximize else box[i].hi
        else:
            continue
        if math.isinf(v):
            continue
        box[i] = Interval.point(v)
        fixed = True
    return fixed


# ---------------------------------------------------------------------------
# concrete evaluation (witness extraction)
# ---------------------------------------------------------------------------

def concrete_eval(csp: CSP, point: Dict[int, float]) -> List[float]:
    vals = [0.0] * csp.nvars

    def v(o) -> float:
        return vals[int(o[1])] if o[0] == VAR else float(o[1])

    for i in range(csp.nvars):
        d = csp.defs[i]
        if d is None:
            vals[i] = point[i]
            continue
        a = v(d.args[0])
        if d.op == "pow":
            vals[i] = a ** d.n
        elif d.op == "abs":
            vals[i] = abs(a)
        elif d.op == "sqrt":
            vals[i] = math.sqrt(max(a, 0.0))
        else:
            b = v(d.args[1])
            if d.op == "+":
                vals[i] = a + b
            elif d.op == "-":
                vals[i] = a - b
            elif d.op == "*":
                vals[i] = a * b
            elif d.op == "/":
                vals[i] = a / b if b != 0 else math.copysign(_INF, a)
            elif d.op == "min":
                vals[i] = min(a, b)
            elif d.op == "max":
                vals[i] = max(a, b)
            elif d.op == "select":
                ok = {"<": a < b, "<=": a <= b, ">": a > b, ">=": a >= b}[d.cmp]
                vals[i] = v(d.args[2]) if ok else v(d.args[3])
    return vals


def _mid(iv: Interval) -> float:
    if math.isinf(iv.lo) and math.isinf(iv.hi):
        return 0.0
    if math.isinf(iv.lo):
        return iv.hi
    if math.isinf(iv.hi):
        return iv.lo
    return 0.5 * (iv.lo + iv.hi)


def _witness_points(csp: CSP, box: Box, root: int,
                    maximize: bool) -> List[Dict[int, float]]:
    base = csp.base_vars()
    mid = {i: _mid(box[i]) for i in base}
    pts = [mid]
    adj = gradients(csp, box, root)
    corner = {}
    for i in base:
        g = adj[i]
        if g.lo >= 0:
            corner[i] = box[i].hi if maximize else box[i].lo
        elif g.hi <= 0:
            corner[i] = box[i].lo if maximize else box[i].hi
        else:
            corner[i] = mid[i]
        if math.isinf(corner[i]):
            corner[i] = mid[i]
    pts.append(corner)
    for pick in (lambda iv: iv.lo, lambda iv: iv.hi):
        p = {i: pick(box[i]) for i in base}
        if all(not math.isinf(v) for v in p.values()):
            pts.append(p)
    return pts


# ---------------------------------------------------------------------------
# branch and prune
# ---------------------------------------------------------------------------

def _split_candidates(csp: CSP, box: Box, adj: List[Interval]
                      ) -> List[Tuple[int, float]]:
    """(var, split_point) candidates, best first.

    Sign-splits of zero-straddling `*` / `/` / even-`pow` operands come
    first (closest to the root first): they unlock both the extended-
    division backward rule and the colinear affine product.  Select
    conditions against a constant split at the threshold.  Base vars use
    the smear heuristic (width x |gradient|)."""
    out: List[Tuple[int, float]] = []
    seen = set()
    for i in range(csp.nvars - 1, -1, -1):
        d = csp.defs[i]
        if d is None:
            continue
        cand = []
        if d.op in ("*", "/"):
            cand = [d.args[0], d.args[1]]
        elif d.op == "pow" and d.n % 2 == 0:
            cand = [d.args[0]]
        elif d.op == "select":
            for a, b in ((d.args[0], d.args[1]), (d.args[1], d.args[0])):
                if a[0] == VAR and b[0] == CONST:
                    j = int(a[1])
                    iv = box[j]
                    if (j not in seen and iv.lo < b[1] < iv.hi
                            and iv.width > _WIDTH_EPS):
                        seen.add(j)
                        out.append((j, float(b[1])))
        for o in cand:
            if o[0] != VAR:
                continue
            j = int(o[1])
            iv = box[j]
            if j in seen or not (iv.lo < 0.0 < iv.hi):
                continue
            if iv.width <= _WIDTH_EPS:
                continue
            seen.add(j)
            out.append((j, 0.0))
    scored = []
    for i in csp.base_vars():
        iv = box[i]
        w = iv.width
        if i in seen or w <= _WIDTH_EPS or math.isinf(w):
            continue
        g = adj[i]
        mag = max(abs(g.lo), abs(g.hi))
        if math.isinf(mag):
            mag = 1e18
        scored.append((w * max(mag, 1e-18), i, _mid(iv)))
    scored.sort(reverse=True)
    out.extend((i, m) for _, i, m in scored)
    return out


@dataclasses.dataclass
class BPBudget:
    max_nodes: int = 48
    hc4_rounds: int = 6


def decide(csp: CSP, root: int, sense: str, threshold: float,
           budget: Optional[BPBudget] = None) -> Verdict:
    """Decide satisfiability of `root >= T` (sense "ge") or `root <= T`
    ("le") subject to the CSP's defining constraints and box.

    UNSAT is certified (all boxes refuted by contraction / relaxation);
    SAT carries a concrete witness objective value; UNKNOWN = budget out.
    """
    bud = budget or BPBudget()
    maximize = sense == "ge"
    query = (Interval(threshold, _INF) if maximize
             else Interval(-_INF, threshold))
    box0 = list(csp.init)
    m = _meet(box0[root], query)
    if m is None:
        return Verdict(UNSAT)
    box0[root] = m
    frozen = csp.cond_dependent_vars()

    best: Optional[float] = None
    stack: List[Box] = [box0]
    nodes = 0
    while stack:
        nodes += 1
        if nodes > bud.max_nodes:
            return Verdict(UNKNOWN, best)
        box = stack.pop()
        if not hc4(csp, box, bud.hc4_rounds):
            continue
        if not affine_sweep(csp, box):
            continue
        if not hc4(csp, box, 2):
            continue
        sat_v, best = _check_witness(csp, box, root, maximize, threshold, best)
        if sat_v is not None:
            return Verdict(SAT, sat_v)
        if _monotone_fix(csp, box, root, maximize, frozen):
            if not (hc4(csp, box, bud.hc4_rounds) and affine_sweep(csp, box)):
                continue
            sat_v, best = _check_witness(csp, box, root, maximize, threshold,
                                         best)
            if sat_v is not None:
                return Verdict(SAT, sat_v)
        adj = gradients(csp, box, root)
        cands = _split_candidates(csp, box, adj)
        if not cands:
            return Verdict(UNKNOWN, best)   # box irreducible yet not refuted
        j, at = cands[0]
        iv = box[j]
        if not (iv.lo < at < iv.hi):
            at = _mid(iv)
            if not (iv.lo < at < iv.hi):
                return Verdict(UNKNOWN, best)
        left, right = list(box), list(box)
        left[j] = Interval(iv.lo, at)
        right[j] = Interval(at, iv.hi)
        stack.append(left)
        stack.append(right)
    return Verdict(UNSAT, best)


def _check_witness(csp, box, root, maximize, threshold, best):
    for pt in _witness_points(csp, box, root, maximize):
        val = concrete_eval(csp, pt)[root]
        if math.isnan(val) or math.isinf(val):
            continue
        if best is None or (val > best if maximize else val < best):
            best = val
        if (val >= threshold) if maximize else (val <= threshold):
            return val, best
    return None, best
