"""Branch-and-prune satisfiability core — the back half of `repro.smt`.

Answers the paper's §V-B queries — "can stage `s` exceed threshold T?" —
over the CSP produced by `repro.smt.encoder`, without any external solver:

  * **HC4 contraction**: forward interval evaluation of every defining
    constraint, then backward projection (inverse transfer functions) from
    the queried bound onto the free variables, iterated to a fixpoint;
  * **affine relaxation**: one affine-arithmetic sweep with a noise symbol
    per free variable, so linear cancellation (``img - blur(img)``) is
    exact; products of *colinear* deviations keep the signed quadratic
    term, which is what certifies e.g. HCD's ``Ix*Iy <= (3*255/12)^2``;
  * **monotonicity fixing**: interval-gradient (reverse-mode AD over the
    DAG) pins free variables whose derivative sign is constant to the
    bound that maximizes the query — equi-satisfiable, collapses most
    dimensions;
  * **branch-and-prune**: when contraction stalls, split a variable
    (sign-splits of zero-straddling multiplication operands first, then
    largest smear) and recurse under a node budget.

Verdicts are three-valued: UNSAT is a *certificate* (every box refuted),
SAT carries a concrete witness value, UNKNOWN means budget exhausted —
`optimize.dichotomic_tighten` only tightens bounds on UNSAT, so the
analysis stays sound whatever the budget.

When `z3-solver` is importable (optional extra, see requirements-dev.txt)
queries can be delegated to it first — `repro.smt.z3backend`.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.affine import AffineForm
from repro.core.interval import Interval

from repro.smt.encoder import (CONST, CSP, Def, Program, VAR, compile_csp,
                               OP_ABS, OP_ADD, OP_DIV, OP_MAX, OP_MIN,
                               OP_MUL, OP_POW, OP_SELECT, OP_SQRT, OP_SUB)

UNSAT, SAT, UNKNOWN = "unsat", "sat", "unknown"

_INF = math.inf
_WIDTH_EPS = 1e-7      # below this a variable is no longer split
_MEET_SLACK = 1e-9     # relative slack absorbing float round-off in meets

Box = List[Interval]

# rolling throughput counters (benchmarks/run.py --only smt_throughput reads
# these to report solver boxes/sec); a registered obs counter group, so
# mutation is locked and `STATS.reset()` restores the zeros between
# `analyze_smt` runs — still a plain dict to every reader, never used for
# solver logic
STATS = obs.CounterGroup("smt.solver", boxes=0, secs=0.0)


@dataclasses.dataclass
class Verdict:
    status: str                      # UNSAT | SAT | UNKNOWN
    witness: Optional[float] = None  # concrete objective value (SAT / best)
    nodes: int = 0                   # boxes processed answering the query


# ---------------------------------------------------------------------------
# interval plumbing
# ---------------------------------------------------------------------------

def _meet(a: Interval, b: Interval) -> Optional[Interval]:
    """Intersection; None = empty.  Near-misses within float slack collapse
    to the touching point instead of reporting empty."""
    lo = max(a.lo, b.lo)
    hi = min(a.hi, b.hi)
    if lo > hi:
        if lo - hi <= _MEET_SLACK * max(1.0, abs(lo), abs(hi)):
            mid = 0.5 * (lo + hi)
            return Interval(mid, mid)
        return None
    return Interval(lo, hi)


def _val(box: Box, o) -> Interval:
    return box[int(o[1])] if o[0] == VAR else Interval.point(o[1])


def _cmp_decide(op: str, l: Interval, r: Interval) -> Optional[bool]:
    """Decide `l op r` under the box, or None when undetermined."""
    if op == "<":
        if l.hi < r.lo:
            return True
        if l.lo >= r.hi:
            return False
    elif op == "<=":
        if l.hi <= r.lo:
            return True
        if l.lo > r.hi:
            return False
    elif op == ">":
        if l.lo > r.hi:
            return True
        if l.hi <= r.lo:
            return False
    elif op == ">=":
        if l.lo >= r.hi:
            return True
        if l.hi < r.lo:
            return False
    return None


def _forward_op(d: Def, box: Box) -> Interval:
    a = _val(box, d.args[0])
    if d.op == "pow":
        return a ** d.n
    if d.op == "abs":
        return a.abs()
    if d.op == "sqrt":
        return a.sqrt()
    b = _val(box, d.args[1])
    if d.op == "+":
        return a + b
    if d.op == "-":
        return a - b
    if d.op == "*":
        return a * b
    if d.op == "/":
        return a / b
    if d.op == "min":
        return a.min_(b)
    if d.op == "max":
        return a.max_(b)
    if d.op == "select":
        t = _val(box, d.args[2])
        o = _val(box, d.args[3])
        dec = _cmp_decide(d.cmp, a, b)
        if dec is True:
            return t
        if dec is False:
            return o
        return t.join(o)
    raise ValueError(f"unknown op {d.op}")


def _ext_div(v: Interval, b: Interval) -> Interval:
    """Hull of the Kahan extended division v / b (for backward mul)."""
    if b.lo > 0 or b.hi < 0:
        return v / b
    if b.lo == 0.0 and b.hi > 0:
        if v.lo > 0:
            return Interval(v.lo / b.hi, _INF)
        if v.hi < 0:
            return Interval(-_INF, v.hi / b.hi)
    elif b.hi == 0.0 and b.lo < 0:
        if v.lo > 0:
            return Interval(-_INF, v.lo / b.lo)
        if v.hi < 0:
            return Interval(v.hi / b.lo, _INF)
    return Interval.top()


def _root_n(x: float, n: int) -> float:
    if x <= 0:
        return 0.0
    return x ** (1.0 / n)


_INFEASIBLE = object()   # backward projection proved the box empty


def _backward_op(d: Def, v: Interval, box: Box) -> List:
    """Inverse projections: contracted intervals for each *var* operand
    (None = no contraction, _INFEASIBLE = box refuted).  Caller meets
    Interval results into the box."""
    out: List = [None] * len(d.args)
    a = _val(box, d.args[0])
    if d.op == "pow":
        n = d.n
        if n % 2 == 1:
            lo = math.copysign(_root_n(abs(v.lo), n), v.lo)
            hi = math.copysign(_root_n(abs(v.hi), n), v.hi)
            out[0] = Interval(min(lo, hi), max(lo, hi))
        elif n > 0:
            r = _root_n(max(v.hi, 0.0), n)
            if a.lo >= 0:
                out[0] = Interval(_root_n(max(v.lo, 0.0), n), r)
            elif a.hi <= 0:
                out[0] = Interval(-r, -_root_n(max(v.lo, 0.0), n))
            else:
                out[0] = Interval(-r, r)
        return out
    if d.op == "abs":
        if a.lo >= 0:
            out[0] = Interval(max(v.lo, 0.0), v.hi)
        elif a.hi <= 0:
            out[0] = Interval(-v.hi, -max(v.lo, 0.0))
        else:
            out[0] = Interval(-v.hi, v.hi)
        return out
    if d.op == "sqrt":
        # v = sqrt(max(a, 0)): a <= v.hi^2 always; a >= v.lo^2 only if v.lo>0
        hi2 = v.hi * v.hi
        lo2 = v.lo * v.lo if v.lo > 0 else -_INF
        out[0] = Interval(lo2, hi2)
        return out
    b = _val(box, d.args[1])
    if d.op == "+":
        out[0] = v - b
        out[1] = v - a
    elif d.op == "-":
        out[0] = v + b
        out[1] = a - v
    elif d.op == "*":
        out[0] = _ext_div(v, b)
        out[1] = _ext_div(v, a)
    elif d.op == "/":
        out[0] = v * b
        out[1] = _ext_div(a, v)
    elif d.op == "min":
        # both operands >= v.lo; an operand must also be <= v.hi when the
        # other provably cannot supply the minimum
        for slot, (x, y) in enumerate(((a, b), (b, a))):
            lo = v.lo
            hi = x.hi if y.lo <= v.hi else min(x.hi, v.hi)
            out[slot] = _INFEASIBLE if lo > hi else Interval(lo, hi)
    elif d.op == "max":
        for slot, (x, y) in enumerate(((a, b), (b, a))):
            hi = v.hi
            lo = x.lo if y.hi >= v.lo else max(x.lo, v.lo)
            out[slot] = _INFEASIBLE if lo > hi else Interval(lo, hi)
    elif d.op == "select":
        dec = _cmp_decide(d.cmp, a, b)
        if dec is True:
            out[2] = v
        elif dec is False:
            out[3] = v
    return out


def hc4(csp: CSP, box: Box, rounds: int = 6) -> bool:
    """Forward/backward contraction to (approximate) fixpoint.

    Returns False when the box is proven empty (constraint refuted)."""
    n = csp.nvars
    for _ in range(rounds):
        changed = False
        for i in range(n):           # forward (operand ids < def id)
            d = csp.defs[i]
            if d is None:
                continue
            m = _meet(box[i], _forward_op(d, box))
            if m is None:
                return False
            if m is not box[i] and (m.lo != box[i].lo or m.hi != box[i].hi):
                box[i] = m
                changed = True
        for i in range(n - 1, -1, -1):  # backward
            d = csp.defs[i]
            if d is None:
                continue
            for slot, niv in enumerate(_backward_op(d, box[i], box)):
                if niv is None:
                    continue
                if niv is _INFEASIBLE:
                    return False     # holds even when the slot is a const
                tag, val = d.args[slot]
                if tag != VAR:
                    continue
                j = int(val)
                m = _meet(box[j], niv)
                if m is None:
                    return False
                if m.lo != box[j].lo or m.hi != box[j].hi:
                    box[j] = m
                    changed = True
        if not changed:
            break
    return True


# ---------------------------------------------------------------------------
# affine relaxation sweep
# ---------------------------------------------------------------------------

def _colinear_ratio(a: Dict[int, float], b: Dict[int, float]) -> Optional[float]:
    """r with b == r*a (same symbol support), else None."""
    if not a or len(a) != len(b):
        return None
    r = None
    for k, av in a.items():
        bv = b.get(k)
        if bv is None or av == 0.0:
            return None
        rk = bv / av
        if r is None:
            r = rk
        elif not math.isclose(rk, r, rel_tol=1e-12, abs_tol=1e-300):
            return None
    return r


def _aff_mul(x: AffineForm, y: AffineForm) -> AffineForm:
    """Affine product keeping the signed quadratic term when the deviation
    vectors are colinear: dev_y = r*dev_x  =>  dev_x*dev_y = r*dev_x^2 in
    r*[0, rad_x^2] — exact, instead of the symmetric ±rad_x*rad_y blob.

    This single refinement is what proves Cauchy–Schwarz-flavored facts like
    HCD's `Ix*Iy` bound, where interval and plain affine both give ±85²."""
    r = _colinear_ratio(x.terms, y.terms)
    if r is None or not x.terms:
        return x * y
    rad2 = x.radius ** 2
    qlo, qhi = (r * 0.0, r * rad2) if r >= 0 else (r * rad2, 0.0)
    # x*y = x0*y0 + x0*dev_y + y0*dev_x + r*dev_x^2
    out = AffineForm(x.x0 * y.x0 + 0.5 * (qlo + qhi))
    terms: Dict[int, float] = {}
    for k, c in x.terms.items():
        terms[k] = y.x0 * c + x.x0 * y.terms[k]
    out.terms.update({k: c for k, c in terms.items() if c != 0.0})
    err = 0.5 * (qhi - qlo)
    if err > 0.0:
        from repro.core.affine import _fresh
        out.terms[_fresh()] = err
    return out


def affine_sweep(csp: CSP, box: Box) -> bool:
    """One affine evaluation of the DAG, meeting each var's affine hull into
    the box.  Returns False on empty.

    Base var `i` gets noise symbol `-(i+1)`: negative ids cannot collide
    with the non-negative ids AffineForm's `_fresh()` mints for
    linearization-error terms (aliasing them would fabricate correlations)."""
    forms: List[Optional[AffineForm]] = [None] * csp.nvars

    def form_of(o) -> AffineForm:
        if o[0] == CONST:
            return AffineForm.point(o[1])
        return forms[int(o[1])]

    for i in range(csp.nvars):
        d = csp.defs[i]
        if d is None:
            iv = box[i]
            if math.isinf(iv.lo) or math.isinf(iv.hi):
                forms[i] = AffineForm.from_interval(iv.lo, iv.hi)
            else:
                mid, rad = 0.5 * (iv.lo + iv.hi), 0.5 * (iv.hi - iv.lo)
                forms[i] = AffineForm(mid, {-(i + 1): rad} if rad else {})
            continue
        a = form_of(d.args[0])
        if d.op == "pow":
            f = a ** d.n
        elif d.op == "abs":
            f = a.abs()
        elif d.op == "sqrt":
            f = a.sqrt()
        else:
            b = form_of(d.args[1])
            if d.op == "+":
                f = a + b
            elif d.op == "-":
                f = a - b
            elif d.op == "*":
                f = _aff_mul(a, b)
            elif d.op == "/":
                f = a / b
            elif d.op == "min":
                f = a.min_(b)
            elif d.op == "max":
                f = a.max_(b)
            elif d.op == "select":
                dec = _cmp_decide(d.cmp, a.to_interval(), b.to_interval())
                t, o = form_of(d.args[2]), form_of(d.args[3])
                if dec is True:
                    f = t
                elif dec is False:
                    f = o
                else:
                    iv = t.to_interval().join(o.to_interval())
                    f = AffineForm.from_interval(iv.lo, iv.hi)
            else:
                raise ValueError(d.op)
        # meet the hull into the box, but keep the *form* intact: its
        # correlations are its value (rebuilding from the clamped box would
        # destroy exactly the colinearity the refined product exploits)
        m = _meet(box[i], f.to_interval())
        if m is None:
            return False
        box[i] = m
        forms[i] = f
    return True


# ---------------------------------------------------------------------------
# interval gradients (reverse mode) + monotonicity fixing
# ---------------------------------------------------------------------------

_ZERO = Interval.point(0.0)
_UNIT = Interval(0.0, 1.0)


def gradients(csp: CSP, box: Box, root: int) -> List[Interval]:
    """adjoint[i] ⊇ d(root)/d(var i) over the box (reverse-mode interval AD).

    Select conditions contribute TOP to their operands (jump discontinuity);
    callers must not monotonicity-fix variables feeding a condition."""
    adj: List[Interval] = [_ZERO] * csp.nvars
    adj[root] = Interval.point(1.0)
    for i in range(csp.nvars - 1, -1, -1):
        d = csp.defs[i]
        g = adj[i]
        if d is None or (g.lo == 0.0 and g.hi == 0.0):
            continue
        a = _val(box, d.args[0])
        if d.op == "pow":
            if d.n == 0:
                parts = [_ZERO]      # d(x^0)/dx = 0 (x**-1 would raise)
            else:
                parts = [Interval.point(float(d.n)) * a ** (d.n - 1)]
        elif d.op == "abs":
            if a.lo >= 0:
                parts = [Interval.point(1.0)]
            elif a.hi <= 0:
                parts = [Interval.point(-1.0)]
            else:
                parts = [Interval(-1.0, 1.0)]
        elif d.op == "sqrt":
            if a.lo > 0:
                parts = [Interval(0.5 / math.sqrt(a.hi), 0.5 / math.sqrt(a.lo))]
            else:
                parts = [Interval(0.0, _INF)]
        else:
            b = _val(box, d.args[1])
            if d.op == "+":
                parts = [Interval.point(1.0), Interval.point(1.0)]
            elif d.op == "-":
                parts = [Interval.point(1.0), Interval.point(-1.0)]
            elif d.op == "*":
                parts = [b, a]
            elif d.op == "/":
                if b.lo > 0 or b.hi < 0:
                    inv = Interval(1.0, 1.0) / b
                    parts = [inv, -a * (inv ** 2)]
                else:
                    parts = [Interval.top(), Interval.top()]
            elif d.op in ("min", "max"):
                parts = [_UNIT, _UNIT]
            elif d.op == "select":
                dec = _cmp_decide(d.cmp, a, b)
                if dec is True:
                    parts = [_ZERO, _ZERO, Interval.point(1.0), _ZERO]
                elif dec is False:
                    parts = [_ZERO, _ZERO, _ZERO, Interval.point(1.0)]
                else:
                    parts = [Interval.top(), Interval.top(), _UNIT, _UNIT]
            else:
                raise ValueError(d.op)
        for slot, p in enumerate(parts):
            tag, val = d.args[slot]
            if tag == VAR:
                j = int(val)
                adj[j] = adj[j] + g * p
    return adj


def _monotone_fix(csp: CSP, box: Box, root: int, maximize: bool,
                  frozen: set) -> bool:
    """Pin base vars with constant derivative sign to the objective-optimal
    bound.  Equi-satisfiable for a `root >= T` (maximize) / `root <= T`
    (minimize) query, since the only non-box constraint is on the root.
    Returns True when anything was fixed."""
    adj = gradients(csp, box, root)
    fixed = False
    for i in csp.base_vars():
        if i in frozen or box[i].width <= 0:
            continue
        g = adj[i]
        if g.lo >= 0:
            v = box[i].hi if maximize else box[i].lo
        elif g.hi <= 0:
            v = box[i].lo if maximize else box[i].hi
        else:
            continue
        if math.isinf(v):
            continue
        box[i] = Interval.point(v)
        fixed = True
    return fixed


# ---------------------------------------------------------------------------
# concrete evaluation (witness extraction)
# ---------------------------------------------------------------------------

def concrete_eval(csp: CSP, point: Dict[int, float]) -> List[float]:
    vals = [0.0] * csp.nvars

    def v(o) -> float:
        return vals[int(o[1])] if o[0] == VAR else float(o[1])

    for i in range(csp.nvars):
        d = csp.defs[i]
        if d is None:
            vals[i] = point[i]
            continue
        a = v(d.args[0])
        if d.op == "pow":
            vals[i] = a ** d.n
        elif d.op == "abs":
            vals[i] = abs(a)
        elif d.op == "sqrt":
            vals[i] = math.sqrt(max(a, 0.0))
        else:
            b = v(d.args[1])
            if d.op == "+":
                vals[i] = a + b
            elif d.op == "-":
                vals[i] = a - b
            elif d.op == "*":
                vals[i] = a * b
            elif d.op == "/":
                vals[i] = a / b if b != 0 else math.copysign(_INF, a)
            elif d.op == "min":
                vals[i] = min(a, b)
            elif d.op == "max":
                vals[i] = max(a, b)
            elif d.op == "select":
                ok = {"<": a < b, "<=": a <= b, ">": a > b, ">=": a >= b}[d.cmp]
                vals[i] = v(d.args[2]) if ok else v(d.args[3])
    return vals


def _mid(iv: Interval) -> float:
    if math.isinf(iv.lo) and math.isinf(iv.hi):
        return 0.0
    if math.isinf(iv.lo):
        return iv.hi
    if math.isinf(iv.hi):
        return iv.lo
    return 0.5 * (iv.lo + iv.hi)


def _witness_points(csp: CSP, box: Box, root: int,
                    maximize: bool) -> List[Dict[int, float]]:
    base = csp.base_vars()
    mid = {i: _mid(box[i]) for i in base}
    pts = [mid]
    adj = gradients(csp, box, root)
    corner = {}
    for i in base:
        g = adj[i]
        if g.lo >= 0:
            corner[i] = box[i].hi if maximize else box[i].lo
        elif g.hi <= 0:
            corner[i] = box[i].lo if maximize else box[i].hi
        else:
            corner[i] = mid[i]
        if math.isinf(corner[i]):
            corner[i] = mid[i]
    pts.append(corner)
    for pick in (lambda iv: iv.lo, lambda iv: iv.hi):
        p = {i: pick(box[i]) for i in base}
        if all(not math.isinf(v) for v in p.values()):
            pts.append(p)
    return pts


# ---------------------------------------------------------------------------
# branch and prune
# ---------------------------------------------------------------------------

def _split_candidates(csp: CSP, box: Box, adj: List[Interval]
                      ) -> List[Tuple[int, float]]:
    """(var, split_point) candidates, best first.

    Sign-splits of zero-straddling `*` / `/` / even-`pow` operands come
    first (closest to the root first): they unlock both the extended-
    division backward rule and the colinear affine product.  Select
    conditions against a constant split at the threshold.  Base vars use
    the smear heuristic (width x |gradient|)."""
    out: List[Tuple[int, float]] = []
    seen = set()
    for i in range(csp.nvars - 1, -1, -1):
        d = csp.defs[i]
        if d is None:
            continue
        cand = []
        if d.op in ("*", "/"):
            cand = [d.args[0], d.args[1]]
        elif d.op == "pow" and d.n % 2 == 0:
            cand = [d.args[0]]
        elif d.op == "select":
            for a, b in ((d.args[0], d.args[1]), (d.args[1], d.args[0])):
                if a[0] == VAR and b[0] == CONST:
                    j = int(a[1])
                    iv = box[j]
                    if (j not in seen and iv.lo < b[1] < iv.hi
                            and iv.width > _WIDTH_EPS):
                        seen.add(j)
                        out.append((j, float(b[1])))
        for o in cand:
            if o[0] != VAR:
                continue
            j = int(o[1])
            iv = box[j]
            if j in seen or not (iv.lo < 0.0 < iv.hi):
                continue
            if iv.width <= _WIDTH_EPS:
                continue
            seen.add(j)
            out.append((j, 0.0))
    scored = []
    for i in csp.base_vars():
        iv = box[i]
        w = iv.width
        if i in seen or w <= _WIDTH_EPS or math.isinf(w):
            continue
        g = adj[i]
        mag = max(abs(g.lo), abs(g.hi))
        if math.isinf(mag):
            mag = 1e18
        scored.append((w * max(mag, 1e-18), i, _mid(iv)))
    scored.sort(reverse=True)
    out.extend((i, m) for _, i, m in scored)
    return out


@dataclasses.dataclass
class BPBudget:
    max_nodes: int = 48
    hc4_rounds: int = 6
    batch: int = 512     # boxes popped per iteration (batched engine only)
    deadline: float = _INF   # time.monotonic() cutoff -> UNKNOWN (anytime)


def decide_scalar(csp: CSP, root: int, sense: str, threshold: float,
                  budget: Optional[BPBudget] = None) -> Verdict:
    """Reference-oracle scalar branch-and-prune (the pre-batching engine).

    Decide satisfiability of `root >= T` (sense "ge") or `root <= T`
    ("le") subject to the CSP's defining constraints and box.  Kept as the
    differential-test oracle for `decide` (the batched engine): one box at
    a time, Python dict/list walks, depth-first stack.

    UNSAT is certified (all boxes refuted by contraction / relaxation);
    SAT carries a concrete witness objective value; UNKNOWN = budget out.
    """
    return decide_scalar_multi(((csp, root),), sense, threshold, budget)


def decide_scalar_multi(entries, sense: str, threshold: float,
                        budget: Optional[BPBudget] = None) -> Verdict:
    """Scalar oracle for a multi-phase (OR-composed) query.

    `entries` is a sequence of `(csp, root)` phase systems — the phase-split
    encoding of one stage.  The query "∃ output pixel with root {sense} T"
    is satisfiable iff *some* phase is, so SAT short-circuits, UNSAT
    requires refuting every phase, and the node budget / deadline is shared
    across all phases (one query costs one budget, phase-split or not).
    """
    with obs.span("smt.decide", engine="scalar", phases=len(entries),
                  sense=sense, threshold=threshold) as sp:
        return _decide_scalar_multi(entries, sense, threshold, budget, sp)


def _decide_scalar_multi(entries, sense: str, threshold: float,
                         budget: Optional[BPBudget], sp) -> Verdict:
    t0 = time.perf_counter()
    bud = budget or BPBudget()
    maximize = sense == "ge"
    query = (Interval(threshold, _INF) if maximize
             else Interval(-_INF, threshold))
    stack: List[Tuple[int, Box]] = []
    for pi in range(len(entries) - 1, -1, -1):    # phase 0 popped first
        csp, root = entries[pi]
        box0 = list(csp.init)
        m = _meet(box0[root], query)
        if m is None:
            continue                              # phase refuted up front
        box0[root] = m
        stack.append((pi, box0))
    frozen: Dict[int, set] = {}
    peak = len(stack)

    def _done(v: Verdict) -> Verdict:
        STATS.add("boxes", v.nodes)
        STATS.add("secs", time.perf_counter() - t0)
        sp.set(status=v.status, nodes=v.nodes, frontier_peak=peak)
        return v

    best: Optional[float] = None
    nodes = 0
    while stack:
        peak = max(peak, len(stack))
        nodes += 1
        if nodes > bud.max_nodes or time.monotonic() > bud.deadline:
            return _done(Verdict(UNKNOWN, best, nodes - 1))
        pi, box = stack.pop()
        csp, root = entries[pi]
        if pi not in frozen:
            frozen[pi] = csp.cond_dependent_vars()
        sat_v, best, children, stuck, _ = _scalar_step(
            csp, box, root, maximize, threshold, best, frozen[pi],
            bud.hc4_rounds)
        if sat_v is not None:
            return _done(Verdict(SAT, sat_v, nodes))
        if stuck:
            return _done(Verdict(UNKNOWN, best, nodes))
        stack.extend((pi, ch) for ch in children)
    return _done(Verdict(UNSAT, best, nodes))


def _scalar_step(csp: CSP, box: Box, root: int, maximize: bool,
                 threshold: float, best, frozen, hc4_rounds: int):
    """One scalar branch-and-prune node: contract, probe, fix, split.

    Returns (sat_value, best, children, stuck, score): `sat_value`
    non-None means SAT; `children` is the (possibly empty) list of split
    boxes; `stuck` marks an irreducible-yet-unrefuted box (UNSAT can no
    longer be certified); `score` is the split variable's smear (width x
    clamped |gradient|) so batched-engine callers can push children with
    the same best-first priority scale `_split_batch` uses.  Shared by
    `decide_scalar` and the batched engine's small-frontier fallback."""
    if not hc4(csp, box, hc4_rounds):
        return None, best, [], False, 0.0
    if not affine_sweep(csp, box):
        return None, best, [], False, 0.0
    if not hc4(csp, box, 2):
        return None, best, [], False, 0.0
    sat_v, best = _check_witness(csp, box, root, maximize, threshold, best)
    if sat_v is not None:
        return sat_v, best, [], False, 0.0
    if _monotone_fix(csp, box, root, maximize, frozen):
        if not (hc4(csp, box, hc4_rounds) and affine_sweep(csp, box)):
            return None, best, [], False, 0.0
        sat_v, best = _check_witness(csp, box, root, maximize, threshold,
                                     best)
        if sat_v is not None:
            return sat_v, best, [], False, 0.0
    adj = gradients(csp, box, root)
    cands = _split_candidates(csp, box, adj)
    if not cands:
        return None, best, [], True, 0.0   # box irreducible yet not refuted
    j, at = cands[0]
    iv = box[j]
    if not (iv.lo < at < iv.hi):
        at = _mid(iv)
        if not (iv.lo < at < iv.hi):
            return None, best, [], True, 0.0
    left, right = list(box), list(box)
    left[j] = Interval(iv.lo, at)
    right[j] = Interval(at, iv.hi)
    # same smear scale as _split_batch's priority score
    w = iv.width if math.isfinite(iv.width) else 1e18
    mag = max(abs(adj[j].lo), abs(adj[j].hi))
    if math.isinf(mag) or math.isnan(mag):
        mag = 1e18
    score = w * max(mag, 1e-18)
    return None, best, [left, right], False, score


def _check_witness(csp, box, root, maximize, threshold, best):
    for pt in _witness_points(csp, box, root, maximize):
        val = concrete_eval(csp, pt)[root]
        if math.isnan(val) or math.isinf(val):
            continue
        if best is None or (val > best if maximize else val < best):
            best = val
        if (val >= threshold) if maximize else (val <= threshold):
            return val, best
    return None, best


# ===========================================================================
# batched-box engine
# ===========================================================================
#
# Everything below re-implements the scalar walk above over a whole frontier
# of boxes at once: the frontier is a pair of (N, nvars) lo/hi float arrays,
# the CSP is compiled once into a flat numpy op table (encoder.compile_csp),
# and hc4 contraction, the affine relaxation, interval gradients, monotone
# fixing, witness probes, and splitting all run as (N,)-vectorized sweeps
# over that table.  A "node" of the branch-and-prune budget is one row —
# ~100x cheaper than a scalar dict walk — which is what lets SMTConfig's
# default budgets grow by the same factor.
#
# The affine relaxation uses an AF1-style form (dense coefficients over the
# base variables + one aggregated non-negative error radius per variable)
# instead of the scalar path's sparse symbol dicts: base-variable
# correlations — linear cancellation and the colinear signed-quadratic
# product — are preserved exactly; only correlations *between* fresh
# linearization-error terms are lumped (sound, and none of the paper
# pipelines rely on them).

_POS0 = np.float64(0.0)
_SMALL_BATCH = 12   # below this many rows the scalar per-box path is faster


def _b_meet(lo_c, hi_c, nlo, nhi):
    """Meet (nlo, nhi) into the column (lo_c, hi_c).

    Returns (mlo, mhi, empty, changed) — same slack rule as `_meet`:
    near-misses within float round-off collapse to the touching point.
    nan bounds (inf-inf artifacts) carry no information: fmax/fmin drop
    them, which is exactly "no contraction" on that side."""
    mlo = np.fmax(lo_c, nlo)
    mhi = np.fmin(hi_c, nhi)
    gap = mlo - mhi
    viol = gap > 0.0
    if viol.any():
        slack = _MEET_SLACK * np.maximum(
            1.0, np.maximum(np.abs(mlo), np.abs(mhi)))
        near = viol & (gap <= slack) & np.isfinite(mlo) & np.isfinite(mhi)
        if near.any():
            mid = 0.5 * (mlo + mhi)
            mlo = np.where(near, mid, mlo)
            mhi = np.where(near, mid, mhi)
        empty = viol & ~near
    else:
        empty = viol
    changed = (mlo != lo_c) | (mhi != hi_c)
    return mlo, mhi, empty, changed


def _b_mul(alo, ahi, blo, bhi):
    """Interval product with the 0 * inf = 0 convention, elementwise."""
    p1 = alo * blo
    p2 = alo * bhi
    p3 = ahi * blo
    p4 = ahi * bhi
    if np.isnan(p1 + p2 + p3 + p4).any():   # 0*inf (or empty-ish inf-inf)
        p1 = np.where((alo == 0.0) | (blo == 0.0), 0.0, p1)
        p2 = np.where((alo == 0.0) | (bhi == 0.0), 0.0, p2)
        p3 = np.where((ahi == 0.0) | (blo == 0.0), 0.0, p3)
        p4 = np.where((ahi == 0.0) | (bhi == 0.0), 0.0, p4)
    return (np.minimum(np.minimum(p1, p2), np.minimum(p3, p4)),
            np.maximum(np.maximum(p1, p2), np.maximum(p3, p4)))


def _b_div(alo, ahi, blo, bhi):
    straddle = (blo <= 0.0) & (0.0 <= bhi)
    ilo = 1.0 / bhi
    ihi = 1.0 / blo
    rlo, rhi = _b_mul(alo, ahi, ilo, ihi)
    if np.any(straddle):
        rlo = np.where(straddle, -_INF, rlo)
        rhi = np.where(straddle, _INF, rhi)
    return rlo, rhi


def _b_pow(alo, ahi, n: int):
    if n == 0:
        one = np.ones_like(alo + _POS0)
        return one, one
    l = alo ** n
    h = ahi ** n
    if n % 2 == 1:
        return l, h
    lo = np.where(alo >= 0, l, np.where(ahi < 0, h, 0.0))
    hi = np.where(alo >= 0, h, np.where(ahi < 0, l, np.maximum(l, h)))
    return lo, hi


def _b_abs(alo, ahi):
    lo = np.where(alo >= 0, alo, np.where(ahi <= 0, -ahi, 0.0))
    hi = np.where(alo >= 0, ahi, np.where(ahi <= 0, -alo,
                                          np.maximum(-alo, ahi)))
    return lo, hi


def _b_sqrt(alo, ahi):
    return (np.sqrt(np.maximum(alo, 0.0)), np.sqrt(np.maximum(ahi, 0.0)))


def _b_cmp(code: int, llo, lhi, rlo, rhi):
    """Vectorized `_cmp_decide`: (provably_true, provably_false) masks."""
    if code == 0:      # <
        return lhi < rlo, llo >= rhi
    if code == 1:      # <=
        return lhi <= rlo, llo > rhi
    if code == 2:      # >
        return llo > rhi, lhi <= rlo
    return llo >= rhi, lhi < rlo   # >=


def _b_ext_div(vlo, vhi, blo, bhi):
    """Vectorized Kahan extended division hull (see `_ext_div`)."""
    nz = (blo > 0) | (bhi < 0)
    dlo, dhi = _b_div(vlo, vhi, blo, bhi)
    if np.all(nz):
        return dlo, dhi
    rlo = np.where(nz, dlo, -_INF)
    rhi = np.where(nz, dhi, _INF)
    m1 = (blo == 0.0) & (bhi > 0)
    if np.any(m1):
        c = m1 & (vlo > 0)
        rlo = np.where(c, vlo / bhi, rlo)
        rhi = np.where(c, _INF, rhi)
        c = m1 & (vhi < 0)
        rlo = np.where(c, -_INF, rlo)
        rhi = np.where(c, vhi / bhi, rhi)
    m2 = (bhi == 0.0) & (blo < 0)
    if np.any(m2):
        c = m2 & (vlo > 0)
        rlo = np.where(c, -_INF, rlo)
        rhi = np.where(c, vlo / blo, rhi)
        c = m2 & (vhi < 0)
        rlo = np.where(c, vhi / blo, rlo)
        rhi = np.where(c, _INF, rhi)
    return rlo, rhi


def _b_root(x, n: int):
    with np.errstate(invalid="ignore"):
        r = np.where(x > 0, np.abs(x) ** (1.0 / n), 0.0)
    return r


def _b_arg(prog: Program, k: int, lo, hi, j: int):
    ix = prog.argv[k, j]
    if ix >= 0:
        return lo[:, ix], hi[:, ix]
    c = prog.argc[k, j]
    return c, c


def _b_forward(prog: Program, k: int, lo, hi):
    op = prog.opcode[k]
    alo, ahi = _b_arg(prog, k, lo, hi, 0)
    if op == OP_POW:
        return _b_pow(alo, ahi, int(prog.pow_n[k]))
    if op == OP_ABS:
        return _b_abs(alo, ahi)
    if op == OP_SQRT:
        return _b_sqrt(alo, ahi)
    blo, bhi = _b_arg(prog, k, lo, hi, 1)
    if op == OP_ADD:
        return alo + blo, ahi + bhi
    if op == OP_SUB:
        return alo - bhi, ahi - blo
    if op == OP_MUL:
        return _b_mul(alo, ahi, blo, bhi)
    if op == OP_DIV:
        return _b_div(alo, ahi, blo, bhi)
    if op == OP_MIN:
        return np.minimum(alo, blo), np.minimum(ahi, bhi)
    if op == OP_MAX:
        return np.maximum(alo, blo), np.maximum(ahi, bhi)
    # select
    t, f = _b_cmp(int(prog.cmp[k]), alo, ahi, blo, bhi)
    tlo, thi = _b_arg(prog, k, lo, hi, 2)
    olo, ohi = _b_arg(prog, k, lo, hi, 3)
    jlo = np.minimum(tlo, olo)
    jhi = np.maximum(thi, ohi)
    return (np.where(t, tlo, np.where(f, olo, jlo)),
            np.where(t, thi, np.where(f, ohi, jhi)))


def _b_backward(prog: Program, k: int, lo, hi):
    """Vectorized `_backward_op`: ([(slot, clo, chi), ...], infeasible)."""
    op = prog.opcode[k]
    i = prog.def_var[k]
    vlo, vhi = lo[:, i], hi[:, i]
    alo, ahi = _b_arg(prog, k, lo, hi, 0)
    no_inf = np.zeros(lo.shape[0], bool)
    if op == OP_POW:
        n = int(prog.pow_n[k])
        if n % 2 == 1:
            rl = np.copysign(_b_root(np.abs(vlo), n), vlo)
            rh = np.copysign(_b_root(np.abs(vhi), n), vhi)
            return [(0, np.minimum(rl, rh), np.maximum(rl, rh))], no_inf
        if n > 0:
            r = _b_root(np.maximum(vhi, 0.0), n)
            rp = _b_root(np.maximum(vlo, 0.0), n)
            clo = np.where(alo >= 0, rp, -r)
            chi = np.where(alo >= 0, r, np.where(ahi <= 0, -rp, r))
            return [(0, clo, chi)], no_inf
        return [], no_inf
    if op == OP_ABS:
        clo = np.where(alo >= 0, np.maximum(vlo, 0.0), -vhi)
        chi = np.where(alo >= 0, vhi,
                       np.where(ahi <= 0, -np.maximum(vlo, 0.0), vhi))
        return [(0, clo, chi)], no_inf
    if op == OP_SQRT:
        hi2 = vhi * vhi
        lo2 = np.where(vlo > 0, vlo * vlo, -_INF)
        return [(0, lo2, hi2)], no_inf
    blo, bhi = _b_arg(prog, k, lo, hi, 1)
    # only compute projections for slots that are variables — the caller
    # cannot meet a constant slot anyway (mul-by-stencil-weight is the
    # single hottest def shape, and this halves its backward cost)
    v0 = prog.argv[k, 0] >= 0
    v1 = prog.argv[k, 1] >= 0
    if op == OP_ADD:
        out = []
        if v0:
            out.append((0, vlo - bhi, vhi - blo))
        if v1:
            out.append((1, vlo - ahi, vhi - alo))
        return out, no_inf
    if op == OP_SUB:
        out = []
        if v0:
            out.append((0, vlo + blo, vhi + bhi))
        if v1:
            out.append((1, alo - vhi, ahi - vlo))
        return out, no_inf
    if op == OP_MUL:
        out = []
        if v0:
            out.append((0,) + _b_ext_div(vlo, vhi, blo, bhi))
        if v1:
            out.append((1,) + _b_ext_div(vlo, vhi, alo, ahi))
        return out, no_inf
    if op == OP_DIV:
        out = []
        if v0:
            out.append((0,) + _b_mul(vlo, vhi, blo, bhi))
        if v1:
            out.append((1,) + _b_ext_div(alo, ahi, vlo, vhi))
        return out, no_inf
    if op in (OP_MIN, OP_MAX):
        outs = []
        infeas = no_inf
        for slot, (xlo, xhi, ylo, yhi) in enumerate(
                ((alo, ahi, blo, bhi), (blo, bhi, alo, ahi))):
            if op == OP_MIN:
                clo = vlo + np.zeros_like(xhi + _POS0)
                chi = np.where(ylo <= vhi, xhi, np.minimum(xhi, vhi))
            else:
                chi = vhi + np.zeros_like(xlo + _POS0)
                clo = np.where(yhi >= vlo, xlo, np.maximum(xlo, vlo))
            bad = clo > chi
            infeas = infeas | bad
            # keep meet well-formed on rows just proven infeasible
            outs.append((slot, np.where(bad, -_INF, clo),
                         np.where(bad, _INF, chi)))
        return outs, infeas
    # select: the decided branch inherits the output interval
    t, f = _b_cmp(int(prog.cmp[k]), alo, ahi, blo, bhi)
    return [(2, np.where(t, vlo, -_INF), np.where(t, vhi, _INF)),
            (3, np.where(f, vlo, -_INF), np.where(f, vhi, _INF))], no_inf


def hc4_batch(prog: Program, lo, hi, alive, rounds: int = 6):
    """Vectorized `hc4` over the whole (N, nvars) frontier, in place.

    Returns the updated alive mask (False = box proven empty)."""
    with np.errstate(all="ignore"):
        return _hc4_rows(prog, lo, hi, alive, rounds)


def _hc4_rows(prog: Program, lo, hi, alive, rounds: int):
    for _ in range(rounds):
        changed = np.zeros(lo.shape[0], bool)
        for k in range(prog.ndefs):              # forward
            i = prog.def_var[k]
            flo, fhi = _b_forward(prog, k, lo, hi)
            mlo, mhi, empty, ch = _b_meet(lo[:, i], hi[:, i], flo, fhi)
            alive = alive & ~empty
            changed |= ch
            lo[:, i] = mlo
            hi[:, i] = mhi
        for k in range(prog.ndefs - 1, -1, -1):  # backward
            outs, infeas = _b_backward(prog, k, lo, hi)
            alive = alive & ~infeas
            for slot, clo, chi in outs:
                ix = prog.argv[k, slot]
                if ix < 0:
                    continue
                mlo, mhi, empty, ch = _b_meet(lo[:, ix], hi[:, ix], clo, chi)
                alive = alive & ~empty
                changed |= ch
                lo[:, ix] = mlo
                hi[:, ix] = mhi
        if not (changed & alive).any():
            break
    return alive


# ---------------------------------------------------------------------------
# batched affine relaxation (AF1 forms: dense base coeffs + lumped error)
# ---------------------------------------------------------------------------
#
# A form is the triple (c, K, e): value = c + K @ eps + e*u, eps_i/u in
# [-1, 1], with one eps per *base* variable and every fresh linearization
# error lumped into the single non-negative radius e.  Mirrors
# `affine_sweep`/`_aff_mul` op by op; only inter-error correlations are
# dropped (sound over-approximation).

_AFFINE_MEM_CAP = 48e6     # bytes of coefficient tensor per sub-batch


def _af1_rad(K, e):
    return np.abs(K).sum(axis=1) + e


def _af1_mul(x, y, colinear: bool):
    """AF1 product; `colinear` enables the `_aff_mul` signed-quadratic
    refinement where the deviation vectors are colinear and error-free."""
    cx, Kx, ex = x
    cy, Ky, ey = y
    rx = _af1_rad(Kx, ex)
    ry = _af1_rad(Ky, ey)
    c = cx * cy
    K = cy[:, None] * Kx + cx[:, None] * Ky
    e = np.abs(cx) * ey + np.abs(cy) * ex + rx * ry
    e = np.where(np.isnan(e), _INF, e)
    if not colinear:
        return c, K, e
    # colinear refinement: dev_y = r * dev_x  =>  dev_x*dev_y = r*dev_x^2
    # in r*[0, rad_x^2] (exact, signed) instead of +-rad_x*rad_y
    xnz = Kx != 0.0
    ynz = Ky != 0.0
    supp = ~(xnz ^ ynz).any(axis=1) & xnz.any(axis=1)
    if supp.any():
        rows = np.arange(Kx.shape[0])
        jmax = np.argmax(np.abs(Kx), axis=1)
        kx = Kx[rows, jmax]
        ky = Ky[rows, jmax]
        r = np.where(kx == 0.0, 0.0, ky / kx)
        pr = r[:, None] * Kx
        close = np.where(
            xnz, np.abs(Ky - pr) <= 1e-12 * np.maximum(np.abs(Ky),
                                                       np.abs(pr)),
            True).all(axis=1)
        col = supp & close & (ex == 0.0) & (ey == 0.0)
        if col.any():
            rad2 = np.abs(Kx).sum(axis=1) ** 2
            q = r * rad2                     # quadratic term in r*[0, rad2]
            qlo = np.minimum(q, 0.0)
            qhi = np.maximum(q, 0.0)
            c = np.where(col, cx * cy + 0.5 * (qlo + qhi), c)
            e = np.where(col, 0.5 * (qhi - qlo), e)
    return c, K, e


def _af1_square(x):
    cx, Kx, ex = x
    r = _af1_rad(Kx, ex)
    c = cx * cx + 0.5 * r * r
    K = 2.0 * cx[:, None] * Kx
    e = 2.0 * np.abs(cx) * ex + 0.5 * r * r
    e = np.where(np.isnan(e), _INF, e)
    return c, K, e


def _af1_pow(x, n: int):
    if n == 0:
        c = np.ones_like(x[0])
        return c, np.zeros_like(x[1]), np.zeros_like(x[0])
    if n == 1:
        return x
    if n == 2:
        return _af1_square(x)
    half = _af1_pow(_af1_square(x), n // 2)
    return _af1_mul(half, x, colinear=False) if n % 2 else half


def _af1_hull(x):
    c, K, e = x
    r = _af1_rad(K, e)
    return c - r, c + r


def _af1_from_hull(lo, hi):
    """from_interval twin: finite -> (mid, 0, rad); infinite -> (0, 0, inf)."""
    bad = ~np.isfinite(lo) | ~np.isfinite(hi)
    c = np.where(bad, 0.0, 0.5 * (lo + hi))
    e = np.where(bad, _INF, 0.5 * (hi - lo))
    return c, e


def _af1_recip(y):
    """1/y via the min-range linear approximation (see AffineForm.reciprocal)."""
    cy, Ky, ey = y
    lo, hi = _af1_hull(y)
    straddle = (lo <= 0.0) & (0.0 <= hi)
    point = _af1_rad(Ky, ey) == 0.0
    p = np.where(lo > 0, -1.0 / (hi * hi), -1.0 / (lo * lo))
    ya = 1.0 / lo - p * lo
    yb = 1.0 / hi - p * hi
    q = 0.5 * (ya + yb)
    delta = 0.5 * np.abs(ya - yb)
    c = np.where(straddle, 0.0, np.where(point, 1.0 / cy, p * cy + q))
    K = np.where((straddle | point)[:, None], 0.0, p[:, None] * Ky)
    e = np.where(straddle, _INF,
                 np.where(point, 0.0, np.abs(p) * ey + delta))
    c = np.where(np.isnan(c), 0.0, c)
    e = np.where(np.isnan(e), _INF, e)
    return c, K, e


def _af1_blend(mask, x, y):
    """Elementwise form select: mask ? x : y."""
    return (np.where(mask, x[0], y[0]),
            np.where(mask[:, None], x[1], y[1]),
            np.where(mask, x[2], y[2]))


def affine_batch(prog: Program, lo, hi, alive):
    """Vectorized `affine_sweep` over the frontier (AF1 forms), in place.

    Sub-batches rows so the (rows, nvars, nbase) coefficient tensor stays
    under a fixed memory cap.  Returns the updated alive mask."""
    nb = len(prog.base)
    if nb == 0 or prog.ndefs == 0:
        return alive
    rows_per = max(1, int(_AFFINE_MEM_CAP / (prog.nvars * nb * 8 + 1)))
    for s in range(0, lo.shape[0], rows_per):
        sl = slice(s, min(s + rows_per, lo.shape[0]))
        if alive[sl].any():
            alive[sl] = _affine_rows(prog, lo[sl], hi[sl], alive[sl])
    return alive


def _affine_rows(prog: Program, lo, hi, alive):
    with np.errstate(all="ignore"):
        return _affine_rows_inner(prog, lo, hi, alive)


def _affine_rows_inner(prog: Program, lo, hi, alive):
    N = lo.shape[0]
    nb = len(prog.base)
    C = np.zeros((N, prog.nvars))
    K = np.zeros((N, prog.nvars, nb))
    E = np.zeros((N, prog.nvars))
    for col, i in enumerate(prog.base):
        l, h = lo[:, i], hi[:, i]
        inf_m = ~np.isfinite(l) | ~np.isfinite(h)
        C[:, i] = np.where(inf_m, 0.0, 0.5 * (l + h))
        K[:, i, col] = np.where(inf_m, 0.0, 0.5 * (h - l))
        E[:, i] = np.where(inf_m, _INF, 0.0)

    zK = np.zeros((N, nb))
    z0 = np.zeros(N)

    def form(k, j):
        ix = prog.argv[k, j]
        if ix >= 0:
            return C[:, ix], K[:, ix], E[:, ix]
        return np.full(N, prog.argc[k, j]), zK, z0

    for k in range(prog.ndefs):
        i = prog.def_var[k]
        op = prog.opcode[k]
        a = form(k, 0)
        if op == OP_POW:
            f = _af1_pow(a, int(prog.pow_n[k]))
        elif op == OP_ABS:
            l, h = _af1_hull(a)
            pos = l >= 0.0
            neg = h <= 0.0
            hc, he = _af1_from_hull(np.zeros_like(l), np.maximum(-l, h))
            f = _af1_blend(pos, a, _af1_blend(neg, (-a[0], -a[1], a[2]),
                                              (hc, zK, he)))
        elif op == OP_SQRT:
            l, h = _af1_hull(a)
            c, e = _af1_from_hull(np.sqrt(np.maximum(l, 0.0)),
                                  np.sqrt(np.maximum(h, 0.0)))
            f = (c, zK, e)
        else:
            b = form(k, 1)
            if op == OP_ADD:
                f = (a[0] + b[0], a[1] + b[1], a[2] + b[2])
            elif op == OP_SUB:
                f = (a[0] - b[0], a[1] - b[1], a[2] + b[2])
            elif op == OP_MUL:
                f = _af1_mul(a, b, colinear=True)
            elif op == OP_DIV:
                f = _af1_mul(a, _af1_recip(b), colinear=False)
            elif op in (OP_MIN, OP_MAX):
                la, ha = _af1_hull(a)
                lb, hb = _af1_hull(b)
                if op == OP_MIN:
                    c, e = _af1_from_hull(np.minimum(la, lb),
                                          np.minimum(ha, hb))
                else:
                    c, e = _af1_from_hull(np.maximum(la, lb),
                                          np.maximum(ha, hb))
                f = (c, zK, e)
            else:      # select — decided on the FORM hulls, like the scalar
                la, ha = _af1_hull(a)
                lb, hb = _af1_hull(b)
                t, fm = _b_cmp(int(prog.cmp[k]), la, ha, lb, hb)
                th = form(k, 2)
                ot = form(k, 3)
                lt, ht = _af1_hull(th)
                log, hog = _af1_hull(ot)
                jc, je = _af1_from_hull(np.minimum(lt, log),
                                        np.maximum(ht, hog))
                f = _af1_blend(t, th, _af1_blend(fm, ot, (jc, zK, je)))
        # meet the hull into the box; keep the form intact (its correlations
        # are its value, exactly like the scalar sweep)
        fl, fh = _af1_hull(f)
        mlo, mhi, empty, _ = _b_meet(lo[:, i], hi[:, i], fl, fh)
        alive = alive & ~empty
        lo[:, i] = mlo
        hi[:, i] = mhi
        C[:, i] = f[0]
        K[:, i] = f[1]
        E[:, i] = f[2]
    return alive


# ---------------------------------------------------------------------------
# batched gradients + monotonicity fixing + witness probes + splitting
# ---------------------------------------------------------------------------

def gradients_batch(prog: Program, lo, hi, root: int):
    """Vectorized `gradients`: (glo, ghi) arrays of shape (N, nvars)."""
    with np.errstate(all="ignore"):
        return _gradients_rows(prog, lo, hi, root)


def _gradients_rows(prog: Program, lo, hi, root: int):
    N = lo.shape[0]
    glo = np.zeros((N, prog.nvars))
    ghi = np.zeros((N, prog.nvars))
    glo[:, root] = 1.0
    ghi[:, root] = 1.0
    one = np.ones(N)
    zero = np.zeros(N)
    inf = np.full(N, _INF)
    for k in range(prog.ndefs - 1, -1, -1):
        i = prog.def_var[k]
        gl, gh = glo[:, i], ghi[:, i]
        if not (gl.any() or gh.any()):
            continue
        op = prog.opcode[k]
        alo, ahi = _b_arg(prog, k, lo, hi, 0)
        if op == OP_POW:
            n = int(prog.pow_n[k])
            if n == 0:
                parts = [(0, zero, zero)]
            else:
                plo, phi = _b_pow(alo, ahi, n - 1)
                parts = [(0, n * plo, n * phi)]
        elif op == OP_ABS:
            plo = np.where(alo >= 0, 1.0, -1.0)
            phi = np.where(alo >= 0, 1.0, np.where(ahi <= 0, -1.0, 1.0))
            parts = [(0, plo, phi)]
        elif op == OP_SQRT:
            pos = alo > 0
            plo = np.where(pos, 0.5 / np.sqrt(np.maximum(ahi, 1e-300)), 0.0)
            phi = np.where(pos, 0.5 / np.sqrt(np.where(pos, alo, 1.0)), _INF)
            parts = [(0, plo, phi)]
        elif op == OP_ADD:
            parts = [(0, one, one), (1, one, one)]
        elif op == OP_SUB:
            parts = [(0, one, one), (1, -one, -one)]
        elif op == OP_MUL:
            blo, bhi = _b_arg(prog, k, lo, hi, 1)
            parts = [(0, blo, bhi), (1, alo, ahi)]
        elif op == OP_DIV:
            blo, bhi = _b_arg(prog, k, lo, hi, 1)
            nz = (blo > 0) | (bhi < 0)
            ivlo = 1.0 / np.where(nz, bhi, 1.0)
            ivhi = 1.0 / np.where(nz, blo, 1.0)
            i2lo, i2hi = _b_pow(ivlo, ivhi, 2)
            q0lo, q0hi = _b_mul(-ahi, -alo, i2lo, i2hi)
            parts = [(0, np.where(nz, ivlo, -inf), np.where(nz, ivhi, inf)),
                     (1, np.where(nz, q0lo, -inf), np.where(nz, q0hi, inf))]
        elif op in (OP_MIN, OP_MAX):
            parts = [(0, zero, one), (1, zero, one)]
        else:     # select
            blo, bhi = _b_arg(prog, k, lo, hi, 1)
            t, f = _b_cmp(int(prog.cmp[k]), alo, ahi, blo, bhi)
            und = ~t & ~f
            parts = [
                (0, np.where(und, -inf, 0.0), np.where(und, inf, 0.0)),
                (1, np.where(und, -inf, 0.0), np.where(und, inf, 0.0)),
                (2, np.where(t, 1.0, 0.0),
                 np.where(t | und, 1.0, 0.0)),
                (3, np.where(f, 1.0, 0.0),
                 np.where(f | und, 1.0, 0.0)),
            ]
        for slot, plo, phi in parts:
            ix = prog.argv[k, slot]
            if ix < 0:
                continue
            dlo, dhi = _b_mul(gl, gh, plo, phi)
            nlo = glo[:, ix] + dlo
            nhi = ghi[:, ix] + dhi
            glo[:, ix] = np.where(np.isnan(nlo), -_INF, nlo)
            ghi[:, ix] = np.where(np.isnan(nhi), _INF, nhi)
    return glo, ghi


def _monotone_fix_batch(prog: Program, lo, hi, glo, ghi, maximize: bool,
                        alive):
    """Vectorized `_monotone_fix`; returns the per-box fixed-anything mask."""
    fixed = np.zeros(lo.shape[0], bool)
    for i in prog.base:
        if prog.frozen[i]:
            continue
        elig = alive & (hi[:, i] - lo[:, i] > 0)
        up = elig & (glo[:, i] >= 0)
        dn = elig & ~up & (ghi[:, i] <= 0)
        v_up = hi[:, i] if maximize else lo[:, i]
        v_dn = lo[:, i] if maximize else hi[:, i]
        m_up = up & np.isfinite(v_up)
        m_dn = dn & np.isfinite(v_dn)
        pin = np.where(m_up, v_up, v_dn)
        m = m_up | m_dn
        lo[:, i] = np.where(m, pin, lo[:, i])
        hi[:, i] = np.where(m, pin, hi[:, i])
        fixed |= m
    return fixed


def concrete_batch(prog: Program, pts):
    """Vectorized `concrete_eval`: pts is (N, nvars) with base columns set;
    fills every defined column in place and returns the array."""
    with np.errstate(all="ignore"):
        return _concrete_rows(prog, pts)


def _concrete_rows(prog: Program, pts):
    for k in range(prog.ndefs):
        i = prog.def_var[k]
        op = prog.opcode[k]

        def v(j):
            ix = prog.argv[k, j]
            return pts[:, ix] if ix >= 0 else prog.argc[k, j]

        a = v(0)
        if op == OP_POW:
            r = a ** int(prog.pow_n[k])
        elif op == OP_ABS:
            r = np.abs(a)
        elif op == OP_SQRT:
            r = np.sqrt(np.maximum(a, 0.0))
        else:
            b = v(1)
            if op == OP_ADD:
                r = a + b
            elif op == OP_SUB:
                r = a - b
            elif op == OP_MUL:
                r = a * b
            elif op == OP_DIV:
                r = np.where(b == 0.0, np.copysign(_INF, a), a / b)
            elif op == OP_MIN:
                r = np.minimum(a, b)
            elif op == OP_MAX:
                r = np.maximum(a, b)
            else:
                code = int(prog.cmp[k])
                ok = (a < b if code == 0 else a <= b if code == 1
                      else a > b if code == 2 else a >= b)
                r = np.where(ok, v(2), v(3))
        pts[:, i] = r
    return pts


def _b_mid(l, h):
    """Vectorized `_mid`."""
    m = 0.5 * (l + h)
    m = np.where(np.isinf(l) & np.isinf(h), 0.0,
                 np.where(np.isinf(l), h, np.where(np.isinf(h), l, m)))
    return m


def _witness_batch(prog: Program, lo, hi, alive, root: int, maximize: bool,
                   threshold: float, glo, ghi, best):
    """Vectorized `_check_witness` over the frontier.

    Probes mid / gradient-corner / all-lo / all-hi points of every alive
    box; returns (sat_value_or_None, best)."""
    base = prog.base
    bl = lo[:, base]
    bh = hi[:, base]
    mid = _b_mid(bl, bh)
    gl = glo[:, base]
    gh = ghi[:, base]
    pick_hi = bh if maximize else bl
    pick_lo = bl if maximize else bh
    corner = np.where(gl >= 0, pick_hi, np.where(gh <= 0, pick_lo, mid))
    corner = np.where(np.isinf(corner), mid, corner)
    probes = [(mid, alive), (corner, alive),
              (bl, alive & np.isfinite(bl).all(axis=1)),
              (bh, alive & np.isfinite(bh).all(axis=1))]
    probes = [(pt, valid) for pt, valid in probes if valid.any()]
    if not probes:
        return None, best
    # stack all probe points into ONE forward pass over the op table: the
    # per-def Python cost is paid once, not once per probe kind
    P = len(probes)
    N = lo.shape[0]
    pts = np.zeros((P * N, prog.nvars))
    valid = np.zeros(P * N, bool)
    for q, (pt, vd) in enumerate(probes):
        pts[q * N:(q + 1) * N, base] = pt
        valid[q * N:(q + 1) * N] = vd
    vals = concrete_batch(prog, pts)[:, root]
    good = valid & np.isfinite(vals)
    sat_val = None
    if good.any():
        gv = vals[good]
        ext = gv.max() if maximize else gv.min()
        if best is None or (ext > best if maximize else ext < best):
            best = float(ext)
        meets = good & ((vals >= threshold) if maximize
                        else (vals <= threshold))
        if meets.any():
            mv = vals[meets]
            sat_val = float(mv.max() if maximize else mv.min())
    return sat_val, best


def _split_batch(prog: Program, lo, hi, glo, ghi, alive):
    """Vectorized `_split_candidates`[0]: per-box (split var, split point,
    priority score).  var = -1 marks an irreducible box."""
    N = lo.shape[0]
    svar = np.full(N, -1, np.int32)
    sat = np.zeros(N)
    pend = alive.copy()
    for t in range(len(prog.split_var)):
        if not pend.any():
            break
        j = int(prog.split_var[t])
        l, h = lo[:, j], hi[:, j]
        at = prog.split_at[t] if prog.split_sel[t] else 0.0
        ok = pend & (l < at) & (at < h) & (h - l > _WIDTH_EPS)
        if ok.any():
            svar[ok] = j
            sat[ok] = at
            pend &= ~ok
    if pend.any():
        bl = lo[:, prog.base]
        bh = hi[:, prog.base]
        w = bh - bl
        mag = np.maximum(np.abs(glo[:, prog.base]),
                         np.abs(ghi[:, prog.base]))
        mag = np.where(np.isinf(mag) | np.isnan(mag), 1e18, mag)
        score = w * np.maximum(mag, 1e-18)
        score = np.where((w <= _WIDTH_EPS) | np.isinf(w), -_INF, score)
        kbest = np.argmax(score, axis=1) if score.shape[1] else \
            np.zeros(N, np.int64)
        rows = np.arange(N)
        has = score.shape[1] > 0
        if has:
            sc = score[rows, kbest]
            jvar = prog.base[kbest]
            mids = _b_mid(bl[rows, kbest], bh[rows, kbest])
            inside = (lo[rows, jvar] < mids) & (mids < hi[rows, jvar])
            take = pend & (sc > -_INF) & inside
            svar = np.where(take, jvar.astype(np.int32), svar)
            sat = np.where(take, mids, sat)
    # priority score for best-first popping: smear of the chosen split var
    rows = np.arange(N)
    jj = np.maximum(svar, 0)
    w = hi[rows, jj] - lo[rows, jj]
    mag = np.maximum(np.abs(glo[rows, jj]), np.abs(ghi[rows, jj]))
    mag = np.where(np.isinf(mag) | np.isnan(mag), 1e18, mag)
    score = np.where(np.isfinite(w), w, 1e18) * np.maximum(mag, 1e-18)
    return svar, sat, score


def _group_step(csp: CSP, prog: Program, root: int, lo, hi, maximize: bool,
                threshold: float, best, bud: BPBudget, frozen_set):
    """Process one homogeneous (single-CSP) batch of popped boxes: contract,
    probe, fix, split.  Returns (sat_value, best, kid_lo, kid_hi,
    kid_scores, stuck); `kid_*` are the split children (possibly empty
    arrays of shape (k, nvars) / (k,))."""
    B = lo.shape[0]
    empty = (np.empty((0, prog.nvars)), np.empty((0, prog.nvars)),
             np.empty(0))
    if B < _SMALL_BATCH:
        # narrow frontier: numpy per-def overhead beats vectorization
        # gains below ~a dozen rows, so run these boxes through the
        # scalar per-box step (identical semantics, ~4x faster here)
        kid_rows = []
        kid_scores = []
        stuck = False
        for r in range(B):
            box = [Interval(float(lo[r, i]), float(hi[r, i]))
                   if lo[r, i] <= hi[r, i] else
                   Interval(float(lo[r, i]), float(lo[r, i]))
                   for i in range(prog.nvars)]
            sat_v, best, children, irred, sc = _scalar_step(
                csp, box, root, maximize, threshold, best, frozen_set,
                bud.hc4_rounds)
            if sat_v is not None:
                return sat_v, best, *empty, stuck
            stuck = stuck or irred
            for ch in children:
                kid_rows.append(([iv.lo for iv in ch],
                                 [iv.hi for iv in ch]))
                kid_scores.append(sc)
        if not kid_rows:
            return None, best, *empty, stuck
        return (None, best, np.array([r[0] for r in kid_rows]),
                np.array([r[1] for r in kid_rows]), np.array(kid_scores),
                stuck)
    alive = np.ones(B, bool)
    alive = hc4_batch(prog, lo, hi, alive, bud.hc4_rounds)
    if alive.any():
        alive = affine_batch(prog, lo, hi, alive)
    if alive.any():
        alive = hc4_batch(prog, lo, hi, alive, 2)
    if not alive.any():
        return None, best, *empty, False
    if not alive.all():
        # compact to the surviving rows: gradients/witness/monotone-fix
        # cost is proportional to N, and near an UNSAT threshold most
        # of a batch dies in contraction
        keep_rows = np.nonzero(alive)[0]
        lo, hi = lo[keep_rows], hi[keep_rows]
        alive = np.ones(len(keep_rows), bool)
    glo, ghi = gradients_batch(prog, lo, hi, root)
    sat_v, best = _witness_batch(prog, lo, hi, alive, root, maximize,
                                 threshold, glo, ghi, best)
    if sat_v is not None:
        return sat_v, best, *empty, False
    fixed = _monotone_fix_batch(prog, lo, hi, glo, ghi, maximize, alive)
    if fixed.any():
        alive = hc4_batch(prog, lo, hi, alive, bud.hc4_rounds)
        if alive.any():
            alive = affine_batch(prog, lo, hi, alive)
        if not alive.any():
            return None, best, *empty, False
        if not alive.all():
            keep_rows = np.nonzero(alive)[0]
            lo, hi = lo[keep_rows], hi[keep_rows]
            alive = np.ones(len(keep_rows), bool)
        glo, ghi = gradients_batch(prog, lo, hi, root)
        sat_v, best = _witness_batch(prog, lo, hi, alive, root, maximize,
                                     threshold, glo, ghi, best)
        if sat_v is not None:
            return sat_v, best, *empty, False
    svar, sat, score = _split_batch(prog, lo, hi, glo, ghi, alive)
    stuck = bool((alive & (svar < 0)).any())  # cannot certify UNSAT any more
    sp = alive & (svar >= 0)
    if not sp.any():
        return None, best, *empty, stuck
    rows = np.nonzero(sp)[0]
    j = svar[rows]
    at = sat[rows]
    left_lo, left_hi = lo[rows], hi[rows].copy()
    right_lo, right_hi = lo[rows].copy(), hi[rows]
    rr = np.arange(len(rows))
    left_hi[rr, j] = at
    right_lo[rr, j] = at
    return (None, best, np.concatenate([left_lo, right_lo]),
            np.concatenate([left_hi, right_hi]),
            np.concatenate([score[rows], score[rows]]), stuck)


def decide(csp: CSP, root: int, sense: str, threshold: float,
           budget: Optional[BPBudget] = None) -> Verdict:
    """Batched-box `decide`: same three-valued contract as `decide_scalar`
    (UNSAT is certified, SAT carries a witness, UNKNOWN = budget out), but
    the frontier is popped and split in best-first batches of vectorized
    rows instead of one Python box at a time.
    """
    return decide_multi(((csp, root),), sense, threshold, budget)


def decide_multi(entries, sense: str, threshold: float,
                 budget: Optional[BPBudget] = None) -> Verdict:
    """Batched-box engine for a multi-phase (OR-composed) query.

    The phase id is an extra leading axis folded into the box frontier:
    rows of every phase live in ONE `(N, max_nvars)` lo/hi tensor (short
    phases are padded with inert point columns) tagged by a per-row phase
    index, so all phases share the same best-first loop, node budget, and
    anytime deadline.  Each popped batch is regrouped by phase and run
    through that phase's compiled op table.  SAT short-circuits on any
    phase; UNSAT certifies that *every* phase's frontier was refuted.
    """
    with obs.span("smt.decide", engine="batched", phases=len(entries),
                  sense=sense, threshold=threshold) as sp:
        return _decide_multi(entries, sense, threshold, budget, sp)


def _decide_multi(entries, sense: str, threshold: float,
                  budget: Optional[BPBudget], sp) -> Verdict:
    t0 = time.perf_counter()
    bud = budget or BPBudget()
    progs = [compile_csp(c) for c, _ in entries]
    nv = max(p.nvars for p in progs)
    maximize = sense == "ge"
    query = (Interval(threshold, _INF) if maximize
             else Interval(-_INF, threshold))
    rows_lo, rows_hi, rows_ph = [], [], []
    for pi, ((csp, root), prog) in enumerate(zip(entries, progs)):
        m = _meet(Interval(float(prog.init_lo[root]),
                           float(prog.init_hi[root])), query)
        if m is None:
            continue                              # phase refuted up front
        lo = np.zeros(nv)
        hi = np.zeros(nv)
        lo[:prog.nvars] = prog.init_lo
        hi[:prog.nvars] = prog.init_hi
        lo[root] = m.lo
        hi[root] = m.hi
        rows_lo.append(lo)
        rows_hi.append(hi)
        rows_ph.append(pi)
    if not rows_lo:
        sp.set(status=UNSAT, nodes=0, frontier_peak=0)
        return Verdict(UNSAT)
    f_lo = np.stack(rows_lo)
    f_hi = np.stack(rows_hi)
    f_ph = np.array(rows_ph, np.int32)
    f_score = np.zeros(len(rows_ph))
    peak = f_lo.shape[0]

    def _done(v: Verdict) -> Verdict:
        STATS.add("boxes", v.nodes)
        STATS.add("secs", time.perf_counter() - t0)
        sp.set(status=v.status, nodes=v.nodes, frontier_peak=peak)
        return v

    frozen_sets: Dict[int, set] = {}
    best: Optional[float] = None
    nodes = 0
    stuck = False
    while f_lo.shape[0]:
        peak = max(peak, f_lo.shape[0])
        remaining = bud.max_nodes - nodes
        if remaining <= 0 or time.monotonic() > bud.deadline:
            return _done(Verdict(UNKNOWN, best, nodes))
        B = min(f_lo.shape[0], remaining, bud.batch)
        if B < f_lo.shape[0]:          # pop the best-scored B boxes
            order = np.argpartition(-f_score, B - 1)
            take, keep = order[:B], order[B:]
            lo, hi, ph = f_lo[take], f_hi[take], f_ph[take]
            f_lo, f_hi, f_ph, f_score = (f_lo[keep], f_hi[keep],
                                         f_ph[keep], f_score[keep])
        else:
            lo, hi, ph = f_lo, f_hi, f_ph
            f_lo = np.empty((0, nv))
            f_hi = np.empty((0, nv))
            f_ph = np.empty(0, np.int32)
            f_score = np.empty(0)
        nodes += B
        for pi in np.unique(ph):
            pi = int(pi)
            csp, root = entries[pi]
            prog = progs[pi]
            if pi not in frozen_sets:
                frozen_sets[pi] = {int(i)
                                   for i in np.nonzero(prog.frozen)[0]}
            rows = np.nonzero(ph == pi)[0]
            g_lo = lo[rows][:, :prog.nvars]
            g_hi = hi[rows][:, :prog.nvars]
            sat_v, best, k_lo, k_hi, k_sc, g_stuck = _group_step(
                csp, prog, root, g_lo, g_hi, maximize, threshold, best,
                bud, frozen_sets[pi])
            if sat_v is not None:
                return _done(Verdict(SAT, sat_v, nodes))
            stuck = stuck or g_stuck
            if len(k_lo):
                if prog.nvars < nv:    # pad children back to the frontier
                    pad_lo = np.zeros((len(k_lo), nv))
                    pad_hi = np.zeros((len(k_lo), nv))
                    pad_lo[:, :prog.nvars] = k_lo
                    pad_hi[:, :prog.nvars] = k_hi
                    k_lo, k_hi = pad_lo, pad_hi
                f_lo = np.concatenate([f_lo, k_lo])
                f_hi = np.concatenate([f_hi, k_hi])
                f_ph = np.concatenate(
                    [f_ph, np.full(len(k_lo), pi, np.int32)])
                f_score = np.concatenate([f_score, k_sc])
    status = UNKNOWN if stuck else UNSAT
    return _done(Verdict(status, best, nodes))
