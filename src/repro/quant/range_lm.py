"""Static alpha-analysis for transformer stages — the paper's Algorithm 1
applied to an LM's tensor-class DAG.

The homogeneity argument transfers: every token's activation at a given
tensor class (block input, qkv out, mlp hidden, ...) shares range
statistics, and every layer of the same class is pooled (max over the
stacked-layer weight statistics), so ONE combined interval per class
suffices — exactly the per-stage pooling the paper does for pixels.

Transfer functions:
  rmsnorm   : |out_i| <= gamma_i * sqrt(D)             (since |x_i/rms| <= sqrt(D))
  matmul    : |y_i|  <= max_i sum_j |W_ji| * max|x|    (L1 column norm)
  softmax   : probs in [0, 1] -> attn out bounded by value range
  silu(g)*u : |.| <= max(|g|) * |u| and silu >= -0.2785
  residual  : interval sum

Like the paper's image pipelines, the static estimates are sound but
loosen with depth (the residual stream's bound grows linearly in L);
profile calibration (`repro.quant.calibrate`) tightens them — Table IX's
static-vs-profile gap, reproduced on transformers.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.interval import Interval
from repro.models.common import ModelConfig


def _absmax(x) -> float:
    return float(jnp.max(jnp.abs(x)))


def _l1_col_max(w) -> float:
    """max_i sum_j |W[j, i]| over the last two dims (pooled over layers)."""
    w = jnp.abs(jnp.asarray(w, jnp.float32))
    col = jnp.sum(w, axis=-2)          # sum over input dim
    return float(jnp.max(col))


def static_ranges(params, cfg: ModelConfig) -> Dict[str, Interval]:
    """Per-tensor-class value ranges from weights alone (no data)."""
    D = cfg.d_model
    sq = float(np.sqrt(D))
    out: Dict[str, Interval] = {}

    emb = _absmax(params["embed"]) * cfg.emb_scale
    out["embed_out"] = Interval(-emb, emb)
    resid = out["embed_out"]

    blocks = params["blocks"]
    if cfg.arch_class in ("dense", "moe", "vlm"):
        g_attn = _absmax(blocks["ln_attn"]) * sq
        norm1 = Interval(-g_attn, g_attn)
        qkv = norm1 * _l1_col_max(blocks["attn"]["wq"])
        out["attn_qkv"] = qkv
        # softmax-weighted values stay within the value range; wo expands
        attn_out = (norm1 * _l1_col_max(blocks["attn"]["wv"])) \
            * _l1_col_max(blocks["attn"]["wo"])
        out["attn_out"] = attn_out
        g_mlp = _absmax(blocks["ln_mlp"]) * sq
        norm2 = Interval(-g_mlp, g_mlp)
        key = "moe" if cfg.is_moe else "mlp"
        gate_b = _l1_col_max(blocks[key]["w_gate"]) * g_mlp
        up_b = _l1_col_max(blocks[key]["w_up"]) * g_mlp
        h = Interval(-gate_b * up_b, gate_b * up_b)     # silu(g)*u bound
        out["mlp_hidden"] = h
        mlp_out = h * _l1_col_max(blocks[key]["w_down"])
        out["mlp_out"] = mlp_out
        per_layer = attn_out.abs().hi + mlp_out.abs().hi
    elif cfg.arch_class == "rwkv":
        g1 = _absmax(blocks["ln1"]) * sq
        n1 = Interval(-g1, g1)
        out["attn_qkv"] = n1 * _l1_col_max(blocks["tmix"]["w_k"])
        attn_out = n1 * _l1_col_max(blocks["tmix"]["w_o"])
        out["attn_out"] = attn_out
        g2 = _absmax(blocks["ln2"]) * sq
        kk = Interval(0.0, (_l1_col_max(blocks["cmix"]["w_k"]) * g2) ** 2)
        out["mlp_hidden"] = kk
        mlp_out = kk * _l1_col_max(blocks["cmix"]["w_v"])
        out["mlp_out"] = mlp_out
        per_layer = attn_out.abs().hi + mlp_out.abs().hi
    elif cfg.arch_class == "hybrid":
        g1 = _absmax(blocks["ln"]) * sq
        n1 = Interval(-g1, g1)
        proj = n1 * _l1_col_max(blocks["in_proj"])
        out["attn_qkv"] = proj
        mlp_out = Interval(-sq, sq) * _l1_col_max(blocks["out_proj"])
        out["mlp_out"] = mlp_out
        out["attn_out"] = mlp_out
        out["mlp_hidden"] = proj
        per_layer = mlp_out.abs().hi
    else:
        raise ValueError(cfg.arch_class)

    # residual stream after L layers: embed + L per-layer contributions
    # (the deep-pipeline blow-up, cf. paper Table IX)
    total = resid.abs().hi + cfg.n_layers * cfg.residual_scale * per_layer
    out["resid_final"] = Interval(-total, total)
    logit_b = total * _l1_col_max(params["unembed"]) * cfg.logit_scale
    out["logits"] = Interval(-logit_b, logit_b)
    return out


def static_alpha_table(params, cfg: ModelConfig) -> Dict[str, int]:
    from repro.core.fixedpoint import alpha_for_range
    return {k: alpha_for_range(v.lo, v.hi)
            for k, v in static_ranges(params, cfg).items()}
