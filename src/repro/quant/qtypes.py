"""Quantized tensor container + per-tensor precision assignment.

The LM realization of the paper's (alpha, beta) stage types: each named
tensor class ("attn_in", "mlp_w", ...) gets a *TensorPrecision* — either a
float format or a fixed-point/integer container with a static scale derived
from range analysis + calibration, mirroring how each pipeline stage's
buffer is typed in the FPGA design.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import FixedPointType
from repro.core.interval import Interval
from repro.core.policy import LegalizedType, legalize


@dataclasses.dataclass(frozen=True)
class TensorPrecision:
    """Precision assignment for one tensor class."""
    name: str
    range: Interval                   # analyzed/calibrated value range
    fp: Optional[FixedPointType]      # None = keep bf16/f32
    legal: LegalizedType              # TPU container after legalization

    @property
    def container(self) -> str:
        return self.legal.container

    @property
    def bits(self) -> int:
        return self.legal.bits if self.fp is not None else 16

    @staticmethod
    def from_range(name: str, rng: Interval, beta: int) -> "TensorPrecision":
        from repro.core.fixedpoint import alpha_for_range
        alpha = max(alpha_for_range(rng.lo, rng.hi), 1)
        fp = FixedPointType(alpha=alpha, beta=beta, signed=rng.lo < 0)
        return TensorPrecision(name=name, range=rng, fp=fp, legal=legalize(fp))

    @staticmethod
    def float_ref(name: str, rng: Interval) -> "TensorPrecision":
        return TensorPrecision(name=name, range=rng, fp=None,
                               legal=legalize(None))


def quantize_symmetric(x: jax.Array, bits: int = 8, axis=None):
    """Symmetric absmax quantization -> (codes, scale)."""
    qmax = 2 ** (bits - 1) - 1
    if axis is None:
        s = jnp.max(jnp.abs(x))
    else:
        s = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    s = jnp.where(s == 0, 1.0, s) / qmax
    dt = jnp.int8 if bits <= 8 else jnp.int16
    q = jnp.clip(jnp.rint(x / s), -qmax - 1, qmax).astype(dt)
    return q, s.astype(jnp.float32)


def dequantize_symmetric(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s


def fake_quant_ste(x: jax.Array, bits: int = 8, axis=None) -> jax.Array:
    """Quantize-dequantize with straight-through gradients (training path)."""

    @jax.custom_vjp
    def _fq(v):
        q, s = quantize_symmetric(v, bits, axis)
        return dequantize_symmetric(q, s).astype(v.dtype)

    def _fwd(v):
        return _fq(v), None

    def _bwd(_, g):
        return (g,)              # straight-through estimator

    _fq.defvjp(_fwd, _bwd)
    return _fq(x)


def bytes_per_element(p: TensorPrecision) -> float:
    return p.legal.bytes if p.fp is not None else 2.0   # bf16 reference
