"""Profile-driven calibration — the paper's §V-A on LM tensor classes.

Runs forward passes over calibration batches and collects per-class absmax
(activations) and per-tensor absmax (weights).  Like the Oxford-Buildings
profiling run, the calibrated ranges are usually FAR tighter than the
static interval analysis, especially for the deep residual stream
(`repro.quant.range_lm` mirrors Table IX's blow-up).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.interval import Interval
from repro.models.common import ModelConfig
from repro.models.registry import ModelBundle

# tree-path substrings defining the weight classes (the paper's "stages")
WEIGHT_CLASSES = {
    "embed": ("embed",),
    "attn": ("attn", "tmix", "cross", "in_proj", "out_proj", "shared_attn"),
    "mlp": ("mlp", "cmix", "moe", "shared_gate", "shared_up", "shared_down"),
    "unembed": ("unembed",),
}

# classes eligible for quantization, in reverse-topological order
# (output -> input), the order the paper's refinement pass visits stages
REVERSE_TOPO_CLASSES = ["unembed", "mlp", "attn", "embed"]


def classify_path(path: str) -> str | None:
    segs = path.split("/")
    # exact segment match first ("unembed" must not hit the "embed" pattern)
    for cls, pats in WEIGHT_CLASSES.items():
        if any(p in segs for p in pats):
            return cls
    for cls, pats in WEIGHT_CLASSES.items():
        if any(p in path for p in pats):
            return cls
    return None


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def weight_stats(params) -> Dict[str, Dict[str, float]]:
    """Per-class weight absmax + rms (profile analysis of the weights)."""
    stats: Dict[str, Dict[str, float]] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        cls = classify_path(_path_str(path))
        if cls is None or leaf.ndim < 2:
            continue
        s = stats.setdefault(cls, {"absmax": 0.0, "rms": 0.0, "n": 0})
        s["absmax"] = max(s["absmax"], float(jnp.max(jnp.abs(leaf))))
        s["rms"] += float(jnp.sqrt(jnp.mean(jnp.square(leaf))))
        s["n"] += 1
    for s in stats.values():
        s["rms"] /= max(s["n"], 1)
    return stats


def activation_stats(bundle: ModelBundle, params,
                     batches: Sequence[Dict]) -> Dict[str, Interval]:
    """Calibrated activation ranges: logits + residual stream absmax."""
    lo: Dict[str, float] = {}
    hi: Dict[str, float] = {}

    def upd(name, arr):
        a = np.asarray(arr, np.float32)
        lo[name] = min(lo.get(name, np.inf), float(a.min()))
        hi[name] = max(hi.get(name, -np.inf), float(a.max()))

    for b in batches:
        logits = bundle.forward(params, b)
        upd("logits", logits)
    return {k: Interval(lo[k], hi[k]) for k in lo}


def calibrated_ranges(bundle: ModelBundle, params,
                      batches: Sequence[Dict]) -> Dict[str, Interval]:
    """Static weight-based ranges refined by activation probes."""
    from repro.quant.range_lm import static_ranges
    ranges = dict(static_ranges(params, bundle.cfg))
    ranges.update(activation_stats(bundle, params, batches))
    return ranges
