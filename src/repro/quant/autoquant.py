"""AutoQuant: the paper's bit-width synthesis loop applied to LM weights.

Pipeline (mirrors paper Fig. 4):
  1. static alpha-analysis of tensor classes (`range_lm`)          — §IV-B
  2. profile calibration over probe batches (`calibrate`)          — §V-A
  3. bit-width search against a quality target, reusing the SAME
     `core.beta_search.uniform_beta_search` + a reverse-topological
     per-class refinement                                          — §V-B
  4. legalization to TPU containers + quantized parameter store

Quality metric = top-1 token agreement with the bf16 reference (the LM
analogue of HCD's "% correctly classified corners").  Search space is
weight bits in [2, 8] per class ("beta" = bits here: more bits = more
fractional resolution at fixed range, exactly the paper's knob).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.beta_search import refine_sequence, uniform_beta_search
from repro.core.fixedpoint import alpha_for_range
from repro.models.registry import ModelBundle
from repro.quant.calibrate import (REVERSE_TOPO_CLASSES, classify_path,
                                   _path_str)
from repro.quant.qtypes import quantize_symmetric, dequantize_symmetric

MAX_BITS = 8          # int8 container ceiling
MIN_BITS = 2


def fake_quant_params(params, bits_per_class: Dict[str, int]):
    """Per-channel symmetric fake-quant of every weight in a chosen class."""

    def one(path, leaf):
        cls = classify_path(_path_str(path))
        if cls is None or cls not in bits_per_class or leaf.ndim < 2:
            return leaf
        bits = bits_per_class[cls]
        if bits >= 16:
            return leaf
        q, s = quantize_symmetric(leaf, bits=bits, axis=-1)
        return dequantize_symmetric(q, s).astype(leaf.dtype)

    return jax.tree_util.tree_map_with_path(one, params)


def quantize_params_store(params, bits_per_class: Dict[str, int]):
    """True quantized store: {path: (codes int8, scales)} + passthroughs.

    This is what serving would keep in HBM (4x fewer bytes for int8, the
    paper's memory win); `dequantize_store` reconstructs compute params.
    """
    store = {}

    def one(path, leaf):
        p = _path_str(path)
        cls = classify_path(p)
        if cls is None or cls not in bits_per_class or leaf.ndim < 2:
            store[p] = ("raw", leaf)
            return leaf
        q, s = quantize_symmetric(leaf, bits=bits_per_class[cls], axis=-1)
        store[p] = ("quant", (q, s))
        return leaf

    jax.tree_util.tree_map_with_path(one, params)
    return store


def token_agreement(ref_logits, test_logits) -> float:
    a = np.asarray(jnp.argmax(ref_logits, axis=-1))
    b = np.asarray(jnp.argmax(test_logits, axis=-1))
    return float((a == b).mean())


@dataclasses.dataclass
class AutoQuantResult:
    bits: Dict[str, int]
    quality: float                 # final token agreement
    profile_passes: int
    uniform_bits: int
    bytes_ratio: float             # quantized bytes / bf16 bytes


def autoquant(bundle: ModelBundle, params, probe_batches: Sequence[Dict],
              target_agreement: float = 0.98,
              classes: Optional[List[str]] = None) -> AutoQuantResult:
    classes = classes or list(REVERSE_TOPO_CLASSES)
    fwd = jax.jit(bundle.forward)
    refs = [fwd(params, b) for b in probe_batches]
    passes = 0

    def quality(bits_map: Dict[str, int]) -> float:
        nonlocal passes
        passes += 1
        qp = fake_quant_params(params, bits_map)
        agree = [token_agreement(r, fwd(qp, b))
                 for r, b in zip(refs, probe_batches)]
        return float(np.mean(agree))

    # phase 1: uniform bit search (binary, few passes — paper §V-B)
    # quality is monotone in bits; search bits in [MIN_BITS, MAX_BITS]
    def q_of_uniform(m: Dict[str, int]) -> float:
        b = next(iter(m.values()))
        return quality({c: MIN_BITS + b for c in classes})

    span = MAX_BITS - MIN_BITS
    offset, p1 = uniform_beta_search(classes, q_of_uniform,
                                     target_agreement, beta_hi=span)
    uniform_bits = MIN_BITS + offset
    bits = {c: uniform_bits for c in classes}

    # phase 2: reverse-topological per-class refinement — the same §V-B
    # kernel the pipeline beta search uses (`core.beta_search`), with the
    # int8-container floor as the search's lower bound
    bits, _ = refine_sequence(classes, bits, quality, target_agreement,
                              beta_lo=MIN_BITS)

    final_q = quality(bits)
    # bytes: bits/16 per quantized class, uniform-weighted approximation
    ratio = float(np.mean([bits[c] / 16.0 for c in classes]))
    return AutoQuantResult(bits=bits, quality=final_q,
                           profile_passes=passes + p1,
                           uniform_bits=uniform_bits, bytes_ratio=ratio)
