"""int8 x int8 -> int32 quantized matmul Pallas kernel (MXU-aligned).

The LM-side realization of the paper's custom-width multipliers: weights and
activations legalized to int8 containers (core.policy) hit the TPU's int8
MXU path at 2x bf16 throughput and 4x fewer HBM bytes than f32.

Blocked (BM, BN, BK) matmul, K innermost with an int32 VMEM accumulator;
block shapes default to MXU-aligned 128s (any multiple works; ops.py pads).
A fused variant applies per-row/per-column dequantization scales in the
final K step so the f32 result never round-trips through HBM as int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _qmm_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _qmm_fused_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _dequant():
        # per-row a-scale x per-col b-scale epilogue, fused in VMEM
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * sa_ref[...] * sb_ref[...])


def qmatmul_i32(a_q: jax.Array, b_q: jax.Array, block_m: int = 128,
                block_n: int = 128, block_k: int = 128,
                interpret: bool = True) -> jax.Array:
    """(M, K) int8 @ (K, N) int8 -> (M, N) int32, exact."""
    M, K = a_q.shape
    K2, N = b_q.shape
    assert K == K2
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    return pl.pallas_call(
        _qmm_kernel,
        grid=(M // block_m, N // block_n, K // block_k),
        in_specs=[pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
                  pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_q, b_q)


def qmatmul_dequant(a_q: jax.Array, b_q: jax.Array, a_scale: jax.Array,
                    b_scale: jax.Array, block_m: int = 128,
                    block_n: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Fused int8 matmul + dequant: f32 (M, N) = (acc * sa[:, None] * sb[None, :]).

    a_scale: (M, 1) f32 per-row; b_scale: (1, N) f32 per-column.
    """
    M, K = a_q.shape
    _, N = b_q.shape
    assert a_scale.shape == (M, 1) and b_scale.shape == (1, N)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    return pl.pallas_call(
        _qmm_fused_kernel,
        grid=(M // block_m, N // block_n, K // block_k),
        in_specs=[pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
                  pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
                  pl.BlockSpec((block_m, 1), lambda i, j, k: (i, 0)),
                  pl.BlockSpec((1, block_n), lambda i, j, k: (0, j))],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_q, b_q, a_scale, b_scale)
