"""Public quantized-matmul API: f32 in, int8 internally, f32 out.

`matmul_quantized(a, b)` = rowwise-absmax-quantize(a) @ colwise(b), the
symmetric per-channel scheme `repro.quant` assigns when the range analysis
legalizes a matmul's operands to int8 containers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qmatmul.kernel import qmatmul_dequant, qmatmul_i32
from repro.kernels.qmatmul.ref import qmatmul_dequant_ref


def absmax_scale(x: jax.Array, axis: int, qmax: int = 127) -> jax.Array:
    s = jnp.max(jnp.abs(x), axis=axis, keepdims=True) / qmax
    return jnp.where(s == 0.0, 1.0, s)


def quantize_rows(a: jax.Array, qmax: int = 127):
    s = absmax_scale(a, axis=1, qmax=qmax)                    # (M, 1)
    q = jnp.clip(jnp.rint(a / s), -qmax - 1, qmax).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def quantize_cols(b: jax.Array, qmax: int = 127):
    s = absmax_scale(b, axis=0, qmax=qmax)                    # (1, N)
    q = jnp.clip(jnp.rint(b / s), -qmax - 1, qmax).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block", "use_ref", "interpret"))
def matmul_quantized(a: jax.Array, b: jax.Array, block: int = 128,
                     use_ref: bool = False, interpret: bool = True) -> jax.Array:
    """f32 (M, K) @ (K, N) via per-channel int8 quantization."""
    M, K = a.shape
    _, N = b.shape
    a_q, sa = quantize_rows(a)
    b_q, sb = quantize_cols(b)
    if use_ref:
        return qmatmul_dequant_ref(a_q, b_q, sa, sb)
    bm = min(block, M) if M % min(block, M) == 0 else 1
    # pad every dim to the block multiple (cheap; sliced off afterwards)
    a_q = _pad_to(_pad_to(a_q, block, 0), block, 1)
    b_q = _pad_to(_pad_to(b_q, block, 0), block, 1)
    sa_p = _pad_to(sa, block, 0)
    sb_p = _pad_to(sb, block, 1)
    out = qmatmul_dequant(a_q, b_q, sa_p, sb_p, block_m=block, block_n=block,
                          block_k=block, interpret=interpret)
    return out[:M, :N]
