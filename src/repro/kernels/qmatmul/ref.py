"""Pure-jnp oracles for the quantized matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def qmatmul_i32_ref(a_q, b_q):
    return jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32),
                   preferred_element_type=jnp.int32)


def qmatmul_dequant_ref(a_q, b_q, a_scale, b_scale):
    acc = qmatmul_i32_ref(a_q, b_q)
    return acc.astype(jnp.float32) * a_scale * b_scale
