"""Fused block-quantize / dequantize Pallas kernel.

The bit-truncation wire of the paper, as a TPU kernel: chunk a tensor into
blocks, compute one absmax scale per block, emit int8 codes + scales.  Used
by (a) the fake-quant training path and (b) quantized gradient all-reduce
compression (`repro.train.compression`) — the paper's technique applied to
collective bytes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: int):
    x = x_ref[...]
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    s = jnp.where(s == 0.0, 1.0, s)
    q_ref[...] = jnp.clip(jnp.rint(x / s), -qmax - 1, qmax).astype(jnp.int8)
    s_ref[...] = s.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def block_quantize(x: jax.Array, rows_per_tile: int = 8, qmax: int = 127,
                   interpret: bool = True):
    """x: (NB, BS) f32 -> (codes int8 (NB, BS), scales f32 (NB, 1))."""
    NB, BS = x.shape
    rt = rows_per_tile
    while NB % rt != 0:
        rt -= 1
    kern = functools.partial(_quant_kernel, qmax=qmax)
    return pl.pallas_call(
        kern,
        grid=(NB // rt,),
        in_specs=[pl.BlockSpec((rt, BS), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rt, BS), lambda i: (i, 0)),
                   pl.BlockSpec((rt, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((NB, BS), jnp.int8),
                   jax.ShapeDtypeStruct((NB, 1), jnp.float32)],
        interpret=interpret,
    )(x)


def block_dequantize(q: jax.Array, s: jax.Array, rows_per_tile: int = 8,
                     interpret: bool = True) -> jax.Array:
    NB, BS = q.shape
    rt = rows_per_tile
    while NB % rt != 0:
        rt -= 1
    return pl.pallas_call(
        _dequant_kernel,
        grid=(NB // rt,),
        in_specs=[pl.BlockSpec((rt, BS), lambda i: (i, 0)),
                  pl.BlockSpec((rt, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rt, BS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((NB, BS), jnp.float32),
        interpret=interpret,
    )(q, s)
