"""Public API: tensor-shaped fake-quant + flat compress/decompress."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.qdq.kernel import block_dequantize, block_quantize
from repro.kernels.qdq.ref import block_dequantize_ref, block_quantize_ref


def _to_blocks(x: jax.Array, block_size: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block_size
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block_size), pad


@functools.partial(jax.jit, static_argnames=("block_size", "use_ref",
                                             "interpret"))
def fake_quant(x: jax.Array, block_size: int = 256, use_ref: bool = False,
               interpret: bool = True) -> jax.Array:
    """Quantize-dequantize round trip preserving shape (STE forward)."""
    blocks, pad = _to_blocks(x, block_size)
    if use_ref:
        q, s = block_quantize_ref(blocks)
        out = block_dequantize_ref(q, s)
    else:
        q, s = block_quantize(blocks, interpret=interpret)
        out = block_dequantize(q, s, interpret=interpret)
    flat = out.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(x.shape).astype(x.dtype)


def compress(x: jax.Array, block_size: int = 256, interpret: bool = True):
    """-> (codes int8, scales f32, pad): 4x fewer bytes on the wire."""
    blocks, pad = _to_blocks(x, block_size)
    q, s = block_quantize(blocks, interpret=interpret)
    return q, s, pad


def decompress(q: jax.Array, s: jax.Array, pad: int, shape,
               interpret: bool = True) -> jax.Array:
    out = block_dequantize(q, s, interpret=interpret).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape)
