"""Pure-jnp oracle for block quantize/dequantize."""
from __future__ import annotations

import jax.numpy as jnp


def block_quantize_ref(x, qmax: int = 127):
    s = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / qmax
    s = jnp.where(s == 0.0, 1.0, s)
    q = jnp.clip(jnp.rint(x / s), -qmax - 1, qmax).astype(jnp.int8)
    return q, s.astype(jnp.float32)


def block_dequantize_ref(q, s):
    return q.astype(jnp.float32) * s
