"""Fixed-point 2-D stencil Pallas kernel — the paper's core datapath on TPU.

FPGA adaptation (DESIGN.md §2): the paper's designs stream pixels through
*line buffers* so each output pixel sees its stencil window without HBM
re-reads.  The TPU analogue keeps a band of rows (the tile + halo) resident
in VMEM: the input stays in HBM (`pl.ANY`), each grid step copies one
(TH + 2*halo)-row band, and the taps become static shifted slices combined
with integer multiply-accumulate in VREGs.

Arithmetic is the paper's saturating fixed point, exactly:

    out_q = clip( (sum_k w_q[k] * in_q[y+dy_k, x+dx_k] + round_bias) >> shift,
                  qmin, qmax )

with `in_q` the (alpha_in, beta_in) scaled integers, `w_q` the stencil
weights quantized at `w_beta` fractional bits, and
`shift = beta_in + w_beta - beta_out`.  All integer math is exact in int32
(ops.py checks the width budget), so kernel == oracle bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Tap = Tuple[int, int, int]   # (dy, dx, w_q)


def _stencil_kernel(x_ref, o_ref, *, taps: Sequence[Tap], halo: int,
                    shift: int, qmin: int, qmax: int, tile_h: int, width: int):
    i = pl.program_id(0)
    # one VMEM-resident band of rows: the line-buffer analogue
    band = x_ref[pl.ds(i * tile_h, tile_h + 2 * halo), :]
    acc = jnp.zeros((tile_h, width), jnp.int32)
    for dy, dx, wq in taps:
        if wq == 0:
            continue
        sl = band[halo + dy: halo + dy + tile_h,
                  halo + dx: halo + dx + width]
        acc = acc + wq * sl
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift     # round-half-up
    o_ref[...] = jnp.clip(acc, qmin, qmax)            # saturation mode


def fixedpoint_stencil(x_q: jax.Array, taps: Sequence[Tap], halo: int,
                       shift: int, qmin: int, qmax: int,
                       tile_h: int = 8, interpret: bool = True) -> jax.Array:
    """Apply the quantized stencil to a pre-padded scaled-int image.

    x_q: int32 (H + 2*halo, W + 2*halo), edge-padded
    returns int32 (H, W) at the output type's scale.
    """
    Hp, Wp = x_q.shape
    H, W = Hp - 2 * halo, Wp - 2 * halo
    if H % tile_h != 0:
        raise ValueError(f"H={H} not divisible by tile_h={tile_h}")
    kern = functools.partial(_stencil_kernel, taps=tuple(taps), halo=halo,
                             shift=shift, qmin=qmin, qmax=qmax,
                             tile_h=tile_h, width=W)
    return pl.pallas_call(
        kern,
        grid=(H // tile_h,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],   # stays in HBM; band-loaded
        out_specs=pl.BlockSpec((tile_h, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.int32),
        interpret=interpret,
    )(x_q)
