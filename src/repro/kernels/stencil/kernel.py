"""Fixed-point stencil Pallas kernels — the paper's core datapath on TPU.

FPGA adaptation (DESIGN.md §2): the paper's designs stream pixels through
*line buffers* so each output pixel sees its stencil window without HBM
re-reads.  The TPU analogue keeps a band of rows (the tile + halo) resident
in VMEM: the input stays in HBM (`pl.ANY`), each grid step copies one
(TH + 2*hy)-row band, and the taps become static shifted slices combined
with integer multiply-accumulate in VREGs.

Two entry points live here:

  * `fixedpoint_stencil` — the single-stage kernel (one linear stencil,
    unit stride), with per-axis halos: a horizontal-only stencil copies a
    band of TH rows (no row halo at all — the line-buffer-free case).
    Arithmetic is the paper's saturating fixed point, exactly:

        out_q = clip((sum_k w_q[k] * in_q[y+dy_k, x+dx_k] + bias) >> shift,
                     qmin, qmax)

    with `shift = beta_in + w_beta - beta_out` (round-half-up; all integer
    math exact in int32 — ops.py checks the width budget).

  * `fused_pipeline` — the multi-stage generalization the plan-driven
    lowering (`repro.lowering.pallas_backend`) compiles into: one grid
    walks a band schedule over the whole stage DAG, every intermediate
    stage's rows stay in VMEM, and taps are resolved by clamped gathers
    that handle non-unit stride, upsampling, multi-input stages, and
    edge-replicate padding without materializing anything to HBM.  The
    kernel body here owns the *geometry* (band loads, tap index algebra);
    the caller supplies each stage's datapath as a closure
    ``fn(tap, rows_abs) -> tile`` so this module stays IR-agnostic.

Stage descriptors for `fused_pipeline` are plain dicts:

    kind      "input" | "compute"
    name      stage key
    step      output rows per grid tile
    lo, L     row-span start (relative to i*step) and length
    H, W      full stage height/width
    dtype     tile/output dtype
    in_slot   (inputs) operand index of the pallas_call
    stride, upsample, fn   (compute) vertical/horizontal rates + datapath
    out_slot  optional output index

Tap resolution implements the executor's exact sampling semantics: output
row `y` of a stage reads its input at row `floor((y*sy + dy) / uy)`
(upsample-expand, shift, decimate), clamped to the valid grid — which is
provably identical to edge-padding the expanded array like
`dsl.exec._pad_inputs` does.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Tap = Tuple[int, int, int]   # (dy, dx, w_q)
Halo = Union[int, Tuple[int, int]]


def _halo_yx(halo: Halo) -> Tuple[int, int]:
    if isinstance(halo, tuple):
        return halo
    return (int(halo), int(halo))


def _stencil_kernel(x_ref, o_ref, *, taps: Sequence[Tap], halo: Tuple[int, int],
                    shift: int, qmin: int, qmax: int, tile_h: int, width: int):
    i = pl.program_id(0)
    hy, hx = halo
    # one VMEM-resident band of rows: the line-buffer analogue (hy rows of
    # halo only — a horizontal stencil's band is just its own tile rows)
    band = x_ref[pl.ds(i * tile_h, tile_h + 2 * hy), :]
    acc = jnp.zeros((tile_h, width), jnp.int32)
    for dy, dx, wq in taps:
        if wq == 0:
            continue
        sl = band[hy + dy: hy + dy + tile_h,
                  hx + dx: hx + dx + width]
        acc = acc + wq * sl
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift     # round-half-up
    o_ref[...] = jnp.clip(acc, qmin, qmax)            # saturation mode


def fixedpoint_stencil(x_q: jax.Array, taps: Sequence[Tap], halo: Halo,
                       shift: int, qmin: int, qmax: int,
                       tile_h: int = 8, interpret: bool = True) -> jax.Array:
    """Apply the quantized stencil to a pre-padded scaled-int image.

    x_q: int32 (H + 2*hy, W + 2*hx), edge-padded per axis
    returns int32 (H, W) at the output type's scale.
    """
    hy, hx = _halo_yx(halo)
    Hp, Wp = x_q.shape
    H, W = Hp - 2 * hy, Wp - 2 * hx
    if H % tile_h != 0:
        raise ValueError(f"H={H} not divisible by tile_h={tile_h}")
    kern = functools.partial(_stencil_kernel, taps=tuple(taps),
                             halo=(hy, hx), shift=shift, qmin=qmin,
                             qmax=qmax, tile_h=tile_h, width=W)
    return pl.pallas_call(
        kern,
        grid=(H // tile_h,),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],   # stays in HBM; band-loaded
        out_specs=pl.BlockSpec((tile_h, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.int32),
        interpret=interpret,
    )(x_q)


# ---------------------------------------------------------------------------
# fused multi-stage band kernel
# ---------------------------------------------------------------------------

def eval_band(program: Sequence[Dict], i, load_band) -> Dict[str, jax.Array]:
    """Evaluate one band step `i` of a fused stage program.

    This is the ONE definition of the band geometry — the tap index
    algebra and edge-replicate clamps — shared by the pallas kernel
    (`_fused_kernel`, where `load_band` slices an HBM ref) and the
    `shard_map` band-sharded executor (`repro.lowering.sharded`, where
    `load_band` is a `dynamic_slice` on a device-local array).  Sharing
    it is what makes the sharded program bit-identical to the fused
    kernel by construction.

    `load_band(d, start)` returns the contiguous `(d["L"], d["W"])` band
    of input descriptor `d` beginning at (already-clamped) row `start`.
    `i` may be a traced index (pallas `program_id` or a shard's
    `axis_index`-derived step).  Returns the full tile dict.
    """
    by_name = {d["name"]: d for d in program}
    tiles: Dict[str, jax.Array] = {}
    for d in program:
        start = i * d["step"] + d["lo"]
        L, H = d["L"], d["H"]
        if d["kind"] == "input":
            # contiguous band load (the line-buffer copy), then reorder
            # with clamped indices for the edge-replicate rows
            b = jnp.clip(start, 0, H - L)
            band = load_band(d, b)
            idx = jnp.clip(start + jnp.arange(L), 0, H - 1) - b
            tiles[d["name"]] = jnp.take(band, idx, axis=0)
        else:
            rows_abs = jnp.clip(start + jnp.arange(L), 0, H - 1)
            sy, sx = d["stride"]
            uy, ux = d["upsample"]
            W = d["W"]

            def tap(pname, dy, dx, *, rows_abs=rows_abs, sy=sy, sx=sx,
                    uy=uy, ux=ux, W=W):
                pd = by_name[pname]
                p_start = i * pd["step"] + pd["lo"]
                # band-relative clamp: a no-op under a banded schedule
                # (the span pass keeps src inside the parent band) but
                # load-bearing for single-tile schedules, where the full
                # parent column is resident and clamping to [0, L-1] IS
                # the oracle's absolute edge-replicate clamp
                src = jnp.clip(
                    jnp.floor_divide(rows_abs * sy + dy, uy) - p_start,
                    0, pd["L"] - 1)
                t = jnp.take(tiles[pname], src, axis=0)
                cols = jnp.clip(jnp.floor_divide(jnp.arange(W) * sx + dx, ux),
                                0, pd["W"] - 1)
                return jnp.take(t, cols, axis=1)

            tiles[d["name"]] = d["fn"](tap, rows_abs)
    return tiles


def band_output(d: Dict, tile: jax.Array) -> jax.Array:
    """The `step` output rows of a stage's band tile (drops the halo)."""
    return tile[-d["lo"]: -d["lo"] + d["step"]]


def _write_outputs(program: Sequence[Dict], tiles: Dict, out_refs,
                   batched: bool) -> None:
    for d in program:
        slot = d.get("out_slot")
        if slot is not None:
            rows = band_output(d, tiles[d["name"]])
            if batched:
                out_refs[slot][0] = rows      # block carries a unit batch dim
            else:
                out_refs[slot][...] = rows


def _fused_kernel(*refs, program: Sequence[Dict], n_in: int, batched: bool):
    in_refs, out_refs = refs[:n_in], refs[n_in:]
    if batched:
        # batch is the outer grid axis: one image's band walk per inner
        # step, intermediates still VMEM-only per (image, band)
        bi, i = pl.program_id(0), pl.program_id(1)

        def load_band(d, start):
            return in_refs[d["in_slot"]][bi, pl.ds(start, d["L"]), :]
    else:
        i = pl.program_id(0)

        def load_band(d, start):
            return in_refs[d["in_slot"]][pl.ds(start, d["L"]), :]

    tiles = eval_band(program, i, load_band)
    _write_outputs(program, tiles, out_refs, batched)


def _fused_kernel_prefetch(*refs, program: Sequence[Dict], n_in: int,
                           n_out: int, batched: bool, nbands: int):
    """The double-buffered variant of `_fused_kernel`.

    Each HBM input gets a two-slot VMEM scratch: band `i` computes out
    of slot ``i % 2`` while the async copy of band ``i + 1`` fills the
    other slot, overlapping the HBM->VMEM line-buffer fill with compute
    (grid steps run sequentially per core, so scratch persists across
    them).  Band start rows are data-independent — the same clamped
    ``i*step + lo`` formula `eval_band` uses — so the prefetched band is
    exactly the band the direct-slice kernel would load; the datapath is
    untouched and exactness is unaffected.  Prefetch never crosses the
    image boundary of the outer batch axis: each image's first band is
    fetched under the ``i == 0`` warm-up (one bubble per image).
    """
    from jax.experimental.pallas import tpu as pltpu

    in_refs = refs[:n_in]
    out_refs = refs[n_in:n_in + n_out]
    scratch = refs[n_in + n_out:]          # (vmem, sem) pair per input
    if batched:
        bi, i = pl.program_id(0), pl.program_id(1)
    else:
        bi, i = None, pl.program_id(0)
    inputs = [d for d in program if d["kind"] == "input"]
    cur, nxt = i % 2, (i + 1) % 2

    def dma(d, slot, j):
        vmem = scratch[2 * d["in_slot"]]
        sem = scratch[2 * d["in_slot"] + 1]
        b = jnp.clip(j * d["step"] + d["lo"], 0, d["H"] - d["L"])
        src = in_refs[d["in_slot"]]
        src = src.at[bi, pl.ds(b, d["L"]), :] if batched \
            else src.at[pl.ds(b, d["L"]), :]
        return pltpu.make_async_copy(src, vmem.at[slot], sem.at[slot])

    for d in inputs:
        @pl.when(i == 0)                   # warm-up: fetch this image's
        def _(d=d):                        # first band synchronously
            dma(d, cur, i).start()

        @pl.when(i + 1 < nbands)
        def _(d=d):
            dma(d, nxt, i + 1).start()
    for d in inputs:
        dma(d, cur, i).wait()

    def load_band(d, start):
        # `start` is the same clamped row the in-flight DMA used
        return scratch[2 * d["in_slot"]][cur]

    tiles = eval_band(program, i, load_band)
    _write_outputs(program, tiles, out_refs, batched)


def fused_pipeline(program: Sequence[Dict], grid: int,
                   interpret: bool = True,
                   batch: int | None = None,
                   prefetch: bool | None = None) -> Callable:
    """Compile a band-scheduled stage program into one pallas_call.

    Returns ``f(*input_arrays) -> tuple(output_arrays)``; see the module
    docstring for the descriptor contract.  With `batch` the inputs and
    outputs carry a leading batch dimension and the grid gains an outer
    batch axis — `grid=(batch, bands)` — so every (image, band) pair is
    one grid step of the same VMEM-resident band program.

    `prefetch` selects the double-buffered band DMA
    (`_fused_kernel_prefetch`): band i+1's HBM->VMEM copy overlaps band
    i's compute through a two-slot scratch per input.  ``None`` (the
    default) enables it exactly on native TPU runs — interpret mode
    keeps the direct slice (the DMA emulation would only add overhead) —
    but an explicit ``True`` works under interpret too, which is how the
    tests pin the prefetch schedule bit-exact off-hardware.
    """
    n_in = sum(1 for d in program if d["kind"] == "input")
    outs = sorted((d for d in program if d.get("out_slot") is not None),
                  key=lambda d: d["out_slot"])
    if prefetch is None:
        prefetch = not interpret and jax.default_backend() == "tpu"
    scratch_shapes = []
    if prefetch and grid > 1:
        try:
            from jax.experimental.pallas import tpu as pltpu
        except ImportError:            # no TPU lowering available: keep
            prefetch = False           # the direct-slice kernel
    if prefetch and grid > 1:
        ins = sorted((d for d in program if d["kind"] == "input"),
                     key=lambda d: d["in_slot"])
        for d in ins:
            scratch_shapes += [pltpu.VMEM((2, d["L"], d["W"]), d["dtype"]),
                               pltpu.SemaphoreType.DMA((2,))]
        kern = functools.partial(_fused_kernel_prefetch,
                                 program=tuple(program), n_in=n_in,
                                 n_out=len(outs),
                                 batched=batch is not None, nbands=grid)
    else:
        kern = functools.partial(_fused_kernel, program=tuple(program),
                                 n_in=n_in, batched=batch is not None)
    if batch is None:
        out_specs = [pl.BlockSpec((d["step"], d["W"]), lambda i: (i, 0))
                     for d in outs]
        out_shape = [jax.ShapeDtypeStruct((d["H"], d["W"]), d["dtype"])
                     for d in outs]
        grid_dims: Tuple[int, ...] = (grid,)
    else:
        out_specs = [pl.BlockSpec((1, d["step"], d["W"]),
                                  lambda b, i: (b, i, 0)) for d in outs]
        out_shape = [jax.ShapeDtypeStruct((batch, d["H"], d["W"]),
                                          d["dtype"]) for d in outs]
        grid_dims = (batch, grid)
    call = pl.pallas_call(
        kern,
        grid=grid_dims,
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)] * n_in,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
    )

    def run(*arrays):
        out = call(*arrays)
        return out if isinstance(out, (tuple, list)) else (out,)

    return run
