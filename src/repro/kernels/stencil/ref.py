"""Pure-jnp oracle for the fixed-point stencil kernel (bit-exact)."""
from __future__ import annotations

from typing import Sequence, Tuple, Union

import jax.numpy as jnp

Tap = Tuple[int, int, int]


def fixedpoint_stencil_ref(x_q, taps: Sequence[Tap],
                           halo: Union[int, Tuple[int, int]], shift: int,
                           qmin: int, qmax: int):
    """Identical integer math to kernel.py, expressed with whole-array slices."""
    hy, hx = (halo, halo) if isinstance(halo, int) else halo
    Hp, Wp = x_q.shape
    H, W = Hp - 2 * hy, Wp - 2 * hx
    acc = jnp.zeros((H, W), jnp.int32)
    for dy, dx, wq in taps:
        if wq == 0:
            continue
        acc = acc + wq * x_q[hy + dy: hy + dy + H,
                             hx + dx: hx + dx + W].astype(jnp.int32)
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    return jnp.clip(acc, qmin, qmax)
