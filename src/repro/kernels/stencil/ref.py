"""Pure-jnp oracle for the fixed-point stencil kernel (bit-exact)."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

Tap = Tuple[int, int, int]


def fixedpoint_stencil_ref(x_q, taps: Sequence[Tap], halo: int, shift: int,
                           qmin: int, qmax: int):
    """Identical integer math to kernel.py, expressed with whole-array slices."""
    Hp, Wp = x_q.shape
    H, W = Hp - 2 * halo, Wp - 2 * halo
    acc = jnp.zeros((H, W), jnp.int32)
    for dy, dx, wq in taps:
        if wq == 0:
            continue
        acc = acc + wq * x_q[halo + dy: halo + dy + H,
                             halo + dx: halo + dx + W].astype(jnp.int32)
    if shift > 0:
        acc = (acc + (1 << (shift - 1))) >> shift
    return jnp.clip(acc, qmin, qmax)
