"""jit'd public wrapper: float image -> fixed-point stencil -> float image.

Handles weight quantization (exact where the weights are dyadic — all the
paper's stencils are w/2^k), input/output (alpha, beta) scaling, per-axis
edge padding, and the int32 width budget check.

Tap extraction is the single-stencil specialization of the general
linear-form machinery in `repro.lowering.ir` (`dyadic_weights`), with a
lossy rounding fallback at the beta cap for non-dyadic weights.
"""
from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.fixedpoint import FixedPointType
from repro.kernels.stencil.kernel import fixedpoint_stencil
from repro.kernels.stencil.ref import fixedpoint_stencil_ref
from repro.lowering.ir import dyadic_weights


def quantize_weights(weights: Sequence[Sequence[float]], scale: float,
                     max_beta: int = 12):
    """(taps, w_beta): smallest w_beta that represents scale*weights exactly,
    else max_beta.  Returns taps [(dy, dx, w_q)] centered on the kernel."""
    rows = len(weights)
    cols = max(len(r) for r in weights)
    cy, cx = rows // 2, cols // 2
    vals = [scale * w for r in weights for w in r]
    exact = dyadic_weights(vals, max_beta=max_beta)
    w_beta = exact[1] if exact is not None else max_beta
    taps = []
    for r, row in enumerate(weights):
        for c, w in enumerate(row):
            wq = int(round(scale * w * (1 << w_beta)))
            if wq != 0:
                taps.append((r - cy, c - cx, wq))
    return taps, w_beta


def tap_halo(taps) -> tuple:
    """Per-axis (hy, hx) halo of a tap list."""
    if not taps:
        return (0, 0)
    return (max(abs(dy) for dy, _, _ in taps),
            max(abs(dx) for _, dx, _ in taps))


def check_width_budget(t_in: FixedPointType, taps, w_beta: int) -> None:
    """Exactness requires the accumulator to fit int32."""
    wsum = sum(abs(w) for _, _, w in taps)
    max_abs = max(abs(t_in.int_min), t_in.int_max) * wsum
    if max_abs >= 2 ** 31:
        raise ValueError(
            f"stencil accumulator needs {math.ceil(math.log2(max_abs)) + 1} bits"
            f" > int32; reduce beta_in ({t_in}) or w_beta ({w_beta})")


@functools.partial(jax.jit, static_argnames=("taps", "t_in", "t_out",
                                             "w_beta", "tile_h", "use_ref",
                                             "interpret"))
def _stencil_fixed(img, taps, t_in: FixedPointType, t_out: FixedPointType,
                   w_beta: int, tile_h: int, use_ref: bool, interpret: bool):
    hy, hx = tap_halo(taps)
    shift = t_in.beta + w_beta - t_out.beta
    if shift < 0:
        raise ValueError("negative shift: raise w_beta or lower beta_out")
    # quantize input to scaled ints (int32 carrier)
    q = jnp.clip(jnp.rint(img * (1 << t_in.beta)), t_in.int_min,
                 t_in.int_max).astype(jnp.int32)
    q = jnp.pad(q, ((hy, hy), (hx, hx)), mode="edge")
    fn = fixedpoint_stencil_ref if use_ref else functools.partial(
        fixedpoint_stencil, tile_h=tile_h, interpret=interpret)
    out_q = fn(q, taps, (hy, hx), shift, t_out.int_min, t_out.int_max)
    return out_q.astype(jnp.float32) * (2.0 ** -t_out.beta)


def stencil_fixed(img, weights, scale: float, t_in: FixedPointType,
                  t_out: FixedPointType, tile_h: int = 8,
                  use_ref: bool = False, interpret: bool = True):
    """Public API: float (H, W) image -> fixed-point stencil -> float (H, W).

    `interpret=True` runs the Pallas kernel in interpret mode (CPU); on a
    real TPU pass interpret=False.
    """
    taps, w_beta = quantize_weights(weights, scale)
    check_width_budget(t_in, taps, w_beta)
    H = img.shape[0]
    th = tile_h
    while H % th != 0:        # shrink tile to divide the image
        th -= 1
    return _stencil_fixed(img, tuple(taps), t_in, t_out, w_beta, th,
                          use_ref, interpret)
