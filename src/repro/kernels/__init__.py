"""Pallas TPU kernels (validated in interpret mode on CPU).

stencil : fixed-point 2-D stencil with VMEM line-buffer bands
qmatmul : int8 x int8 -> int32 MXU matmul (+ fused dequant epilogue)
qdq     : fused block quantize/dequantize (fake-quant, gradient compression)
"""
