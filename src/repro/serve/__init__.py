"""Serving layer: continuous batched inference over compiled pipelines.

`PipelineServer` (`repro.serve.pipeline_server`) is the maxtext
`OfflineInference`-shaped harness: a warmup-compiled executor behind a
background batcher that packs request streams into fixed-size batches
for the batched/sharded execution backends (docs/serving.md).
"""
from repro.serve.pipeline_server import (PipelineServer, SERVE_STATS,
                                         serve_offline)

__all__ = ["PipelineServer", "SERVE_STATS", "serve_offline"]
