"""Continuous batched pipeline inference — the serving harness.

The execution backends (`repro.lowering`) compile a pipeline + bitwidth
plan into shape-specialized executors that accept a leading batch
dimension; this module puts a *server* in front of one: requests enter a
queue, a background thread packs them into fixed-size batches, and every
batch runs through ONE warmup-compiled batched program — the shape of
maxtext's ``OfflineInference`` (PAPERS.md / SNIPPETS.md), adapted from
token decode to image pipelines.

Design points (docs/serving.md):

  * **fixed batch shape** — partial batches are padded with zero frames
    up to ``batch_size``, so exactly one batched program per
    (pipeline, plan, batch shape, backend, datapath) ever compiles; pad
    frames are dropped before results are delivered.  Padding is pure
    overhead, never a semantics change: the batched programs are
    bit-for-bit per-frame independent (tests/test_serving.py).
  * **warmup** — `warmup(shapes)` drives zero batches through the
    executor so jit/pallas compilation happens before traffic; serving a
    cold shape still works, it just pays the compile on the first batch.
  * **compile caching** — the executor comes from the process-wide
    content-keyed memo (`dsl.exec`), which compiles under its lock:
    concurrent servers (or threads inside one) racing on the same key
    produce exactly one compile.
  * **drain** — `close()` serves every queued request (final partial
    batch padded), then joins the worker; `submit` after close raises.

Telemetry: each served batch is an `obs.span("serve.batch")`; the
process-wide `SERVE_STATS` counter group tracks frames / batches /
padded frames.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.dsl import exec as _exec

__all__ = ["PipelineServer", "SERVE_STATS", "serve_offline"]

SERVE_STATS = obs.CounterGroup("serve.pipeline_server",
                               frames=0, batches=0, padded=0)

_SENTINEL = object()


class _Request:
    __slots__ = ("images", "future", "t_submit")

    def __init__(self, images: List[np.ndarray]):
        self.images = images
        self.future: Future = Future()
        self.t_submit = time.monotonic()


class PipelineServer:
    """Batched serving front-end over one compiled pipeline executor.

    ``backend`` is a `run_fixed` lowered backend name — ``"lowered"``
    (fused jnp + vmap), ``"pallas"`` (fused line-buffer kernels, batch
    as the outer grid axis) or ``"sharded"`` (band-sharded shard_map
    program).  Usable as a context manager; `close()` drains.

    ``batch_timeout_s`` bounds how long the batcher holds a partial
    batch open waiting for more requests (the classic throughput vs
    tail-latency knob); 0 serves whatever is immediately queued.
    """

    def __init__(self, pipeline, types, params: Optional[dict] = None,
                 *, backend: str = "lowered", batch_size: int = 4,
                 column: Optional[str] = None, datapath: str = "exact",
                 batch_timeout_s: float = 0.002,
                 max_queue: int = 4096):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.pipeline = pipeline
        self.batch_size = int(batch_size)
        self.batch_timeout_s = float(batch_timeout_s)
        self.backend = backend
        self.cache_key = _exec.executor_cache_key(
            pipeline, types, dict(params or {}), backend, column, datapath)
        # the process-wide memo compiles under its lock: many servers on
        # one key -> one compile (pinned in tests/test_serving.py)
        self._executor = _exec._lowered_executor(
            pipeline, types, dict(params or {}), backend, column,
            datapath=datapath)
        self._input_names = pipeline.input_stages()
        # zero-copy ingestion: quantize each frame ONCE at submit into
        # its input stage's legalized container (identity for uint8
        # beta-0 full-range sources), so queued frames, pad frames and
        # the stacked batch all carry the narrow stored representation
        # and the executor skips the f64 round-trip (`B.ingest_input`)
        from repro.lowering import backends as _B
        lowered = getattr(self._executor, "lowered", None)
        self._ingest = []
        for n in self._input_names:
            ls = lowered.stages[n] if lowered is not None else None
            if ls is None or ls.t is None:
                self._ingest.append(None)
            else:
                self._ingest.append(
                    (ls.t, np.dtype(_B.store_dtype(ls))))
        self._q: "queue.Queue" = queue.Queue(maxsize=max_queue)
        self._closed = False
        self._warm: set = set()
        self._worker = threading.Thread(
            target=self._loop, name=f"serve-{pipeline.name}", daemon=True)
        self._worker.start()

    # -- request side -----------------------------------------------------

    def _quantize(self, a: np.ndarray, slot: int) -> np.ndarray:
        """Frame -> container tile: zero-copy when already container-
        dtype (pre-quantized), one numpy snap otherwise."""
        ing = self._ingest[slot]
        if ing is None:
            return np.asarray(a, dtype=np.float64)
        t, dt = ing
        a = np.asarray(a)
        if a.dtype == dt:              # pre-quantized: ship as-is
            return a
        from repro.lowering.backends import quantize_input
        return quantize_input(a.astype(np.float64), t, dt, np)

    def _normalize(self, image) -> List[np.ndarray]:
        if isinstance(image, dict):
            arrs = [np.asarray(image[n]) for n in self._input_names]
        elif isinstance(image, (tuple, list)):
            arrs = [np.asarray(a) for a in image]
        else:
            arrs = [np.asarray(image)]
        if len(arrs) != len(self._input_names):
            raise ValueError(
                f"pipeline {self.pipeline.name!r} takes "
                f"{len(self._input_names)} inputs, got {len(arrs)}")
        for a in arrs:
            if a.ndim != 2:
                raise ValueError(
                    f"submit() takes single (H, W) frames; got {a.shape}")
        return [self._quantize(a, i) for i, a in enumerate(arrs)]

    def submit(self, image) -> Future:
        """Enqueue one frame (run_fixed input convention: array / tuple /
        dict of (H, W) arrays); resolves to ``{output: (H', W') f64}``."""
        if self._closed:
            raise RuntimeError("PipelineServer is closed")
        req = _Request(self._normalize(image))
        self._q.put(req)
        return req.future

    def warmup(self, shapes: Iterable[Tuple[int, int]]) -> List[tuple]:
        """Compile the batched program for each (H, W) ahead of traffic.

        Runs one zero batch of the fixed batch shape through the
        executor per shape (and the per-shape island/kernel builds it
        implies).  Returns the warmed (batch, H, W) keys.
        """
        warmed = []
        for shape in shapes:
            h, w = shape
            key = (self.batch_size, int(h), int(w))
            if key in self._warm:
                continue
            # container-dtype zeros: compile the same narrow-ingest
            # program the quantized traffic will hit
            zeros = [np.zeros(key) if ing is None
                     else np.zeros(key, dtype=ing[1])
                     for ing in self._ingest]
            with obs.span("serve.warmup", pipeline=self.pipeline.name,
                          backend=self.backend, batch=self.batch_size,
                          h=int(h), w=int(w)):
                self._executor(dict(zip(self._input_names, zeros)))
            self._warm.add(key)
            warmed.append(key)
        return warmed

    # -- batcher side -----------------------------------------------------

    def _collect(self) -> Tuple[List[_Request], bool]:
        """Block for one request, then fill the batch until the timeout
        or the close sentinel.  Returns (requests, saw_sentinel)."""
        item = self._q.get()
        if item is _SENTINEL:
            return [], True
        reqs = [item]
        deadline = time.monotonic() + self.batch_timeout_s
        while len(reqs) < self.batch_size:
            try:
                nxt = self._q.get(timeout=max(deadline - time.monotonic(),
                                              0.0))
            except queue.Empty:
                break
            if nxt is _SENTINEL:
                return reqs, True
            reqs.append(nxt)
        return reqs, False

    def _serve_batch(self, reqs: List[_Request]) -> None:
        n = len(reqs)
        pad = self.batch_size - n
        with obs.span("serve.batch", pipeline=self.pipeline.name,
                      backend=self.backend, size=n, padded=pad):
            try:
                batch = {}
                for slot, name in enumerate(self._input_names):
                    frames = [r.images[slot] for r in reqs]
                    frames += [np.zeros_like(frames[0])] * pad
                    batch[name] = np.stack(frames)
                out = self._executor(batch)
                key = (self.batch_size,) + tuple(
                    batch[self._input_names[0]].shape[1:])
                self._warm.add(key)
            except BaseException as e:          # deliver, don't kill the loop
                for r in reqs:
                    r.future.set_exception(e)
                return
        SERVE_STATS.add("frames", n)
        SERVE_STATS.add("batches")
        SERVE_STATS.add("padded", pad)
        for b, r in enumerate(reqs):
            r.future.set_result({k: v[b] for k, v in out.items()})

    def _loop(self) -> None:
        while True:
            reqs, stop = self._collect()
            if reqs:
                self._serve_batch(reqs)
            if stop:
                return

    # -- lifecycle --------------------------------------------------------

    def close(self) -> None:
        """Drain: serve everything queued (padding the final partial
        batch), then stop the worker."""
        if self._closed:
            return
        self._closed = True
        self._q.put(_SENTINEL)
        self._worker.join()

    def __enter__(self) -> "PipelineServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_offline(server: PipelineServer, images: Sequence
                  ) -> List[Dict[str, np.ndarray]]:
    """Offline inference: submit every frame, gather in order.

    The `OfflineInference` entry point: maximal queue pressure, so the
    batcher runs full batches end to end (only the final one pads).
    """
    futures = [server.submit(im) for im in images]
    return [f.result() for f in futures]
