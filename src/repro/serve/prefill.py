"""Fused prefill: populate a decode state from a whole prompt in one pass.

The continuous batcher's slot-local fallback feeds prompts token-by-token
(correct, O(prompt) decode steps); production serving prefills the KV cache
with one full-sequence forward — this module provides that path for the
attention-cache archs and the recurrent-state archs, validated against
step-by-step decode in tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import lm
from repro.models.attention import _project_qkv
from repro.models.common import ModelConfig, rms_norm


def prefill_dense(params, tokens, cfg: ModelConfig, max_len: int
                  ) -> Tuple[jax.Array, Dict]:
    """tokens (B, S) -> (next-token logits (B, Vp), decode state at S).

    Runs the train-style forward but also captures each layer's K/V for the
    cache.  bf16 cache only (int8 prefill would quantize at the end).
    """
    assert cfg.arch_class in ("dense", "moe", "vlm")
    assert cfg.kv_cache_dtype == "bf16", "int8 prefill: quantize post-hoc"
    Bsz, S = tokens.shape
    x = lm._embed(params, tokens, cfg)
    positions = jnp.arange(S)[None, :]
    KV, hd = cfg.n_kv_heads, cfg.hd

    def body(h, layer_p):
        # capture K/V exactly as attend_train computes them
        hin = rms_norm(h, layer_p["ln_attn"], cfg.norm_eps)
        _, k, v = _project_qkv(hin, layer_p["attn"], cfg, positions)
        h = B.transformer_fwd(h, layer_p, cfg, positions=positions)
        return h, (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))

    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (jnp.einsum("bd,dv->bv", x[:, -1, :].astype(jnp.bfloat16),
                         params["unembed"].astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
              * cfg.logit_scale)

    pad = max_len - S
    state = {
        "k": jnp.pad(ks.astype(jnp.bfloat16), ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "v": jnp.pad(vs.astype(jnp.bfloat16), ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "length": jnp.asarray(S, jnp.int32),
    }
    return logits, state


def prefill_recurrent(params, tokens, cfg: ModelConfig, max_len: int
                      ) -> Tuple[jax.Array, Dict]:
    """Prefill for rwkv: run the chunked forward carrying per-layer states."""
    assert cfg.arch_class == "rwkv"
    Bsz, S = tokens.shape
    x = lm._embed(params, tokens, cfg)

    def body(h, layer_p):
        h, st = B.rwkv_fwd(h, layer_p, cfg, state=None, chunked=True)
        return h, (st["s"], st["x_att"], st["x_ffn"])

    x, (s_all, xa_all, xf_all) = jax.lax.scan(body, x, params["blocks"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (jnp.einsum("bd,dv->bv", x[:, -1, :].astype(jnp.bfloat16),
                         params["unembed"].astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
              * cfg.logit_scale)
    state = {"s": s_all, "x_att": xa_all.astype(jnp.bfloat16),
             "x_ffn": xf_all.astype(jnp.bfloat16),
             "length": jnp.asarray(S, jnp.int32)}
    return logits, state


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    if cfg.arch_class == "rwkv":
        return prefill_recurrent(params, tokens, cfg, max_len)
    return prefill_dense(params, tokens, cfg, max_len)
