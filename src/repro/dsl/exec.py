"""Pipeline executors — the paper's polymorphic generated program (§IV-C).

One evaluator, four value domains:

  * `run_float`    — f32/f64 reference design (the paper's `typ = float`)
  * `run_fixed`    — bit-accurate (alpha, beta) fixed point with saturation
                     (the paper's `typ = ap_fixed<..>`); stage outputs are
                     snapped to their stage's grid, exactly like the HLS
                     stream/line buffers typed `typ`
  * `run_abstract` — object arrays of Interval / AffineForm per pixel
                     (the paper's `typ = Easyval / yalaa::aff_e_d` switch);
                     this is the per-pixel analysis path that validates the
                     fast combined analysis in `core.range_analysis`
  * `make_jitted_fixed` — jit-compiled fixed executor for throughput

Stencil halos use edge-clamp padding.  Downsampling stages decimate their
output; upsampling stages nearest-expand their inputs before evaluation.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.absval import Domain, get_domain
from repro.core.fixedpoint import FixedPointType, fix_round
from repro.core.graph import (BinOp, Call, Cmp, Const, Expr, ParamRef,
                              Pipeline, Pow, Ref, Select, Stage)
from repro.core.interval import Interval
from repro.core.range_analysis import static_cmp

Array = Any

# per-phase fixed-point selection: stage -> ((My, Mx), residue -> type);
# the shape `BitwidthPlan.phase_types` produces (one datapath per
# sampling-lattice residue — the §IV homogeneity clusters in hardware)
PhaseTypeMap = Dict[str, Any]


# ---------------------------------------------------------------------------
# concrete evaluation (float / fixed) — jnp
# ---------------------------------------------------------------------------

def _pad_inputs(env: Dict[str, Array], stage: Stage, xp) -> Dict[str, Array]:
    """Edge-pad each input of `stage` by its per-axis halo; upsample-expand
    first.  1-D separable stencils pad only their own axis (hy, hx)."""
    hy, hx = stage.halo_yx()
    uy, ux = stage.upsample
    padded = {}
    for name in stage.inputs:
        a = env[name]
        if uy > 1 or ux > 1:
            a = xp.repeat(xp.repeat(a, uy, axis=0), ux, axis=1)
        if hy > 0 or hx > 0:
            a = xp.pad(a, ((hy, hy), (hx, hx)), mode="edge")
        padded[name] = a
    return padded


def eval_expr(e: Expr, ref: Callable, params: Dict[str, float], xp, where):
    """Evaluate an expression tree with a pluggable `Ref` resolver.

    `ref(stage, dy, dx)` returns the tap's array.  This is the ONE
    definition of concrete evaluation order — the per-stage interpreter
    resolves refs by padded-array slicing, while `repro.lowering` backends
    resolve them by (banded, clamped) gathers.  Both must route through
    this function: bit-exactness between backends relies on every floating
    op being issued in the identical order.
    """

    def go(n: Expr):
        if isinstance(n, Const):
            return n.value
        if isinstance(n, ParamRef):
            return params[n.name]
        if isinstance(n, Ref):
            return ref(n.stage, n.dy, n.dx)
        if isinstance(n, BinOp):
            l, r = go(n.left), go(n.right)
            if n.op == "+":
                return l + r
            if n.op == "-":
                return l - r
            if n.op == "*":
                return l * r
            return l / r
        if isinstance(n, Pow):
            return go(n.base) ** n.n
        if isinstance(n, Call):
            args = [go(a) for a in n.args]
            if n.fn == "abs":
                return xp.abs(args[0])
            if n.fn == "sqrt":
                return xp.sqrt(args[0])
            if n.fn == "min":
                return xp.minimum(args[0], args[1])
            return xp.maximum(args[0], args[1])
        if isinstance(n, Cmp):
            l, r = go(n.left), go(n.right)
            return {"<": l < r, "<=": l <= r, ">": l > r, ">=": l >= r}[n.op]
        if isinstance(n, Select):
            return where(go(n.cond), go(n.then), go(n.other))
        raise TypeError(type(n))

    return go(e)


def _eval_concrete(e: Expr, padded: Dict[str, Array], halo: Tuple[int, int],
                   out_shape, params: Dict[str, float], xp, where):
    H, W = out_shape
    hy, hx = halo

    def ref(stage, dy, dx):
        a = padded[stage]
        return a[hy + dy: hy + dy + H, hx + dx: hx + dx + W]

    return eval_expr(e, ref, params, xp, where)


def _stage_out_shape(stage: Stage, in_shape):
    H, W = in_shape
    H, W = H * stage.upsample[0], W * stage.upsample[1]
    return H, W


def _snap(out, t: FixedPointType, xp):
    """Round onto the (alpha, beta) grid with saturation (backend-matched)."""
    if xp is jnp:
        return fix_round(out, t)
    step = 2.0 ** t.beta
    return np.clip(np.rint(out * step), t.int_min, t.int_max) / step


def _run_concrete(pipeline: Pipeline, image, params: Dict[str, float],
                  types: Optional[Dict[str, Optional[FixedPointType]]],
                  xp=jnp, where=None,
                  phase_types: Optional[PhaseTypeMap] = None) -> Dict[str, Array]:
    if where is None:
        where = jnp.where if xp is jnp else np.where
    env: Dict[str, Array] = {}
    shapes: Dict[str, tuple] = {}
    # multi-input pipelines (e.g. optical flow) take a dict or a tuple
    # matched against input_stages() order; single arrays feed the sole input
    input_names = pipeline.input_stages()
    if isinstance(image, dict):
        inputs = image
    elif isinstance(image, (tuple, list)):
        inputs = dict(zip(input_names, image))
    else:
        inputs = {input_names[0]: image}
    for name in pipeline.topo_order():
        st = pipeline.stages[name]
        with obs.span("exec.stage", stage=name, input=st.is_input):
            if st.is_input:
                out = xp.asarray(inputs[name],
                                 dtype=jnp.float32 if xp is jnp
                                 else np.float64)
            else:
                in_shape = shapes[st.inputs[0]]
                out_shape = _stage_out_shape(st, in_shape)
                padded = _pad_inputs(env, st, xp)
                out = _eval_concrete(st.expr, padded, st.halo_yx(),
                                     out_shape, params, xp, where)
                sy, sx = st.stride
                if sy > 1 or sx > 1:
                    out = out[::sy, ::sx]
            if types is not None:
                t = types.get(name)
                raw = out
                if t is not None:
                    out = _snap(raw, t, xp)
                if phase_types is not None and name in phase_types:
                    # per-phase datapaths: each output-phase residue of the
                    # sampling lattice gets its own (alpha, beta) type,
                    # exactly like the per-residue line buffers a
                    # phase-split design would synthesize.  Residues
                    # missing from the map keep the union-column type
                    # applied above.  Each residue's strided subarray is
                    # snapped on its own — no full-array pass per phase.
                    (my, mx), tmap = phase_types[name]
                    if xp is not jnp:
                        out = np.array(out, copy=True)
                    for (ry, rx), t_ph in sorted(tmap.items()):
                        q = _snap(raw[ry::my, rx::mx], t_ph, xp)
                        if xp is jnp:
                            out = out.at[ry::my, rx::mx].set(q)
                        else:
                            out[ry::my, rx::mx] = q
        env[name] = out
        shapes[name] = tuple(out.shape)
        if obs.runtime_ranges_enabled():
            # read-only: measures the already-snapped stage value, never
            # feeds back into the computation (bit-exactness preserved)
            obs.runtime.record_stage(
                name, out, types.get(name) if types is not None else None,
                (phase_types or {}).get(name),
                backend="interp" if xp is np else "jax")
    return env


def run_float(pipeline: Pipeline, image, params: Dict[str, float] | None = None,
              backend: str = "numpy") -> Dict[str, Array]:
    """Float reference design. numpy/f64 backend by default (oracle-grade)."""
    xp = np if backend == "numpy" else jnp
    return _run_concrete(pipeline, image, params or {}, None, xp=xp)


# compiled-executor memo for the lowered run_fixed backends: repeated
# calls (per-image loops like BenchmarkSetup.fixed_envs, the serving
# batcher threads) must reuse one fused program instead of re-lowering +
# re-jitting per call.  Keyed on content, not identity, so mutated
# pipelines / type maps never hit stale entries.  LRU with a small
# configurable cap — executors pin jit caches.  All access holds
# `_LOWERED_MEMO_LOCK`, including the compile itself: concurrent
# `run_fixed` calls for the same key (the pipeline server's background
# threads) must produce EXACTLY ONE compile, and an entry one thread just
# inserted must never be evicted by a racing insert it can't see.
_LOWERED_MEMO: "OrderedDict[tuple, Callable]" = OrderedDict()
_LOWERED_MEMO_LOCK = threading.RLock()
_LOWERED_MEMO_CAP = int(os.environ.get("REPRO_EXEC_CACHE_CAP", "16"))
# executor-memo disposition (obs counter group: locked, resettable; shows
# whether benchmark loops actually reuse their fused programs)
EXEC_CACHE_STATS = obs.CounterGroup("lowering.executor_cache",
                                    hits=0, misses=0, evictions=0)

_BACKEND_OF = {"lowered": "jnp", "pallas": "pallas", "sharded": "sharded"}


def set_executor_cache_cap(cap: int) -> int:
    """Set the lowered-executor memo capacity; returns the previous cap.

    Default 16, or the `REPRO_EXEC_CACHE_CAP` env var.  Shrinking evicts
    LRU entries immediately (with `exec.executor_cache` evict events)."""
    global _LOWERED_MEMO_CAP
    if cap < 1:
        raise ValueError(f"executor cache cap must be >= 1, got {cap}")
    with _LOWERED_MEMO_LOCK:
        prev, _LOWERED_MEMO_CAP = _LOWERED_MEMO_CAP, cap
        while len(_LOWERED_MEMO) > _LOWERED_MEMO_CAP:
            _evict_locked()
    return prev


def clear_executor_cache() -> None:
    """Drop every memoized executor (test isolation / memory pressure)."""
    with _LOWERED_MEMO_LOCK:
        _LOWERED_MEMO.clear()


def _evict_locked() -> None:
    """Evict the least-recently-used entry (lock held by caller)."""
    key, _ = _LOWERED_MEMO.popitem(last=False)
    EXEC_CACHE_STATS.add("evictions")
    obs.event("exec.executor_cache", result="evict", backend=key[3],
              cap=_LOWERED_MEMO_CAP)


def executor_cache_key(pipeline: Pipeline, types, params: Dict[str, float],
                       backend: str, column: Optional[str],
                       datapath: str = "exact") -> tuple:
    """Content key of one compiled executor: (pipeline content hash,
    plan/type-map serialization, params, backend, column, datapath)."""
    from repro.analysis.driver import pipeline_content_hash
    if hasattr(types, "to_json"):          # BitwidthPlan: stable serialized
        types_key = types.to_json()
    else:
        types_key = repr(sorted((k, str(v)) for k, v in types.items()))
    return (pipeline_content_hash(pipeline), types_key,
            repr(sorted(params.items())), backend, column, datapath)


def _lowered_executor(pipeline: Pipeline, types, params: Dict[str, float],
                      backend: str, column: Optional[str],
                      datapath: str = "exact") -> Callable:
    key = executor_cache_key(pipeline, types, params, backend, column,
                             datapath)
    with _LOWERED_MEMO_LOCK:
        fn = _LOWERED_MEMO.get(key)
        if fn is not None:
            _LOWERED_MEMO.move_to_end(key)      # LRU, not FIFO: a hit is use
            EXEC_CACHE_STATS.add("hits")
            obs.event("exec.executor_cache", result="hit", backend=backend,
                      pipeline=pipeline.name)
            return fn
        EXEC_CACHE_STATS.add("misses")
        obs.event("exec.executor_cache", result="miss", backend=backend,
                  pipeline=pipeline.name)
        from repro.lowering import compile_pipeline
        be = _BACKEND_OF[backend]
        outs = list(pipeline.stages) if be == "jnp" else None
        # compile under the lock: the second thread racing for this key
        # blocks here and takes the hit path above instead of compiling
        # its own copy
        fn = compile_pipeline(pipeline, types, params=params,
                              backend=be, outputs=outs, column=column,
                              datapath=datapath)
        while len(_LOWERED_MEMO) >= _LOWERED_MEMO_CAP:
            _evict_locked()
        _LOWERED_MEMO[key] = fn
        return fn


def run_fixed(pipeline: Pipeline, image, types,
              params: Dict[str, float] | None = None,
              backend: str = "numpy",
              column: Optional[str] = None,
              datapath: str = "exact") -> Dict[str, Array]:
    """Bit-accurate fixed-point design (saturating, round-to-nearest-even).

    `types` is either a plain per-stage type map or a
    `repro.analysis.BitwidthPlan`: a plan supplies its `column` (default:
    the plan's default column) type map plus per-phase sub-types where the
    plan carries phase columns — each sampling-lattice residue is then
    quantized with its own datapath type.

    Backends:
      * ``"numpy"`` — the per-stage f64 interpreter (THE bit-exactness
        oracle every other executor is pinned against);
      * ``"jax"``   — the same per-stage walk in f32 jnp (legacy);
      * ``"lowered"`` / ``"pallas"`` / ``"sharded"`` — the plan-driven
        compile path (`repro.lowering`): one fused jit program / the
        fused line-buffer Pallas kernel / the `shard_map` band-sharded
        program.  All bit-identical to ``"numpy"``; ``"lowered"``
        returns the full stage env, ``"pallas"``/``"sharded"`` only the
        pipeline outputs (intermediates never leave VMEM / the shards).
        ``"lowered"`` and ``"pallas"`` also accept a leading batch
        dimension — ``(B, H, W)`` images run as one batched program,
        bit-identical to the per-image loop (docs/serving.md).

    `datapath` (lowered backends only) selects the carrier election:
    ``"exact"`` (int64/f64 wherever the bound needs it) or ``"narrow"``
    (int32/f32-first re-election under exactness proofs — see
    `repro.lowering.ir`).  Both are bit-identical to the numpy oracle.
    """
    if backend in _BACKEND_OF:
        run = _lowered_executor(pipeline, types, params or {}, backend,
                                column, datapath=datapath)
        return run(image)
    xp = np if backend == "numpy" else jnp
    phase_types = None
    if hasattr(types, "phase_types"):          # BitwidthPlan (duck-typed to
        plan = types                           # keep dsl import-light)
        phase_types = plan.phase_types(column) or None
        types = plan.types(column)
    names = pipeline.input_stages()
    if isinstance(image, dict):
        arrs = [np.asarray(image[n]) for n in names]
    elif isinstance(image, (tuple, list)):
        arrs = [np.asarray(a) for a in image]
    else:
        arrs = [np.asarray(image)]
    if arrs and all(a.ndim == 3 for a in arrs):
        # (B, H, W) batch: the per-image python loop — the DEFINITION the
        # batched fused executors are pinned against (docs/serving.md)
        per = [_run_concrete(pipeline,
                             dict(zip(names, [a[b] for a in arrs])),
                             params or {}, types, xp=xp,
                             phase_types=phase_types)
               for b in range(arrs[0].shape[0])]
        return {k: xp.stack([p[k] for p in per]) for k in per[0]}
    return _run_concrete(pipeline, image, params or {}, types, xp=xp,
                         phase_types=phase_types)


def make_jitted_fixed(pipeline: Pipeline,
                      types: Dict[str, Optional[FixedPointType]],
                      params: Dict[str, float],
                      outputs: Optional[list[str]] = None) -> Callable:
    """jit-compiled fixed-point executor returning the output stages only.

    Thin wrapper over the plan-driven lowering's fused jnp backend
    (`repro.lowering.compile_pipeline`) — one fused XLA program instead of
    the old per-stage f32 walk, now bit-identical to the numpy oracle.
    """
    from repro.lowering import compile_pipeline
    return compile_pipeline(pipeline, types, params=params,
                            backend="jnp", outputs=outputs or None)


# ---------------------------------------------------------------------------
# per-pixel abstract execution (§IV-C framework path)
# ---------------------------------------------------------------------------

def run_abstract(pipeline: Pipeline, image_shape, domain: str | Domain = "interval",
                 input_ranges: Optional[Dict[str, Interval]] = None,
                 ) -> Dict[str, Dict[str, Any]]:
    """Run the pipeline with per-pixel abstract values (object arrays).

    Every input pixel is a *fresh* abstract signal over the input range, so
    affine forms share noise symbols only through genuine reuse of the same
    pixel — the cancellation-aware analysis the paper gets from YalAA.

    Returns {stage: {"values": object-array, "range": Interval}} where range
    is the join over all pixels (the per-stage combined range).
    """
    dom = get_domain(domain) if isinstance(domain, str) else domain
    H, W = image_shape
    env: Dict[str, np.ndarray] = {}
    ranges: Dict[str, Interval] = {}
    param_cache: Dict[str, Any] = {}   # one shared signal per scalar parameter

    def abs_u(a): return np.frompyfunc(lambda v: v.abs(), 1, 1)(a)
    def sqrt_u(a): return np.frompyfunc(lambda v: v.sqrt(), 1, 1)(a)
    def min_u(a, b): return np.frompyfunc(lambda x, y: x.min_(y), 2, 1)(a, b)
    def max_u(a, b): return np.frompyfunc(lambda x, y: x.max_(y), 2, 1)(a, b)

    for name in pipeline.topo_order():
        st = pipeline.stages[name]
        if st.is_input:
            rng = (input_ranges or {}).get(name, st.input_range)
            vals = np.empty((H, W), dtype=object)
            for i in range(H):
                for j in range(W):
                    vals[i, j] = dom.fresh_signal(rng)
        else:
            shp = env[st.inputs[0]].shape
            oh = shp[0] * st.upsample[0]
            ow = shp[1] * st.upsample[1]
            padded = _pad_inputs(env, st, np)
            hy, hx = st.halo_yx()

            def go(n: Expr):
                if isinstance(n, Const):
                    return dom.const(n.value)
                if isinstance(n, ParamRef):
                    if n.name not in param_cache:
                        param_cache[n.name] = dom.fresh_signal(pipeline.params[n.name])
                    return param_cache[n.name]
                if isinstance(n, Ref):
                    a = padded[n.stage]
                    return a[hy + n.dy: hy + n.dy + oh,
                             hx + n.dx: hx + n.dx + ow]
                if isinstance(n, BinOp):
                    l, r = go(n.left), go(n.right)
                    if n.op == "+":
                        return l + r
                    if n.op == "-":
                        return l - r
                    if n.op == "*":
                        return l * r
                    return l / r
                if isinstance(n, Pow):
                    return go(n.base) ** n.n
                if isinstance(n, Call):
                    args = [go(a) for a in n.args]
                    if n.fn == "abs":
                        return abs_u(args[0])
                    if n.fn == "sqrt":
                        return sqrt_u(args[0])
                    if n.fn == "min":
                        return min_u(args[0], args[1])
                    return max_u(args[0], args[1])
                if isinstance(n, Select):
                    # abstract select: decide the guard pixel-wise where the
                    # operand ranges separate, join both branches otherwise
                    # (mirrors range_analysis.eval_expr_abstract, so the
                    # combined analysis stays an enclosure of this one)
                    op = n.cond.op

                    def pick(lv, rv, tv, ov):
                        taken = static_cmp(op, dom.to_interval(lv),
                                           dom.to_interval(rv))
                        if taken is True:
                            return tv
                        if taken is False:
                            return ov
                        # legacy domains: select() hook without join()
                        return tv.join(ov) if hasattr(tv, "join") \
                            else tv.select(tv, ov)

                    return np.frompyfunc(pick, 4, 1)(
                        go(n.cond.left), go(n.cond.right),
                        go(n.then), go(n.other))
                if isinstance(n, Cmp):
                    raise ValueError("bare Cmp in abstract eval")
                raise TypeError(type(n))

            vals = go(st.expr)
            vals = np.asarray(vals, dtype=object)
            sy, sx = st.stride
            if sy > 1 or sx > 1:
                vals = vals[::sy, ::sx]

        # join over pixels -> combined stage range
        lo = min(dom.to_interval(v).lo for v in vals.ravel())
        hi = max(dom.to_interval(v).hi for v in vals.ravel())
        env[name] = vals
        ranges[name] = Interval(lo, hi)

    return {n: {"values": env[n], "range": ranges[n]} for n in env}


def make_profile_runner(pipeline: Pipeline) -> Callable:
    """Adapter for `core.profile.profile_pipeline`: (image, params) -> env."""

    def runner(image, params):
        return run_float(pipeline, image, params, backend="numpy")

    return runner
