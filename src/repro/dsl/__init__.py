"""PolyMage-flavored DSL front end + polymorphic executors."""
from repro.dsl.builder import (PipelineBuilder, absv, ite, maxv, minv,
                               shifted, sqrtv)
from repro.dsl.exec import (make_jitted_fixed, run_abstract, run_fixed,
                            run_float)

__all__ = [
    "PipelineBuilder", "absv", "ite", "maxv", "minv", "shifted", "sqrtv",
    "make_jitted_fixed", "run_abstract", "run_fixed", "run_float",
]
