"""PolyMage-flavored pipeline builder (paper Listing 1 analogue).

Example (Unsharp Mask):

    p = PipelineBuilder("usm")
    img = p.image("img", 0, 255)
    blurx = p.stencil("blurx", img, [[1], [4], [6], [4], [1]], scale=1/16)
    blury = p.stencil("blury", blurx, [[1, 4, 6, 4, 1]], scale=1/16)
    sharpen = p.define("sharpen", img * (1 + W) + blury * (-W))
    masked = p.define("masked", ite(absv(img - blury) < T, img, sharpen))
    p.output(masked)
    pipe = p.build()

All handles are `Ref` expression nodes, so arbitrary point-wise arithmetic
composes with Python operators; `Stencil`/up/down-sampling helpers expand to
expression trees the analyses walk.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.core.graph import (Call, Cmp, Const, Expr, ParamRef, Pipeline, Ref,
                              Select, Stage, expr_refs, stencil_expr)
from repro.core.interval import Interval


def _wrap(e) -> Expr:
    return e if isinstance(e, Expr) else Const(float(e))


# -- expression helpers (usable inside stage definitions) --------------------

def ite(cond: Cmp, then, other) -> Select:
    """Select(Condition, then, else) — paper Listing 1's Select."""
    if not isinstance(cond, Cmp):
        raise TypeError("ite condition must be a comparison")
    return Select(cond, _wrap(then), _wrap(other))


def absv(e: Expr) -> Call:
    return Call("abs", (e,))


def sqrtv(e: Expr) -> Call:
    return Call("sqrt", (e,))


def minv(a: Expr, b: Expr) -> Call:
    return Call("min", (a, b))


def maxv(a: Expr, b: Expr) -> Call:
    return Call("max", (a, b))


def shifted(h: Ref, dy: int, dx: int) -> Ref:
    """Access pixel (i+dy, j+dx) of a stage — for hand-written stencils."""
    return Ref(h.stage, dy=h.dy + dy, dx=h.dx + dx)


class PipelineBuilder:
    def __init__(self, name: str):
        self.p = Pipeline(name)

    # -- inputs / params ------------------------------------------------------
    def image(self, name: str, lo: float, hi: float) -> Ref:
        self.p.add_stage(Stage(name=name, expr=None, is_input=True,
                               input_range=Interval(float(lo), float(hi))))
        return Ref(name)

    def param(self, name: str, lo: float, hi: float) -> ParamRef:
        self.p.add_param(name, lo, hi)
        return ParamRef(name)

    # -- stages -----------------------------------------------------------------
    def define(self, name: str, expr: Expr,
               stride: Tuple[int, int] = (1, 1),
               upsample: Tuple[int, int] = (1, 1)) -> Ref:
        inputs = tuple(dict.fromkeys(r.stage for r in expr_refs(expr)))
        self.p.add_stage(Stage(name=name, expr=expr, inputs=inputs,
                               stride=stride, upsample=upsample))
        return Ref(name)

    def stencil(self, name: str, inp: Ref, weights: Sequence[Sequence[float]],
                scale: float = 1.0,
                center: Optional[Tuple[int, int]] = None) -> Ref:
        return self.define(name, stencil_expr(inp.stage, weights, scale, center))

    def downsample(self, name: str, inp: Ref,
                   weights: Sequence[Sequence[float]], scale: float = 1.0,
                   stride: Tuple[int, int] = (2, 2)) -> Ref:
        """Filter-then-decimate along the strided axes."""
        return self.define(name, stencil_expr(inp.stage, weights, scale),
                           stride=stride)

    def upsample(self, name: str, inp: Ref,
                 weights: Sequence[Sequence[float]], scale: float = 1.0,
                 factor: Tuple[int, int] = (2, 2)) -> Ref:
        """Nearest-expand by `factor`, then smooth with the given stencil."""
        return self.define(name, stencil_expr(inp.stage, weights, scale),
                           upsample=factor)

    def output(self, h: Ref) -> None:
        self.p.mark_output(h.stage)

    def build(self) -> Pipeline:
        if not self.p.outputs:
            # default: stages nothing consumes
            for n in self.p.stages:
                if not self.p.consumers(n):
                    self.p.mark_output(n)
        return self.p
