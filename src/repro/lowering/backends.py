"""Execution backends over the lowered IR.

A backend compiles a `LoweredPipeline` into an executor
``fn(image_or_dict) -> {stage: float64 ndarray}``.  Registered backends:

  * ``interp``  — the per-stage `dsl.exec.run_fixed` oracle (numpy f64),
                  kept bit-identical by definition;
  * ``jnp``     — one fused jit program: integer multiply-accumulate
                  datapaths for provably-exact linear stages, f64 replay
                  for the rest, all under an x64 scope.  Bit-identical to
                  the oracle (see `repro.lowering.ir` for the argument);
  * ``pallas``  — the fused line-buffer kernel (`pallas_backend`).

Shared here are the datapath finishing helpers both fused backends use:
round-half-even integer shifts (== `rint` on the exact dyadic value) and
per-residue saturation grids for phase-split stages.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.fixedpoint import FixedPointType
from repro.lowering.ir import (LoweredPipeline, LoweredStage, LoweringError,
                               PhaseSnap, lower)

Executor = Callable[..., Dict[str, np.ndarray]]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# shared datapath pieces (jnp-traceable; work under jit and inside pallas)
# ---------------------------------------------------------------------------

def rhe_shift(p, t: int):
    """Round-half-even of `p / 2^t` on integer arrays (t may be <= 0).

    Bit-identical to `rint` of the exact dyadic rational — the oracle's
    `_snap` on an exact float value — including the tie-to-even cases the
    single-stage kernel's legacy round-half-up misses.
    """
    import jax.numpy as jnp
    if t <= 0:
        return p << (-t)
    base = p >> t                      # arithmetic shift == floor division
    rem = p - (base << t)
    half = 1 << (t - 1)
    inc = (rem > half) | ((rem == half) & ((base & 1) == 1))
    return base + inc.astype(p.dtype)


def residue_bounds(phase: PhaseSnap, t: FixedPointType, rows_abs, W: int):
    """(qmin, qmax) saturation grids for a phase-split stage tile.

    `rows_abs` is the (possibly traced) absolute-row index vector of the
    tile; columns are static.  Residues absent from the phase map keep the
    union-column bounds."""
    import jax.numpy as jnp
    my, mx = phase.lattice
    # scalar-only construction (no captured constant arrays — the same
    # code traces inside a pallas kernel)
    rr = (rows_abs % my).reshape(-1, 1)
    cc = (jnp.arange(W) % mx).reshape(1, -1)
    qmin = jnp.full((rows_abs.shape[0], W), t.int_min, dtype=jnp.int64)
    qmax = jnp.full((rows_abs.shape[0], W), t.int_max, dtype=jnp.int64)
    for (ry, rx), t_ph in sorted(phase.types.items()):
        mask = (rr == ry % my) & (cc == rx % mx)
        qmin = jnp.where(mask, t_ph.int_min, qmin)
        qmax = jnp.where(mask, t_ph.int_max, qmax)
    return qmin, qmax


def carrier_dtype(name: str):
    """MAC register dtype: an "int32pair" accumulates in int32 lanes."""
    import jax.numpy as jnp
    return jnp.int32 if name in ("int32", "int32pair") else jnp.int64


def store_dtype(ls: LoweredStage):
    """Tile dtype a fused backend materializes for this stage.

    The smallest *legalized* container (`core.policy.legalize`) that
    holds the stage's (alpha, beta) width: int8/uint8/int16/uint16/
    int32/uint32 — this is where the paper's bit-width savings become
    real HBM/VMEM traffic instead of a cost-model line.  Exact by
    construction: every store site (`finish_intlinear`, `snap_expr`,
    `quantize_input`) clips to ``[t.int_min, t.int_max]`` *before* the
    final ``astype``, and the legalized container holds that full range,
    so narrowing the astype never changes a stored value; loads widen
    back into the MAC carrier (``.astype(carrier)``, zero/sign-extending)
    or dequantize to f64, both lossless.  Widths 33–52 keep an int64
    container (legalize's float32 fallback would round); float-stored
    stages stay f64.
    """
    import jax.numpy as jnp
    if ls.store_float:
        return jnp.float64
    from repro.core.policy import legalize
    lt = legalize(ls.t)
    if lt.fp is not None:              # width <= 32: smallest container
        return lt.dtype
    return jnp.int64                   # 33..52 exact-int bits


def wide_store_dtype(ls: LoweredStage):
    """The pre-legalization container rule (int32/int64/f64) — kept as
    the baseline `measured bytes/pixel` is compared against."""
    import jax.numpy as jnp
    if ls.store_float:
        return jnp.float64
    return jnp.int32 if ls.t.width <= 31 else jnp.int64


def fused_store_dtype(ls: LoweredStage):
    """In-program container for the fused jnp executor's intermediates.

    Inside ONE jit program nothing between stages reaches HBM — XLA
    fuses the elementwise chains — so there the stored container is
    only visible as vector converts, and sub-32-bit lanes pessimize
    CPU XLA by ~10% (hcd) while moving zero real bytes.  Trace-time
    specialization (the AnyHLS idiom, no runtime branching): on CPU
    hosts in-program intermediates are floored at 32 bits; on TPU/GPU
    the true legalized container is kept — there narrow tiles are the
    real VMEM/HBM win.  Value-neutral either way: the clip into
    ``[t.int_min, t.int_max]`` precedes the cast and the wider
    container holds the range.  Every *materialization* point — input
    tiles, pallas band copies, island boundary buffers, sharded
    replicated buffers, serving batches — always uses the true
    `store_dtype`.
    """
    import jax
    import jax.numpy as jnp
    dt = np.dtype(store_dtype(ls))
    if jax.default_backend() in ("tpu", "gpu") or dt.itemsize >= 4:
        return store_dtype(ls)
    return jnp.uint32 if dt.kind == "u" else jnp.int32


def accumulate_intlinear(ls: LoweredStage, tap_of, zeros):
    """Shared MAC loop: `tap_of(tp)` yields the carrier-typed tap tile,
    `zeros()` a fresh carrier-typed accumulator.

    For an "int32pair" carrier the taps before `acc_split` and the rest
    accumulate in separate int32 registers, combined by ONE widening add
    before the finishing rule — bit-equal to a flat sum because integer
    adds are associative/commutative and the combined value was proved
    below 2^53 at lowering time.
    """
    import jax.numpy as jnp
    pair = ls.carrier == "int32pair" and 0 < ls.acc_split < len(ls.int_taps)
    accs = [zeros(), zeros()] if pair else [zeros()]
    for k, tp in enumerate(ls.int_taps):
        g = 1 if pair and k >= ls.acc_split else 0
        accs[g] = accs[g] + tp.W * tap_of(tp)
    if ls.carrier != "int32pair":
        return accs[0]
    acc = accs[0].astype(jnp.int64)
    if pair:
        acc = acc + accs[1].astype(jnp.int64)
    return acc


def snap_float(raw, t: FixedPointType, xp):
    """The oracle's `_snap` (numpy branch) in any xp: rint, clip, rescale."""
    step = 2.0 ** t.beta
    return xp.clip(xp.rint(raw * step), t.int_min, t.int_max) / step


def quantize_input(x, t: Optional[FixedPointType], dtype, xp):
    """Image -> scaled-int tile on `t`'s grid (oracle input snapping)."""
    if t is None:
        return x
    q = xp.clip(xp.rint(x * (2.0 ** t.beta)), t.int_min, t.int_max)
    return q.astype(dtype)


def ingest_input(x, ls: LoweredStage, xp):
    """Image (or pre-quantized container array) -> stored input tile.

    The zero-copy ingestion convention: an array arriving already in the
    stage's legalized container dtype is treated as *pre-quantized* —
    its values are the scaled integers ``rint(v * 2^beta)`` — and used
    as the stored tile directly, skipping the f64 round-trip (for a
    uint8 beta-0 full-range input the raw pixel buffer IS that tile).
    Anything else takes the oracle path: cast to f64, snap to `t`'s
    grid.  Callers must only hand container-dtype arrays that really
    are on-grid (``repro.serve`` quantizes once at submit).
    """
    dt = store_dtype(ls)
    if ls.t is not None and x.dtype == dt:
        return x
    x = x.astype(xp.float64)
    if ls.t is None:
        return x
    return quantize_input(x, ls.t, dt, xp)


def finish_intlinear(ls: LoweredStage, acc, rows_abs, W: int,
                     container=None):
    """Accumulator -> saturated scaled-int tile (union + per-residue).

    `container` overrides the stored dtype (must hold the clipped
    range; the fused jnp program passes `fused_store_dtype`)."""
    import jax.numpy as jnp
    if ls.dyadic:
        q = rhe_shift(acc * ls.sm if ls.sm != 1 else acc, ls.t_shift)
    else:
        q = jnp.rint(acc.astype(jnp.float64) * ls.cscale)
    if ls.phase is not None:
        qmin, qmax = residue_bounds(ls.phase, ls.t, rows_abs, W)
        q = jnp.clip(q, qmin, qmax)
    else:
        q = jnp.clip(q, ls.t.int_min, ls.t.int_max)
    return q.astype(container if container is not None else store_dtype(ls))


def snap_expr(ls: LoweredStage, raw, rows_abs, W: int, container=None):
    """Raw f64 stage tile -> stored tile (int grid or oracle-float).

    `container` overrides the stored dtype on the integer path (must
    hold the clipped range; the fused jnp program passes
    `fused_store_dtype`); the float paths ignore it."""
    import jax.numpy as jnp
    t = ls.t
    if t is None:
        return raw
    if ls.phase is not None and not ls.phase.int_ok:
        # residues carry different betas: store the float composite the
        # oracle stores (union snap, then per-residue re-snap of raw)
        out = snap_float(raw, t, jnp)
        my, mx = ls.phase.lattice
        rows = (rows_abs % my).reshape(-1, 1)
        cols = (jnp.arange(W) % mx).reshape(1, -1)
        for (ry, rx), t_ph in sorted(ls.phase.types.items()):
            mask = (rows == ry % my) & (cols == rx % mx)
            out = jnp.where(mask, snap_float(raw, t_ph, jnp), out)
        return out
    if ls.store_float:                  # wide type: keep the oracle floats
        return snap_float(raw, t, jnp)
    q = jnp.rint(raw * (2.0 ** t.beta))
    if ls.phase is not None:
        qmin, qmax = residue_bounds(ls.phase, t, rows_abs, W)
        q = jnp.clip(q, qmin, qmax)
    else:
        q = jnp.clip(q, t.int_min, t.int_max)
    return q.astype(container if container is not None else store_dtype(ls))


def dequant(ls: LoweredStage, tile):
    """Stored tile -> the f64 stage value the oracle's env carries."""
    import jax.numpy as jnp
    if ls.store_float:
        return tile
    return tile.astype(jnp.float64) * (2.0 ** -ls.t.beta)


def dequant_f32(ls: LoweredStage, tile):
    """Stored tile -> the *exact* f32 stage value (narrow-mode f32 path).

    Exact because the demotion proof (`ir._expr_fits_f32`) bounds the
    scaled magnitude below 2^24 and a power-of-two rescale is lossless —
    so this f32 value equals the f64 value `dequant` produces, bit for
    bit after the final upconversion."""
    import jax.numpy as jnp
    return tile.astype(jnp.float32) * np.float32(2.0 ** -ls.t.beta)


def needed_stages(lp: LoweredPipeline, outputs: Sequence[str]) -> List[str]:
    """Ancestors of `outputs` in topo order (prune dead stages)."""
    need = set()
    stack = list(outputs)
    while stack:
        n = stack.pop()
        if n in need:
            continue
        need.add(n)
        stack.extend(lp.pipeline.stages[n].inputs)
    return [n for n in lp.order if n in need]


def normalize_images(lp: LoweredPipeline, image):
    """run_fixed's input convention: dict / tuple / single array."""
    input_names = lp.pipeline.input_stages()
    if isinstance(image, dict):
        return [image[n] for n in input_names], input_names
    if isinstance(image, (tuple, list)):
        return list(image), input_names
    return [image], input_names


# ---------------------------------------------------------------------------
# fused jnp backend
# ---------------------------------------------------------------------------

def compile_jnp(lp: LoweredPipeline,
                outputs: Optional[Sequence[str]] = None) -> Executor:
    """One jitted x64 program with the oracle's padded-grid geometry.

    Integer linear stages run as int32/int64 multiply-accumulates; every
    other stage replays the oracle's f64 expression tree
    (`dsl.exec.eval_expr`) on dequantized operands.  Output dict values
    are the same float64 arrays `run_fixed(backend="numpy")` produces.

    Images with a leading batch dimension — ``(B, H, W)`` instead of
    ``(H, W)`` — run as ONE `vmap`-batched program over the same fused
    forward.  Every op in the datapath is per-pixel (MACs, shifts,
    clips, gathers; no cross-batch reduction anywhere), so the batched
    program is bit-for-bit the per-image loop (pinned in
    tests/test_serving.py).
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
    from repro.dsl.exec import _pad_inputs, _stage_out_shape, eval_expr

    outs = list(outputs or lp.pipeline.outputs)
    order = needed_stages(lp, outs)
    params = dict(lp.params)

    def forward(*images):
        tiles: Dict[str, object] = {}      # stored tiles (int grid or f64)
        vals: Dict[str, object] = {}       # f64 env values (lazy-ish)
        shapes: Dict[str, tuple] = {}
        input_names = lp.pipeline.input_stages()
        img_of = dict(zip(input_names, images))
        for name in order:
            ls = lp.stages[name]
            st = ls.stage
            if st.is_input:
                x = img_of[name]
                # trace-time branch: a container-dtype input arrives
                # pre-quantized and is the stored tile zero-copy
                tiles[name] = ingest_input(x, ls, jnp)
                vals[name] = dequant(ls, tiles[name])
                shapes[name] = x.shape
                continue
            in_shape = shapes[st.inputs[0]]
            out_shape = _stage_out_shape(st, in_shape)
            H, W = out_shape
            hy, hx = ls.halo
            if ls.kind == "intlinear":
                cdt = carrier_dtype(ls.carrier)
                padded = _pad_inputs(
                    {i: tiles[i].astype(cdt) for i in st.inputs}, st, jnp)
                sy, sx = st.stride
                # stride folded into the tap slices: decimated pixels are
                # never computed (the interpreter computes-then-drops)
                Hs, Ws = _ceil_div(H, sy), _ceil_div(W, sx)

                def tap_of(tp, padded=padded, hy=hy, hx=hx, H=H, W=W,
                           sy=sy, sx=sx):
                    a = padded[tp.stage]
                    return a[hy + tp.dy: hy + tp.dy + H: sy,
                             hx + tp.dx: hx + tp.dx + W: sx]

                acc = accumulate_intlinear(
                    ls, tap_of, lambda: jnp.zeros((Hs, Ws), cdt))
                rows_abs = jnp.arange(acc.shape[0])
                q = finish_intlinear(ls, acc, rows_abs, acc.shape[1],
                                     container=fused_store_dtype(ls))
                tiles[name] = q
            else:
                if ls.expr_dtype == "f32":
                    padded = _pad_inputs(
                        {i: dequant_f32(lp.stages[i], tiles[i])
                         for i in st.inputs}, st, jnp)
                else:
                    padded = _pad_inputs({i: vals[i] for i in st.inputs},
                                         st, jnp)

                def ref(stage, dy, dx, padded=padded, H=H, W=W,
                        hy=hy, hx=hx):
                    a = padded[stage]
                    return a[hy + dy: hy + dy + H, hx + dx: hx + dx + W]

                raw = eval_expr(st.expr, ref, params, jnp, jnp.where)
                sy, sx = st.stride
                if sy > 1 or sx > 1:
                    raw = raw[::sy, ::sx]
                rows_abs = jnp.arange(raw.shape[0])
                tiles[name] = snap_expr(ls, raw, rows_abs, raw.shape[1],
                                        container=fused_store_dtype(ls))
            vals[name] = dequant(ls, tiles[name])
            shapes[name] = tuple(vals[name].shape)
        return {k: vals[k] for k in outs}

    jitted = jax.jit(forward)
    vjitted = jax.jit(jax.vmap(forward))

    def run(image, params_override=None):
        if params_override is not None and dict(params_override) != params:
            raise ValueError("params are baked at compile time; re-lower "
                             "with the new params")
        with obs.span("exec.lowered", backend="jnp",
                      pipeline=lp.pipeline.name, outputs=len(outs)) as sp:
            imgs, in_names = normalize_images(lp, image)
            with enable_x64():
                # container-dtype frames ship narrow (zero-copy ingest);
                # everything else takes the f64 quantize path in-trace
                def to_dev(im, n):
                    a = np.asarray(im)
                    ls = lp.stages[n]
                    if ls.t is not None \
                            and a.dtype == np.dtype(store_dtype(ls)):
                        return jnp.asarray(a)
                    return jnp.asarray(a, dtype=jnp.float64)

                arrs = tuple(to_dev(im, n)
                             for im, n in zip(imgs, in_names))
                ndims = {a.ndim for a in arrs}
                if ndims == {3}:          # leading batch dim: vmap program
                    if len({a.shape[0] for a in arrs}) != 1:
                        raise LoweringError(
                            "batched inputs must share one batch size; got "
                            f"{[a.shape for a in arrs]}")
                    sp.set(batch=int(arrs[0].shape[0]))
                    out = vjitted(*arrs)
                elif ndims == {2}:
                    out = jitted(*arrs)
                else:
                    raise LoweringError(
                        f"images must all be (H, W) or all (B, H, W); got "
                        f"{[a.shape for a in arrs]}")
                res = {k: np.asarray(v) for k, v in out.items()}
        # read-only post-processing: never feeds back into the computation
        obs.runtime.record_env(res, lp, backend="jnp")
        return res

    run.lowered = lp          # introspection hook for tests/benchmarks
    return run


# ---------------------------------------------------------------------------
# interpreter (oracle) backend + registry
# ---------------------------------------------------------------------------

def compile_interp(lp: LoweredPipeline,
                   outputs: Optional[Sequence[str]] = None) -> Executor:
    """The per-stage numpy f64 oracle, as a backend (the reference).

    Batched ``(B, H, W)`` input runs as a per-image python loop — the
    DEFINITION the batched fused backends are pinned against."""
    outs = list(outputs or lp.pipeline.outputs)
    phase_types = {n: (ls.phase.lattice, dict(ls.phase.types))
                   for n, ls in lp.stages.items() if ls.phase is not None}

    def one(image, params_override):
        from repro.dsl.exec import _run_concrete
        # per-stage spans + runtime range telemetry live inside
        # `_run_concrete` (it sees every intermediate stage value)
        env = _run_concrete(lp.pipeline, image,
                            dict(params_override or lp.params), lp.types,
                            xp=np, phase_types=phase_types or None)
        return {k: np.asarray(env[k]) for k in outs}

    def run(image, params_override=None):
        imgs, names = normalize_images(lp, image)
        # the oracle is definitionally f64: a pre-quantized container
        # frame (zero-copy convention, `ingest_input`) dequantizes to
        # the on-grid value the oracle's own input snap reproduces
        def to_f64(im, n):
            a = np.asarray(im)
            ls = lp.stages[n]
            if ls.t is not None and a.dtype == np.dtype(store_dtype(ls)):
                return a.astype(np.float64) * (2.0 ** -ls.t.beta)
            return a.astype(np.float64)

        arrs = [to_f64(im, n) for im, n in zip(imgs, names)]
        with obs.span("exec.interp", backend="interp",
                      pipeline=lp.pipeline.name, outputs=len(outs)):
            if all(a.ndim == 3 for a in arrs):
                per = [one(dict(zip(names, [a[b] for a in arrs])),
                           params_override)
                       for b in range(arrs[0].shape[0])]
                return {k: np.stack([p[k] for p in per]) for k in outs}
            return one(dict(zip(names, arrs)), params_override)

    run.lowered = lp
    return run


BACKENDS = {
    "interp": compile_interp,
    "jnp": compile_jnp,
}


def register_backend(name: str, factory) -> None:
    BACKENDS[name] = factory


def compile_pipeline(pipeline, types, params=None, backend: str = "jnp",
                     outputs=None, column=None, datapath: str = "exact",
                     **kw) -> Executor:
    """Lower + compile in one call (the `repro.lowering` front door)."""
    lp = lower(pipeline, types, params=params, column=column,
               datapath=datapath)
    return compile_backend(lp, backend, outputs=outputs, **kw)


def compile_backend(lp: LoweredPipeline, backend: str = "jnp",
                    outputs=None, **kw) -> Executor:
    if backend == "pallas":
        from repro.lowering import pallas_backend  # registers itself
    elif backend == "sharded":
        from repro.lowering import sharded         # registers itself
    try:
        factory = BACKENDS[backend]
    except KeyError:
        raise LoweringError(
            f"unknown lowering backend {backend!r}; "
            f"registered: {sorted(BACKENDS)}") from None
    kinds = lp.kinds()
    with obs.span("lowering.compile", backend=backend,
                  pipeline=lp.pipeline.name, n_stages=len(lp.stages),
                  intlinear=sum(1 for k in kinds.values()
                                if k == "intlinear")):
        return factory(lp, outputs=outputs, **kw)
