"""Row-band tile schedule for the fused line-buffer (pallas) backend.

The fused kernel walks the whole stage DAG once per band of output rows,
keeping every intermediate stage's band resident in VMEM (the TPU
analogue of the paper's FPGA line buffers).  For that to be a static
program, every stage's per-tile row window must be a *translation* of the
same window: tile `i` of stage `s` covers rows

    [i * step_s + lo_s,  i * step_s + hi_s)        (clamped at the edges)

which works exactly when every per-stage row rate `r_s` (output rows per
root-image row, an exact rational through stride/upsample chains) times
the root tile height `T` is an integer.  `build_schedule` picks the
smallest such `T` dividing the image height, then runs one backward span
pass computing (lo, hi) per stage from its consumers' needs — the
tap-shifted, rate-scaled union:

    lo_p = min over consumer taps  floor((sy*lo_c + dy) / uy)
    hi_p = max over consumer taps  floor((sy*(hi_c - 1) + dy) / uy) + 1

`floor((i*step_c*sy + k) / uy) == i*step_p + floor(k / uy)` holds because
`step_c * sy / uy = step_p` is an integer by construction — the whole
point of the lattice-aligned tile height (the same divisibility argument
`smt.encoder.sampling_lattice` makes for phase-split CSPs).
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from math import gcd
from typing import Dict, List, Optional, Tuple

from repro.lowering.ir import LoweredPipeline, LoweringError


@dataclasses.dataclass
class StageSched:
    step: int          # output rows this stage advances per grid tile
    lo: int            # row-span start, relative to i*step
    hi: int            # row-span end (exclusive), relative to i*step
    H: int             # full stage height
    W: int             # full stage width

    @property
    def L(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass
class Schedule:
    grid: int                         # number of row tiles
    tile_rows: int                    # T: root-image rows per tile
    stages: Dict[str, StageSched]     # materialized stages only (topo order)
    order: List[str]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def stage_shapes(lp: LoweredPipeline, in_shape: Tuple[int, int]
                 ) -> Dict[str, Tuple[int, int]]:
    """Exact executor shapes: expand by upsample, then `[::s]` decimation."""
    shapes: Dict[str, Tuple[int, int]] = {}
    for name in lp.order:
        st = lp.stages[name].stage
        if st.is_input:
            shapes[name] = in_shape
            continue
        h, w = shapes[st.inputs[0]]
        h, w = h * st.upsample[0], w * st.upsample[1]
        shapes[name] = (_ceil_div(h, st.stride[0]),
                        _ceil_div(w, st.stride[1]))
    return shapes


def row_rates(lp: LoweredPipeline) -> Dict[str, Fraction]:
    """Output rows per root row, per stage; LoweringError on rate conflicts."""
    rates: Dict[str, Fraction] = {}
    for name in lp.order:
        st = lp.stages[name].stage
        if st.is_input:
            rates[name] = Fraction(1)
            continue
        rs = {rates[i] for i in st.inputs}
        if len(rs) != 1:
            raise LoweringError(
                f"stage {name!r} mixes inputs at different row rates "
                f"{sorted(map(str, rs))}; no uniform band schedule exists")
        rates[name] = rs.pop() * st.upsample[0] / st.stride[0]
    return rates


def build_schedule(lp: LoweredPipeline, in_shape: Tuple[int, int],
                   order: Optional[List[str]] = None,
                   outputs: Optional[List[str]] = None,
                   tile_rows: Optional[int] = None,
                   min_tile: int = 8) -> Schedule:
    """Static band schedule for `in_shape` images over `order` stages.

    `order` defaults to every stage (callers prune to output ancestors);
    `outputs` to the pipeline outputs.  Raises `LoweringError` when no
    lattice-aligned tile height exists — the caller falls back to the
    un-banded jnp backend.
    """
    order = list(order or lp.order)
    outputs = list(outputs or lp.pipeline.outputs)
    H0, _ = in_shape
    shapes = stage_shapes(lp, in_shape)
    rates = row_rates(lp)
    for name in order:
        st = lp.stages[name].stage
        if not st.is_input:
            exp = rates[name] * H0
            if exp != shapes[name][0]:
                raise LoweringError(
                    f"stage {name!r}: height {shapes[name][0]} is not "
                    f"rate-exact ({exp}); pad the image so every "
                    f"stride divides its stage height")
    base = 1
    for name in order:
        d = rates[name].denominator
        base = base * d // gcd(base, d)

    def try_tile(T: int) -> Optional[Schedule]:
        steps = {n: int(rates[n] * T) for n in order}
        lo: Dict[str, Optional[int]] = {
            n: 0 if n in outputs else None for n in order}
        hi: Dict[str, Optional[int]] = {
            n: steps[n] if n in outputs else None for n in order}
        for c in reversed(order):
            if lo[c] is None:        # dead stage w.r.t. outputs: skip
                continue
            st = lp.stages[c].stage
            if st.is_input:
                continue
            sy, uy = st.stride[0], st.upsample[0]
            for r in st.refs():
                a = (sy * lo[c] + r.dy) // uy
                b = (sy * (hi[c] - 1) + r.dy) // uy + 1
                p = r.stage
                lo[p] = a if lo[p] is None else min(lo[p], a)
                hi[p] = b if hi[p] is None else max(hi[p], b)
        stages = {}
        for n in order:
            if lo[n] is None:
                continue
            s = StageSched(step=steps[n], lo=lo[n], hi=hi[n],
                           H=shapes[n][0], W=shapes[n][1])
            if s.step < 1 or s.L > s.H:
                return None
            stages[n] = s
        return Schedule(grid=H0 // T, tile_rows=T, stages=stages,
                        order=[n for n in order if n in stages])

    if tile_rows is not None:
        if tile_rows % base or H0 % tile_rows:
            raise LoweringError(
                f"tile_rows={tile_rows} must be a multiple of {base} "
                f"and divide H={H0}")
        sched = try_tile(tile_rows)
        if sched is None:
            raise LoweringError(
                f"tile_rows={tile_rows}: a stage's band would exceed its "
                f"full height; use a larger tile")
        return sched

    candidates = sorted(T for T in range(base, H0 + 1, base) if H0 % T == 0)
    best = None
    for T in candidates:
        sched = try_tile(T)
        if sched is None:
            continue
        best = sched
        if T >= min(min_tile, H0):
            break
    if best is None:
        raise LoweringError(
            f"no lattice-aligned tile height divides H={H0} "
            f"(phase modulus {base}, halos too deep for every candidate)")
    return best
