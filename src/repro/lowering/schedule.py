"""Row-band tile schedules for the fused line-buffer (pallas) backend.

The fused kernel walks a stage subgraph once per band of output rows,
keeping every intermediate stage's band resident in VMEM (the TPU
analogue of the paper's FPGA line buffers).  For that to be a static
program, every stage's per-tile row window must be a *translation* of the
same window: tile `i` of stage `s` covers rows

    [i * step_s + lo_s,  i * step_s + hi_s)        (clamped at the edges)

which works exactly when every per-stage row rate `r_s` (output rows per
*base* row, an exact rational through stride/upsample chains) times the
base tile height `T` is an integer.  The core solver picks the smallest
such `T` dividing the base height, then runs one backward span pass
computing (lo, hi) per stage from its consumers' needs — the tap-shifted,
rate-scaled union:

    lo_p = min over consumer taps  floor((sy*lo_c + dy) / uy)
    hi_p = max over consumer taps  floor((sy*(hi_c - 1) + dy) / uy) + 1

`floor((i*step_c*sy + k) / uy) == i*step_p + floor(k / uy)` holds because
`step_c * sy / uy = step_p` is an integer by construction — the whole
point of the lattice-aligned tile height (the same divisibility argument
`smt.encoder.sampling_lattice` makes for phase-split CSPs).

Two entry points share the core:

* `build_schedule` — whole-DAG schedule anchored at the pipeline input
  (the historical interface; raises `LoweringError` on rate conflicts or
  rate-inexact heights).
* `build_island_schedule` — schedule for a *rate island*: an arbitrary
  rate-uniform stage subgraph whose external inputs are materialized
  arrays (pipeline inputs or upstream islands' stored outputs).  Rates
  are anchored at the tallest external input, so a coarse pyramid level
  schedules at rate 1 relative to itself.

`single_tile_schedule` is the universal escape hatch: one grid step whose
band is each stage's full height.  It is always valid (the kernel's
clamped gathers degenerate to whole-array gathers), so islands that
cannot be banded — rate-inexact heights, halos deeper than any aligned
tile — still fuse instead of falling back to the jnp program.
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from math import gcd
from typing import Dict, List, Optional, Set, Tuple

from repro.lowering.ir import LoweredPipeline, LoweringError


@dataclasses.dataclass
class StageSched:
    step: int          # output rows this stage advances per grid tile
    lo: int            # row-span start, relative to i*step
    hi: int            # row-span end (exclusive), relative to i*step
    H: int             # full stage height
    W: int             # full stage width

    @property
    def L(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass
class Schedule:
    grid: int                         # number of row tiles
    tile_rows: int                    # T: base rows per tile
    stages: Dict[str, StageSched]     # materialized stages only (topo order)
    order: List[str]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def stage_shapes(lp: LoweredPipeline, in_shape: Tuple[int, int]
                 ) -> Dict[str, Tuple[int, int]]:
    """Exact executor shapes: expand by upsample, then `[::s]` decimation."""
    shapes: Dict[str, Tuple[int, int]] = {}
    for name in lp.order:
        st = lp.stages[name].stage
        if st.is_input:
            shapes[name] = in_shape
            continue
        h, w = shapes[st.inputs[0]]
        h, w = h * st.upsample[0], w * st.upsample[1]
        shapes[name] = (_ceil_div(h, st.stride[0]),
                        _ceil_div(w, st.stride[1]))
    return shapes


def row_rates(lp: LoweredPipeline) -> Dict[str, Fraction]:
    """Output rows per root row, per stage; LoweringError on rate conflicts."""
    rates: Dict[str, Fraction] = {}
    for name in lp.order:
        st = lp.stages[name].stage
        if st.is_input:
            rates[name] = Fraction(1)
            continue
        rs = {rates[i] for i in st.inputs}
        if len(rs) != 1:
            raise LoweringError(
                f"stage {name!r} mixes inputs at different row rates "
                f"{sorted(map(str, rs))}; no uniform band schedule exists")
        rates[name] = rs.pop() * st.upsample[0] / st.stride[0]
    return rates


def island_rates(lp: LoweredPipeline, compute: List[str],
                 ext_inputs: List[str],
                 shapes: Dict[str, Tuple[int, int]]
                 ) -> Tuple[str, Dict[str, Fraction]]:
    """Row rates for an island, anchored at its tallest external input.

    External inputs get the *definitional* rate `H_ext / H_base`; compute
    stages propagate through stride/upsample with the same conflict and
    rate-exactness checks `build_schedule` makes globally.  Returns
    `(base_name, rates)`.
    """
    if not ext_inputs:
        raise LoweringError("island has no external inputs")
    base = max(ext_inputs, key=lambda n: shapes[n][0])
    Hb = shapes[base][0]
    rates: Dict[str, Fraction] = {
        n: Fraction(shapes[n][0], Hb) for n in ext_inputs}
    for name in compute:
        st = lp.stages[name].stage
        rs = {rates[i] for i in st.inputs}
        if len(rs) != 1:
            raise LoweringError(
                f"stage {name!r} mixes inputs at different row rates "
                f"{sorted(map(str, rs))}; no uniform band schedule exists")
        r = rs.pop() * st.upsample[0] / st.stride[0]
        if r * Hb != shapes[name][0]:
            raise LoweringError(
                f"stage {name!r}: height {shapes[name][0]} is not "
                f"rate-exact ({r * Hb}); pad the image so every "
                f"stride divides its stage height")
        rates[name] = r
    return base, rates


def _schedule_core(lp: LoweredPipeline, shapes: Dict[str, Tuple[int, int]],
                   order: List[str], outputs: List[str],
                   H_base: int, rates: Dict[str, Fraction],
                   ext: Set[str],
                   tile_rows: Optional[int], min_tile: int) -> Schedule:
    """Shared tile search + backward span pass over `order` (topo).

    `ext` marks stages treated as materialized inputs (no tap recursion
    past them); `H_base` / `rates` anchor the step arithmetic.
    """
    base = 1
    for name in order:
        d = rates[name].denominator
        base = base * d // gcd(base, d)

    def try_tile(T: int) -> Optional[Schedule]:
        steps = {n: int(rates[n] * T) for n in order}
        lo: Dict[str, Optional[int]] = {
            n: 0 if n in outputs else None for n in order}
        hi: Dict[str, Optional[int]] = {
            n: steps[n] if n in outputs else None for n in order}
        for c in reversed(order):
            if lo[c] is None:        # dead stage w.r.t. outputs: skip
                continue
            if c in ext:
                continue
            st = lp.stages[c].stage
            if st.is_input:
                continue
            sy, uy = st.stride[0], st.upsample[0]
            for r in st.refs():
                a = (sy * lo[c] + r.dy) // uy
                b = (sy * (hi[c] - 1) + r.dy) // uy + 1
                p = r.stage
                lo[p] = a if lo[p] is None else min(lo[p], a)
                hi[p] = b if hi[p] is None else max(hi[p], b)
        stages = {}
        for n in order:
            if lo[n] is None:
                continue
            s = StageSched(step=steps[n], lo=lo[n], hi=hi[n],
                           H=shapes[n][0], W=shapes[n][1])
            if s.step < 1 or s.L > s.H:
                return None
            stages[n] = s
        return Schedule(grid=H_base // T, tile_rows=T, stages=stages,
                        order=[n for n in order if n in stages])

    if tile_rows is not None:
        if tile_rows % base or H_base % tile_rows:
            raise LoweringError(
                f"tile_rows={tile_rows} must be a multiple of {base} "
                f"and divide H={H_base}")
        sched = try_tile(tile_rows)
        if sched is None:
            raise LoweringError(
                f"tile_rows={tile_rows}: a stage's band would exceed its "
                f"full height; use a larger tile")
        return sched

    candidates = sorted(T for T in range(base, H_base + 1, base)
                        if H_base % T == 0)
    best = None
    for T in candidates:
        sched = try_tile(T)
        if sched is None:
            continue
        best = sched
        if T >= min(min_tile, H_base):
            break
    if best is None:
        raise LoweringError(
            f"no lattice-aligned tile height divides H={H_base} "
            f"(phase modulus {base}, halos too deep for every candidate)")
    return best


def build_schedule(lp: LoweredPipeline, in_shape: Tuple[int, int],
                   order: Optional[List[str]] = None,
                   outputs: Optional[List[str]] = None,
                   tile_rows: Optional[int] = None,
                   min_tile: int = 8) -> Schedule:
    """Static whole-DAG band schedule for `in_shape` images.

    `order` defaults to every stage (callers prune to output ancestors);
    `outputs` to the pipeline outputs.  Raises `LoweringError` when the
    DAG mixes rates, a height is rate-inexact, or no lattice-aligned tile
    height exists — callers that want totality partition into rate
    islands instead (`repro.lowering.islands.partition_islands`).
    """
    order = list(order or lp.order)
    outputs = list(outputs or lp.pipeline.outputs)
    H0, _ = in_shape
    shapes = stage_shapes(lp, in_shape)
    rates = row_rates(lp)
    for name in order:
        st = lp.stages[name].stage
        if not st.is_input:
            exp = rates[name] * H0
            if exp != shapes[name][0]:
                raise LoweringError(
                    f"stage {name!r}: height {shapes[name][0]} is not "
                    f"rate-exact ({exp}); pad the image so every "
                    f"stride divides its stage height")
    ext = {n for n in order if lp.stages[n].stage.is_input}
    return _schedule_core(lp, shapes, order, outputs, H0, rates, ext,
                          tile_rows, min_tile)


def build_island_schedule(lp: LoweredPipeline,
                          shapes: Dict[str, Tuple[int, int]],
                          compute: List[str], ext_inputs: List[str],
                          outputs: List[str],
                          tile_rows: Optional[int] = None,
                          min_tile: int = 8) -> Schedule:
    """Band schedule for one rate island.

    `compute` is the island's stages in topo order; `ext_inputs` the
    materialized arrays it reads (pipeline inputs and/or upstream island
    outputs); `outputs` the island stages materialized back to HBM.
    Raises `LoweringError` when the island cannot be banded (callers fall
    back to `single_tile_schedule`, never to the jnp program).
    """
    base, rates = island_rates(lp, compute, ext_inputs, shapes)
    order = list(ext_inputs) + list(compute)
    return _schedule_core(lp, shapes, order, outputs, shapes[base][0],
                          rates, set(ext_inputs), tile_rows, min_tile)


def single_tile_schedule(lp: LoweredPipeline,
                         shapes: Dict[str, Tuple[int, int]],
                         compute: List[str], ext_inputs: List[str],
                         outputs: List[str]) -> Schedule:
    """Degenerate one-tile schedule: every band is the full stage height.

    Always valid: with `grid=1`, `step=H`, `lo=0`, `hi=H` the fused
    kernel's clamped band copies and tap gathers read exactly the rows
    the oracle's padded geometry reads, for any stride/upsample/height
    combination — including rate-inexact (ceil-divided) heights.
    """
    order = list(ext_inputs) + list(compute)
    stages = {n: StageSched(step=shapes[n][0], lo=0, hi=shapes[n][0],
                            H=shapes[n][0], W=shapes[n][1])
              for n in order}
    tile = max(shapes[n][0] for n in ext_inputs) if ext_inputs else \
        max(shapes[n][0] for n in order)
    return Schedule(grid=1, tile_rows=tile, stages=stages, order=order)
