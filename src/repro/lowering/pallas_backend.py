"""Fused line-buffer Pallas backend over the lowered IR.

Compiles a `LoweredPipeline` + image shape into a chain of fused
`pallas_call`s — one per *rate island* (`repro.lowering.islands`): the
DAG is partitioned into maximal band-schedulable subgraphs, each island
walks a band of every member stage's rows down the image with
intermediates resident in VMEM, and islands hand off through
materialized HBM boundary buffers holding the boundary stages' *stored*
tiles in their smallest legalized container (`backends.store_dtype`:
int8/uint8/int16/uint16/int32 scaled ints, int64 for 33–52 exact bits,
f64 only for float-stored stages).  The historical whole-DAG case is the
single-island fast path; DAGs the old backend rejected with
`LoweringError` (mixed rates, rate-inexact heights, halos deeper than
any aligned tile) now partition instead, so there is NO jnp whole-DAG
fallback left (pass `islands=False` to opt back into the raising
monolithic behavior).

Per-stage datapaths are synthesized from each `LoweredStage`:

  * `intlinear` — integer multiply-accumulate over clamped tap gathers
    (int32, an int32 *pair* with one widening combine, or int64 —
    narrow-mode election, see `repro.lowering.ir`), finished by a
    round-half-even shift (dyadic scale) or one f64 multiply + rint,
    saturated per lattice residue where the plan carries phase types;
  * `expr`      — the oracle's expression tree replayed on dequantized
    gathers (`dsl.exec.eval_expr`) in f64, or in f32 under a narrow-mode
    exactness proof, then snapped.

Everything is bit-identical to `run_fixed(backend="numpy")` (see
`repro.lowering.ir` for the exactness arguments; the band geometry is
value-equal to the oracle's padded full-array geometry by the clamp
equivalence spelled out in `kernels.stencil.kernel`, and island
boundaries reproduce the oracle's stage values exactly because the
stored representation IS the oracle's value grid).

`interpret=None` (the default) resolves by capability detection:
`interpret=False` automatically on a real TPU/GPU whose backend passes a
one-time 64-bit probe (or when the pipeline needs no 64-bit datapath),
with a graceful one-time `RuntimeWarning` fallback to interpreter mode
everywhere else — so off-accelerator CI needs no TPU runner.
"""
from __future__ import annotations

from fractions import Fraction
from typing import Dict, Optional, Sequence

import numpy as np

from repro import obs
from repro.lowering import backends as B
from repro.lowering.ir import LoweredPipeline, LoweredStage, LoweringError
from repro.lowering.islands import Island, partition_islands
from repro.lowering.schedule import Schedule, build_schedule

# ---------------------------------------------------------------------------
# capability detection
# ---------------------------------------------------------------------------

# capability warnings dedupe through the process-wide registry so every
# entry point (pallas, sharded, serve) that resolves capabilities warns
# once per process, not once per compiled executor (tests clear the set)
from repro.obs.warnonce import _WARNED as _warned  # noqa: E402


def _warn_once(msg: str) -> None:
    obs.warn_once(msg, stacklevel=4)


_probe_cache: Dict[str, bool] = {}


def needs_64bit(lp: LoweredPipeline) -> bool:
    """True when any stage's in-kernel datapath touches int64/f64."""
    for ls in lp.stages.values():
        if ls.store_float:
            return True
        if ls.t is not None and ls.t.width > 31:
            return True
        if ls.phase is not None:        # residue grids build in int64
            return True
        if ls.kind == "intlinear" and (ls.carrier == "int64"
                                       or not ls.dyadic):
            return True
        if ls.kind == "expr" and not ls.stage.is_input \
                and ls.expr_dtype == "f64":
            return True
    return False


def supports_64bit(platform: str) -> bool:
    """One-time probe: does this jax backend hold int64/f64 natively?"""
    if platform in _probe_cache:
        return _probe_cache[platform]
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        with enable_x64():
            i = jax.jit(lambda a: a.astype(jnp.int64) * ((1 << 40) + 1))(
                jnp.arange(3, dtype=jnp.int32))
            f = jax.jit(lambda a: a.astype(jnp.float64) * 2.0 ** -40)(
                jnp.arange(3, dtype=jnp.int32))
            ok = (i.dtype == jnp.int64 and int(i[2]) == 2 * ((1 << 40) + 1)
                  and f.dtype == jnp.float64
                  and float(f[1]) == 2.0 ** -40)
    except Exception:
        ok = False
    _probe_cache[platform] = bool(ok)
    return _probe_cache[platform]


def resolve_interpret(lp: Optional[LoweredPipeline] = None) -> bool:
    """Pick `interpret` for `pallas_call`: False on capable accelerators."""
    import jax
    platform = jax.default_backend()
    if platform in ("tpu", "gpu"):
        if lp is not None and needs_64bit(lp) \
                and not supports_64bit(platform):
            _warn_once(
                f"pallas: the pipeline needs 64-bit datapaths the "
                f"{platform} backend lacks; running the fused kernel in "
                f"interpret mode (narrow the plan with "
                f"lower(..., datapath='narrow'))")
            return True
        return False
    _warn_once(
        f"pallas: no TPU/GPU accelerator (jax default_backend="
        f"{platform!r}); the fused kernel runs in interpret mode")
    return True


# ---------------------------------------------------------------------------
# stage descriptors
# ---------------------------------------------------------------------------

def _input_descriptor(name: str, ls: LoweredStage, ss, slot: int):
    return dict(kind="input", name=name, step=ss.step, lo=ss.lo, L=ss.L,
                H=ss.H, W=ss.W, dtype=B.store_dtype(ls), in_slot=slot)


def _compute_descriptor(lp: LoweredPipeline, name: str, ss):
    import jax.numpy as jnp
    from repro.dsl.exec import eval_expr

    ls = lp.stages[name]
    st = ls.stage
    params = lp.params

    if ls.kind == "intlinear":
        cdt = B.carrier_dtype(ls.carrier)

        def fn(tap, rows_abs, ls=ls, cdt=cdt, W=ss.W):
            acc = B.accumulate_intlinear(
                ls,
                lambda tp: tap(tp.stage, tp.dy, tp.dx).astype(cdt),
                lambda: jnp.zeros((rows_abs.shape[0], W), cdt))
            return B.finish_intlinear(ls, acc, rows_abs, W)
    else:
        deq = B.dequant_f32 if ls.expr_dtype == "f32" else B.dequant

        def fn(tap, rows_abs, ls=ls, deq=deq, W=ss.W):
            def ref(stage, dy, dx):
                return deq(lp.stages[stage], tap(stage, dy, dx))

            raw = eval_expr(st.expr, ref, params, jnp, jnp.where)
            return B.snap_expr(ls, raw, rows_abs, W)

    return dict(kind="compute", name=name, step=ss.step, lo=ss.lo, L=ss.L,
                H=ss.H, W=ss.W, dtype=B.store_dtype(ls),
                stride=st.stride, upsample=st.upsample,
                inputs=tuple(st.inputs), fn=fn)


def island_program(lp: LoweredPipeline, isl: Island) -> list:
    """Stage descriptors (kernels.stencil.kernel contract) for one island.

    Shared with the `shard_map` band-sharded executor
    (`repro.lowering.sharded`): both execute the same descriptor list
    through `kernels.stencil.kernel.eval_band`, so their datapaths are
    identical closures by construction."""
    program = []
    slot = {n: i for i, n in enumerate(isl.inputs)}
    for n in isl.schedule.order:
        ss = isl.schedule.stages[n]
        if n in slot:
            program.append(_input_descriptor(n, lp.stages[n], ss, slot[n]))
        else:
            program.append(_compute_descriptor(lp, n, ss))
    for out_slot, n in enumerate(isl.outputs):
        for d in program:
            if d["name"] == n:
                d["out_slot"] = out_slot
    return program


# ---------------------------------------------------------------------------
# compile
# ---------------------------------------------------------------------------

def compile_pallas(lp: LoweredPipeline,
                   outputs: Optional[Sequence[str]] = None,
                   interpret: Optional[bool] = None,
                   tile_rows: Optional[int] = None,
                   islands: bool = True,
                   prefetch: Optional[bool] = None) -> B.Executor:
    """Shape-specialized executor: the island plan + kernels are built
    (and cached) per input shape on first call.

    `islands=False` opts out of partitioning: the whole DAG must band-
    schedule as one program or `LoweringError` is raised (the historical
    contract, for callers that want to catch-and-fallback themselves).

    `prefetch` (default auto: on for native TPU runs) selects the
    double-buffered two-slot band DMA so each island overlaps the next
    band's HBM->VMEM copy with the current band's compute.
    """
    from repro.kernels.stencil.kernel import fused_pipeline

    outs = list(outputs or lp.pipeline.outputs)
    order = B.needed_stages(lp, outs)
    input_names = [n for n in order if lp.stages[n].stage.is_input]
    interp = resolve_interpret(lp) if interpret is None else interpret
    cache: Dict[tuple, list] = {}

    def compile_island(isl: Island, batch: Optional[int]):
        return fused_pipeline(island_program(lp, isl),
                              grid=isl.schedule.grid,
                              interpret=interp, batch=batch,
                              prefetch=prefetch)

    def build(shape):
        # a leading batch dim becomes the kernels' outer grid axis; the
        # band plan itself is a function of the per-image (H, W) only
        batch = shape[0] if len(shape) == 3 else None
        in_shape = tuple(shape[-2:])
        if islands:
            plan = partition_islands(lp, in_shape, outputs=outs,
                                     tile_rows=tile_rows)
            isls = plan.islands
        else:
            sched: Schedule = build_schedule(lp, in_shape, order=order,
                                             outputs=outs,
                                             tile_rows=tile_rows)
            isls = [Island(0, [n for n in sched.order
                               if not lp.stages[n].stage.is_input],
                           input_names, outs, Fraction(1), sched,
                           single_tile=False)]
        return [(isl, compile_island(isl, batch)) for isl in isls]

    def run(image, params_override=None):
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        if params_override is not None and \
                dict(params_override) != lp.params:
            raise ValueError("params are baked at compile time; re-lower "
                             "with the new params")
        imgs, _ = B.normalize_images(lp, image)
        img_of = dict(zip(lp.pipeline.input_stages(), imgs))
        with obs.span("exec.pallas", backend="pallas",
                      pipeline=lp.pipeline.name, outputs=len(outs)) as sp:
            with enable_x64():
                buffers: Dict[str, object] = {}
                shape = None
                for n in input_names:
                    x = jnp.asarray(np.asarray(img_of[n]))
                    if x.ndim not in (2, 3):
                        raise LoweringError(
                            f"images must be (H, W) or (B, H, W); got "
                            f"{tuple(x.shape)}")
                    if shape is None:
                        shape = tuple(x.shape)
                    elif tuple(x.shape) != shape:
                        raise LoweringError("all pipeline inputs must share "
                                            f"one shape; got {shape} vs "
                                            f"{x.shape}")
                    # container-dtype frames are pre-quantized stored
                    # tiles (zero-copy); others quantize from f64
                    buffers[n] = B.ingest_input(x, lp.stages[n], jnp)
                if len(shape) == 3:
                    sp.set(batch=int(shape[0]))
                if shape not in cache:
                    sp.set(kernel_cache="miss")
                    cache[shape] = build(shape)
                else:
                    sp.set(kernel_cache="hit")
                compiled = cache[shape]
                sp.set(islands=len(compiled))
                for isl, call in compiled:
                    out_b, saved_b = isl.boundary_bytes(lp)
                    with obs.span("exec.pallas.island",
                                  island=isl.idx, rate=str(isl.rate),
                                  stages=len(isl.stages),
                                  grid=isl.schedule.grid,
                                  single_tile=isl.single_tile,
                                  carriers=isl.carrier_mix(lp),
                                  containers=isl.stored_mix(lp),
                                  out_mb=round(out_b / 1e6, 4),
                                  saved_mb=round(saved_b / 1e6, 4)):
                        for n, arr in zip(isl.outputs,
                                          call(*[buffers[n]
                                                 for n in isl.inputs])):
                            buffers[n] = arr
                res = {n: np.asarray(B.dequant(lp.stages[n], buffers[n]))
                       for n in outs}
        # fused kernels: intermediates never leave their island's bands,
        # so telemetry covers the materialized boundaries + outputs only
        obs.runtime.record_env(res, lp, backend="pallas")
        return res

    run.lowered = lp
    return run


B.register_backend("pallas", compile_pallas)
