"""Fused line-buffer Pallas backend over the lowered IR.

Compiles a `LoweredPipeline` + image shape into ONE `pallas_call`
(`kernels.stencil.kernel.fused_pipeline`): a band of every stage's rows
walks down the image, intermediates never touch HBM, and each stage's
datapath is synthesized from its `LoweredStage`:

  * `intlinear` — integer multiply-accumulate over clamped tap gathers,
    finished by a round-half-even shift (dyadic scale) or one f64
    multiply + rint, saturated per lattice residue where the plan carries
    phase types (one datapath per §IV homogeneity cluster);
  * `expr`      — the oracle's f64 expression tree replayed on
    dequantized gathers (`dsl.exec.eval_expr`), then snapped.

Both are bit-identical to `run_fixed(backend="numpy")` (see
`repro.lowering.ir` for the exactness argument; the band geometry is
value-equal to the oracle's padded full-array geometry by the clamp
equivalence spelled out in `kernels.stencil.kernel`).

Everything runs under an x64 scope; `interpret=True` (the default) runs
on CPU, `interpret=False` requires a real TPU — note f64/int64 stages
only lower on targets with 64-bit support, so off-TPU CI uses interpreter
mode throughout.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro import obs
from repro.lowering import backends as B
from repro.lowering.ir import LoweredPipeline, LoweredStage, LoweringError
from repro.lowering.schedule import Schedule, build_schedule


def _input_descriptor(name: str, ls: LoweredStage, ss, slot: int):
    return dict(kind="input", name=name, step=ss.step, lo=ss.lo, L=ss.L,
                H=ss.H, W=ss.W, dtype=B.store_dtype(ls), in_slot=slot)


def _compute_descriptor(lp: LoweredPipeline, name: str, ss):
    import jax.numpy as jnp
    from repro.dsl.exec import eval_expr

    ls = lp.stages[name]
    st = ls.stage
    params = lp.params

    if ls.kind == "intlinear":
        cdt = B.carrier_dtype(ls.carrier)

        def fn(tap, rows_abs, ls=ls, cdt=cdt, W=ss.W):
            acc = jnp.zeros((rows_abs.shape[0], W), cdt)
            for tp in ls.int_taps:
                acc = acc + tp.W * tap(tp.stage, tp.dy, tp.dx).astype(cdt)
            return B.finish_intlinear(ls, acc, rows_abs, W)
    else:
        def fn(tap, rows_abs, ls=ls, W=ss.W):
            def ref(stage, dy, dx):
                g = tap(stage, dy, dx)
                return B.dequant(lp.stages[stage], g)

            raw = eval_expr(st.expr, ref, params, jnp, jnp.where)
            return B.snap_expr(ls, raw, rows_abs, W)

    return dict(kind="compute", name=name, step=ss.step, lo=ss.lo, L=ss.L,
                H=ss.H, W=ss.W, dtype=B.store_dtype(ls),
                stride=st.stride, upsample=st.upsample,
                inputs=tuple(st.inputs), fn=fn)


def compile_pallas(lp: LoweredPipeline,
                   outputs: Optional[Sequence[str]] = None,
                   interpret: bool = True,
                   tile_rows: Optional[int] = None) -> B.Executor:
    """Shape-specialized executor: the schedule + kernel are built (and
    cached) per input shape on first call."""
    from repro.kernels.stencil.kernel import fused_pipeline

    outs = list(outputs or lp.pipeline.outputs)
    order = B.needed_stages(lp, outs)
    input_names = [n for n in order if lp.stages[n].stage.is_input]
    cache: Dict[tuple, object] = {}

    def build(in_shape):
        sched: Schedule = build_schedule(lp, in_shape, order=order,
                                         outputs=outs, tile_rows=tile_rows)
        program = []
        slot = {n: i for i, n in enumerate(input_names)}
        for n in sched.order:
            ls = lp.stages[n]
            ss = sched.stages[n]
            if ls.stage.is_input:
                program.append(_input_descriptor(n, ls, ss, slot[n]))
            else:
                program.append(_compute_descriptor(lp, n, ss))
        for out_slot, n in enumerate(outs):
            for d in program:
                if d["name"] == n:
                    d["out_slot"] = out_slot
        return fused_pipeline(program, grid=sched.grid, interpret=interpret)

    def run(image, params_override=None):
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        if params_override is not None and \
                dict(params_override) != lp.params:
            raise ValueError("params are baked at compile time; re-lower "
                             "with the new params")
        imgs, _ = B.normalize_images(lp, image)
        img_of = dict(zip(lp.pipeline.input_stages(), imgs))
        with obs.span("exec.pallas", backend="pallas",
                      pipeline=lp.pipeline.name, outputs=len(outs)) as sp:
            with enable_x64():
                arrays = []
                shape = None
                for n in input_names:
                    x = jnp.asarray(np.asarray(img_of[n]), dtype=jnp.float64)
                    if shape is None:
                        shape = tuple(x.shape)
                    elif tuple(x.shape) != shape:
                        raise LoweringError("all pipeline inputs must share "
                                            f"one shape; got {shape} vs "
                                            f"{x.shape}")
                    arrays.append(B.quantize_input(
                        x, lp.stages[n].t, B.store_dtype(lp.stages[n]), jnp))
                key = shape
                if key not in cache:
                    sp.set(kernel_cache="miss")
                    cache[key] = build(shape)
                else:
                    sp.set(kernel_cache="hit")
                out_arrays = cache[key](*arrays)
                res = {n: np.asarray(B.dequant(lp.stages[n], arr))
                       for n, arr in zip(outs, out_arrays)}
        # fused kernel: intermediates never leave the band, so telemetry is
        # limited to the pipeline outputs (read-only post-processing)
        obs.runtime.record_env(res, lp, backend="pallas")
        return res

    run.lowered = lp
    return run


B.register_backend("pallas", compile_pallas)
