"""Plan-driven lowering: `(Pipeline, BitwidthPlan)` -> typed program -> backend.

The compile path the analysis plans exist for (docs/execution_backends.md):

    from repro.lowering import compile_pipeline
    run = compile_pipeline(pipe, plan, params, backend="pallas")
    outs = run(image)          # {output stage: float64 ndarray}

Backends: ``interp`` (the per-stage run_fixed oracle), ``jnp`` (one fused
jit program), ``pallas`` (fused line-buffer kernels, one per rate island
— `repro.lowering.islands`), ``sharded`` (the same island band walk
distributed over a device mesh with `shard_map` —
`repro.lowering.sharded`).  All are bit-for-bit identical on every
pipeline, with or without a leading batch dimension — the differential
batteries in tests/test_lowering.py, tests/test_islands.py and
tests/test_serving.py pin it.

`lower(..., datapath="narrow")` re-elects every datapath int32/f32-first
for real-hardware targets (see `repro.lowering.ir`).
"""
from repro.lowering.ir import (IntTap, LoweredPipeline, LoweredStage,
                               LoweringError, PhaseSnap, Tap, dyadic_scale,
                               dyadic_weights, lower, match_linear)
from repro.lowering.backends import (BACKENDS, compile_backend,
                                     compile_pipeline, register_backend)
from repro.lowering.islands import Island, IslandPlan, partition_islands
from repro.lowering.schedule import (Schedule, StageSched,
                                     build_island_schedule, build_schedule,
                                     single_tile_schedule)

__all__ = [
    "IntTap", "LoweredPipeline", "LoweredStage", "LoweringError",
    "PhaseSnap", "Tap", "dyadic_scale", "dyadic_weights", "lower",
    "match_linear", "BACKENDS", "compile_backend", "compile_pipeline",
    "register_backend", "Island", "IslandPlan", "partition_islands",
    "Schedule", "StageSched", "build_island_schedule", "build_schedule",
    "single_tile_schedule",
]
