"""Rate-island partitioning of a lowered DAG.

A *rate island* is a maximal rate-uniform subgraph of the
`LoweredPipeline` DAG that admits one lattice-aligned row-band schedule
(`build_island_schedule`).  Each island fuses through the Pallas
line-buffer kernel; islands are stitched with materialized HBM boundary
buffers holding each boundary stage's *stored* representation — the
smallest legalized container (`core.policy.legalize` via
`backends.store_dtype`: int8/uint8/int16/uint16/int32, int64 for 33–52
exact-integer bits, f64 for float-stored stages).  Narrow stitching
preserves the bit-for-bit differential contract against the numpy
oracle: the stored value was clipped into the container's range before
the narrowing astype, loads widen losslessly, so the downstream
island's clamped gathers over a materialized boundary read exactly the
values the oracle's padded geometry reads — in a quarter of the bytes
where the plan proves 8-bit ranges.

This is the Rigel / heterogeneous-systems-DSL composition (PAPERS.md):
multi-rate pipelines are built from rate-uniform fused segments joined
at rate boundaries.  The partitioner is greedy over the topological
order: it grows the current island one stage at a time, accepting a
stage iff the extended island still schedules; on failure it closes the
island and starts a new one.  A stage that cannot be banded even alone
(rate-inexact height, halo deeper than every aligned tile) becomes a
single-stage island on the degenerate one-tile schedule
(`single_tile_schedule`) — so partitioning is *total*: every DAG lowers
to fused Pallas islands with zero whole-DAG jnp fallbacks.

For a fully schedulable DAG the fast path returns one island whose
schedule is identical to `build_schedule`'s (pinned by
`tests/test_islands.py`).
"""
from __future__ import annotations

import dataclasses
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from repro.lowering.backends import needed_stages
from repro.lowering.ir import LoweredPipeline, LoweringError
from repro.lowering.schedule import (Schedule, build_island_schedule,
                                     single_tile_schedule, stage_shapes)


@dataclasses.dataclass
class Island:
    """One fused segment: `stages` (topo) + its materialized boundary."""
    idx: int
    stages: List[str]          # compute stages, topo order
    inputs: List[str]          # external inputs (materialized upstream)
    outputs: List[str]         # stages stored back to HBM
    rate: Fraction             # first stage's rows per root-image row
    schedule: Schedule
    single_tile: bool          # True when on the one-tile escape hatch

    def carrier_mix(self, lp: LoweredPipeline) -> str:
        """Compact datapath census for telemetry, e.g. 'int32x3,f64x1'."""
        counts: Dict[str, int] = {}
        for n in self.stages:
            ls = lp.stages[n]
            if ls.kind == "intlinear":
                label = ls.carrier
            else:
                label = getattr(ls, "expr_dtype", "f64")
            counts[label] = counts.get(label, 0) + 1
        return ",".join(f"{k}x{v}" for k, v in sorted(counts.items()))

    def stored_mix(self, lp: LoweredPipeline) -> str:
        """Stored-container census (legalized tile dtypes), e.g.
        'int16x3,uint8x1' — the storage-side twin of `carrier_mix`."""
        import numpy as np

        from repro.lowering.backends import store_dtype
        counts: Dict[str, int] = {}
        for n in self.stages:
            label = np.dtype(store_dtype(lp.stages[n])).name
            counts[label] = counts.get(label, 0) + 1
        return ",".join(f"{k}x{v}" for k, v in sorted(counts.items()))

    def boundary_bytes(self, lp: LoweredPipeline) -> Tuple[int, int]:
        """(stored, saved) bytes of this island's materialized HBM
        outputs per image — `saved` relative to a uniform int32
        baseline (negative for f64-stored boundaries)."""
        import numpy as np

        from repro.lowering.backends import store_dtype
        stored = saved = 0
        for n in self.outputs:
            ss = self.schedule.stages[n]
            nb = np.dtype(store_dtype(lp.stages[n])).itemsize
            stored += ss.H * ss.W * nb
            saved += ss.H * ss.W * (4 - nb)
        return stored, saved


@dataclasses.dataclass
class IslandPlan:
    islands: List[Island]
    order: List[str]           # all needed stages (inputs + compute), topo
    inputs: List[str]          # pipeline input stages
    outputs: List[str]         # pipeline outputs requested

    @property
    def fully_fused(self) -> bool:
        return len(self.islands) == 1 and not self.islands[0].single_tile


def _ext_inputs(lp: LoweredPipeline, stages: Sequence[str]) -> List[str]:
    inside = set(stages)
    seen, out = set(), []
    for n in stages:
        for i in lp.stages[n].stage.inputs:
            if i not in inside and i not in seen:
                seen.add(i)
                out.append(i)
    return out


def partition_islands(lp: LoweredPipeline, in_shape: Tuple[int, int],
                      outputs: Optional[Sequence[str]] = None,
                      tile_rows: Optional[int] = None) -> IslandPlan:
    """Cut the lowered DAG into scheduled rate islands (always succeeds).

    `tile_rows`, when given, forces the historical whole-DAG schedule at
    that tile height and raises `LoweringError` if it does not exist —
    an explicit tile request is a statement about the *whole* program.
    """
    outs = list(outputs or lp.pipeline.outputs)
    order = needed_stages(lp, outs)
    shapes = stage_shapes(lp, in_shape)
    inputs = [n for n in order if lp.stages[n].stage.is_input]
    compute = [n for n in order if not lp.stages[n].stage.is_input]
    outs_set = set(outs)
    consumers: Dict[str, List[str]] = {n: [] for n in order}
    for n in compute:
        for i in lp.stages[n].stage.inputs:
            if i in consumers:
                consumers[i].append(n)

    def boundary_outputs(stages: Sequence[str]) -> List[str]:
        inside = set(stages)
        return [n for n in stages
                if n in outs_set
                or any(c not in inside for c in consumers[n])]

    def try_build(stages: List[str],
                  tile: Optional[int] = None) -> Optional[Schedule]:
        try:
            return build_island_schedule(
                lp, shapes, stages, _ext_inputs(lp, stages),
                boundary_outputs(stages), tile_rows=tile)
        except LoweringError:
            return None

    def rate_of(stages: Sequence[str]) -> Fraction:
        return Fraction(shapes[stages[0]][0], in_shape[0])

    # fast path: the whole DAG as one island (the historical case)
    whole = try_build(compute, tile=tile_rows)
    if whole is not None:
        isl = Island(0, compute, inputs, outs, rate_of(compute), whole,
                     single_tile=False)
        return IslandPlan([isl], order, inputs, outs)
    if tile_rows is not None:
        # surface the schedule's own diagnostic for the forced tile
        build_island_schedule(lp, shapes, compute, inputs, outs,
                              tile_rows=tile_rows)

    islands: List[Island] = []

    def close(stages: List[str], sched: Optional[Schedule]) -> None:
        ext = _ext_inputs(lp, stages)
        bout = boundary_outputs(stages)
        single = sched is None
        if single:
            sched = single_tile_schedule(lp, shapes, stages, ext, bout)
        islands.append(Island(len(islands), list(stages), ext, bout,
                              rate_of(stages), sched, single))

    cur: List[str] = []
    cur_sched: Optional[Schedule] = None
    for name in compute:
        cand = cur + [name]
        sched = try_build(cand)
        if sched is not None:
            cur, cur_sched = cand, sched
            continue
        if cur:
            close(cur, cur_sched)
        solo = try_build([name])
        if solo is not None:
            cur, cur_sched = [name], solo
        else:
            close([name], None)
            cur, cur_sched = [], None
    if cur:
        close(cur, cur_sched)
    return IslandPlan(islands, order, inputs, outs)
