"""`shard_map` band-sharded execution of the lowered island plan.

The pallas backend walks each rate island's row-band schedule serially
down the image; this backend distributes the *same* band walk across
devices: a 1-D mesh (`launch.mesh.make_band_mesh`, axis ``"band"``)
splits each island's grid into contiguous runs of ``grid // n_shards``
bands, every device executes its run with the island's intermediates
device-local, and the per-shard output rows concatenate back into the
full stage arrays along the lattice-aligned band axis — bands are the
partition unit exactly as they are the VMEM-residency unit in the fused
kernel, and island boundaries stay materialized (replicated) buffers
just like the HBM stitching.

Bit-exactness is by construction, not by re-derivation: the shard body
executes the SAME stage descriptors (`pallas_backend.island_program`)
through the SAME band geometry (`kernels.stencil.kernel.eval_band`) as
the fused Pallas kernel, with `load_band` a clamped `dynamic_slice` on
the replicated input instead of an HBM-ref slice.  Device ``d`` computes
band steps ``[d*k, (d+1)*k)`` via `lax.axis_index`; since every band's
value depends only on the (replicated) island inputs, the concatenated
result is bit-identical to the serial walk — pinned against the numpy
oracle in tests/test_serving.py, batched and phase-split plans included.

Fallbacks (one-time `RuntimeWarning` via `repro.obs.warn_once`):

  * an island whose grid does not divide over the mesh, and
  * single-tile islands (grid == 1 cannot split),

run the identical band walk unsharded on the local device — never a
different datapath, so exactness is unaffected.

Images with a leading batch dimension ``(B, H, W)`` vmap the shard body
over the batch axis inside `shard_map` (bands stay the partition unit;
the batch axis is replicated).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.lowering import backends as B
from repro.lowering.ir import LoweredPipeline, LoweringError
from repro.lowering.islands import Island, partition_islands
from repro.lowering.pallas_backend import island_program


def _band_walk(program: Sequence[dict], k: int, base_of):
    """f(*inputs) -> tuple of output stage arrays for `k` band steps.

    `base_of()` yields the first band index of this walk — 0 for the
    serial fallback, ``axis_index("band") * k`` inside a shard.  The
    loop over the k steps is a static python loop (k is small: bands
    per shard), each step re-running `eval_band` — the one shared
    definition of the tap/clamp geometry.
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels.stencil.kernel import band_output, eval_band

    outs = sorted((d for d in program if d.get("out_slot") is not None),
                  key=lambda d: d["out_slot"])

    def fn(*inputs):
        def load_band(d, start):
            return jax.lax.dynamic_slice_in_dim(
                inputs[d["in_slot"]], start, d["L"], axis=0)

        base = base_of()
        chunks: Dict[str, List] = {d["name"]: [] for d in outs}
        for j in range(k):
            tiles = eval_band(program, base + j, load_band)
            for d in outs:
                chunks[d["name"]].append(band_output(d, tiles[d["name"]]))
        return tuple(jnp.concatenate(chunks[d["name"]], axis=0)
                     for d in outs)

    return fn


def compile_sharded(lp: LoweredPipeline,
                    outputs: Optional[Sequence[str]] = None,
                    mesh=None,
                    tile_rows: Optional[int] = None) -> B.Executor:
    """Band-sharded executor over `mesh` (default: all local devices).

    Shape-specialized like the pallas backend: the island plan and the
    jitted shard programs are built (and cached) per input shape on
    first call.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_band_mesh
    from repro.launch.sharding import spec_for

    outs = list(outputs or lp.pipeline.outputs)
    order = B.needed_stages(lp, outs)
    input_names = [n for n in order if lp.stages[n].stage.is_input]
    cache: Dict[tuple, list] = {}

    def compile_island(isl: Island, mesh, batch: Optional[int]):
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:            # newer jax: promoted out of experimental
            from jax.sharding import shard_map   # type: ignore
        program = island_program(lp, isl)
        S = mesh.shape["band"]
        grid = isl.schedule.grid
        outs_d = sorted((d for d in program
                         if d.get("out_slot") is not None),
                        key=lambda d: d["out_slot"])
        if isl.single_tile or grid % S != 0:
            reason = ("single-tile island" if isl.single_tile else
                      f"grid {grid} does not divide over {S} shards")
            obs.warn_once(
                f"sharded: island {isl.idx} of {lp.pipeline.name!r} falls "
                f"back to the serial band walk ({reason}); pad the image "
                f"or shrink the mesh for full band sharding")
            body = _band_walk(program, grid, lambda: 0)
            fn = jax.jit(jax.vmap(body) if batch else body)
            return fn, False
        k = grid // S
        body = _band_walk(
            program, k, lambda: jax.lax.axis_index("band") * k)
        if batch:
            body = jax.vmap(body)
        # every input is replicated; outputs shard their band-built row
        # axis — spec_for maps the "band_rows" logical axis onto the mesh
        # (grid % S == 0 implies row divisibility: H = grid * step)
        row_axes = ("band_rows",) if not batch else (None, "band_rows")
        out_specs = tuple(
            spec_for((d["H"], d["W"]) if not batch
                     else (batch, d["H"], d["W"]),
                     row_axes + (None,), mesh)
            for d in outs_d)
        fn = jax.jit(shard_map(body, mesh=mesh,
                               in_specs=(P(),) * len(isl.inputs),
                               out_specs=out_specs, check_rep=False))
        return fn, True

    def build(shape, mesh):
        batch = shape[0] if len(shape) == 3 else None
        in_shape = tuple(shape[-2:])
        plan = partition_islands(lp, in_shape, outputs=outs,
                                 tile_rows=tile_rows)
        return [(isl,) + compile_island(isl, mesh, batch)
                for isl in plan.islands]

    def run(image, params_override=None):
        import jax.numpy as jnp
        from jax.experimental import enable_x64
        if params_override is not None and \
                dict(params_override) != lp.params:
            raise ValueError("params are baked at compile time; re-lower "
                             "with the new params")
        m = make_band_mesh() if mesh is None else mesh
        imgs, _ = B.normalize_images(lp, image)
        img_of = dict(zip(lp.pipeline.input_stages(), imgs))
        with obs.span("exec.sharded", backend="sharded",
                      pipeline=lp.pipeline.name, outputs=len(outs),
                      shards=m.shape["band"]) as sp:
            with enable_x64():
                buffers: Dict[str, object] = {}
                shape = None
                for n in input_names:
                    x = jnp.asarray(np.asarray(img_of[n]))
                    if x.ndim not in (2, 3):
                        raise LoweringError(
                            f"images must be (H, W) or (B, H, W); got "
                            f"{tuple(x.shape)}")
                    if shape is None:
                        shape = tuple(x.shape)
                    elif tuple(x.shape) != shape:
                        raise LoweringError(
                            "all pipeline inputs must share one shape; "
                            f"got {shape} vs {x.shape}")
                    # narrow replicated inputs: container-dtype frames
                    # ship as-is across the mesh (zero-copy ingest)
                    buffers[n] = B.ingest_input(x, lp.stages[n], jnp)
                if len(shape) == 3:
                    sp.set(batch=int(shape[0]))
                key = shape + (m.shape["band"],)
                if key not in cache:
                    sp.set(kernel_cache="miss")
                    cache[key] = build(shape, m)
                else:
                    sp.set(kernel_cache="hit")
                compiled = cache[key]
                sp.set(islands=len(compiled),
                       sharded_islands=sum(1 for _, _, s in compiled
                                           if s))
                for isl, call, is_sharded in compiled:
                    with obs.span("exec.sharded.island",
                                  island=isl.idx, rate=str(isl.rate),
                                  stages=len(isl.stages),
                                  grid=isl.schedule.grid,
                                  sharded=is_sharded):
                        for n, arr in zip(isl.outputs,
                                          call(*[buffers[i]
                                                 for i in isl.inputs])):
                            buffers[n] = arr
                res = {n: np.asarray(B.dequant(lp.stages[n], buffers[n]))
                       for n in outs}
        # like pallas: intermediates never materialize, telemetry covers
        # island boundaries + outputs only
        obs.runtime.record_env(res, lp, backend="sharded")
        return res

    run.lowered = lp
    return run


B.register_backend("sharded", compile_sharded)
