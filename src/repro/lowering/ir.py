"""Typed per-stage program IR for plan-driven lowering.

`lower()` (see `repro.lowering.lower_pipeline`) turns `(Pipeline,
BitwidthPlan)` into a `LoweredPipeline`: one `LoweredStage` per stage
carrying everything a backend needs to synthesize the stage's datapath —
quantized integer taps, beta-alignment shifts, the finishing rule
(dyadic round-half-even shift or one f64 scale multiply), per-axis halos,
sampling rates, saturation bounds, and per-phase datapaths (one set of
bounds per sampling-lattice residue, the paper §IV homogeneity clusters).

Datapath-kind selection is the load-bearing decision.  The bit-exactness
contract with the `run_fixed` per-pixel oracle (numpy f64) rests on two
facts:

  * an ``expr`` stage re-issues the oracle's IEEE-754 double ops in the
    identical order (`dsl.exec.eval_expr` is shared), so it is equal by
    construction;
  * an ``intlinear`` stage replaces the oracle's float tree with integer
    multiply-accumulates, which is equal **iff the oracle's float math was
    exact**: all taps are dyadic multiples of on-grid inputs and every
    partial sum stays below 2^53.  `_plan_intlinear` proves that bound
    from the input types before electing the integer path; anything it
    cannot prove falls back to ``expr``.

The finishing step after an integer accumulation:

  value = s * acc / 2^(w_beta + bmax),   q_out = rint(value * 2^beta_out)

  * dyadic s = sm/2^se  ->  q_out = round_half_even(acc * sm, t) with
    t = se + w_beta + bmax - beta_out (pure integer datapath);
  * otherwise  q_out = rint(f64(acc) * cscale) with cscale =
    s * 2^(beta_out - w_beta - bmax), exact because scaling a double by a
    power of two is lossless — one IEEE multiply, the same one the oracle
    issues.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.fixedpoint import FixedPointType
from repro.core.graph import BinOp, Const, Expr, Pipeline, Ref, Stage

Residue = Tuple[int, int]


class LoweringError(ValueError):
    """The pipeline (or shape) cannot be lowered by the requested backend."""


# ---------------------------------------------------------------------------
# linear-form matching (generalizes kernels/stencil/ops.py tap extraction)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Tap:
    """One structural stencil tap: `w * input[(i+dy, j+dx)]`."""
    stage: str
    dy: int
    dx: int
    w: float


def match_linear(expr: Expr) -> Optional[Tuple[Tuple[Tap, ...], float]]:
    """Match `[Const(s) *] (sum/difference of [Const(w) *] Ref taps)`.

    This is exactly the shape `core.graph.stencil_expr` emits (plus bare
    linear point-wise stages like ``img2 - img1``), multi-input included.
    Returns (taps, scale) or None when the stage is not a linear stencil.
    """
    scale = 1.0
    body = expr
    if isinstance(body, BinOp) and body.op == "*" \
            and isinstance(body.left, Const) \
            and not isinstance(body.right, (Ref, Const)):
        scale = float(body.left.value)
        body = body.right
    taps: List[Tap] = []

    def go(n: Expr, sign: int) -> bool:
        if isinstance(n, BinOp) and n.op == "+":
            return go(n.left, sign) and go(n.right, sign)
        if isinstance(n, BinOp) and n.op == "-":
            return go(n.left, sign) and go(n.right, -sign)
        if isinstance(n, BinOp) and n.op == "*" \
                and isinstance(n.left, Const) and isinstance(n.right, Ref):
            r = n.right
            taps.append(Tap(r.stage, r.dy, r.dx, sign * float(n.left.value)))
            return True
        if isinstance(n, Ref):
            taps.append(Tap(n.stage, n.dy, n.dx, float(sign)))
            return True
        return False

    if not go(body, 1) or not taps:
        return None
    return tuple(taps), scale


def dyadic_weights(vals: Sequence[float], max_beta: int = 24
                   ) -> Optional[Tuple[List[int], int]]:
    """Smallest w_beta with every `v * 2^w_beta` an exact integer, else None.

    The exact-only core of `kernels.stencil.ops.quantize_weights` (which
    additionally accepts lossy rounding at its beta cap)."""
    for w_beta in range(max_beta + 1):
        sc = 1 << w_beta
        if all(float(v) * sc == int(v * sc) for v in vals):
            return [int(v * sc) for v in vals], w_beta
    return None


def dyadic_scale(s: float, max_num: int = 1 << 20,
                 max_exp: int = 64) -> Optional[Tuple[int, int]]:
    """`s == sm / 2^se` with a small odd-ish integer sm, else None."""
    if s == 0 or not math.isfinite(s):
        return None
    f = Fraction(s)          # exact: every float is p/2^k
    den = f.denominator
    if den & (den - 1) != 0:         # not a power of two (cannot happen for
        return None                  # floats, but keep the guard explicit)
    se = den.bit_length() - 1
    sm = f.numerator
    if abs(sm) > max_num or se > max_exp:
        return None
    return sm, se


# ---------------------------------------------------------------------------
# lowered stages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IntTap:
    """Beta-aligned integer tap: `W * q_in[(i+dy, j+dx)]` on scaled ints."""
    stage: str
    dy: int
    dx: int
    W: int


@dataclasses.dataclass
class PhaseSnap:
    """Per-phase datapaths: one output type per sampling-lattice residue.

    `int_ok` marks the common case where every residue shares the union
    column's beta — the residue split then only changes the saturation
    bounds, so the integer datapath re-clips per residue.  Mixed betas
    (possible with hand-built type maps) force the float path: the oracle
    re-snaps each residue's raw value onto a different grid.
    """
    lattice: Tuple[int, int]                     # (My, Mx)
    types: Dict[Residue, FixedPointType]
    int_ok: bool = True


@dataclasses.dataclass
class LoweredStage:
    name: str
    kind: str                        # "input" | "intlinear" | "expr"
    stage: Stage                     # original IR node (expr/stride/upsample)
    t: Optional[FixedPointType]      # union-column output type (None = float)
    halo: Tuple[int, int]            # per-axis (hy, hx)
    # -- intlinear datapath ---------------------------------------------------
    int_taps: Tuple[IntTap, ...] = ()
    sm: int = 1                      # dyadic finishing numerator
    t_shift: int = 0                 # dyadic finishing right-shift (may be <0)
    dyadic: bool = True
    cscale: float = 1.0              # f64 finishing multiplier (non-dyadic)
    carrier: str = "int64"           # accumulator dtype ("int32" | "int64")
    acc_bound: int = 0               # proved |accumulator| bound
    # -- saturation -----------------------------------------------------------
    phase: Optional[PhaseSnap] = None
    # backends keep this stage's tile as f64 values instead of scaled ints
    # (untyped, wider than a double's mantissa, or residue-mixed-beta)
    store_float: bool = False


@dataclasses.dataclass
class LoweredPipeline:
    """Topologically ordered typed program — what backends compile."""
    pipeline: Pipeline
    stages: Dict[str, LoweredStage]          # in topo order
    order: List[str]
    params: Dict[str, float]
    types: Dict[str, Optional[FixedPointType]]
    column: Optional[str] = None             # plan column, if plan-derived

    def outputs(self) -> List[str]:
        return list(self.pipeline.outputs)

    def kinds(self) -> Dict[str, str]:
        return {n: s.kind for n, s in self.stages.items()}


# ---------------------------------------------------------------------------
# datapath planning
# ---------------------------------------------------------------------------

F64_EXACT = 1 << 53      # integer sums below this are exact IEEE doubles
INT32_BUDGET = 1 << 30


def _qabs(t: FixedPointType) -> int:
    return max(abs(t.int_min), t.int_max)


def _plan_intlinear(st: Stage, taps: Tuple[Tap, ...], scale: float,
                    t_out: FixedPointType,
                    in_types: Dict[str, Optional[FixedPointType]]):
    """Integer-datapath parameters, or None when exactness is unprovable."""
    if any(in_types.get(tp.stage) is None for tp in taps):
        return None
    w = dyadic_weights([tp.w for tp in taps])
    if w is None:
        return None
    wq, w_beta = w
    bmax = max(in_types[tp.stage].beta for tp in taps)
    int_taps = []
    bound = 0
    for tp, q in zip(taps, wq):
        t_in = in_types[tp.stage]
        W = q << (bmax - t_in.beta)
        if W == 0:
            continue
        int_taps.append(IntTap(tp.stage, tp.dy, tp.dx, W))
        bound += abs(W) * _qabs(t_in)
    if bound >= F64_EXACT:
        # the oracle's own float sum may round — only `expr` replays that
        return None
    ds = dyadic_scale(scale)
    if ds is not None:
        sm, se = ds
        t_shift = se + w_beta + bmax - t_out.beta
        # the oracle computes fl(s * sum): exact only while |sm * acc|
        # fits a double's mantissa — beyond that the float tree rounds and
        # only the `expr` kind can replay it.  The carrier must hold the
        # *finished* value too: a negative t_shift left-shifts the product
        # (beta_out deeper than the input grid), so bound the post-shift
        # magnitude, not just the accumulator.
        prod = bound * abs(sm)
        if t_shift < 0:
            fin = prod << (-t_shift)
        else:
            fin = prod + (1 << max(t_shift - 1, 0))
        if fin >= F64_EXACT:
            return None
        carrier = "int32" if fin < INT32_BUDGET else "int64"
        return dict(int_taps=tuple(int_taps), sm=sm, t_shift=t_shift,
                    dyadic=True, cscale=1.0, carrier=carrier,
                    acc_bound=bound)
    # non-dyadic scale: one f64 multiply finishes the stage, bit-equal to
    # the oracle's fl(scale * sum) (power-of-two rescale is lossless)
    cscale = scale * 2.0 ** (t_out.beta - w_beta - bmax)
    carrier = "int32" if bound < INT32_BUDGET else "int64"
    return dict(int_taps=tuple(int_taps), sm=1, t_shift=0, dyadic=False,
                cscale=cscale, carrier=carrier, acc_bound=bound)


def _phase_snap(t_union: FixedPointType, entry) -> PhaseSnap:
    (my, mx), tmap = entry
    return PhaseSnap(lattice=(my, mx), types=dict(tmap),
                     int_ok=all(t.beta == t_union.beta
                                for t in tmap.values()))


def lower(pipeline: Pipeline, types, params: Optional[Dict[str, float]] = None,
          column: Optional[str] = None) -> LoweredPipeline:
    """Lower `(Pipeline, BitwidthPlan-or-TypeMap)` into a typed program.

    Mirrors `dsl.exec.run_fixed`'s duck-typed plan handling: a plan
    supplies its `column` types plus per-phase sub-types; a plain dict is
    a per-stage union type map.
    """
    from repro import obs
    phase_types = {}
    col = column
    if hasattr(types, "phase_types"):            # BitwidthPlan (duck-typed)
        plan = types
        phase_types = plan.phase_types(column) or {}
        col = column or getattr(plan, "default_column", None)
        types = plan.types(column)
    with obs.span("lowering.lower", pipeline=pipeline.name, column=col,
                  n_stages=len(pipeline.stages)) as sp:
        tmap: Dict[str, Optional[FixedPointType]] = {
            n: types.get(n) for n in pipeline.stages}
        stages: Dict[str, LoweredStage] = {}
        order = pipeline.topo_order()
        # stages whose values backends must keep as floats (no single
        # scaled-int grid): untyped, wider than a double's mantissa, or
        # residue-mixed-beta.  Their consumers cannot take the integer path.
        float_stored: set = set()
        for name in order:
            st = pipeline.stages[name]
            t_out = tmap.get(name)
            halo = st.halo_yx()
            phase = None
            if name in phase_types and t_out is not None:
                phase = _phase_snap(t_out, phase_types[name])
            sf = (t_out is None or t_out.width > 52
                  or (phase is not None and not phase.int_ok))
            if sf:
                float_stored.add(name)
            if st.is_input:
                stages[name] = LoweredStage(name=name, kind="input", stage=st,
                                            t=t_out, halo=(0, 0),
                                            store_float=sf)
                continue
            lin = match_linear(st.expr) if t_out is not None else None
            plan_int = None
            if lin is not None and not sf \
                    and not any(i in float_stored for i in st.inputs):
                plan_int = _plan_intlinear(st, lin[0], lin[1], t_out,
                                           {i: tmap.get(i)
                                            for i in st.inputs})
            if plan_int is not None:
                stages[name] = LoweredStage(name=name, kind="intlinear",
                                            stage=st, t=t_out, halo=halo,
                                            phase=phase, **plan_int)
            else:
                stages[name] = LoweredStage(name=name, kind="expr", stage=st,
                                            t=t_out, halo=halo, phase=phase,
                                            store_float=sf)
        kinds = [s.kind for s in stages.values()]
        sp.set(intlinear=kinds.count("intlinear"), expr=kinds.count("expr"))
    return LoweredPipeline(pipeline=pipeline, stages=stages, order=order,
                           params=dict(params or {}), types=tmap, column=col)
