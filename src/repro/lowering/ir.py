"""Typed per-stage program IR for plan-driven lowering.

`lower()` (see `repro.lowering.lower_pipeline`) turns `(Pipeline,
BitwidthPlan)` into a `LoweredPipeline`: one `LoweredStage` per stage
carrying everything a backend needs to synthesize the stage's datapath —
quantized integer taps, beta-alignment shifts, the finishing rule
(dyadic round-half-even shift or one f64 scale multiply), per-axis halos,
sampling rates, saturation bounds, and per-phase datapaths (one set of
bounds per sampling-lattice residue, the paper §IV homogeneity clusters).

Datapath-kind selection is the load-bearing decision.  The bit-exactness
contract with the `run_fixed` per-pixel oracle (numpy f64) rests on two
facts:

  * an ``expr`` stage re-issues the oracle's IEEE-754 double ops in the
    identical order (`dsl.exec.eval_expr` is shared), so it is equal by
    construction;
  * an ``intlinear`` stage replaces the oracle's float tree with integer
    multiply-accumulates, which is equal **iff the oracle's float math was
    exact**: all taps are dyadic multiples of on-grid inputs and every
    partial sum stays below 2^53.  `_plan_intlinear` proves that bound
    from the input types before electing the integer path; anything it
    cannot prove falls back to ``expr``.

The finishing step after an integer accumulation:

  value = s * acc / 2^(w_beta + bmax),   q_out = rint(value * 2^beta_out)

  * dyadic s = sm/2^se  ->  q_out = round_half_even(acc * sm, t) with
    t = se + w_beta + bmax - beta_out (pure integer datapath);
  * otherwise  q_out = rint(f64(acc) * cscale) with cscale =
    s * 2^(beta_out - w_beta - bmax), exact because scaling a double by a
    power of two is lossless — one IEEE multiply, the same one the oracle
    issues.

**Narrow datapath re-election** (`lower(..., datapath="narrow")`) is the
real-hardware mode: the exact-mode election above happily hands out
int64 carriers and f64 expression datapaths, which no FPGA/TPU lane
holds natively.  Narrow mode re-elects every datapath int32/f32-first,
and only keeps a 64-bit resource when it can *prove* no narrower one is
bit-exact — recording each election (and each justified retention) in
the plan's provenance:

  * accumulator bounds are re-tightened per tap from the plan's
    per-phase columns (a tap that only ever lands on low-magnitude
    lattice residues is bounded by those residues' types, not the union
    column — edge clamps handled conservatively);
  * an accumulator whose tightened bound still exceeds `INT32_BUDGET`
    is *split* into two int32 partial accumulators (`carrier =
    "int32pair"`, taps partitioned by `acc_split`), combined by one wide
    add before the finishing rule — bit-equal because integer adds are
    associative and the combined value stays below 2^53;
  * an `expr` stage is demoted to f32 evaluation (`expr_dtype = "f32"`)
    when a value-grid walk over its tree proves every intermediate is a
    dyadic rational whose scaled magnitude fits a 24-bit mantissa — then
    every f32 op is exact, hence bit-identical to the oracle's f64 ops.
"""
from __future__ import annotations

import dataclasses
import math
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.fixedpoint import FixedPointType
from repro.core.graph import BinOp, Const, Expr, Pipeline, Ref, Stage

Residue = Tuple[int, int]


class LoweringError(ValueError):
    """The pipeline (or shape) cannot be lowered by the requested backend."""


# ---------------------------------------------------------------------------
# linear-form matching (generalizes kernels/stencil/ops.py tap extraction)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Tap:
    """One structural stencil tap: `w * input[(i+dy, j+dx)]`."""
    stage: str
    dy: int
    dx: int
    w: float


def match_linear(expr: Expr) -> Optional[Tuple[Tuple[Tap, ...], float]]:
    """Match `[Const(s) *] (sum/difference of [Const(w) *] Ref taps)`.

    This is exactly the shape `core.graph.stencil_expr` emits (plus bare
    linear point-wise stages like ``img2 - img1``), multi-input included.
    Returns (taps, scale) or None when the stage is not a linear stencil.
    """
    scale = 1.0
    body = expr
    if isinstance(body, BinOp) and body.op == "*" \
            and isinstance(body.left, Const) \
            and not isinstance(body.right, (Ref, Const)):
        scale = float(body.left.value)
        body = body.right
    taps: List[Tap] = []

    def go(n: Expr, sign: int) -> bool:
        if isinstance(n, BinOp) and n.op == "+":
            return go(n.left, sign) and go(n.right, sign)
        if isinstance(n, BinOp) and n.op == "-":
            return go(n.left, sign) and go(n.right, -sign)
        if isinstance(n, BinOp) and n.op == "*" \
                and isinstance(n.left, Const) and isinstance(n.right, Ref):
            r = n.right
            taps.append(Tap(r.stage, r.dy, r.dx, sign * float(n.left.value)))
            return True
        if isinstance(n, Ref):
            taps.append(Tap(n.stage, n.dy, n.dx, float(sign)))
            return True
        return False

    if not go(body, 1) or not taps:
        return None
    return tuple(taps), scale


def dyadic_weights(vals: Sequence[float], max_beta: int = 24
                   ) -> Optional[Tuple[List[int], int]]:
    """Smallest w_beta with every `v * 2^w_beta` an exact integer, else None.

    The exact-only core of `kernels.stencil.ops.quantize_weights` (which
    additionally accepts lossy rounding at its beta cap)."""
    for w_beta in range(max_beta + 1):
        sc = 1 << w_beta
        if all(float(v) * sc == int(v * sc) for v in vals):
            return [int(v * sc) for v in vals], w_beta
    return None


def dyadic_scale(s: float, max_num: int = 1 << 20,
                 max_exp: int = 64) -> Optional[Tuple[int, int]]:
    """`s == sm / 2^se` with a small odd-ish integer sm, else None."""
    if s == 0 or not math.isfinite(s):
        return None
    f = Fraction(s)          # exact: every float is p/2^k
    den = f.denominator
    if den & (den - 1) != 0:         # not a power of two (cannot happen for
        return None                  # floats, but keep the guard explicit)
    se = den.bit_length() - 1
    sm = f.numerator
    if abs(sm) > max_num or se > max_exp:
        return None
    return sm, se


# ---------------------------------------------------------------------------
# lowered stages
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IntTap:
    """Beta-aligned integer tap: `W * q_in[(i+dy, j+dx)]` on scaled ints."""
    stage: str
    dy: int
    dx: int
    W: int


@dataclasses.dataclass
class PhaseSnap:
    """Per-phase datapaths: one output type per sampling-lattice residue.

    `int_ok` marks the common case where every residue shares the union
    column's beta — the residue split then only changes the saturation
    bounds, so the integer datapath re-clips per residue.  Mixed betas
    (possible with hand-built type maps) force the float path: the oracle
    re-snaps each residue's raw value onto a different grid.
    """
    lattice: Tuple[int, int]                     # (My, Mx)
    types: Dict[Residue, FixedPointType]
    int_ok: bool = True


@dataclasses.dataclass
class LoweredStage:
    name: str
    kind: str                        # "input" | "intlinear" | "expr"
    stage: Stage                     # original IR node (expr/stride/upsample)
    t: Optional[FixedPointType]      # union-column output type (None = float)
    halo: Tuple[int, int]            # per-axis (hy, hx)
    # -- intlinear datapath ---------------------------------------------------
    int_taps: Tuple[IntTap, ...] = ()
    sm: int = 1                      # dyadic finishing numerator
    t_shift: int = 0                 # dyadic finishing right-shift (may be <0)
    dyadic: bool = True
    cscale: float = 1.0              # f64 finishing multiplier (non-dyadic)
    carrier: str = "int64"           # accumulator ("int32"|"int32pair"|"int64")
    acc_bound: int = 0               # proved |accumulator| bound
    # int32pair: int_taps[:acc_split] / int_taps[acc_split:] accumulate in
    # separate int32 registers, combined by one wide add before finishing
    acc_split: int = 0
    # -- expr datapath --------------------------------------------------------
    expr_dtype: str = "f64"          # "f32" only under a narrow-mode proof
    # -- saturation -----------------------------------------------------------
    phase: Optional[PhaseSnap] = None
    # backends keep this stage's tile as f64 values instead of scaled ints
    # (untyped, wider than a double's mantissa, or residue-mixed-beta)
    store_float: bool = False
    # narrow-mode election record ("" in exact mode): the chosen datapath,
    # with the proof obligation that blocked anything narrower
    election: str = ""


@dataclasses.dataclass
class LoweredPipeline:
    """Topologically ordered typed program — what backends compile."""
    pipeline: Pipeline
    stages: Dict[str, LoweredStage]          # in topo order
    order: List[str]
    params: Dict[str, float]
    types: Dict[str, Optional[FixedPointType]]
    column: Optional[str] = None             # plan column, if plan-derived
    datapath: str = "exact"                  # "exact" | "narrow"

    def outputs(self) -> List[str]:
        return list(self.pipeline.outputs)

    def kinds(self) -> Dict[str, str]:
        return {n: s.kind for n, s in self.stages.items()}


# ---------------------------------------------------------------------------
# datapath planning
# ---------------------------------------------------------------------------

F64_EXACT = 1 << 53      # integer sums below this are exact IEEE doubles
F32_EXACT = 1 << 24      # scaled magnitudes below this are exact IEEE singles
INT32_BUDGET = 1 << 30


def _qabs(t: FixedPointType) -> int:
    return max(abs(t.int_min), t.int_max)


def _touched_residues(s: int, u: int, d: int, m: int) -> Optional[set]:
    """Row (or col) residues mod `m` a tap offset `d` can read, or None.

    The consumer reads input index `floor((y*s + d)/u)`; over one lattice
    period (`y` mod `m*u`) the unclamped indices hit a fixed residue set.
    Edge clamping is handled conservatively: a negative offset can clamp
    onto index 0 (residue 0, added); a positive offset can clamp onto
    `H-1`, whose residue is shape-dependent — unknown at lowering time,
    so the caller falls back to the union bound (None).
    """
    if m <= 1:
        return {0}
    res = {((y * s + d) // u) % m for y in range(m * u)}
    if d < 0:
        res.add(0)
    if d > 0:
        return None
    return res


def _tap_qabs_narrow(st: Stage, tp: Tap, t_in: FixedPointType,
                     phase_in: Optional["PhaseSnap"]) -> int:
    """Tightened |q| bound for one tap from the input's per-phase types.

    Sound because the runtime (every backend and the oracle alike) clips
    the input stage per lattice residue, so a stored value at residue
    (ry, rx) obeys that residue's saturation bounds.
    """
    if phase_in is None or not phase_in.int_ok:
        return _qabs(t_in)
    my, mx = phase_in.lattice
    ry = _touched_residues(st.stride[0], st.upsample[0], tp.dy, my)
    rx = _touched_residues(st.stride[1], st.upsample[1], tp.dx, mx)
    if ry is None or rx is None:
        return _qabs(t_in)
    best = 0
    for a in ry:
        for b in rx:
            t_ph = phase_in.types.get((a, b), t_in)
            best = max(best, _qabs(t_ph))
    return best


def _split_int32(tap_bounds: List[int]
                 ) -> Optional[Tuple[List[int], int]]:
    """2-partition tap indices so each partial sum stays under the int32
    budget.  Returns `(reordered_indices, split_at)` — taps before the
    split accumulate in one int32 register, the rest in the other — or
    None when no split exists.  Integer adds are associative and
    commutative, so any regrouping is bit-exact."""
    if len(tap_bounds) < 2:
        return None
    order = sorted(range(len(tap_bounds)), key=lambda i: -tap_bounds[i])
    a: List[int] = []
    b: List[int] = []
    sa = sb = 0
    for i in order:
        if sa <= sb:
            a.append(i)
            sa += tap_bounds[i]
        else:
            b.append(i)
            sb += tap_bounds[i]
    if sa >= INT32_BUDGET or sb >= INT32_BUDGET or not a or not b:
        return None
    return a + b, len(a)


def _expr_fits_f32(st: Stage, t_out: Optional[FixedPointType],
                   in_types: Dict[str, Optional[FixedPointType]],
                   float_stored: set,
                   phase: Optional["PhaseSnap"]) -> Optional[str]:
    """Proof that f32 evaluation of `st.expr` is bit-identical to f64.

    Walks the tree tracking an exact dyadic value grid `(bound, e)`:
    every node's value is `k * 2^-e` with `|k| <= bound`.  When every
    node keeps `bound < 2^24` (and `e` well inside the exponent range),
    each op's result is exactly representable in BOTH f32 and f64, so
    neither rounds — the two evaluations are equal, and the final snap
    (`rint` after a lossless power-of-two rescale, clip against
    f32-exact bounds) is the same single rounding the oracle performs.

    Returns None when the proof succeeds, else the retention reason.
    """
    if t_out is None:
        return "untyped output"
    if phase is not None:
        return "phase-split residues re-snap per lattice residue"
    if _qabs(t_out) >= F32_EXACT:
        return (f"output grid needs "
                f"{_qabs(t_out).bit_length()} magnitude bits")
    if abs(t_out.beta) > 60:
        return "output beta outside f32 exponent headroom"

    class _No(Exception):
        pass

    def fail(msg: str):
        raise _No(msg)

    def chk(b: int, e: int) -> Tuple[int, int]:
        if b >= F32_EXACT:
            fail(f"a node needs {b.bit_length()} magnitude bits")
        if e > 60:
            fail("a node's beta exceeds f32 exponent headroom")
        return b, e

    def go(n: Expr) -> Tuple[int, int]:
        from repro.core.graph import Call, Cmp, ParamRef, Pow, Select
        if isinstance(n, Const):
            if n.value == 0:
                return 0, 0
            ds = dyadic_scale(float(n.value), max_num=F32_EXACT - 1,
                              max_exp=60)
            if ds is None:
                fail(f"constant {n.value!r} is not f32-exact")
            return chk(abs(ds[0]), ds[1])
        if isinstance(n, Ref):
            t = in_types.get(n.stage)
            if t is None:
                fail(f"input {n.stage!r} is untyped")
            if n.stage in float_stored:
                fail(f"input {n.stage!r} is float-stored")
            return chk(_qabs(t), t.beta)
        if isinstance(n, ParamRef):
            fail(f"runtime parameter {n.name!r} has no proven grid")
        if isinstance(n, BinOp):
            if n.op == "/":
                fail("division rounds")
            (bl, el), (br, er) = go(n.left), go(n.right)
            if n.op == "*":
                return chk(bl * br, el + er)
            e = max(el, er)
            return chk((bl << (e - el)) + (br << (e - er)), e)
        if isinstance(n, Pow):
            b, e = go(n.base)
            if n.n < 0:
                fail("negative power rounds")
            return chk(b ** n.n, e * n.n)
        if isinstance(n, Call):
            if n.fn == "sqrt":
                fail("sqrt rounds")
            gs = [go(a) for a in n.args]
            e = max(ee for _, ee in gs)
            return chk(max(bb << (e - ee) for bb, ee in gs), e)
        if isinstance(n, Cmp):
            go(n.left)
            go(n.right)
            return 1, 0      # exact compare of exact values
        if isinstance(n, Select):
            go(n.cond)
            gs = [go(n.then), go(n.other)]
            e = max(ee for _, ee in gs)
            return chk(max(bb << (e - ee) for bb, ee in gs), e)
        fail(f"unsupported node {type(n).__name__}")

    try:
        go(st.expr)
    except _No as exc:
        return str(exc)
    return None


def _plan_intlinear(st: Stage, taps: Tuple[Tap, ...], scale: float,
                    t_out: FixedPointType,
                    in_types: Dict[str, Optional[FixedPointType]],
                    narrow: bool = False,
                    in_phases: Optional[Dict[str, "PhaseSnap"]] = None):
    """Integer-datapath parameters, or None when exactness is unprovable.

    With `narrow=True` the carrier election is int32-first: accumulator
    bounds are tightened per tap from the inputs' per-phase types, and a
    bound over `INT32_BUDGET` is split across an int32 pair before an
    int64 carrier is conceded (the retention reason lands in `election`).
    """
    if any(in_types.get(tp.stage) is None for tp in taps):
        return None
    w = dyadic_weights([tp.w for tp in taps])
    if w is None:
        return None
    wq, w_beta = w
    bmax = max(in_types[tp.stage].beta for tp in taps)
    int_taps: List[IntTap] = []
    tap_bounds: List[int] = []
    for tp, q in zip(taps, wq):
        t_in = in_types[tp.stage]
        W = q << (bmax - t_in.beta)
        if W == 0:
            continue
        qa = (_tap_qabs_narrow(st, tp, t_in, (in_phases or {}).get(tp.stage))
              if narrow else _qabs(t_in))
        int_taps.append(IntTap(tp.stage, tp.dy, tp.dx, W))
        tap_bounds.append(abs(W) * qa)
    bound = sum(tap_bounds)
    if bound >= F64_EXACT:
        # the oracle's own float sum may round — only `expr` replays that
        return None
    ds = dyadic_scale(scale)
    if ds is not None:
        sm, se = ds
        t_shift = se + w_beta + bmax - t_out.beta
        # the oracle computes fl(s * sum): exact only while |sm * acc|
        # fits a double's mantissa — beyond that the float tree rounds and
        # only the `expr` kind can replay it.  The carrier must hold the
        # *finished* value too: a negative t_shift left-shifts the product
        # (beta_out deeper than the input grid), so bound the post-shift
        # magnitude, not just the accumulator.
        prod = bound * abs(sm)
        if t_shift < 0:
            fin = prod << (-t_shift)
        else:
            fin = prod + (1 << max(t_shift - 1, 0))
        if fin >= F64_EXACT:
            return None
        plan = dict(int_taps=tuple(int_taps), sm=sm, t_shift=t_shift,
                    dyadic=True, cscale=1.0, acc_bound=bound)
        gate = fin       # the finishing multiply/shift runs in-carrier
    else:
        # non-dyadic scale: one f64 multiply finishes the stage, bit-equal
        # to the oracle's fl(scale * sum) (power-of-two rescale is
        # lossless); the carrier only has to hold the raw accumulator
        cscale = scale * 2.0 ** (t_out.beta - w_beta - bmax)
        plan = dict(int_taps=tuple(int_taps), sm=1, t_shift=0, dyadic=False,
                    cscale=cscale, acc_bound=bound)
        gate = bound
    if gate < INT32_BUDGET:
        plan.update(carrier="int32", acc_split=0,
                    election="int32" if narrow else "")
        return plan
    if not narrow:
        plan.update(carrier="int64", acc_split=0)
        return plan
    # narrow mode: split the accumulation across an int32 pair when every
    # partial sum fits; the widening combine + finish run in int64
    if bound < INT32_BUDGET:
        sp = (list(range(len(int_taps))), len(int_taps))
    else:
        sp = _split_int32(tap_bounds)
    if sp is not None:
        order_ix, k = sp
        plan["int_taps"] = tuple(int_taps[i] for i in order_ix)
        plan.update(
            carrier="int32pair", acc_split=k,
            election=(f"int32pair: acc bound 2^{bound.bit_length()} split "
                      f"{k}+{len(int_taps) - k} taps under INT32_BUDGET"))
        return plan
    why = ("a single tap's bound exceeds INT32_BUDGET"
           if max(tap_bounds) >= INT32_BUDGET
           else "no 2-way tap split fits INT32_BUDGET")
    plan.update(carrier="int64", acc_split=0,
                election=(f"int64 kept: acc bound "
                          f"2^{bound.bit_length()} — {why}"))
    return plan


def _phase_snap(t_union: FixedPointType, entry) -> PhaseSnap:
    (my, mx), tmap = entry
    return PhaseSnap(lattice=(my, mx), types=dict(tmap),
                     int_ok=all(t.beta == t_union.beta
                                for t in tmap.values()))


def lower(pipeline: Pipeline, types, params: Optional[Dict[str, float]] = None,
          column: Optional[str] = None,
          datapath: str = "exact") -> LoweredPipeline:
    """Lower `(Pipeline, BitwidthPlan-or-TypeMap)` into a typed program.

    Mirrors `dsl.exec.run_fixed`'s duck-typed plan handling: a plan
    supplies its `column` types plus per-phase sub-types; a plain dict is
    a per-stage union type map.

    `datapath="narrow"` turns on int32/f32-first re-election (see the
    module docstring); every election — and every justified 64-bit
    retention — is recorded on the stages and, when `types` is a
    `BitwidthPlan`, appended to the plan column's provenance notes.
    """
    from repro import obs
    if datapath not in ("exact", "narrow"):
        raise LoweringError(f"unknown datapath mode {datapath!r}; "
                            "expected 'exact' or 'narrow'")
    narrow = datapath == "narrow"
    phase_types = {}
    col = column
    plan_obj = None
    if hasattr(types, "phase_types"):            # BitwidthPlan (duck-typed)
        plan_obj = types
        phase_types = plan_obj.phase_types(column) or {}
        col = column or getattr(plan_obj, "default_column", None)
        types = plan_obj.types(column)
    with obs.span("lowering.lower", pipeline=pipeline.name, column=col,
                  n_stages=len(pipeline.stages), datapath=datapath) as sp:
        tmap: Dict[str, Optional[FixedPointType]] = {
            n: types.get(n) for n in pipeline.stages}
        stages: Dict[str, LoweredStage] = {}
        order = pipeline.topo_order()
        # stages whose values backends must keep as floats (no single
        # scaled-int grid): untyped, wider than a double's mantissa, or
        # residue-mixed-beta.  Their consumers cannot take the integer path.
        float_stored: set = set()
        for name in order:
            st = pipeline.stages[name]
            t_out = tmap.get(name)
            halo = st.halo_yx()
            phase = None
            if name in phase_types and t_out is not None:
                phase = _phase_snap(t_out, phase_types[name])
            sf = (t_out is None or t_out.width > 52
                  or (phase is not None and not phase.int_ok))
            if sf:
                float_stored.add(name)
            if st.is_input:
                stages[name] = LoweredStage(name=name, kind="input", stage=st,
                                            t=t_out, halo=(0, 0),
                                            store_float=sf)
                continue
            lin = match_linear(st.expr) if t_out is not None else None
            plan_int = None
            if lin is not None and not sf \
                    and not any(i in float_stored for i in st.inputs):
                plan_int = _plan_intlinear(
                    st, lin[0], lin[1], t_out,
                    {i: tmap.get(i) for i in st.inputs},
                    narrow=narrow,
                    in_phases={i: stages[i].phase for i in st.inputs})
            if plan_int is not None:
                stages[name] = LoweredStage(name=name, kind="intlinear",
                                            stage=st, t=t_out, halo=halo,
                                            phase=phase, **plan_int)
            else:
                expr_dtype, election = "f64", ""
                if narrow:
                    reason = _expr_fits_f32(st, t_out, tmap, float_stored,
                                            phase)
                    if reason is None:
                        expr_dtype, election = "f32", "f32"
                    else:
                        election = f"f64 kept: {reason}"
                stages[name] = LoweredStage(name=name, kind="expr", stage=st,
                                            t=t_out, halo=halo, phase=phase,
                                            store_float=sf,
                                            expr_dtype=expr_dtype,
                                            election=election)
        kinds = [s.kind for s in stages.values()]
        sp.set(intlinear=kinds.count("intlinear"), expr=kinds.count("expr"))
        if narrow:
            sp.set(narrowed=sum(1 for s in stages.values()
                                if s.election in ("int32", "f32")
                                or s.carrier == "int32pair"))
            if plan_obj is not None and hasattr(plan_obj, "record_election"):
                plan_obj.record_election(col, _election_notes(pipeline.name,
                                                              stages))
    return LoweredPipeline(pipeline=pipeline, stages=stages, order=order,
                           params=dict(params or {}), types=tmap, column=col,
                           datapath=datapath)


def _election_notes(pipe_name: str,
                    stages: Dict[str, LoweredStage]) -> List[str]:
    """Provenance lines for a narrow-mode lowering: one census line plus
    one justification line per retained 64-bit datapath."""
    labels = []
    details = []
    for name, ls in stages.items():
        if ls.stage.is_input:
            continue
        label = ls.carrier if ls.kind == "intlinear" else ls.expr_dtype
        labels.append(f"{name}={label}")
        if ls.election.startswith(("int64 kept", "f64 kept")):
            details.append(f"datapath[narrow] {pipe_name}.{name}: "
                           f"{ls.election}")
    return [f"datapath[narrow] {pipe_name}: " + ", ".join(labels)] + details
