"""HBM roofline for the lowered pipeline executors.

Every fused executor in this repo is memory-bound: the datapath is a few
integer MACs per pixel, so the floor on frame time is the stage traffic
the cost model already counts — `design_cost(...).bytes_per_pixel_tpu`,
the per-pixel HBM bytes after container legalization (`core.policy`).
`pipeline_roofline` turns one measured frame time into that comparison:

    model_bytes   = bytes_per_pixel * H * W
    floor_ms      = model_bytes / HBM_BW
    achieved_gbs  = model_bytes / measured frame time
    hbm_frac      = achieved / peak        (1.0 == riding the roof)

On the CPU/interpret hosts CI runs on, `hbm_frac` is a sanity ratio, not
a hardware claim — the number exists so the throughput benchmark and the
job summary can show how far each pipeline sits from the v5e roof the
bytes model targets, and so regressions in *model* bytes/pixel (a plan
or policy change) are visible next to regressions in measured time.

    PYTHONPATH=src python -m benchmarks.roofline   # table from the
                                                   # throughput blob
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

HBM_BW = 819e9               # B/s per chip (TPU v5e)


def measured_bytes(lp, shape, outputs=None) -> Dict[str, object]:
    """*Measured* stored-container traffic of a lowered program.

    Unlike the cost model's `bytes_per_pixel_tpu` (a design-time price),
    this sums what the executors actually materialize: every needed
    stage's ``H_s * W_s * itemsize(store_dtype)``, per root pixel —
    deterministic, so it can gate hard in CI.  `wide_bytes_per_pixel`
    re-prices the same stages under the pre-legalization int32/int64/f64
    rule; their ratio is the traffic the narrow containers removed.
    """
    import numpy as np

    from repro.lowering.backends import (needed_stages, store_dtype,
                                         wide_store_dtype)
    from repro.lowering.schedule import stage_shapes

    order = needed_stages(lp, list(outputs or lp.pipeline.outputs))
    shapes = stage_shapes(lp, tuple(shape))
    pixels = float(shape[0] * shape[1])
    narrow = wide = 0.0
    mix: Dict[str, int] = {}
    for n in order:
        ls = lp.stages[n]
        h, w = shapes[n]
        dt = np.dtype(store_dtype(ls))
        narrow += h * w * dt.itemsize
        wide += h * w * np.dtype(wide_store_dtype(ls)).itemsize
        mix[dt.name] = mix.get(dt.name, 0) + 1
    return {
        "measured_bytes_per_pixel": narrow / pixels,
        "wide_bytes_per_pixel": wide / pixels,
        "container_mix": ",".join(f"{k}x{v}" for k, v in sorted(mix.items())),
        "bytes_saved_frac": 1.0 - narrow / wide if wide else 0.0,
    }


def pipeline_roofline(pipeline, types, frame_ms: float, shape,
                      phase_types: Optional[Dict] = None,
                      datapaths: Optional[Dict] = None,
                      lowered=None) -> Dict[str, float]:
    """Roofline record for one (pipeline, type map, measured frame time).

    `datapaths` (a `cost_model.lowered_datapaths` map) prices the model
    bytes from the actual lowering election when given; `lowered` (a
    `LoweredPipeline`) additionally reports the *measured* stored-
    container bytes next to the model number (`measured_bytes`).
    """
    from repro.core.cost_model import design_cost
    cost = design_cost(pipeline, types, image_width=shape[1],
                       phase_types=phase_types, datapaths=datapaths)
    pixels = float(shape[0] * shape[1])
    model_bytes = cost.bytes_per_pixel_tpu * pixels
    achieved = model_bytes / (frame_ms * 1e-3) if frame_ms > 0 else 0.0
    rec = {
        "bytes_per_pixel": cost.bytes_per_pixel_tpu,
        "model_mb_per_frame": model_bytes / 1e6,
        "floor_ms": model_bytes / HBM_BW * 1e3,
        "achieved_gbs": achieved / 1e9,
        "hbm_frac": achieved / HBM_BW,
    }
    if lowered is not None:
        rec.update(measured_bytes(lowered, shape))
    return rec


def main() -> None:
    here = os.path.dirname(os.path.abspath(__file__))
    blob_path = os.path.join(os.path.dirname(here),
                             "BENCH_pipeline_throughput.json")
    with open(blob_path) as f:
        blob = json.load(f)
    h, w = blob["shape"]
    print(f"shape {h}x{w}  (HBM roof {HBM_BW / 1e9:.0f} GB/s)")
    print(f"{'bench':10s} {'B/px':>7s} {'meas':>7s} {'wide':>7s} "
          f"{'floor_ms':>9s} {'jnp_ms':>8s} {'GB/s':>7s} {'roof%':>6s}")
    for name, e in blob["benchmarks"].items():
        r = e.get("roofline")
        if not r:
            continue
        meas = r.get("measured_bytes_per_pixel")
        wide = r.get("wide_bytes_per_pixel")
        print(f"{name:10s} {r['bytes_per_pixel']:7.1f} "
              f"{meas if meas is not None else float('nan'):7.1f} "
              f"{wide if wide is not None else float('nan'):7.1f} "
              f"{r['floor_ms']:9.4f} {e['lowered_jnp_ms']:8.2f} "
              f"{r['achieved_gbs']:7.2f} {100 * r['hbm_frac']:5.1f}%")


if __name__ == "__main__":
    main()
