"""Roofline analysis (§Roofline of EXPERIMENTS.md).

Per (arch x shape), single-pod mesh, derive three time terms on TPU v5e:

    compute    = FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HBM bytes / (chips * 819e9 B/s)
    collective = collective bytes / (chips * 50e9 B/s per ICI link)

Sources:
  * FLOPs / HBM bytes: compiled cost_analysis, corrected for scan-once
    counting by the DIFFERENTIAL method — lower each cell at scan_unroll=1
    and scan_unroll=2; the difference is one extra scan-body, so
        corrected = C1 + (trips - 1) * (C2 - C1)
    For chunked-recurrence archs (rwkv/hybrid) the inner chunk scan is also
    counted once; the analytic per-chunk model (launch/flops.py) supplies
    that correction and the report flags it.
  * collective bytes: parsed from compiled HLO (launch/lowering.py), same
    differential correction.
  * MODEL_FLOPS: 6*N*D / 6*N_active*D (launch/flops.py).

Writes benchmarks/results/roofline.json + a markdown table.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip (v5e)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
CHIPS = 256                  # single pod


def scan_trips(cfg, cell) -> int:
    """Trip count of the outer layer scan for the differential correction."""
    if cfg.arch_class == "hybrid":
        trips = cfg.n_layers // cfg.shared_attn_period
    elif cfg.arch_class == "encdec":
        trips = cfg.n_layers          # decoder dominates; encoder handled too
    else:
        trips = cfg.n_layers
    return trips


def accum_steps_for(cfg, cell) -> int:
    return 4 if (cfg.is_moe and cell.kind == "train") else 1


def measure_cell(arch: str, shape: str, seq_parallel: bool = True,
                 overrides: Optional[Dict] = None,
                 accum: Optional[int] = None) -> Dict:
    """Differential lowering -> corrected per-device cost terms."""
    import jax
    from repro.configs import get_config
    from repro.launch.lowering import lower_cell
    from repro.launch.mesh import make_production_mesh
    from repro.launch.shapes import SHAPES, skip_reason
    from repro.launch.flops import analytic_flops

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    reason = skip_reason(cfg, cell)
    if reason:
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": reason}
    mesh = make_production_mesh(multi_pod=False)

    def one(unroll: int):
        c = dataclasses.replace(cfg, scan_unroll=unroll)
        lc = lower_cell(arch, c, cell, mesh, "pod16x16",
                        seq_parallel=seq_parallel, accum_steps=accum)
        return lc.analyses()

    t0 = time.time()
    a1 = one(1)
    a2 = one(2)
    trips = scan_trips(cfg, cell)
    accum = accum if accum else accum_steps_for(cfg, cell)

    def corrected(key):
        c1, c2 = a1[key], a2[key]
        body = max(c2 - c1, 0.0)
        total = c1 + (trips - 1) * body
        if accum > 1:
            # the microbatch scan is ALSO counted once; the whole model part
            # scales with accum (the update part doesn't — treat the layer
            # body total as the microbatch content)
            total = c1 + (trips - 1) * body + (accum - 1) * trips * body
        return total

    coll1 = a1["collective_bytes"].get("total", 0.0)
    coll2 = a2["collective_bytes"].get("total", 0.0)
    coll_body = max(coll2 - coll1, 0.0)
    coll = coll1 + (trips - 1) * coll_body
    if accum > 1:
        coll += (accum - 1) * trips * coll_body

    flops = corrected("flops")
    hbm = corrected("hbm_bytes")

    # inner chunk-scan correction for linear-recurrence archs: the chunk
    # scan's body is also counted once; add the analytic recurrence work of
    # the remaining (nc - 1) chunks
    rec_note = ""
    if cfg.arch_class in ("rwkv", "hybrid") and cell.kind != "decode":
        nc = max(cell.seq // 64, 1)
        if cfg.arch_class == "rwkv":
            K = cfg.rwkv_head_dim
            H = cfg.d_model // K
            C = 64
            rec = cfg.n_layers * H * nc * (4 * C * C * K + 4 * C * K * K) \
                * cell.global_batch
        else:
            d_inner = cfg.ssm_expand * cfg.d_model
            N, P = cfg.ssm_state, cfg.ssm_head_dim
            H = d_inner // P
            C = 64
            rec = cfg.n_layers * nc * (
                2 * C * C * N + H * (C * C + 2 * C * C * P + 4 * C * N * P)) \
                * cell.global_batch
        mult = 3 if cell.kind == "train" else 1
        flops += mult * rec * (nc - 1) / nc / CHIPS
        rec_note = f"+analytic chunk-scan correction ({nc} chunks)"

    ar = analytic_flops(cfg, cell)
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    model_per_dev = ar.model_flops / CHIPS
    rec_dict = {
        "arch": arch, "shape": shape, "status": "ok",
        "flops_per_dev": flops, "hbm_bytes_per_dev": hbm,
        "coll_bytes_per_dev": coll,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": model_per_dev,
        "useful_ratio": model_per_dev / flops if flops else 0.0,
        "roofline_fraction": t_compute / max(t_compute, t_memory, t_coll),
        "memory_temp_gb": a1["memory"]["temp_size"] / 1e9,
        "memory_args_gb": a1["memory"]["argument_size"] / 1e9,
        "note": rec_note,
        "measure_s": round(time.time() - t0, 1),
    }
    return rec_dict


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import ARCH_IDS
    from repro.launch.shapes import SHAPES

    here = os.path.dirname(os.path.abspath(__file__))
    out_path = args.out or os.path.join(here, "results", "roofline.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    records = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            records = [r for r in json.load(f)
                       if not ((args.arch is None or r["arch"] == args.arch)
                               and (args.shape is None
                                    or r["shape"] == args.shape))]

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            rec = measure_cell(arch, shape)
            records.append(rec)
            if rec["status"] == "ok":
                print(f"{arch:18s} {shape:12s} comp={rec['t_compute_s']*1e3:8.2f}ms "
                      f"mem={rec['t_memory_s']*1e3:8.2f}ms "
                      f"coll={rec['t_collective_s']*1e3:8.2f}ms "
                      f"dom={rec['dominant']:10s} "
                      f"useful={rec['useful_ratio']:.2f}", flush=True)
            else:
                print(f"{arch:18s} {shape:12s} SKIP", flush=True)
            with open(out_path, "w") as f:
                json.dump(records, f, indent=1)
    print("wrote", out_path)


if __name__ == "__main__":
    import os as _os
    _os.environ.setdefault("XLA_FLAGS",
                           "--xla_force_host_platform_device_count=512")
    main()
