"""Generate the data-driven sections of EXPERIMENTS.md from results JSON.

    PYTHONPATH=src python -m benchmarks.report > EXPERIMENTS_tables.md
"""
from __future__ import annotations

import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))


def load(name):
    path = os.path.join(HERE, "results", name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def dryrun_table() -> str:
    recs = load("dryrun.json")
    lines = ["| arch | shape | mesh | compile | FLOPs/dev | HBM B/dev | "
             "coll B/dev | temp GB | args GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP (sub-quadratic only) | | | | | |")
            continue
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']}s | {r['flops']:.2e} | {r['hbm_bytes']:.2e} | "
            f"{r['collective_bytes'].get('total', 0):.2e} | "
            f"{m['temp_size'] / 1e9:.1f} | {m['argument_size'] / 1e9:.1f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    recs = load("roofline.json")
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f}ms | "
            f"{r['t_memory_s']*1e3:.1f}ms | {r['t_collective_s']*1e3:.1f}ms | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def perf_table() -> str:
    recs = load("perf_iters.json")
    lines = ["| cell | variant | compute | memory | collective | dominant | "
             "step time (max term) |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        step = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        lines.append(
            f"| {r['cell']} | {r['variant']} | {r['t_compute_s']*1e3:.1f}ms |"
            f" {r['t_memory_s']*1e3:.1f}ms | {r['t_collective_s']*1e3:.1f}ms |"
            f" {r['dominant']} | {step*1e3:.1f}ms |")
    return "\n".join(lines)


def main():
    print("## Dry-run table\n")
    print(dryrun_table())
    print("\n## Roofline table\n")
    print(roofline_table())
    print("\n## Perf iterations\n")
    print(perf_table())


if __name__ == "__main__":
    main()
