"""Benchmark harness — one function per paper table/figure + extensions.

    PYTHONPATH=src python -m benchmarks.run             # all benchmarks
    PYTHONPATH=src python -m benchmarks.run --only table2_hcd_ranges,kernels

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the
benchmark body; derived = the benchmark's headline result).  Detailed rows
go to benchmarks/results/<name>.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _kernel_bench():
    """Pallas kernels: interpret-mode correctness + jitted-oracle timing."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.fixedpoint import FixedPointType
    from repro.kernels.qdq import ops as qdq_ops
    from repro.kernels.qmatmul.ops import matmul_quantized
    from repro.kernels.stencil.ops import stencil_fixed

    rng = np.random.default_rng(0)
    rows = []

    img = jnp.asarray(rng.integers(0, 256, (64, 64)).astype(np.float32))
    t_in = FixedPointType(8, 0, signed=False)
    t_out = FixedPointType(9, 4, signed=True)
    f = lambda: stencil_fixed(img, [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]],
                              1 / 12, t_in, t_out, use_ref=True)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f().block_until_ready()
    rows.append(("stencil_ref_64x64", (time.perf_counter() - t0) / 20 * 1e6))

    a = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    f = lambda: matmul_quantized(a, b, use_ref=True)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f().block_until_ready()
    rows.append(("qmatmul_ref_256", (time.perf_counter() - t0) / 20 * 1e6))

    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    f = lambda: qdq_ops.fake_quant(x, use_ref=True)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f().block_until_ready()
    rows.append(("qdq_ref_16k", (time.perf_counter() - t0) / 20 * 1e6))
    return rows, "jitted oracle paths (Pallas kernels validated in tests)"


def _lm_quant_bench():
    """Beyond-paper: AutoQuant on LM smoke models (token agreement)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data.batches import make_batch
    from repro.models.registry import get_model
    from repro.quant.autoquant import autoquant

    rows = []
    for arch in ("qwen3-4b", "rwkv6-3b", "mixtral-8x7b"):
        cfg = get_smoke_config(arch)
        m = get_model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        batches = [make_batch(cfg, 2, 16, seed=s) for s in range(2)]
        res = autoquant(m, params, batches, target_agreement=0.95)
        rows.append((arch, res.bits, round(res.quality, 4),
                     res.profile_passes, round(res.bytes_ratio, 3)))
    return rows, "per-class weight bits via the paper's beta-search loop"


def _lm_beta_sweep():
    """Paper Fig. 6, LM edition: token agreement vs uniform weight bits."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data.batches import make_batch
    from repro.models.registry import get_model
    from repro.quant.autoquant import fake_quant_params, token_agreement
    from repro.quant.calibrate import REVERSE_TOPO_CLASSES

    cfg = get_smoke_config("qwen3-4b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, seed=0)
    ref = m.forward(params, batch)
    rows = []
    for bits in (8, 6, 4, 3, 2):
        qp = fake_quant_params(params,
                               {c: bits for c in REVERSE_TOPO_CLASSES})
        agree = token_agreement(ref, m.forward(qp, batch))
        rows.append((bits, round(agree, 4), round(bits / 16, 3)))
    knee = next((b for b, a, _ in rows if a < 0.9), 2)
    return rows, (f"agreement degrades gracefully to ~{knee} bits "
                  f"(paper Fig.6: HCD accuracy flat until beta floor)")


def _smt_throughput():
    """Solver-throughput smoke: boxes/sec on a fixed HCD decide workload.

    Runs the batched engine and the scalar reference oracle on the same
    query — "can HCD's det exceed 2^30?" — at their production node budgets
    and reports boxes/sec for each plus the speedup.  CI prints this line
    so hot-loop regressions in the branch-and-prune core are visible.
    """
    import time as _t
    from repro.core.range_analysis import analyze
    from repro.pipelines import hcd
    from repro.smt import solver as S
    from repro.smt.encoder import encode_stage

    p = hcd.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "det", bounds)
    threshold = 2.0 ** 30        # deep in UNKNOWN territory: forces search
    rows = []
    rates = {}
    for name, fn, nodes in (("batched", S.decide, 4096),
                            ("scalar", S.decide_scalar, 256)):
        t0 = _t.perf_counter()
        v = fn(csp, root, "ge", threshold, S.BPBudget(nodes, 6))
        dt = _t.perf_counter() - t0
        rates[name] = v.nodes / dt
        rows.append((name, v.status, v.nodes, round(dt, 3),
                     round(rates[name], 1)))
    speedup = rates["batched"] / max(rates["scalar"], 1e-9)
    return rows, (f"HCD det decide: batched {rates['batched']:.0f} boxes/s "
                  f"vs scalar {rates['scalar']:.0f} boxes/s "
                  f"({speedup:.1f}x)")


BENCHES = {}


def _register():
    from benchmarks import paper_tables as T
    BENCHES.update({
        "table2_hcd_ranges": T.table2_hcd_ranges,
        "table3_hcd_power": T.table3_hcd_power,
        "table4_hcd_bitwidths": T.table4_hcd_bitwidths,
        "table5_usm_bitwidths": T.table5_usm_bitwidths,
        "table6_usm_power": T.table6_usm_power,
        "table7_dus_power": T.table7_dus_power,
        "table8_dus_bitwidths": T.table8_dus_bitwidths,
        "table9_of_bitwidths": T.table9_of_bitwidths,
        "table10_of_power": T.table10_of_power,
        "table11_smt_alphas": T.table11_smt_alphas,
        "fig5_cdf": T.fig5_cdf,
        "fig6_beta_sweep": T.fig6_beta_sweep,
        "kernels": _kernel_bench,
        "lm_quant": _lm_quant_bench,
        "lm_beta_sweep": _lm_beta_sweep,
        "smt_throughput": _smt_throughput,
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    _register()
    names = [n for n in args.only.split(",") if n] or list(BENCHES)

    here = os.path.dirname(os.path.abspath(__file__))
    outdir = os.path.join(here, "results")
    os.makedirs(outdir, exist_ok=True)

    print("name,us_per_call,derived")
    for name in names:
        fn = BENCHES[name]
        t0 = time.perf_counter()
        rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},\"{derived}\"", flush=True)
        with open(os.path.join(outdir, f"{name}.json"), "w") as f:
            json.dump({"rows": [list(map(str, r)) for r in rows],
                       "derived": derived, "us_per_call": us}, f, indent=1)


if __name__ == "__main__":
    main()
