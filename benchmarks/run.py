"""Benchmark harness — one function per paper table/figure + extensions.

    PYTHONPATH=src python -m benchmarks.run             # all benchmarks
    PYTHONPATH=src python -m benchmarks.run --only table2_hcd_ranges,kernels
    PYTHONPATH=src python -m benchmarks.run --only pipeline_throughput \
        --trace trace_out                               # traced run

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the
benchmark body; derived = the benchmark's headline result).  Detailed rows
go to benchmarks/results/<name>.json.

``--trace <dir>`` runs every selected benchmark under a fresh `repro.obs`
tracer with runtime range telemetry on, and writes two artifacts per
benchmark into <dir>: ``<name>.trace.json`` (Chrome trace-event JSON —
load in ui.perfetto.dev or chrome://tracing) and ``<name>.jsonl`` (the
event stream ``python -m repro.obs.report`` summarizes).  Tracing changes
no benchmark outputs (telemetry is read-only post-processing) but does
add measurement overhead — don't compare traced timings against untraced
ones.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _kernel_bench():
    """Pallas kernels: interpret-mode correctness + jitted-oracle timing."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.fixedpoint import FixedPointType
    from repro.kernels.qdq import ops as qdq_ops
    from repro.kernels.qmatmul.ops import matmul_quantized
    from repro.kernels.stencil.ops import stencil_fixed

    rng = np.random.default_rng(0)
    rows = []

    img = jnp.asarray(rng.integers(0, 256, (64, 64)).astype(np.float32))
    t_in = FixedPointType(8, 0, signed=False)
    t_out = FixedPointType(9, 4, signed=True)
    f = lambda: stencil_fixed(img, [[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]],
                              1 / 12, t_in, t_out, use_ref=True)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f().block_until_ready()
    rows.append(("stencil_ref_64x64", (time.perf_counter() - t0) / 20 * 1e6))

    a = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(256, 256)).astype(np.float32))
    f = lambda: matmul_quantized(a, b, use_ref=True)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f().block_until_ready()
    rows.append(("qmatmul_ref_256", (time.perf_counter() - t0) / 20 * 1e6))

    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    f = lambda: qdq_ops.fake_quant(x, use_ref=True)
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f().block_until_ready()
    rows.append(("qdq_ref_16k", (time.perf_counter() - t0) / 20 * 1e6))
    return rows, "jitted oracle paths (Pallas kernels validated in tests)"


def _lm_quant_bench():
    """Beyond-paper: AutoQuant on LM smoke models (token agreement)."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data.batches import make_batch
    from repro.models.registry import get_model
    from repro.quant.autoquant import autoquant

    rows = []
    for arch in ("qwen3-4b", "rwkv6-3b", "mixtral-8x7b"):
        cfg = get_smoke_config(arch)
        m = get_model(cfg)
        params = m.init_params(jax.random.PRNGKey(0))
        batches = [make_batch(cfg, 2, 16, seed=s) for s in range(2)]
        res = autoquant(m, params, batches, target_agreement=0.95)
        rows.append((arch, res.bits, round(res.quality, 4),
                     res.profile_passes, round(res.bytes_ratio, 3)))
    return rows, "per-class weight bits via the paper's beta-search loop"


def _lm_beta_sweep():
    """Paper Fig. 6, LM edition: token agreement vs uniform weight bits."""
    import jax
    from repro.configs import get_smoke_config
    from repro.data.batches import make_batch
    from repro.models.registry import get_model
    from repro.quant.autoquant import fake_quant_params, token_agreement
    from repro.quant.calibrate import REVERSE_TOPO_CLASSES

    cfg = get_smoke_config("qwen3-4b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32, seed=0)
    ref = m.forward(params, batch)
    rows = []
    for bits in (8, 6, 4, 3, 2):
        qp = fake_quant_params(params,
                               {c: bits for c in REVERSE_TOPO_CLASSES})
        agree = token_agreement(ref, m.forward(qp, batch))
        rows.append((bits, round(agree, 4), round(bits / 16, 3)))
    knee = next((b for b, a, _ in rows if a < 0.9), 2)
    return rows, (f"agreement degrades gracefully to ~{knee} bits "
                  f"(paper Fig.6: HCD accuracy flat until beta floor)")


def _smt_throughput():
    """Solver-throughput smoke: boxes/sec on a fixed HCD decide workload.

    Runs the batched engine and the scalar reference oracle on the same
    query — "can HCD's det exceed 2^30?" — at their production node budgets
    and reports boxes/sec for each plus the speedup.  CI prints this line
    so hot-loop regressions in the branch-and-prune core are visible.
    """
    import time as _t
    from repro.core.range_analysis import analyze
    from repro.pipelines import hcd
    from repro.smt import solver as S
    from repro.smt.encoder import encode_stage

    p = hcd.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "det", bounds)
    threshold = 2.0 ** 30        # deep in UNKNOWN territory: forces search
    rows = []
    rates = {}
    for name, fn, nodes in (("batched", S.decide, 4096),
                            ("scalar", S.decide_scalar, 256)):
        t0 = _t.perf_counter()
        v = fn(csp, root, "ge", threshold, S.BPBudget(nodes, 6))
        dt = _t.perf_counter() - t0
        rates[name] = v.nodes / dt
        rows.append((name, v.status, v.nodes, round(dt, 3),
                     round(rates[name], 1)))
    speedup = rates["batched"] / max(rates["scalar"], 1e-9)
    return rows, (f"HCD det decide: batched {rates['batched']:.0f} boxes/s "
                  f"vs scalar {rates['scalar']:.0f} boxes/s "
                  f"({speedup:.1f}x)")


def _pipeline_throughput():
    """End-to-end fixed-pipeline throughput: interpreter vs plan-lowered.

    Runs USM / HCD / DUS-ext through the three executors of the
    plan-driven compile path (docs/execution_backends.md):

      * interpreter — per-stage `run_fixed` numpy oracle
      * lowered-jnp — one fused jit program (`repro.lowering`, backend
        "jnp"); the acceptance bar is >=3x over the interpreter on at
        least one benchmark
      * pallas      — the fused line-buffer kernel; *interpret mode* on
        CPU (a pure-python emulation, reported for completeness but not a
        performance number; on a real TPU pass interpret=False)

    Every backend's outputs are checked bit-for-bit against the oracle
    before timing.  Emits BENCH_pipeline_throughput.json at the repo root
    (uploaded as a CI artifact) in addition to the harness row JSON.

    Env knobs: REPRO_BENCH_ROWS (default 512 — small sizes measure jax
    dispatch overhead, not the datapath), REPRO_BENCH_REPS (default 8),
    REPRO_BENCH_PALLAS=0 to skip pallas timing (it is interpret-mode
    slow; correctness is still checked at a small size).

    Each entry also records its rate-island count and an HBM roofline
    record (`benchmarks.roofline.pipeline_roofline`): cost-model
    bytes/pixel, the frame-time floor those bytes imply, and the
    achieved GB/s of the lowered-jnp executor.
    """
    import warnings

    import numpy as np

    from benchmarks.roofline import pipeline_roofline
    from repro.dsl.exec import run_fixed
    from repro.core.cost_model import lowered_datapaths
    from repro.lowering import compile_pipeline, partition_islands
    from repro.pipelines import dus, hcd, usm
    from repro.pipelines import workflows as W

    DEFAULT_ROWS = 512
    rows_n = int(os.environ.get("REPRO_BENCH_ROWS", DEFAULT_ROWS))
    reps = int(os.environ.get("REPRO_BENCH_REPS", 8))
    time_pallas = os.environ.get("REPRO_BENCH_PALLAS", "1") != "0"
    shape = (rows_n, rows_n)
    rows, blob = [], {"shape": list(shape), "reps": reps, "benchmarks": {}}
    if rows_n < DEFAULT_ROWS:
        # sub-default shapes time dispatch overhead, not the datapath —
        # keep the artifact honest about it
        warnings.warn(
            f"pipeline_throughput at {rows_n}x{rows_n} (default "
            f"{DEFAULT_ROWS}x{DEFAULT_ROWS}): timings measure jax "
            f"dispatch overhead, not the datapath; the emitted JSON is "
            f"marked debug_shape", RuntimeWarning, stacklevel=2)
        blob["debug_shape"] = True
    for name, pipe, params in (
            ("usm", usm.build(), dict(usm.DEFAULT_PARAMS)),
            ("hcd", hcd.build(), {}),
            ("dus_ext", dus.build_extended(), {})):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            alphas, signed = W.static_alphas(pipe)
            types = W.types_from_alpha(pipe, alphas, signed,
                                       {n: 4 for n in pipe.stages})
        img = np.random.default_rng(0).integers(
            0, 256, shape).astype(np.float64)
        oracle = run_fixed(pipe, img, types, params)

        def bench(fn, n):
            fn()                       # warm (compile included, untimed)
            t0 = time.perf_counter()
            for _ in range(n):
                fn()
            return (time.perf_counter() - t0) / n

        t_int = bench(lambda: run_fixed(pipe, img, types, params),
                      max(reps // 3, 2))
        entry = {"interp_ms": t_int * 1e3}

        run_jnp = compile_pipeline(pipe, types, params=params, backend="jnp")
        got = run_jnp(img)
        exact = all(np.array_equal(np.asarray(oracle[k]), got[k])
                    for k in got)
        entry["lowered_jnp_ms"] = bench(lambda: run_jnp(img), reps) * 1e3
        entry["lowered_exact"] = bool(exact)
        entry["speedup_lowered"] = t_int * 1e3 / entry["lowered_jnp_ms"]

        # every DAG now lowers to fused pallas islands (no LoweringError
        # fallback left) — a failure here is a real bug and should raise
        run_pl = compile_pipeline(pipe, types, params=params,
                                  backend="pallas")
        small = img[:32, :32]
        o_small = run_fixed(pipe, small, types, params)
        g_small = run_pl(small)
        entry["pallas_exact"] = bool(all(
            np.array_equal(np.asarray(o_small[k]), g_small[k])
            for k in g_small))
        entry["islands"] = len(
            partition_islands(run_pl.lowered, shape).islands)
        if time_pallas:
            entry["pallas_interpret_ms"] = bench(
                lambda: run_pl(img), max(reps // 5, 1)) * 1e3

        entry["roofline"] = pipeline_roofline(
            pipe, types, entry["lowered_jnp_ms"], shape,
            datapaths=lowered_datapaths(run_jnp.lowered),
            lowered=run_jnp.lowered)
        blob["benchmarks"][name] = entry
        rows.append((name, round(entry["interp_ms"], 2),
                     round(entry["lowered_jnp_ms"], 2),
                     round(entry.get("pallas_interpret_ms", float("nan")), 2),
                     round(entry["speedup_lowered"], 2),
                     entry["lowered_exact"], entry["pallas_exact"]))

    here = os.path.dirname(os.path.abspath(__file__))
    out_path = os.path.join(os.path.dirname(here),
                            "BENCH_pipeline_throughput.json")
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1)
    best = max(blob["benchmarks"].items(),
               key=lambda kv: kv[1]["speedup_lowered"])
    broken = [n for n, e in blob["benchmarks"].items()
              if not (e["lowered_exact"] and e["pallas_exact"] is True)]
    if broken:
        # a perf number for a wrong answer is worthless — fail the run
        # (and the CI step) outright
        raise AssertionError(
            f"lowered/pallas outputs diverged from the run_fixed oracle on "
            f"{broken}; see {out_path}")
    _check_throughput_baseline(blob, os.path.dirname(here))
    return rows, (f"lowered-jnp best {best[1]['speedup_lowered']:.1f}x over "
                  f"interpreter on {best[0]} at {rows_n}x{rows_n} "
                  f"(bit-exact); pallas interpret-mode checked")


def _check_throughput_baseline(blob, root, tol: float = 0.20):
    """Perf-regression gate vs BENCH_pipeline_throughput.baseline.json.

    Measured bytes/pixel is deterministic (store-dtype x stage-shape
    arithmetic), so a >`tol` regression **fails** the run.  Wall-clock is
    machine-noisy, so a >`tol` `lowered_jnp_ms` regression *warns* by
    default and fails only under ``REPRO_BENCH_STRICT_MS=1`` (set on
    runners with stable hardware).  Debug shapes skip the gate — the
    baseline speaks for the default geometry only.
    """
    import warnings

    base_path = os.path.join(root, "BENCH_pipeline_throughput.baseline.json")
    if blob.get("debug_shape") or not os.path.exists(base_path):
        return
    with open(base_path) as f:
        base = json.load(f)
    if base.get("shape") != blob.get("shape"):
        warnings.warn(
            f"throughput baseline shape {base.get('shape')} != run shape "
            f"{blob.get('shape')}; skipping the regression gate",
            RuntimeWarning, stacklevel=2)
        return
    strict_ms = os.environ.get("REPRO_BENCH_STRICT_MS", "0") == "1"
    failures = []
    for name, be in base.get("benchmarks", {}).items():
        e = blob["benchmarks"].get(name)
        if e is None:
            continue
        b_bytes = be.get("roofline", {}).get("measured_bytes_per_pixel")
        n_bytes = e.get("roofline", {}).get("measured_bytes_per_pixel")
        if b_bytes and n_bytes and n_bytes > b_bytes * (1 + tol):
            failures.append(
                f"{name}: measured bytes/pixel {n_bytes:.1f} vs baseline "
                f"{b_bytes:.1f} (>{tol:.0%} regression)")
        b_ms, n_ms = be.get("lowered_jnp_ms"), e.get("lowered_jnp_ms")
        if b_ms and n_ms and n_ms > b_ms * (1 + tol):
            msg = (f"{name}: lowered_jnp_ms {n_ms:.2f} vs baseline "
                   f"{b_ms:.2f} (>{tol:.0%} regression)")
            if strict_ms:
                failures.append(msg)
            else:
                warnings.warn(f"throughput regression (non-strict): {msg}",
                              RuntimeWarning, stacklevel=2)
    if failures:
        raise AssertionError(
            "pipeline_throughput regressed vs the committed baseline:\n  "
            + "\n  ".join(failures))


def _serving_throughput():
    """Sustained serving throughput through `repro.serve.PipelineServer`.

    Drives USM frame streams through the batched serving harness
    (docs/serving.md) at batch sizes 1/4/16 and reports frames/sec plus
    p50/p99 per-frame latency (submit -> result, queueing included) per
    shape.  Shapes: ``smoke`` (64x64 — dispatch-overhead regime, where
    batching shows its >=2x win), ``1080p`` (1080x1920) and ``4k``
    (2160x3840) full-frame rates.

    Bit-exactness: EVERY (shape, batch) cell is verified against the
    per-image `run_fixed` numpy oracle and the run fails loudly on any
    mismatch — all served frames at the smoke shape, a sampled frame at
    the large shapes (the batched program is shape-generic; the sample
    proves this process's compile, while tests/test_serving.py pins the
    full cross-shape/plan battery).

    Emits BENCH_serving_throughput.json at the repo root (CI artifact +
    job summary).  Env knobs: REPRO_SERVE_SHAPES (comma list of smoke /
    1080p / 4k / HxW; default "smoke,1080p,4k" — CI smoke runs set
    "smoke"), REPRO_SERVE_BATCHES (default "1,4,16"),
    REPRO_SERVE_BACKEND (default "lowered"; also "pallas"/"sharded"),
    REPRO_SERVE_FRAMES (frames per measurement, default 2*batch,
    min 8).
    """
    import warnings

    import numpy as np

    from repro.dsl.exec import run_fixed
    from repro.pipelines import usm
    from repro.pipelines import workflows as W
    from repro.serve import PipelineServer

    NAMED = {"smoke": (64, 64), "1080p": (1080, 1920),
             "4k": (2160, 3840)}

    def parse_shape(s):
        if s in NAMED:
            return s, NAMED[s]
        h, w = s.lower().split("x")
        return s, (int(h), int(w))

    shapes = [parse_shape(s) for s in os.environ.get(
        "REPRO_SERVE_SHAPES", "smoke,1080p,4k").split(",") if s]
    batches = [int(b) for b in os.environ.get(
        "REPRO_SERVE_BATCHES", "1,4,16").split(",") if b]
    backend = os.environ.get("REPRO_SERVE_BACKEND", "lowered")
    frames_env = os.environ.get("REPRO_SERVE_FRAMES", "")

    pipe = usm.build()
    params = dict(usm.DEFAULT_PARAMS)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        alphas, signed = W.static_alphas(pipe)
        types = W.types_from_alpha(pipe, alphas, signed,
                                   {n: 4 for n in pipe.stages})

    rows = []
    blob = {"pipeline": "usm", "backend": backend, "shapes": {}}
    rng = np.random.default_rng(0)
    for label, (h, w) in shapes:
        n_frames_of = lambda b: int(frames_env) if frames_env \
            else max(2 * b, 8)
        imgs = [rng.integers(0, 256, (h, w)).astype(np.float64)
                for _ in range(max(n_frames_of(b) for b in batches))]
        # oracle reference frames: every frame at the smoke shape, a
        # sampled frame at the big shapes — so every (shape, batch)
        # cell below is verified (no silent verified:false rows)
        sample = range(len(imgs)) if label == "smoke" else range(1)
        oracle = {i: run_fixed(pipe, imgs[i], types, params)
                  for i in sample}
        shape_entry = {"h": h, "w": w, "batch": {}}
        for b in batches:
            n = n_frames_of(b)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", RuntimeWarning)
                with PipelineServer(pipe, types, params, backend=backend,
                                    batch_size=b) as srv:
                    srv.warmup([(h, w)])
                    t_done = [None] * n
                    futs = []
                    t0 = time.perf_counter()
                    for i in range(n):
                        fut = srv.submit(imgs[i])
                        fut.add_done_callback(
                            lambda f, i=i: t_done.__setitem__(
                                i, time.perf_counter()))
                        futs.append((time.perf_counter(), fut))
                    outs = [f.result() for _, f in futs]
                    t1 = max(t_done)
            checked = 0
            for i, out in enumerate(outs):
                ref = oracle.get(i)
                if ref is None:
                    continue
                for k in out:
                    if not np.array_equal(out[k], np.asarray(ref[k])):
                        raise AssertionError(
                            f"serving output diverged from the oracle "
                            f"(usm/{label}, batch={b}, frame {i}, "
                            f"stage {k!r})")
                checked += 1
            if checked == 0:       # a cell nobody verified is a harness bug
                raise AssertionError(
                    f"serving benchmark verified zero frames at "
                    f"usm/{label} batch={b}")
            lat_ms = [(t_done[i] - futs[i][0]) * 1e3 for i in range(n)]
            fps = n / (t1 - t0)
            entry = {"fps": fps, "frames": n,
                     "p50_ms": float(np.percentile(lat_ms, 50)),
                     "p99_ms": float(np.percentile(lat_ms, 99)),
                     "verified": True, "verified_frames": checked}
            shape_entry["batch"][str(b)] = entry
            rows.append((f"usm/{label}", b, round(fps, 2),
                         round(entry["p50_ms"], 2),
                         round(entry["p99_ms"], 2)))
        if "1" in shape_entry["batch"] and "16" in shape_entry["batch"]:
            shape_entry["speedup_b16_vs_b1"] = (
                shape_entry["batch"]["16"]["fps"]
                / shape_entry["batch"]["1"]["fps"])
        blob["shapes"][label] = shape_entry

    here = os.path.dirname(os.path.abspath(__file__))
    out_path = os.path.join(os.path.dirname(here),
                            "BENCH_serving_throughput.json")
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1)
    best = max((e for e in blob["shapes"].values()
                if "speedup_b16_vs_b1" in e),
               key=lambda e: e["speedup_b16_vs_b1"], default=None)
    head = "" if best is None else (
        f"; batch-16 {best['speedup_b16_vs_b1']:.1f}x batch-1 fps at "
        f"{best['h']}x{best['w']}")
    return rows, (f"usm serving via {backend} across "
                  f"{len(shapes)} shapes x batches {batches}{head}")


def _design_search():
    """Closed-loop bitwidth DSE (`repro.dse`) on USM / HCD / DUS-ext.

    Runs `run_design_search` — plan-seeded §V-B beta sweep, §IV
    homogeneity-cluster alpha descent, and the annealing controller —
    under per-benchmark PSNR budgets and emits
    ``BENCH_design_search.json`` at the repo root (CI artifact + job
    summary).  Every number on the frontier is *measured*: candidates
    are specialized and executed through the lowered backend against
    the f64 oracle, and the run re-verifies each returned point
    (`Evaluator.verify` — bit-exact lowered re-score + numpy oracle
    cross-check) before reporting.  Hard gates, asserted here:

      * every frontier has >= 5 points and every point is `verified`;
      * the chosen design beats the all-float design on BOTH modeled
        power and area while meeting its error budget.

    Also reported: ratios vs the plan's default mapping (sound alphas +
    §V-B uniform beta) — what the closed loop buys over just reading
    the plan off.  Env knobs: REPRO_DSE_SHAPE (default 32x32),
    REPRO_DSE_IMAGES (calibration images, default 2), REPRO_DSE_ITERS
    (anneal steps, default 24), REPRO_DSE_SEED (default 0),
    REPRO_DSE_BACKEND (default "lowered").
    """
    import warnings

    from repro.core import cost_model
    from repro.dse import DSE_STATS, ErrorBudget, run_design_search
    from repro.pipelines import workflows as W

    h, w = (int(x) for x in os.environ.get(
        "REPRO_DSE_SHAPE", "32x32").lower().split("x"))
    n_img = int(os.environ.get("REPRO_DSE_IMAGES", 2))
    iters = int(os.environ.get("REPRO_DSE_ITERS", 24))
    seed = int(os.environ.get("REPRO_DSE_SEED", 0))
    backend = os.environ.get("REPRO_DSE_BACKEND", "lowered")

    # budgets track each pipeline's quality plateau (saturation at the
    # profile-seeded alphas caps PSNR well before the beta floor does):
    # USM is exact-friendly, HCD's downstream consumer is a thresholded
    # corner mask (tolerates saturation on `harris`), DUS-ext plateaus
    # just under 48 dB at its profile alphas
    cases = (("usm", W.make_usm, 50.0), ("hcd", W.make_hcd, 40.0),
             ("dus_ext", W.make_dus_ext, 45.0))
    rows = []
    blob = {"shape": [h, w], "images": n_img, "anneal_iters": iters,
            "seed": seed, "backend": backend, "benchmarks": {}}
    for name, make, min_psnr in cases:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            setup = make(n_train=n_img, n_test=n_img, shape=(h, w))
            plan = setup.plan()
            t0 = time.perf_counter()
            res = run_design_search(
                setup.pipeline, plan, setup.train_images,
                ErrorBudget(min_psnr=min_psnr), params=setup.params,
                seed=seed, anneal_iters=iters, backend=backend,
                verify=True)
        dt = time.perf_counter() - t0
        pts = res.frontier.points()
        ch = res.chosen

        # the two hard gates of this benchmark: a real frontier, every
        # point re-scored bit-exactly through the lowered backend
        assert len(pts) >= 5, \
            f"{name}: frontier has {len(pts)} points (< 5)"
        unverified = [p.strategy for p in pts if not p.verified]
        assert not unverified, \
            f"{name}: unverified frontier points from {unverified}"
        assert ch is not None and ch.meets_budget

        flt = cost_model.design_cost(
            setup.pipeline, cost_model.float_design(setup.pipeline))
        flt_area = flt.lut_bits + flt.dsp_bits
        assert ch.power < flt.power_proxy and ch.area < flt_area, \
            (f"{name}: chosen design does not beat float on both axes "
             f"(power {ch.power} vs {flt.power_proxy}, "
             f"area {ch.area} vs {flt_area})")

        # the plan's default mapping: sound alphas + §V-B uniform beta
        plan_types = W.types_from_alpha(
            setup.pipeline, plan.alphas(None), plan.signed(None),
            {n: res.beta_result.uniform_beta
             for n in setup.pipeline.stages})
        pl = cost_model.design_cost(setup.pipeline, plan_types)
        entry = {
            "budget_min_psnr": min_psnr, "seconds": dt,
            "evaluations": res.evaluations,
            "clusters": [list(c) for c in res.clusters],
            "frontier": res.frontier.to_json_dict(),
            "chosen": ch.to_json_dict(),
            "float": {"power": flt.power_proxy, "area": flt_area},
            "plan_default": {"power": pl.power_proxy,
                             "area": pl.lut_bits + pl.dsp_bits},
            "ratio_vs_float": {"power": flt.power_proxy / ch.power,
                               "area": flt_area / ch.area},
            "ratio_vs_plan": {"power": pl.power_proxy / ch.power,
                              "area": (pl.lut_bits + pl.dsp_bits)
                                      / ch.area},
        }
        blob["benchmarks"][name] = entry
        rows.append((name, len(pts), res.evaluations,
                     round(ch.psnr, 2),
                     round(entry["ratio_vs_float"]["power"], 2),
                     round(entry["ratio_vs_float"]["area"], 2),
                     round(entry["ratio_vs_plan"]["power"], 2),
                     round(dt, 1)))
    blob["stats"] = dict(DSE_STATS)

    here = os.path.dirname(os.path.abspath(__file__))
    out_path = os.path.join(os.path.dirname(here),
                            "BENCH_design_search.json")
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=1, sort_keys=True)
    best = max(blob["benchmarks"].items(),
               key=lambda kv: kv[1]["ratio_vs_float"]["power"])
    sizes = "/".join(str(len(e["frontier"]["points"]))
                     for e in blob["benchmarks"].values())
    return rows, (f"frontiers {sizes} pts (all verified bit-exact); "
                  f"every chosen design beats float on power AND area "
                  f"(best {best[1]['ratio_vs_float']['power']:.1f}x power "
                  f"on {best[0]})")


BENCHES = {}


def _register():
    from benchmarks import paper_tables as T
    BENCHES.update({
        "table2_hcd_ranges": T.table2_hcd_ranges,
        "table3_hcd_power": T.table3_hcd_power,
        "table4_hcd_bitwidths": T.table4_hcd_bitwidths,
        "table5_usm_bitwidths": T.table5_usm_bitwidths,
        "table6_usm_power": T.table6_usm_power,
        "table7_dus_power": T.table7_dus_power,
        "table8_dus_bitwidths": T.table8_dus_bitwidths,
        "table9_of_bitwidths": T.table9_of_bitwidths,
        "table10_of_power": T.table10_of_power,
        "table11_smt_alphas": T.table11_smt_alphas,
        "fig5_cdf": T.fig5_cdf,
        "fig6_beta_sweep": T.fig6_beta_sweep,
        "kernels": _kernel_bench,
        "lm_quant": _lm_quant_bench,
        "lm_beta_sweep": _lm_beta_sweep,
        "smt_throughput": _smt_throughput,
        "pipeline_throughput": _pipeline_throughput,
        "serving_throughput": _serving_throughput,
        "design_search": _design_search,
        "table12_design_frontier": T.table12_design_frontier,
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names")
    ap.add_argument("--trace", default="", metavar="DIR",
                    help="run each benchmark under a repro.obs tracer "
                         "(runtime range telemetry on) and write "
                         "DIR/<name>.trace.json + DIR/<name>.jsonl")
    args = ap.parse_args()
    _register()
    names = [n for n in args.only.split(",") if n] or list(BENCHES)

    here = os.path.dirname(os.path.abspath(__file__))
    outdir = os.path.join(here, "results")
    os.makedirs(outdir, exist_ok=True)
    if args.trace:
        from repro import obs
        os.makedirs(args.trace, exist_ok=True)

    print("name,us_per_call,derived")
    for name in names:
        fn = BENCHES[name]
        t0 = time.perf_counter()
        if args.trace:
            with obs.tracing(runtime_ranges=True) as tr:
                rows, derived = fn()
            obs.write_jsonl(tr, os.path.join(args.trace, f"{name}.jsonl"))
            obs.write_chrome_trace(
                tr, os.path.join(args.trace, f"{name}.trace.json"),
                process_name=f"repro:{name}")
        else:
            rows, derived = fn()
        us = (time.perf_counter() - t0) * 1e6
        print(f"{name},{us:.0f},\"{derived}\"", flush=True)
        with open(os.path.join(outdir, f"{name}.json"), "w") as f:
            json.dump({"rows": [list(map(str, r)) for r in rows],
                       "derived": derived, "us_per_call": us}, f, indent=1)


if __name__ == "__main__":
    main()
