"""Benchmarks reproducing the paper's tables/figures (one function each).

Each function returns (rows, derived) where `derived` is a short
human-readable summary asserted against the paper's claims where the claim
is hardware-independent (static analysis), and reported as modeled where
the paper measured watts on a Zynq.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Tuple

import numpy as np

from repro.core import cost_model, policy
from repro.core.fixedpoint import FixedPointType
from repro.core.range_analysis import analyze
from repro.pipelines import hcd, optical_flow, usm, dus
from repro.pipelines import workflows as W

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")

PAPER_TABLE2 = {"img": 8, "Ix": 8, "Iy": 8, "Ixx": 13, "Ixy": 14, "Iyy": 13,
                "Sxx": 16, "Sxy": 17, "Syy": 16, "det": 33, "trace": 17,
                "harris": 34}


def table2_hcd_ranges() -> Tuple[List, str]:
    """Paper Table II: HCD static ranges + integral bit-widths."""
    res = analyze(hcd.build())
    rows = [(k, f"[{v.range.lo:g},{v.range.hi:g}]", v.alpha)
            for k, v in res.items()]
    match = all(res[k].alpha == a for k, a in PAPER_TABLE2.items())
    return rows, f"alpha==paper for {len(PAPER_TABLE2)} stages: {match}"


def _bitwidth_table(setup: "W.BenchmarkSetup", beta_hi: int = 10) -> Dict:
    alphas, signed = W.static_alphas(setup.pipeline)
    prof = setup.profile()
    res = setup.run_beta_search(prof.alpha_max, signed, beta_hi=beta_hi)
    return {
        "alpha_sa": alphas,
        "alpha_max": prof.alpha_max,
        "alpha_avg": prof.alpha_avg,
        "beta": res.betas,
        "signed": signed,
        "quality": res.quality,
        "passes": res.profile_passes,
    }


def table4_hcd_bitwidths() -> Tuple[List, str]:
    """Paper Table IV: alpha^sa vs alpha^max vs alpha^avg vs beta (HCD)."""
    b = W.make_hcd(n_train=4, n_test=4, shape=(40, 40))
    t = _bitwidth_table(b)
    rows = [(s, t["alpha_sa"][s], t["alpha_max"][s], t["alpha_avg"][s],
             t["beta"][s]) for s in b.pipeline.topo_order()]
    deep_gap = t["alpha_sa"]["det"] - t["alpha_max"]["det"]
    return rows, (f"profile<=static everywhere; det gap={deep_gap} bits "
                  f"(paper: 3); quality={t['quality']:.2f}% "
                  f"passes={t['passes']}")


def table5_usm_bitwidths() -> Tuple[List, str]:
    b = W.make_usm(n_train=4, n_test=4, shape=(40, 40))
    t = _bitwidth_table(b)
    rows = [(s, t["alpha_sa"][s], t["alpha_max"][s], t["alpha_avg"][s],
             t["beta"][s]) for s in b.pipeline.topo_order()]
    return rows, (f"static alphas {[t['alpha_sa'][s] for s in b.pipeline.topo_order()]}"
                  f" == paper [8,8,8,10,9]; quality={t['quality']:.3f}%")


def table8_dus_bitwidths() -> Tuple[List, str]:
    b = W.make_dus(n_train=4, n_test=4, shape=(40, 40))
    t = _bitwidth_table(b)
    rows = [(s, t["alpha_sa"][s], t["alpha_max"][s], t["alpha_avg"][s],
             t["beta"][s]) for s in b.pipeline.topo_order()]
    all8 = all(v == 8 for v in t["alpha_sa"].values())
    return rows, f"all static alpha == 8 (paper Table VIII): {all8}"


def table9_of_bitwidths() -> Tuple[List, str]:
    b = W.make_of(n_pairs=3, shape=(32, 32))
    t = _bitwidth_table(b, beta_hi=12)
    fams = optical_flow.stage_families()
    rows = [(f, [t["alpha_sa"][s] for s in ss],
             [t["alpha_max"][s] for s in ss],
             [t["beta"][s] for s in ss]) for f, ss in fams.items()]
    v_sa = [t["alpha_sa"][f"Vx{k}"] for k in range(1, 5)]
    v_prof = [t["alpha_max"][f"Vx{k}"] for k in range(1, 5)]
    return rows, (f"V-stage static alpha grows {v_sa} while profile stays "
                  f"{v_prof} (paper: (13,18,25,33) vs (8,8,9,9)); "
                  f"AAE={-t['quality']:.3f} deg")


def _power_area_table(make, name: str, paper_power: float,
                      paper_area: float) -> Tuple[List, str]:
    """Tables III/VI/VII/X: float vs alpha^sa vs alpha^avg designs."""
    b = make()
    alphas_sa, signed = W.static_alphas(b.pipeline)
    prof = b.profile()
    res = b.run_beta_search(prof.alpha_avg, signed, beta_hi=10)
    rows = []
    ratios = {}
    for label, alph in (("float", None), ("alpha_sa", alphas_sa),
                        ("alpha_avg", prof.alpha_avg)):
        if alph is None:
            types = cost_model.float_design(b.pipeline)
            quality = b.mean_quality({n: None for n in b.pipeline.stages}) \
                if False else float("nan")
        else:
            types = W.types_from_alpha(b.pipeline, alph, signed, res.betas)
            quality = b.mean_quality(types)
        rep = W.design_report(b.pipeline, types)
        fixed = rep["fixed"] if alph is not None else rep["float"]
        rows.append((label, f"{quality:.3f}", f"{fixed.power_proxy:.0f}",
                     f"{fixed.lut_bits:.0f}", f"{fixed.dsp_bits:.0f}",
                     f"{fixed.bram_bits / 1e3:.0f}k"))
        if alph is not None:
            ratios[label] = rep["improvement"]
    imp = ratios["alpha_avg"]
    return rows, (f"{name}: modeled power x{imp['power']:.1f} "
                  f"area(LUT) x{imp['area_lut']:.1f} DSP x{imp['area_dsp']:.1f}"
                  f" vs float (paper measured x{paper_power} power, "
                  f"x{paper_area} slices)")


def table3_hcd_power() -> Tuple[List, str]:
    return _power_area_table(lambda: W.make_hcd(4, 4, (40, 40)), "HCD",
                             3.8, 6.2)


def table6_usm_power() -> Tuple[List, str]:
    return _power_area_table(lambda: W.make_usm(4, 4, (40, 40)), "USM",
                             1.6, 2.6)


def table7_dus_power() -> Tuple[List, str]:
    return _power_area_table(lambda: W.make_dus(4, 4, (40, 40)), "DUS",
                             1.7, 4.0)


def table10_of_power() -> Tuple[List, str]:
    return _power_area_table(lambda: W.make_of(3, (32, 32)), "OF", 1.6, 2.5)


def table11_smt_alphas() -> Tuple[List, str]:
    """Paper §V-B/§VI: interval vs SMT vs profile alpha per stage.

    The SMT column is the whole-DAG branch-and-prune analysis (`repro.smt`)
    emulating the paper's solver-based bounds; sound analyses must nest as
    profile <= smt <= interval per stage.  The derived line reports how much
    of the interval->profile gap the solver closes (paper: its Optical Flow
    bounds nearly match the profile-driven ones) and the batched solver's
    throughput (boxes/sec) over the whole run.

    Each benchmark runs as one `BitwidthPlan` through the pass driver
    (`BenchmarkSetup.plan`): columns interval/smt/profile plus per-phase
    sub-columns on phase-split stages.  The plans themselves are written to
    `results/table11_plans.json` — the artifact `benchmarks/alpha_delta.py`
    gates on (the legacy `rows` table stays for human eyes and older
    baselines)."""
    from repro.analysis import PlanNestingError
    from repro.smt import SMTConfig
    from repro.smt import solver as S

    makers = {
        "usm": (lambda: W.make_usm(3, 3, (32, 32)), SMTConfig()),
        "dus": (lambda: W.make_dus(3, 3, (32, 32)), SMTConfig()),
        "hcd": (lambda: W.make_hcd(3, 3, (32, 32)), SMTConfig()),
        # OF needs the long budget: ~30 stages x two dichotomic passes; the
        # batched engine's phase-2 deep escalations are what the extra
        # time buys (phase 1 alone reproduces the PR-1 bounds)
        "optical_flow": (lambda: W.make_of(2, (24, 24)),
                         SMTConfig(time_budget_s=240.0)),
        # phase-split groups (PR 3): the paper's convex DUS chain is
        # already exact at [0,255], so the recovered bits live in the
        # extended pyramid's detail stages (DoG band, reconstruction
        # residual) and the coarse-to-fine optical-flow stages
        "dus_ext": (lambda: W.make_dus_ext(3, 3, (32, 32)), SMTConfig()),
        "of_pyramid": (lambda: W.make_of_pyramid(2, (24, 24)),
                       SMTConfig(time_budget_s=120.0)),
    }
    S.STATS.update(boxes=0, secs=0.0)
    rows: List = []
    plans: Dict[str, Dict] = {}
    closed_bits = 0
    gap_bits = 0
    nested = True
    n_phase_cols = 0
    for name, (make, cfg) in makers.items():
        b = make()
        plan = b.plan(smt_config=cfg, phases=True)
        plans[name] = plan.to_json_dict()
        ia = plan.columns["interval"]
        sm = plan.columns["smt"]
        pr = plan.columns["profile"]
        try:
            plan.check_nesting(["profile", "smt", "interval"])
        except PlanNestingError:
            nested = False
        n_phase_cols += len(plan.phases.get("smt", {}))
        for s in b.pipeline.topo_order():
            rows.append((name, s, ia[s].alpha, sm[s].alpha, pr[s].alpha))
            closed_bits += ia[s].alpha - sm[s].alpha
            gap_bits += ia[s].alpha - pr[s].alpha
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "table11_plans.json"), "w") as f:
        json.dump({"version": 1, "groups": plans}, f, sort_keys=True,
                  indent=1)
    pct = 100.0 * closed_bits / max(gap_bits, 1)
    boxes_per_s = S.STATS["boxes"] / max(S.STATS["secs"], 1e-9)
    return rows, (f"profile<=smt<=interval nesting holds: {nested}; SMT "
                  f"recovers {closed_bits}/{gap_bits} interval-vs-profile "
                  f"alpha bits ({pct:.0f}%) across USM/DUS/HCD/OF + "
                  f"phase-split DUS-ext/OF-pyramid; {n_phase_cols} per-phase "
                  f"stage columns in table11_plans.json; solver throughput "
                  f"{S.STATS['boxes']} boxes in "
                  f"{S.STATS['secs']:.1f}s ({boxes_per_s:.0f} boxes/s)")


def table12_design_frontier() -> Tuple[List, str]:
    """Beyond-paper table: the USM bitwidth-DSE Pareto frontier.

    The paper reports one hand-tuned fixed design per pipeline (Tables
    III/VI/VII); the closed-loop search (`repro.dse`,
    docs/design_search.md) returns the whole measured error/power/area
    trade-off curve.  One row per frontier point, walked cheapest-power
    first; every PSNR is from executing the specialized design through
    the lowered backend against the f64 oracle and re-verified
    bit-exactly (`verified` is asserted, not assumed).
    """
    import warnings

    from repro.dse import ErrorBudget, run_design_search

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        b = W.make_usm(n_train=2, n_test=2, shape=(32, 32))
        res = run_design_search(b.pipeline, b.plan(), b.train_images,
                                ErrorBudget(min_psnr=50.0),
                                params=b.params, seed=0, anneal_iters=16,
                                backend="lowered", verify=True)
    pts = res.frontier.points()
    assert pts and all(p.verified for p in pts)
    rows = [(p.strategy, f"{p.psnr:.2f}", f"{p.power:.0f}",
             f"{p.lut_bits:.0f}", f"{p.dsp_bits:.0f}", p.total_bits)
            for p in pts]
    ch = res.chosen
    flt = cost_model.design_cost(b.pipeline,
                                 cost_model.float_design(b.pipeline))
    return rows, (f"USM frontier: {len(pts)} verified points; chosen "
                  f"x{flt.power_proxy / ch.power:.1f} power "
                  f"x{(flt.lut_bits + flt.dsp_bits) / ch.area:.1f} area "
                  f"vs float at {ch.psnr:.1f} dB (paper Table VI: x1.6 "
                  f"power, x2.6 slices for its one hand-mapped design)")


def fig5_cdf() -> Tuple[List, str]:
    """Fig 5: per-pixel integral-bit CDFs for HCD stages."""
    b = W.make_hcd(4, 4, (40, 40))
    prof = b.profile()
    rows = []
    for stage in ("Ix", "Ixy", "Sxy", "det", "trace", "harris"):
        bits, cum = prof.cdf[stage]
        p95 = int(bits[np.searchsorted(cum, 95.0)]) if len(bits) else 0
        rows.append((stage, p95, int(bits[-1]) if len(bits) else 0))
    return rows, "per-stage (bits at 95% pixels, max bits) CDF summary"


def fig6_beta_sweep() -> Tuple[List, str]:
    """Fig 6: HCD accuracy + power proxy vs uniform beta."""
    b = W.make_hcd(3, 3, (32, 32))
    alphas, signed = W.static_alphas(b.pipeline)
    rows = []
    for beta in range(0, 9, 2):
        types = W.types_from_alpha(b.pipeline, alphas, signed,
                                   {n: beta for n in b.pipeline.stages})
        q = b.mean_quality(types)
        c = cost_model.design_cost(b.pipeline, types)
        rows.append((beta, f"{q:.3f}", f"{c.power_proxy:.0f}"))
    q0 = float(rows[0][1])
    return rows, (f"accuracy at beta=0: {q0:.2f}% "
                  f"(paper: >99% with zero fractional bits)")
