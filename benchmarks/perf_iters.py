"""Perf hillclimbing on the three selected cells (EXPERIMENTS.md §Perf).

Cells (chosen from the baseline roofline table):
  A. rwkv6-3b    x train_4k   — worst roofline fraction (6.8%), collective-bound
  B. mixtral-8x7b x train_4k  — largest absolute collective term (33s modeled)
  C. deepseek-7b x decode_32k — memory-bound; most representative of the
                                paper's technique (narrow the bytes)

Each experiment records hypothesis -> change -> before/after roofline terms.
Run:  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
      PYTHONPATH=src python -m benchmarks.perf_iters
"""
from __future__ import annotations

import json
import os


def run():
    from benchmarks.roofline import measure_cell

    here = os.path.dirname(os.path.abspath(__file__))
    out_path = os.path.join(here, "results", "perf_iters.json")
    results = []

    def experiment(cell_name, arch, shape, variant, hypothesis, **kw):
        rec = measure_cell(arch, shape, **kw)
        rec.update(cell=cell_name, variant=variant, hypothesis=hypothesis)
        results.append(rec)
        print(f"[{cell_name}/{variant}] comp={rec['t_compute_s']*1e3:.1f}ms "
              f"mem={rec['t_memory_s']*1e3:.1f}ms "
              f"coll={rec['t_collective_s']*1e3:.1f}ms "
              f"dom={rec['dominant']} temp={rec['memory_temp_gb']:.1f}GB",
              flush=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        return rec

    # ---------------- Cell A: rwkv6-3b train_4k --------------------------
    experiment("A", "rwkv6-3b", "train_4k", "baseline",
               "paper-faithful baseline (FSDP f32 gathers + SP)")
    experiment("A", "rwkv6-3b", "train_4k", "bf16_gather",
               "A1: cast params bf16 before use -> FSDP all-gather bytes "
               "halve -> collective term ~ -40%",
               overrides={"train_cast_bf16": True})
    experiment("A", "rwkv6-3b", "train_4k", "bf16_gather+batch_shard",
               "A2: recurrences hate seq sharding; shard batch over BOTH "
               "mesh axes (256-way DP), no SP -> seq collectives vanish; "
               "activations 0.7GB/dev still fit",
               overrides={"train_cast_bf16": True,
                          "act_pspec": (("data", "model"),)})
    experiment("A", "rwkv6-3b", "train_4k", "bf16_gather+batch_shard+accum2",
               "A3: 2 microbatches shrink peak activations further at the "
               "price of re-gathering weights twice",
               overrides={"train_cast_bf16": True,
                          "act_pspec": (("data", "model"),)},
               accum=2)

    # ---------------- Cell B: mixtral-8x7b train_4k ----------------------
    experiment("B", "mixtral-8x7b", "train_4k", "baseline",
               "baseline (accum=4, f32 gathers)")
    experiment("B", "mixtral-8x7b", "train_4k", "bf16_gather",
               "B1: bf16 FSDP gathers halve the dominant weight-gather "
               "bytes (47B params x 4 microbatches)",
               overrides={"train_cast_bf16": True})
    experiment("B", "mixtral-8x7b", "train_4k", "bf16_gather+accum2",
               "B2: accum 4->2 halves weight re-gathers again; expert "
               "buffers double but fit after the bf16/remat fixes",
               overrides={"train_cast_bf16": True}, accum=2)
    experiment("B", "mixtral-8x7b", "train_4k", "bf16_gather+accum1",
               "B3: no microbatching: weight gathers once per step; "
               "checks whether activation memory still fits",
               overrides={"train_cast_bf16": True}, accum=1)

    # ---------------- Cell C: deepseek-7b decode_32k ---------------------
    experiment("C", "deepseek-7b", "decode_32k", "baseline",
               "baseline (bf16 KV cache)")
    experiment("C", "deepseek-7b", "decode_32k", "int8_kv",
               "C1: the paper's technique on the decode working set — int8 "
               "KV codes + per-vector scales -> cache bytes ~0.53x -> "
               "memory term ~ -45%",
               overrides={"kv_cache_dtype": "int8"})

    print("wrote", out_path)


if __name__ == "__main__":
    import os as _os
    _os.environ.setdefault("XLA_FLAGS",
                           "--xla_force_host_platform_device_count=512")
    run()


def run_round2():
    """Second hillclimb round: A4 (bf16 chunk staging) + B4 (int8 gathers)."""
    from benchmarks.roofline import measure_cell

    here = os.path.dirname(os.path.abspath(__file__))
    out_path = os.path.join(here, "results", "perf_iters.json")
    with open(out_path) as f:
        results = json.load(f)

    def experiment(cell_name, arch, shape, variant, hypothesis, **kw):
        rec = measure_cell(arch, shape, **kw)
        rec.update(cell=cell_name, variant=variant, hypothesis=hypothesis)
        results[:] = [r for r in results if not (
            r["cell"] == cell_name and r["variant"] == variant)]
        results.append(rec)
        print(f"[{cell_name}/{variant}] comp={rec['t_compute_s']*1e3:.1f}ms "
              f"mem={rec['t_memory_s']*1e3:.1f}ms "
              f"coll={rec['t_collective_s']*1e3:.1f}ms "
              f"dom={rec['dominant']} temp={rec['memory_temp_gb']:.1f}GB",
              flush=True)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)
        return rec

    experiment("A", "rwkv6-3b", "train_4k", "batch_shard+bf16_staging",
               "A4: on top of A2 — stage r/k/v (and SSD u/B/C) in bf16, "
               "cast f32 per chunk in VMEM -> chunk-scan HBM reads halve "
               "-> memory term (now dominant) ~ -25%",
               overrides={"act_pspec": (("data", "model"),)})
    experiment("B", "mixtral-8x7b", "train_4k", "int8_gathers+accum2",
               "B4: paper technique on the collective wire — QAT int8 "
               "codes+scales gathered instead of f32 weights -> expert "
               "weight-gather bytes ~ -75% -> collective term ~ -50%",
               overrides={"train_weight_cast": "int8"}, accum=2)
    experiment("C", "deepseek-7b", "decode_32k", "int8_kv+bf16_params",
               "C2: int8 cache + confirm the bf16 param store (already "
               "default for serving) — memory term vs C1 unchanged "
               "(cache-dominated), records the combined final state",
               overrides={"kv_cache_dtype": "int8"})
    print("wrote", out_path)
