"""Per-benchmark alpha delta of the committed golden plans vs the baseline.

    PYTHONPATH=src python -m benchmarks.alpha_delta [--markdown]

The golden artifact is `benchmarks/results/table11_plans.json` — one
serialized `BitwidthPlan` per benchmark group, written by the plan driver
in `paper_tables.table11_smt_alphas` (alphas are read from each plan's
interval/smt/profile columns).  It is compared against
`table11_smt_alphas.baseline.json` (the previous PR's snapshot, legacy
rows format) and prints one summary line per benchmark group plus every
per-stage alpha move.  CI appends the markdown form to the job summary so
encoder/solver/pass changes show their recovered (or regressed!) bits at a
glance.

Exit status is non-zero when any smt alpha regressed (grew) on a stage both
artifacts know — the delta report doubles as a cheap golden-regression
gate.  Both loaders accept either format, so baselines can stay frozen
across the plan migration.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict

HERE = os.path.dirname(os.path.abspath(__file__))
GOLDEN_PLANS = os.path.join(HERE, "results", "table11_plans.json")
GOLDEN_ROWS = os.path.join(HERE, "results", "table11_smt_alphas.json")
BASELINE = os.path.join(HERE, "results", "table11_smt_alphas.baseline.json")


def _load(path):
    """(group, stage) -> (interval, smt, profile) alphas, either format."""
    with open(path) as f:
        data = json.load(f)
    if "groups" in data:           # plan JSON (BitwidthPlan per group)
        out = {}
        for g, plan in data["groups"].items():
            cols = plan["columns"]
            for stage in cols["interval"]:
                out[(g, stage)] = (int(cols["interval"][stage]["alpha"]),
                                   int(cols["smt"][stage]["alpha"]),
                                   int(cols["profile"][stage]["alpha"]))
        return out
    return {(r[0], r[1]): (int(r[2]), int(r[3]), int(r[4]))
            for r in data["rows"]}


# provenance note SmtPass writes when analyze_smt's time budget ran out on a
# stage and the interval seed was kept (see repro.smt.optimize)
_STARVED_NOTE = "budget-exhausted (seed kept): "


def _starved(path):
    """group -> [stage, ...] whose smt alphas are interval seeds because the
    SMT time budget was exhausted (from plan provenance notes; empty for the
    legacy rows format, which carries no provenance)."""
    with open(path) as f:
        data = json.load(f)
    out = {}
    for g, plan in data.get("groups", {}).items():
        for prov in plan.get("provenance", {}).values():
            for note in prov.get("notes", []):
                if note.startswith(_STARVED_NOTE):
                    stages = [s.strip()
                              for s in note[len(_STARVED_NOTE):].split(",")]
                    out.setdefault(g, []).extend(s for s in stages if s)
    return out


def _golden_path():
    return GOLDEN_PLANS if os.path.exists(GOLDEN_PLANS) else GOLDEN_ROWS


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true",
                    help="emit a GitHub-flavored markdown table")
    args = ap.parse_args()
    golden = _load(_golden_path())
    base = _load(BASELINE)
    starved = _starved(_golden_path())

    groups = defaultdict(lambda: {"delta": 0, "moves": [], "new": 0})
    regressed = []
    for key, (ia, sa, pa) in sorted(golden.items()):
        g, stage = key
        if key not in base:
            groups[g]["new"] += 1
            continue
        d = sa - base[key][1]          # negative = bits recovered
        if d:
            groups[g]["delta"] += d
            groups[g]["moves"].append(f"{stage} {base[key][1]}->{sa}")
        if d > 0:
            regressed.append((key, base[key][1], sa))
    # rows the baseline knew that vanished from the golden table are silent
    # coverage loss — gate them like regressions (regenerate the baseline
    # deliberately when a benchmark group is really renamed/retired)
    dropped = sorted(set(base) - set(golden))

    if args.markdown:
        print("### table11 smt alpha delta vs baseline\n")
        print("| benchmark | alpha bits moved | stages | new stages "
              "| budget-starved |")
        print("|---|---|---|---|---|")
        for g in sorted(set(k[0] for k in golden)):
            info = groups[g]
            moves = ", ".join(info["moves"]) or "—"
            kept = ", ".join(sorted(set(starved.get(g, [])))) or "—"
            print(f"| {g} | {info['delta']:+d} | {moves} | {info['new']} "
                  f"| {kept} |")
    else:
        for g in sorted(set(k[0] for k in golden)):
            info = groups[g]
            moves = ", ".join(info["moves"]) or "none"
            line = (f"{g}: delta {info['delta']:+d} bits "
                    f"({moves}; {info['new']} new stages)")
            kept = sorted(set(starved.get(g, [])))
            if kept:
                # these smt alphas are interval seeds, not converged values —
                # re-run with a bigger time_budget_s before reading deltas
                line += ("  [budget-exhausted, seed kept: "
                         + ", ".join(kept) + "]")
            print(line)

    if regressed:
        print(f"\nALPHA REGRESSION on {len(regressed)} stage(s): "
              f"{regressed}", file=sys.stderr)
    if dropped:
        print(f"\nBASELINE ROWS MISSING from golden table: {dropped}",
              file=sys.stderr)
    return 1 if (regressed or dropped) else 0


if __name__ == "__main__":
    sys.exit(main())
