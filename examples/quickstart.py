"""Quickstart: the paper's full bit-width synthesis loop on Unsharp Mask.

    PYTHONPATH=src python examples/quickstart.py

Walks Figure 4 of the paper end to end: build the DSL pipeline -> static
interval alpha-analysis -> profile refinement -> beta search against the
quality metric -> fixed-point design + power/area report -> run the
resulting design on an image.
"""
import numpy as np

from repro.core import cost_model
from repro.core.range_analysis import analyze
from repro.dsl.exec import run_fixed, run_float
from repro.pipelines import usm, workflows as W


def main():
    print("== 1. build the USM pipeline (paper Listing 1) ==")
    pipe = usm.build()
    print(f"   stages: {pipe.topo_order()}")

    print("\n== 2. static alpha-analysis (Algorithm 1) ==")
    res = analyze(pipe)
    for stage in pipe.topo_order():
        r = res[stage]
        print(f"   {stage:8s} range={str(r.range):16s} alpha={r.alpha}")

    print("\n== 3. profile-driven refinement + beta search (paper SS V) ==")
    bench = W.make_usm(n_train=4, n_test=4, shape=(48, 48))
    prof = bench.profile()
    alphas, signed = W.static_alphas(pipe)
    search = bench.run_beta_search(prof.alpha_max, signed, beta_hi=10)
    print(f"   betas: {search.betas}")
    print(f"   quality: {search.quality:.3f}% correct classification "
          f"({search.profile_passes} profile passes)")

    print("\n== 4. fixed-point design vs float: modeled power/area ==")
    types = W.types_from_alpha(pipe, prof.alpha_max, signed, search.betas)
    rep = W.design_report(pipe, types)
    imp = rep["improvement"]
    print(f"   power x{imp['power']:.1f}  LUT x{imp['area_lut']:.1f}  "
          f"DSP x{imp['area_dsp']:.1f}  TPU-bytes x{imp['tpu_bytes']:.1f}")
    print(f"   containers: {rep['containers']}")

    print("\n== 5. run both designs on an image ==")
    from repro.pipelines.data import natural_image
    img = natural_image((48, 48), seed=3)
    ref = run_float(pipe, img, usm.DEFAULT_PARAMS)
    fix = run_fixed(pipe, img, types, usm.DEFAULT_PARAMS)
    err = np.abs(np.asarray(ref["masked"]) - np.asarray(fix["masked"]))
    print(f"   max abs pixel error: {err.max():.3f} (of 255)")
    print("\ndone — see DESIGN.md for how this maps onto TPU containers.")


if __name__ == "__main__":
    main()
