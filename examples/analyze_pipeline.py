"""Author a NEW pipeline in the DSL and synthesize its bit-widths.

    PYTHONPATH=src python examples/analyze_pipeline.py

Shows the pluggable-analysis framework (paper SS IV-C): the same pipeline is
analyzed with interval arithmetic, affine arithmetic, and per-pixel abstract
execution, then profiled and synthesized — the workflow a user follows for
their own image-processing pipeline.
"""
import numpy as np

from repro.core.graph import Pow
from repro.core.range_analysis import analyze
from repro.dsl.builder import PipelineBuilder, absv, ite
from repro.dsl.exec import run_abstract, run_float
from repro.pipelines import workflows as W
from repro.pipelines.data import natural_image
from repro.pipelines.metrics import psnr


def build_edge_enhance():
    """A custom pipeline: Laplacian edge boost with a noise gate.

    `resid` re-reads `img` alongside `boost` — the per-stage interval walk
    treats the two as independent signals, while the whole-DAG SMT analysis
    (`domain="smt"`) sees that `boost - img == 0.5*lap` exactly."""
    p = PipelineBuilder("edge_enhance")
    img = p.image("img", 0, 255)
    lap = p.stencil("lap", img, [[0, -1, 0], [-1, 4, -1], [0, -1, 0]])
    mag = p.define("mag", absv(lap))
    boost = p.define("boost", img + 0.5 * lap)
    out = p.define("out", ite(mag < 8.0, img, boost))
    resid = p.define("resid", boost - img)   # how much boosting happened
    p.output(out)
    p.output(resid)
    return p.build()


def main():
    pipe = build_edge_enhance()
    print(f"pipeline: {pipe.topo_order()}")

    print("\n== pluggable domains (paper SS IV-C) ==")
    results = {}
    for domain in ("interval", "affine", "smt"):
        # "smt" dispatches to the whole-DAG solver analysis (repro.smt):
        # same one-string integration, solver-tightened bounds
        results[domain] = analyze(pipe, domain=domain)
        alphas = {k: v.alpha for k, v in results[domain].items()}
        print(f"   {domain:9s}: {alphas}")
    per_pix = run_abstract(pipe, (12, 12), "interval")
    print(f"   per-pixel : out range {per_pix['out']['range']}")

    print("\n== whole-DAG SMT analysis vs interval (paper SS V-B) ==")
    ia = results["interval"]
    sm = results["smt"]
    for k in pipe.topo_order():
        note = "  <- tightened" if (sm[k].range.lo, sm[k].range.hi) != \
            (ia[k].range.lo, ia[k].range.hi) else ""
        print(f"   {k:6s} interval {ia[k].range!s:>18s}   "
              f"smt {sm[k].range!s:>22s}{note}")

    print("\n== phase-split encoding across sampling boundaries ==")
    # detail stages of a down/up pyramid difference signals across stride-2
    # producers: the alignment-blind encoding must cut them to independent
    # [0,255] signals; phase-split recovers the exactly-aligned expansion
    from repro.pipelines import dus
    from repro.smt import SMTConfig, analyze_smt
    pyr = dus.build_extended()
    blind = analyze_smt(pyr, config=SMTConfig(phase_split=False))
    phase = analyze_smt(pyr, config=SMTConfig())
    for k in ("band", "res"):
        print(f"   {k:5s} blind {blind[k].range!s:>18s} (alpha "
              f"{blind[k].alpha})   phase-split {phase[k].range!s:>18s} "
              f"(alpha {phase[k].alpha})")

    print("\n== profile + synthesize ==")
    from repro.core.profile import profile_pipeline
    imgs = [natural_image((48, 48), seed=i) for i in range(4)]
    prof = profile_pipeline(pipe, imgs,
                            lambda im, par: run_float(pipe, im, par))
    print(f"   alpha^max: {prof.alpha_max}")

    alphas, signed = W.static_alphas(pipe)
    types = W.types_from_alpha(
        pipe, prof.alpha_max, signed,
        {n: 4 for n in pipe.stages})
    rep = W.design_report(pipe, types)
    print(f"   modeled power x{rep['improvement']['power']:.1f}, "
          f"LUT x{rep['improvement']['area_lut']:.1f} vs float")

    from repro.dsl.exec import run_fixed
    img = natural_image((48, 48), seed=99)
    ref = run_float(pipe, img)
    fix = run_fixed(pipe, img, types)
    print(f"   PSNR(fixed vs float): "
          f"{psnr(ref['out'], fix['out']):.1f} dB")


if __name__ == "__main__":
    main()
