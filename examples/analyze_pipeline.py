"""Author a NEW pipeline in the DSL and synthesize its bit-widths.

    PYTHONPATH=src python examples/analyze_pipeline.py

Shows the composable analysis-pass architecture (paper SS IV-C / V): the
same pipeline is analyzed by a declared pass DAG — interval, affine, their
meet, and the whole-DAG SMT pass — into one `BitwidthPlan` with
provenance, then profiled, refined, and executed from the plan.  This is
the workflow a user follows for their own image-processing pipeline; the
old per-call entry points (`analyze`, `static_alphas`) remain as shims
over one-pass plans.
"""
import numpy as np

from repro.analysis import ProfilePass, SmtPass, meet, refine, run_plan
from repro.core.range_analysis import analyze
from repro.dsl.builder import PipelineBuilder, absv, ite
from repro.dsl.exec import run_abstract, run_fixed, run_float
from repro.pipelines import workflows as W
from repro.pipelines.data import natural_image
from repro.pipelines.metrics import psnr


def build_edge_enhance():
    """A custom pipeline: Laplacian edge boost with a noise gate.

    `resid` re-reads `img` alongside `boost` — the per-stage interval walk
    treats the two as independent signals, while the whole-DAG SMT analysis
    (`domain="smt"`) sees that `boost - img == 0.5*lap` exactly."""
    p = PipelineBuilder("edge_enhance")
    img = p.image("img", 0, 255)
    lap = p.stencil("lap", img, [[0, -1, 0], [-1, 4, -1], [0, -1, 0]])
    mag = p.define("mag", absv(lap))
    boost = p.define("boost", img + 0.5 * lap)
    out = p.define("out", ite(mag < 8.0, img, boost))
    resid = p.define("resid", boost - img)   # how much boosting happened
    p.output(out)
    p.output(resid)
    return p.build()


def main():
    pipe = build_edge_enhance()
    print(f"pipeline: {pipe.topo_order()}")

    print("\n== one pass DAG, one plan (paper SS V architecture) ==")
    imgs = [natural_image((48, 48), seed=i) for i in range(4)]
    prof = ProfilePass(imgs)
    plan = run_plan(pipe, ["interval", "affine", meet("interval", "affine"),
                           SmtPass(), prof,
                           refine("interval", prof)])
    for col in plan.columns:
        print(f"   {col:24s}: {plan.alphas(col)}")
    plan.check_nesting(["profile", "smt", "meet(interval,affine)"])
    print("   nesting profile ⊆ smt ⊆ meet(interval,affine): OK")
    print(f"   provenance[smt] = {plan.provenance['smt'].spec[:60]}...")

    print("\n== whole-DAG SMT analysis vs interval (paper SS V-B) ==")
    ia = plan.stage_ranges("interval")
    sm = plan.stage_ranges("smt")
    for k in pipe.topo_order():
        note = "  <- tightened" if (sm[k].range.lo, sm[k].range.hi) != \
            (ia[k].range.lo, ia[k].range.hi) else ""
        print(f"   {k:6s} interval {ia[k].range!s:>18s}   "
              f"smt {sm[k].range!s:>22s}{note}")
    per_pix = run_abstract(pipe, (12, 12), "interval")
    print(f"   per-pixel : out range {per_pix['out']['range']}")

    print("\n== per-phase alpha columns across sampling boundaries ==")
    # detail stages of a down/up pyramid difference signals across stride-2
    # producers; the plan keeps one sub-column per output-phase residue, so
    # the aligned phase's smaller alpha survives the union bound
    from repro.pipelines import dus
    from repro.smt import SMTConfig
    pyr = dus.build_extended()
    pplan = run_plan(pyr, ["interval",
                           SmtPass(config=SMTConfig(), phases=True)],
                     default_column="smt")
    sm = pplan.stage_ranges("smt")
    for k in ("band", "res", "resS"):
        phases = pplan.phases.get("smt", {}).get(k)
        ph = ("  phases: " + ", ".join(
            f"{r}={sr.range!s} (a{sr.alpha})"
            for r, sr in sorted(phases[1].items()))) if phases else ""
        print(f"   {k:5s} union {sm[k].range!s:>18s} (alpha {sm[k].alpha})"
              f"{ph}")

    print("\n== execute the plan (per-phase datapaths where present) ==")
    img = natural_image((48, 48), seed=99)
    ref = run_float(pyr, img)
    fix = run_fixed(pyr, img, pplan)       # plan in, per-phase types applied
    union_bits = sum(t.width for t in pplan.types().values())
    phase_bits = union_bits
    for stage, (lat, tmap) in pplan.phase_types().items():
        u = pplan.types()[stage].width
        phase_bits += sum(t.width for t in tmap.values()) / len(tmap) - u
    print(f"   PSNR(resS fixed vs float): {psnr(ref['resS'], fix['resS']):.1f}"
          f" dB; mean datapath bits {phase_bits:.1f} vs union {union_bits}")

    print("\n== lower the plan (fused executors, docs/execution_backends.md) ==")
    from repro.lowering import compile_pipeline, lower
    lp = lower(pyr, pplan)
    kinds = lp.kinds()
    n_int = sum(1 for k in kinds.values() if k == "intlinear")
    print(f"   {n_int} integer-datapath stages / "
          f"{sum(1 for k in kinds.values() if k == 'expr')} f64-replay "
          f"stages")
    fused = compile_pipeline(pyr, pplan, backend="jnp")
    low = fused(img)
    exact = all(np.array_equal(np.asarray(fix[k]), low[k]) for k in low)
    print(f"   fused jnp outputs bit-identical to the oracle: {exact}")

    print("\n== profile + synthesize (legacy shims still work) ==")
    alphas, signed = W.static_alphas(pipe)
    types = W.types_from_alpha(
        pipe, plan.alphas("profile"), signed,
        {n: 4 for n in pipe.stages})
    rep = W.design_report(pipe, types)
    print(f"   alpha^max: {plan.alphas('profile')}")
    print(f"   modeled power x{rep['improvement']['power']:.1f}, "
          f"LUT x{rep['improvement']['area_lut']:.1f} vs float")
    ref = run_float(pipe, img)
    fix = run_fixed(pipe, img, types)
    print(f"   PSNR(fixed vs float): "
          f"{psnr(ref['out'], fix['out']):.1f} dB")

    print("\n== traced compile + execution (docs/observability.md) ==")
    # the whole compile path emits spans into one repro.obs trace; with
    # runtime_ranges=True the executed stages also report observed range,
    # saturation, and alpha headroom (plan bits this input did not need)
    from repro import obs
    from repro.obs import report
    from repro.pipelines import usm

    upipe = usm.build()
    with obs.tracing(runtime_ranges=True) as tr:
        uplan = run_plan(upipe, ["interval", SmtPass()],
                         default_column="smt")
        run_fixed(upipe, natural_image((48, 48), seed=5), uplan,
                  usm.DEFAULT_PARAMS, backend="lowered")
    total_us = sum(s.t1 - s.t0 for s in tr.spans("analysis.pass")) * 1e6
    print(f"   plan time breakdown ({total_us:.0f} us across "
          f"{len(tr.spans('analysis.pass'))} passes):")
    for s in tr.spans("analysis.pass"):
        print(f"     {s.attrs['pass']:10s} {(s.t1 - s.t0) * 1e6:8.0f} us  "
              f"memo={s.attrs['memo']}")
    summary = report.summarize(obs.to_jsonl_records(tr))
    table = report.render({"passes": [], "smt_stages": [],
                           "runtime": summary["runtime"]})
    print("   " + table.replace("\n", "\n   ").rstrip())
    # export for perfetto (ui.perfetto.dev) / the report CLI:
    #   obs.write_chrome_trace(tr, "usm.trace.json")
    #   obs.write_jsonl(tr, "usm.jsonl")

    print("\n== closed-loop bitwidth DSE (docs/design_search.md) ==")
    # search per-stage (alpha, beta) under a measured error budget: every
    # candidate is specialized, executed through the lowered backend, and
    # scored against the f64 oracle — the result is a Pareto frontier of
    # verified designs, not one point and not an analytical guess
    import warnings

    from repro.core import cost_model
    from repro.dse import ErrorBudget, run_design_search

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        setup = W.make_usm(n_train=2, n_test=2, shape=(32, 32))
        res = run_design_search(setup.pipeline, setup.plan(),
                                setup.train_images,
                                ErrorBudget(min_psnr=50.0),
                                params=setup.params, seed=0,
                                anneal_iters=12, backend="lowered",
                                verify=True)
    print(f"   clusters: {res.clusters}")
    print(f"   {res.evaluations} designs executed -> "
          f"{len(res.frontier)} on the frontier (all verified):")
    print("   strategy         psnr_dB   power     lut     dsp  bits")
    for p in res.frontier.points():
        print(f"   {p.strategy:15s} {p.psnr:8.2f} {p.power:7.0f} "
              f"{p.lut_bits:7.0f} {p.dsp_bits:7.0f} {p.total_bits:5d}")
    flt = cost_model.design_cost(setup.pipeline,
                                 cost_model.float_design(setup.pipeline))
    ch = res.chosen
    print(f"   chosen: {ch.psnr:.1f} dB at "
          f"x{flt.power_proxy / ch.power:.1f} power, "
          f"x{(flt.lut_bits + flt.dsp_bits) / ch.area:.1f} area vs float")


if __name__ == "__main__":
    main()
