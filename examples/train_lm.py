"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps with checkpointing and (optionally) int8 gradient compression.

    PYTHONPATH=src python examples/train_lm.py --steps 300

On the CPU container this uses a reduced-width config (~tens of M params by
default so it finishes in minutes; pass --width 512 --layers 8 for the full
~100M run if you have time); on a TPU pod the same driver runs the full
config via --arch/--production-mesh (see repro.launch.train).
"""
import argparse
import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.data.batches import TokenStream
from repro.launch.mesh import make_debug_mesh
from repro.models.registry import get_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_smoke_config("qwen3-4b"),
        n_layers=args.layers, d_model=args.width,
        n_heads=max(args.width // 32, 2), n_kv_heads=max(args.width // 64, 1),
        head_dim=32, d_ff=args.width * 4, vocab_size=args.vocab)
    bundle = get_model(cfg)
    n_params = cfg.param_count()
    print(f"model: {cfg.name} {args.layers}L d={args.width} "
          f"(~{n_params/1e6:.1f}M params)")

    opt_cfg = AdamWConfig(lr=1e-3, schedule="cosine",
                          warmup_steps=args.steps // 10,
                          total_steps=args.steps)
    step_fn = jax.jit(
        make_train_step(bundle, opt_cfg, compress_grads=args.compress_grads),
        donate_argnums=(0,))
    # a finite corpus (8 fixed batches): uniform-random tokens have a loss
    # floor of ln(vocab); a finite set is memorizable, so the loss visibly
    # falls — the point of an e2e training demo
    stream = TokenStream(cfg, args.batch, args.seq)
    corpus = [stream.batch_at(i) for i in range(8)]
    saver = ckpt.AsyncCheckpointer(args.ckpt_dir)

    with make_debug_mesh():
        state = init_train_state(bundle, jax.random.PRNGKey(0),
                                 compress_grads=args.compress_grads)
        losses = []
        for step in range(args.steps):
            state, metrics = step_fn(state, corpus[step % len(corpus)])
            losses.append(float(metrics["loss"]))
            if step % 25 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                      f"lr {float(metrics['lr']):.2e}")
            if step % 100 == 99:
                saver.save(step, state)
        saver.wait()

    first = sum(losses[:20]) / 20
    last = sum(losses[-20:]) / 20
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'check setup'}); "
          f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
