"""Serve a small model with batched requests + AutoQuant int8 weights.

    PYTHONPATH=src python examples/serve_quantized.py

Runs the paper's bit-width synthesis on an LM (AutoQuant), then serves
batched requests through the continuous batcher with the quantized weights,
comparing generated tokens against the bf16 reference server.
"""
import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data.batches import make_batch
from repro.launch.mesh import make_debug_mesh
from repro.launch.serve import ContinuousBatcher, Request
from repro.models.registry import get_model
from repro.quant.autoquant import autoquant, fake_quant_params


def generate(bundle, params, prompts, max_new=8, slots=2, max_len=64):
    batcher = ContinuousBatcher(bundle, params, slots, max_len)
    reqs = [Request(i, p, max_new) for i, p in enumerate(prompts)]
    pending = list(reqs)
    while pending or batcher.active():
        while pending and batcher.admit(pending[0]):
            pending.pop(0)
        batcher.step()
    return [r.generated for r in reqs]


def main():
    cfg = get_smoke_config("qwen3-4b")
    bundle = get_model(cfg)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=4)) for _ in range(4)]

    with make_debug_mesh():
        params = bundle.init_params(jax.random.PRNGKey(0))

        print("== AutoQuant: paper beta-search on LM weight classes ==")
        batches = [make_batch(cfg, 2, 16, seed=s) for s in range(2)]
        res = autoquant(bundle, params, batches, target_agreement=0.97)
        print(f"   bits per class: {res.bits}")
        print(f"   token agreement: {res.quality:.3f} "
              f"({res.profile_passes} profile passes, "
              f"{res.bytes_ratio:.2f}x bf16 bytes)")

        qparams = fake_quant_params(params, res.bits)

        print("\n== serve 4 requests on both weight stores ==")
        ref = generate(bundle, params, prompts)
        quant = generate(bundle, qparams, prompts)
        agree = np.mean([a == b for ra, rq in zip(ref, quant)
                         for a, b in zip(ra, rq)])
        print(f"   generated-token agreement vs bf16 server: {agree:.2%}")
        for i, (a, b) in enumerate(zip(ref, quant)):
            print(f"   req{i}: bf16={a} int{max(res.bits.values())}={b}")


if __name__ == "__main__":
    main()
