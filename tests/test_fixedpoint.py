"""Fixed-point type + bit-accurate op tests."""
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.core.fixedpoint import (FixedPointType, alpha_for_range, fix_round,
                                   np_quantize, quantize, dequantize)


def test_alpha_formula_paper_values():
    # Table II anchors
    assert alpha_for_range(0, 255) == 8
    assert alpha_for_range(-85, 85) == 8
    assert alpha_for_range(0, 85 ** 2) == 13
    assert alpha_for_range(-85 ** 2, 85 ** 2) == 14
    assert alpha_for_range(0, 9 * 85 ** 2) == 16
    assert alpha_for_range(-9 * 85 ** 2, 9 * 85 ** 2) == 17
    assert alpha_for_range(-(9 * 85 ** 2) ** 2, (9 * 85 ** 2) ** 2) == 33
    assert alpha_for_range(0, 2 * 9 * 85 ** 2) == 17
    assert alpha_for_range(-1.16 * (9 * 85 ** 2) ** 2, (9 * 85 ** 2) ** 2) == 34


def test_type_ranges():
    t = FixedPointType(8, 0, signed=False)
    assert t.min_value == 0 and t.max_value == 255
    t = FixedPointType(8, 4, signed=True)
    assert t.min_value == -128 and abs(t.max_value - (128 - 2 ** -4)) < 1e-12


@given(st.integers(1, 12), st.integers(0, 10),
       st.floats(-1e4, 1e4, allow_nan=False))
@settings(max_examples=300)
def test_fix_round_properties(alpha, beta, x):
    t = FixedPointType(alpha, beta, signed=True)
    y = float(fix_round(np.float64(x), t))
    # in-range, on-grid, and within half a step of the clipped input
    assert t.min_value - 1e-9 <= y <= t.max_value + 1e-9
    assert abs(y * 2 ** beta - round(y * 2 ** beta)) < 1e-6
    clipped = min(max(x, t.min_value), t.max_value)
    assert abs(y - clipped) <= 0.5 * t.resolution + 1e-9


@given(st.integers(1, 12), st.integers(0, 10),
       st.lists(st.floats(-1e4, 1e4, allow_nan=False), min_size=1, max_size=16))
@settings(max_examples=100)
def test_quantize_matches_numpy_oracle(alpha, beta, xs):
    t = FixedPointType(alpha, beta, signed=True)
    x = np.asarray(xs, dtype=np.float64)
    q_jax = np.asarray(quantize(x, t))
    q_np = np_quantize(x, t)
    np.testing.assert_array_equal(q_jax, q_np)


def test_quantize_dequantize_roundtrip_on_grid():
    t = FixedPointType(6, 3, signed=True)
    grid = np.arange(t.int_min, t.int_max + 1) * t.resolution
    q = quantize(grid, t)
    back = np.asarray(dequantize(q, t))
    np.testing.assert_allclose(back, grid, atol=1e-12)


def test_saturation_mode():
    t = FixedPointType(4, 2, signed=True)   # range [-8, 7.75]
    assert float(fix_round(np.float64(100.0), t)) == t.max_value
    assert float(fix_round(np.float64(-100.0), t)) == t.min_value


def test_for_range():
    t = FixedPointType.for_range(0, 255)
    assert t.alpha == 8 and not t.signed
    t = FixedPointType.for_range(-85, 85, beta=5)
    assert t.alpha == 8 and t.signed and t.beta == 5


def test_invalid_types():
    with pytest.raises(ValueError):
        FixedPointType(0, 0)
    with pytest.raises(ValueError):
        FixedPointType(-1, 2)
