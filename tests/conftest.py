"""Shared test config.

NOTE: do NOT set XLA_FLAGS / device-count env vars here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces
the 512-device placeholder topology (and only in its own process).

`hypothesis` is an optional dev dependency (see requirements-dev.txt): when
it is absent the tier-1 suite must still collect and run — only the
property-fuzz module is skipped (mixed modules import via _hyp_compat and
degrade their property tests to runtime skips).

Profiles: "repro" (default) disables deadlines for local runs; "ci"
additionally bounds example counts so fuzz suites are deterministic and
fast in CI — select it with HYPOTHESIS_PROFILE=ci and pin the run with
pytest's --hypothesis-seed (see .github/workflows/ci.yml).
"""
import os

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    # skip only the hypothesis-only module; everything else runs without it
    collect_ignore = ["test_property_fuzz.py"]
else:
    # jax dispatch inside property bodies easily exceeds hypothesis' 200 ms
    # deadline on a 1-core container; disable deadlines globally.
    settings.register_profile(
        "repro",
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    # CI twin: same deadline policy, bounded example budget (the seeded
    # deterministic batteries carry the coverage; hypothesis adds breadth).
    # Determinism comes from pytest's --hypothesis-seed flag — NOT from
    # derandomize=True, which would silently ignore that seed.
    settings.register_profile(
        "ci",
        deadline=None,
        max_examples=15,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "repro"))
