"""Shared test config.

NOTE: do NOT set XLA_FLAGS / device-count env vars here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces
the 512-device placeholder topology (and only in its own process).

`hypothesis` is an optional dev dependency (see requirements-dev.txt): when
it is absent the tier-1 suite must still collect and run — only the
property-fuzz module is skipped (mixed modules import via _hyp_compat and
degrade their property tests to runtime skips).
"""
try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    # skip only the hypothesis-only module; everything else runs without it
    collect_ignore = ["test_property_fuzz.py"]
else:
    # jax dispatch inside property bodies easily exceeds hypothesis' 200 ms
    # deadline on a 1-core container; disable deadlines globally.
    settings.register_profile(
        "repro",
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.load_profile("repro")
