"""Shared test config.

NOTE: do NOT set XLA_FLAGS / device-count env vars here — smoke tests and
benches must see the single real CPU device; only launch/dryrun.py forces
the 512-device placeholder topology (and only in its own process).
"""
from hypothesis import HealthCheck, settings

# jax dispatch inside property bodies easily exceeds hypothesis' 200 ms
# deadline on a 1-core container; disable deadlines globally.
settings.register_profile(
    "repro",
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("repro")
