"""`repro.smt` — whole-DAG SMT-style range analysis (paper §V-B).

Covers the encoder's correlation model, the branch-and-prune solver's
three-valued verdicts, the dichotomic tightener's soundness ordering
(profile ⊆ smt ⊆ interval on USM and HCD), and the acceptance-level claims:
alphas never exceed the interval domain's, and HCD's `Ixy` drops a bit
(the correlated max of `Ix*Iy` is 9*(255/12)^2 < 2^12, which interval
arithmetic cannot see).

Also hosts the `IntersectDomain._meet` round-off-fallback coverage.
"""
import math

import pytest

from repro.core import intersect
from repro.core.absval import get_domain
from repro.core.interval import Interval
from repro.core.range_analysis import analyze
from repro.dsl.builder import PipelineBuilder
from repro.pipelines import dus, hcd, usm, workflows as W
from repro.smt import SMTConfig, analyze_smt
from repro.smt import solver as S
from repro.smt.encoder import encode_stage

# analyses shared across tests (HCD SMT is the expensive one: ~10 s)
_TEST_CFG = SMTConfig(time_budget_s=60.0)


@pytest.fixture(scope="module")
def usm_res():
    p = usm.build()
    return p, analyze(p), analyze_smt(p, config=_TEST_CFG)


@pytest.fixture(scope="module")
def hcd_res():
    p = hcd.build()
    return p, analyze(p), analyze_smt(p, config=_TEST_CFG)


def _diff_pipeline():
    """d = img - img: per-stage interval walk sees two independent signals;
    the whole-DAG encoder must share the pixel variable."""
    p = PipelineBuilder("diff")
    img = p.image("img", 0, 255)
    p.define("d", img - img)
    return p.build()


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def test_encoder_shares_same_pixel():
    p = _diff_pipeline()
    csp, root = encode_stage(p, "d", {n: r.range for n, r in
                                      analyze(p).items()})
    assert sum(1 for k in csp.kinds if k == "input") == 1


def test_encoder_distinct_taps_stay_independent():
    # blurx taps 5 distinct pixels -> 5 input vars (homogeneity model)
    p = usm.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, _ = encode_stage(p, "blurx", bounds)
    assert sum(1 for k in csp.kinds if k == "input") == 5


def test_encoder_budget_cut_is_bounded():
    p = hcd.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, _ = encode_stage(p, "harris", bounds, max_vars=40)
    cuts = [i for i, k in enumerate(csp.kinds) if k == "cut"]
    assert cuts, "tiny budget must force cut variables"
    for i in cuts:
        assert not math.isinf(csp.init[i].lo) and not math.isinf(csp.init[i].hi)


def test_encoder_cuts_sampled_producers():
    p = dus.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, _ = encode_stage(p, "Uy", bounds)
    # Ux is up-sampled: its instances must be cuts, not expansions
    assert any(k == "cut" for k in csp.kinds)
    assert not any(k == "input" for k in csp.kinds)


# ---------------------------------------------------------------------------
# solver verdicts
# ---------------------------------------------------------------------------

def test_decide_refutes_and_witnesses_cancellation():
    p = _diff_pipeline()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "d", bounds)
    assert S.decide(csp, root, "ge", 1.0).status == S.UNSAT
    v = S.decide(csp, root, "ge", 0.0)
    assert v.status == S.SAT and v.witness == 0.0
    assert S.decide(csp, root, "le", -1.0).status == S.UNSAT


def test_decide_finds_usm_sharpen_witness():
    p = usm.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "sharpen", bounds)
    v = S.decide(csp, root, "ge", 400.0, S.BPBudget(64, 6))
    assert v.status == S.SAT
    assert v.witness >= 400.0
    # the true max is 474.140625 (weight=1, center=255, neighborhood=0)
    assert v.witness <= 474.140625 + 1e-9


def test_minmax_backward_projection_refutes_instead_of_raising():
    # regression: min/max inverse projections used to construct malformed
    # Intervals (ValueError) when contraction proved the box empty
    p = PipelineBuilder("clampdiff")
    img = p.image("img", 0, 255)
    from repro.dsl.builder import maxv, minv
    from repro.core.graph import Const
    m = p.define("m", minv(img, Const(16.0)))
    p.define("r", m - 2.0 * img)
    pipe = p.build()
    ia = analyze(pipe)
    sm = analyze_smt(pipe, config=_TEST_CFG)
    for s in pipe.topo_order():
        assert ia[s].range.encloses(sm[s].range), s


def test_pow_zero_exponent_gradient():
    # regression: d(x^0)/dx used Interval**-1 and raised
    from repro.core.graph import Pow
    p = PipelineBuilder("pow0")
    img = p.image("img", 0, 255)
    p.define("k", Pow(img - 3.0, 0) + img)
    pipe = p.build()
    sm = analyze_smt(pipe, config=_TEST_CFG)
    assert sm["k"].range.lo == 1.0 and sm["k"].range.hi == 256.0


def test_meet_slack_absorbs_roundoff():
    a = Interval(0.0, 1.0)
    assert S._meet(a, Interval(1.0 + 1e-12, 2.0)) is not None
    assert S._meet(a, Interval(1.1, 2.0)) is None


# ---------------------------------------------------------------------------
# analyze_smt: acceptance-level properties
# ---------------------------------------------------------------------------

def test_usm_smt_subset_of_interval_and_strictly_tighter(usm_res):
    p, ia, sm = usm_res
    for s in p.topo_order():
        assert sm[s].alpha <= ia[s].alpha, s
        assert ia[s].range.encloses(sm[s].range), s
    # USM's interval alphas are already alpha-exact (true worst-case ranges
    # round to the same bit counts), so the win here is strictly tighter
    # *ranges*: sharpen's true range is [-219.14, 474.14], not [-255, 510].
    assert sm["sharpen"].range.hi < 510.0 - 1.0
    assert sm["sharpen"].range.lo > -255.0 + 1.0
    # ...and it still contains the true extreme (weight=1 corner case)
    assert sm["sharpen"].range.contains(474.140625)
    assert sm["sharpen"].range.contains(-219.140625)


def test_hcd_smt_subset_and_ixy_alpha_improves(hcd_res):
    p, ia, sm = hcd_res
    for s in p.topo_order():
        assert sm[s].alpha <= ia[s].alpha, s
        assert ia[s].range.encloses(sm[s].range), s
    # correlated max of Ix*Iy is 9*(255/12)^2 = 4064.0625 < 2^12: one
    # full bit below the interval domain's +-85^2 (paper Table II: 14)
    assert ia["Ixy"].alpha == 14
    assert sm["Ixy"].alpha == 13
    assert sm["Ixy"].range.contains(4064.0625)


def test_dus_smt_matches_interval_exactly():
    p = dus.build()
    ia = analyze(p)
    sm = analyze_smt(p, config=_TEST_CFG)
    for s in p.topo_order():
        assert sm[s].alpha == ia[s].alpha == 8, s
        assert ia[s].range.encloses(sm[s].range), s


def test_smt_alpha_never_worse_than_interval_on_deep_pipeline():
    from repro.pipelines import optical_flow
    p = optical_flow.build(n_iters=1)
    ia = analyze(p)
    sm = analyze_smt(p, config=SMTConfig(time_budget_s=30.0))
    for s in p.topo_order():
        assert sm[s].alpha <= ia[s].alpha, s
        assert ia[s].range.encloses(sm[s].range), s
    # the paper's headline: correlation through Denom = alpha^2 + Ix^2 + Iy^2
    # caps |Vx0| near 0.05*255, far below interval's 0.85*255
    assert sm["Vx0"].alpha < ia["Vx0"].alpha


# ---------------------------------------------------------------------------
# soundness ordering: profile ⊆ smt ⊆ interval (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [lambda: W.make_usm(3, 3, (32, 32)),
                                  lambda: W.make_hcd(3, 3, (32, 32))],
                         ids=["usm", "hcd"])
def test_soundness_ordering_profile_smt_interval(make):
    b = make()
    ia = analyze(b.pipeline)
    sm = analyze_smt(b.pipeline, config=_TEST_CFG)
    prof = b.profile()
    for s in b.pipeline.topo_order():
        assert sm[s].range.encloses(prof.observed_range[s]), s
        assert ia[s].range.encloses(sm[s].range), s
        assert prof.alpha_max[s] <= sm[s].alpha <= ia[s].alpha, s


# ---------------------------------------------------------------------------
# registry / dispatch / config plumbing
# ---------------------------------------------------------------------------

def test_registry_lazy_loads_and_dispatches_whole_dag(usm_res):
    p, _, sm = usm_res
    dom = get_domain("smt")
    assert getattr(dom, "whole_dag", False)
    via_analyze = analyze(p, domain="smt")
    assert {k: v.alpha for k, v in via_analyze.items()} == \
        {k: v.alpha for k, v in sm.items()}


def test_smt_alphas_workflow_column(usm_res):
    p, _, sm = usm_res
    alphas, signed = W.smt_alphas(p, config=_TEST_CFG)
    assert alphas == {k: v.alpha for k, v in sm.items()}
    assert signed["sharpen"] is True and signed["masked"] is False


def test_input_range_override_propagates():
    p = _diff_pipeline()
    res = analyze_smt(p, input_ranges={"img": Interval(0.0, 16.0)})
    assert res["img"].range.hi == 16.0
    assert res["d"].range.lo == res["d"].range.hi == 0.0


def test_z3_backend_gated():
    from repro.smt import z3backend
    if z3backend.HAVE_Z3:
        pytest.skip("z3 installed: gating path not reachable")
    p = _diff_pipeline()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "d", bounds)
    # without z3 the backend must politely return UNKNOWN...
    assert z3backend.decide(csp, root, "ge", 1.0).status == S.UNKNOWN
    # ...and analyze_smt must give identical results with z3 disabled
    a = analyze_smt(p, config=SMTConfig(use_z3="never"))
    b = analyze_smt(p, config=SMTConfig(use_z3="auto"))
    assert {k: (v.range.lo, v.range.hi) for k, v in a.items()} == \
        {k: (v.range.lo, v.range.hi) for k, v in b.items()}


def test_z3_backend_answers_when_available():
    pytest.importorskip("z3")
    from repro.smt import z3backend
    p = _diff_pipeline()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "d", bounds)
    assert z3backend.decide(csp, root, "ge", 1.0).status == S.UNSAT
    assert z3backend.decide(csp, root, "ge", 0.0).status == S.SAT


# ---------------------------------------------------------------------------
# IntersectDomain._meet round-off fallback (satellite)
# ---------------------------------------------------------------------------

def test_intersect_meet_overlap():
    m = intersect._meet(Interval(0.0, 2.0), Interval(1.0, 3.0))
    assert (m.lo, m.hi) == (1.0, 2.0)


def test_intersect_meet_roundoff_fallback_prefers_narrower():
    # both operands are sound over-approximations; when round-off makes them
    # "disjoint", the fallback must keep the narrower one (still sound)
    a = Interval(0.0, 1.0)            # width 1
    b = Interval(1.0 + 1e-9, 2.5)     # width ~1.5
    assert intersect._meet(a, b) is a
    assert intersect._meet(b, a) is a
    # symmetric case: second operand narrower
    c = Interval(2.0, 2.25)
    assert intersect._meet(Interval(0.0, 1.0), c) is c


def test_intersect_domain_end_to_end_sound():
    p = hcd.build()
    ia = analyze(p, domain="interval")
    ii = analyze(p, domain="intersect")
    for s in p.topo_order():
        assert ia[s].range.encloses(ii[s].range), s
        assert ii[s].alpha <= ia[s].alpha, s
