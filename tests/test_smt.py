"""`repro.smt` — whole-DAG SMT-style range analysis (paper §V-B).

Covers the encoder's correlation model, the branch-and-prune solver's
three-valued verdicts, the dichotomic tightener's soundness ordering
(profile ⊆ smt ⊆ interval on USM and HCD), and the acceptance-level claims:
alphas never exceed the interval domain's, and HCD's `Ixy` drops a bit
(the correlated max of `Ix*Iy` is 9*(255/12)^2 < 2^12, which interval
arithmetic cannot see).

Also hosts the `IntersectDomain._meet` round-off-fallback coverage.
"""
import math

import pytest

from repro.core import intersect
from repro.core.absval import get_domain
from repro.core.interval import Interval
from repro.core.range_analysis import analyze
from repro.dsl.builder import PipelineBuilder
from repro.pipelines import dus, hcd, usm, workflows as W
from repro.smt import SMTConfig, analyze_smt
from repro.smt import solver as S
from repro.smt.encoder import encode_stage

# analyses shared across tests (HCD SMT is the expensive one: ~10 s)
_TEST_CFG = SMTConfig(time_budget_s=60.0)


@pytest.fixture(scope="module")
def usm_res():
    p = usm.build()
    return p, analyze(p), analyze_smt(p, config=_TEST_CFG)


@pytest.fixture(scope="module")
def hcd_res():
    p = hcd.build()
    return p, analyze(p), analyze_smt(p, config=_TEST_CFG)


def _diff_pipeline():
    """d = img - img: per-stage interval walk sees two independent signals;
    the whole-DAG encoder must share the pixel variable."""
    p = PipelineBuilder("diff")
    img = p.image("img", 0, 255)
    p.define("d", img - img)
    return p.build()


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def test_encoder_shares_same_pixel():
    p = _diff_pipeline()
    csp, root = encode_stage(p, "d", {n: r.range for n, r in
                                      analyze(p).items()})
    assert sum(1 for k in csp.kinds if k == "input") == 1


def test_encoder_distinct_taps_stay_independent():
    # blurx taps 5 distinct pixels -> 5 input vars (homogeneity model)
    p = usm.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, _ = encode_stage(p, "blurx", bounds)
    assert sum(1 for k in csp.kinds if k == "input") == 5


def test_encoder_budget_cut_is_bounded():
    p = hcd.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, _ = encode_stage(p, "harris", bounds, max_vars=40)
    cuts = [i for i, k in enumerate(csp.kinds) if k == "cut"]
    assert cuts, "tiny budget must force cut variables"
    for i in cuts:
        assert not math.isinf(csp.init[i].lo) and not math.isinf(csp.init[i].hi)


def test_encoder_cuts_sampled_producers():
    p = dus.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, _ = encode_stage(p, "Uy", bounds)
    # Ux is up-sampled: its instances must be cuts, not expansions
    assert any(k == "cut" for k in csp.kinds)
    assert not any(k == "input" for k in csp.kinds)


# ---------------------------------------------------------------------------
# solver verdicts
# ---------------------------------------------------------------------------

def test_decide_refutes_and_witnesses_cancellation():
    p = _diff_pipeline()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "d", bounds)
    assert S.decide(csp, root, "ge", 1.0).status == S.UNSAT
    v = S.decide(csp, root, "ge", 0.0)
    assert v.status == S.SAT and v.witness == 0.0
    assert S.decide(csp, root, "le", -1.0).status == S.UNSAT


def test_decide_finds_usm_sharpen_witness():
    p = usm.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "sharpen", bounds)
    v = S.decide(csp, root, "ge", 400.0, S.BPBudget(64, 6))
    assert v.status == S.SAT
    assert v.witness >= 400.0
    # the true max is 474.140625 (weight=1, center=255, neighborhood=0)
    assert v.witness <= 474.140625 + 1e-9


def test_minmax_backward_projection_refutes_instead_of_raising():
    # regression: min/max inverse projections used to construct malformed
    # Intervals (ValueError) when contraction proved the box empty
    p = PipelineBuilder("clampdiff")
    img = p.image("img", 0, 255)
    from repro.dsl.builder import maxv, minv
    from repro.core.graph import Const
    m = p.define("m", minv(img, Const(16.0)))
    p.define("r", m - 2.0 * img)
    pipe = p.build()
    ia = analyze(pipe)
    sm = analyze_smt(pipe, config=_TEST_CFG)
    for s in pipe.topo_order():
        assert ia[s].range.encloses(sm[s].range), s


def test_pow_zero_exponent_gradient():
    # regression: d(x^0)/dx used Interval**-1 and raised
    from repro.core.graph import Pow
    p = PipelineBuilder("pow0")
    img = p.image("img", 0, 255)
    p.define("k", Pow(img - 3.0, 0) + img)
    pipe = p.build()
    sm = analyze_smt(pipe, config=_TEST_CFG)
    assert sm["k"].range.lo == 1.0 and sm["k"].range.hi == 256.0


def test_meet_slack_absorbs_roundoff():
    a = Interval(0.0, 1.0)
    assert S._meet(a, Interval(1.0 + 1e-12, 2.0)) is not None
    assert S._meet(a, Interval(1.1, 2.0)) is None


# ---------------------------------------------------------------------------
# analyze_smt: acceptance-level properties
# ---------------------------------------------------------------------------

def test_usm_smt_subset_of_interval_and_strictly_tighter(usm_res):
    p, ia, sm = usm_res
    for s in p.topo_order():
        assert sm[s].alpha <= ia[s].alpha, s
        assert ia[s].range.encloses(sm[s].range), s
    # USM's interval alphas are already alpha-exact (true worst-case ranges
    # round to the same bit counts), so the win here is strictly tighter
    # *ranges*: sharpen's true range is [-219.14, 474.14], not [-255, 510].
    assert sm["sharpen"].range.hi < 510.0 - 1.0
    assert sm["sharpen"].range.lo > -255.0 + 1.0
    # ...and it still contains the true extreme (weight=1 corner case)
    assert sm["sharpen"].range.contains(474.140625)
    assert sm["sharpen"].range.contains(-219.140625)


def test_hcd_smt_subset_and_ixy_alpha_improves(hcd_res):
    p, ia, sm = hcd_res
    for s in p.topo_order():
        assert sm[s].alpha <= ia[s].alpha, s
        assert ia[s].range.encloses(sm[s].range), s
    # correlated max of Ix*Iy is 9*(255/12)^2 = 4064.0625 < 2^12: one
    # full bit below the interval domain's +-85^2 (paper Table II: 14)
    assert ia["Ixy"].alpha == 14
    assert sm["Ixy"].alpha == 13
    assert sm["Ixy"].range.contains(4064.0625)


def test_dus_phase_split_strictly_tightens_detail_stages():
    """Phase-split SMT on the extended DUS pyramid: the alignment-blind
    PR-2 encoder recovered zero bits over interval analysis on any DUS
    stage; the polyphase encoding must now strictly tighten the detail
    stages.  The paper's convex chain itself stays exactly [0, 255] —
    that IS its true range (the kernels are convex), so equality there is
    the correct answer, not a missed opportunity."""
    p = dus.build_extended()
    ia = analyze(p)
    sm = analyze_smt(p, config=_TEST_CFG)
    for s in p.topo_order():
        assert sm[s].alpha <= ia[s].alpha, s
        assert ia[s].range.encloses(sm[s].range), s
    # convex down-up chain: exact, and exactly the interval result
    for s in ("Dx", "Dy", "Ux", "Uy", "D5"):
        assert sm[s].alpha == ia[s].alpha == 8, s
        assert (sm[s].range.lo, sm[s].range.hi) == (0.0, 255.0), s
    # DoG band on the decimated grid: 2 alpha bits recovered (exact +-59.77
    # vs the blind +-255); reconstruction residual: strictly tighter range
    assert sm["band"].alpha <= ia["band"].alpha - 2
    assert sm["res"].range.hi < ia["res"].range.hi - 1.0
    assert sm["res"].range.lo > ia["res"].range.lo + 1.0


def test_smt_alpha_never_worse_than_interval_on_deep_pipeline():
    from repro.pipelines import optical_flow
    p = optical_flow.build(n_iters=1)
    ia = analyze(p)
    sm = analyze_smt(p, config=SMTConfig(time_budget_s=30.0))
    for s in p.topo_order():
        assert sm[s].alpha <= ia[s].alpha, s
        assert ia[s].range.encloses(sm[s].range), s
    # the paper's headline: correlation through Denom = alpha^2 + Ix^2 + Iy^2
    # caps |Vx0| near 0.05*255, far below interval's 0.85*255
    assert sm["Vx0"].alpha < ia["Vx0"].alpha


# ---------------------------------------------------------------------------
# soundness ordering: profile ⊆ smt ⊆ interval (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [lambda: W.make_usm(3, 3, (32, 32)),
                                  lambda: W.make_hcd(3, 3, (32, 32))],
                         ids=["usm", "hcd"])
def test_soundness_ordering_profile_smt_interval(make):
    b = make()
    ia = analyze(b.pipeline)
    sm = analyze_smt(b.pipeline, config=_TEST_CFG)
    prof = b.profile()
    for s in b.pipeline.topo_order():
        assert sm[s].range.encloses(prof.observed_range[s]), s
        assert ia[s].range.encloses(sm[s].range), s
        assert prof.alpha_max[s] <= sm[s].alpha <= ia[s].alpha, s


# ---------------------------------------------------------------------------
# registry / dispatch / config plumbing
# ---------------------------------------------------------------------------

def test_registry_lazy_loads_and_dispatches_whole_dag(usm_res):
    p, _, sm = usm_res
    dom = get_domain("smt")
    assert getattr(dom, "whole_dag", False)
    via_analyze = analyze(p, domain="smt")
    assert {k: v.alpha for k, v in via_analyze.items()} == \
        {k: v.alpha for k, v in sm.items()}


def test_smt_alphas_workflow_column(usm_res):
    p, _, sm = usm_res
    alphas, signed = W.smt_alphas(p, config=_TEST_CFG)
    assert alphas == {k: v.alpha for k, v in sm.items()}
    assert signed["sharpen"] is True and signed["masked"] is False


def test_input_range_override_propagates():
    p = _diff_pipeline()
    res = analyze_smt(p, input_ranges={"img": Interval(0.0, 16.0)})
    assert res["img"].range.hi == 16.0
    assert res["d"].range.lo == res["d"].range.hi == 0.0


def test_z3_backend_gated():
    from repro.smt import z3backend
    if z3backend.HAVE_Z3:
        pytest.skip("z3 installed: gating path not reachable")
    p = _diff_pipeline()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "d", bounds)
    # without z3 the backend must politely return UNKNOWN...
    assert z3backend.decide(csp, root, "ge", 1.0).status == S.UNKNOWN
    # ...and analyze_smt must give identical results with z3 disabled
    a = analyze_smt(p, config=SMTConfig(use_z3="never"))
    b = analyze_smt(p, config=SMTConfig(use_z3="auto"))
    assert {k: (v.range.lo, v.range.hi) for k, v in a.items()} == \
        {k: (v.range.lo, v.range.hi) for k, v in b.items()}


def test_z3_backend_answers_when_available():
    pytest.importorskip("z3")
    from repro.smt import z3backend
    p = _diff_pipeline()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "d", bounds)
    assert z3backend.decide(csp, root, "ge", 1.0).status == S.UNSAT
    assert z3backend.decide(csp, root, "ge", 0.0).status == S.SAT


# ---------------------------------------------------------------------------
# batched-box engine: differential vs the scalar reference oracle
# ---------------------------------------------------------------------------

def _stage_csp(pipe, stage):
    bounds = {n: r.range for n, r in analyze(pipe).items()}
    csp, root = encode_stage(pipe, stage, bounds)
    return csp, root, bounds[stage]


def _of_flat():
    from repro.pipelines import optical_flow
    return optical_flow.build(n_iters=1)


def _of_pyramid():
    from repro.pipelines import optical_flow
    return optical_flow.build_pyramid(n_iters=1)


_DIFF_STAGES = [("usm", lambda: usm.build(), "sharpen"),
                ("usm", lambda: usm.build(), "masked"),
                ("dus", lambda: dus.build(), "Uy"),
                ("dus", lambda: dus.build(), "Dy"),
                ("dus_ext", lambda: dus.build_extended(), "band"),
                ("hcd", lambda: hcd.build(), "Ixy"),
                ("hcd", lambda: hcd.build(), "trace"),
                ("of", _of_flat, "Denom"),
                ("of_pyr", _of_pyramid, "cVx0"),
                ("of_pyr", _of_pyramid, "Vx1")]


@pytest.mark.parametrize("pipe_name,make,stage",
                         _DIFF_STAGES,
                         ids=[f"{p}-{s}" for p, _, s in _DIFF_STAGES])
def test_batched_decide_never_contradicts_scalar(pipe_name, make, stage):
    """Equal-budget differential on pinned stages/queries: the batched
    engine's verdicts must never contradict the scalar oracle's, and on
    these fixed workloads it certifies UNSAT wherever the oracle does.
    (The engines may explore different trees in general — best-first
    batches vs LIFO — so the UNSAT-parity clause is a golden check on
    these specific deterministic inputs, not a universal invariant.)"""
    csp, root, seed = _stage_csp(make(), stage)
    bud = S.BPBudget(48, 6)
    for frac, sense in ((1.5, "ge"), (0.5, "ge"), (1.5, "le"), (0.5, "le")):
        t = (seed.hi if sense == "ge" else seed.lo) * frac
        vs = S.decide_scalar(csp, root, sense, t, bud)
        vb = S.decide(csp, root, sense, t, bud)
        assert {vs.status, vb.status} != {S.SAT, S.UNSAT}, (stage, sense, t)
        if vs.status == S.UNSAT:
            assert vb.status == S.UNSAT, (stage, sense, t)


@pytest.mark.parametrize("pipe_name,make,stage",
                         _DIFF_STAGES,
                         ids=[f"{p}-{s}" for p, _, s in _DIFF_STAGES])
def test_batched_tighten_not_looser_than_scalar(pipe_name, make, stage):
    """tighten_stage with the batched engine must produce bounds no looser
    than the scalar reference path at equal node budget."""
    import time as _t
    from repro.smt.optimize import tighten_stage
    csp, root, seed = _stage_csp(make(), stage)
    cfg_b = SMTConfig(engine="batched", max_nodes=64, work_budget=4096)
    cfg_s = SMTConfig(engine="scalar")
    ivb = tighten_stage(csp, root, seed, cfg_b, _t.monotonic() + 120.0)
    ivs = tighten_stage(csp, root, seed, cfg_s, _t.monotonic() + 120.0)
    tol = 1e-9 * max(1.0, abs(ivs.lo), abs(ivs.hi))
    assert ivb.lo >= ivs.lo - tol, (stage, ivb, ivs)
    assert ivb.hi <= ivs.hi + tol, (stage, ivb, ivs)


def test_batched_small_budget_equals_scalar_exactly():
    """Below the vectorization threshold the batched engine runs the very
    same per-box scalar step, so tiny-budget verdicts must be identical."""
    p = usm.build()
    csp, root, seed = _stage_csp(p, "sharpen")
    for t in (474.0, 475.0, 400.0):
        vs = S.decide_scalar(csp, root, "ge", t, S.BPBudget(8, 6))
        vb = S.decide(csp, root, "ge", t, S.BPBudget(8, 6))
        assert vs.status == vb.status, t
        if vs.status == S.SAT:
            assert vb.witness is not None and vb.witness >= t


def test_batched_engine_processes_full_budget():
    """The batched engine must actually spend its (much larger) node
    budget on a hard query — processed-node accounting is deterministic,
    unlike boxes/sec, which the CI "Solver throughput smoke" benchmark
    step reports instead (wall-clock assertions don't belong in a -x
    tier-1 suite)."""
    p = hcd.build()
    csp, root, _ = _stage_csp(p, "det")
    v = S.decide(csp, root, "ge", 2.0 ** 30, S.BPBudget(1024, 6))
    assert v.status == S.UNKNOWN           # deep in unresolvable territory
    assert v.nodes == 1024                 # the whole budget was consumed


def test_program_compilation_cached_and_wellformed():
    p = hcd.build()
    csp, root, _ = _stage_csp(p, "Ixy")
    from repro.smt.encoder import compile_csp
    prog = compile_csp(csp)
    assert compile_csp(csp) is prog          # cached on the CSP
    assert prog.nvars == csp.nvars
    # topo order: every operand id is smaller than the defined id
    for k in range(prog.ndefs):
        for j in range(int(prog.nargs[k])):
            if prog.argv[k, j] >= 0:
                assert prog.argv[k, j] < prog.def_var[k]
    assert set(prog.base.tolist()) == set(csp.base_vars())


def test_smt_scalar_domain_registered():
    dom = get_domain("smt-scalar")
    assert getattr(dom, "whole_dag", False)
    assert dom.config.engine == "scalar"
    p = _diff_pipeline()
    res = analyze(p, domain="smt-scalar")
    assert res["d"].range.lo == res["d"].range.hi == 0.0


# ---------------------------------------------------------------------------
# golden: regenerated table11 must never be looser than the PR-1 alphas
# ---------------------------------------------------------------------------

_PR1_SMT_ALPHAS = {
    ("usm", "img"): 8, ("usm", "blurx"): 8, ("usm", "blury"): 8,
    ("usm", "sharpen"): 10, ("usm", "masked"): 9,
    ("dus", "img"): 8, ("dus", "Dx"): 8, ("dus", "Dy"): 8,
    ("dus", "Ux"): 8, ("dus", "Uy"): 8,
    ("hcd", "img"): 8, ("hcd", "Ix"): 8, ("hcd", "Iy"): 8,
    ("hcd", "Ixx"): 13, ("hcd", "Ixy"): 13, ("hcd", "Iyy"): 13,
    ("hcd", "Sxx"): 16, ("hcd", "Sxy"): 17, ("hcd", "Syy"): 16,
    ("hcd", "det"): 33, ("hcd", "trace"): 17, ("hcd", "harris"): 33,
    ("optical_flow", "img1"): 8, ("optical_flow", "img2"): 8,
    ("optical_flow", "It"): 9, ("optical_flow", "Ix"): 8,
    ("optical_flow", "Iy"): 8, ("optical_flow", "Ixx"): 13,
    ("optical_flow", "Iyy"): 13, ("optical_flow", "Denom"): 14,
    ("optical_flow", "commonX"): 1, ("optical_flow", "commonY"): 1,
    ("optical_flow", "Vx0"): 5, ("optical_flow", "Vy0"): 5,
    ("optical_flow", "Avgx1"): 5, ("optical_flow", "Avgy1"): 5,
    ("optical_flow", "Common1"): 3, ("optical_flow", "Vx1"): 7,
    ("optical_flow", "Vy1"): 7, ("optical_flow", "Avgx2"): 7,
    ("optical_flow", "Avgy2"): 7, ("optical_flow", "Common2"): 4,
    ("optical_flow", "Vx2"): 11, ("optical_flow", "Vy2"): 11,
    ("optical_flow", "Avgx3"): 11, ("optical_flow", "Avgy3"): 11,
    ("optical_flow", "Common3"): 12, ("optical_flow", "Vx3"): 18,
    ("optical_flow", "Vy3"): 18, ("optical_flow", "Avgx4"): 18,
    ("optical_flow", "Avgy4"): 18, ("optical_flow", "Common4"): 19,
    ("optical_flow", "Vx4"): 25, ("optical_flow", "Vy4"): 25,
}


def _table11_rows():
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "results", "table11_smt_alphas.json")
    with open(path) as f:
        data = json.load(f)
    return {(r[0], r[1]): (int(r[2]), int(r[3]), int(r[4]))
            for r in data["rows"]}


def test_table11_golden_not_looser_than_pr1():
    """The committed `table11_smt_alphas.json` (regenerated with the
    batched engine's larger budgets and phase-split encoding) must keep
    profile <= smt <= interval nesting on every row and must never report
    an smt alpha above the PR-1 value on the paper benchmarks.  (PR-3 adds
    the `dus_ext`/`of_pyramid` groups, hence superset not equality.)"""
    rows = _table11_rows()
    assert set(rows) >= set(_PR1_SMT_ALPHAS)
    for key, (interval_a, smt_a, profile_a) in rows.items():
        assert profile_a <= smt_a <= interval_a, key
    for key in _PR1_SMT_ALPHAS:
        assert rows[key][1] <= _PR1_SMT_ALPHAS[key], (key, rows[key])


def test_table11_golden_phase_split_wins():
    """Golden-nesting regression for the phase-split groups: the committed
    table must show the sampled detail stages losing alpha bits that the
    alignment-blind PR-2 encoder could not recover (band: 2 bits below its
    interval column; pyramid coarse flow: the flat-OF headline carried
    through the sampling boundary)."""
    rows = _table11_rows()
    band_i, band_s, band_p = rows[("dus_ext", "band")]
    assert band_s <= band_i - 2
    assert band_p <= band_s
    # the paper's convex DUS chain stays pinned at 8 everywhere
    for (g, s), (ia, sa, pa) in rows.items():
        if g == "dus":
            assert ia == sa == 8, (g, s)
    cvx_i, cvx_s, _ = rows[("of_pyramid", "cVx0")]
    assert cvx_s <= cvx_i - 3
    vx1_i, vx1_s, _ = rows[("of_pyramid", "Vx1")]
    assert vx1_s < vx1_i


# ---------------------------------------------------------------------------
# IntersectDomain._meet round-off fallback (satellite)
# ---------------------------------------------------------------------------

def test_intersect_meet_overlap():
    m = intersect._meet(Interval(0.0, 2.0), Interval(1.0, 3.0))
    assert (m.lo, m.hi) == (1.0, 2.0)


def test_intersect_meet_roundoff_fallback_prefers_narrower():
    # both operands are sound over-approximations; when round-off makes them
    # "disjoint", the fallback must keep the narrower one (still sound)
    a = Interval(0.0, 1.0)            # width 1
    b = Interval(1.0 + 1e-9, 2.5)     # width ~1.5
    assert intersect._meet(a, b) is a
    assert intersect._meet(b, a) is a
    # symmetric case: second operand narrower
    c = Interval(2.0, 2.25)
    assert intersect._meet(Interval(0.0, 1.0), c) is c


def test_intersect_domain_end_to_end_sound():
    p = hcd.build()
    ia = analyze(p, domain="interval")
    ii = analyze(p, domain="intersect")
    for s in p.topo_order():
        assert ia[s].range.encloses(ii[s].range), s
        assert ii[s].alpha <= ia[s].alpha, s
