"""`repro.obs` unit + integration battery (docs/observability.md).

Covers: span nesting / attributes / thread safety, the CounterGroup shim
behind the legacy stat dicts, both exporter schemas (JSONL round-trip and
Chrome trace-event JSON), runtime range telemetry on a deliberately
saturating synthetic residue plan, the tracing-on vs tracing-off
bit-exactness guarantee of the lowered backends, SMT budget-exhaustion
visibility (warning + event + plan provenance note), and one end-to-end
traced compile of HCD matching the acceptance trace content.
"""
import json
import threading
import warnings

import numpy as np
import pytest

from repro import obs
from repro.analysis import run_plan
from repro.analysis import driver as D
from repro.core.interval import Interval
from repro.core.range_analysis import StageRange
from repro.dsl.exec import run_fixed
from repro.obs import report
from repro.pipelines import dus, hcd, usm
from repro.smt import BudgetExhaustedWarning, SMTConfig, analyze_smt
from repro.smt import solver as S


# ---------------------------------------------------------------------------
# spans + counters
# ---------------------------------------------------------------------------

def test_span_nesting_and_attrs():
    with obs.tracing() as tr:
        with obs.span("outer", k=1) as o:
            with obs.span("inner") as i:
                i.set(found=True)
            o.set(done=2)
    outer, = tr.spans("outer")
    inner, = tr.spans("inner")
    assert outer.parent_id is None
    assert inner.parent_id == outer.span_id
    assert outer.attrs == {"k": 1, "done": 2}
    assert inner.attrs == {"found": True}
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1


def test_span_exception_records_error_and_unwinds():
    with obs.tracing() as tr:
        with pytest.raises(ValueError):
            with obs.span("boom"):
                raise ValueError("x")
        assert tr.current_span() is None        # stack unwound
    sp, = tr.spans("boom")
    assert sp.attrs["error"] == "ValueError"


def test_event_attaches_to_current_span():
    with obs.tracing() as tr:
        with obs.span("parent") as p:
            obs.event("marker", reason="test")
        obs.event("orphan")
    ev, = tr.events("marker")
    assert ev["parent"] == p.span_id
    assert ev["attrs"] == {"reason": "test"}
    assert tr.events("orphan")[0]["parent"] is None


def test_disabled_tracing_is_shared_noop():
    assert not obs.is_enabled()
    s1, s2 = obs.span("a", x=1), obs.span("b")
    assert s1 is s2                             # one shared null object
    with s1 as sp:
        assert sp.set(k=2) is sp                # fully inert
    obs.event("nothing")                        # no-op, no error
    obs.gauge("nothing", 1.0)
    assert obs.runtime.record_stage("x", np.zeros((2, 2))) is None


def test_span_thread_safety():
    with obs.tracing() as tr:
        def work(i):
            with obs.span("thread.outer", idx=i):
                for j in range(5):
                    with obs.span("thread.inner", idx=i, j=j):
                        pass
        threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    outers = tr.spans("thread.outer")
    inners = tr.spans("thread.inner")
    assert len(outers) == 4 and len(inners) == 20
    ids = [s.span_id for s in outers + inners]
    assert len(set(ids)) == len(ids)            # unique ids across threads
    # every inner's parent is its own worker's outer, never another worker's
    # (key by the idx attr: OS thread idents can be reused across workers)
    outer_of = {s.attrs["idx"]: s.span_id for s in outers}
    for s in inners:
        assert s.parent_id == outer_of[s.attrs["idx"]]


def test_counter_group_semantics():
    g = obs.CounterGroup("test.group", hits=0, secs=0.0)
    assert isinstance(g, dict) and g["hits"] == 0   # dict-compatible reads
    g.add("hits")
    g.add("secs", 0.5)
    g.add("extra", 3)
    assert g["hits"] == 1 and g["secs"] == 0.5 and g["extra"] == 3
    assert obs.all_counters()["test.group"] == dict(g)
    g.reset()
    assert dict(g) == {"hits": 0, "secs": 0.0}      # extras dropped
    assert g.snapshot() == {"hits": 0, "secs": 0.0}


def test_legacy_stat_dicts_are_counter_groups():
    # the three legacy module globals are byte-compatible CounterGroup shims
    for shim, name in [(D.MEMO_STATS, "analysis.memo"),
                       (D.DISK_CACHE_STATS, "analysis.disk_cache"),
                       (S.STATS, "smt.solver")]:
        assert isinstance(shim, obs.CounterGroup)
        assert shim.name == name
        assert obs.all_counters()[name] == dict(shim)
    assert set(S.STATS) == {"boxes", "secs"}
    boxes0 = S.STATS["boxes"]
    S.STATS.add("boxes", 0)                     # locked mutation available
    assert S.STATS["boxes"] == boxes0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _tiny_trace():
    with obs.tracing(runtime_ranges=True) as tr:
        with obs.span("a.outer", k=1):
            with obs.span("a.inner", iv=Interval(0.0, 1.0)):
                obs.event("a.mark", note="hi")
            obs.gauge("a.gauge", 2.5)
    return tr


def test_jsonl_round_trip(tmp_path):
    tr = _tiny_trace()
    path = tmp_path / "t.jsonl"
    obs.write_jsonl(tr, path)
    recs = obs.load_jsonl(path)
    assert recs[0]["kind"] == "meta" and recs[0]["runtime_ranges"] is True
    assert recs[-1]["kind"] == "counters"
    assert "smt.solver" in recs[-1]["values"]
    spans = {r["name"]: r for r in recs if r["kind"] == "span"}
    assert set(spans) == {"a.outer", "a.inner"}
    inner = spans["a.inner"]
    assert inner["parent"] == spans["a.outer"]["id"]
    assert inner["dur_us"] >= 0 and inner["ts_us"] >= 0
    assert isinstance(inner["attrs"]["iv"], str)    # repr-sanitized Interval
    ev, = [r for r in recs if r["kind"] == "event"]
    assert ev["name"] == "a.mark" and ev["parent"] == inner["id"]
    gg, = [r for r in recs if r["kind"] == "gauge"]
    assert gg["value"] == 2.5


def test_chrome_trace_schema(tmp_path):
    tr = _tiny_trace()
    path = tmp_path / "t.trace.json"
    obs.write_chrome_trace(tr, path, process_name="repro-test")
    with open(path) as f:
        doc = json.load(f)                      # valid JSON document
    ev = doc["traceEvents"]
    assert ev[0] == {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
                     "args": {"name": "repro-test"}}
    phs = {e["ph"] for e in ev}
    assert phs <= {"M", "X", "i", "C"}
    xs = [e for e in ev if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"a.outer", "a.inner"}
    for e in xs:                                # perfetto-required fields
        assert isinstance(e["ts"], float) and isinstance(e["dur"], float)
        assert e["cat"] == "a"
    assert any(e["ph"] == "i" and e["name"] == "a.mark" for e in ev)
    assert any(e["ph"] == "C" and e["args"]["value"] == 2.5 for e in ev)
    assert doc["otherData"]["counters"].keys() >= {"smt.solver"}


def test_jsonable_handles_numpy_and_nonfinite():
    from repro.obs.exporters import _jsonable
    assert _jsonable(np.int64(3)) == 3
    assert _jsonable(np.float64(0.5)) == 0.5
    assert _jsonable(float("inf")) == "inf"
    assert _jsonable((1, 2)) == [1, 2]
    assert _jsonable({1: np.int32(2)}) == {"1": 2}


# ---------------------------------------------------------------------------
# runtime range telemetry
# ---------------------------------------------------------------------------

def test_record_stage_ranges_saturation_headroom():
    from repro.core.fixedpoint import FixedPointType
    t = FixedPointType(8, 0, True)
    v = np.array([[t.max_value, t.min_value, 0.0, 1.0]])
    with obs.tracing(runtime_ranges=True) as tr:
        attrs = obs.runtime.record_stage("s", v, t, backend="test")
    assert attrs["min"] == t.min_value and attrs["max"] == t.max_value
    assert attrs["sat_hi"] == 1 and attrs["sat_lo"] == 1 and attrs["sat"] == 2
    assert attrs["alpha_plan"] == 8
    assert attrs["headroom"] == attrs["alpha_plan"] - attrs["alpha_obs"]
    ev, = tr.events("rt.range")
    assert ev["attrs"] == attrs


def test_record_stage_unsigned_zero_not_saturation():
    from repro.core.fixedpoint import FixedPointType
    t = FixedPointType(8, 0, False)
    with obs.tracing(runtime_ranges=True):
        attrs = obs.runtime.record_stage("s", np.zeros((4, 4)), t)
    # unsigned lower rail is 0: legitimate zero pixels must not count
    assert attrs["sat_lo"] == 0 and attrs["sat_hi"] == 0


def _saturating_phase_plan(pipe, betas=3):
    """The tests/test_lowering.py synthetic residue plan: per-phase ranges
    deliberately tighter than true so per-residue saturation engages."""
    plan = run_plan(pipe, ["interval"],
                    betas={n: betas for n in pipe.stages})
    plan.phases["interval"] = {
        "resS": ((2, 1), {(0, 0): StageRange.from_interval(
            Interval(-50.0, 50.0))}),
        "UyS": ((2, 1), {(0, 0): StageRange.from_interval(
            Interval(0.0, 150.0)),
            (1, 0): StageRange.from_interval(Interval(0.0, 250.0))}),
        "band": ((2, 2), {(0, 0): StageRange.from_interval(
            Interval(-30.0, 30.0))}),
    }
    return plan


def test_saturation_telemetry_on_residue_plan():
    pipe = dus.build_extended()
    plan = _saturating_phase_plan(pipe)
    rng = np.random.default_rng(3)
    img = rng.integers(0, 256, (48, 48)).astype(np.float64)
    with obs.tracing(runtime_ranges=True) as tr:
        run_fixed(pipe, img, plan, backend="lowered")
    by_stage = {e["attrs"]["stage"]: e["attrs"] for e in tr.events("rt.range")}
    assert set(by_stage) == set(pipe.stages)    # every stage measured
    res = by_stage["resS"]
    # the narrow aligned residue must clip on this data, and the counts must
    # be attributed per residue against that residue's own rails
    assert res["sat"] > 0
    assert res["sat_phases"] and all(k == "0,0" for k in res["sat_phases"])
    assert res["sat"] == res["sat_lo"] + res["sat_hi"]
    for a in by_stage.values():
        assert a["min"] <= a["max"]
        assert a["headroom"] == a["alpha_plan"] - a["alpha_obs"]


def test_tracing_does_not_change_lowered_outputs():
    pipe = dus.build_extended()
    plan = _saturating_phase_plan(pipe)
    rng = np.random.default_rng(9)
    img = rng.integers(0, 256, (48, 48)).astype(np.float64)
    assert not obs.is_enabled()
    plain = run_fixed(pipe, img, plan, backend="lowered")
    with obs.tracing(runtime_ranges=True):
        traced = run_fixed(pipe, img, plan, backend="lowered")
    assert sorted(plain) == sorted(traced)
    for stage in plain:
        np.testing.assert_array_equal(
            np.asarray(plain[stage]), np.asarray(traced[stage]),
            err_msg=f"{stage}: tracing changed lowered execution")


# ---------------------------------------------------------------------------
# SMT budget-exhaustion visibility
# ---------------------------------------------------------------------------

def test_budget_exhaustion_warns_events_and_diagnostics():
    p = usm.build()
    diag = {}
    with obs.tracing() as tr:
        with pytest.warns(BudgetExhaustedWarning, match="kept its interval"):
            res = analyze_smt(p, config=SMTConfig(time_budget_s=0.0),
                              diagnostics=diag)
    starved = diag["budget_exhausted"]
    assert starved                              # zero budget: all stages starve
    assert {e["attrs"]["stage"]
            for e in tr.events("smt.budget_exhausted")} == set(starved)
    asp, = tr.spans("smt.analyze")
    assert asp.attrs["budget_exhausted"] == starved
    # starved stages keep the sound interval seed (never missing/looser)
    from repro.core.range_analysis import analyze
    seed = analyze(p, "interval")
    for n in starved:
        assert res[n].range.lo >= seed[n].range.lo
        assert res[n].range.hi <= seed[n].range.hi


def test_budget_exhaustion_note_lands_in_plan_provenance():
    from repro.analysis.passes import SmtPass
    p = usm.build()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", BudgetExhaustedWarning)
        plan = run_plan(p, ["interval",
                            SmtPass(config=SMTConfig(time_budget_s=0.0))])
    notes = plan.provenance["smt"].notes
    note = [n for n in notes if n.startswith("budget-exhausted (seed kept):")]
    assert note, notes
    # ... and survives serialization, where benchmarks/alpha_delta.py reads it
    blob = json.loads(json.dumps(plan.to_json_dict()))
    assert note[0] in blob["provenance"]["smt"]["notes"]


# ---------------------------------------------------------------------------
# end-to-end acceptance: traced HCD compile + report
# ---------------------------------------------------------------------------

def test_traced_hcd_compile_end_to_end(tmp_path):
    from repro.analysis.passes import SmtPass
    pipe = hcd.build()
    rng = np.random.default_rng(17)
    img = rng.integers(0, 256, (32, 32)).astype(np.float64)
    with obs.tracing(runtime_ranges=True) as tr:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", BudgetExhaustedWarning)
            plan = run_plan(pipe, [
                "interval", SmtPass(config=SMTConfig(time_budget_s=5.0))])
        env = run_fixed(pipe, img, plan, backend="lowered")
    oracle = run_fixed(pipe, img, plan)
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(np.asarray(oracle[stage]), env[stage])

    # per-pass spans with memo disposition
    passes = tr.spans("analysis.pass")
    assert {s.attrs["pass"] for s in passes} >= {"interval", "smt"}
    assert all(s.attrs["memo"] in ("hit", "miss") for s in passes)
    # per-stage smt spans with boxes / budget / verdict
    stage_spans = tr.spans("smt.stage")
    assert stage_spans
    for s in stage_spans:
        assert s.attrs["verdict"] in ("seed", "tightened")
        assert s.attrs["boxes"] >= 0 and s.attrs["budget_s"] > 0
        assert "deadline_exhausted" in s.attrs
    # runtime telemetry for every executed stage
    rt = {e["attrs"]["stage"] for e in tr.events("rt.range")}
    assert rt == set(pipe.stages)
    # both exporters produce loadable artifacts, and the report summarizes
    obs.write_jsonl(tr, tmp_path / "hcd.jsonl")
    obs.write_chrome_trace(tr, tmp_path / "hcd.trace.json")
    with open(tmp_path / "hcd.trace.json") as f:
        assert json.load(f)["traceEvents"]
    recs = obs.load_jsonl(tmp_path / "hcd.jsonl")
    summary = report.summarize(recs)
    assert summary["passes"] and summary["smt_stages"] and summary["runtime"]
    text = report.render(summary)
    md = report.render(summary, markdown=True)
    assert "smt stages" in text and "| stage |" in md


# ---------------------------------------------------------------------------
# pallas island execution spans
# ---------------------------------------------------------------------------

def test_pallas_island_spans_and_report_breakdown(tmp_path):
    # dus at an odd height is rate-inexact: the pallas executor stitches
    # several islands, and every island call must emit one
    # `exec.pallas.island` span nested under the `exec.pallas` run span
    pipe = dus.build()
    plan = run_plan(pipe, ["interval"])
    rng = np.random.default_rng(23)
    img = rng.integers(0, 256, (47, 48)).astype(np.float64)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)   # cpu interpret note
        with obs.tracing() as tr:
            run_fixed(pipe, img, plan, backend="pallas")
    outer, = tr.spans("exec.pallas")
    isl = tr.spans("exec.pallas.island")
    assert outer.attrs["islands"] == len(isl) > 1
    for s in isl:
        assert s.parent_id == outer.span_id
        assert s.attrs["stages"] >= 1 and s.attrs["grid"] >= 1
        assert "/" in s.attrs["rate"] or s.attrs["rate"].isdigit()
        assert s.attrs["carriers"]                  # non-empty datapath census
    assert any(s.attrs["single_tile"] for s in isl)

    # the report joins the spans into a per-island breakdown table
    obs.write_jsonl(tr, tmp_path / "p.jsonl")
    summary = report.summarize(obs.load_jsonl(tmp_path / "p.jsonl"))
    rows = summary["islands"]
    assert {r["island"] for r in rows} == {s.attrs["island"] for s in isl}
    for r in rows:
        assert r["calls"] == 1 and r["ms"] >= 0
    md = report.render(summary, markdown=True)
    assert "pallas islands" in md and "single_tile" in md
