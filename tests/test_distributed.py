"""Distribution substrate tests on the 1-device debug mesh.

The full 512-device lowering is exercised by launch/dryrun.py (and its
results asserted in test_dryrun_results); here we test the mesh-size-
agnostic machinery: sharding rules, lowering, checkpointing, fault
tolerance, gradient compression, and the train loop.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.data.batches import TokenStream, make_batch
from repro.launch import sharding as shd
from repro.launch.lowering import lower_cell
from repro.launch.mesh import make_debug_mesh
from repro.launch.shapes import ShapeCell
from repro.models.registry import get_model
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
    @property
    def axis_names(self):
        return tuple(self.shape)


def test_spec_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible -> sharded
    assert shd.spec_for((256, 4096), ("batch", "mlp"), mesh) == \
        P(("data",), "model")
    # non-divisible head count -> replicated
    assert shd.spec_for((10, 128), ("kv_heads", "head_dim"), mesh) == \
        P(None, None)
    # axis reuse -> second dim replicated
    assert shd.spec_for((64, 64), ("mlp", "vocab"), mesh) == P("model", None)


def test_spec_multipod_batch():
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shd.spec_for((256, 4096), ("batch", "seq"), mesh) == \
        P(("pod", "data"), None)
    # batch=1 (long_500k) cannot shard -> replicated
    assert shd.spec_for((1, 8), ("batch", "seq"), mesh) == P(None, None)


def test_zero1_axes_picks_replicated_dim():
    mesh = FakeMesh({"data": 16, "model": 16})
    # first replicated, divisible dim gets the ZeRO axis (layers: 32 % 16 == 0)
    axes = shd.zero1_axes(("layers", "embed", "mlp"), (32, 2560, 9728), mesh)
    assert axes == ("zero1", "embed", "mlp")
    # non-divisible leading dim -> falls through to the next candidate
    axes = shd.zero1_axes(("layers", "embed", "mlp"), (30, 2560, 9728), mesh)
    assert axes == ("layers", "zero1", "mlp")


def test_train_rules_fsdp():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for((2560, 9728), ("embed", "mlp"), mesh,
                        rules=shd.TRAIN_RULES)
    assert spec == P(("data",), "model")        # 2D weight sharding


# ---------------------------------------------------------------------------
# lowering on the debug mesh (1 device) — same code path as the dry-run
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,arch", [("train", "qwen3-4b"),
                                       ("decode", "rwkv6-3b"),
                                       ("prefill", "mixtral-8x7b")])
def test_lower_cell_debug_mesh(kind, arch):
    cfg = get_smoke_config(arch)
    cell = ShapeCell(f"tiny_{kind}", kind, seq=32, global_batch=2)
    mesh = make_debug_mesh()
    lc = lower_cell(arch, cfg, cell, mesh, "debug")
    a = lc.analyses()
    assert a["flops"] > 0
    assert a["hbm_bytes"] > 0


def test_normalize_cost_analysis_shapes():
    """Regression: newer JAX returns a *list* of per-computation dicts from
    Compiled.cost_analysis(); older releases return one dict (or None).
    All consumers route through this one helper."""
    from repro.launch.lowering import normalize_cost_analysis as norm
    d = {"flops": 2.0, "bytes accessed": 8.0}
    assert norm(d) is d                       # legacy flat dict
    assert norm([d]) is d                     # current list-of-dicts
    assert norm([{}, d]) is d                 # empty entries skipped
    assert norm(None) == {}
    assert norm([]) == {}
    assert norm([{}]) == {}


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ckpt.save(str(tmp_path), 3, tree)
    restored, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_ignores_partial_writes(tmp_path):
    tree = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crash mid-write at step 2
    os.makedirs(tmp_path / "step_00000002.tmp")
    restored, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 1                      # partial write invisible
    assert not (tmp_path / "step_00000002.tmp").exists()  # gc'd


def test_checkpoint_prune(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.prune(str(tmp_path), keep=2)
    _, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 4
    assert len(ckpt._complete_steps(str(tmp_path))) == 2


def test_async_checkpointer(tmp_path):
    tree = {"x": jnp.arange(10)}
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(7, tree)
    saver.wait()
    assert saver.last_saved_step == 7
    restored, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 7


def test_deterministic_data_sharding():
    """Straggler/elastic story: shard batches are step-deterministic."""
    cfg = get_smoke_config("qwen3-4b")
    s1 = TokenStream(cfg, 8, 16, n_shards=4, shard_id=2)
    s2 = TokenStream(cfg, 8, 16, n_shards=4, shard_id=2)
    b1, b2 = s1.batch_at(5), s2.batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = s1.batch_at(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


# ---------------------------------------------------------------------------
# train loop integration
# ---------------------------------------------------------------------------

def test_train_step_reduces_loss():
    cfg = get_smoke_config("minicpm-2b")
    bundle = get_model(cfg)
    opt = AdamWConfig(lr=3e-3, schedule="constant", warmup_steps=1,
                      total_steps=50)
    step_fn = jax.jit(make_train_step(bundle, opt), donate_argnums=(0,))
    state = init_train_state(bundle, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 32, seed=0)     # overfit one batch
    losses = []
    for _ in range(30):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_grad_accumulation_matches_full_batch():
    cfg = get_smoke_config("qwen3-4b")
    bundle = get_model(cfg)
    opt = AdamWConfig(lr=1e-3, schedule="constant", grad_clip=1e9)
    state0 = init_train_state(bundle, jax.random.PRNGKey(1))
    batch = make_batch(cfg, 4, 16, seed=2)
    s_full, m_full = jax.jit(make_train_step(bundle, opt))(state0, batch)
    state0b = init_train_state(bundle, jax.random.PRNGKey(1))
    s_acc, m_acc = jax.jit(make_train_step(bundle, opt, accum_steps=2))(
        state0b, batch)
    # same data, same math up to accumulation-order rounding
    assert abs(float(m_full["loss"]) - float(m_acc["loss"])) < 1e-2
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s_full.params, s_acc.params)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_compressed_grads_still_train():
    cfg = get_smoke_config("qwen3-4b")
    bundle = get_model(cfg)
    opt = AdamWConfig(lr=3e-3, schedule="constant")
    step_fn = jax.jit(make_train_step(bundle, opt, compress_grads=True),
                      donate_argnums=(0,))
    state = init_train_state(bundle, jax.random.PRNGKey(0),
                             compress_grads=True)
    batch = make_batch(cfg, 4, 32, seed=0)
    losses = []
    for _ in range(25):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3
    # error-feedback buffers are live
    assert state.ef is not None


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written under one topology restores under another."""
    cfg = get_smoke_config("qwen3-4b")
    bundle = get_model(cfg)
    state = init_train_state(bundle, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 0, state.params)
    # restore with explicit shardings for a (1,1) debug mesh ("new" topology)
    mesh = make_debug_mesh()
    shards = shd.shardings_for_tree(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     state.params),
        bundle.param_axes(), mesh)
    restored = ckpt.restore(str(tmp_path / "step_00000000"), state.params,
                            sharding_tree=shards)
    np.testing.assert_array_equal(np.asarray(restored["embed"]),
                                  np.asarray(state.params["embed"]))
