"""Property fuzzing of the paper's core invariants over RANDOM pipelines.

hypothesis generates arbitrary stage DAGs (stencils, pointwise arithmetic,
abs/min/max/select, powers); the invariants checked are the ones the whole
synthesis flow rests on:

  I1  soundness      — concrete float execution stays inside the analyzed
                       interval of every stage, for every domain
  I2  domain order   — the intersect domain is at least as tight as
                       interval (both sound)
  I3  alpha monotone — profile alpha <= static alpha per stage
  I4  fixed exec     — with alpha from analysis and saturating arithmetic,
                       per-stage error <= an accumulated rounding bound
                       (no overflow ever)
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.intersect  # registers the "intersect" domain
from repro.core.fixedpoint import FixedPointType
from repro.core.graph import Pow
from repro.core.range_analysis import analyze
from repro.dsl.builder import PipelineBuilder, absv, ite, maxv, minv
from repro.dsl.exec import run_fixed, run_float

KERNELS = [
    ([[1, 2, 1], [2, 4, 2], [1, 2, 1]], 1 / 16),
    ([[-1, 0, 1]], 1.0),
    ([[1, 1, 1], [1, 1, 1], [1, 1, 1]], 1.0),
    ([[0, -1, 0], [-1, 4, -1], [0, -1, 0]], 1.0),
    ([[1, 4, 6, 4, 1]], 1 / 16),
]


@st.composite
def pipelines(draw):
    """A random DAG of 2-6 stages over one 8-bit input image."""
    p = PipelineBuilder("fuzz")
    handles = [p.image("img", 0, 255)]
    n_stages = draw(st.integers(2, 6))
    for i in range(n_stages):
        kind = draw(st.sampled_from(
            ["stencil", "add", "sub", "mul_const", "square", "abs",
             "minmax", "select", "affine_comb"]))
        a = handles[draw(st.integers(0, len(handles) - 1))]
        b = handles[draw(st.integers(0, len(handles) - 1))]
        name = f"s{i}"
        if kind == "stencil":
            w, sc = draw(st.sampled_from(KERNELS))
            h = p.stencil(name, a, w, scale=sc)
        elif kind == "add":
            h = p.define(name, a + b)
        elif kind == "sub":
            h = p.define(name, a - b)
        elif kind == "mul_const":
            c = draw(st.sampled_from([0.25, 0.5, 2.0, -1.0, 1.5]))
            h = p.define(name, a * c)
        elif kind == "square":
            h = p.define(name, Pow(a, 2) * (1.0 / 256))
        elif kind == "abs":
            h = p.define(name, absv(a - b))
        elif kind == "minmax":
            h = p.define(name, minv(a, b) if draw(st.booleans())
                         else maxv(a, b))
        elif kind == "select":
            t = draw(st.floats(1.0, 200.0))
            h = p.define(name, ite(absv(a - b) < t, a, b))
        else:  # affine_comb
            h = p.define(name, 0.5 * a + 0.5 * b)
        handles.append(h)
    return p.build()


def _img(seed, shape=(12, 12)):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, shape).astype(np.float64)


@given(pipelines(), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_I1_soundness_all_domains(pipe, seed):
    env = run_float(pipe, _img(seed))
    for domain in ("interval", "affine", "intersect"):
        res = analyze(pipe, domain=domain)
        for stage in pipe.topo_order():
            arr = np.asarray(env[stage])
            r = res[stage].range
            tol = 1e-6 * (1.0 + max(abs(r.lo), abs(r.hi)))
            if math.isinf(r.hi):
                continue
            assert r.lo - tol <= arr.min(), (domain, stage, r, arr.min())
            assert arr.max() <= r.hi + tol, (domain, stage, r, arr.max())


@given(pipelines())
@settings(max_examples=40, deadline=None)
def test_I2_intersect_at_least_as_tight(pipe):
    ia = analyze(pipe, domain="interval")
    x = analyze(pipe, domain="intersect")
    for stage in pipe.topo_order():
        tol = 1e-6 * (1.0 + abs(ia[stage].range.hi)
                      + abs(ia[stage].range.lo))
        if math.isinf(ia[stage].range.hi):
            continue
        assert x[stage].range.lo >= ia[stage].range.lo - tol, stage
        assert x[stage].range.hi <= ia[stage].range.hi + tol, stage


@given(pipelines(), st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_I3_profile_within_static(pipe, seed):
    from repro.core.profile import profile_pipeline
    res = analyze(pipe)
    if any(math.isinf(r.range.hi) for r in res.values()):
        return
    prof = profile_pipeline(pipe, [_img(seed), _img(seed + 1)],
                            lambda im, par: run_float(pipe, im, par))
    for stage in pipe.topo_order():
        assert prof.alpha_max[stage] <= res[stage].alpha, stage


@given(pipelines(), st.integers(0, 10_000), st.integers(4, 8))
@settings(max_examples=30, deadline=None)
def test_I4_fixed_exec_never_overflows(pipe, seed, beta):
    res = analyze(pipe)
    if any(math.isinf(r.range.hi) or r.alpha > 24 for r in res.values()):
        return
    types = {n: FixedPointType(alpha=max(r.alpha, 1), beta=beta,
                               signed=r.signed)
             for n, r in res.items()}
    img = _img(seed)
    ref = run_float(pipe, img)
    fix = run_fixed(pipe, img, types)
    for stage in pipe.topo_order():
        t = types[stage]
        arr = np.asarray(fix[stage])
        # saturating arithmetic keeps every value representable
        assert arr.min() >= t.min_value - 1e-9, stage
        assert arr.max() <= t.max_value + 1e-9, stage
        assert np.all(np.isfinite(arr)), stage
