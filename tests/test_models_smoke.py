"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.data.batches import make_batch
from repro.models.registry import get_model

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)
    loss, metrics = m.loss_fn(params, batch)
    assert np.isfinite(float(loss)), arch
    logits = m.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not np.any(np.isnan(np.asarray(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(1))
    batch = make_batch(cfg, B, S)
    (loss, _), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(params,
                                                                   batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm)), arch
    assert float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(2))
    state = m.init_decode_state(B, 32)
    tok = jnp.zeros((B,), jnp.int32)
    logits, state = m.decode_step(params, tok, state)
    assert logits.shape == (B, cfg.vocab_padded)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert int(state["length"]) == 1
    logits2, state = m.decode_step(params, tok, state)
    assert int(state["length"]) == 2


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-3b", "zamba2-2.7b",
                                  "mixtral-8x7b"])
def test_decode_matches_forward(arch):
    """Sequential one-token decode == full forward at every position."""
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(3))
    batch = make_batch(cfg, 1, 8, seed=7)
    tokens = batch["tokens"]
    full = np.asarray(m.forward(params, {"tokens": tokens}))

    state = m.init_decode_state(1, 16)
    outs = []
    for t in range(tokens.shape[1]):
        logits, state = m.decode_step(params, tokens[:, t], state)
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)                 # (1, S, V)
    # bf16 matmuls + different contraction orders: compare top-1 + loose value
    np.testing.assert_allclose(dec, full, atol=0.18, rtol=0.05)
    top_full = full.argmax(-1)
    top_dec = dec.argmax(-1)
    assert (top_full == top_dec).mean() >= 0.85


def test_paligemma_prefix_lm_mask():
    """Image-prefix positions must attend bidirectionally."""
    from repro.models.attention import _causal_mask
    m = np.asarray(_causal_mask(8, 0, prefix=4))
    assert m[0, 3]          # prefix sees later prefix tokens
    assert not m[4, 5]      # suffix remains causal
    assert m[6, 2]          # suffix sees the prefix


def test_param_counts_in_expected_range():
    """Full configs should land near their nameplate sizes."""
    from repro.configs import get_config
    expected = {"qwen3-4b": (3e9, 6e9), "deepseek-7b": (5e9, 9e9),
                "phi3-medium-14b": (11e9, 16e9), "mixtral-8x7b": (40e9, 50e9),
                "minicpm-2b": (2e9, 4e9)}
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
