"""Unit + property tests for the affine-arithmetic domain."""
import math

from _hyp_compat import given, settings, st

from repro.core.affine import AffineForm
from repro.core.interval import Interval

finite = st.floats(min_value=-1e3, max_value=1e3, allow_nan=False,
                   allow_infinity=False)


def test_cancellation_x_minus_x():
    # the paper's headline affine win: x - x == 0 exactly
    x = AffineForm.from_interval(5, 10)
    r = (x - x).to_interval()
    assert r.lo == 0.0 and r.hi == 0.0


def test_interval_no_cancellation_affine_does():
    x = AffineForm.from_interval(0, 255)
    # 2x - x = x exactly under affine
    r = (x * 2 - x).to_interval()
    assert math.isclose(r.lo, 0.0, abs_tol=1e-9)
    assert math.isclose(r.hi, 255.0, rel_tol=1e-9)


@given(st.tuples(finite, finite).map(sorted), st.tuples(finite, finite).map(sorted),
       st.floats(0, 1), st.floats(0, 1))
@settings(max_examples=200)
def test_mul_sound(ab, cd, t1, t2):
    (a, b), (c, d) = ab, cd
    x = AffineForm.from_interval(a, b)
    y = AffineForm.from_interval(c, d)
    vx = a + t1 * (b - a)
    vy = c + t2 * (d - c)
    iv = (x * y).to_interval()
    assert iv.lo - 1e-6 * (1 + abs(vx * vy)) <= vx * vy <= iv.hi + 1e-6 * (1 + abs(vx * vy))


@given(st.tuples(finite, finite).map(sorted), st.floats(0, 1))
@settings(max_examples=200)
def test_square_sound(ab, t):
    a, b = ab
    x = AffineForm.from_interval(a, b)
    v = a + t * (b - a)
    iv = (x ** 2).to_interval()
    # soundness only: the affine parabola bound may dip below 0 by r^2/2
    # (affine forms cannot represent the x^2 >= 0 constraint exactly)
    assert iv.lo <= v * v + 1e-6 * (1 + v * v)
    assert v * v <= iv.hi + 1e-6 * (1 + v * v)


@given(st.tuples(st.floats(1, 1e3), st.floats(1, 1e3)).map(sorted), st.floats(0, 1))
@settings(max_examples=200)
def test_reciprocal_sound(ab, t):
    a, b = ab
    x = AffineForm.from_interval(a, b)
    v = a + t * (b - a)
    iv = x.reciprocal().to_interval()
    assert iv.lo - 1e-9 <= 1.0 / v <= iv.hi + 1e-9


def test_div_by_interval_containing_zero_is_top():
    x = AffineForm.from_interval(1, 2)
    y = AffineForm.from_interval(-1, 1)
    iv = (x / y).to_interval()
    assert math.isinf(iv.lo) and math.isinf(iv.hi)


def test_shared_vs_fresh_symbols():
    # shared symbols correlate; fresh ones do not
    x = AffineForm.from_interval(0, 10)
    y = AffineForm.from_interval(0, 10)
    assert (x - x).to_interval().width == 0.0
    assert (x - y).to_interval().width == 20.0
