"""Plan-driven lowering: bit-exact differential battery + schedule units.

The contract under test (docs/execution_backends.md): every lowering
backend — the fused jnp program and the fused line-buffer pallas kernel —
is **bit-for-bit identical** to the per-pixel `run_fixed` numpy oracle, on
every benchmark pipeline, including per-phase-typed stages where sampling
lattice residues carry different datapaths.  Plus hypothesis fuzz over
random small pipelines with stride/upsample stages.
"""
import warnings

import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.analysis import BitwidthPlan, run_plan
from repro.core.fixedpoint import FixedPointType
from repro.core.graph import Pow
from repro.core.interval import Interval
from repro.core.range_analysis import StageRange, analyze
from repro.dsl.builder import PipelineBuilder, absv, ite, maxv
from repro.dsl.exec import make_jitted_fixed, run_fixed
from repro.lowering import (LoweringError, build_schedule, compile_backend,
                            compile_pipeline, lower, match_linear)
from repro.lowering.schedule import row_rates, stage_shapes
from repro.pipelines import dus, hcd, optical_flow, usm
from repro.pipelines import workflows as W

RNG = np.random.default_rng(1234)


def _types_for(pipe, beta=4):
    alphas, signed = W.static_alphas(pipe)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return W.types_from_alpha(pipe, alphas, signed,
                                  {n: beta for n in pipe.stages})


def _img(shape=(48, 48), seed=None, lo=0, hi=256):
    rng = np.random.default_rng(seed) if seed is not None else RNG
    return rng.integers(lo, hi, shape).astype(np.float64)


BENCHES = [
    ("usm", usm.build, dict(usm.DEFAULT_PARAMS), 1, (48, 48)),
    ("hcd", hcd.build, {}, 1, (48, 48)),
    ("dus", dus.build, {}, 1, (48, 48)),
    ("dus_ext", dus.build_extended, {}, 1, (48, 48)),
    ("of", optical_flow.build, {}, 2, (40, 40)),
    ("of_pyramid", lambda: optical_flow.build_pyramid(1), {}, 2, (40, 40)),
]


# ---------------------------------------------------------------------------
# the differential battery: lowered jnp + pallas vs the per-pixel oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,build,params,n_in,shape",
                         BENCHES, ids=[b[0] for b in BENCHES])
def test_lowered_jnp_bit_exact_all_stages(name, build, params, n_in, shape):
    pipe = build()
    types = _types_for(pipe)
    img = _img(shape, seed=7) if n_in == 1 else \
        tuple(_img(shape, seed=7 + i) for i in range(n_in))
    oracle = run_fixed(pipe, img, types, params)
    env = run_fixed(pipe, img, types, params, backend="lowered")
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(
            np.asarray(oracle[stage]), env[stage],
            err_msg=f"{name}/{stage}: lowered jnp != oracle")


@pytest.mark.parametrize("name,build,params,n_in,shape",
                         BENCHES, ids=[b[0] for b in BENCHES])
def test_pallas_bit_exact_outputs(name, build, params, n_in, shape):
    pipe = build()
    types = _types_for(pipe)
    img = _img(shape, seed=11) if n_in == 1 else \
        tuple(_img(shape, seed=11 + i) for i in range(n_in))
    oracle = run_fixed(pipe, img, types, params)
    outs = run_fixed(pipe, img, types, params, backend="pallas")
    assert sorted(outs) == sorted(pipe.outputs)
    for stage in pipe.outputs:
        np.testing.assert_array_equal(
            np.asarray(oracle[stage]), outs[stage],
            err_msg=f"{name}/{stage}: pallas != oracle")


def _phase_plan(pipe, betas=3):
    """Interval plan with synthetic per-phase sub-columns whose residues
    carry different alphas (the dus_ext resS story, made cheap for CI).

    The residue ranges are deliberately *tighter than true* so the
    per-residue saturation engages on random data — this is an executor
    differential, not a soundness test."""
    plan = run_plan(pipe, ["interval"],
                    betas={n: betas for n in pipe.stages})
    phases = {
        "resS": ((2, 1), {(0, 0): StageRange.from_interval(
            Interval(-50.0, 50.0))}),
        "UyS": ((2, 1), {(0, 0): StageRange.from_interval(
            Interval(0.0, 150.0)),
            (1, 0): StageRange.from_interval(Interval(0.0, 250.0))}),
        "band": ((2, 2), {(0, 0): StageRange.from_interval(
            Interval(-30.0, 30.0))}),
    }
    plan.phases["interval"] = phases
    return plan


def test_phase_split_stage_bit_exact_all_backends():
    """Residues with different alphas: one datapath per lattice residue,
    still bit-identical to the oracle's per-residue re-snap."""
    pipe = dus.build_extended()
    plan = _phase_plan(pipe)
    img = _img((48, 48), seed=3)
    oracle = run_fixed(pipe, img, plan)
    lp = lower(pipe, plan)
    assert lp.stages["resS"].phase is not None
    assert lp.stages["resS"].kind == "intlinear"
    env = run_fixed(pipe, img, plan, backend="lowered")
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(np.asarray(oracle[stage]), env[stage],
                                      err_msg=stage)
    outs = run_fixed(pipe, img, plan, backend="pallas")
    for stage in pipe.outputs:
        np.testing.assert_array_equal(np.asarray(oracle[stage]), outs[stage],
                                      err_msg=stage)
    # the narrow aligned residue must actually saturate somewhere on this
    # data — otherwise the phase path is not exercised
    t_u = plan.types()["resS"]
    raw = run_fixed(pipe, img, plan.types())  # union-only design
    assert not np.array_equal(np.asarray(raw["resS"]),
                              np.asarray(oracle["resS"]))


def test_phase_split_mixed_beta_falls_back_to_float_store():
    """Hand-built phase maps may change beta per residue; the lowering
    must take the float path and still match the oracle exactly."""
    pipe = dus.build_extended()
    plan = _phase_plan(pipe)
    # a residue type with a different beta than the union column
    types = plan.types()
    phase_types = {"resS": ((2, 1), {(0, 0): FixedPointType(8, 1, True)})}
    img = _img((48, 48), seed=5)
    from repro.dsl.exec import _run_concrete
    oracle = _run_concrete(pipe, img, {}, types, xp=np,
                           phase_types=phase_types)

    class FakePlan:
        def phase_types(self, column=None):
            return phase_types

        def types(self, column=None):
            return types

    lp = lower(pipe, FakePlan())
    assert lp.stages["resS"].store_float
    run = compile_backend(lp, "jnp", outputs=list(pipe.stages))
    env = run(img)
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(np.asarray(oracle[stage]), env[stage],
                                      err_msg=stage)


def test_make_jitted_fixed_is_bit_exact_wrapper():
    pipe = usm.build()
    types = _types_for(pipe)
    params = dict(usm.DEFAULT_PARAMS)
    fn = make_jitted_fixed(pipe, types, params)
    img = _img((32, 32), seed=13)
    oracle = run_fixed(pipe, img, types, params)
    out = fn(img)
    assert sorted(out) == sorted(pipe.outputs)
    for k, v in out.items():
        np.testing.assert_array_equal(np.asarray(oracle[k]), v)


def test_executor_helper_and_repeat_calls():
    setup = W.make_usm(n_train=1, n_test=1, shape=(24, 24))
    types = _types_for(setup.pipeline)
    run = setup.executor(types, backend="jnp")
    a = run(setup.test_images[0])
    b = run(setup.test_images[0])
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


# ---------------------------------------------------------------------------
# IR units
# ---------------------------------------------------------------------------

def test_match_linear_shapes():
    pipe = usm.build()
    taps, scale = match_linear(pipe.stages["blurx"].expr)
    assert scale == 1.0 / 16
    assert sorted((t.dy, t.dx, t.w) for t in taps) == \
        [(-2, 0, 1.0), (-1, 0, 4.0), (0, 0, 6.0), (1, 0, 4.0), (2, 0, 1.0)]
    # point-wise linear, multi-input, unit scale
    ext = dus.build_extended()
    taps, scale = match_linear(ext.stages["band"].expr)
    assert scale == 1.0
    assert sorted((t.stage, t.w) for t in taps) == \
        [("D5", -1.0), ("Dy", 1.0)]
    # non-linear stages don't match
    assert match_linear(hcd.build().stages["det"].expr) is None


def test_lowering_kind_selection():
    pipe = hcd.build()
    lp = lower(pipe, _types_for(pipe))
    kinds = lp.kinds()
    # box sums are dyadic-integer stencils; Sobel/12 is intlinear with an
    # f64 finishing multiply; det/harris are expr replays
    assert kinds["Sxx"] == "intlinear" and lp.stages["Sxx"].dyadic
    assert kinds["Ix"] == "intlinear" and not lp.stages["Ix"].dyadic
    assert kinds["det"] == "expr"
    assert kinds["harris"] == "expr"


def test_negative_shift_elects_wide_carrier():
    """beta_out deeper than the input grid left-shifts the finished value
    past the accumulator bound — the carrier election must account for the
    post-shift magnitude (regression: int32 wrap returned -0.0039 where
    the oracle returns 16777215.0)."""
    p = PipelineBuilder("negshift")
    a = p.image("a", 0, 2 ** 26 - 1)
    s = p.define("s0", 0.5 * (a + a))
    p.output(s)
    pipe = p.build()
    types = {"a": FixedPointType(26, 0, signed=False),
             "s0": FixedPointType(40, 8, signed=False)}
    lp = lower(pipe, types)
    ls = lp.stages["s0"]
    assert ls.kind == "intlinear" and ls.t_shift < 0
    assert ls.carrier == "int64"
    img = np.full((8, 8), 2 ** 26 - 2, dtype=np.float64)
    oracle = run_fixed(pipe, img, types)
    env = run_fixed(pipe, img, types, backend="lowered")
    np.testing.assert_array_equal(np.asarray(oracle["s0"]), env["s0"])


def test_per_axis_halo():
    pipe = usm.build()
    assert pipe.stages["blurx"].halo_yx() == (2, 0)
    assert pipe.stages["blury"].halo_yx() == (0, 2)
    assert pipe.stages["blurx"].halo() == 2


# ---------------------------------------------------------------------------
# schedule units
# ---------------------------------------------------------------------------

def test_schedule_rates_and_spans():
    pipe = dus.build_extended()
    lp = lower(pipe, _types_for(pipe))
    rates = row_rates(lp)
    assert rates["Dy"] == rates["D5"] == rates["DyS"]
    assert float(rates["Dy"]) == 0.5
    assert float(rates["Uy"]) == 1.0
    sched = build_schedule(lp, (48, 48))
    assert sched.grid * sched.tile_rows == 48
    for n, ss in sched.stages.items():
        assert ss.L <= ss.H, n
        assert ss.step >= 1, n
    # decimated stages advance half a tile per grid step
    assert sched.stages["Dy"].step * 2 == sched.stages["Uy"].step


def test_schedule_rejects_rate_inexact_heights():
    pipe = dus.build()
    lp = lower(pipe, _types_for(pipe))
    with pytest.raises(LoweringError):
        build_schedule(lp, (47, 48))       # odd height under stride 2


def test_stage_shapes_match_executor():
    pipe = dus.build_extended()
    lp = lower(pipe, _types_for(pipe))
    img = _img((48, 48), seed=17)
    env = run_fixed(pipe, img, lp.types)
    shapes = stage_shapes(lp, (48, 48))
    for n in pipe.topo_order():
        assert tuple(np.asarray(env[n]).shape) == shapes[n], n


# ---------------------------------------------------------------------------
# seeded + hypothesis fuzz: random sampled pipelines, all backends agree
# ---------------------------------------------------------------------------

KERNELS = [
    ([[1, 2, 1], [2, 4, 2], [1, 2, 1]], 1 / 16),
    ([[-1, 0, 1]], 1.0),
    ([[1, 1, 1], [1, 1, 1], [1, 1, 1]], 1.0),
    ([[1, 4, 6, 4, 1]], 1 / 16),
    ([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], 1 / 12),   # non-dyadic scale
]


def _gen_pipe(name: str, pick_int, pick_float):
    """Shared random-DAG builder; `pick_int(n)`/`pick_float(lo, hi)` are
    the randomness source (hypothesis draws or a seeded Generator).

    Combining stages only pairs handles at the SAME cumulative sampling
    rate — anything else is not a well-formed pipeline (the executors all
    reject mismatched grids)."""
    p = PipelineBuilder(name)
    handles = [(p.image("img", 0, 255), (1, 1))]    # (handle, rate)
    n_stages = 2 + pick_int(4)
    for i in range(n_stages):
        kind = ["stencil", "down", "up", "add", "sub", "mul_const",
                "square", "abs", "select"][pick_int(9)]
        a, ra = handles[pick_int(len(handles))]
        name_i = f"s{i}"
        if kind == "stencil":
            w, sc = KERNELS[pick_int(len(KERNELS))]
            h, r = p.stencil(name_i, a, w, scale=sc), ra
        elif kind == "down":
            w, sc = KERNELS[pick_int(2)]
            sy, sx = [(2, 1), (1, 2), (2, 2)][pick_int(3)]
            h = p.downsample(name_i, a, w, scale=sc, stride=(sy, sx))
            r = (ra[0] * sy, ra[1] * sx)
        elif kind == "up":
            w, sc = KERNELS[pick_int(2)]
            uy, ux = [(2, 1), (1, 2), (2, 2)][pick_int(3)]
            h = p.upsample(name_i, a, w, scale=sc, factor=(uy, ux))
            r = (ra[0] / uy, ra[1] / ux)
        elif kind in ("add", "sub", "abs", "select"):
            peers = [hb for hb, rb in handles if rb == ra]
            b = peers[pick_int(len(peers))]
            if kind == "add":
                h = p.define(name_i, a + b)
            elif kind == "sub":
                h = p.define(name_i, a - b)
            elif kind == "abs":
                h = p.define(name_i, absv(a - b))
            else:
                h = p.define(name_i, ite(absv(a - b) <
                                         pick_float(1.0, 200.0), a, b))
            r = ra
        elif kind == "mul_const":
            h = p.define(name_i, a * [0.25, 0.5, 2.0, -1.0, 1.5][pick_int(5)])
            r = ra
        else:
            h = p.define(name_i, Pow(a, 2) * (1.0 / 256))
            r = ra
        handles.append((h, r))
    return p.build()


@st.composite
def sampled_pipelines(draw):
    """Random DAGs over one 8-bit input with stride/upsample stages."""
    return _gen_pipe("fuzz_lower",
                     lambda n: draw(st.integers(0, n - 1)),
                     lambda lo, hi: draw(st.floats(lo, hi)))


def _rand_pipe(rng: np.random.Generator):
    """Seeded twin of `sampled_pipelines` (runs without hypothesis)."""
    return _gen_pipe("fuzz_lower_seeded",
                     lambda n: int(rng.integers(0, n)),
                     lambda lo, hi: float(rng.uniform(lo, hi)))


@pytest.mark.parametrize("seed", range(12))
def test_S1_seeded_random_pipelines_all_backends(seed):
    rng = np.random.default_rng(9000 + seed)
    pipe = _rand_pipe(rng)
    res = analyze(pipe)
    if any(np.isinf(r.range.hi) or r.alpha > 24 for r in res.values()):
        pytest.skip("range blow-up: executor would need >int32 carriers")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        types = {n: FixedPointType(alpha=max(r.alpha, 1),
                                   beta=int(rng.integers(0, 6)),
                                   signed=r.signed)
                 for n, r in res.items()}
    img = _img((16, 16), seed=seed)
    oracle = run_fixed(pipe, img, types)
    env = run_fixed(pipe, img, types, backend="lowered")
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(np.asarray(oracle[stage]), env[stage],
                                      err_msg=stage)
    # every DAG partitions into fused islands now — no LoweringError escape
    outs = run_fixed(pipe, img, types, backend="pallas")
    for stage in outs:
        np.testing.assert_array_equal(np.asarray(oracle[stage]), outs[stage],
                                      err_msg=f"pallas/{stage}")


@given(sampled_pipelines(), st.integers(0, 10_000), st.integers(0, 6))
@settings(max_examples=25, deadline=None)
def test_F1_lowered_jnp_matches_oracle_on_random_pipelines(pipe, seed, beta):
    res = analyze(pipe)
    if any(np.isinf(r.range.hi) or r.alpha > 24 for r in res.values()):
        return
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        types = {n: FixedPointType(alpha=max(r.alpha, 1), beta=beta,
                                   signed=r.signed)
                 for n, r in res.items()}
    img = _img((16, 16), seed=seed)
    oracle = run_fixed(pipe, img, types)
    env = run_fixed(pipe, img, types, backend="lowered")
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(np.asarray(oracle[stage]), env[stage],
                                      err_msg=stage)


@given(sampled_pipelines(), st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_F2_pallas_matches_oracle_on_random_pipelines(pipe, seed):
    res = analyze(pipe)
    if any(np.isinf(r.range.hi) or r.alpha > 24 for r in res.values()):
        return
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        types = {n: FixedPointType(alpha=max(r.alpha, 1), beta=4,
                                   signed=r.signed)
                 for n, r in res.items()}
    img = _img((16, 16), seed=seed)
    oracle = run_fixed(pipe, img, types)
    # island partitioning is total: every sampled DAG must lower to fused
    # pallas islands — a LoweringError here is a real regression
    outs = run_fixed(pipe, img, types, backend="pallas")
    for stage in outs:
        np.testing.assert_array_equal(np.asarray(oracle[stage]), outs[stage],
                                      err_msg=stage)


# ---------------------------------------------------------------------------
# stored containers: legalized narrow tiles end-to-end
# ---------------------------------------------------------------------------

from repro.core.policy import legalize
from repro.lowering import backends as B


@pytest.mark.parametrize("name,build,params,n_in,shape",
                         BENCHES, ids=[b[0] for b in BENCHES])
def test_store_dtype_is_the_legalized_container(name, build, params,
                                                n_in, shape):
    """Every integer-stored tile lives in `policy.legalize`'s smallest
    container; 33-52 exact-integer bits stay int64; float-stored stages
    stay f64 (docs/execution_backends.md, "Stored containers")."""
    pipe = build()
    lp = lower(pipe, _types_for(pipe), params=params)
    narrow = 0
    for n, ls in lp.stages.items():
        dt = np.dtype(B.store_dtype(ls))
        if ls.store_float:
            assert dt == np.float64, n
            continue
        if ls.t.width <= 32:
            lt = legalize(ls.t)
            assert lt.fp is not None and dt == np.dtype(lt.dtype), \
                f"{name}/{n}: stored {dt}, legalized {lt.container}"
        else:
            assert dt == np.int64, n
        narrow += dt.itemsize < 4
    # the beta-4 battery designs are 8-bit imaging pipelines: a plan
    # that elects zero sub-int32 containers means legalization regressed
    assert narrow, f"{name}: no stage elected a sub-int32 container"


def _narrow_pipe():
    """Handmade design whose plan elects int8 / uint8 / int16 / uint16 —
    every sub-int32 container at once."""
    p = PipelineBuilder("narrowpipe")
    a = p.image("img", 0, 15)
    d = p.define("diff", a - 7.0)
    s = p.stencil("blur", a, [[1.0, 2.0, 1.0]], scale=0.25)
    m = p.define("mix", s - d)
    p.output(m)
    pipe = p.build()
    types = {
        "img": FixedPointType(alpha=4, beta=0, signed=False),    # uint8
        "diff": FixedPointType(alpha=4, beta=0, signed=True),    # int8
        "blur": FixedPointType(alpha=4, beta=8, signed=False),   # uint16
        "mix": FixedPointType(alpha=5, beta=8, signed=True),     # int16
    }
    return pipe, types


def test_narrow_tiles_bit_exact_across_backends():
    pipe, types = _narrow_pipe()
    lp = lower(pipe, types)
    stored = {n: np.dtype(B.store_dtype(ls)) for n, ls in lp.stages.items()}
    assert stored == {"img": np.dtype(np.uint8), "diff": np.dtype(np.int8),
                      "blur": np.dtype(np.uint16), "mix": np.dtype(np.int16)}
    img = _img((24, 24), seed=13, hi=16)
    oracle = run_fixed(pipe, img, types)
    for backend in ("jnp", "pallas"):
        outs = compile_backend(lp, backend, outputs=list(pipe.stages))(img)
        for stage in pipe.topo_order():
            np.testing.assert_array_equal(
                np.asarray(oracle[stage]), outs[stage],
                err_msg=f"{backend}/{stage} (narrow containers)")


def test_saturating_phase_plan_stores_narrow_containers():
    """Per-residue saturation runs in the *union* container — which the
    plan still narrows below int32 — and stays oracle-exact."""
    pipe = dus.build_extended()
    plan = _phase_plan(pipe)
    lp = lower(pipe, plan)
    for n in ("resS", "UyS", "band"):
        ls = lp.stages[n]
        assert ls.phase is not None and not ls.store_float, n
        assert np.dtype(B.store_dtype(ls)).itemsize < 4, \
            f"{n}: saturating phase stage lost its narrow container"
    img = _img((48, 48), seed=17)
    oracle = run_fixed(pipe, img, plan)
    env = run_fixed(pipe, img, plan, backend="lowered")
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(np.asarray(oracle[stage]), env[stage],
                                      err_msg=stage)


def test_narrow_equals_wide_equals_oracle(monkeypatch):
    """Storage narrowing is value-neutral: forcing the pre-legalization
    int32/int64/f64 containers (`wide_store_dtype`) produces byte-equal
    outputs on both lowered backends."""
    pipe = dus.build_extended()
    types = _types_for(pipe)
    lp = lower(pipe, types)
    img = _img((48, 48), seed=31)
    oracle = run_fixed(pipe, img, types)
    narrow = {b: compile_backend(lp, b)(img) for b in ("jnp", "pallas")}
    monkeypatch.setattr(B, "store_dtype", B.wide_store_dtype)
    wide = {b: compile_backend(lp, b)(img) for b in ("jnp", "pallas")}
    for b in ("jnp", "pallas"):
        for stage in pipe.outputs:
            np.testing.assert_array_equal(
                np.asarray(oracle[stage]), narrow[b][stage],
                err_msg=f"{b}/{stage} narrow != oracle")
            np.testing.assert_array_equal(
                narrow[b][stage], wide[b][stage],
                err_msg=f"{b}/{stage}: narrow != wide storage")


def test_container_dtype_input_is_zero_copy_and_bit_exact():
    """The zero-copy ingestion convention: an input already in its
    stage's container dtype is treated as pre-quantized scaled integers
    and must land byte-identical to the f64 path on every backend —
    for a beta-0 8-bit input the raw uint8 frame IS the stored tile."""
    pipe = usm.build()
    params = dict(usm.DEFAULT_PARAMS)
    types = _types_for(pipe, beta=0)
    lp = lower(pipe, types, params=params)
    ls = lp.stages["img"]
    assert np.dtype(B.store_dtype(ls)) == np.uint8
    img = _img((48, 48), seed=23)
    raw = img.astype(np.uint8)              # beta=0: values == scaled ints
    assert np.array_equal(
        raw, np.asarray(B.quantize_input(img, ls.t, np.uint8, np)))
    for backend in ("interp", "jnp", "pallas"):
        run = compile_backend(lp, backend)
        a, b = run(img), run(raw)
        for stage in pipe.outputs:
            np.testing.assert_array_equal(
                np.asarray(a[stage]), np.asarray(b[stage]),
                err_msg=f"{backend}/{stage}: uint8 ingest != f64 ingest")


def test_prequantized_fractional_input_matches_f64_path():
    """Same convention off the trivial grid: beta=4 scaled ints in the
    legalized uint16 container replace the f64 quantization exactly."""
    pipe = usm.build()
    params = dict(usm.DEFAULT_PARAMS)
    types = _types_for(pipe)                # beta=4 -> 12-bit -> uint16
    lp = lower(pipe, types, params=params)
    ls = lp.stages["img"]
    dt = np.dtype(B.store_dtype(ls))
    assert dt == np.uint16
    img = _img((48, 48), seed=24)
    q = np.asarray(B.quantize_input(img, ls.t, dt, np))
    assert q.dtype == dt
    for backend in ("jnp", "pallas"):
        run = compile_backend(lp, backend)
        a, b = run(img), run(q)
        for stage in pipe.outputs:
            np.testing.assert_array_equal(
                np.asarray(a[stage]), np.asarray(b[stage]),
                err_msg=f"{backend}/{stage}: pre-quantized != f64 ingest")


@pytest.mark.parametrize("name,build,params,n_in,shape",
                         [BENCHES[0], BENCHES[3]], ids=["usm", "dus_ext"])
def test_pallas_prefetch_double_buffer_bit_exact(name, build, params,
                                                 n_in, shape):
    """Forced double-buffered band prefetch (interpret mode emulates the
    DMA copies + semaphores) stays bit-identical to the numpy oracle,
    single-frame and batched."""
    pipe = build()
    types = _types_for(pipe)
    lp = lower(pipe, types, params=params)
    run = compile_backend(lp, "pallas", prefetch=True, interpret=True)
    img = _img(shape, seed=29)
    oracle = run_fixed(pipe, img, types, params)
    outs = run(img)
    for stage in pipe.outputs:
        np.testing.assert_array_equal(
            np.asarray(oracle[stage]), outs[stage],
            err_msg=f"{name}/{stage}: prefetch kernel != oracle")
    batch = np.stack([img, _img(shape, seed=30)])
    per = [run_fixed(pipe, batch[i], types, params) for i in range(2)]
    outs_b = run(batch)
    for stage in pipe.outputs:
        np.testing.assert_array_equal(
            np.stack([np.asarray(p[stage]) for p in per]), outs_b[stage],
            err_msg=f"{name}/{stage}: batched prefetch != oracle")


@given(sampled_pipelines(), st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_F3_containers_and_prequantized_ingest_on_random_pipelines(pipe,
                                                                   seed):
    """Random DAGs: every integer-stored stage lands in its legalized
    container, and a pre-quantized container-dtype input round-trips
    bit-exact through the lowered backend."""
    res = analyze(pipe)
    if any(np.isinf(r.range.hi) or r.alpha > 24 for r in res.values()):
        return
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        types = {n: FixedPointType(alpha=max(r.alpha, 1), beta=2,
                                   signed=r.signed)
                 for n, r in res.items()}
    lp = lower(pipe, types)
    for n, ls in lp.stages.items():
        if ls.store_float or ls.t is None:
            continue
        lt = legalize(ls.t)
        if lt.fp is not None:
            assert np.dtype(B.store_dtype(ls)) == np.dtype(lt.dtype), n
    img = _img((16, 16), seed=seed)
    oracle = run_fixed(pipe, img, types)
    ls_in = lp.stages["img"]
    q = np.asarray(B.quantize_input(
        img, ls_in.t, np.dtype(B.store_dtype(ls_in)), np))
    env = compile_backend(lp, "jnp", outputs=list(pipe.stages))(q)
    for stage in pipe.topo_order():
        np.testing.assert_array_equal(np.asarray(oracle[stage]), env[stage],
                                      err_msg=stage)
