"""Assert the recorded multi-pod dry-run covered every cell successfully.

The dry-run itself runs out-of-process (it needs the 512-device placeholder
topology, which must not leak into this test process); this test validates
its committed results file — re-generate with:

    PYTHONPATH=src python -m repro.launch.dryrun
"""
import json
import os

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.shapes import SHAPES, skip_reason

RESULTS = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                       "results", "dryrun.json")


@pytest.fixture(scope="module")
def records():
    if not os.path.exists(RESULTS):
        pytest.skip("dry-run results not generated yet")
    with open(RESULTS) as f:
        return json.load(f)


def test_all_cells_present(records):
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in records}
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("pod16x16", "pod2x16x16"):
                assert (arch, shape, mesh) in seen, (arch, shape, mesh)
    # 10 archs x 4 shapes x 2 meshes
    assert len(seen) == 80


def test_no_failures(records):
    fails = [r for r in records if r["status"] == "fail"]
    assert not fails, fails


def test_skips_match_policy(records):
    for r in records:
        cfg = get_config(r["arch"])
        cell = SHAPES[r["shape"]]
        expected_skip = skip_reason(cfg, cell) is not None
        assert (r["status"] == "skip") == expected_skip, r


def test_ok_cells_have_analyses(records):
    for r in records:
        if r["status"] != "ok":
            continue
        assert r["flops"] > 0, r["arch"]
        assert r["hbm_bytes"] > 0
        assert r["memory"]["temp_size"] >= 0
        # train cells must communicate (DP grads at minimum)
        if r["shape"] == "train_4k":
            assert r["collective_bytes"].get("total", 0) > 0


def test_multi_pod_pod_axis_shards(records):
    """Multi-pod train runs shard the batch over the pod axis: per-device
    work (flops) must not exceed the single-pod figure."""
    for arch in ARCH_IDS:
        one = [r for r in records if r["arch"] == arch
               and r["shape"] == "train_4k" and r["status"] == "ok"]
        if len(one) != 2:
            continue
        single = next(r for r in one if r["mesh"] == "pod16x16")
        multi = next(r for r in one if r["mesh"] == "pod2x16x16")
        assert multi["flops"] <= single["flops"] * 1.1, arch
