"""Profile analysis (§V-A) + beta search (§V-B) + end-to-end workflows."""
import numpy as np
import pytest

from repro.core import beta_search
from repro.core.profile import np_alpha_bits
from repro.pipelines import workflows as W


def test_np_alpha_bits_matches_scalar_formula():
    from repro.core.fixedpoint import alpha_for_range
    vals = np.array([0.0, 0.4, 1.0, 255.0, 256.0, -1.0, -85.0, -0.2, 7224.9])
    got = np_alpha_bits(vals)
    want = [alpha_for_range(min(v, 0.0), max(v, 0.0)) if v != 0 else 1
            for v in vals]
    # for single values the formula reduces to alpha_for_range([min(v,0), max(v,0)])
    np.testing.assert_array_equal(got, want)


@pytest.fixture(scope="module")
def hcd_setup():
    return W.make_hcd(n_train=3, n_test=3, shape=(32, 32))


@pytest.fixture(scope="module")
def of_setup():
    return W.make_of(n_pairs=2, shape=(24, 24))


def test_profile_never_exceeds_static(hcd_setup):
    """Profile ranges are realizable -> always within static analysis."""
    alphas, _ = W.static_alphas(hcd_setup.pipeline)
    prof = hcd_setup.profile()
    for stage in hcd_setup.pipeline.stages:
        assert prof.alpha_max[stage] <= alphas[stage], stage
        assert prof.alpha_avg[stage] <= prof.alpha_max[stage], stage


def test_profile_refines_deep_stages(of_setup):
    """Paper Table IX: the static/profile gap grows with pipeline depth."""
    alphas, _ = W.static_alphas(of_setup.pipeline)
    prof = of_setup.profile()
    # last-iteration velocity: static blows up, profile stays small
    assert alphas["Vx4"] - prof.alpha_max["Vx4"] >= 20
    # shallow stages: no gap
    assert alphas["It"] == prof.alpha_max["It"] or \
        alphas["It"] - prof.alpha_max["It"] <= 1


def test_uniform_beta_search_monotone_quality():
    calls = []

    def qf(m):
        b = next(iter(m.values()))
        calls.append(b)
        return 90.0 + b          # quality rises with beta

    beta, passes = beta_search.uniform_beta_search(["a", "b"], qf, target=95.0,
                                                   beta_hi=16)
    assert beta == 5             # 90 + 5 = 95
    assert passes <= 7           # binary search, few passes (paper's point)


def test_reverse_topo_refine_drops_unneeded_bits(hcd_setup):
    p = hcd_setup.pipeline

    def qf(m):
        # only 'Ix' actually needs 3 fractional bits
        return 100.0 if m.get("Ix", 0) >= 3 else 0.0

    start = {n: 8 for n in p.topo_order()}
    refined, _ = beta_search.reverse_topo_refine(p, start, qf, target=99.0)
    assert refined["Ix"] == 3
    assert all(v == 0 for k, v in refined.items() if k != "Ix")


def test_hcd_full_flow_quality_and_cost(hcd_setup):
    """Paper Table III/IV regime: >=99% accuracy, large power/area wins."""
    alphas, signed = W.static_alphas(hcd_setup.pipeline)
    res = hcd_setup.run_beta_search(alphas, signed, beta_hi=8)
    assert res.quality >= 99.0
    assert res.profile_passes < 60          # few passes (vs simulated annealing)
    types = W.types_from_alpha(hcd_setup.pipeline, alphas, signed, res.betas)
    rep = W.design_report(hcd_setup.pipeline, types)
    assert rep["improvement"]["power"] > 2.0
    assert rep["improvement"]["area_lut"] > 2.0


def test_of_profile_types_meet_aae_target(of_setup):
    alphas, signed = W.static_alphas(of_setup.pipeline)
    prof = of_setup.profile()
    res = of_setup.run_beta_search(prof.alpha_max, signed, beta_hi=12)
    assert -res.quality <= 2.0              # AAE within 2 degrees
    types = W.types_from_alpha(of_setup.pipeline, prof.alpha_max, signed,
                               res.betas)
    rep = W.design_report(of_setup.pipeline, types)
    assert rep["improvement"]["power"] > 1.3   # paper: 1.6x


def test_dus_psnr_inf_with_enough_beta():
    b = W.make_dus(n_train=2, n_test=2, shape=(32, 32))
    alphas, signed = W.static_alphas(b.pipeline)
    # paper: PSNR -> inf achievable; beta=10 on all stages reaches exactness
    types = W.types_from_alpha(b.pipeline, alphas, signed,
                               {n: 10 for n in b.pipeline.stages})
    q = b.mean_quality(types)
    assert q > 55.0 or q == float("inf")
