"""Fused prefill vs step-by-step decode; elastic re-mesh planning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.batches import make_batch
from repro.launch import elastic
from repro.launch import sharding as shd
from repro.models.registry import get_model
from repro.serve.prefill import prefill


@pytest.mark.parametrize("arch", ["qwen3-4b", "rwkv6-3b"])
def test_prefill_matches_stepwise_decode(arch):
    cfg = get_smoke_config(arch)
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = make_batch(cfg, 1, 8, seed=4)["tokens"]

    # step-by-step reference
    state = m.init_decode_state(1, 16)
    for t in range(8):
        logits_ref, state = m.decode_step(params, toks[:, t], state)

    # fused prefill
    logits_pf, state_pf = prefill(params, toks, cfg, max_len=16)
    np.testing.assert_allclose(np.asarray(logits_pf),
                               np.asarray(logits_ref), atol=0.15, rtol=0.05)
    assert int(state_pf["length"]) == int(state["length"])

    # and both states continue identically: decode one more token
    nxt = jnp.asarray(np.argmax(np.asarray(logits_ref), -1), jnp.int32)
    l1, _ = m.decode_step(params, nxt, state)
    l2, _ = m.decode_step(params, nxt, state_pf)
    assert (np.asarray(l1).argmax(-1) == np.asarray(l2).argmax(-1)).all()


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


def test_rescale_plan_reports_layout_changes():
    cfg = get_smoke_config("qwen3-4b")
    m = get_model(cfg)
    shapes = jax.eval_shape(m.init_params, jax.random.PRNGKey(0))
    axes = m.param_axes()
    big = FakeMesh({"data": 16, "model": 16})
    degraded = FakeMesh({"data": 3, "model": 5})   # lost a rack: ragged mesh
    plan = elastic.plan_rescale(shapes, axes, big, degraded)
    assert plan.bytes_moved > 0
    # the smoke model's 64-wide dims divide 16 but not 5 -> layout changes
    # and some tensors fall back to replication (reported, not fatal)
    assert plan.resharded, "expected at least one layout change"
    assert plan.newly_replicated, "expected replication fallbacks on 5-way"


def test_rescale_restore_roundtrip(tmp_path):
    from repro.train import checkpoint as ckpt
    cfg = get_smoke_config("qwen3-4b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(1))
    ckpt.save(str(tmp_path), 0, params)
    new_mesh = jax.make_mesh((1, 1), ("data", "model"))
    restored, step = elastic.rescale_restore(str(tmp_path), params,
                                             m.param_axes(), new_mesh)
    assert step == 0
    np.testing.assert_array_equal(np.asarray(restored["embed"]),
                                  np.asarray(params["embed"]))
