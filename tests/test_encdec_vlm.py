"""Decode-vs-forward consistency for the two remaining families:
encoder-decoder (whisper) and prefix-LM VLM (paligemma)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.batches import make_batch
from repro.models import encdec
from repro.models.registry import get_model


def test_whisper_decode_matches_decode_train():
    cfg = get_smoke_config("whisper-medium")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(5))
    batch = make_batch(cfg, 1, 8, seed=9)

    enc_out = encdec.encode(params, batch["frames"], cfg)
    full = np.asarray(encdec.decode_train(params, batch["tokens"], enc_out,
                                          cfg))

    # stepwise decode against the same encoder output
    state = m.init_decode_state(1, 16)
    ck, cv = encdec.cross_kv(params, enc_out, cfg)
    state = dict(state, cross_k=ck.astype(jnp.bfloat16),
                 cross_v=cv.astype(jnp.bfloat16))
    outs = []
    for t in range(8):
        logits, state = m.decode_step(params, batch["tokens"][:, t], state)
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)
    assert (full.argmax(-1) == dec.argmax(-1)).mean() >= 0.85
    np.testing.assert_allclose(dec, full, atol=0.2, rtol=0.05)


def test_paligemma_decode_matches_forward_text_only():
    """Without an image prefix the VLM reduces to a causal LM; decode and
    forward must agree (the image path is exercised by the prefix-mask and
    smoke tests)."""
    cfg = get_smoke_config("paligemma-3b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(6))
    toks = make_batch(cfg, 1, 8, seed=3)["tokens"]
    # text-only: no patch embeds and no bidirectional prefix
    cfg_txt = dataclasses.replace(cfg, n_image_tokens=0)
    m_txt = get_model(cfg_txt)
    full = np.asarray(m_txt.forward(params, {"tokens": toks}))

    state = m_txt.init_decode_state(1, 16)
    outs = []
    for t in range(8):
        logits, state = m_txt.decode_step(params, toks[:, t], state)
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)
    assert (full.argmax(-1) == dec.argmax(-1)).mean() >= 0.85


def test_paligemma_image_prefix_changes_suffix_logits():
    """The image prefix must influence text positions after it (prefix-LM
    routing works end to end)."""
    cfg = get_smoke_config("paligemma-3b")
    m = get_model(cfg)
    params = m.init_params(jax.random.PRNGKey(7))
    batch = make_batch(cfg, 1, 16, seed=1)
    with_img = np.asarray(m.forward(params, batch))
    zero_img = dict(batch, patch_embeds=jnp.zeros_like(batch["patch_embeds"]))
    without = np.asarray(m.forward(params, zero_img))
    # suffix logits differ when the image embedding changes
    n = cfg.n_image_tokens
    assert np.abs(with_img[:, n:] - without[:, n:]).max() > 1e-3
