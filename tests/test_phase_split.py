"""Phase-split (polyphase) encoding — property battery + unit tests.

The phase-split encoder (`repro.smt.encoder.encode_stage_phases`) replaces
the alignment-blind cuts across stride/upsample stages with one exactly-
aligned expansion per output-phase residue.  Two properties must hold for
it to be shippable:

  (a) soundness   — the union-of-phases range contains every value a dense
                    concrete execution produces (borders included: edge-
                    clamping only *duplicates* in-range pixels, which the
                    independent-pixel model over-approximates);
  (b) tightness   — phase-split bounds are never looser than the
                    alignment-blind encoding at equal budget.  Asserted on
                    linear pipelines, where both sides are certified by the
                    exact affine pass (no search, no anytime noise).

Both run as seeded deterministic batteries (always) and as hypothesis
properties (when the optional dev dependency is installed — see
`_hyp_compat`).  The module also pins the acceptance-level facts on the
extended DUS pyramid (`dus.build_extended`) and covers the multi-phase
solver engines differentially (batched vs the scalar reference oracle).
"""
import math
import random
from fractions import Fraction

import numpy as np
import pytest

from _hyp_compat import given, settings, st

from repro.core.graph import Const
from repro.core.interval import Interval
from repro.core.range_analysis import analyze
from repro.dsl.builder import PipelineBuilder, absv, maxv, minv
from repro.dsl.exec import run_float
from repro.pipelines import dus, optical_flow
from repro.smt import SMTConfig, analyze_smt
from repro.smt import solver as S
from repro.smt.encoder import (closure_is_sampled, encode_stage,
                               encode_stage_phases, sampling_lattice)
from repro.smt.optimize import tighten_stage_phases

_BUDGET = SMTConfig(time_budget_s=5.0)
_BLIND = SMTConfig(time_budget_s=5.0, phase_split=False)


# ---------------------------------------------------------------------------
# random sampled-pipeline generator (shared by the seeded battery and the
# hypothesis properties — hypothesis feeds it seeds and shrinks over them)
# ---------------------------------------------------------------------------

_KERNELS_1D = ([1, 2, 1], [1, 1], [1, 3, 1], [2, 1, 1])


def _random_sampled_pipeline(seed: int, linear_only: bool):
    """2-5 stages over one 8-bit image; every stride/upsample keeps the
    cumulative grid factor in [1/4, 4] per axis so shapes stay integral and
    pointwise stages only ever combine equal-rate producers."""
    rng = random.Random(seed)
    p = PipelineBuilder(f"fuzz{seed}")
    handles = [(p.image("img", 0, 255), (Fraction(1), Fraction(1)))]
    for i in range(rng.randint(2, 5)):
        name = f"s{i}"
        h, f = handles[rng.randrange(len(handles))]
        roll = rng.random()
        if roll < 0.45:
            k = list(rng.choice(_KERNELS_1D))
            y_axis = rng.random() < 0.5
            weights = [[w] for w in k] if y_axis else [k]
            scale = 1.0 / sum(k)
            down_ok = f[0 if y_axis else 1] > Fraction(1, 4)
            up_ok = f[0 if y_axis else 1] < 4
            go_down = down_ok and (not up_ok or rng.random() < 0.5)
            if go_down:
                s = (2, 1) if y_axis else (1, 2)
                new = p.downsample(name, h, weights, scale=scale, stride=s)
                nf = (f[0] / s[0], f[1] / s[1])
            else:
                u = (2, 1) if y_axis else (1, 2)
                new = p.upsample(name, h, weights, scale=scale, factor=u)
                nf = (f[0] * u[0], f[1] * u[1])
        else:
            peers = [e for e in handles if e[1] == f]
            h2, _ = peers[rng.randrange(len(peers))]
            if roll < 0.8 or linear_only:
                c1 = rng.choice([1.0, 2.0, -1.0, 0.5, 3.0])
                c2 = rng.choice([1.0, -1.0, -2.0, 0.25])
                c0 = rng.choice([0.0, 10.0, -5.0])
                new = p.define(name, h * c1 + h2 * c2 + c0)
            else:
                op = rng.choice(["mul", "abs", "minmax"])
                if op == "mul":
                    new = p.define(
                        name, (h - 100.0) * ((h2 - 100.0) * (1.0 / 64)))
                elif op == "abs":
                    new = p.define(name, absv(h - h2))
                else:
                    fn = minv if rng.random() < 0.5 else maxv
                    new = p.define(name, fn(h, h2))
            nf = f
        handles.append((new, nf))
    return p.build()


def _check_sound(seed: int):
    pipe = _random_sampled_pipeline(seed, linear_only=False)
    sm = analyze_smt(pipe, config=_BUDGET)
    rng = np.random.default_rng(seed)
    images = [rng.integers(0, 256, (16, 16)).astype(float) for _ in range(3)]
    images += [np.zeros((16, 16)), np.full((16, 16), 255.0)]
    checker = np.indices((16, 16)).sum(axis=0) % 2 * 255.0
    images.append(checker)
    for img in images:
        env = run_float(pipe, img)
        for stage, vals in env.items():
            r = sm[stage].range
            tol = 1e-7 * max(1.0, abs(r.lo), abs(r.hi))
            assert r.lo - tol <= float(np.min(vals)), (seed, stage, r)
            assert float(np.max(vals)) <= r.hi + tol, (seed, stage, r)


def _check_not_looser_than_blind(seed: int):
    pipe = _random_sampled_pipeline(seed, linear_only=True)
    sm_phase = analyze_smt(pipe, config=_BUDGET)
    sm_blind = analyze_smt(pipe, config=_BLIND)
    for stage in pipe.topo_order():
        b, ph = sm_blind[stage].range, sm_phase[stage].range
        tol = 1e-9 * max(1.0, abs(b.lo), abs(b.hi))
        assert ph.lo >= b.lo - tol, (seed, stage, ph, b)
        assert ph.hi <= b.hi + tol, (seed, stage, ph, b)
        assert sm_phase[stage].alpha <= sm_blind[stage].alpha, (seed, stage)


@pytest.mark.parametrize("seed", range(8))
def test_phase_split_sound_vs_dense_execution(seed):
    """(a) phase-split bounds contain dense concrete execution."""
    _check_sound(seed)


@pytest.mark.parametrize("seed", range(8, 16))
def test_phase_split_not_looser_than_blind(seed):
    """(b) phase-split is never looser than alignment-blind, equal budget."""
    _check_not_looser_than_blind(seed)


@given(seed=st.integers(min_value=100, max_value=99999))
@settings(max_examples=15, deadline=None)
def test_phase_split_sound_fuzz(seed):
    _check_sound(seed)


@given(seed=st.integers(min_value=100, max_value=99999))
@settings(max_examples=15, deadline=None)
def test_phase_split_tightness_fuzz(seed):
    _check_not_looser_than_blind(seed)


# ---------------------------------------------------------------------------
# sampling lattice
# ---------------------------------------------------------------------------

def test_lattice_of_dus_tail_is_2x2():
    p = dus.build()
    assert sampling_lattice(p, "Uy") == (2, 2)
    assert sampling_lattice(p, "Ux") == (1, 2)   # x expanded, y still coarse
    assert sampling_lattice(p, "Dx") == (1, 1)   # pure decimation: integral
    assert closure_is_sampled(p, "Dx") and not closure_is_sampled(p, "img")


def test_lattice_none_on_rate_conflict():
    # root reads img both directly and through a stride-2 producer: the two
    # paths give img rates 1 and 2 — no uniform lattice, encoder falls back
    p = PipelineBuilder("conflict")
    img = p.image("img", 0, 255)
    d = p.downsample("d", img, [[1, 1]], scale=0.5, stride=(1, 2))
    p.define("mix", d + img * 0.5)
    pipe = p.build()
    assert sampling_lattice(pipe, "mix") is None
    bounds = {n: r.range for n, r in analyze(pipe).items()}
    assert encode_stage_phases(pipe, "mix", bounds) is None
    # ...and the analysis still runs (blind fallback), staying sound
    sm = analyze_smt(pipe, config=_BUDGET)
    ia = analyze(pipe)
    for s in pipe.topo_order():
        assert ia[s].range.encloses(sm[s].range), s


def test_max_phases_falls_back_to_blind():
    p = dus.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    assert encode_stage_phases(p, "Uy", bounds, max_phases=3) is None
    assert len(encode_stage_phases(p, "Uy", bounds, max_phases=4)) == 4


def test_phase_csp_shares_through_sampled_producers():
    # the blind encoder cuts every tap through Ux/Dy/Dx; each phase CSP
    # must instead reach the shared img pixels with zero sampling cuts
    p = dus.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    for csp, root in encode_stage_phases(p, "Uy", bounds):
        kinds = set(csp.kinds)
        assert "input" in kinds and "cut" not in kinds
        assert csp.is_linear()


# ---------------------------------------------------------------------------
# uniform known-bound meet (encode_stage fix)
# ---------------------------------------------------------------------------

def test_known_bound_meet_wraps_const_folded_producers():
    p = PipelineBuilder("cf")
    img = p.image("img", 0, 255)
    k = p.define("k", Const(3.0) + Const(2.0))     # folds to 5.0
    p.define("out", img * 2.0 + k)
    pipe = p.build()
    bounds = {n: r.range for n, r in analyze(pipe).items()}
    csp, root = encode_stage(pipe, "out", bounds)
    # the const-folded producer instance is wrapped in an aux var whose
    # init box met the known stage bound (uniform meet, not VAR-roots-only)
    wrapped = [i for i, n in enumerate(csp.names) if n == "k[0,0]"]
    assert wrapped, csp.names
    i = wrapped[0]
    assert csp.kinds[i] == "aux"
    assert (csp.init[i].lo, csp.init[i].hi) == (5.0, 5.0)
    assert csp.defs[i] is not None
    # and the analysis end-to-end stays exact
    sm = analyze_smt(pipe, config=_BUDGET)
    assert (sm["out"].range.lo, sm["out"].range.hi) == (5.0, 515.0)


def test_known_bound_meet_tightens_expansion_root():
    # an artificially tighter (still sound) producer bound must land in the
    # expansion root's init box — the "benefit from earlier tightening" path
    p = PipelineBuilder("mt")
    img = p.image("img", 0, 255)
    b = p.define("blur", img * 0.5)
    p.define("out", b + 1.0)
    pipe = p.build()
    bounds = {n: r.range for n, r in analyze(pipe).items()}
    bounds["blur"] = Interval(10.0, 20.0)          # pretend SMT tightened it
    csp, _ = encode_stage(pipe, "out", bounds)
    roots = [i for i, n in enumerate(csp.names) if n == "*"]
    assert roots and (csp.init[roots[0]].lo, csp.init[roots[0]].hi) == \
        (10.0, 20.0)


# ---------------------------------------------------------------------------
# extended DUS: the acceptance-level phase-split wins
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dus_ext_res():
    p = dus.build_extended()
    return (p, analyze(p), analyze_smt(p, config=SMTConfig(time_budget_s=30)),
            analyze_smt(p, config=SMTConfig(time_budget_s=30,
                                            phase_split=False)))


def test_dus_ext_band_recovers_two_alpha_bits(dus_ext_res):
    """The DoG band on the decimated grid: both operands hide behind
    stride-2 producers, so the alignment-blind encoding cuts them to
    independent [0, 255] signals (+-255, alpha 9).  The phase-split
    expansion is exact: +-255 * 60/256 = +-59.77 (alpha 7) — certified by
    the affine pass alone (the CSP is linear), no search budget involved."""
    p, ia, phase, blind = dus_ext_res
    assert ia["band"].alpha == 9 and blind["band"].alpha == 9
    assert phase["band"].alpha == 7
    assert math.isclose(phase["band"].range.hi, 255.0 * 60.0 / 256.0)
    assert math.isclose(phase["band"].range.lo, -255.0 * 60.0 / 256.0)


def test_dus_ext_residual_strictly_tighter(dus_ext_res):
    """Reconstruction residual img - Uy: every output phase shares the
    center pixel with the down-up chain (union bound +-239.06 < +-255)."""
    p, ia, phase, blind = dus_ext_res
    assert blind["res"].range.hi == 255.0 and blind["res"].range.lo == -255.0
    assert phase["res"].range.hi < 240.0
    assert phase["res"].range.lo > -240.0
    # exact union: the loosest phase shares 1/16 of the center tap's mass
    assert math.isclose(phase["res"].range.hi, 255.0 * 15.0 / 16.0)


def test_dus_ext_nesting_and_convex_stages_exact(dus_ext_res):
    p, ia, phase, blind = dus_ext_res
    for s in p.topo_order():
        assert ia[s].range.encloses(blind[s].range), s
        assert blind[s].range.encloses(phase[s].range), s
    # the paper's convex chain is already exact at [0, 255]: phase-split
    # must reproduce, not "improve", the true range
    for s in ("Dx", "Dy", "Ux", "Uy", "D5"):
        assert (phase[s].range.lo, phase[s].range.hi) == (0.0, 255.0), s


def test_dus_ext_sound_vs_dense_execution(dus_ext_res):
    p, _, phase, _ = dus_ext_res
    rng = np.random.default_rng(7)
    for _ in range(20):
        env = run_float(p, rng.integers(0, 256, (16, 16)).astype(float))
        for stage, vals in env.items():
            r = phase[stage].range
            assert r.lo - 1e-7 <= float(np.min(vals)), stage
            assert float(np.max(vals)) <= r.hi + 1e-7, stage


# ---------------------------------------------------------------------------
# multi-phase solver engines: batched vs scalar reference oracle
# ---------------------------------------------------------------------------

def _phase_entries(pipe, stage):
    bounds = {n: r.range for n, r in analyze(pipe).items()}
    entries = encode_stage_phases(pipe, stage, bounds)
    assert entries is not None
    return entries, bounds[stage]


_PHASE_DIFF = [
    ("dus", lambda: dus.build(), "Uy"),
    ("dus_ext", lambda: dus.build_extended(), "band"),
    ("dus_ext", lambda: dus.build_extended(), "res"),
    ("of_pyr", lambda: optical_flow.build_pyramid(n_iters=1), "cDenom"),
    ("of_pyr", lambda: optical_flow.build_pyramid(n_iters=1), "Vx1"),
]


@pytest.mark.parametrize("pipe_name,make,stage", _PHASE_DIFF,
                         ids=[f"{p}-{s}" for p, _, s in _PHASE_DIFF])
def test_multi_decide_batched_never_contradicts_scalar(pipe_name, make,
                                                       stage):
    """Equal-budget differential on the phase-split CSPs: the batched
    engine's verdicts must never contradict the scalar oracle's, and on
    these pinned workloads both certify the same UNSATs — `engine="scalar"`
    stays a trustworthy oracle for phase-split constraint systems."""
    entries, seed = _phase_entries(make(), stage)
    bud = S.BPBudget(48, 6)
    for frac, sense in ((1.5, "ge"), (0.5, "ge"), (1.5, "le"), (0.5, "le")):
        t = (seed.hi if sense == "ge" else seed.lo) * frac
        vs = S.decide_scalar_multi(entries, sense, t, bud)
        vb = S.decide_multi(entries, sense, t, bud)
        assert {vs.status, vb.status} != {S.SAT, S.UNSAT}, (stage, sense, t)
        if vs.status == S.UNSAT:
            assert vb.status == S.UNSAT, (stage, sense, t)


@pytest.mark.parametrize("pipe_name,make,stage", _PHASE_DIFF[:3],
                         ids=[f"{p}-{s}" for p, _, s in _PHASE_DIFF[:3]])
def test_multi_tighten_linear_phases_engine_identical(pipe_name, make,
                                                      stage):
    """All-linear phase systems are certified by the exact affine pass —
    no search runs, so both engines must return IDENTICAL bounds at any
    budget (the strongest equal-budget parity statement)."""
    import time as _t
    entries, seed = _phase_entries(make(), stage)
    assert all(c.is_linear() for c, _ in entries)
    cfg_b = SMTConfig(engine="batched", max_nodes=64, work_budget=4096)
    cfg_s = SMTConfig(engine="scalar")
    ivb = tighten_stage_phases(entries, seed, cfg_b, _t.monotonic() + 60.0)
    ivs = tighten_stage_phases(entries, seed, cfg_s, _t.monotonic() + 60.0)
    assert (ivb.lo, ivb.hi) == (ivs.lo, ivs.hi), (stage, ivb, ivs)


@pytest.mark.parametrize("pipe_name,make,stage", _PHASE_DIFF[3:],
                         ids=[f"{p}-{s}" for p, _, s in _PHASE_DIFF[3:]])
def test_multi_tighten_batched_not_looser_than_scalar(pipe_name, make,
                                                      stage):
    """On nonlinear phase systems the production-budget batched engine must
    produce bounds no looser than the scalar reference oracle (the PR-2
    contract, extended to multi-phase queries).  Node-for-node the two
    explore different trees (best-first batches vs LIFO), so parity is
    asserted at each engine's production budget, like `analyze_smt` runs
    them."""
    import time as _t
    entries, seed = _phase_entries(make(), stage)
    cfg_b = SMTConfig(engine="batched")
    cfg_s = SMTConfig(engine="scalar")
    ivb = tighten_stage_phases(entries, seed, cfg_b, _t.monotonic() + 30.0)
    ivs = tighten_stage_phases(entries, seed, cfg_s, _t.monotonic() + 30.0)
    tol = 1e-9 * max(1.0, abs(ivs.lo), abs(ivs.hi))
    assert ivb.lo >= ivs.lo - tol, (stage, ivb, ivs)
    assert ivb.hi <= ivs.hi + tol, (stage, ivb, ivs)


def test_multi_decide_sat_witness_and_budget_sharing():
    entries, seed = _phase_entries(dus.build(), "Uy")
    # all four phases are refutable above the convex maximum...
    assert S.decide_multi(entries, "ge", 255.5).status == S.UNSAT
    # ...and a witness exists just below it (shared node budget, SAT
    # short-circuits on whichever phase finds it first)
    v = S.decide_multi(entries, "ge", 254.0)
    assert v.status == S.SAT and v.witness >= 254.0
    vs = S.decide_scalar_multi(entries, "ge", 254.0)
    assert vs.status == S.SAT and vs.witness >= 254.0


def test_multi_decide_single_entry_matches_classic_decide():
    """decide(csp, ...) is decide_multi([(csp, root)], ...): node
    accounting and verdicts must be unchanged on a classic workload."""
    from repro.pipelines import hcd
    p = hcd.build()
    bounds = {n: r.range for n, r in analyze(p).items()}
    csp, root = encode_stage(p, "det", bounds)
    v1 = S.decide(csp, root, "ge", 2.0 ** 30, S.BPBudget(256, 6))
    v2 = S.decide_multi([(csp, root)], "ge", 2.0 ** 30, S.BPBudget(256, 6))
    assert v1.status == v2.status == S.UNKNOWN
    assert v1.nodes == v2.nodes == 256


# ---------------------------------------------------------------------------
# optical-flow pyramid: sampled deep pipeline end-to-end
# ---------------------------------------------------------------------------

def test_of_pyramid_nesting_and_coarse_flow_tight():
    p = optical_flow.build_pyramid(n_iters=1)
    ia = analyze(p)
    sm = analyze_smt(p, config=SMTConfig(time_budget_s=45.0))
    for s in p.topo_order():
        assert ia[s].range.encloses(sm[s].range), s
        assert sm[s].alpha <= ia[s].alpha, s
    # the coarse HS update must keep the flat-OF headline through the
    # sampling boundary: |cVx0| far below interval's 0.85*255
    assert sm["cVx0"].alpha < ia["cVx0"].alpha - 2
    assert sm["Vx1"].alpha < ia["Vx1"].alpha
