"""Serving layer: batched/sharded execution + the concurrent compile cache.

Three contracts under test (docs/serving.md):

  * **batched bit-exactness** — every lowered backend accepts a leading
    batch dimension and is bit-for-bit the per-image numpy-oracle loop,
    on every benchmark pipeline, including deliberately-saturating
    phase-split residue plans;
  * **executor cache** — the `dsl.exec` memo is a locked LRU: concurrent
    `run_fixed` calls for one key produce EXACTLY ONE compile, hits
    refresh recency, shrinking the cap evicts;
  * **PipelineServer** — fixed-batch padding, drain-on-close, and
    end-to-end oracle equality through the background batcher.
"""
import threading
import warnings

import numpy as np
import pytest

from repro import obs
from repro.core.interval import Interval
from repro.core.range_analysis import StageRange
from repro.analysis import run_plan
from repro.dsl.exec import (EXEC_CACHE_STATS, clear_executor_cache,
                            run_fixed, set_executor_cache_cap)
from repro.lowering import compile_backend, lower
from repro.pipelines import dus, hcd, optical_flow, usm
from repro.pipelines import workflows as W

RNG = np.random.default_rng(777)


def _types_for(pipe, beta=4):
    alphas, signed = W.static_alphas(pipe)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return W.types_from_alpha(pipe, alphas, signed,
                                  {n: beta for n in pipe.stages})


def _phase_plan(pipe, betas=3):
    """Deliberately-saturating residue plan (test_lowering's dus_ext
    story): residue ranges tighter than true, so per-residue saturation
    engages on random data."""
    plan = run_plan(pipe, ["interval"],
                    betas={n: betas for n in pipe.stages})
    plan.phases["interval"] = {
        "resS": ((2, 1), {(0, 0): StageRange.from_interval(
            Interval(-50.0, 50.0))}),
        "UyS": ((2, 1), {(0, 0): StageRange.from_interval(
            Interval(0.0, 150.0)),
            (1, 0): StageRange.from_interval(Interval(0.0, 250.0))}),
        "band": ((2, 2), {(0, 0): StageRange.from_interval(
            Interval(-30.0, 30.0))}),
    }
    return plan


def _batch(n_in, B, shape, seed):
    rng = np.random.default_rng(seed)
    arrs = tuple(rng.integers(0, 256, (B,) + shape).astype(np.float64)
                 for _ in range(n_in))
    return arrs if n_in > 1 else arrs[0]


BENCHES = [
    ("usm", usm.build, dict(usm.DEFAULT_PARAMS), 1, (48, 48)),
    ("hcd", hcd.build, {}, 1, (48, 48)),
    ("dus_ext", dus.build_extended, {}, 1, (48, 48)),
    ("of_pyramid", lambda: optical_flow.build_pyramid(1), {}, 2, (40, 40)),
]


# ---------------------------------------------------------------------------
# batched differential battery: every backend vs the per-image oracle loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,build,params,n_in,shape",
                         BENCHES, ids=[b[0] for b in BENCHES])
@pytest.mark.parametrize("backend", ["lowered", "pallas", "sharded"])
def test_batched_backends_bit_exact(name, build, params, n_in, shape,
                                    backend):
    pipe = build()
    types = _types_for(pipe)
    arg = _batch(n_in, 3, shape, seed=5)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        oracle = run_fixed(pipe, arg, types, params)   # per-image loop
        out = run_fixed(pipe, arg, types, params, backend=backend)
        for k in out:
            np.testing.assert_array_equal(
                np.asarray(oracle[k]), np.asarray(out[k]),
                err_msg=f"{name}/{backend}/{k}")
        # the same executor still takes single images afterwards
        single_arg = tuple(a[0] for a in arg) if n_in > 1 else arg[0]
        one = run_fixed(pipe, single_arg, types, params, backend=backend)
        for k in one:
            np.testing.assert_array_equal(
                np.asarray(oracle[k])[0], np.asarray(one[k]),
                err_msg=f"{name}/{backend}/{k}/single")


@pytest.mark.parametrize("backend", ["lowered", "pallas", "sharded"])
def test_batched_phase_split_saturating_plan_bit_exact(backend):
    """Batched residue datapaths: per-residue saturation engages and the
    batched program still matches the per-image oracle bit-for-bit."""
    pipe = dus.build_extended()
    plan = _phase_plan(pipe)
    imgs = _batch(1, 3, (48, 48), seed=9)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        lp = lower(pipe, plan)
        assert lp.stages["resS"].phase is not None
        oracle = run_fixed(pipe, imgs, plan)
        out = run_fixed(pipe, imgs, plan, backend=backend)
    for k in out:
        np.testing.assert_array_equal(np.asarray(oracle[k]),
                                      np.asarray(out[k]), err_msg=k)
    # the tightened (0,0)-residue rail must actually clip somewhere,
    # else this proved nothing
    t_res = lp.stages["resS"].phase.types[(0, 0)]
    q = np.rint(np.asarray(oracle["resS"])[:, 0::2, :] * 2.0 ** t_res.beta)
    assert (np.count_nonzero(q >= t_res.int_max)
            + np.count_nonzero(q <= t_res.int_min)) > 0


def test_sharded_explicit_mesh_and_fallback():
    """compile_backend(..., "sharded", mesh=...): the 1-device band mesh
    runs the shard_map program; a rate-inexact height partitions into
    single-tile islands that take the warned serial fallback — both
    bit-exact."""
    from repro.launch.mesh import make_band_mesh
    pipe = usm.build()
    types = _types_for(pipe)
    params = dict(usm.DEFAULT_PARAMS)
    img = _batch(1, 2, (48, 48), seed=13)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        lp = lower(pipe, types, params=params)
        run = compile_backend(lp, "sharded", mesh=make_band_mesh(1))
        oracle = run_fixed(pipe, img, types, params)
        out = run(img)
    for k in out:
        np.testing.assert_array_equal(np.asarray(oracle[k]),
                                      np.asarray(out[k]), err_msg=k)

    pyr = dus.build()                  # 47 rows: rate-inexact heights
    ptypes = _types_for(pyr)
    pimg = _batch(1, 2, (47, 48), seed=14)
    obs.reset_warn_once()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        o2 = run_fixed(pyr, pimg, ptypes, {})
        s2 = run_fixed(pyr, pimg, ptypes, {}, backend="sharded")
    caught = [w for w in rec if "serial band walk" in str(w.message)]
    for k in s2:
        np.testing.assert_array_equal(np.asarray(o2[k]),
                                      np.asarray(s2[k]), err_msg=k)
    assert caught, "expected the sharded fallback RuntimeWarning"


# ---------------------------------------------------------------------------
# batched runtime telemetry
# ---------------------------------------------------------------------------

def test_batched_telemetry_matches_per_image_sums():
    """`record_stage` on a (B, H, W) array: min/max join and rail counts
    sum over the per-image planes (the 2-D-only assumption is gone)."""
    from repro.core.fixedpoint import FixedPointType
    from repro.obs.runtime import record_stage
    t = FixedPointType(6, 2, signed=True)
    phase = ((2, 1), {(0, 0): FixedPointType(4, 2, signed=True)})
    rng = np.random.default_rng(21)
    batched = rng.uniform(-9, 9, (3, 8, 8)).round(1)
    with obs.tracing(runtime_ranges=True):
        whole = record_stage("s", batched, t, phase, backend="test")
        per = [record_stage("s", batched[b], t, phase, backend="test")
               for b in range(3)]
    assert whole["min"] == min(p["min"] for p in per)
    assert whole["max"] == max(p["max"] for p in per)
    assert whole["n"] == sum(p["n"] for p in per)
    for key in ("sat", "sat_lo", "sat_hi"):
        assert whole[key] == sum(p[key] for p in per), key
    assert whole["alpha_obs"] == max(p["alpha_obs"] for p in per)


# ---------------------------------------------------------------------------
# executor cache: locked LRU, one compile per key under contention
# ---------------------------------------------------------------------------

def test_concurrent_run_fixed_compiles_exactly_once():
    """The hammer: many threads, one (pipeline, plan, backend) key ->
    exactly one compile (miss), the rest hits, all outputs exact."""
    pipe = usm.build()
    types = _types_for(pipe)
    params = dict(usm.DEFAULT_PARAMS)
    img = _batch(1, 1, (32, 32), seed=2)[0]
    clear_executor_cache()
    EXEC_CACHE_STATS.reset()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        oracle = run_fixed(pipe, img, types, params)
        results, errors = [None] * 8, []
        barrier = threading.Barrier(8)

        def work(i):
            try:
                barrier.wait()
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore", RuntimeWarning)
                    results[i] = run_fixed(pipe, img, types, params,
                                           backend="lowered")
            except BaseException as e:       # surface, don't deadlock
                errors.append(e)

        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert not errors, errors
    assert EXEC_CACHE_STATS["misses"] == 1
    assert EXEC_CACHE_STATS["hits"] == 7
    for r in results:
        for k in r:
            np.testing.assert_array_equal(np.asarray(oracle[k]),
                                          np.asarray(r[k]), err_msg=k)


def test_executor_cache_lru_and_cap():
    """Hits refresh recency (LRU, not FIFO) and the cap is enforced with
    eviction counters; `set_executor_cache_cap` shrinks immediately."""
    pipes = {b: _types_for(usm.build(), beta=b) for b in (3, 4, 5)}
    pipe = usm.build()
    params = dict(usm.DEFAULT_PARAMS)
    img = _batch(1, 1, (32, 32), seed=4)[0]
    clear_executor_cache()
    EXEC_CACHE_STATS.reset()
    prev = set_executor_cache_cap(2)
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            run_fixed(pipe, img, pipes[3], params, backend="lowered")  # A
            run_fixed(pipe, img, pipes[4], params, backend="lowered")  # B
            run_fixed(pipe, img, pipes[3], params, backend="lowered")  # hit A
            assert EXEC_CACHE_STATS["hits"] == 1
            # C evicts the LRU entry — B, because the hit refreshed A
            run_fixed(pipe, img, pipes[5], params, backend="lowered")
            assert EXEC_CACHE_STATS["evictions"] == 1
            run_fixed(pipe, img, pipes[3], params, backend="lowered")
            assert EXEC_CACHE_STATS["hits"] == 2          # A survived
            run_fixed(pipe, img, pipes[4], params, backend="lowered")
            assert EXEC_CACHE_STATS["misses"] == 4        # B recompiled
            # shrinking the cap evicts down to size right away
            set_executor_cache_cap(1)
            assert EXEC_CACHE_STATS["evictions"] >= 2
    finally:
        set_executor_cache_cap(prev)
        clear_executor_cache()


# ---------------------------------------------------------------------------
# PipelineServer: padding, drain, oracle equality through the batcher
# ---------------------------------------------------------------------------

def test_pipeline_server_end_to_end_exact():
    from repro.serve import PipelineServer, serve_offline
    pipe = usm.build()
    types = _types_for(pipe)
    params = dict(usm.DEFAULT_PARAMS)
    frames = [_batch(1, 1, (32, 32), seed=100 + i)[0] for i in range(7)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with PipelineServer(pipe, types, params, backend="lowered",
                            batch_size=4) as srv:
            assert srv.warmup([(32, 32)]) == [(4, 32, 32)]
            assert srv.warmup([(32, 32)]) == []      # already warm
            outs = serve_offline(srv, frames)
        for f, o in zip(frames, outs):
            ref = run_fixed(pipe, f, types, params)
            for k in o:
                np.testing.assert_array_equal(np.asarray(ref[k]), o[k],
                                              err_msg=k)
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(frames[0])


def test_pipeline_server_pads_partial_batches_and_drains():
    from repro.serve import SERVE_STATS, PipelineServer
    pipe = usm.build()
    types = _types_for(pipe)
    params = dict(usm.DEFAULT_PARAMS)
    SERVE_STATS.reset()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        srv = PipelineServer(pipe, types, params, backend="lowered",
                             batch_size=4, batch_timeout_s=0.05)
        fut = srv.submit(_batch(1, 1, (32, 32), seed=7)[0])
        fut.result(timeout=60)        # lone request: padded 1 -> 4
        srv.close()
        srv.close()                   # idempotent
    assert SERVE_STATS["frames"] == 1
    assert SERVE_STATS["batches"] == 1
    assert SERVE_STATS["padded"] == 3


def test_pipeline_server_concurrent_producers_share_one_compile():
    """Multi-threaded submitters + the memo: one compile for the server's
    key even with producers racing the warmup."""
    from repro.serve import PipelineServer
    pipe = usm.build()
    types = _types_for(pipe)
    params = dict(usm.DEFAULT_PARAMS)
    clear_executor_cache()
    EXEC_CACHE_STATS.reset()
    frames = [_batch(1, 1, (32, 32), seed=200 + i)[0] for i in range(12)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        ref = run_fixed(pipe, frames[0], types, params)
        EXEC_CACHE_STATS.reset()      # count only the server's traffic
        clear_executor_cache()
        with PipelineServer(pipe, types, params, backend="lowered",
                            batch_size=4) as srv:
            futs = [None] * len(frames)

            def produce(lo, hi):
                for i in range(lo, hi):
                    futs[i] = srv.submit(frames[i])

            threads = [threading.Thread(target=produce,
                                        args=(j * 4, (j + 1) * 4))
                       for j in range(3)]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            outs = [f.result(timeout=120) for f in futs]
    assert EXEC_CACHE_STATS["misses"] == 1     # the server's own compile
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]), outs[0][k],
                                      err_msg=k)


def test_pipeline_server_zero_copy_uint8_ingestion():
    """uint8 frames on a beta-0 design are ingested zero-copy (quantized
    once at submit, stored tile == the raw pixel buffer) and produce
    byte-identical results to the same frames submitted as f64."""
    from repro.lowering import backends as B
    from repro.serve import PipelineServer, serve_offline
    pipe = usm.build()
    types = _types_for(pipe, beta=0)
    params = dict(usm.DEFAULT_PARAMS)
    lp = lower(pipe, types, params=params)
    assert np.dtype(B.store_dtype(lp.stages["img"])) == np.uint8
    f64 = [_batch(1, 1, (32, 32), seed=200 + i)[0] for i in range(5)]
    u8 = [f.astype(np.uint8) for f in f64]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        with PipelineServer(pipe, types, params, backend="lowered",
                            batch_size=4) as srv:
            srv.warmup([(32, 32)])
            outs_u8 = serve_offline(srv, u8)
        with PipelineServer(pipe, types, params, backend="lowered",
                            batch_size=4) as srv:
            outs_f64 = serve_offline(srv, f64)
    for f, a, b in zip(f64, outs_u8, outs_f64):
        ref = run_fixed(pipe, f, types, params)
        for k in a:
            np.testing.assert_array_equal(np.asarray(ref[k]), a[k],
                                          err_msg=f"uint8/{k}")
            np.testing.assert_array_equal(a[k], b[k],
                                          err_msg=f"uint8 vs f64/{k}")
