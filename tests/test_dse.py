"""repro.dse: frontier invariants, cluster soundness, closed-loop search.

Pins the guarantees docs/design_search.md advertises:

  * `Frontier` — budget gating, mutual non-domination, eviction on
    insert, stable serde;
  * `ClusterPass` — §IV homogeneity clustering is a sound widening of
    its sub-pass (`check_nesting` holds) and groups the stages the paper
    groups (HCD's Ix/Iy, Ixx/Iyy, Sxx/Syy);
  * `search_betas` / the deprecated `run_beta_search` shim are
    numerically identical on USM;
  * evaluations memoize — a re-proposed candidate never re-executes,
    and identical type maps share one compiled executor across
    evaluators (the locked-LRU executor cache);
  * `run_design_search` is deterministic under a fixed seed and, end to
    end on DUS-ext, returns a verified fixed design that beats the
    all-float design on both modeled power and area within budget.

No hypothesis imports here — this file runs in the CI no-hypothesis lane.
"""
import warnings

import numpy as np
import pytest

from repro.analysis import ClusterPass, homogeneity_clusters, stage_rates
from repro.analysis.driver import run_plan
from repro.core import cost_model
from repro.core.beta_search import refine_sequence
from repro.dse import (DSE_STATS, DesignPoint, ErrorBudget, Evaluator,
                       Frontier, PSNR_CAP, run_design_search, search_betas,
                       seed_alphas)
from repro.dsl.exec import EXEC_CACHE_STATS
from repro.pipelines import hcd
from repro.pipelines import workflows as W


@pytest.fixture(scope="module")
def usm_setup():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return W.make_usm(n_train=2, n_test=2, shape=(24, 24))


@pytest.fixture(scope="module")
def usm_plan(usm_setup):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return usm_setup.plan()


@pytest.fixture(scope="module")
def dus_setup():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        return W.make_dus_ext(n_train=2, n_test=2, shape=(24, 24))


def _point(psnr, power, area, tag="t", meets=True):
    return DesignPoint(alphas={"s": 8}, betas={"s": int(tag == "t")},
                       signed={"s": False}, psnr=psnr, max_abs_err=0.1,
                       power=power, lut_bits=area, dsp_bits=0.0,
                       bram_bits=0.0, total_bits=8, meets_budget=meets,
                       strategy=tag)


# -- frontier model ---------------------------------------------------------

def test_error_budget():
    b = ErrorBudget(min_psnr=40.0, max_abs_err=1.0)
    assert b.met_by(45.0, 0.5)
    assert not b.met_by(39.0, 0.5)
    assert not b.met_by(45.0, 2.0)
    assert ErrorBudget.from_json_dict(b.to_json_dict()) == b


def test_dominance():
    a, b = _point(50, 10, 10), _point(50, 12, 10)
    assert a.dominates(b) and not b.dominates(a)
    assert not a.dominates(a)                      # never dominates self
    c = _point(60, 12, 10)                         # trade-off: incomparable
    assert not a.dominates(c) and not c.dominates(a)


def test_frontier_add_evict_invariants():
    fr = Frontier(ErrorBudget(min_psnr=40.0))
    assert fr.add(_point(30, 5, 5, meets=False)) == "budget"
    assert fr.add(_point(50, 10, 10)) == "accepted"
    assert fr.add(_point(50, 12, 12, tag="u")) == "dominated"
    # a cheaper point evicts the dominated incumbent
    p = _point(50, 8, 8, tag="v")
    p.betas = {"s": 2}                             # distinct config key
    assert fr.add(p) == "accepted"
    assert len(fr) == 1 and fr.points()[0].strategy == "v"
    # duplicate configuration never re-enters
    assert fr.add(p) == "dominated"
    fr.check_invariants()
    assert fr.best("power").strategy == "v"


def test_frontier_json_roundtrip():
    fr = Frontier(ErrorBudget(min_psnr=40.0, max_abs_err=2.0))
    p = _point(PSNR_CAP, 10, 10)
    p.verified, p.oracle_exact = True, False
    fr.add(p)
    q = _point(50, 5, 20, tag="u")
    q.betas = {"s": 3}
    fr.add(q)
    fr2 = Frontier.from_json(fr.to_json())
    assert fr.to_json() == fr2.to_json()
    assert [r.key() for r in fr2.points()] == [r.key() for r in fr.points()]
    assert fr2.points()[-1].verified and not fr2.points()[-1].oracle_exact
    # PSNR_CAP keeps exact designs finite in strict JSON
    assert "Infinity" not in fr.to_json()


# -- homogeneity clustering (§IV) ------------------------------------------

def test_cluster_pass_groups_and_nests():
    pipe = hcd.build()
    plan = run_plan(pipe, ["interval", ClusterPass(sub="interval")])
    # the cluster column is a sound widening of its sub-column
    plan.check_nesting(["interval", "cluster(interval)"])
    clusters = homogeneity_clusters(pipe, plan.stage_ranges("interval"))
    multi = [set(c) for c in clusters if len(c) > 1]
    for pair in ({"Ix", "Iy"}, {"Ixx", "Iyy"}, {"Sxx", "Syy"}):
        assert any(pair <= m for m in multi), f"{pair} not clustered"
    # cluster alphas are the member max (here: members agree exactly)
    srs = plan.stage_ranges("cluster(interval)")
    sub = plan.stage_ranges("interval")
    for members in clusters:
        alpha = max(sub[m].alpha for m in members)
        assert all(srs[m].alpha == alpha for m in members)
    # provenance: membership is recorded in the column notes
    note = " ".join(plan.provenance["cluster(interval)"].notes)
    assert "homogeneity cluster" in note


def test_stage_rates_follow_stride(dus_setup):
    rates = stage_rates(dus_setup.pipeline)
    assert min(min(r) for r in rates.values()) < 1   # a downsampled stage
    # stages at different rates never share a cluster
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        plan = run_plan(dus_setup.pipeline, ["interval"])
    for members in homogeneity_clusters(
            dus_setup.pipeline, plan.stage_ranges("interval")):
        assert len({rates[m] for m in members}) == 1


# -- beta search un-orphaned ------------------------------------------------

def test_refine_sequence_unit():
    quality = lambda bm: 100.0 if bm["a"] >= 2 and bm["b"] >= 3 else 0.0
    betas, passes = refine_sequence(["a", "b"], {"a": 6, "b": 6},
                                    quality, target=50.0)
    assert betas == {"a": 2, "b": 3} and passes > 0


def test_shim_matches_search_betas(usm_setup):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        alphas, signed = W.static_alphas(usm_setup.pipeline)
    with pytest.warns(DeprecationWarning):
        shim = usm_setup.run_beta_search(alphas, signed, beta_hi=8)
    direct = search_betas(
        usm_setup.pipeline, alphas, signed=signed,
        images=usm_setup.train_images, target=usm_setup.quality_target,
        params=usm_setup.params,
        metric=lambda r, f, p: usm_setup.quality_of(r, f, p),
        backend="numpy", beta_hi=8)
    assert shim.betas == direct.betas
    assert shim.uniform_beta == direct.uniform_beta
    assert shim.quality == direct.quality


# -- evaluator memoization --------------------------------------------------

def test_evaluator_memoizes(usm_setup, usm_plan):
    col = usm_plan._col(None)
    ev = Evaluator(usm_setup.pipeline, usm_plan.signed(col),
                   usm_setup.train_images, ErrorBudget(min_psnr=30.0),
                   params=usm_setup.params, backend="lowered")
    alphas = usm_plan.alphas(col)
    betas = {n: 4 for n in usm_setup.pipeline.stages}
    p1 = ev.evaluate(alphas, betas, strategy="a")
    before = (dict(DSE_STATS), dict(EXEC_CACHE_STATS))
    p2 = ev.evaluate(alphas, betas, strategy="b")
    assert p2 is p1                                  # no re-execution
    assert DSE_STATS["cached"] == before[0]["cached"] + 1
    assert DSE_STATS["evaluated"] == before[0]["evaluated"]
    assert EXEC_CACHE_STATS["misses"] == before[1]["misses"]
    # a *fresh* evaluator re-executes but reuses the compiled executor:
    # the locked-LRU cache keys on the type-map content hash
    ev2 = Evaluator(usm_setup.pipeline, usm_plan.signed(col),
                    usm_setup.train_images, ErrorBudget(min_psnr=30.0),
                    params=usm_setup.params, backend="lowered")
    p3 = ev2.evaluate(alphas, betas, strategy="c")
    assert EXEC_CACHE_STATS["misses"] == before[1]["misses"]
    assert (p3.psnr, p3.max_abs_err) == (p1.psnr, p1.max_abs_err)


def test_verify_detects_tamper(usm_setup, usm_plan):
    col = usm_plan._col(None)
    ev = Evaluator(usm_setup.pipeline, usm_plan.signed(col),
                   usm_setup.train_images, ErrorBudget(min_psnr=30.0),
                   params=usm_setup.params, backend="lowered")
    p = ev.evaluate(usm_plan.alphas(col),
                    {n: 4 for n in usm_setup.pipeline.stages})
    assert not p.verified
    ev.verify(p)
    assert p.verified
    bad = DesignPoint.from_json_dict(p.to_json_dict())
    bad.psnr += 1.0
    with pytest.raises(AssertionError):
        ev.verify(bad)


# -- closed-loop driver -----------------------------------------------------

def test_seed_alphas_profile_capped(usm_plan):
    start = seed_alphas(usm_plan)
    sound = usm_plan.alphas(None)
    prof = usm_plan.alphas("profile")
    assert start == {n: min(prof[n], sound[n]) for n in sound}


def test_run_design_search_deterministic(usm_setup, usm_plan):
    kw = dict(params=usm_setup.params, seed=3, anneal_iters=8, ladder=1,
              backend="numpy")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        r1 = run_design_search(usm_setup.pipeline, usm_plan,
                               usm_setup.train_images,
                               ErrorBudget(min_psnr=45.0), **kw)
        r2 = run_design_search(usm_setup.pipeline, usm_plan,
                               usm_setup.train_images,
                               ErrorBudget(min_psnr=45.0), **kw)
    assert len(r1.frontier) > 0
    assert r1.frontier.to_json() == r2.frontier.to_json()
    assert r1.evaluations == r2.evaluations


def test_design_search_dus_ext_beats_float(dus_setup):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        plan = dus_setup.plan()
        res = run_design_search(dus_setup.pipeline, plan,
                                dus_setup.train_images,
                                ErrorBudget(min_psnr=40.0),
                                params=dus_setup.params, seed=0,
                                anneal_iters=6, ladder=1,
                                backend="lowered", verify=True)
    res.frontier.check_invariants()
    ch = res.chosen
    assert ch is not None and ch.meets_budget and ch.psnr >= 40.0
    assert all(p.verified for p in res.frontier.points())
    flt = cost_model.design_cost(
        dus_setup.pipeline, cost_model.float_design(dus_setup.pipeline))
    assert ch.power < flt.power_proxy
    assert ch.area < flt.lut_bits + flt.dsp_bits
    # provenance links every point back to the seeding plan
    assert ch.plan_hash == plan.content_hash
    assert ch.plan_column == plan._col(None)
    # the serialized result is self-consistent
    d = res.to_json_dict()
    assert d["plan_column"] == plan._col(None)
    assert len(d["frontier"]["points"]) == len(res.frontier)


# -- obs report tables ------------------------------------------------------

def test_report_renders_dse_tables():
    from repro.obs.report import render, summarize
    records = [
        {"kind": "span", "name": "dse.evaluate", "dur_us": 2000,
         "attrs": {"pipeline": "usm", "strategy": "anneal", "psnr": 50.5}},
        {"kind": "span", "name": "dse.evaluate", "dur_us": 1000,
         "attrs": {"pipeline": "usm", "strategy": "anneal", "psnr": 52.0}},
        {"kind": "event", "name": "dse.evaluate",
         "attrs": {"pipeline": "usm", "strategy": "anneal",
                   "result": "cached"}},
        {"kind": "event", "name": "dse.accept",
         "attrs": {"pipeline": "usm", "strategy": "anneal", "psnr": 52.0,
                   "power": 123.0, "area": 456.0, "total_bits": 42}},
    ]
    s = summarize(records)
    assert s["dse_strategies"] == [{"pipeline": "usm", "strategy": "anneal",
                                    "evals": 2, "cached": 1, "ms": 3.0,
                                    "best_psnr": 52.0}]
    assert s["dse_frontier"][0]["total_bits"] == 42
    out = render(s)
    assert "design search strategies" in out
    assert "design frontier (accepted points)" in out
    md = render(s, markdown=True)
    assert "| usm | anneal |" in md
